"""Train a small decoder LM with block-level ACT (compressed checkpointing).

Demonstrates the beyond-paper generalization: TinyKG's quantizer applied
per transformer block via ``act_remat`` — loss parity with the plain-remat
FP32 baseline on a learnable synthetic language.

    PYTHONPATH=src python examples/train_lm.py [--steps 150] [--bits 2]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import step_key
from repro.core.policy import policy_for_bits
from repro.data.synthetic import lm_batches
from repro.models import transformer as tf
from repro.training.optimizer import adamw

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--bits", type=int, default=2)
    args = ap.parse_args()

    cfg = tf.TransformerConfig(
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=4, d_head=16,
        d_ff=512, vocab=257, q_chunk=32, kv_chunk=32)
    policy = policy_for_bits(args.bits if args.bits else None)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"LM: {n/1e6:.2f}M params, policy bits={args.bits}")

    opt = adamw(1e-3, weight_decay=0.01, clip_norm=1.0)
    opt_state = opt.init(params)
    root = jax.random.PRNGKey(3)

    @jax.jit
    def train_step(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(tf.lm_loss)(
            params, batch, cfg=cfg, policy=policy,
            key=step_key(root, step))
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    it = lm_batches(vocab=cfg.vocab, batch=16, seq=64, seed=0, noise=0.05)
    for step in range(args.steps):
        batch = jax.tree_util.tree_map(jnp.asarray, next(it))
        params, opt_state, loss = train_step(params, opt_state, batch, step)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}: loss {float(loss):.4f}")
    # the affine-bigram language has ~5% noise -> loss floor ≈ 0.05·ln(257)
    print(f"done (floor ≈ {0.05 * jnp.log(257.0) + 0.2:.2f} nats for the "
          f"5%-noise synthetic language)")


if __name__ == "__main__":
    main()
