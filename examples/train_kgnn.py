"""End-to-end driver: train a ~100M-parameter KGIN for a few hundred steps
with the full production stack — model-step registry, fault-tolerant
Trainer, async checkpointing with run-identity metadata, SR-keyed
replay, Recall/NDCG eval.

The ~100M parameters come from the entity/relation embedding tables
(the realistic KGNN regime: params ∝ N·d): 600k entities × d=160 ≈ 96M,
plus propagation weights.

    PYTHONPATH=src python examples/train_kgnn.py [--steps 300] [--bits 2]
"""

import argparse
import os
import sys
import tempfile

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.policy import schedule_from_cli, schedule_label  # noqa: E402
from repro.data.synthetic import gen_kg_dataset  # noqa: E402
from repro.models import kgnn  # noqa: E402
from repro.models.registry import build_step  # noqa: E402
from repro.training.optimizer import adam, cosine_warmup  # noqa: E402
from repro.training.step import make_train_step, step_metadata  # noqa: E402
from repro.training.trainer import Trainer, TrainerConfig  # noqa: E402

from benchmarks.common import evaluate  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--dim", type=int, default=160)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="graph size multiplier")
    ap.add_argument("--kernel", default="jnp", choices=["jnp", "pallas"],
                    help="ACT backend (pallas = fused quant kernels; this "
                         "example's KGIN aggregation does not use act_spmm, "
                         "so the fused SPMM path applies to kgat/kgcn runs)")
    ap.add_argument("--schedule", default=None,
                    help="PolicySchedule spec (e.g. "
                         "first_layer_int8_rest_int2); overrides --bits")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    # ~600k entities at scale=1.0 -> ~100M embedding params at dim=160
    ds = gen_kg_dataset(
        n_users=int(120_000 * args.scale), n_items=int(200_000 * args.scale),
        n_attrs=int(280_000 * args.scale), n_relations=12,
        n_triples=int(1_200_000 * args.scale), inter_per_user=12, seed=0)
    cfg = kgnn.KGNNConfig(
        model="kgin", n_users=ds.n_users, n_entities=ds.n_entities,
        n_relations=ds.n_relations, dim=args.dim, n_layers=3, readout="sum")
    schedule = schedule_from_cli(args.schedule, args.bits,
                                 kernel=args.kernel)
    schedule_spec = schedule_label(args.schedule, args.bits)

    # one step definition, from the registry — the same object the
    # launcher and the DP wrapper consume (DESIGN.md §9)
    step = build_step("kgin", schedule=schedule, ds=ds, cfg=cfg,
                      batch_size=4096, data_seed=1)
    params = step.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: kgin dim={args.dim} | {n_params/1e6:.1f}M params | "
          f"{step.data_spec['n_edges']/1e6:.2f}M edges | "
          f"policy bits={args.bits}")

    opt = adam(cosine_warmup(3e-3, warmup=50, total=args.steps),
               clip_norm=1.0)
    train_step = make_train_step(step, opt, schedule=schedule,
                                 root_key=jax.random.PRNGKey(7))

    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt or tempfile.mkdtemp(prefix="kgin_ckpt_"),
        ckpt_every=100, log_every=25)
    trainer = Trainer(train_step, (params, opt.init(params)),
                      step.batches(), tcfg,
                      ckpt_meta=step_metadata(step, schedule_spec)
                      ).restore_if_available()
    state = trainer.run()

    recall, ndcg = evaluate(state[0], step.data["graph"], cfg, ds)
    print(f"final: recall@20={recall:.4f} ndcg@20={ndcg:.4f} "
          f"(ckpts in {tcfg.ckpt_dir})")


if __name__ == "__main__":
    main()
