"""Serve a DLRM-style ranking model with batched requests.

Simulates the serve_p99 path: a warm jitted scoring function, batched
request queue, latency percentiles, plus the retrieval head scoring one
query against a large candidate set.

    PYTHONPATH=src python examples/serve_recsys.py [--requests 200]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.configs.smoke import reduced
from repro.models import recsys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()

    arch = reduced(get("dlrm-mlperf"))
    cfg = arch.model_cfg
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def score(params, batch):
        return recsys.forward(params, batch, cfg, key=None)

    rng = np.random.default_rng(0)

    def request(n):
        return {
            "sparse": jnp.asarray(rng.integers(
                0, min(cfg.vocab_sizes), (n, cfg.n_sparse)), jnp.int32),
            "dense": jnp.asarray(rng.normal(size=(n, cfg.n_dense)),
                                 jnp.float32),
        }

    score(params, request(args.batch)).block_until_ready()  # warm
    lat = []
    for _ in range(args.requests):
        b = request(args.batch)
        t0 = time.perf_counter()
        score(params, b).block_until_ready()
        lat.append((time.perf_counter() - t0) * 1e3)
    lat = np.sort(np.array(lat))
    print(f"dlrm serve: batch={args.batch} n={args.requests} | "
          f"p50 {lat[len(lat)//2]:.2f}ms  p99 {lat[int(len(lat)*0.99)]:.2f}ms")

    # retrieval: one query against 100k candidates as a single batched dot
    cand = jnp.arange(min(100_000, cfg.vocab_sizes[0]))
    q = {"sparse": jnp.asarray(rng.integers(
        0, min(cfg.vocab_sizes), (cfg.n_sparse,)), jnp.int32)}
    t0 = time.perf_counter()
    scores = recsys.retrieval_scores(params, q, cand, cfg)
    top = jax.lax.top_k(scores, 10)[1].block_until_ready()
    print(f"retrieval: scored {len(cand)} candidates in "
          f"{(time.perf_counter()-t0)*1e3:.1f}ms; top10 = {np.asarray(top)}")


if __name__ == "__main__":
    main()
