"""Serve a DLRM-style recommender through the quantized serving stack.

The serve_p99 path has two stages (DESIGN.md §8):

  1. RETRIEVAL — the two-tower head (``recsys.retrieval_towers``) packed
     into a ``QuantizedEmbeddingStore``; requests flow through the
     micro-batching ``ServingEngine`` (bounded queue, bucketed padding,
     fused dequant·score·top-K scorer) instead of the old hand-rolled
     single-query dense dot.
  2. RE-RANK — the full-interaction DLRM ``forward`` scores only the
     retrieved top-K per request (a warm jitted batch).

    PYTHONPATH=src python examples/serve_recsys.py [--requests 200] [--bits 8]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.configs.smoke import reduced
from repro.models import recsys
from repro.serving import QuantizedEmbeddingStore, ServingEngine

N_CAND = 10_000        # retrieval candidate pool (item tower rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--bits", default="8", choices=["8", "4", "fp32"],
                    help="item-tower store precision")
    ap.add_argument("--k", type=int, default=32, help="retrieval top-K")
    args = ap.parse_args()

    arch = reduced(get("dlrm-mlperf"))
    cfg = arch.model_cfg
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_cand = min(N_CAND, cfg.vocab_sizes[0])
    bits = None if args.bits == "fp32" else int(args.bits)

    # -- offline rollout: pack the item tower, precompute the query pool.
    # "Users" in the store are the encoded query vectors of the simulated
    # request population (one row per request id).
    queries = rng.integers(0, min(cfg.vocab_sizes),
                           (args.requests, cfg.n_sparse)).astype(np.int32)
    user_aug, cand_aug = recsys.retrieval_towers(
        params, jnp.asarray(queries), jnp.arange(n_cand), cfg)
    # only the ITEM tower is packed: query vectors are computed per
    # request, nothing is saved by quantizing them
    store = QuantizedEmbeddingStore.from_arrays(user_aug, cand_aug, bits=bits,
                                                quantize_users=False)
    mem = store.memory_report()
    print(f"item tower: {n_cand} cands bits={args.bits} "
          f"{mem['total_bytes']} B ({mem['compression_ratio']:.2f}x vs fp32)")

    # -- stage 1: retrieval through the engine (micro-batched top-K)
    backend = "pallas" if bits is not None else "jnp"
    with ServingEngine(store, k=args.k, backend=backend,
                       buckets=(1, 4, 16, 64)) as eng:
        eng.warmup()
        futs = [eng.submit(i) for i in range(args.requests)]
        retrieved = [f.result(timeout=300) for f in futs]
    print(f"retrieval: {eng.stats()}")

    # -- stage 2: re-rank each top-K with the full DLRM forward
    @jax.jit
    def rerank(params, batch):
        return recsys.forward(params, batch, cfg, key=None)

    topk_ids = np.stack([idx for _, idx in retrieved])       # (R, k)
    first = {"sparse": jnp.asarray(np.repeat(queries[:1], args.k, 0)
                                   .copy()),
             "dense": jnp.zeros((args.k, cfg.n_dense), jnp.float32)}
    rerank(params, first).block_until_ready()                # warm
    lat = []
    best = None
    for r in range(args.requests):
        b_sparse = np.repeat(queries[r:r + 1], args.k, 0).copy()
        b_sparse[:, 0] = topk_ids[r]                         # candidate slot
        batch = {"sparse": jnp.asarray(b_sparse),
                 "dense": jnp.zeros((args.k, cfg.n_dense), jnp.float32)}
        t0 = time.perf_counter()
        scores = rerank(params, batch).block_until_ready()
        lat.append((time.perf_counter() - t0) * 1e3)
        if r == 0:
            best = topk_ids[0][int(jnp.argmax(scores))]
    lat = np.sort(np.array(lat))
    print(f"re-rank: batch={args.k} p50 {lat[len(lat) // 2]:.2f}ms "
          f"p99 {lat[int(len(lat) * 0.99)]:.2f}ms")

    # -- parity: engine retrieval vs the reference dense retrieval head
    ref = recsys.retrieval_scores(params, {"sparse": jnp.asarray(queries[0])},
                                  jnp.arange(n_cand), cfg)
    ref_top = np.asarray(jax.lax.top_k(ref, 10)[1])
    got_top = retrieved[0][1][:10]
    tag = ("exact" if bits is None else f"int{bits} store")
    print(f"top10 ({tag}) = {got_top}  | fp32 reference = {ref_top}")
    print(f"winner after re-rank for request 0: candidate {best}")


if __name__ == "__main__":
    main()
