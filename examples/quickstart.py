"""Quickstart: TinyKG in ~50 lines.

Trains KGAT on a synthetic knowledge graph with INT2-compressed
activations, compares against the FP32 baseline, and shows the per-site
``PolicySchedule`` API (INT8 first layer / INT2 rest — the tiered
schedule; activation memory is read off the residual trace).

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import first_layer_int8_rest_int2  # noqa: E402

# the benchmark harness is the supported high-level API for KGNN training
from benchmarks.common import dataset, train_kgnn  # noqa: E402


def main() -> None:
    print(f"devices: {jax.devices()}")
    ds = dataset(seed=0)
    print(f"KG: {ds.n_users} users, {ds.n_items} items, "
          f"{ds.graph.n_nodes} nodes, {len(ds.graph.src)} edges")

    fp32 = train_kgnn("kgat", bits=None, steps=120, dim=32, ds=ds)
    int2 = train_kgnn("kgat", bits=2, steps=120, dim=32, ds=ds)
    mixed = train_kgnn("kgat", bits=2, steps=120, dim=32, ds=ds,
                       schedule=first_layer_int8_rest_int2())

    print(f"\n{'':14s}{'Recall@20':>11s}{'NDCG@20':>9s}"
          f"{'ActMem':>10s}{'ms/step':>9s}")
    for name, r in [("FP32", fp32), ("TinyKG INT2", int2),
                    ("INT8/INT2", mixed)]:
        print(f"{name:14s}{r['recall@20']:11.4f}{r['ndcg@20']:9.4f}"
              f"{r['act_mem_bytes']/2**20:9.2f}M{r['step_ms']:9.1f}")
    print(f"\nactivation compression: {int2['act_mem_ratio']:.1f}x "
          f"(paper reports ~7x at INT2)")
    drop = 100 * (fp32["recall@20"] - int2["recall@20"]) / fp32["recall@20"]
    print(f"accuracy delta: {drop:+.2f}% (paper: < 2%)")


if __name__ == "__main__":
    main()
