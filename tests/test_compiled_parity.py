"""Compiled-vs-interpret parity suite (runs where a native Pallas
lowering exists: Mosaic on TPU, Triton on GPU).

The interpret-mode tests elsewhere prove the kernels match their jnp
oracles; this suite proves the COMPILED lowering matches interpret mode
— the step the CPU CI cannot take. The nightly ``kernels-compiled`` job
runs it on accelerator runners; on an interpret-only runner every test
skips with a named reason rather than silently passing, so a green run
is never mistaken for compiled coverage.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.csr import build_spmm_layout
from repro.kernels import backend, ops as kops, quant_pack as kqp
from repro.kernels import spmm as ksp
from repro.kernels import topk_score as ktk
from repro.kernels.hashrng import key_to_seed

_INFO = backend.probe_backend()
pytestmark = pytest.mark.skipif(
    not _INFO.compiled_available,
    reason=f"compiled Pallas lowering unavailable on backend="
           f"{_INFO.platform} ({_INFO.device_kind}): only interpret mode "
           f"runs here — parity suite needs Mosaic/Triton")

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_pack_compiled_bit_exact(bits):
    x = jax.random.normal(KEY, (128, 256))
    seed = key_to_seed(KEY)
    pi = kqp.quant_pack(x, seed, bits=bits, interpret=True)
    pc = kqp.quant_pack(x, seed, bits=bits, interpret=False)
    for a, b in zip(pi, pc):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_spmm_compiled_matches_interpret():
    rng = np.random.default_rng(0)
    N, E, d = 256, 2048, 128
    src = jnp.asarray(rng.integers(0, N, E))
    dst = jnp.asarray(rng.integers(0, N, E))
    x = jax.random.normal(KEY, (N, d))
    ew = jax.random.uniform(jax.random.fold_in(KEY, 1), (E,))
    lay = build_spmm_layout(src, dst, n_dst=N)
    for dma in (False, True):
        a = ksp.spmm(x, ew, lay, interpret=True, dma=dma)
        b = ksp.spmm(x, ew, lay, interpret=False, dma=dma)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_dequant_sddmm_compiled_matches_interpret():
    rng = np.random.default_rng(1)
    N, E, d = 256, 2048, 128
    src = jnp.asarray(rng.integers(0, N, E))
    dst = jnp.asarray(rng.integers(0, N, E))
    x = jax.random.normal(KEY, (N, d))
    g = jax.random.normal(jax.random.fold_in(KEY, 2), (N, d))
    lay = build_spmm_layout(src, dst, n_dst=N)
    q = kops.quantize(x, KEY, bits=4)
    for dma in (False, True):
        a = ksp.dequant_sddmm_ew(q.packed, q.scale, q.zero, g, lay,
                                 bits=4, dim=d, interpret=True, dma=dma)
        b = ksp.dequant_sddmm_ew(q.packed, q.scale, q.zero, g, lay,
                                 bits=4, dim=d, interpret=False, dma=dma)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_topk_compiled_bit_exact():
    n_items, b, d, k = 1024, 32, 128, 20
    x = jax.random.normal(KEY, (n_items, d))
    q = kops.quantize(x, KEY, bits=8)
    qv = jax.random.normal(jax.random.fold_in(KEY, 3), (b, d))
    excl = jnp.full((b, 4), -1, jnp.int32)
    vi, xi = ktk.fused_topk_scores(qv, q.packed, q.scale, q.zero, excl,
                                   bits=8, dim=d, k=k, n_items=n_items,
                                   interpret=True)
    vc, xc = ktk.fused_topk_scores(qv, q.packed, q.scale, q.zero, excl,
                                   bits=8, dim=d, k=k, n_items=n_items,
                                   interpret=False)
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(xc))
    np.testing.assert_allclose(np.asarray(vi), np.asarray(vc),
                               rtol=1e-6, atol=1e-6)
