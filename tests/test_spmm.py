"""Fused Pallas SPMM subsystem tests (interpret mode).

Covers the ISSUE-1 acceptance criteria:
  * layout construction invariants (every edge in exactly one slot)
  * forward exactness vs the ``segment_sum`` reference — bit-exact on
    exactly-representable inputs (integer grids: every partial sum is an
    exact fp32 value, so ANY accumulation order must give identical
    bits), float32-tight on gaussian inputs
  * ∇x / ∇ew gradient match at fp32 to ≤1e-5
  * unbiasedness of ∇ew under stochastic INT2/INT4 packed residuals
  * KGAT train step end-to-end under ACTPolicy(kernel="pallas") routes
    through the fused kernels (trace counters) with exact forward
  * automatic fallback to the jnp path when no layout is given
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import act_spmm
from repro.core.policy import ACTPolicy
from repro.data.csr import attach_layout, build_spmm_layout
from repro.kernels import ops as kops
from repro.kernels import spmm as ksp

KEY = jax.random.PRNGKey(0)


def _graph(N=48, E=256, d=32, seed=0, n_src=None):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_src if n_src else N, E)
    dst = rng.integers(0, N, E)
    x = jnp.asarray(rng.normal(size=(n_src or N, d)).astype(np.float32))
    ew = jnp.asarray(rng.uniform(0.1, 1.0, E).astype(np.float32))
    return jnp.asarray(src), jnp.asarray(dst), x, ew


def _ref_spmm(x, src, dst, ew, n):
    msgs = x[src] if ew is None else x[src] * ew[:, None]
    return jax.ops.segment_sum(msgs, dst, num_segments=n)


# ---------------------------------------------------------------------------
# layout invariants
# ---------------------------------------------------------------------------


def test_layout_slots_cover_each_edge_once():
    src, dst, _, _ = _graph(N=37, E=300)
    lay = build_spmm_layout(src, dst, n_dst=37, block_e=32, block_rows=8)
    m = lay.meta
    perm = np.asarray(lay.perm_blk).ravel()
    real = perm[perm < m.n_edges]
    assert sorted(real.tolist()) == list(range(m.n_edges))
    # every real slot reproduces its original edge
    src_np, dst_np = np.asarray(src), np.asarray(dst)
    slot_src = np.asarray(lay.src_blk).ravel()
    slot_dstg = np.asarray(lay.dstg_blk).ravel()
    slot_ldst = np.asarray(lay.ldst_blk).ravel()
    tile = np.repeat(np.asarray(lay.tile_of_blk), m.block_e)
    mask = perm < m.n_edges
    np.testing.assert_array_equal(slot_src[mask], src_np[perm[mask]])
    np.testing.assert_array_equal(slot_dstg[mask], dst_np[perm[mask]])
    np.testing.assert_array_equal(
        slot_ldst[mask] + tile[mask] * m.block_rows, dst_np[perm[mask]])
    # blocks of one tile are consecutive (the revisiting contract)
    t = np.asarray(lay.tile_of_blk)
    assert (np.diff(t) >= 0).all() and len(t) == m.n_blocks


def test_layout_empty_tiles_get_pad_blocks():
    # all edges land in tile 0; tiles 1..5 must still own one pad block
    src = jnp.arange(20, dtype=jnp.int32)
    dst = jnp.zeros(20, dtype=jnp.int32)
    lay = build_spmm_layout(src, dst, n_dst=48, block_e=16, block_rows=8)
    assert lay.meta.n_tiles == 6
    assert sorted(np.asarray(lay.tile_of_blk).tolist()).count(5) == 1
    out = ksp.spmm(jnp.ones((48, 4)), None, lay, interpret=True)
    assert out.shape == (48, 4)
    np.testing.assert_array_equal(np.asarray(out[1:]), 0.0)


# ---------------------------------------------------------------------------
# forward exactness
# ---------------------------------------------------------------------------


def test_forward_bit_exact_on_exact_inputs():
    """Integer-grid inputs: all products/sums are exact fp32 integers, so
    the fused kernel must match segment_sum BIT-exactly."""
    rng = np.random.default_rng(3)
    N, E, d = 40, 500, 24
    src = jnp.asarray(rng.integers(0, N, E))
    dst = jnp.asarray(rng.integers(0, N, E))
    x = jnp.asarray(rng.integers(-8, 9, (N, d)).astype(np.float32))
    ew = jnp.asarray(rng.integers(0, 5, E).astype(np.float32))
    lay = build_spmm_layout(src, dst, n_dst=N, block_e=64, block_rows=16)
    out = ksp.spmm(x, ew, lay, interpret=True)
    ref = _ref_spmm(x, src, dst, ew, N)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("block_e,block_rows,block_d",
                         [(256, 256, None), (32, 16, 8)])
def test_forward_matches_reference_float(block_e, block_rows, block_d):
    src, dst, x, ew = _graph(N=50, E=400, d=40)
    lay = build_spmm_layout(src, dst, n_dst=50, block_e=block_e,
                            block_rows=block_rows)
    out = ksp.spmm(x, ew, lay, block_d=block_d, interpret=True)
    ref = _ref_spmm(x, src, dst, ew, 50)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_forward_unweighted_and_transpose_rectangular():
    # n_src != n_dst exercises the gathered-global-table (shard_map) shape
    src, dst, x, ew = _graph(N=30, E=200, d=24, n_src=70)
    lay = build_spmm_layout(src, dst, n_dst=30, n_src=70,
                            block_e=32, block_rows=8)
    np.testing.assert_allclose(
        np.asarray(ksp.spmm(x, None, lay, interpret=True)),
        np.asarray(_ref_spmm(x, src, dst, None, 30)), rtol=1e-6, atol=1e-6)
    g = jax.random.normal(KEY, (30, 24))
    ref_t = jax.ops.segment_sum(g[dst] * ew[:, None], src, num_segments=70)
    np.testing.assert_allclose(
        np.asarray(ksp.spmm(g, ew, lay, transpose=True, interpret=True)),
        np.asarray(ref_t), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# SDDMM (∇ew) kernels
# ---------------------------------------------------------------------------


def test_sddmm_fp32_matches_reference():
    src, dst, x, _ = _graph(N=44, E=300, d=36)
    g = jax.random.normal(jax.random.fold_in(KEY, 1), (44, 36))
    lay = build_spmm_layout(src, dst, n_dst=44, block_e=64, block_rows=16)
    out = ksp.sddmm_ew(x, g, lay, interpret=True)
    ref = jnp.sum(x[src] * g[dst], axis=-1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_dequant_sddmm_reads_packed_residual(bits):
    """Fused shift+mask dequant inside the SDDMM must equal dequantize-
    then-SDDMM on the same QTensor."""
    src, dst, x, _ = _graph(N=32, E=200, d=64)
    g = jax.random.normal(jax.random.fold_in(KEY, 2), (32, 64))
    lay = build_spmm_layout(src, dst, n_dst=32, block_e=64, block_rows=16)
    q = kops.quantize(x, KEY, bits=bits)
    xh = kops.dequantize(q)
    ref = jnp.sum(xh[src] * g[dst], axis=-1)
    out = ksp.dequant_sddmm_ew(q.packed, q.scale, q.zero, g, lay,
                               bits=bits, dim=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# HBM-DMA double-buffered variants vs the VMEM-resident kernels
# ---------------------------------------------------------------------------


def test_dma_spmm_forward_bit_exact_vs_vmem():
    """The DMA gather feeds the SAME one-hot matmul in the same block
    order, so forward and transpose must match the VMEM kernel BIT-exactly
    — not just within tolerance."""
    src, dst, x, ew = _graph(N=64, E=512, d=48, seed=9)
    lay = build_spmm_layout(src, dst, n_dst=64, block_e=64, block_rows=16)
    for transpose in (False, True):
        a = ksp.spmm(x, ew, lay, transpose=transpose, dma=False,
                     interpret=True)
        b = ksp.spmm(x, ew, lay, transpose=transpose, dma=True,
                     interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # unweighted + rectangular (n_src != n_dst) through the DMA path
    src2, dst2, x2, _ = _graph(N=30, E=200, d=24, n_src=70, seed=10)
    lay2 = build_spmm_layout(src2, dst2, n_dst=30, n_src=70,
                             block_e=32, block_rows=8)
    np.testing.assert_array_equal(
        np.asarray(ksp.spmm(x2, None, lay2, dma=True, interpret=True)),
        np.asarray(ksp.spmm(x2, None, lay2, dma=False, interpret=True)))


@pytest.mark.parametrize("bits", [4, 8])
def test_dma_dequant_sddmm_matches_vmem(bits):
    """Streaming packed rows + g rows from HBM changes only the data
    movement; the single full-width reduction may reassociate vs the
    per-tile accumulation, so parity is ≤1e-5, not bit-exact."""
    src, dst, x, _ = _graph(N=48, E=320, d=64, seed=12)
    g = jax.random.normal(jax.random.fold_in(KEY, 3), (48, 64))
    lay = build_spmm_layout(src, dst, n_dst=48, block_e=64, block_rows=16)
    q = kops.quantize(x, KEY, bits=bits)
    a = ksp.dequant_sddmm_ew(q.packed, q.scale, q.zero, g, lay,
                             bits=bits, dim=64, dma=False, interpret=True)
    b = ksp.dequant_sddmm_ew(q.packed, q.scale, q.zero, g, lay,
                             bits=bits, dim=64, dma=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_vmem_budget_routes_to_dma_and_grads_match(monkeypatch):
    """With the VMEM budget forced below the node-table size, ops.spmm /
    ops.spmm_grad_ew must route to the DMA kernels (trace counters) and
    end-to-end act_spmm grads must still match the reference to ≤1e-5."""
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")  # 4 KB: nothing fits
    src, dst, x, ew = _graph(N=40, E=220, d=32, seed=7)
    lay = build_spmm_layout(src, dst, n_dst=40, block_e=64, block_rows=16)

    base = dict(kops.TRACE_COUNTS)
    out = kops.spmm(x, ew, lay)
    used = {k: kops.TRACE_COUNTS[k] - base.get(k, 0)
            for k in kops.TRACE_COUNTS}
    assert used.get("spmm_dma", 0) == 1 and used.get("spmm", 0) == 0
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(ksp.spmm(x, ew, lay, dma=False, interpret=True)))

    def ref_loss(x_, ew_):
        return (_ref_spmm(x_, src, dst, ew_, 40) ** 2).sum()

    def act_loss(x_, ew_):
        pol = ACTPolicy(bits=None, kernel="pallas")  # fp32 residual
        return (act_spmm(x_, src, dst, ew_, num_nodes=40, key=KEY,
                         policy=pol, layout=lay) ** 2).sum()

    ex, eew = jax.grad(ref_loss, argnums=(0, 1))(x, ew)
    gx, gew = jax.grad(act_loss, argnums=(0, 1))(x, ew)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gew), np.asarray(eew),
                               rtol=1e-5, atol=1e-5)

    # packed residual: ∇ew must route through the DMA dequant-SDDMM
    base = dict(kops.TRACE_COUNTS)
    q = kops.quantize(x, KEY, bits=4)
    g = jax.random.normal(jax.random.fold_in(KEY, 4), (40, 32))
    dew = kops.spmm_grad_ew(q, g, lay)
    used = {k: kops.TRACE_COUNTS[k] - base.get(k, 0)
            for k in kops.TRACE_COUNTS}
    assert used.get("dequant_sddmm_dma", 0) == 1
    ref = ksp.dequant_sddmm_ew(q.packed, q.scale, q.zero, g, lay,
                               bits=4, dim=32, dma=False, interpret=True)
    np.testing.assert_allclose(np.asarray(dew), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# act_spmm integration: gradients
# ---------------------------------------------------------------------------


def _grad_setup(N=40, E=220, d=32, seed=7):
    src, dst, x, ew = _graph(N=N, E=E, d=d, seed=seed)
    lay = build_spmm_layout(src, dst, n_dst=N, block_e=64, block_rows=16)

    def ref_loss(x_, ew_):
        return (_ref_spmm(x_, src, dst, ew_, N) ** 2).sum()

    def act_loss(x_, ew_, pol, key=KEY):
        return (act_spmm(x_, src, dst, ew_, num_nodes=N, key=key,
                         policy=pol, layout=lay) ** 2).sum()

    return x, ew, ref_loss, act_loss


def test_act_spmm_pallas_fp32_grads_match_1e5():
    """Acceptance: ∇x and ∇ew at fp32 match the reference to ≤1e-5."""
    x, ew, ref_loss, act_loss = _grad_setup()
    pol = ACTPolicy(bits=None, kernel="pallas")  # fp32 residual, fused path
    ex, eew = jax.grad(ref_loss, argnums=(0, 1))(x, ew)
    gx, gew = jax.jit(jax.grad(
        lambda x_, ew_: act_loss(x_, ew_, pol), argnums=(0, 1)))(x, ew)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gew), np.asarray(eew),
                               rtol=1e-5, atol=1e-5)


def test_act_spmm_pallas_dx_exact_under_quantization():
    """∇x uses only indices+weights — exact whatever the residual bits."""
    x, ew, ref_loss, act_loss = _grad_setup()
    ex = jax.grad(lambda x_: ref_loss(x_, ew))(x)
    for bits in (8, 2):
        gx = jax.grad(lambda x_: act_loss(x_, ew, ACTPolicy(
            bits=bits, kernel="pallas")))(x)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(ex),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("bits,n_seeds,tol", [(4, 48, 0.05), (2, 96, 0.08)])
def test_act_spmm_pallas_dew_unbiased(bits, n_seeds, tol):
    """Mean ∇ew over SR seeds converges to the exact gradient (CI scales
    as 1/sqrt(n_seeds); tolerances sit several sigmas out)."""
    x, ew, ref_loss, act_loss = _grad_setup(N=24, E=96, d=16, seed=11)
    eew = jax.grad(ref_loss, argnums=1)(x, ew)
    pol = ACTPolicy(bits=bits, stochastic=True, kernel="pallas")
    gfn = jax.jit(jax.grad(
        lambda ew_, key: act_loss(x, ew_, pol, key), argnums=0))
    acc = np.zeros(ew.shape, np.float64)
    for s in range(n_seeds):
        acc += np.asarray(gfn(ew, jax.random.fold_in(KEY, s)),
                          dtype=np.float64)
    rel = float(np.abs(acc / n_seeds - np.asarray(eew)).max()
                / np.abs(np.asarray(eew)).max())
    assert rel < tol, (bits, rel)


# ---------------------------------------------------------------------------
# end-to-end: KGAT training step on the fused path
# ---------------------------------------------------------------------------


def _small_kgat():
    from repro.data.synthetic import gen_kg_dataset
    from repro.models import kgnn
    ds = gen_kg_dataset(n_users=30, n_items=40, n_attrs=20, n_relations=4,
                        n_triples=200, inter_per_user=5, seed=0)
    cfg = kgnn.KGNNConfig(model="kgat", n_users=ds.n_users,
                          n_entities=ds.n_entities,
                          n_relations=ds.n_relations, dim=16, n_layers=2,
                          readout="concat")
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    params = kgnn.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"user": jnp.array([0, 1]), "pos": jnp.array([3, 4]),
             "neg": jnp.array([5, 6])}
    return kgnn, cfg, g, params, batch


@pytest.mark.slow
def test_kgat_train_step_uses_fused_kernels_end_to_end():
    kgnn, cfg, g, params, batch = _small_kgat()
    gp = attach_layout(g, block_e=64, block_rows=64)
    assert gp.layout.meta.n_edges == g.src.shape[0]

    vg = jax.jit(jax.value_and_grad(kgnn.bpr_loss),
                 static_argnames=("cfg", "policy"))
    base = dict(kops.TRACE_COUNTS)
    loss_p, grads_p = vg(params, gp, batch, cfg=cfg,
                         policy=ACTPolicy(bits=4, kernel="pallas"), key=KEY)
    used = {k: kops.TRACE_COUNTS[k] - base.get(k, 0)
            for k in kops.TRACE_COUNTS}
    # one fused fwd + transpose + dequant-SDDMM per propagation layer
    assert used.get("spmm", 0) >= cfg.n_layers
    assert used.get("spmm_t", 0) >= cfg.n_layers
    assert used.get("dequant_sddmm", 0) >= cfg.n_layers

    # forward is exact up to fp32 reduction order (the in-block MXU dot
    # may associate differently from segment_sum on real TPUs; the
    # genuinely bit-exact check lives in
    # test_forward_bit_exact_on_exact_inputs)
    loss_f, _ = vg(params, g, batch, cfg=cfg, policy=ACTPolicy(bits=None),
                   key=KEY)
    np.testing.assert_allclose(float(loss_p), float(loss_f), rtol=1e-6)

    # fp32 residuals on the fused path: grads match jnp fp32 to ≤1e-5
    _, grads_ref = vg(params, g, batch, cfg=cfg,
                      policy=ACTPolicy(bits=None, enabled=True), key=KEY)
    _, grads_pf = vg(params, gp, batch, cfg=cfg,
                     policy=ACTPolicy(bits=None, kernel="pallas"), key=KEY)
    for a, b in zip(jax.tree_util.tree_leaves(grads_pf),
                    jax.tree_util.tree_leaves(grads_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    # grads stay finite under real quantization
    assert all(bool(jnp.isfinite(leaf).all())
               for leaf in jax.tree_util.tree_leaves(grads_p))


def test_act_spmm_falls_back_without_layout():
    """kernel='pallas' with no/mismatched layout takes the jnp path."""
    src, dst, x, ew = _graph(N=20, E=64, d=8, seed=5)
    pol = ACTPolicy(bits=8, kernel="pallas")
    base = dict(kops.TRACE_COUNTS)
    out = act_spmm(x, src, dst, ew, num_nodes=20, key=KEY, policy=pol,
                   layout=None)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_ref_spmm(x, src, dst, ew, 20)),
                               rtol=1e-6)
    # a layout built for a different edge count must also be rejected
    lay = build_spmm_layout(src[:32], dst[:32], n_dst=20,
                            block_e=16, block_rows=8)
    out2 = act_spmm(x, src, dst, ew, num_nodes=20, key=KEY, policy=pol,
                    layout=lay)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out), rtol=1e-6)
    assert dict(kops.TRACE_COUNTS) == base  # fused kernels never traced
