"""Record the single-device KGAT step pin values (tests/test_model_step.py).

Run from the repo root against a known-good tree (it was first run against
the pre-registry code, so the recorded values pin the refactor to the
original numerics):

    PYTHONPATH=src python tests/data/record_kgat_regression.py
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core import act_context
from repro.core.policy import parse_schedule
from repro.models import kgnn
from repro.training.optimizer import adam


def build_case():
    rng = np.random.default_rng(0)
    cfg = kgnn.KGNNConfig(model="kgat", n_users=16, n_entities=48,
                          n_relations=5, dim=8, n_layers=2, n_bases=2,
                          readout="concat")
    N, E, B = cfg.n_nodes, 200, 32
    g = kgnn.CKG(src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
                 dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
                 rel=jnp.asarray(rng.integers(0, 5, E), jnp.int32),
                 n_nodes=N, n_relations=5)
    params = kgnn.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "user": jnp.asarray(rng.integers(0, cfg.n_users, B), jnp.int32),
        "pos": jnp.asarray(rng.integers(0, cfg.n_entities, B), jnp.int32),
        "neg": jnp.asarray(rng.integers(0, cfg.n_entities, B), jnp.int32)}
    return cfg, g, params, batch


def run_case():
    cfg, g, params, batch = build_case()
    schedule = parse_schedule("int8")
    root = jax.random.PRNGKey(11)

    reps = kgnn.propagate(params, g, cfg)

    def loss_fn(p):
        with act_context(schedule, root, step=3):
            return kgnn.bpr_loss(p, g, batch, cfg)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    opt = adam(1e-2)
    new_params, _ = opt.update(grads, opt.init(params), params)
    flat_g, _ = ravel_pytree(grads)
    flat_p, _ = ravel_pytree(new_params)
    flat_r = reps.reshape(-1)
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "loss": float(loss),
        "reps_sample": [float(x) for x in np.asarray(flat_r[::173])],
        "reps_abs_sum": float(jnp.abs(flat_r).sum()),
        "grads_sample": [float(x) for x in np.asarray(flat_g[::173])],
        "grads_abs_sum": float(jnp.abs(flat_g).sum()),
        "params_after_sample": [float(x) for x in np.asarray(flat_p[::173])],
        "params_after_abs_sum": float(jnp.abs(flat_p).sum()),
    }


if __name__ == "__main__":
    out = run_case()
    path = os.path.join(os.path.dirname(__file__), "kgat_step_regression.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"recorded -> {path}")
    print(json.dumps({k: v for k, v in out.items()
                      if not isinstance(v, list)}, indent=1))
