"""Data-parallel training path: edge partitioning, the shard_map KGAT
step, and the compressed gradient all-reduce (DESIGN.md §7).

Host-side partitioning and error contracts run in-process (1 device);
anything that needs a real multi-device mesh runs in a subprocess with
forced host devices, same pattern as tests/test_distributed.py.
"""

import numpy as np
import pytest

from _subproc import forced_device_run as _run


# ---------------------------------------------------------------------------
# partition_edges (host-side, no mesh needed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 2, 4, 8])
def test_partition_edges_roundtrip(n_shards):
    """Reassembled shards == original COO lists, for every shard count."""
    from repro.data.csr import partition_edges, unpartition_edges

    rng = np.random.default_rng(3)
    n_nodes, n_edges = 37, 211   # deliberately not shard-divisible
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    rel = rng.integers(0, 7, n_edges)
    part = partition_edges(src, dst, rel, n_nodes=n_nodes,
                           n_shards=n_shards)
    assert part.n_shards == n_shards
    assert part.n_nodes_padded >= n_nodes
    s2, d2, r2 = unpartition_edges(part)
    np.testing.assert_array_equal(s2, src)
    np.testing.assert_array_equal(d2, dst)
    np.testing.assert_array_equal(r2, rel)


def test_partition_edges_halo_and_locality():
    """Halo-local src indices resolve to the global ids, local dst rows
    stay inside the shard, masks cover exactly the real edges."""
    from repro.data.csr import partition_edges

    rng = np.random.default_rng(0)
    n_nodes, n_edges = 64, 400
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    part = partition_edges(src, dst, n_nodes=n_nodes, n_shards=4)
    mask = np.asarray(part.mask) > 0
    assert int(mask.sum()) == n_edges
    halo = np.asarray(part.halo)
    src_h = np.asarray(part.src_h)
    src_g = np.asarray(part.src_g)
    resolved = np.take_along_axis(halo, src_h, axis=1)
    np.testing.assert_array_equal(resolved[mask], src_g[mask])
    assert np.asarray(part.dst_l).max() < part.rows_per_shard
    # halo is deduplicated: per-shard unique sources only
    for s in range(4):
        h = halo[s, :int(np.asarray(part.halo_count)[s])]
        assert len(np.unique(h)) == len(h)


def test_partition_edges_errors():
    from repro.data.csr import partition_edges

    with pytest.raises(ValueError, match="bad edge list"):
        partition_edges([1, 2], [1], n_nodes=4, n_shards=2)
    with pytest.raises(ValueError, match="n_shards"):
        partition_edges([1], [1], n_nodes=4, n_shards=0)


# ---------------------------------------------------------------------------
# mesh construction contracts (honest errors on small hosts)
# ---------------------------------------------------------------------------


def test_production_mesh_honest_error_and_sim_hatch():
    """On a 1-device host the pod mesh fails with the fix in the message;
    sim= keeps the axis names at host-sized extents."""
    from repro.launch.mesh import batch_axes, make_production_mesh

    with pytest.raises(RuntimeError) as ei:
        make_production_mesh()
    msg = str(ei.value)
    assert "256 devices" in msg and "XLA_FLAGS" in msg and "sim=" in msg
    m = make_production_mesh(sim=(1, 1))
    assert m.axis_names == ("data", "model")
    assert batch_axes(m) == ("data",)
    with pytest.raises(ValueError, match="must name 3 extents"):
        make_production_mesh(multi_pod=True, sim=(1, 1))


def test_make_sim_mesh_honest_error():
    from repro.sharding.compat import make_sim_mesh

    m = make_sim_mesh(1)
    assert m.axis_names == ("data",)
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_sim_mesh(4096)
    with pytest.raises(ValueError, match="axis names"):
        make_sim_mesh((2, 2), ("data",))


def test_make_mesh_axis_type_requests():
    """make_mesh honors Auto requests on every runtime and refuses —
    never silently elides — non-Auto requests a pre-axis-type runtime
    cannot express."""
    from repro.sharding.compat import (HAS_AXIS_TYPES, AxisType, make_mesh)

    m = make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    assert m.axis_names == ("data",)
    assert make_mesh((1,), ("data",)).axis_names == ("data",)
    if not HAS_AXIS_TYPES:
        with pytest.raises(NotImplementedError, match="Auto meshes"):
            make_mesh((1,), ("data",), axis_types=(AxisType.Explicit,))


def test_all_reduce_grads_requires_key():
    from repro.training.compress import all_reduce_grads

    with pytest.raises(ValueError, match="per-step PRNG key"):
        all_reduce_grads({"w": np.zeros(4)}, "data", compressed=True)


def test_dp_step_contract_errors():
    """Shard-count and batch-divisibility mismatches fail fast, before
    any shard_map tracing."""
    import jax
    import jax.numpy as jnp

    from repro.data.csr import partition_edges
    from repro.models import kgnn
    from repro.sharding.compat import make_sim_mesh
    from repro.training import data_parallel as dp

    from repro.models.registry import kg_dp_spec

    cfg = kgnn.KGNNConfig(model="kgat", n_users=4, n_entities=12,
                          n_relations=3, dim=4, n_layers=1, n_bases=2)
    spec = kg_dp_spec(cfg)
    part2 = partition_edges([0, 1], [1, 2], n_nodes=cfg.n_nodes, n_shards=2)
    mesh1 = make_sim_mesh(1)
    params = kgnn.init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.zeros((4,), jnp.int32) for k in ("user", "pos", "neg")}
    with pytest.raises(ValueError, match="partition built for 2"):
        dp.dp_loss_and_grads(spec, params, part2, batch, mesh=mesh1,
                             root_key=jax.random.PRNGKey(0))
    part1 = partition_edges([0, 1], [1, 2], n_nodes=cfg.n_nodes, n_shards=1)
    with pytest.raises(ValueError, match="root key"):
        dp.dp_loss_and_grads(spec, params, part1, batch, mesh=mesh1)


# ---------------------------------------------------------------------------
# multi-device semantics (subprocess, forced host devices)
# ---------------------------------------------------------------------------

# indented to match the test bodies so the concatenation dedents cleanly
_SETUP = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.flatten_util import ravel_pytree
        from repro.models import kgnn
        from repro.models.registry import kg_dp_spec
        from repro.training import data_parallel as dp
        from repro.sharding.compat import make_sim_mesh

        rng = np.random.default_rng(0)
        cfg = kgnn.KGNNConfig(model="kgat", n_users=16, n_entities=48,
                              n_relations=5, dim=8, n_layers=2, n_bases=2,
                              readout="concat")
        N, E, B = cfg.n_nodes, 200, 32
        g = kgnn.CKG(src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
                     dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
                     rel=jnp.asarray(rng.integers(0, 5, E), jnp.int32),
                     n_nodes=N, n_relations=5)
        params = kgnn.init_params(jax.random.PRNGKey(0), cfg)
        batch = {
            "user": jnp.asarray(rng.integers(0, cfg.n_users, B), jnp.int32),
            "pos": jnp.asarray(rng.integers(0, cfg.n_entities, B), jnp.int32),
            "neg": jnp.asarray(rng.integers(0, cfg.n_entities, B), jnp.int32)}
        spec = kg_dp_spec(cfg, g)
"""


def test_dp_step_matches_single_device():
    """8-shard shard_map KGAT step vs the single-device step, exact
    compression + fp32 all-reduce: per-shard forward rows are bit-exact
    (stable dst partition, same accumulation order) and the gradient
    all-reduce agrees to fp32-reassociation roundoff. One optimizer step
    stays within the same bound."""
    print(_run(_SETUP + """
        from repro.training.optimizer import adam
        loss_ref, g_ref = jax.value_and_grad(kgnn.bpr_loss)(
            params, g, batch, cfg, policy=None, key=None)
        mesh = make_sim_mesh(8)
        part = dp.partition_graph(g, mesh)
        loss_dp, g_dp = dp.dp_loss_and_grads(
            spec, params, part, batch, mesh=mesh, schedule=None,
            root_key=jax.random.PRNGKey(7), compress_grads=False)
        assert abs(float(loss_ref - loss_dp)) < 1e-6, (loss_ref, loss_dp)
        fr, _ = ravel_pytree(g_ref)
        fd, _ = ravel_pytree(g_dp)
        rel = float(jnp.abs(fr - fd).max() / (jnp.abs(fr).max() + 1e-12))
        assert rel < 1e-5, rel

        opt = adam(1e-2)
        st_ref = opt.update(g_ref, opt.init(params), params)[0]
        st_dp = opt.update(g_dp, opt.init(params), params)[0]
        pr, _ = ravel_pytree(st_ref)
        pd, _ = ravel_pytree(st_dp)
        drift = float(jnp.abs(pr - pd).max())
        assert drift < 1e-5, drift
        print("dp==single ok: loss", float(loss_dp), "grad rel", rel,
              "param drift", drift)
    """))


def test_dp_forward_loss_invariant_under_act_policy():
    """ACT compresses *residuals*, never the forward values: the DP loss
    under a stochastic INT8 schedule equals the exact-policy loss."""
    print(_run(_SETUP + """
        from repro.core.policy import parse_schedule
        mesh = make_sim_mesh(4)
        part = dp.partition_graph(g, mesh)
        l_exact, _ = dp.dp_loss_and_grads(
            spec, params, part, batch, mesh=mesh, schedule=None,
            root_key=jax.random.PRNGKey(3), compress_grads=False)
        l_int8, _ = dp.dp_loss_and_grads(
            spec, params, part, batch, mesh=mesh,
            schedule=parse_schedule("int8"),
            root_key=jax.random.PRNGKey(3), compress_grads=True)
        d = abs(float(l_exact - l_int8))
        assert d < 1e-7, d
        print("forward invariance ok", d)
    """, n_devices=4))


def _arch_setup(model: str) -> str:
    """Same shapes as _SETUP, parametrized over the registered KG archs
    and wired through the generic registry/DPSpec path."""
    return f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.flatten_util import ravel_pytree
        from repro.models import kgnn
        from repro.models.registry import kg_dp_spec
        from repro.training import data_parallel as dp
        from repro.sharding.compat import make_sim_mesh

        MODEL = {model!r}
        rng = np.random.default_rng(0)
        cfg = kgnn.KGNNConfig(model=MODEL, n_users=16, n_entities=48,
                              n_relations=5, dim=8, n_layers=2, n_bases=2,
                              readout="concat" if MODEL == "kgat" else "sum")
        N, E, B = cfg.n_nodes, 200, 32
        g = kgnn.CKG(src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
                     dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
                     rel=jnp.asarray(rng.integers(0, 5, E), jnp.int32),
                     n_nodes=N, n_relations=5)
        params = kgnn.init_params(jax.random.PRNGKey(0), cfg)
        batch = {{
            "user": jnp.asarray(rng.integers(0, cfg.n_users, B), jnp.int32),
            "pos": jnp.asarray(rng.integers(0, cfg.n_entities, B), jnp.int32),
            "neg": jnp.asarray(rng.integers(0, cfg.n_entities, B), jnp.int32)}}
        spec = kg_dp_spec(cfg, g)
"""


@pytest.mark.slow
@pytest.mark.parametrize("model", ["kgat", "kgcn", "kgin"])
def test_dp_parity_every_kg_arch_2_4_8(model):
    """The generic DP path (one ``DPSpec.shard_loss`` per arch, same
    ``propagate_view`` layer math as single device) holds the full
    exactness contract for EVERY registered KG arch at 2/4/8 shards:

      * forward readout reps BIT-exact vs single-device ``propagate``
        under exact compression (stable dst partition, same per-row
        accumulation order);
      * gradients <=1e-5 relative (psum reassociation only);
      * the DP loss invariant under a stochastic INT8 ACT schedule
        (ACT compresses residuals, never forward values).
    """
    print(_run(_arch_setup(model) + """
        from repro.core.policy import parse_schedule
        loss_ref, g_ref = jax.value_and_grad(kgnn.bpr_loss)(
            params, g, batch, cfg)
        reps_ref = np.asarray(kgnn.propagate(params, g, cfg))
        fr, _ = ravel_pytree(g_ref)
        for S in (2, 4, 8):
            mesh = make_sim_mesh(S)
            part = dp.partition_graph(g, mesh)
            loss_dp, g_dp = dp.dp_loss_and_grads(
                spec, params, part, batch, mesh=mesh, schedule=None,
                root_key=jax.random.PRNGKey(7), compress_grads=False)
            reps_dp = np.asarray(dp.dp_forward_reps(spec, params, part,
                                                    mesh=mesh))
            assert np.array_equal(reps_ref, reps_dp), \\
                (MODEL, S, "forward reps not bit-exact")
            assert abs(float(loss_ref - loss_dp)) < 1e-6, \\
                (MODEL, S, float(loss_ref), float(loss_dp))
            fd, _ = ravel_pytree(g_dp)
            rel = float(jnp.abs(fr - fd).max() / (jnp.abs(fr).max() + 1e-12))
            assert rel < 1e-5, (MODEL, S, rel)
            l_int8, _ = dp.dp_loss_and_grads(
                spec, params, part, batch, mesh=mesh,
                schedule=parse_schedule("int8"),
                root_key=jax.random.PRNGKey(3), compress_grads=True)
            d = abs(float(loss_dp - l_int8))
            assert d < 1e-7, (MODEL, S, d)
            print(MODEL, S, "shards ok: grad rel", rel,
                  "int8-loss drift", d, flush=True)
        print("dp parity ok for", MODEL)
    """, timeout=900))


@pytest.mark.slow
def test_compressed_psum_grad_unbiasedness_2_4_8():
    """The INT8 SR gradient all-reduce is an unbiased estimator of the
    exact mean-reduced gradient at every shard count: averaging the
    compressed DP gradients over 200 psum keys converges ~1/sqrt(K) to
    the exact-all-reduce gradients (single draws sit ~20x further out)."""
    print(_run(_SETUP + """
        for S in (2, 4, 8):
            mesh = make_sim_mesh(S)
            part = dp.partition_graph(g, mesh)
            _, g_exact = dp.dp_loss_and_grads(
                spec, params, part, batch, mesh=mesh, schedule=None,
                root_key=jax.random.PRNGKey(0), compress_grads=False)
            fe, _ = ravel_pytree(g_exact)

            @jax.jit
            def comp(root, part=part, mesh=mesh):
                _, gr = dp.dp_loss_and_grads(
                    spec, params, part, batch, mesh=mesh, schedule=None,
                    root_key=root, compress_grads=True)
                return ravel_pytree(gr)[0]

            acc = jnp.zeros_like(fe)
            single = None
            for k in jax.random.split(jax.random.PRNGKey(5), 200):
                v = comp(k)
                acc = acc + v
                if single is None:
                    single = float(jnp.abs(v - fe).max())
            mean_err = float(jnp.abs(acc / 200 - fe).max())
            assert single < 5e-3, (S, single)
            assert mean_err < 6e-5, (S, mean_err)
            print(S, "shards: single", single, "mean", mean_err)
        print("compressed-psum unbiasedness ok")
    """, timeout=900))
