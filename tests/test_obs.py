"""Telemetry subsystem tier (DESIGN.md §13): tracer, registry, sinks,
and the instrumented seams.

Covers, in rough dependency order:

  * ``repro.obs.trace`` — span nesting/threading in the Chrome-trace
    export, disabled fast path, the ``@traced`` decorator, save();
  * ``repro.obs.metrics`` — bounded reservoir (memory + exactness +
    determinism), labeled series, snapshot/diff;
  * ``repro.obs.sinks`` — summary round-trip, NAMED schema violations,
    the JSONL step writer;
  * ``repro.obs.log`` — level filtering incl. the env var;
  * the tiered store's stats invariants (rows_transferred vs unique
    cold-miss rows across gather/patch/apply interleavings; hit-rate
    monotonicity under LFU refresh);
  * the serving engine's bounded latency reservoir;
  * ``allreduce_byte_report`` analytic accounting;
  * ``check_regression`` BENCH-record schema errors;
  * ``publish_activation_report`` gauges;
  * the <2% disabled-overhead budget;
  * (slow) the launcher end-to-end: ``--trace`` emits nested
    train/step spans, ``--metrics-out`` summary's activation bytes
    agree with ``traced_activation_report`` to <= 1e-6.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import metrics as obs_metrics
from repro.obs.sinks import StepLogWriter, SummarySchemaError

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------


def test_tracer_records_nested_spans():
    tr = obs.Tracer().enable()
    with tr.span("train"):
        with tr.span("train/step", step=0):
            with tr.span("train/step/gather"):
                pass
    evs = tr.events()
    names = [e["name"] for e in evs]
    # inner spans exit (and append) first
    assert names == ["train/step/gather", "train/step", "train"]
    for e in evs:
        assert e["ph"] == "X" and e["dur"] >= 0.0 and e["ts"] >= 0.0
        assert e["tid"] == threading.get_ident()
    # nesting by timestamp containment: child inside parent
    by = {e["name"]: e for e in evs}
    child, parent = by["train/step/gather"], by["train/step"]
    assert child["ts"] >= parent["ts"]
    assert child["ts"] + child["dur"] <= parent["ts"] + parent["dur"] + 1e-3
    assert by["train/step"]["args"] == {"step": 0}


def test_tracer_disabled_returns_shared_null_span():
    tr = obs.Tracer()
    assert tr.span("a") is tr.span("b")        # no allocation when off
    with tr.span("a"):
        pass
    assert tr.events() == []


def test_tracer_thread_ids_separate_tracks():
    tr = obs.Tracer().enable()

    def worker():
        with tr.span("bg"):
            pass

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    with tr.span("fg"):
        pass
    tids = {e["name"]: e["tid"] for e in tr.events()}
    assert tids["bg"] != tids["fg"]


def test_tracer_chrome_trace_shape_and_save(tmp_path):
    tr = obs.Tracer().enable()
    with tr.span("x"):
        pass
    doc = tr.to_chrome_trace(run={"kind": "test"})
    assert set(doc) == {"traceEvents", "displayTimeUnit", "metadata"}
    assert doc["metadata"]["kind"] == "test"
    p = tr.save(str(tmp_path / "t.json"), run={"kind": "test"})
    loaded = json.load(open(p))
    assert loaded["traceEvents"][0]["name"] == "x"


def test_traced_decorator_both_forms():
    tr = obs.get_tracer()
    tr.enable()
    try:
        @obs.traced
        def f(x):
            return x + 1

        @obs.traced("custom/label")
        def g(x):
            return x * 2

        assert f(1) == 2 and g(2) == 4
        names = [e["name"] for e in tr.events()]
        assert "custom/label" in names
        assert any("f" in n for n in names)
    finally:
        tr.disable()


def test_step_span_enters_jax_annotation():
    # StepTraceAnnotation is a no-op without an active profiler, but the
    # ExitStack path must still record the host span
    tr = obs.get_tracer()
    tr.enable()
    try:
        with obs.step_span("train/step", 3):
            pass
        evs = tr.events()
        assert evs and evs[-1]["name"] == "train/step"
        assert evs[-1]["args"] == {"step": 3}
    finally:
        tr.disable()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_histogram_reservoir_bounded_and_exact_under_capacity():
    h = obs_metrics.Histogram(capacity=64)
    for x in range(50):
        h.observe(float(x))
    s = h.snapshot()
    assert s["count"] == 50 and s["sum"] == sum(range(50))
    assert s["min"] == 0.0 and s["max"] == 49.0
    assert s["p50"] == 25.0          # nearest-rank over the exact sample
    # past capacity: memory stays bounded, count/sum/min/max stay exact
    for x in range(50, 10_000):
        h.observe(float(x))
    assert len(h._buf) == 64
    s = h.snapshot()
    assert s["count"] == 10_000 and s["max"] == 9999.0
    assert s["sum"] == sum(range(10_000))
    # the uniform sample keeps percentiles in the right ballpark
    assert 2_000 < s["p50"] < 8_000


def test_histogram_deterministic_per_series_key():
    def fill(h):
        for x in range(5_000):
            h.observe(float(x % 977))
        return h.snapshot()

    a = fill(obs_metrics.Histogram(capacity=128, seed="train/step_ms"))
    b = fill(obs_metrics.Histogram(capacity=128, seed="train/step_ms"))
    assert a == b                     # replay => bit-identical snapshot


def test_registry_labeled_series_and_snapshot():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("tiering/gathers", store="tier0").inc(3)
    assert reg.counter("tiering/gathers", store="tier0").value == 3.0
    reg.gauge("train/loss").set(0.5)
    reg.histogram("lat", arch="kgat").observe(1.0)
    snap = reg.snapshot()
    assert snap["counters"] == {"tiering/gathers{store=tier0}": 3.0}
    assert snap["gauges"] == {"train/loss": 0.5}
    assert snap["histograms"]["lat{arch=kgat}"]["count"] == 1
    # same labels in any order -> same series
    reg.counter("c", a=1, b=2).inc()
    assert reg.counter("c", b=2, a=1).value == 1.0


def test_snapshot_diff_windows_counters_not_gauges():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("n")
    g = reg.gauge("depth")
    h = reg.histogram("ms")
    c.inc(5)
    g.set(7)
    h.observe(1.0)
    before = reg.snapshot()
    c.inc(2)
    g.set(3)
    h.observe(2.0)
    d = obs_metrics.diff(before, reg.snapshot())
    assert d["counters"]["n"] == 2.0
    assert d["gauges"]["depth"] == 3.0          # instantaneous
    assert d["histograms"]["ms"]["count"] == 1


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------


def test_summary_round_trip(tmp_path):
    reg = obs_metrics.MetricsRegistry()
    reg.counter("train/steps").inc(5)
    reg.histogram("train/step_ms").observe(12.0)
    path = obs.write_summary(str(tmp_path), {"kind": "train", "arch": "kgat"},
                             reg)
    loaded = json.load(open(path))
    obs.validate_summary(loaded)      # round-trips valid
    assert loaded["counters"]["train/steps"] == 5.0
    assert loaded["run"]["arch"] == "kgat"


def test_validate_summary_names_all_violations():
    bad = {"schema_version": 99, "run": {"kind": 3},
           "counters": {"x": "NaN-ish"}, "gauges": {},
           "histograms": {"h": {"count": 1}}}
    with pytest.raises(SummarySchemaError) as ei:
        obs.validate_summary(bad)
    msg = str(ei.value)
    assert "schema_version 99" in msg
    assert "run.kind" in msg
    assert "counters['x']" in msg
    assert "histograms['h'] missing" in msg and "p99" in msg
    with pytest.raises(SummarySchemaError) as ei:
        obs.validate_summary({})
    assert "missing required key" in str(ei.value)


def test_step_log_writer_extras_and_flush(tmp_path):
    p = tmp_path / "steps.jsonl"
    with StepLogWriter(str(p)) as w:
        w.extras["act_total_bytes"] = 123
        w.write({"step": 1, "wall_ms": 2.5})
        w.write({"step": 2, "wall_ms": 2.6})
        assert w.n_records == 2
    rows = [json.loads(line) for line in open(p)]
    assert [r["step"] for r in rows] == [1, 2]
    assert all(r["act_total_bytes"] == 123 for r in rows)
    with pytest.raises(ValueError):
        w.write({"step": 3})          # closed writer fails loudly


# ---------------------------------------------------------------------------
# log
# ---------------------------------------------------------------------------


def test_log_levels_filter(capsys, monkeypatch):
    monkeypatch.delenv("REPRO_LOG_LEVEL", raising=False)
    obs.set_log_level(None)
    obs.log("info-line")
    obs.log("debug-line", level="debug")
    err = capsys.readouterr().err
    assert "info-line" in err and "debug-line" not in err

    monkeypatch.setenv("REPRO_LOG_LEVEL", "error")
    obs.log("info-2")
    obs.log("err-2", level="error")
    err = capsys.readouterr().err
    assert "info-2" not in err and "err-2" in err

    obs.set_log_level("debug")        # override beats the env
    try:
        obs.log("debug-3", level="debug")
        assert "debug-3" in capsys.readouterr().err
    finally:
        obs.set_log_level(None)
    with pytest.raises(ValueError):
        obs.set_log_level("verbose")


def test_log_goes_to_stderr_not_stdout(capsys):
    obs.log("hello")
    cap = capsys.readouterr()
    assert "hello" in cap.err and "hello" not in cap.out


# ---------------------------------------------------------------------------
# tiered store stats invariants
# ---------------------------------------------------------------------------


def _store(n=64, d=4, hot_frac=0.25, refresh_every=0, seed=0, **kw):
    from repro.training.tiering import TieredEmbeddingStore

    rng = np.random.default_rng(seed)
    table = rng.normal(size=(n, d)).astype(np.float32)
    return TieredEmbeddingStore(table, hot_frac=hot_frac,
                                refresh_every=refresh_every, **kw)


def _next_pow2(n):
    from repro.training.tiering import _next_pow2 as f
    return f(n)


def test_tiering_stats_transfer_invariant_across_interleavings():
    """rows_transferred == Σ next_pow2(unique cold rows per boundary
    event), cold_rows == Σ exact unique cold rows — across gathers,
    grad scatters and patches. A shadow model recomputes both from the
    store's hot-slot table before each call."""
    import jax.numpy as jnp

    store = _store(n=64, hot_frac=0.25)
    rng = np.random.default_rng(1)
    expect_transfer = 0
    expect_cold = 0

    def n_cold(ids):
        """Cold entries of an id list, positionally (no dedup here:
        gather/apply_grads hand _scatter_rows a pre-uniqued list, patch
        hands raw positions — the shadow model mirrors the call)."""
        return int((store._hot_slot[np.asarray(ids, np.int64)] < 0).sum())

    prev_rows = None
    for t in range(12):
        rows = rng.integers(0, 64, size=rng.integers(1, 40))
        cold = n_cold(np.unique(rows))
        if cold:
            expect_transfer += _next_pow2(cold)
            expect_cold += cold
        out = store.gather(rows)
        assert out.shape == (len(rows), store.dim)

        if prev_rows is not None:
            # grad scatter-back: unique cold rows of the touched set
            grads = jnp.ones((len(prev_rows), store.dim), jnp.float32)
            cold = n_cold(np.unique(prev_rows))
            if cold:
                expect_transfer += _next_pow2(cold)
                expect_cold += cold
            updated = store.apply_grads(prev_rows, grads, lr=0.1)
            # patch re-fetches overlap POSITIONS (id repeats re-fetch
            # once per position)
            idx = np.nonzero(np.isin(rows, updated))[0]
            cold = n_cold(rows[idx]) if len(idx) else 0
            if cold:
                expect_transfer += _next_pow2(cold)
                expect_cold += cold
            out = store.patch(out, rows, updated)
        prev_rows = rows

    assert store.stats["rows_transferred"] == expect_transfer
    assert store.stats["cold_rows"] == expect_cold
    # padding can only inflate: pow2-bucketed >= exact unique cold rows
    assert store.stats["rows_transferred"] >= store.stats["cold_rows"]


def test_tiering_patch_dedups_rows_before_pricing():
    """patch() passes rows[idx] positions (not unique ids) — but the
    underlying _scatter_rows prices the id list it is given; the loop
    passes positional duplicates only when `rows` itself repeats an id,
    and those repeats DO cross the boundary once per position. Pin the
    exact semantics so a refactor can't silently change the bill."""
    store = _store(n=32, hot_frac=0.0)     # everything cold
    rows = np.array([3, 3, 5], np.int64)
    out = store.gather(rows)               # unique -> 2 cold rows, bucket 2
    assert store.stats["rows_transferred"] == 2
    assert store.stats["cold_rows"] == 2
    out = store.patch(out, rows, np.array([3]))
    # both positions of id 3 re-fetch: 2 rows -> bucket 2, cold_rows +2
    assert store.stats["rows_transferred"] == 4
    assert store.stats["cold_rows"] == 4
    del out


def test_tiering_hit_rate_monotone_under_lfu_refresh():
    """A skewed access stream must not see its hit rate degraded by LFU
    refreshes: after the counters learn the skew, the refreshed hot set
    contains the heavy hitters, so the post-refresh windowed hit rate
    is >= the pre-refresh window's."""
    store = _store(n=128, hot_frac=0.1, refresh_every=8, seed=2)
    rng = np.random.default_rng(3)
    # stream concentrated on 8 ids OUTSIDE the initial hot set (with no
    # freq seed the initial ranking is id-ascending: rows 0..12 are hot)
    heavy = rng.choice(np.arange(32, 128), size=8, replace=False)

    def window(n_gathers):
        before = dict(store.stats)
        for _ in range(n_gathers):
            ids = np.concatenate([
                rng.choice(heavy, size=24),
                rng.integers(0, 128, size=8)])
            store.gather(ids)
        after = store.stats
        req = after["rows_requested"] - before["rows_requested"]
        hit = after["hot_hits"] - before["hot_hits"]
        return hit / req

    early = window(8)    # includes the cold start + first refresh
    late = window(8)     # counters now know the heavy set
    assert late >= early
    assert store.stats["refreshes"] >= 1
    assert 0.0 <= store.hit_rate <= 1.0


def test_tiering_stats_backcompat_keys():
    store = _store()
    expected = {"gathers", "rows_requested", "hot_hits",
                "rows_transferred", "refreshes", "patch_rows", "cold_rows"}
    assert set(store.stats) == expected
    assert all(isinstance(v, int) for v in store.stats.values())
    store.gather(np.array([1, 2, 3]))
    assert store.stats["gathers"] == 1


def test_tiering_private_registry_isolated():
    reg = obs_metrics.MetricsRegistry()
    store = _store(registry=reg)
    store.gather(np.array([0, 1]))
    snap = reg.snapshot()["counters"]
    assert any(k.startswith("tiering/gathers") for k in snap)


# ---------------------------------------------------------------------------
# serving engine bounded reservoir
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_engine_latency_reservoir_bounded():
    import jax

    from repro.serving.engine import ServingEngine
    from repro.serving.store import QuantizedEmbeddingStore

    rng = np.random.default_rng(0)
    users = rng.normal(size=(16, 8)).astype(np.float32)
    items = rng.normal(size=(64, 8)).astype(np.float32)
    store = QuantizedEmbeddingStore.from_arrays(users, items, bits=8)
    reg = obs_metrics.MetricsRegistry()
    with ServingEngine(store, k=4, backend="jnp", buckets=(1, 2, 4),
                       lat_capacity=32, registry=reg) as eng:
        eng.warmup()
        futs = [eng.submit(int(u))
                for u in rng.integers(0, 16, size=100)]
        for f in futs:
            f.result(timeout=120)
        st = eng.stats()
    assert st.n_requests == 100
    assert st.p50_ms > 0.0 and st.p99_ms >= st.p50_ms
    # the reservoir, not an unbounded list, backs the percentiles
    assert len(eng._m_lat._buf) <= 32
    assert eng._m_lat.count == 100
    snap = reg.snapshot()
    assert any(k.startswith("serve/latency_ms") for k in snap["histograms"])
    assert any(k.startswith("serve/requests") for k in snap["counters"])
    del jax


# ---------------------------------------------------------------------------
# all-reduce byte accounting
# ---------------------------------------------------------------------------


def test_allreduce_byte_report_analytic():
    from repro.training.compress import allreduce_byte_report

    class Leaf:
        def __init__(self, size):
            self.size = size

    grads = {"entity": {"w": Leaf(1000)},
             "mlp": {"w": Leaf(64), "b": Leaf(8)}}
    # 2D mesh, entity row-sharded over model: entity reduces over data
    # only (int8: 1 B/elem + 4 B scale/leaf), mlp over both axes
    rows = allreduce_byte_report(grads, ("data", "model"),
                                 placement={"entity": "model"},
                                 compressed=True)
    by_axes = {r["axes"]: r for r in rows}
    assert by_axes["data"]["bytes"] == 1000 + 4
    assert by_axes["data"]["params"] == ["entity"]
    assert by_axes["data+model"]["bytes"] == 64 + 8 + 2 * 4
    # fp32 baseline: 4 B/elem, one group without placement
    rows = allreduce_byte_report(grads, "data", compressed=False)
    assert len(rows) == 1
    assert rows[0]["bytes"] == 4 * (1000 + 64 + 8)
    assert rows[0]["wire"] == "fp32"
    # sharded over every reduced axis -> no wire hop
    rows = allreduce_byte_report({"entity": {"w": Leaf(10)}}, "model",
                                 placement={"entity": "model"})
    assert rows[0]["axes"] == "none" and rows[0]["bytes"] == 0
    with pytest.raises(TypeError):
        allreduce_byte_report([Leaf(3)], "data", placement={"x": "data"})


# ---------------------------------------------------------------------------
# check_regression BENCH schema
# ---------------------------------------------------------------------------


def test_check_regression_names_missing_bench_keys():
    sys.path.insert(0, _REPO)
    try:
        from benchmarks.check_regression import (BenchSchemaError,
                                                 validate_bench_rows)
    finally:
        sys.path.pop(0)

    ok = [{"op": "spmm", "mode": "interpret", "backend": "cpu"}]
    validate_bench_rows(ok)
    bad = ok + [{"bench": "minibatch", "model": "kgat"},
                {"bench": "mesh2d", "op": "dp2d_step", "model": "kgat"}]
    with pytest.raises(BenchSchemaError) as ei:
        validate_bench_rows(bad)
    msg = str(ei.value)
    assert "['op', 'mode', 'backend']" in msg     # row missing all three
    assert "['mode', 'backend']" in msg            # row missing two
    assert "bench=minibatch" in msg                # rows named by key


def test_committed_bench_baseline_passes_schema():
    sys.path.insert(0, _REPO)
    try:
        from benchmarks.check_regression import validate_bench_rows
    finally:
        sys.path.pop(0)
    rows = json.load(open(os.path.join(_REPO, "BENCH_kernels.json")))
    validate_bench_rows(rows)


# ---------------------------------------------------------------------------
# activation report publishing
# ---------------------------------------------------------------------------


def test_publish_activation_report_gauges():
    from repro.core.memory import publish_activation_report

    report = {"kgat/layer0/spmm": 1024.0, "kgat/layer1/spmm": 512.0,
              "total_bytes": 1536.0, "total_fp32_bytes": 12288.0,
              "compression_ratio": 8.0}
    reg = obs_metrics.MetricsRegistry()
    publish_activation_report(report, reg)
    g = reg.snapshot()["gauges"]
    assert g["act/bytes{scope=kgat/layer0/spmm}"] == 1024.0
    assert g["act/total_bytes"] == 1536.0
    assert g["act/compression_ratio"] == 8.0


# ---------------------------------------------------------------------------
# overhead budget
# ---------------------------------------------------------------------------


def test_disabled_instrumentation_under_two_percent():
    """DESIGN.md §13 budget: with tracing disabled, the per-step cost of
    the instrumentation bundle (4 span checks + histogram observe +
    counter inc — what Trainer._run and the sampled loop add per step)
    must stay under 2% of the smoke-config median step time. Measured
    directly instead of diffing two noisy end-to-end runs: CPU step time
    is ~ms, the bundle is ~µs, so the assertion has two orders of
    headroom and stays deterministic."""
    tr = obs.Tracer()                       # disabled
    assert not tr.enabled
    reg = obs_metrics.MetricsRegistry()
    hist = reg.histogram("train/step_ms")
    ctr = reg.counter("train/steps")

    n = 20_000
    t0 = time.perf_counter()
    for i in range(n):
        with tr.span("train/step"):
            with tr.span("train/step/data"):
                pass
            with tr.span("train/step/update"):
                pass
        with tr.span("train/step/gather"):
            pass
        ctr.inc()
        hist.observe(1.0)
    per_step_overhead = (time.perf_counter() - t0) / n

    # median step time of the smoke config (kgat --steps 5 class): the
    # cheapest real step in the suite is ~2 ms on CPU; budget against a
    # conservative 1 ms so the bound is meaningful on any runner
    median_step_s = 1e-3
    assert per_step_overhead < 0.02 * median_step_s, (
        f"disabled instrumentation costs {per_step_overhead * 1e6:.2f} µs "
        f"per step — over 2% of a {median_step_s * 1e3:.0f} ms step")


# ---------------------------------------------------------------------------
# launcher end-to-end (slow tier)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_launch_trace_and_metrics_end_to_end(tmp_path):
    """The ISSUE acceptance command: 5 kgat steps with --trace and
    --metrics-out. The trace must be Perfetto-loadable JSON with nested
    train/step spans; the summary's activation-bytes gauges must agree
    with an independent traced_activation_report to <= 1e-6."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    trace_path = tmp_path / "trace.json"
    mdir = tmp_path / "metrics"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "kgat",
         "--steps", "5", "--trace", str(trace_path),
         "--metrics-out", str(mdir)],
        env=env, capture_output=True, text=True, timeout=600, cwd=_REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "[train] done" in out.stdout

    doc = json.load(open(trace_path))
    evs = doc["traceEvents"]
    names = [e["name"] for e in evs]
    assert {"train", "train/step", "train/step/data",
            "train/step/update"} <= set(names)
    assert names.count("train/step") == 5
    # nesting: every step span sits inside the train span's window
    train = next(e for e in evs if e["name"] == "train")
    for e in evs:
        if e["name"] == "train/step" and e["tid"] == train["tid"]:
            assert e["ts"] >= train["ts"] - 1e-3
            assert e["ts"] + e["dur"] <= train["ts"] + train["dur"] + 1e-3
    assert doc["metadata"]["arch"] == "kgat"

    summary = json.load(open(mdir / "summary.json"))
    obs.validate_summary(summary)
    assert summary["counters"]["train/steps"] == 5.0
    assert summary["histograms"]["train/step_ms"]["count"] == 5

    # activation-bytes agreement with an independent re-trace
    import jax

    from repro.configs import get
    from repro.core.memory import traced_activation_report
    from repro.core.policy import schedule_from_cli
    from repro.models.registry import build_step

    step = build_step(get("kgat"),
                      schedule=schedule_from_cli(None, 2, kernel="jnp"))
    params = step.init(jax.random.PRNGKey(0))
    batch = next(iter(step.batches()))
    act = traced_activation_report(step.loss, params, batch,
                                   schedule=schedule_from_cli(
                                       None, 2, kernel="jnp"),
                                   key=jax.random.PRNGKey(1))
    got = summary["gauges"]["act/total_bytes"]
    assert abs(got - act["total_bytes"]) <= 1e-6 * max(act["total_bytes"], 1)
    assert summary["gauges"]["act/compression_ratio"] == pytest.approx(
        act["compression_ratio"], rel=1e-6)

    # the step log is the activation timeline: constant per-step total
    rows = [json.loads(line) for line in open(mdir / "steps.jsonl")]
    assert len(rows) == 5
    assert all(r["act_total_bytes"] == act["total_bytes"] for r in rows)
    assert all(r["wall_ms"] > 0 for r in rows)
