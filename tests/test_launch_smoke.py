"""Launcher smoke matrix: every ``--arch`` through the generic
registry-backed driver for 2 steps, plus one ``--mesh data=2`` row per
KG arch — a registry/driver wiring regression fails here fast, before
it reaches the heavier parity suites.

Subprocess-per-run (same rationale as tests/_subproc.py: the --mesh rows
must force the XLA host device count before jax initializes).
"""

import os
import subprocess
import sys

import pytest

from repro.configs import ARCHS

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow  # fast tier skips; CI runs the file whole


def _launch(*argv: str, expect_ok: bool = True, timeout: int = 600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *argv],
        env=env, capture_output=True, text=True, timeout=timeout, cwd=_REPO)
    if expect_ok:
        assert out.returncode == 0, (argv, out.stderr[-3000:])
        # the result line is the stdout contract; progress lines now go
        # to stderr through the leveled obs log
        assert "[train] done" in out.stdout, out.stdout[-2000:]
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_two_steps(arch):
    """--arch <id> --steps 2 runs the generic driver end to end."""
    _launch("--arch", arch, "--steps", "2")


@pytest.mark.parametrize("arch", ["kgat", "kgcn", "kgin"])
def test_train_two_steps_data_parallel(arch):
    """--mesh data=2 is legal for every KG arch through make_dp_step."""
    out = _launch("--arch", arch, "--steps", "2", "--mesh", "data=2")
    assert f"data-parallel {arch}: mesh data=2" in out.stdout + out.stderr


@pytest.mark.parametrize("arch,family", [("fm", "recsys"),
                                         ("stablelm-12b", "lm"),
                                         ("gcn-cora", "gnn")])
def test_mesh_refused_with_named_reason(arch, family):
    """Non-graph archs refuse --mesh naming the arch and the reason —
    not the old blanket 'implemented for kgat' message."""
    out = _launch("--arch", arch, "--steps", "2", "--mesh", "data=2",
                  expect_ok=False)
    assert out.returncode != 0
    err = out.stderr
    assert arch in err and family in err
    # says WHY, not just "no": every reason names the missing axis
    assert "edge" in err or "shard" in err
    assert "implemented for --arch kgat" not in err


def test_train_sampled_minibatch():
    """--sample fanout=... runs the tiered minibatch path end to end."""
    out = _launch("--arch", "kgat", "--steps", "3",
                  "--sample", "fanout=5,4,3", "--batch", "16",
                  "--hot-frac", "0.1")
    assert "sampled kgat" in out.stdout + out.stderr
    assert "hit-rate" in out.stdout


def test_sample_plus_mesh_refused_with_named_reason():
    """--sample + --mesh refuses up front with the named explanation,
    before any device or sampler work starts."""
    out = _launch("--arch", "kgat", "--steps", "2", "--mesh", "data=2",
                  "--sample", "fanout=5,4", expect_ok=False)
    assert out.returncode != 0
    err = out.stderr
    assert "--sample" in err and "--mesh" in err
    assert "dst-partitioned" in err
    assert "Drop --mesh" in err


def test_schedule_flag_still_routes():
    """--schedule spec reaches the ActContext path in the generic driver."""
    out = _launch("--arch", "kgat", "--steps", "2",
                  "--schedule", "first_layer_int8_rest_int2")
    assert "schedule=first_layer_int8_rest_int2" in out.stdout + out.stderr
