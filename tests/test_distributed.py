"""Distribution tests: run in a subprocess with forced host devices
(XLA device count is locked at first jax init, so the main pytest process
stays at 1 device).

Everything SPMD goes through ``repro.sharding.compat`` — these tests are
the executable statement of the supported-JAX-range policy (DESIGN.md
§7.5): they must pass on the pinned 0.4.37 *and* on the latest release
leg of the CI matrix, on a simulated 8-device CPU mesh.
"""

from _subproc import forced_device_run as _run


def test_compressed_psum_matches_mean():
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.sharding.compat import P, make_sim_mesh, shard_map
        from repro.training.compress import compressed_psum_mean, psum_mean
        mesh = make_sim_mesh(8)
        grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32)),
                 "b": jax.random.normal(jax.random.PRNGKey(1), (8, 16))}

        def body(g):
            key = jax.random.PRNGKey(7)
            return (compressed_psum_mean(g, "data", key),
                    psum_mean(g, "data"))

        comp, exact = jax.jit(shard_map(
            body, mesh=mesh, in_specs=P("data"),
            out_specs=P()))(grads)
        for k in grads:
            ref = grads[k].mean(0)
            rel = float(jnp.abs(comp[k] - ref).max() /
                        (jnp.abs(ref).max() + 1e-9))
            exact_rel = float(jnp.abs(exact[k] - ref).max() /
                              (jnp.abs(ref).max() + 1e-9))
            assert exact_rel < 1e-6, exact_rel
            assert rel < 0.05, (k, rel)   # int8 SR: ~1/254 per-element noise
        print("compressed psum OK")
    """))


def test_compressed_psum_unbiased():
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.sharding.compat import P, make_sim_mesh, shard_map
        from repro.training.compress import compressed_psum_mean
        mesh = make_sim_mesh(4)
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 128))}
        ref = g["w"].mean(0)

        def body(g_, key):
            return compressed_psum_mean(g_, "data", key)

        f = jax.jit(shard_map(body, mesh=mesh,
                              in_specs=(P("data"), P()), out_specs=P()))
        keys = jax.random.split(jax.random.PRNGKey(1), 300)
        outs = jnp.stack([f(g, k)["w"] for k in keys])
        err = float(jnp.abs(outs.mean(0) - ref).max())
        assert err < 2e-3, err        # unbiased: mean converges to exact
        print("unbiasedness OK", err)
    """))


def test_mesh_and_cell_lowering_small():
    """build_cell lowers on an 8-device (2×4) mini-mesh — exercises the
    full partition machinery without the 512-device cost."""
    print(_run("""
        from repro.configs import get
        from repro.configs.smoke import reduced
        from repro.core.policy import INT2
        from repro.launch.partition import build_cell
        from repro.sharding.compat import make_sim_mesh
        mesh = make_sim_mesh((2, 4), ("data", "model"))
        for arch_name, shape in [("fm", "serve_p99"),
                                 ("gcn-cora", "molecule")]:
            cell = build_cell(get(arch_name), shape, mesh, policy=INT2)
            compiled = cell.lower(mesh).compile()
            ma = compiled.memory_analysis()
            assert ma is not None
            print(arch_name, shape, "lowered+compiled OK")
    """))


def test_production_mesh_shapes():
    print(_run("""
        from repro.launch.mesh import make_production_mesh, batch_axes
        m1 = make_production_mesh(multi_pod=False)
        assert m1.devices.shape == (16, 16)
        assert m1.axis_names == ("data", "model")
        assert batch_axes(m1) == ("data",)
        m2 = make_production_mesh(multi_pod=True)
        assert m2.devices.shape == (2, 16, 16)
        assert batch_axes(m2) == ("pod", "data")
        # the sim= escape hatch keeps axis names at laptop extents
        m3 = make_production_mesh(sim=(2, 4))
        assert m3.devices.shape == (2, 4)
        assert m3.axis_names == ("data", "model")
        print("meshes OK")
    """, n_devices=512))


def test_checkpoint_reshard_elastic():
    """A checkpoint written under one mesh restores onto a smaller mesh
    (elastic scale-down) via sharding-aware device_put."""
    print(_run("""
        import jax, jax.numpy as jnp, tempfile
        from repro.sharding.compat import P, make_sim_mesh, reshard
        from repro.training.checkpoint import (save_checkpoint,
                                               restore_checkpoint)
        mesh8 = make_sim_mesh(8)
        x = reshard(jnp.arange(64.0), mesh8, P("data"))
        d = tempfile.mkdtemp()
        save_checkpoint(d, 1, {"x": x})
        mesh4 = make_sim_mesh(4)
        tmpl = {"x": reshard(jnp.zeros(64), mesh4, P("data"))}
        step, restored = restore_checkpoint(d, tmpl)
        assert step == 1
        assert restored["x"].sharding.mesh.shape["data"] == 4
        assert float(restored["x"].sum()) == float(x.sum())
        print("elastic reshard OK")
    """))


def test_kgat_spmd_partition_invariance():
    """propagate_spmd on a 4-shard mesh equals the 1-shard result when
    edges are dst-partitioned — the strongest correctness check for the
    explicitly-partitioned KGAT layer — AND both equal single-device
    ``propagate`` on the same edge list. The second check pins the
    aligned semantics: attention is computed ONCE from the layer-0
    embeddings (propagate_spmd used to recompute it per layer from the
    evolving embeddings, silently diverging from ``propagate`` — that
    fork is gone)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import kgnn
        from repro.core.policy import FP32
        from repro.sharding.compat import make_sim_mesh

        N, E, R, d = 32, 200, 5, 8
        rng = np.random.default_rng(0)
        cfg = kgnn.KGNNConfig(model="kgat", n_users=8, n_entities=24,
                              n_relations=R, dim=d, n_layers=2, n_bases=2,
                              readout="concat")
        params = kgnn.init_params(jax.random.PRNGKey(0), cfg)
        src = rng.integers(0, N, E)
        dst = rng.integers(0, N, E)
        rel = rng.integers(0, R, E)

        def build(n_shards):
            # partition edges by dst shard, pad each shard to equal count,
            # local dst ids
            rows = N // n_shards
            shard = dst // rows
            per = [np.where(shard == s)[0] for s in range(n_shards)]
            cap = max(len(ix) for ix in per)
            S, D_, Rl = [], [], []
            for s, ix in enumerate(per):
                # dst is resampled below so shards are exactly even —
                # pad stays 0 and the invariance check is strict
                pad = cap - len(ix)
                assert pad >= 0
                S.append(np.concatenate([src[ix],
                                         np.full(pad, s * rows)]))
                D_.append(np.concatenate([dst[ix] % rows,
                                          np.zeros(pad, np.int64)]))
                Rl.append(np.concatenate([rel[ix], np.zeros(pad,
                                                            np.int64)]))
            return (np.concatenate(S).astype(np.int32),
                    np.concatenate(D_).astype(np.int32),
                    np.concatenate(Rl).astype(np.int32))

        # padding injects duplicate edges which change results; to keep a
        # strict invariance check, make the edge set evenly partitioned by
        # construction: resample dst so each shard gets exactly E//4
        dst = np.concatenate([rng.integers(s * (N // 4), (s + 1) * (N // 4),
                                           E // 4) for s in range(4)])

        outs = {}
        for n_shards in (1, 4):
            mesh = make_sim_mesh(n_shards)
            s_, d_, r_ = build(n_shards)
            g = kgnn.CKG(src=jnp.asarray(s_), dst=jnp.asarray(d_),
                         rel=jnp.asarray(r_), n_nodes=N, n_relations=R)
            if n_shards == 1:
                # build(1) keeps global dst ids: the same graph drives
                # the single-device reference
                ref = np.asarray(kgnn.propagate(params, g, cfg,
                                                policy=FP32,
                                                key=jax.random.PRNGKey(1)))
            with mesh:
                reps = kgnn.propagate_spmd(params, g, cfg, mesh=mesh,
                                           axes=("data",), policy=FP32,
                                           key=jax.random.PRNGKey(1))
            outs[n_shards] = np.asarray(jax.device_get(reps))
        err = np.abs(outs[1] - outs[4]).max()
        assert err < 1e-4, err
        err_ref = max(np.abs(outs[1] - ref).max(),
                      np.abs(outs[4] - ref).max())
        assert err_ref < 1e-4, err_ref
        print("kgat spmd partition invariance OK", err,
              "matches propagate", err_ref)
    """))
