"""Minibatch KG training subsystem (DESIGN.md §11).

Four layers of guarantees, strongest first:

  * sampler invariants — every block edge references in-range rows, the
    seeds-prefix invariant holds hop over hop, masked pad slots are
    weight-zero self-edges (property-tested under hypothesis when
    available, with a seeded sweep fallback);
  * exactness — with fanout >= max in-degree the sampler keeps every
    edge, so sampled reps/losses/gradients match the full-graph path
    BIT-EXACTLY for all four registered KG models (no tolerance);
  * unbiasedness — with a small fanout, the multi-draw mean of sampled
    R-GCN entity gradients approximates the full-graph gradient
    (mean aggregation is the unbiased case; the attention models are
    sampled-softmax approximations, see the §11 exactness ledger);
  * the tier store — gather/scatter-back round-trips, LFU refresh
    promotion, prefetch-patch sequential equivalence, replay
    determinism, and the device-budget acceptance run (table over
    budget, peak live bytes under it, loss decreasing).
"""

from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.data import sampler  # noqa: E402
from repro.data.minibatch import (  # noqa: E402
    MinibatchStream, build_kg_csr, parse_fanouts, sample_kg_blocks,
    sampled_items)
from repro.data.synthetic import (  # noqa: E402
    gen_kg_dataset, gen_zipf_kg_dataset)
from repro.models.kgnn import (  # noqa: E402
    KGNNConfig, bpr_loss, init_params, propagate, sampled_bpr_loss,
    sampled_reps)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # container image has no hypothesis; the seeded
    HAVE_HYPOTHESIS = False  # sweep below covers the same invariant


def _toy_ds(seed=0, **kw):
    kw.setdefault("n_users", 30)
    kw.setdefault("n_items", 45)
    kw.setdefault("n_attrs", 25)
    kw.setdefault("n_relations", 3)
    return gen_kg_dataset(seed=seed, **kw)


def _adj(ds):
    g = ds.graph
    return build_kg_csr(np.asarray(g.src), np.asarray(g.dst),
                        np.asarray(g.rel), g.n_nodes)


def _cfg(ds, model, n_layers=2, dim=8, l2=0.0):
    return KGNNConfig(
        model=model, n_users=ds.n_users,
        n_entities=ds.graph.n_nodes - ds.n_users,
        n_relations=ds.graph.n_relations, dim=dim, n_layers=n_layers,
        readout="concat" if model == "kgat" else "sum", l2=l2)


def _check_invariants(adj, view, input_nodes, seeds):
    """The contract every sampled minibatch must satisfy."""
    frontier = input_nodes
    assert view.n_input_rows == len(frontier)
    # blocks outermost-first; walk inward toward the seeds
    for h, b in enumerate(view.blocks):
        src = np.asarray(b.src)
        dst = np.asarray(b.dst)
        mask = np.asarray(b.mask)
        # 1. in-range: every edge endpoint is a valid local row
        assert src.min() >= 0 and src.max() < b.n_src, f"hop {h} src OOB"
        assert dst.min() >= 0 and dst.max() < b.n_dst, f"hop {h} dst OOB"
        assert b.n_src == len(frontier)
        # 2. masked pad slots are self-edges (weight-0, in-range by
        #    construction: the dst's own id is a frontier member)
        pad = mask == 0.0
        np.testing.assert_array_equal(
            frontier[src[pad]], frontier[dst[pad]],
            err_msg=f"hop {h}: pad slot is not a self-edge")
        # 3. seeds-prefix: this hop's dst frontier is the leading prefix
        frontier = frontier[: b.n_dst]
    np.testing.assert_array_equal(frontier, seeds)


def test_build_kg_csr_matches_edge_multiset():
    ds = _toy_ds()
    g = ds.graph
    adj = _adj(ds)
    src, dst, rel = map(np.asarray, (g.src, g.dst, g.rel))
    for v in [0, 1, ds.n_users, g.n_nodes - 1]:
        mine = sorted(zip(adj.src[adj.indptr[v]: adj.indptr[v + 1]],
                          adj.rel[adj.indptr[v]: adj.indptr[v + 1]]))
        ref = sorted(zip(src[dst == v], rel[dst == v]))
        assert mine == ref


def test_sampled_blocks_invariants():
    ds = _toy_ds()
    adj = _adj(ds)
    rng = np.random.default_rng(0)
    for fanouts in [(4,), (5, 3), (3, 3, 2)]:
        seeds = rng.choice(ds.graph.n_nodes, 9, replace=False)
        view, inp, req = sample_kg_blocks(adj, seeds.astype(np.int64),
                                          fanouts, rng=rng)
        assert len(view.blocks) == len(fanouts)
        _check_invariants(adj, view, inp, seeds)
        # requests only reference real nodes
        assert req.min() >= 0 and req.max() < adj.n_nodes


def test_static_shapes_across_stream():
    """Same fanouts + batch size -> identical pytree structure and leaf
    shapes for every item, so the jitted step traces exactly once."""
    ds = _toy_ds()
    with MinibatchStream(ds, (5, 3), batch_size=8, seed=1) as stream:
        a, b = stream.next(), stream.next()
    ta = jax.tree_util.tree_structure(a.view)
    tb = jax.tree_util.tree_structure(b.view)
    assert ta == tb
    sa = [x.shape for x in jax.tree_util.tree_leaves(a.view)]
    sb = [x.shape for x in jax.tree_util.tree_leaves(b.view)]
    assert sa == sb
    assert not np.array_equal(a.input_nodes, b.input_nodes)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 3),
           st.integers(1, 6))
    def test_sampler_in_range_property(seed, n_hops, fanout):
        """Property: arbitrary graph/seed draws never produce an
        out-of-range block index (hypothesis build of the sweep)."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 60))
        e = int(rng.integers(0, 4 * n))
        adj = build_kg_csr(rng.integers(0, n, e), rng.integers(0, n, e),
                           rng.integers(0, 5, e), n)
        seeds = rng.choice(n, int(rng.integers(1, min(8, n) + 1)),
                           replace=False).astype(np.int64)
        view, inp, _ = sample_kg_blocks(adj, seeds, (fanout,) * n_hops,
                                        rng=rng)
        _check_invariants(adj, view, inp, seeds)
else:
    @pytest.mark.parametrize("seed", range(20))
    def test_sampler_in_range_property(seed):
        """Seeded fallback for the hypothesis property: random graphs
        (including edgeless and self-loop-only ones) never yield an
        out-of-range block index."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 60))
        e = int(rng.integers(0, 4 * n))
        adj = build_kg_csr(rng.integers(0, n, e), rng.integers(0, n, e),
                           rng.integers(0, 5, e), n)
        seeds = rng.choice(n, int(rng.integers(1, min(8, n) + 1)),
                           replace=False).astype(np.int64)
        fanouts = tuple(rng.integers(1, 6, int(rng.integers(1, 4))))
        view, inp, _ = sample_kg_blocks(adj, seeds, fanouts, rng=rng)
        _check_invariants(adj, view, inp, seeds)


def test_legacy_sampler_pads_are_frontier_members():
    """data/sampler.py satellite fix: with zero-degree seeds the static
    pad must repeat FRONTIER node ids (self-loop semantics), not
    whichever node happens to hold the smallest global id."""
    # node 9 has in-edges from node 0 only; node 5 has none at all
    src = np.array([0, 0, 0], np.int64)
    dst = np.array([9, 9, 9], np.int64)
    indptr, indices = sampler.build_csr(src, dst, n_nodes=10)
    rng = np.random.default_rng(0)
    blocks, inp = sampler.sample_blocks(
        indptr, indices, np.array([5, 9], np.int64), [4], rng=rng)
    (blk,) = blocks
    frontier = {5, 9}
    uniq = {5, 9, 0}  # frontier + node 9's only neighbor
    pads = [x for x in blk["src_nodes"].tolist() if True][len(uniq):]
    assert pads, "expected static padding"
    assert set(pads) <= frontier, (
        f"pad ids {sorted(set(pads))} escape the frontier {frontier} "
        f"(the old uniq[0] bug padded with node 0)")
    # and the padded set is exactly the advertised static size
    assert len(blk["src_nodes"]) == blk["n_src"] == 2 * (4 + 1)


@pytest.mark.parametrize("model", ["kgat", "kgcn", "kgin", "rgcn"])
def test_take_all_fanout_is_bit_exact(model):
    """fanout >= max in-degree keeps every edge, so the sampled forward
    equals full-graph ``propagate`` at the seed rows bit-for-bit."""
    ds = _toy_ds(seed=1, n_users=20, n_items=30, n_attrs=15)
    adj = _adj(ds)
    f = adj.max_in_degree
    rng = np.random.default_rng(0)
    seeds = rng.choice(ds.graph.n_nodes, 8, replace=False).astype(np.int64)
    view, inp, _ = sample_kg_blocks(adj, seeds, (f, f), rng=rng)
    cfg = _cfg(ds, model)
    params = init_params(jax.random.PRNGKey(2), cfg)
    full = propagate(params, jax.tree_util.tree_map(jnp.asarray, ds.graph),
                     cfg)
    ps = dict(params)
    ps["entity"] = params["entity"][inp]
    samp = sampled_reps(ps, view, cfg)
    np.testing.assert_array_equal(np.asarray(samp),
                                  np.asarray(full[seeds]))


@pytest.mark.parametrize("model", ["kgat", "rgcn"])
def test_take_all_fanout_gradients_match_full_graph(model):
    """Same take-all setting, but through the BPR loss and backward:
    dense-param grads match, and the sampled entity-row grads scattered
    back to global ids match the full-table gradient."""
    ds = _toy_ds(seed=2, n_users=20, n_items=30, n_attrs=15)
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    adj = _adj(ds)
    f = adj.max_in_degree
    b = 6
    rng = np.random.default_rng(3)
    batch = {"user": rng.integers(0, ds.n_users, b).astype(np.int32),
             "pos": rng.integers(0, ds.n_items, b).astype(np.int32),
             "neg": rng.integers(0, ds.n_items, b).astype(np.int32)}
    seeds = np.concatenate([batch["user"].astype(np.int64),
                            ds.n_users + batch["pos"].astype(np.int64),
                            ds.n_users + batch["neg"].astype(np.int64)])
    view, inp, _ = sample_kg_blocks(adj, seeds, (f, f), rng=rng)
    cfg = _cfg(ds, model, l2=0.0)  # reg terms differ by design (§11)
    params = init_params(jax.random.PRNGKey(4), cfg)
    g_full = jax.grad(lambda p: bpr_loss(p, g, jax.tree_util.tree_map(
        jnp.asarray, batch), cfg))(params)

    def sampled_loss(p):
        return sampled_bpr_loss(p, view, cfg)

    ps = dict(params)
    ps["entity"] = params["entity"][inp]
    g_samp = jax.grad(sampled_loss)(ps)
    for k in g_full:
        if k == "entity":
            continue
        np.testing.assert_allclose(np.asarray(g_samp[k]),
                                   np.asarray(g_full[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    acc = np.zeros_like(np.asarray(g_full["entity"]))
    np.add.at(acc, inp, np.asarray(g_samp["entity"]))
    np.testing.assert_allclose(acc, np.asarray(g_full["entity"]),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.slow
def test_sampled_gradient_mean_approximates_full():
    """Unbiasedness: with a SMALL fanout, the mean sampled R-GCN entity
    gradient over many independent draws approaches the full-graph
    gradient (R-GCN aggregates by masked mean — the estimator the
    uniform-sampling unbiasedness argument covers exactly)."""
    ds = _toy_ds(seed=5, n_users=20, n_items=30, n_attrs=15)
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    adj = _adj(ds)
    b = 6
    rng = np.random.default_rng(6)
    batch = {"user": rng.integers(0, ds.n_users, b).astype(np.int32),
             "pos": rng.integers(0, ds.n_items, b).astype(np.int32),
             "neg": rng.integers(0, ds.n_items, b).astype(np.int32)}
    seeds = np.concatenate([batch["user"].astype(np.int64),
                            ds.n_users + batch["pos"].astype(np.int64),
                            ds.n_users + batch["neg"].astype(np.int64)])
    cfg = _cfg(ds, "rgcn", n_layers=1, l2=0.0)
    params = init_params(jax.random.PRNGKey(7), cfg)
    g_full = np.asarray(jax.grad(lambda p: bpr_loss(
        p, g, jax.tree_util.tree_map(jnp.asarray, batch), cfg)
    )(params)["entity"])

    grad_fn = jax.jit(lambda ps, view: jax.grad(
        lambda p: sampled_bpr_loss(p, view, cfg))(ps)["entity"])
    acc = np.zeros_like(g_full)
    draws = 60
    for _ in range(draws):
        view, inp, _ = sample_kg_blocks(adj, seeds, (6,), rng=rng)
        ps = dict(params)
        ps["entity"] = params["entity"][inp]
        np.add.at(acc, inp, np.asarray(grad_fn(ps, view)))
    mean = acc / draws
    num = float((mean * g_full).sum())
    den = float(np.linalg.norm(mean) * np.linalg.norm(g_full))
    cos = num / den
    rel = float(np.linalg.norm(mean - g_full) / np.linalg.norm(g_full))
    assert cos > 0.98, f"cosine(mean sampled grad, full grad) = {cos}"
    assert rel < 0.25, f"relative error {rel}"


# ---------------------------------------------------------------------------
# tier store
# ---------------------------------------------------------------------------


def test_tier_store_gather_scatter_roundtrip():
    from repro.training.tiering import TieredEmbeddingStore

    rng = np.random.default_rng(0)
    tab = rng.normal(size=(64, 6)).astype(np.float32)
    freq = rng.random(64)
    store = TieredEmbeddingStore(tab, freq, hot_frac=0.25)
    rows = np.array([1, 5, 1, 60, 33, 5, 5])  # duplicates on purpose
    out = np.asarray(store.gather(rows))
    np.testing.assert_allclose(out, tab[rows], atol=0)
    grads = jnp.asarray(rng.normal(size=(len(rows), 6)).astype(np.float32))
    updated = store.apply_grads(rows, grads, lr=0.5)
    np.testing.assert_array_equal(updated, np.unique(rows))
    exp = tab.copy()
    np.add.at(exp, rows, -0.5 * np.asarray(grads))  # dup accumulation
    np.testing.assert_allclose(store.flush(), exp, rtol=1e-6, atol=1e-6)


def test_tier_store_lfu_refresh_promotes_hot_row():
    from repro.training.tiering import TieredEmbeddingStore

    tab = np.arange(40, dtype=np.float32).reshape(20, 2)
    freq = np.zeros(20)
    freq[:2] = 100.0            # rows 0-1 start hot (hot_frac=0.1 -> 2)
    store = TieredEmbeddingStore(tab, freq, hot_frac=0.1)
    assert set(store._hot_ids) == {0, 1}
    hammered = np.full(64, 17)  # row 17 becomes the hottest
    for _ in range(8):
        store.gather(hammered)
    store.refresh()
    assert 17 in set(store._hot_ids)
    # the demoted row's values survived the flush
    np.testing.assert_allclose(store.flush(), tab)


def test_tier_store_patch_restores_sequential_semantics():
    from repro.training.tiering import TieredEmbeddingStore

    rng = np.random.default_rng(1)
    tab = rng.normal(size=(30, 4)).astype(np.float32)
    store = TieredEmbeddingStore(tab, np.arange(30), hot_frac=0.2)
    cur = np.array([2, 9, 14])
    nxt = np.array([9, 14, 22, 2])
    pre = store.gather(nxt)                     # prefetch (stale)
    grads = jnp.ones((len(cur), 4))
    updated = store.apply_grads(cur, grads, lr=0.1)
    patched = np.asarray(store.patch(pre, nxt, updated))
    fresh = np.asarray(store.gather(nxt))       # sequential reference
    np.testing.assert_allclose(patched, fresh, atol=0)


def test_hot_frac_zero_and_one_are_degenerate_tiers():
    from repro.training.tiering import TieredEmbeddingStore

    rng = np.random.default_rng(2)
    tab = rng.normal(size=(16, 3)).astype(np.float32)
    rows = np.array([0, 7, 15, 7])
    for hf, hits in ((0.0, 0), (1.0, len(rows))):
        store = TieredEmbeddingStore(tab, None, hot_frac=hf)
        np.testing.assert_allclose(np.asarray(store.gather(rows)),
                                   tab[rows], atol=0)
        assert store.stats["hot_hits"] == hits


def test_mesh_plus_sample_named_refusal():
    """data_parallel satellite: sampled inputs refuse with a NAMED
    error, not a shape crash inside shard_map."""
    from repro.training.data_parallel import check_no_sampled_dp

    ds = _toy_ds()
    it = next(iter(sampled_items(ds, (3,), batch_size=4, seed=0)))
    with pytest.raises(NotImplementedError, match="--sample.*--mesh"):
        check_no_sampled_dp(it.view)
    with pytest.raises(NotImplementedError, match="dst-partitioned"):
        check_no_sampled_dp(it)          # SampledItem unwraps too
    check_no_sampled_dp({"user": np.zeros(4)})  # plain batches pass


# ---------------------------------------------------------------------------
# end-to-end: training loop, determinism, device budget
# ---------------------------------------------------------------------------


def test_replay_determinism_bit_exact():
    """Same sampler seed + same ACT schedule -> bit-identical loss
    trajectory AND bit-identical final entity table, twice."""
    from repro.core.policy import schedule_from_cli
    from repro.models.registry import build_step
    from repro.training.tiering import run_sampled_training

    ds = _toy_ds(seed=3)
    sched = schedule_from_cli(None, 8, kernel="jnp")

    def run():
        step = build_step("kgcn", ds=ds, schedule=sched, batch_size=16,
                          n_layers=2, dim=8, device_graph=False)
        return run_sampled_training(
            step, fanouts=(4, 3), steps=5, batch_size=16, hot_frac=0.1,
            lr=0.01, schedule=sched, root_key=jax.random.PRNGKey(9),
            init_key=jax.random.PRNGKey(0), seed=11)

    rep1, dense1, store1 = run()
    rep2, dense2, store2 = run()
    assert rep1.losses == rep2.losses
    np.testing.assert_array_equal(store1.flush(), store2.flush())
    for k in dense1:
        np.testing.assert_array_equal(np.asarray(dense1[k]),
                                      np.asarray(dense2[k]), err_msg=k)


def test_sampled_training_decreases_loss():
    from repro.models.registry import build_step
    from repro.training.tiering import run_sampled_training

    ds = gen_zipf_kg_dataset(n_users=300, n_items=1500, n_attrs=600,
                             n_triples=8000, zipf_a=2.0, seed=0)
    step = build_step("kgat", ds=ds, batch_size=48, n_layers=2, dim=16,
                      device_graph=False)
    rep, _, _ = run_sampled_training(
        step, fanouts=(8, 4), steps=30, batch_size=48, hot_frac=0.1,
        lr=0.01, init_key=jax.random.PRNGKey(0), seed=0)
    first = float(np.mean(rep.losses[:8]))
    last = float(np.mean(rep.losses[-8:]))
    assert last < first - 0.02, (first, last)


@pytest.mark.slow
def test_device_budget_acceptance():
    """ISSUE 7 acceptance: a KG whose entity table alone exceeds the
    device budget trains end-to-end via the sampled path with loss
    decreasing, peak live device bytes under the budget, and a hot-tier
    hit rate >= 80% on the zipfian graph (the bench records the same
    numbers into BENCH_kernels.json)."""
    from benchmarks.minibatch_bench import ZIPF, DIM, FANOUTS
    from repro.models.registry import build_step
    from repro.training.tiering import run_sampled_training

    ds = gen_zipf_kg_dataset(**ZIPF)
    step = build_step("kgat", ds=ds, batch_size=64, n_layers=len(FANOUTS),
                      dim=DIM, device_graph=False)
    rep, _, store = run_sampled_training(
        step, fanouts=FANOUTS, steps=25, batch_size=64, hot_frac=0.1,
        lr=0.01, init_key=jax.random.PRNGKey(0), seed=0,
        measure_bytes=True)
    # REPRO_VMEM_BUDGET-style cap: the full fp32 table does not fit
    budget = rep.table_bytes
    assert rep.table_bytes > rep.store_device_bytes * 5
    assert rep.peak_device_bytes < budget, (
        f"peak {rep.peak_device_bytes} >= budget {budget}")
    assert rep.hit_rate >= 0.80, rep.hit_rate
    first = float(np.mean(rep.losses[:8]))
    last = float(np.mean(rep.losses[-8:]))
    assert last < first - 0.05, (first, last)
