"""The 2D data×model mesh (DESIGN.md §12): MeshSpec parsing, row
partitioning, the ``fetch_rows`` gather/scatter custom VJP, full-model
parity on simulated 2D meshes, the per-axis INT8 all-reduce, and the
mismatched-mesh checkpoint refusal.

Host-side geometry and error contracts run in-process (1 device);
everything needing a real mesh runs in a subprocess with forced host
devices (tests/_subproc.py).

Comparison convention for sharded trees: transfer each leaf to host
with ``np.asarray`` FIRST, then concatenate numpy ravels. (JAX 0.4.x
CPU miscompiles ``jnp.concatenate`` over mixed-sharding inputs on a 2D
mesh — replicated + row-sharded leaves come back doubled — so
``ravel_pytree`` on a device tree is off-limits here. Per-leaf
transfers are unaffected.)
"""

import numpy as np
import pytest

from _subproc import forced_device_run as _run


# ---------------------------------------------------------------------------
# MeshSpec (pure host-side, imports no jax)
# ---------------------------------------------------------------------------


def test_mesh_spec_parse_and_roundtrip():
    from repro.sharding.mesh_spec import MeshSpec

    ms = MeshSpec.parse("data=4,model=2")
    assert ms.names == ("data", "model")
    assert ms.shape == (4, 2)
    assert ms.size == 8
    assert ms.extent("data") == 4
    assert ms.extent("model") == 2
    assert ms.extent("pod") == 1          # absent axis -> default extent
    assert str(ms) == "data=4,model=2"    # exact round-trip
    assert MeshSpec.parse(str(ms)) == ms
    assert MeshSpec.parse(ms) is ms       # passthrough
    # 1D spec: model extent answers 1, placement is inert
    m1 = MeshSpec.parse("data=8")
    assert m1.shape == (8,) and m1.extent("model") == 1
    # from_shape pairs extents with names (dryrun --sim NxM)
    assert str(MeshSpec.from_shape((2, 4), ("data", "model"))) \
        == "data=2,model=4"
    assert ms.check_axes(("data", "model"), required=("data",)) is ms


@pytest.mark.parametrize("bad", [
    "", "  ", "data", "data=", "data=x", "=4", "2x4", "data=0",
    "data=-2", "data=2,data=4", "da ta=2", "data=2,,model=2",
])
def test_mesh_spec_malformed_is_one_named_error(bad):
    from repro.sharding.mesh_spec import MeshSpec, MeshSpecError

    with pytest.raises(MeshSpecError, match="mesh spec"):
        MeshSpec.parse(bad)
    assert issubclass(MeshSpecError, ValueError)


def test_mesh_spec_axis_contracts():
    from repro.sharding.mesh_spec import MeshSpec, MeshSpecError

    with pytest.raises(MeshSpecError, match="supports axes"):
        MeshSpec.parse("data=2,expert=2").check_axes(("data", "model"))
    with pytest.raises(MeshSpecError, match="missing required axis"):
        MeshSpec.parse("model=2").check_axes(("data", "model"),
                                             required=("data",))
    with pytest.raises(MeshSpecError, match="must name 3 extents"):
        MeshSpec.from_shape((2, 2), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# row partitioning geometry (host-side)
# ---------------------------------------------------------------------------


def test_row_partition_geometry():
    from repro.data.csr import row_partition

    rp = row_partition(37, 4, pad_to=40)
    assert rp.rows_per_shard == 10 and rp.n_rows_padded == 40
    # every real row maps to exactly one shard-local slot
    ids = np.arange(37)
    owner, local = rp.owner_of(ids), rp.local_of(ids)
    assert owner.max() < 4 and local.max() < rp.rows_per_shard
    np.testing.assert_array_equal(owner * rp.rows_per_shard + local, ids)
    # pad_table round-trips through blocks()
    table = np.arange(37 * 3, dtype=np.float32).reshape(37, 3)
    padded = rp.pad_table(table)
    assert padded.shape == (40, 3)
    blocks = rp.blocks(table)
    assert blocks.shape == (4, 10, 3)
    np.testing.assert_array_equal(blocks.reshape(40, 3), padded)
    np.testing.assert_array_equal(padded[:37], table)
    assert not padded[37:].any()
    with pytest.raises(ValueError, match="partition built for"):
        rp.pad_table(np.zeros((12, 3)))
    with pytest.raises(ValueError, match="n_shards"):
        row_partition(10, 0)


def test_row_partition_no_pad_hint():
    from repro.data.csr import row_partition

    rp = row_partition(10, 4)
    assert rp.rows_per_shard == 3 and rp.n_rows_padded == 12
    rp2 = row_partition(10, 4, pad_to=16)   # edge partition padded larger
    assert rp2.n_rows_padded == 16


# ---------------------------------------------------------------------------
# fetch_rows gather/scatter vs numpy (subprocess, model-only mesh)
# ---------------------------------------------------------------------------


def test_fetch_rows_gather_and_scatter_match_numpy():
    """The row-shard fetch forward equals a plain table gather, and its
    VJP equals ``np.add.at`` scatter into the owned block — the local
    scatter IS the model-axis reduce-scatter."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.data.csr import row_partition
        from repro.sharding.compat import make_sim_mesh, shard_map
        from repro.sharding.rowshard import fetch_rows

        M, D, R = 4, 5, 37
        rng = np.random.default_rng(0)
        rp = row_partition(R, M)
        table = rng.normal(size=(R, D)).astype(np.float32)
        padded = rp.pad_table(table)
        ids = rng.integers(0, R, 23).astype(np.int32)
        ct = rng.normal(size=(len(ids), D)).astype(np.float32)

        mesh = make_sim_mesh((M,), ("model",))

        def body(tab, ids_, ct_):
            f = lambda t: fetch_rows(t, ids_, axis="model",
                                     rows_per_shard=rp.rows_per_shard,
                                     n_valid=R)
            rows, vjp = jax.vjp(f, tab)
            return rows, vjp(ct_)[0]

        rows, grad = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P("model", None), P(), P()),
            out_specs=(P(), P("model", None)), check_rep=False))(
            jnp.asarray(padded), jnp.asarray(ids), jnp.asarray(ct))

        np.testing.assert_array_equal(np.asarray(rows), table[ids])
        want = np.zeros_like(padded)
        np.add.at(want, ids, ct)
        got = np.asarray(grad)
        err = float(np.abs(got - want).max())
        assert err < 1e-6, err
        assert not got[R:].any()   # pad rows never accumulate gradient
        print("fetch_rows gather+scatter ok, max err", err)
    """, n_devices=4))


def test_rowshard_l2_matches_full_table():
    """psum of per-block sums-of-squares == the full-table L2, and its
    gradient is 2x the local block (replicated-cotangent contract)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.data.csr import row_partition
        from repro.sharding.compat import make_sim_mesh, shard_map
        from repro.sharding.rowshard import rowshard_l2

        M, D, R = 4, 3, 22
        rng = np.random.default_rng(1)
        rp = row_partition(R, M)
        padded = rp.pad_table(rng.normal(size=(R, D)).astype(np.float32))
        mesh = make_sim_mesh((M,), ("model",))

        def body(tab):
            return jax.value_and_grad(
                lambda t: rowshard_l2(t, axis="model"))(tab)

        val, grad = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("model", None),),
            out_specs=(P(), P("model", None)), check_rep=False))(
            jnp.asarray(padded))
        assert abs(float(val) - float((padded ** 2).sum())) < 1e-5
        np.testing.assert_allclose(np.asarray(grad), 2 * padded, rtol=1e-6)
        print("rowshard_l2 ok", float(val))
    """, n_devices=4))


# ---------------------------------------------------------------------------
# full-model parity on 2D meshes
# ---------------------------------------------------------------------------

# Shared harness: single-device reference vs the generic DP path on a
# list of (data, model) layouts. Host-side comparison per the module
# docstring. {EXTRA} appends per-test assertions after the mesh loop.
_PARITY = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.data.synthetic import gen_kg_dataset
        from repro.models import kgnn
        from repro.models.registry import build_step, kg_dp_spec
        from repro.sharding.mesh_spec import MeshSpec
        from repro.training import data_parallel as dp

        def host(tree):
            return jax.tree_util.tree_map(np.asarray, tree)

        def flat(tree):
            return np.concatenate(
                [np.ravel(x) for x in jax.tree_util.tree_leaves(tree)])

        def rel_err(a, b):
            fa, fb = flat(a), flat(b)
            return float(np.abs(fa - fb).max() / (np.abs(fa).max() + 1e-30))

        ARCH = {arch!r}
        ds = gen_kg_dataset(n_users=16, n_items=32, n_attrs=16, seed=0)
        step = build_step(ARCH, ds=ds, dim=8, n_layers=2, batch_size=32)
        cfg, g = step.cfg, step.data["graph"]
        spec = kg_dp_spec(cfg, g)
        params = step.init(jax.random.PRNGKey(0))
        batch = next(iter(step.batches()))
        root = jax.random.PRNGKey(7)

        def ref_loss(p):
            view = kgnn.FullGraphView(g)
            return kgnn.kg_shard_loss(
                p, view, batch, cfg,
                site_keys=dp._site_keys(None, 0, spec),
                site_policies=dp._site_policies(None, spec))[0]

        ref_l, ref_g = jax.value_and_grad(ref_loss)(params)
        ref_g = host(ref_g)
        reps_ref = np.asarray(kgnn.readout(kgnn.propagate_view(
            params, kgnn.FullGraphView(g), cfg,
            site_keys=dp._site_keys(None, 0, spec),
            site_policies=dp._site_policies(None, spec)), cfg))

        for d_, m_ in {meshes}:
            ms = MeshSpec.parse(f"data={{d_}},model={{m_}}")
            mesh = ms.build_sim()
            part = dp.partition_graph(g, mesh, axis="data")
            p2 = dp.pad_row_sharded(params, spec, part, m_)
            reps2 = np.asarray(dp.dp_forward_reps(
                spec, p2, part, mesh=mesh, model_axis="model"))
            assert np.array_equal(reps_ref, reps2), \\
                (ARCH, d_, m_, "forward reps not bit-exact")
            loss2, g2 = dp.dp_loss_and_grads(
                spec, p2, part, batch, mesh=mesh, model_axis="model",
                root_key=root, compress_grads=False)
            assert abs(float(loss2) - float(ref_l)) < 1e-6, \\
                (ARCH, d_, m_, float(ref_l), float(loss2))
            g2u = host(dp.unpad_row_sharded(g2, spec, g.n_nodes))
            r = rel_err(ref_g, g2u)
            assert r < 1e-5, (ARCH, d_, m_, r)
            print(ARCH, f"{{d_}}x{{m_}}", "reps bit-exact, loss exact,",
                  "grad rel", f"{{r:.2e}}", flush=True)
"""


def test_mesh2d_parity_smoke_kgat_2x2():
    """Fast tier: one arch, one 2x2 mesh — reps bit-exact, loss exact,
    grads <=1e-5 vs single device."""
    print(_run(_PARITY.format(arch="kgat", meshes=[(2, 2)]) + """
        print("mesh2d smoke ok")
    """, n_devices=4))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["kgat", "kgcn", "kgin"])
def test_mesh2d_parity_every_arch_2x2_1x4_4x1(arch):
    """Every registered KG arch holds the full 2D exactness contract on
    2x2, 1x4 (pure model-parallel) and 4x1 (placement inert) layouts:
    forward reps BIT-exact, loss exact, gradients <=1e-5 relative."""
    print(_run(_PARITY.format(arch=arch, meshes=[(2, 2), (1, 4), (4, 1)])
               + """
        print("mesh2d parity ok for", ARCH)
    """, n_devices=4, timeout=900))


@pytest.mark.slow
def test_mesh2d_jitted_training_parity_1d_vs_2d():
    """3 jitted ``make_dp_step`` steps on data=2 vs data=2,model=2 from
    the same init produce the same losses and parameters (<=1e-5) —
    the optimizer update commutes with the row layout."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.data.synthetic import gen_kg_dataset
        from repro.models.registry import build_step, kg_dp_spec
        from repro.sharding.mesh_spec import MeshSpec
        from repro.training import data_parallel as dp
        from repro.training.optimizer import adam

        def host(tree):
            return jax.tree_util.tree_map(np.asarray, tree)

        def flat(tree):
            return np.concatenate(
                [np.ravel(x) for x in jax.tree_util.tree_leaves(tree)])

        ds = gen_kg_dataset(n_users=16, n_items=32, n_attrs=16, seed=0)
        step = build_step("kgat", ds=ds, dim=8, n_layers=2, batch_size=32)
        cfg, g = step.cfg, step.data["graph"]
        spec = kg_dp_spec(cfg, g)
        root = jax.random.PRNGKey(3)
        params0 = step.init(jax.random.PRNGKey(0))
        batches = [next(iter(step.batches())) for _ in range(3)]
        opt = adam(1e-2)

        ms1 = MeshSpec.parse("data=2")
        mesh1 = ms1.build_sim()
        part1 = dp.partition_graph(g, mesh1)
        ts1 = dp.make_dp_step(spec, part1, mesh1, opt, root_key=root,
                              mesh_spec=ms1, compress_grads=False)
        st1 = (params0, opt.init(params0))
        for i, b in enumerate(batches):
            st1, m1 = ts1(st1, b, i)

        ms2 = MeshSpec.parse("data=2,model=2")
        mesh2 = ms2.build_sim()
        part2 = dp.partition_graph(g, mesh2)
        p2 = dp.pad_row_sharded(params0, spec, part2, 2)
        ts2 = dp.make_dp_step(spec, part2, mesh2, opt, root_key=root,
                              mesh_spec=ms2, compress_grads=False)
        st2 = (p2, opt.init(p2))
        for i, b in enumerate(batches):
            st2, m2 = ts2(st2, b, i)

        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-6
        pa = flat(host(st1[0]))
        pb = flat(host(dp.unpad_row_sharded(st2[0], spec, g.n_nodes)))
        r = float(np.abs(pa - pb).max() / (np.abs(pa).max() + 1e-30))
        assert r < 1e-5, r
        print("1D vs 2D training parity ok: 3-step param rel", f"{r:.2e}",
              "loss", float(m2["loss"]))
    """, n_devices=4, timeout=900))


@pytest.mark.slow
def test_mesh2d_int8_allreduce_unbiased():
    """The per-axis compressed all-reduce on the 2D mesh is an unbiased
    estimator of the exact per-axis reduction: the mean over 150 psum
    keys converges to the fp32-reduced gradients while single draws sit
    far out; the row-sharded entity grads (never re-reduced over model)
    stay close to exact in EVERY draw."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.data.synthetic import gen_kg_dataset
        from repro.models.registry import build_step, kg_dp_spec
        from repro.sharding.mesh_spec import MeshSpec
        from repro.training import data_parallel as dp

        def host(tree):
            return jax.tree_util.tree_map(np.asarray, tree)

        ds = gen_kg_dataset(n_users=16, n_items=32, n_attrs=16, seed=0)
        step = build_step("kgat", ds=ds, dim=8, n_layers=2, batch_size=32)
        cfg, g = step.cfg, step.data["graph"]
        spec = kg_dp_spec(cfg, g)
        params = step.init(jax.random.PRNGKey(0))
        batch = next(iter(step.batches()))

        ms = MeshSpec.parse("data=2,model=2")
        mesh = ms.build_sim()
        part = dp.partition_graph(g, mesh, axis="data")
        p2 = dp.pad_row_sharded(params, spec, part, 2)
        _, g_exact = dp.dp_loss_and_grads(
            spec, p2, part, batch, mesh=mesh, model_axis="model",
            root_key=jax.random.PRNGKey(0), compress_grads=False)
        ge = host(g_exact)

        @jax.jit
        def comp(root):
            _, gr = dp.dp_loss_and_grads(
                spec, p2, part, batch, mesh=mesh, model_axis="model",
                root_key=root, compress_grads=True)
            return gr

        K = 150
        le = jax.tree_util.tree_leaves(ge)
        acc = [np.zeros_like(x) for x in le]
        single = None
        for key in jax.random.split(jax.random.PRNGKey(5), K):
            lv = jax.tree_util.tree_leaves(host(comp(key)))
            for i, x in enumerate(lv):
                acc[i] += x
            if single is None:
                single = max(float(np.abs(a - b).max())
                             for a, b in zip(lv, le))
        mean_err = max(float(np.abs(a / K - b).max())
                       for a, b in zip(acc, le))
        assert single < 5e-3, single
        assert mean_err < 1e-4, mean_err
        assert mean_err < single / 5, (single, mean_err)
        print("2D int8 all-reduce unbiased: single", single,
              "mean-of-%d" % K, mean_err)
    """, n_devices=4, timeout=900))


# ---------------------------------------------------------------------------
# acceptance: table >= 8x one device's parameter budget
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_entity_table_8x_device_budget():
    """data=1,model=16: train a KG whose entity table is >= 8x a
    simulated per-device parameter budget while each device holds only
    its 1/16 block — resident table bytes stay under budget (ISSUE 8
    acceptance)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.data.synthetic import gen_kg_dataset
        from repro.models.registry import build_step, kg_dp_spec
        from repro.sharding.mesh_spec import MeshSpec
        from repro.training import data_parallel as dp
        from repro.training.optimizer import adam

        M = 16
        ds = gen_kg_dataset(n_users=64, n_items=1500, n_attrs=500, seed=0)
        step = build_step("kgat", ds=ds, dim=16, n_layers=2, batch_size=64)
        cfg, g = step.cfg, step.data["graph"]
        spec = kg_dp_spec(cfg, g)

        table_bytes = cfg.n_nodes * cfg.dim * 4
        budget = table_bytes // 8           # the simulated device budget
        assert table_bytes >= 8 * budget

        ms = MeshSpec.parse(f"data=1,model={M}")
        mesh = ms.build_sim()
        part = dp.partition_graph(g, mesh, axis="data")
        params = dp.pad_row_sharded(
            step.init(jax.random.PRNGKey(0)), spec, part, M)
        opt = adam(step.lr)
        ts = dp.make_dp_step(spec, part, mesh, opt, root_key=
                             jax.random.PRNGKey(1), mesh_spec=ms,
                             compress_grads=False)
        state = (params, opt.init(params))
        losses = []
        it = iter(step.batches())
        for i in range(6):
            state, m = ts(state, next(it), i)
            losses.append(float(m["loss"]))

        # per-device resident block, measured from the live sharded array
        ent = state[0]["entity"]
        shard_bytes = max(s.data.nbytes for s in ent.addressable_shards)
        assert shard_bytes <= budget, (shard_bytes, budget)
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print(f"8x-budget ok: table {table_bytes/2**20:.2f} MiB, "
              f"budget {budget/2**20:.2f} MiB/dev, resident "
              f"{shard_bytes/2**20:.2f} MiB/dev "
              f"({table_bytes/shard_bytes:.1f}x), loss "
              f"{losses[0]:.4f} -> {losses[-1]:.4f}")
    """, n_devices=16, timeout=900))


# ---------------------------------------------------------------------------
# checkpoint topology contract (in-process)
# ---------------------------------------------------------------------------


def test_checkpoint_refuses_mesh_mismatch_naming_both():
    """Restoring a data=2 checkpoint on a data=2,model=2 run is refused
    with BOTH topologies in the message plus the --reshard-from hint."""
    from repro.training.checkpoint import check_meta

    stored = {"arch": "kgat", "mesh": "data=2", "placement": None}
    expected = {"arch": "kgat", "mesh": "data=2,model=2",
                "placement": "entity=rows"}
    with pytest.raises(ValueError) as ei:
        check_meta(stored, expected, where="ckpt/step_0000000010")
    msg = str(ei.value)
    assert "'data=2'" in msg and "'data=2,model=2'" in msg
    assert "refusing a silent mismatch" in msg
    assert "--reshard-from" in msg
    # same-topology restore passes; legacy checkpoints (no mesh key)
    # restore as before
    check_meta(expected, expected)
    check_meta({"arch": "kgat"}, expected)


def test_step_metadata_records_mesh_and_placement(tmp_path):
    """step_metadata stamps the topology; a full save/restore cycle
    through restore_checkpoint enforces it."""
    import jax
    import numpy as np

    from repro.models.registry import build_step
    from repro.sharding.mesh_spec import MeshSpec
    from repro.training.checkpoint import restore_checkpoint, \
        save_checkpoint
    from repro.training.step import step_metadata

    step = build_step("kgat")
    ms = MeshSpec.parse("data=2,model=2")
    meta = step_metadata(step, "int2", mesh_spec=ms,
                         placement=step.dp_spec.placement_str())
    assert meta["mesh"] == "data=2,model=2"
    assert meta["placement"] == "entity=rows"

    tree = {"w": np.arange(4.0)}
    save_checkpoint(str(tmp_path), 3, tree, meta=meta)
    # same meta restores
    s, out = restore_checkpoint(str(tmp_path), tree, expect_meta=meta)
    assert s == 3
    # a 1D run refuses it, naming the mesh
    bad = dict(meta, mesh="data=4")
    with pytest.raises(ValueError, match="--reshard-from"):
        restore_checkpoint(str(tmp_path), tree, expect_meta=bad)
    # a layout-agnostic expectation (the --reshard-from path) accepts it
    agnostic = {k: v for k, v in meta.items()
                if k not in ("mesh", "placement")}
    s2, _ = restore_checkpoint(str(tmp_path), tree, expect_meta=agnostic)
    assert s2 == 3
