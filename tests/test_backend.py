"""Backend dispatch + tile autotuner tests (ISSUE-6 acceptance).

Covers:
  * autotune cache round-trip determinism — the same backend fingerprint
    and shape key must return the stored winner with ZERO re-measurement
    in a fresh Autotuner (a second process loading the file);
  * dispatch fallback — requesting compiled on a runner without a native
    Pallas lowering delivers interpret and logs the degradation warning
    exactly once per op;
  * the shared heuristics (shape_bucket, pick_block) and the VMEM-budget
    env override that steers the DMA-vs-VMEM SPMM dispatch.
"""

import json
import logging

import pytest

from repro.kernels import autotune, backend

# ---------------------------------------------------------------------------
# probe / dispatch
# ---------------------------------------------------------------------------


def test_probe_backend_is_consistent():
    info = backend.probe_backend()
    assert info.platform in ("cpu", "gpu", "tpu", "cuda", "rocm")
    assert info.fingerprint.startswith(info.platform + "-")
    assert info.default_mode in backend.MODES
    assert info.compiled_available == (info.default_mode == "compiled")
    # probe is cached: same object both times
    assert backend.probe_backend() is info


def test_resolve_mode_auto_and_passthrough():
    info = backend.probe_backend()
    assert backend.resolve_mode("auto") == info.default_mode
    assert backend.resolve_mode("interpret") == "interpret"
    assert backend.resolve_mode("jnp") == "jnp"
    with pytest.raises(ValueError, match="unknown mode"):
        backend.resolve_mode("fastest")
    assert backend.interpret_flag("compiled") is False
    assert backend.interpret_flag("interpret") is True
    assert backend.interpret_flag("jnp") is True


@pytest.mark.skipif(backend.probe_backend().compiled_available,
                    reason="this runner HAS a compiled Pallas lowering; "
                           "the degradation path cannot trigger")
def test_compiled_request_degrades_with_one_warning(caplog):
    """compiled requested, interpret delivered, warning logged ONCE."""
    backend.reset_warnings()
    with caplog.at_level(logging.WARNING, logger="repro.kernels.backend"):
        m1 = backend.resolve_mode("compiled", op="spmm")
        m2 = backend.resolve_mode("compiled", op="spmm")   # no second warn
        m3 = backend.resolve_mode("compiled", op="quant_pack")  # new op
    assert m1 == m2 == m3 == "interpret"
    warns = [r for r in caplog.records if "delivering interpret" in
             r.getMessage()]
    assert len(warns) == 2                     # one per op, not per call
    assert "spmm" in warns[0].getMessage()
    backend.reset_warnings()


def test_vmem_budget_env_override(monkeypatch):
    default = backend.vmem_budget_bytes()
    assert default == 16 * 2**20
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")
    assert backend.vmem_budget_bytes() == 4096


def test_pick_block_divides():
    assert backend.pick_block(512, 512) == 512
    assert backend.pick_block(96, 512) == 96
    assert backend.pick_block(96, 64) == 48
    assert backend.pick_block(17, 8) == 1


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------


def test_shape_bucket_powers_of_two():
    assert [autotune.shape_bucket(n) for n in (0, 1, 2, 3, 64, 65, 4096)] \
        == [1, 1, 2, 4, 64, 128, 4096]


def test_autotune_key_buckets_nearby_shapes():
    k1 = autotune.Autotuner.key("spmm", (2000, 128, 16000), bits=4)
    k2 = autotune.Autotuner.key("spmm", (1500, 100, 9000), bits=4)
    assert k1 == k2 == "spmm|2048x128x16384|b4"
    assert autotune.Autotuner.key("topk", (8,), extra="k20") == \
        "topk|8|k20"


def test_autotune_sweep_picks_fastest_and_caches(tmp_path):
    path = str(tmp_path / "cache.json")
    tuner = autotune.Autotuner(path, sweep=True, fingerprint="test-fp",
                               reps=1)
    calls = []

    def measure(params):
        calls.append(params["block"])
        if params["block"] == 13:
            raise ValueError("invalid tile on this backend")

    win = tuner.pick("op", shapes=(100, 64), bits=4,
                     candidates=[{"block": 8}, {"block": 13}, {"block": 32}],
                     measure=measure, default={"block": 99})
    assert win["block"] in (8, 32)             # 13 raised -> excluded
    assert tuner.n_sweeps == 2
    # the invalid candidate is absent from the stored timings
    with open(path) as f:
        data = json.load(f)
    entry = data["test-fp"]["op|128x64|b4"]
    assert entry["winner"] == win
    assert all("13" not in k for k in entry["us"])


def test_autotune_cache_roundtrip_no_resweep(tmp_path):
    """Determinism contract: same fingerprint -> same winners, and a
    fresh Autotuner over the same file performs ZERO measurements."""
    path = str(tmp_path / "cache.json")
    t1 = autotune.Autotuner(path, sweep=True, fingerprint="fp-a", reps=1)
    win1 = t1.pick("spmm", shapes=(512, 128), bits=None,
                   candidates=[{"block_d": 64}, {"block_d": 128}],
                   measure=lambda p: None, default={"block_d": 512})

    t2 = autotune.Autotuner(path, sweep=True, fingerprint="fp-a", reps=1)

    def explode(params):
        raise AssertionError("cache hit must not re-measure")

    win2 = t2.pick("spmm", shapes=(512, 128), bits=None,
                   candidates=[{"block_d": 64}, {"block_d": 128}],
                   measure=explode, default={"block_d": 512})
    assert win1 == win2
    assert t2.n_sweeps == 0

    # a DIFFERENT fingerprint must not see fp-a's winners
    t3 = autotune.Autotuner(path, sweep=False, fingerprint="fp-b")
    assert t3.lookup(autotune.Autotuner.key("spmm", (512, 128))) is None


def test_autotune_default_without_sweep(tmp_path):
    """sweep disabled + cache miss -> heuristic default, nothing written."""
    path = str(tmp_path / "cache.json")
    tuner = autotune.Autotuner(path, sweep=False, fingerprint="fp-c")
    win = tuner.pick("dqmm", shapes=(64, 64), bits=2,
                     candidates=[{"block": 1}],
                     measure=lambda p: (_ for _ in ()).throw(
                         AssertionError("must not measure")),
                     default={"block": 7})
    assert win == {"block": 7}
    assert tuner.n_sweeps == 0
    import os
    assert not os.path.exists(path)            # defaults are not cached


def test_autotune_corrupt_cache_recovers(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text("{not json")
    tuner = autotune.Autotuner(str(path), sweep=False, fingerprint="fp")
    assert tuner.lookup("anything") is None    # fresh empty cache


def test_singleton_reset(tmp_path):
    orig = autotune.get()
    try:
        t = autotune.reset(str(tmp_path / "c.json"), sweep=False)
        assert autotune.get() is t
        assert t.path == str(tmp_path / "c.json")
    finally:
        autotune._singleton = orig
