"""Shared pytest configuration for the tier-1 suite.

Two jobs:

1. **Optional-dependency guards.** Some test modules use extras (e.g.
   ``hypothesis`` for property-based sweeps) that are not part of the
   baked container image. Those modules guard their own imports with a
   module-level ``pytest.importorskip("<dep>")`` so collection succeeds
   everywhere (the module reports as skipped instead of erroring).

2. **Test tiers.** The full suite exercises Pallas kernels in interpret
   mode (the kernel body runs in Python), which makes the heaviest cases
   slow on CPU. Those carry ``@pytest.mark.slow``; the fast tier is

       PYTHONPATH=src python -m pytest -q -m "not slow"

   and finishes in well under two minutes. CI (.github/workflows/ci.yml)
   runs the fast tier on CPU; the slow tier is a local/pre-release gate.
   See DESIGN.md §5.
"""

from __future__ import annotations


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy interpret-mode/statistical cases; deselect with "
        '-m "not slow" for the fast tier')
