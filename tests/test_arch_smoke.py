"""Per-assigned-architecture smoke tests: reduced config, one forward or
train step on CPU, asserting output shapes + finiteness (no NaNs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get
from repro.configs.smoke import reduced
from repro.core.policy import INT2

KEY = jax.random.PRNGKey(0)


def _finite(tree) -> bool:
    return all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(tree))


LM_ARCHS = [a for a in ASSIGNED if get(a).family in ("lm", "moe_lm")]
RECSYS_ARCHS = [a for a in ASSIGNED if get(a).family == "recsys"]


@pytest.mark.slow
@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_train_step(name):
    from repro.models import transformer as tf
    arch = reduced(get(name))
    cfg = arch.model_cfg
    params = tf.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 33), 0, cfg.vocab)
    loss, grads = jax.jit(
        jax.value_and_grad(tf.lm_loss), static_argnames=("cfg", "policy"))(
        params, {"tokens": toks}, cfg=cfg, policy=INT2, key=KEY)
    assert np.isfinite(float(loss))
    assert _finite(grads)


@pytest.mark.slow
@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_decode_step(name):
    from repro.models import transformer as tf
    arch = reduced(get(name))
    cfg = arch.model_cfg
    params = tf.init_params(KEY, cfg)
    cache = tf.init_cache(cfg, batch=2, max_len=64)
    logits, cache = jax.jit(tf.prefill, static_argnames="cfg")(
        params, jax.random.randint(KEY, (2, 16), 0, cfg.vocab),
        cfg=cfg, cache=cache)
    assert logits.shape == (2, cfg.vocab)
    nxt = jnp.argmax(logits, -1)[:, None]
    logits2, cache = jax.jit(tf.decode_step, static_argnames="cfg")(
        params, cache, nxt, cfg)
    assert logits2.shape == (2, cfg.vocab)
    assert int(cache["len"]) == 17
    assert _finite(logits2)


def test_gcn_cora_full_graph():
    from repro.data.synthetic import cora_like
    from repro.models import gnn
    arch = reduced(get("gcn-cora"))
    cfg = arch.model_cfg
    feats, src, dst, labels = cora_like(n_nodes=60, d_feat=cfg.d_in,
                                        n_classes=cfg.n_classes)
    params = gnn.init_params(KEY, cfg)

    def loss_fn(p):
        logits = gnn.gcn_forward(p, jnp.asarray(feats), jnp.asarray(src),
                                 jnp.asarray(dst), n_nodes=60, cfg=cfg,
                                 policy=INT2, key=KEY)
        onehot = jax.nn.one_hot(labels, cfg.n_classes)
        return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)) and _finite(grads)


def test_gcn_cora_minibatch_blocks():
    from repro.data.sampler import build_csr, sample_blocks
    from repro.data.synthetic import cora_like
    from repro.models import gnn
    arch = reduced(get("gcn-cora"))
    cfg = arch.model_cfg
    feats, src, dst, labels = cora_like(n_nodes=200, d_feat=cfg.d_in)
    indptr, indices = build_csr(np.asarray(src), np.asarray(dst), 200)
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, 200, 16)
    blocks, input_nodes = sample_blocks(indptr, indices, seeds, [5, 3],
                                        rng=rng)
    x = jnp.asarray(feats[input_nodes])
    jb = [{"src": jnp.asarray(b["src"]), "dst": jnp.asarray(b["dst"]),
           "n_src": b["n_src"], "n_dst": b["n_dst"]} for b in blocks]
    params = gnn.init_params(KEY, cfg)
    out = gnn.gcn_forward_blocks(params, x, jb, cfg=cfg, policy=INT2, key=KEY)
    assert out.shape == (16, cfg.n_classes)
    assert _finite(out)


def test_gcn_molecule_batched():
    from repro.models import gnn
    arch = reduced(get("gcn-cora"))
    cfg = arch.model_cfg
    B, n, e = 8, 30, 64
    rng = np.random.default_rng(0)
    src = np.concatenate([rng.integers(0, n, e) + i * n for i in range(B)])
    dst = np.concatenate([rng.integers(0, n, e) + i * n for i in range(B)])
    gid = np.repeat(np.arange(B), n)
    x = jnp.asarray(rng.normal(size=(B * n, cfg.d_in)), jnp.float32)
    params = gnn.init_params(KEY, cfg)
    out = gnn.gcn_forward_batched(params, x, jnp.asarray(src),
                                  jnp.asarray(dst), jnp.asarray(gid),
                                  n_graphs=B, n_nodes=B * n, cfg=cfg,
                                  policy=INT2, key=KEY)
    assert out.shape == (B, cfg.n_classes)
    assert _finite(out)


@pytest.mark.slow
@pytest.mark.parametrize("name", RECSYS_ARCHS)
def test_recsys_train_step(name):
    from repro.models import recsys
    arch = reduced(get(name))
    cfg = arch.model_cfg
    params = recsys.init_params(KEY, cfg)
    B = 32
    batch = {
        "sparse": jax.random.randint(KEY, (B, cfg.n_sparse), 0,
                                     min(cfg.vocab_sizes)),
        "dense": jax.random.normal(KEY, (B, max(cfg.n_dense, 1))),
        "label": (jax.random.uniform(KEY, (B,)) > 0.5).astype(jnp.float32),
    }

    def loss_fn(p):
        logits = recsys.forward(p, batch, cfg, policy=INT2, key=KEY)
        z = jax.nn.log_sigmoid(logits)
        zn = jax.nn.log_sigmoid(-logits)
        return -jnp.mean(batch["label"] * z + (1 - batch["label"]) * zn)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)) and _finite(grads)


@pytest.mark.parametrize("name", RECSYS_ARCHS)
def test_recsys_retrieval(name):
    from repro.models import recsys
    arch = reduced(get(name))
    cfg = arch.model_cfg
    params = recsys.init_params(KEY, cfg)
    q = {"sparse": jax.random.randint(KEY, (cfg.n_sparse,), 0,
                                      min(cfg.vocab_sizes))}
    scores = recsys.retrieval_scores(params, q, jnp.arange(100), cfg)
    assert scores.shape == (100,)
    assert _finite(scores)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["kgat", "kgcn", "kgin"])
def test_paper_kgnn_train_step(name):
    from repro.models import kgnn
    arch = reduced(get(name))
    cfg = arch.model_cfg
    E = 300
    g = kgnn.CKG(
        src=jax.random.randint(KEY, (E,), 0, cfg.n_nodes),
        dst=jax.random.randint(jax.random.PRNGKey(1), (E,), 0, cfg.n_nodes),
        rel=jax.random.randint(jax.random.PRNGKey(2), (E,), 0,
                               cfg.n_relations),
        n_nodes=cfg.n_nodes, n_relations=cfg.n_relations)
    params = kgnn.init_params(KEY, cfg)
    batch = {"user": jnp.array([0, 1]), "pos": jnp.array([3, 4]),
             "neg": jnp.array([5, 6])}
    loss, grads = jax.jit(
        jax.value_and_grad(kgnn.bpr_loss),
        static_argnames=("cfg", "policy"))(
        params, g, batch, cfg=cfg, policy=INT2, key=KEY)
    assert np.isfinite(float(loss)) and _finite(grads)
