"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp ref.py oracles.

quant_pack is compared BIT-EXACTLY (same counter-hash SR draws); the
fused dequant+GEMM within fp32 matmul tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import dequantize as core_dequantize
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.hashrng import hash_uniform, key_to_seed

KEY = jax.random.PRNGKey(42)

SHAPES = [(8, 8), (64, 128), (33, 64), (200, 16), (7, 256), (128, 96)]
BITS = [1, 2, 4, 8]


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("shape", SHAPES)
def test_quant_pack_vs_oracle(bits, shape):
    x = jax.random.normal(jax.random.fold_in(KEY, hash(shape) % 1000), shape)
    q = kops.quantize(x, KEY, bits=bits)
    rp, rs, rz = kref.ref_quant_pack(x, key_to_seed(KEY), bits=bits)
    np.testing.assert_array_equal(np.asarray(q.packed), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(q.scale), np.asarray(rs), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(q.zero), np.asarray(rz), rtol=1e-6)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dequant_unpack_vs_oracle(bits, dtype):
    x = jax.random.normal(KEY, (48, 64)).astype(dtype)
    q = kops.quantize(x, KEY, bits=bits)
    out = kops.dequantize(q)
    ref = kref.ref_dequant_unpack(q.packed, q.scale, q.zero, bits=bits,
                                  dim=64, out_dtype=dtype)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=1e-2,
                               atol=1e-2)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("shape,n", [((64, 128), 32), ((37, 64), 8),
                                     ((256, 96), 100)])
def test_dequant_matmul_vs_oracle(bits, shape, n):
    x = jax.random.normal(KEY, shape)
    g = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[0], n))
    q = kops.quantize(x, KEY, bits=bits)
    out = kops.dequant_matmul(q, g)
    ref = kref.ref_dequant_matmul(q.packed, q.scale, q.zero, g, bits=bits,
                                  dim=shape[1])
    assert out.shape == (shape[1], n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("bits", [1, 2, 4])
def test_quantize_odd_feature_dim_stays_fused(bits):
    """d % (8/bits) != 0 pads the last pack chunk IN-KERNEL (masked
    minmax, zero pad codes) — no more silent jnp fallback. The result is
    bit-exact vs the counter-hash oracle, whose pack_bits zero-pads the
    tail the same way."""
    d = 65  # odd: 65 % {8,4,2} != 0
    x = jax.random.normal(KEY, (12, d))
    q = kops.quantize(x, KEY, bits=bits)  # must not raise
    rp, rs, rz = kref.ref_quant_pack(x, key_to_seed(KEY), bits=bits)
    np.testing.assert_array_equal(np.asarray(q.packed), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(q.scale), np.asarray(rs), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(q.zero), np.asarray(rz), rtol=1e-6)
    # roundtrip bounded by one quantization bin per row
    err = jnp.abs(core_dequantize(q) - x)
    assert float((err - q.scale).max()) < 1e-5
    # fused dequant strips the pad features
    np.testing.assert_allclose(np.asarray(kops.dequantize(q)),
                               np.asarray(core_dequantize(q)), atol=1e-6)


def test_odd_feature_dim_trains_end_to_end_pallas():
    """The padded-pack QTensor must survive the BACKWARD too: the fused
    dequant_matmul and spmm_grad_ew kernels consume it directly, masking
    the tail features in-kernel (regression: they used to assert
    dp*cpb == dim and fall back / crash in grad)."""
    from repro.core import act_matmul
    from repro.core.policy import ACTPolicy
    d = 65
    x = jax.random.normal(KEY, (16, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d, 8))
    pol = ACTPolicy(bits=4, kernel="pallas")
    gw = jax.grad(lambda w_: (act_matmul(
        x, w_, key=KEY, policy=pol) ** 2).sum())(w)
    exact = jax.grad(lambda w_: ((x @ w_) ** 2).sum())(w)
    rel = float(jnp.abs(gw - exact).max() / jnp.abs(exact).max())
    assert rel < 0.25, rel


def test_kernel_core_interop():
    """Either backend can dequantize the other's QTensor (shared layout)."""
    x = jax.random.normal(KEY, (32, 64))
    q = kops.quantize(x, KEY, bits=2)
    a = core_dequantize(q)
    b = kops.dequantize(q)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_hash_uniformity():
    """Counter-hash uniforms: mean≈1/2, var≈1/12, low autocorrelation."""
    idx = jnp.arange(1 << 16, dtype=jnp.uint32)
    u = hash_uniform(idx, jnp.uint32(12345))
    assert abs(float(u.mean()) - 0.5) < 5e-3
    assert abs(float(u.var()) - 1 / 12) < 5e-3
    ac = float(jnp.corrcoef(u[:-1], u[1:])[0, 1])
    assert abs(ac) < 0.02


def test_pallas_act_policy_end_to_end():
    """ACTPolicy(kernel='pallas') trains a matmul like the jnp backend."""
    from repro.core import act_matmul
    from repro.core.policy import ACTPolicy
    x = jax.random.normal(KEY, (32, 64))
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (64, 16))
    exact = jax.grad(lambda w_: ((x @ w_) ** 2).sum())(w)
    rels = {}
    for backend in ("jnp", "pallas"):
        pol = ACTPolicy(bits=4, kernel=backend)
        g = jax.grad(lambda w_: (act_matmul(
            x, w_, key=KEY, policy=pol) ** 2).sum())(w)
        rels[backend] = float(jnp.abs(g - exact).max() / jnp.abs(exact).max())
        assert rels[backend] < 0.25, (backend, rels[backend])
    # backends carry the same noise magnitude (different SR draws)
    assert abs(rels["jnp"] - rels["pallas"]) < 0.1, rels
