"""Training substrate: optimizer, metrics, checkpoint, trainer fault
tolerance, gradient compression, data pipeline."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.metrics import auc, recall_ndcg_at_k
from repro.training.optimizer import adam, adamw, cosine_warmup, sgd
from repro.training.trainer import PrefetchIterator, Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


# --- optimizer -------------------------------------------------------------


@pytest.mark.parametrize("make_opt", [
    lambda: adam(0.05), lambda: adamw(0.05, weight_decay=0.001),
    lambda: sgd(0.05, momentum=0.9),
])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2)(params)
        return opt.update(g, state, params)

    for _ in range(300):
        params, state = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert abs(float(params["b"])) < 0.05


def test_adam_bf16_params_fp32_moments():
    opt = adam(0.1)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["mu"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    new_params, state = opt.update(g, state, params)
    assert new_params["w"].dtype == jnp.bfloat16


def test_clip_norm():
    opt = adam(1.0, clip_norm=1e-4)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    g = {"w": jnp.ones(3) * 1e6}
    new_params, _ = opt.update(g, state, params)
    assert float(jnp.abs(new_params["w"]).max()) < 1.1  # step bounded by lr


def test_cosine_schedule_shape():
    s = cosine_warmup(1.0, warmup=10, total=100, floor=0.1)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


# --- metrics ---------------------------------------------------------------


def test_recall_ndcg_basics():
    scores = jnp.array([[9., 8, 7, 6, 5], [1, 2, 3, 4, 5]])
    test = jnp.array([[1, 1, 0, 0, 0], [1, 0, 0, 0, 0]], bool)
    train = jnp.zeros((2, 5), bool)
    r, n = recall_ndcg_at_k(scores, test, train, k=2)
    # user0: both in top2 -> recall 1; user1: item0 ranked last -> 0
    assert float(r) == pytest.approx(0.5)
    assert 0 < float(n) <= 1


def test_recall_excludes_train_positives():
    scores = jnp.array([[10., 9, 1, 0, 0]])
    train = jnp.array([[1, 0, 0, 0, 0]], bool)   # top item is train pos
    test = jnp.array([[0, 1, 0, 0, 0]], bool)
    r, _ = recall_ndcg_at_k(scores, test, train, k=1)
    assert float(r) == 1.0  # train item masked, test item promoted


def test_auc_random_is_half():
    logits = jax.random.normal(KEY, (4000,))
    labels = jax.random.bernoulli(jax.random.fold_in(KEY, 1),
                                  0.5, (4000,)).astype(jnp.float32)
    assert abs(float(auc(logits, labels)) - 0.5) < 0.05


def test_auc_ties_average_ranks():
    """Regression: tied logits used to inherit argsort's arbitrary order;
    average ranks make a tied pos/neg pair count exactly 1/2."""
    # all logits equal -> exactly 0.5, whatever the label arrangement
    for labels in ([1, 0, 1, 0, 1, 0], [1, 1, 1, 0, 0, 0],
                   [0, 0, 0, 1, 1, 1]):
        got = float(auc(jnp.zeros(6), jnp.asarray(labels, jnp.float32)))
        assert got == pytest.approx(0.5, abs=1e-7)
    # duplicated logits vs the exact pairwise Mann-Whitney statistic
    rng = np.random.default_rng(5)
    x = rng.integers(0, 4, 120).astype(np.float32)      # heavy ties
    y = (rng.random(120) < 0.4).astype(np.float32)
    pos, neg = x[y > 0], x[y == 0]
    ref = float(np.mean([(p > n) + 0.5 * (p == n)
                         for p in pos for n in neg]))
    assert float(auc(jnp.asarray(x), jnp.asarray(y))) == \
        pytest.approx(ref, abs=1e-5)


def test_auc_deterministic_under_permutation_of_ties():
    rng = np.random.default_rng(9)
    x = rng.integers(0, 3, 200).astype(np.float32)
    y = (rng.random(200) < 0.5).astype(np.float32)
    base = float(auc(jnp.asarray(x), jnp.asarray(y)))
    for _ in range(3):
        perm = rng.permutation(200)
        assert float(auc(jnp.asarray(x[perm]), jnp.asarray(y[perm]))) == \
            pytest.approx(base, abs=1e-6)


# --- checkpoint ------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc():
    d = tempfile.mkdtemp()
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    for s in (5, 10, 15):
        save_checkpoint(d, s, tree, keep=2)
    assert latest_step(d) == 15
    assert sorted(int(x[5:]) for x in os.listdir(d)) == [10, 15]
    step, restored = restore_checkpoint(
        d, jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert step == 15
    assert bool(jnp.allclose(restored["a"], tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.int32


def test_checkpoint_manager_async():
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d, keep=2, asynchronous=True)
    tree = {"w": jnp.ones((8, 8))}
    for s in (1, 2, 3):
        mgr.save(s, jax.tree_util.tree_map(lambda x: x * s, tree))
    mgr.wait()
    step, restored = mgr.restore(tree)
    assert step == 3
    assert float(restored["w"][0, 0]) == 3.0


def test_restore_no_checkpoint_returns_template():
    step, tree = restore_checkpoint(tempfile.mkdtemp(), {"x": jnp.ones(2)})
    assert step is None
    assert float(tree["x"][0]) == 1.0


# --- trainer fault tolerance ----------------------------------------------


def _counting_data():
    i = 0
    while True:
        yield {"x": np.float32(1.0), "i": i}
        i += 1


def test_trainer_recovers_from_failure():
    d = tempfile.mkdtemp()
    logs = []
    cfg = TrainerConfig(total_steps=30, ckpt_dir=d, ckpt_every=5,
                        log_every=1000, max_failures=3)

    def step(state, batch, step_no):
        return {"w": state["w"] + batch["x"]}, {"w": state["w"]}

    tr = Trainer(step, {"w": jnp.zeros(())}, _counting_data(), cfg,
                 log_fn=logs.append)
    fail_at = {12, 17}
    tr.failure_injector = \
        lambda s: s in fail_at and (fail_at.discard(s) or True)
    out = tr.run()
    assert tr.step == 30
    assert any("rolled back" in str(m) for m in logs)
    assert latest_step(d) == 30


def test_trainer_aborts_after_max_failures():
    d = tempfile.mkdtemp()
    cfg = TrainerConfig(total_steps=10, ckpt_dir=d, ckpt_every=100,
                        log_every=1000, max_failures=2)

    def step(state, batch, step_no):
        return state, {}

    tr = Trainer(step, {"w": jnp.zeros(())}, _counting_data(), cfg,
                 log_fn=lambda *a: None)
    tr.failure_injector = lambda s: True  # always fail
    with pytest.raises(RuntimeError):
        tr.run()


def test_trainer_restart_resumes_from_checkpoint():
    d = tempfile.mkdtemp()
    cfg = TrainerConfig(total_steps=20, ckpt_dir=d, ckpt_every=5,
                        log_every=1000)

    def step(state, batch, step_no):
        return {"w": state["w"] + 1}, {}

    tr1 = Trainer(step, {"w": jnp.zeros(())}, _counting_data(), cfg,
                  log_fn=lambda *a: None)
    tr1.run()
    # "new process": restore and continue to 40
    cfg2 = TrainerConfig(total_steps=40, ckpt_dir=d, ckpt_every=5,
                         log_every=1000)
    tr2 = Trainer(step, {"w": jnp.zeros(())}, _counting_data(), cfg2,
                  log_fn=lambda *a: None).restore_if_available()
    assert tr2.step == 20
    out = tr2.run()
    assert float(out["w"]) == 40.0


# --- prefetch iterator -----------------------------------------------------


def test_prefetch_close_stops_blocked_producer():
    """A producer blocked on a full queue must observe close() and exit
    (regression: plain Queue.put never re-checked the done flag, so the
    thread outlived the trainer)."""
    def infinite():
        i = 0
        while True:
            yield i
            i += 1

    it = PrefetchIterator(infinite(), depth=1, timeout_s=5.0)
    assert it.next() == 0  # producer is now parked on a full queue
    it.close()
    assert not it._thread.is_alive()


def test_prefetch_close_idempotent_after_exhaustion():
    it = PrefetchIterator(iter([1, 2]), depth=4, timeout_s=5.0)
    assert it.next() == 1
    assert it.next() == 2
    with pytest.raises(StopIteration):
        it.next()
    it.close()
    it.close()
    assert not it._thread.is_alive()


def test_trainer_run_closes_prefetch_thread():
    d = tempfile.mkdtemp()
    cfg = TrainerConfig(total_steps=5, ckpt_dir=d, ckpt_every=100,
                        log_every=1000)
    tr = Trainer(lambda s, b, n: (s, {}), {"w": jnp.zeros(())},
                 _counting_data(), cfg, log_fn=lambda *a: None)
    tr.run()
    assert not tr.data._thread.is_alive()


# --- data pipeline ---------------------------------------------------------


def test_bpr_batches_avoid_train_positives():
    from repro.data.synthetic import bpr_batches, gen_kg_dataset
    ds = gen_kg_dataset(n_users=30, n_items=50, n_attrs=20, seed=3)
    pos = set(map(tuple, ds.train_pos))
    b = next(bpr_batches(ds, 64, seed=1))
    for u, n in zip(b["user"], b["neg"]):
        assert (int(u), int(n)) not in pos


def test_lm_batches_learnable_structure():
    from repro.data.synthetic import lm_batches
    b = next(lm_batches(vocab=97, batch=4, seq=64, seed=0, noise=0.0))
    toks = b["tokens"]
    assert ((31 * toks[:, :-1] + 7) % 97 == toks[:, 1:]).all()


def test_neighbor_sampler_block_consistency():
    from repro.data.sampler import build_csr, sample_blocks
    rng = np.random.default_rng(0)
    src = rng.integers(0, 500, 3000)
    dst = rng.integers(0, 500, 3000)
    indptr, indices = build_csr(src, dst, 500)
    seeds = rng.integers(0, 500, 32)
    blocks, input_nodes = sample_blocks(indptr, indices, seeds, [4, 3],
                                        rng=rng)
    assert blocks[-1]["n_dst"] == 32
    assert blocks[0]["n_src"] == len(input_nodes)
    for blk in blocks:
        assert blk["src"].max() < blk["n_src"]
        assert blk["dst"].max() < blk["n_dst"]
