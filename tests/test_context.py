"""ACT context API: scopes, schedules, scope-keyed SR, traced accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    INT2,
    INT8,
    ActContext,
    act_context,
    act_matmul,
    act_relu,
    scope_key,
    traced_activation_report,
)
from repro.core.act import act_spmm
from repro.core.policy import (
    ACTPolicy,
    PolicySchedule,
    ScheduleRule,
    first_layer_int8_rest_int2,
    parse_schedule,
    scope_layer,
)
from repro.core.quant import act_bytes
from repro.data.synthetic import bpr_batches, gen_kg_dataset
from repro.models import kgnn

KEY = jax.random.PRNGKey(0)


def _tiny_kg(model="kgat", dim=16, n_layers=3):
    ds = gen_kg_dataset(n_users=20, n_items=30, n_attrs=10, seed=0)
    cfg = kgnn.KGNNConfig(
        model=model, n_users=ds.n_users, n_entities=ds.n_entities,
        n_relations=ds.n_relations, dim=dim, n_layers=n_layers,
        readout="concat" if model == "kgat" else "sum")
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    params = kgnn.init_params(jax.random.PRNGKey(0), cfg)
    batch = jax.tree_util.tree_map(jnp.asarray,
                                   next(bpr_batches(ds, 32, seed=1)))
    return ds, cfg, g, params, batch


# --- schedule resolution ---------------------------------------------------


def test_schedule_resolution_order_first_match_wins():
    sched = PolicySchedule(rules=(
        ScheduleRule(policy=ACTPolicy(bits=8), op_kind="spmm"),
        ScheduleRule(policy=ACTPolicy(bits=4), scope="m/layer0/*"),
    ), default=ACTPolicy(bits=2))
    # op_kind rule precedes the scope rule even where both match
    assert sched.resolve("spmm", "m/layer0/spmm").bits == 8
    assert sched.resolve("matmul", "m/layer0/w1").bits == 4
    assert sched.resolve("matmul", "m/layer2/w1").bits == 2


def test_scope_layer_and_dedup_suffix_invisible_to_rules():
    assert scope_layer("kgat/layer2/spmm") == 2
    assert scope_layer("kgat/layer2/spmm#1") == 2
    assert scope_layer("dlrm/bot/fc0") is None
    rule = ScheduleRule(policy=INT8, scope="a/*/b")
    assert rule.matches("matmul", "a/x/b#3")


def test_parse_schedule_forms():
    assert parse_schedule("int8").default.bits == 8
    assert parse_schedule("fp32").default.bits is None
    pre = parse_schedule("first_layer_int8_rest_int2")
    assert pre.resolve("spmm", "kgat/layer0/spmm").bits == 8
    assert pre.resolve("spmm", "kgat/layer2/spmm").bits == 2
    rules = parse_schedule("spmm:*/layer0/*=8,*/layer0/*=4,*=1")
    assert rules.resolve("spmm", "m/layer0/spmm").bits == 8
    assert rules.resolve("matmul", "m/layer0/w1").bits == 4
    assert rules.resolve("matmul", "m/layer1/w1").bits == 1
    # rule specs without an explicit *=bits compress ONLY the named sites
    spmm_only = parse_schedule("spmm:*=8")
    assert spmm_only.resolve("spmm", "m/layer1/spmm").bits == 8
    assert spmm_only.resolve("matmul", "m/layer1/w1").bits is None
    with pytest.raises(ValueError):
        parse_schedule("nonsense spec")


# --- mixed per-layer bits land at the right sites (via trace records) ------


def test_mixed_schedule_per_site_bits_in_trace():
    _, cfg, g, params, batch = _tiny_kg()
    ctx = ActContext(first_layer_int8_rest_int2(), KEY)
    with ctx:
        jax.eval_shape(lambda p: kgnn.bpr_loss(p, g, batch, cfg), params)
    by_scope = {r.scope: r.bits for r in ctx.records}
    # 3 layers x (spmm + w1 + w2 + act1 + act2)
    assert len(by_scope) == 15
    layer0 = {k: v for k, v in by_scope.items() if "/layer0/" in k}
    rest = {k: v for k, v in by_scope.items() if "/layer0/" not in k}
    assert layer0 and set(layer0.values()) == {8}
    assert rest and set(rest.values()) == {2}


# --- explicit kwargs vs context: bit-identical grads -----------------------


def test_context_vs_explicit_kwargs_grads_bit_identical():
    _, cfg, g, params, batch = _tiny_kg()
    root = jax.random.PRNGKey(7)

    def loss_ctx(p):
        with act_context(INT2, root):
            return kgnn.bpr_loss(p, g, batch, cfg)

    g_ctx = jax.grad(loss_ctx)(params)
    g_exp = jax.grad(lambda p: kgnn.bpr_loss(
        p, g, batch, cfg, policy=INT2, key=root))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_ctx),
                    jax.tree_util.tree_leaves(g_exp)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_op_level_context_matches_explicit_scope_key():
    x = jax.random.normal(KEY, (16, 32))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (32, 8))
    root = jax.random.PRNGKey(11)

    def loss_ctx(w_):
        with act_context(INT2, root, step=3):
            return (act_matmul(x, w_, scope="site") ** 2).sum()

    g_ctx = jax.grad(loss_ctx)(w)
    g_exp = jax.grad(lambda w_: (act_matmul(
        x, w_, key=scope_key(root, "site", 3), policy=INT2) ** 2).sum())(w)
    assert (np.asarray(g_ctx) == np.asarray(g_exp)).all()


# --- scope-keyed SR: replay determinism + stability under op insertion -----


def test_checkpoint_replay_determinism_across_fresh_contexts():
    """Simulated restart: a replayed step reproduces identical grads."""
    _, cfg, g, params, batch = _tiny_kg()
    root = jax.random.PRNGKey(5)

    def grads_at_step(step):
        def loss(p):
            with act_context(INT2, root, step=step):
                return kgnn.bpr_loss(p, g, batch, cfg)
        return jax.grad(loss)(params)

    g_a, g_b = grads_at_step(4), grads_at_step(4)  # "restart" = fresh trace
    for a, b in zip(jax.tree_util.tree_leaves(g_a),
                    jax.tree_util.tree_leaves(g_b)):
        assert (np.asarray(a) == np.asarray(b)).all()
    g_next = grads_at_step(5)
    assert any((np.asarray(a) != np.asarray(b)).any()
               for a, b in zip(jax.tree_util.tree_leaves(g_a),
                               jax.tree_util.tree_leaves(g_next)))


def test_scope_keys_stable_under_op_insertion():
    """Adding an op must not re-key other sites (the KeyChain failure)."""
    x = jax.random.normal(KEY, (8, 16))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (16, 16))
    root = jax.random.PRNGKey(13)

    def run(insert_extra):
        with act_context(INT2, root):
            if insert_extra:
                act_matmul(x, w, scope="extra")  # new op before "site"
            return act_matmul(x, w, scope="site")

    # forward is exact either way; compare the residual keys via grads
    def gw(insert_extra):
        def loss(w_):
            with act_context(INT2, root):
                if insert_extra:
                    (act_matmul(x, w_, scope="extra") ** 2).sum()
                return (act_matmul(x, w_, scope="site") ** 2).sum()
        return jax.grad(loss)(w)

    assert (np.asarray(gw(False)) == np.asarray(gw(True))).all()


def test_repeated_scope_names_get_distinct_keys():
    ctx = ActContext(INT2, KEY)
    with ctx:
        a = ctx.qualify("s")
        b = ctx.qualify("s")
    assert a == "s" and b == "s#1"
    assert not np.array_equal(np.asarray(ctx.key_for(a)),
                              np.asarray(ctx.key_for(b)))


# --- key-required regression (no silent PRNGKey(0) fallback) ---------------


def test_propagate_requires_key_under_stochastic_policy():
    _, cfg, g, params, _ = _tiny_kg()
    with pytest.raises(ValueError, match="key"):
        kgnn.propagate(params, g, cfg, policy=INT2)
    # nearest rounding / FP32 need no key
    kgnn.propagate(params, g, cfg,
                   policy=ACTPolicy(bits=2, stochastic=False))
    kgnn.propagate(params, g, cfg)


def test_op_requires_key_under_stochastic_policy():
    x = jax.random.normal(KEY, (4, 8))
    w = jax.random.normal(KEY, (8, 4))
    with pytest.raises(ValueError, match="key"):
        act_matmul(x, w, policy=INT2)
    # linear spmm needs no key even under an active stochastic policy
    src = jnp.array([0, 1, 2], jnp.int32)
    dst = jnp.array([1, 2, 3], jnp.int32)
    act_spmm(x, src, dst, None, num_nodes=4, policy=INT2)


# --- traced memory accounting ----------------------------------------------


@pytest.mark.parametrize("model,per_layer", [("kgat", 5), ("kgcn", 3)])
def test_traced_int2_report_matches_hand_totals(model, per_layer):
    """Uniform INT2: trace == the pre-redesign hand-computed totals.

    The deleted activation_shapes tables priced per layer: spmm input E
    plus 4 (kgat) / 2 (kgcn) transform/nonlin inputs, all (n_nodes, dim)
    at dim_in == dim_out. (For KGIN the hand table was already wrong —
    it priced a phantom spmm residual — which is the point of tracing.)
    """
    _, cfg, g, params, batch = _tiny_kg(model=model)
    rep = traced_activation_report(
        lambda p: kgnn.bpr_loss(p, g, batch, cfg), params, schedule=INT2)
    n, d = cfg.n_nodes, cfg.dim
    hand_total = cfg.n_layers * per_layer * act_bytes((n, d), 2)
    hand_fp32 = cfg.n_layers * per_layer * act_bytes((n, d), None)
    assert rep["total_bytes"] == hand_total
    assert rep["total_fp32_bytes"] == hand_fp32


def test_traced_report_prices_mixed_schedule():
    _, cfg, g, params, batch = _tiny_kg()
    rep8 = traced_activation_report(
        lambda p: kgnn.bpr_loss(p, g, batch, cfg), params, schedule=INT8)
    rep2 = traced_activation_report(
        lambda p: kgnn.bpr_loss(p, g, batch, cfg), params, schedule=INT2)
    mix = traced_activation_report(
        lambda p: kgnn.bpr_loss(p, g, batch, cfg), params,
        schedule=first_layer_int8_rest_int2())
    assert rep2["total_bytes"] < mix["total_bytes"] < rep8["total_bytes"]
    # layer0 priced at INT8, deeper layers at INT2
    assert mix["kgat/layer0/spmm"] == rep8["kgat/layer0/spmm"]
    assert mix["kgat/layer2/spmm"] == rep2["kgat/layer2/spmm"]


def test_repeated_model_calls_under_one_trace_dedup_scopes():
    """Two explicit-kwarg model calls under one recording context must get
    distinct (#k-suffixed) sites — unique SR keys, no silently overwritten
    report entries."""
    _, cfg, g, params, _ = _tiny_kg(n_layers=1)
    ctx = ActContext(INT2, KEY)
    with ctx:
        kgnn.propagate(params, g, cfg, policy=INT2, key=KEY)
        kgnn.propagate(params, g, cfg, policy=INT2, key=KEY)
    scopes = [r.scope for r in ctx.records]
    assert len(scopes) == len(set(scopes))
    assert "kgat/layer0/spmm" in scopes and "kgat/layer0/spmm#1" in scopes


def test_explicit_key_override_still_feeds_outer_trace():
    """An explicit key= forces a local context; its records must still
    land in the ambient (recording) context's trace."""
    _, cfg, g, params, batch = _tiny_kg()
    rep = traced_activation_report(
        lambda p: kgnn.bpr_loss(p, g, batch, cfg, key=jax.random.PRNGKey(5)),
        params, schedule=INT2)
    assert rep["total_bytes"] > 0
    assert "kgat/layer0/spmm" in rep


def test_transformer_scan_records_one_residual_per_layer():
    from repro.models import transformer as tf
    cfg = tf.TransformerConfig(n_layers=4, d_model=32, n_heads=2,
                               n_kv_heads=2, d_ff=64, vocab=97, d_head=16)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((2, 16), jnp.int32)
    rep = traced_activation_report(
        lambda p: tf.lm_loss(p, {"tokens": toks}, cfg), params, schedule=INT2)
    assert sum(1 for k in rep if k.startswith("lm/block")) == cfg.n_layers


def test_two_transformer_forwards_get_distinct_sr_roots():
    """Two forwards (e.g. a two-tower loss) under one recording context
    must not reuse identical rounding noise — the key root derives from a
    #k-deduped site."""
    from repro.models import transformer as tf
    cfg = tf.TransformerConfig(n_layers=2, d_model=32, n_heads=2,
                               n_kv_heads=2, d_ff=64, vocab=97, d_head=16)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((2, 8), jnp.int32)
    ctx = ActContext(INT2, KEY)
    with ctx:
        a = tf.forward(params, toks, cfg)
        b = tf.forward(params, toks, cfg)
    # forward is exact either way; the registered sites must differ so the
    # derived roots (and the recorded residual scopes) differ
    scopes = [r.scope for r in ctx.records]
    assert len(scopes) == len(set(scopes))
    assert "lm" in ctx._seen and ctx._seen["lm"] == 2
    assert not np.array_equal(np.asarray(ctx.key_for("lm")),
                              np.asarray(ctx.key_for("lm#1")))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_act_remat_resolves_policy_at_call_time():
    """A block wrapped OUTSIDE any context must honor the schedule it is
    later applied under (same call-time semantics as every other op)."""
    from repro.core import act_remat

    w = jax.random.normal(KEY, (16, 16))
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 16))
    f = act_remat(lambda p, x_, c: jnp.tanh(x_ @ p), scope="blk")  # no ctx

    exact = jax.grad(lambda p: jnp.tanh(x @ p).sum())(w)

    def loss(p):
        with act_context(INT2, KEY):
            return f(p, x).sum()

    ctx = ActContext(INT2, KEY)
    with ctx:
        f(w, x)
    (r,) = ctx.records
    assert r.scope == "blk" and r.bits == 2  # schedule applied, recorded
    g2 = jax.grad(loss)(w)
    assert not np.allclose(np.asarray(g2), np.asarray(exact))  # INT2 noise
    g_fp = jax.grad(lambda p: f(p, x).sum())(w)  # no ctx -> FP32 baseline
    np.testing.assert_allclose(np.asarray(g_fp), np.asarray(exact),
                               rtol=1e-6, atol=1e-6)


def test_relu_mask_recorded_exact():
    x = jax.random.normal(KEY, (32, 64))
    ctx = ActContext(INT2, KEY)
    with ctx:
        act_relu(x, scope="mask")
    (r,) = ctx.records
    assert r.exact_mask and r.bits == 1
    assert ctx.report()["mask"] == 32 * 8  # 64 bits -> 8 bytes per row
