"""Shared subprocess runner for multi-device tests.

The XLA device count locks at first jax init, so the main pytest
process stays at 1 device; anything needing a real mesh runs in a child
process with ``--xla_force_host_platform_device_count`` forced. Used by
tests/test_distributed.py and tests/test_data_parallel.py.
"""

import os
import subprocess
import sys
import textwrap

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def forced_device_run(src: str, n_devices: int = 8,
                      timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)], env=env,
        capture_output=True, text=True, timeout=timeout, cwd=_REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout
