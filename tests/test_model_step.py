"""The model-step registry (DESIGN.md §9): one step definition per arch.

Covers: registry construction for every --arch id, the ModelStep
protocol surface, DPSpec presence/absence with honest reasons, the
generic train-step wiring, checkpoint run-identity metadata, and the
bit-identical regression pin for the single-device KGAT step (recorded
against the pre-registry code — the refactor must not move a single
bit on the pinned toolchain).
"""

import importlib.util
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get
from repro.models.registry import build_step, kg_archs, kg_dp_spec
from repro.training.step import (DPSpec, ModelStep, ModelStepProtocol,
                                 make_train_step, step_metadata)

_DATA = os.path.join(os.path.dirname(__file__), "data")

FAST_ARCHS = ("kgat", "kgcn", "kgin", "gcn-cora", "fm")


def _finite(tree) -> bool:
    return all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# registry construction + protocol surface
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", FAST_ARCHS)
def test_registry_step_trains_one_loss(arch):
    """build_step + init + one loss/grad evaluation for the cheap archs."""
    step = build_step(arch)
    assert isinstance(step, ModelStep)
    assert isinstance(step, ModelStepProtocol)
    assert step.arch == arch
    params = step.init(jax.random.PRNGKey(0))
    batch = next(iter(step.batches()))
    loss, grads = jax.value_and_grad(
        lambda p: step.loss(p, batch))(params)
    assert np.isfinite(float(loss))
    assert _finite(grads)


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(set(ARCHS) - set(FAST_ARCHS)))
def test_registry_step_builds_every_arch(arch):
    """Every remaining --arch id resolves to a constructible step."""
    step = build_step(arch)
    assert isinstance(step, ModelStepProtocol)
    params = step.init(jax.random.PRNGKey(0))
    assert _finite(params)
    batch = next(iter(step.batches()))
    assert np.isfinite(float(step.loss(params, batch)))


def test_dp_spec_for_every_kg_arch_only():
    """KG archs carry a DPSpec (graph + sites + shard_loss); non-graph
    families carry an honest reason instead."""
    assert set(kg_archs()) == {"kgat", "kgcn", "kgin"}
    for arch in kg_archs():
        spec = build_step(arch).dp_spec
        assert isinstance(spec, DPSpec)
        assert spec.graph is not None and spec.shard_loss is not None
        assert spec.n_layers >= 1 and len(spec.sites) >= 1
        assert spec.scope == get(arch).model_cfg.model
    for arch in ("fm", "gcn-cora"):
        step = build_step(arch)
        assert step.dp_spec is None
        assert step.dp_unsupported  # names why, not just "no"


def test_make_dp_step_refuses_without_spec_naming_arch():
    from repro.training.data_parallel import make_dp_step

    step = build_step("fm")
    with pytest.raises(NotImplementedError) as ei:
        make_dp_step(step, None, None, None, root_key=jax.random.PRNGKey(0))
    msg = str(ei.value)
    assert "'fm'" in msg and "edge-shard" in msg


def test_model_sites_tables():
    from repro.models import kgnn

    cfg = lambda m: kgnn.KGNNConfig(model=m, n_bases=2)  # noqa: E731
    assert [s for s, _ in kgnn.model_sites(cfg("kgat"))] == \
        ["spmm", "w1", "w2", "act1", "act2"]
    assert [s for s, _ in kgnn.model_sites(cfg("kgcn"))] == \
        ["spmm", "dense", "act"]
    assert [s for s, _ in kgnn.model_sites(cfg("kgin"))] == ["act"]
    assert [s for s, _ in kgnn.model_sites(cfg("rgcn"))] == \
        ["basis0", "basis1", "self", "act"]
    assert kg_dp_spec(cfg("kgat")).sites == kgnn.model_sites(cfg("kgat"))


# ---------------------------------------------------------------------------
# generic train step + schedules
# ---------------------------------------------------------------------------


def test_make_train_step_runs_and_replays():
    """Two steps run; re-running step 0 from the same state is
    bit-deterministic (scope-hashed SR keys fold in the step index)."""
    from repro.core.policy import parse_schedule
    from repro.training.optimizer import adam

    step = build_step("kgat")
    opt = adam(1e-3)
    train_step = make_train_step(step, opt, schedule=parse_schedule("int2"),
                                 root_key=jax.random.PRNGKey(5))
    params = step.init(jax.random.PRNGKey(0))
    state = (params, opt.init(params))
    it = step.batches()
    b0 = next(it)
    s1, m1 = train_step(state, b0, 0)
    s2, m2 = train_step(s1, next(it), 1)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    s1b, m1b = train_step(state, b0, 0)
    assert float(m1["loss"]) == float(m1b["loss"])
    for a, b in zip(jax.tree_util.tree_leaves(s1),
                    jax.tree_util.tree_leaves(s1b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# checkpoint run-identity metadata
# ---------------------------------------------------------------------------


def test_checkpoint_meta_roundtrip_and_mismatch():
    from repro.training.checkpoint import CheckpointManager

    step = build_step("kgat")
    meta = step_metadata(step, "int8")
    assert meta["arch"] == "kgat" and meta["schedule"] == "int8"
    tree = {"w": np.arange(4.0)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, asynchronous=False, meta=meta)
        mgr.save(7, tree)
        got_step, got = mgr.restore(tree)
        assert got_step == 7
        np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])
        # a different arch refuses to resume
        wrong = CheckpointManager(d, asynchronous=False,
                                  meta=step_metadata(build_step("kgcn"),
                                                     "int8"))
        with pytest.raises(ValueError, match="different run.*arch"):
            wrong.restore(tree)
        # a different schedule refuses too
        wrong_sched = CheckpointManager(d, asynchronous=False,
                                        meta=step_metadata(step, "int2"))
        with pytest.raises(ValueError, match="schedule"):
            wrong_sched.restore(tree)
        # a metadata-free reader (legacy) still restores
        legacy = CheckpointManager(d, asynchronous=False)
        assert legacy.restore(tree)[0] == 7


def test_checkpoint_without_meta_restores_under_expectation():
    """Legacy checkpoints (no stored meta) restore under any expected
    meta — only contradictions fail, absence doesn't."""
    from repro.training.checkpoint import CheckpointManager

    tree = {"w": np.ones(3)}
    with tempfile.TemporaryDirectory() as d:
        CheckpointManager(d, asynchronous=False).save(1, tree)
        mgr = CheckpointManager(d, asynchronous=False,
                                meta={"arch": "kgat"})
        assert mgr.restore(tree)[0] == 1


def test_trainer_threads_ckpt_meta():
    from repro.training.trainer import Trainer, TrainerConfig

    step = build_step("kgat")
    with tempfile.TemporaryDirectory() as d:
        cfg = TrainerConfig(total_steps=1, ckpt_dir=d, ckpt_every=1,
                            log_every=1)
        tr = Trainer(lambda s, b, i: (s, {"loss": jnp.float32(0)}),
                     {"w": np.zeros(2)}, iter([{}]), cfg,
                     ckpt_meta=step_metadata(step, "int2"))
        tr.run()
        assert tr.ckpt.meta["arch"] == "kgat"
        other = Trainer(lambda s, b, i: (s, {"loss": jnp.float32(0)}),
                        {"w": np.zeros(2)}, iter([{}]), cfg,
                        ckpt_meta=step_metadata(build_step("kgin"), "int2"))
        with pytest.raises(ValueError, match="different run"):
            other.restore_if_available()


# ---------------------------------------------------------------------------
# bit-identical regression pin (acceptance criterion)
# ---------------------------------------------------------------------------


def test_kgat_single_device_step_pinned():
    """The refactored single-device KGAT step reproduces the recorded
    pre-refactor values: bit-identical on the recorded toolchain
    (jax version + backend match), <=2e-5 relative anywhere else
    (different BLAS/fma contraction only)."""
    spec = importlib.util.spec_from_file_location(
        "kgat_regression_case",
        os.path.join(_DATA, "record_kgat_regression.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    with open(os.path.join(_DATA, "kgat_step_regression.json")) as f:
        want = json.load(f)
    got = mod.run_case()
    exact = (got["jax_version"] == want["jax_version"]
             and got["backend"] == want["backend"])
    for k, v in want.items():
        if k in ("jax_version", "backend"):
            continue
        g = np.asarray(got[k], dtype=np.float64)
        w = np.asarray(v, dtype=np.float64)
        if exact:
            np.testing.assert_array_equal(
                g, w, err_msg=f"{k} moved — the step is no longer "
                f"bit-identical to the pre-registry code")
        else:
            np.testing.assert_allclose(g, w, rtol=2e-5, atol=1e-7,
                                       err_msg=k)
