"""Property-based tests of the quantizer (paper Proposition 1 + Appendix).

hypothesis sweeps shapes/values; statistical properties use fixed seeds
with generous tolerances (they are laws of the estimator, not flaky
thresholds: unbiasedness error shrinks as 1/sqrt(n_draws)).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep — skip module when absent
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.quant import (  # noqa: E402
    act_bytes,
    dequantize,
    pack_bits,
    quantize,
    unpack_bits,
)

BITS = st.sampled_from([1, 2, 4, 8])


@settings(max_examples=30, deadline=None)
@given(
    bits=BITS,
    rows=st.integers(1, 50),
    d=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip(bits, rows, d, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2**bits, (rows, d)).astype(np.uint8)
    out = unpack_bits(pack_bits(jnp.asarray(codes), bits), bits, d)
    assert (np.asarray(out) == codes).all()


@settings(max_examples=20, deadline=None)
@given(
    bits=BITS,
    rows=st.integers(1, 16),
    d=st.integers(2, 64),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_dequant_within_one_bin(bits, rows, d, scale, seed):
    """|x̂ - x| ≤ R/B elementwise (SR moves at most one bin)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, d)) * scale
    q = quantize(x, key, bits=bits)
    xhat = dequantize(q)
    bin_w = (jnp.max(x, -1, keepdims=True) - jnp.min(x, -1, keepdims=True)) \
        / (2**bits - 1)
    assert bool(jnp.all(jnp.abs(xhat - x) <= bin_w + 1e-5))


@settings(max_examples=10, deadline=None)
@given(bits=BITS, seed=st.integers(0, 1000))
def test_constant_rows_exact(bits, seed):
    """R=0 rows must reconstruct exactly (guarded division)."""
    x = jnp.full((4, 33), float(seed % 7) - 3.0)
    xhat = dequantize(quantize(x, jax.random.PRNGKey(seed), bits=bits))
    assert bool(jnp.allclose(xhat, x, atol=1e-6))


@pytest.mark.parametrize("bits", [1, 2, 4])
def test_unbiasedness(bits):
    """E[Dequant(Quant(x))] = x (Proposition 1, expectation)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
    keys = jax.random.split(jax.random.PRNGKey(1), 4000)
    deq = jax.vmap(lambda k: dequantize(quantize(x, k, bits=bits)))(keys)
    err = jnp.abs(deq.mean(0) - x).max()
    # SE of mean ≈ binwidth/2/sqrt(4000); binwidth ≈ 6/B
    bin_w = 6.0 / (2**bits - 1)
    assert float(err) < 5 * bin_w / 2 / np.sqrt(4000) + 1e-3


@pytest.mark.parametrize("bits", [1, 2, 4])
def test_variance_bound(bits):
    """Var[x̂] ≤ d·R²/(4B²) — per-element form Var ≤ (R/B)²/4."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
    keys = jax.random.split(jax.random.PRNGKey(1), 3000)
    deq = jax.vmap(lambda k: dequantize(quantize(x, k, bits=bits)))(keys)
    var = deq.var(0)
    q = quantize(x, jax.random.PRNGKey(2), bits=bits)
    bound = (q.scale ** 2) / 4  # (R/B)²/4 per element
    assert float((var <= bound * 1.2 + 1e-6).mean()) == 1.0


def test_nearest_rounding_is_biased():
    """NR's bias is what Table 6 blames for divergence — verify it exists."""
    x = jnp.full((1, 64), 0.30)
    x = x.at[0, 0].set(0.0).at[0, 1].set(1.0)  # pin range to [0,1]
    keys = jax.random.split(jax.random.PRNGKey(0), 500)
    sr = jax.vmap(lambda k: dequantize(quantize(x, k, bits=1)))(keys)
    nr = dequantize(quantize(x, keys[0], bits=1, stochastic=False))
    sr_err = abs(float(sr[:, 0, 2:].mean()) - 0.30)
    nr_err = abs(float(nr[0, 2:].mean()) - 0.30)
    assert sr_err < 0.05           # unbiased: mean ≈ 0.30
    assert nr_err > 0.15           # NR rounds 0.3 -> 0 at 1 bit: bias 0.3


@settings(max_examples=20, deadline=None)
@given(bits=BITS, rows=st.integers(1, 20), d=st.integers(8, 256))
def test_act_bytes_compression(bits, rows, d):
    fp32 = act_bytes((rows, d), None)
    qb = act_bytes((rows, d), bits)
    assert qb < fp32
    assert qb >= rows * (d * bits // 8)  # at least the payload


def test_qtensor_nbytes_matches_packed():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    q = quantize(x, jax.random.PRNGKey(1), bits=2)
    assert q.packed.shape == (64, 32)      # 128 codes -> 32 bytes
    assert q.nbytes == 64 * 32 + 64 * 8
