"""Compressed-op gradient tests: exact where exact, bounded-noise where SR."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    act_matmul,
    act_nonlin,
    act_relu,
    act_remat,
    act_rmsnorm,
    act_spmm,
)
from repro.core.policy import FP32, INT8, ACTPolicy

KEY = jax.random.PRNGKey(0)


def test_matmul_dx_exact_any_bits():
    """∇x uses only weights — exact regardless of quantization."""
    x = jax.random.normal(KEY, (16, 32))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (32, 8))
    for bits in (None, 8, 2, 1):
        pol = ACTPolicy(bits=bits)
        gx = jax.grad(lambda x_: (act_matmul(
            x_, w, key=KEY, policy=pol) ** 2).sum())(x)
        exact = jax.grad(lambda x_: ((x_ @ w) ** 2).sum())(x)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(exact),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits,tol", [(8, 0.02), (4, 0.1), (2, 0.5)])
def test_matmul_dw_noise_scales_with_bits(bits, tol):
    x = jax.random.normal(KEY, (64, 32))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (32, 8))
    pol = ACTPolicy(bits=bits)
    gw = jax.grad(lambda w_: (act_matmul(
        x, w_, key=KEY, policy=pol) ** 2).sum())(w)
    exact = jax.grad(lambda w_: ((x @ w_) ** 2).sum())(w)
    rel = float(jnp.abs(gw - exact).max() / jnp.abs(exact).max())
    assert rel < tol, rel


def test_dw_unbiased_across_keys():
    """Averaging ∇w over many SR draws converges to the exact gradient."""
    x = jax.random.normal(KEY, (32, 16))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (16, 4))
    exact = jax.grad(lambda w_: ((x @ w_) ** 2).sum())(w)
    pol = ACTPolicy(bits=2)
    keys = jax.random.split(jax.random.fold_in(KEY, 2), 1500)
    gws = jax.vmap(lambda k: jax.grad(lambda w_: (act_matmul(
        x, w_, key=k, policy=pol) ** 2).sum())(w))(keys)
    rel = float(jnp.abs(gws.mean(0) - exact).max() / jnp.abs(exact).max())
    assert rel < 0.03, rel


def test_relu_mask_is_exact():
    x = jax.random.normal(KEY, (128,))
    g = jax.grad(lambda x_: (act_relu(x_) ** 3).sum())(x)
    e = jax.grad(lambda x_: (jnp.maximum(x_, 0) ** 3).sum())(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=1e-6)


@pytest.mark.parametrize("fn", ["silu", "gelu", "tanh", "sigmoid",
                                "leaky_relu"])
def test_nonlin_fp32_matches_autodiff(fn):
    refs = {"silu": jax.nn.silu, "tanh": jnp.tanh,
            "sigmoid": jax.nn.sigmoid,
            "leaky_relu": lambda x: jnp.where(x > 0, x, 0.01 * x),
            "gelu": lambda x: jax.nn.gelu(x, approximate=True)}
    x = jax.random.normal(KEY, (64,))
    g = jax.grad(lambda x_: act_nonlin(x_, key=KEY, policy=FP32,
                                       fn=fn).sum())(x)
    e = jax.grad(lambda x_: refs[fn](x_).sum())(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=1e-3,
                               atol=1e-4)


def test_rmsnorm_grads_match():
    x = jax.random.normal(KEY, (8, 32))
    gamma = jax.random.normal(jax.random.fold_in(KEY, 1), (32,)) + 1.0

    def ref(x_, g_):
        r = jax.lax.rsqrt(jnp.mean(x_ * x_, -1, keepdims=True) + 1e-6)
        return ((x_ * r * g_) ** 2).sum()

    gx, gg = jax.grad(lambda x_, g_: (act_rmsnorm(
        x_, g_, key=KEY, policy=FP32) ** 2).sum(), argnums=(0, 1))(x, gamma)
    ex, eg = jax.grad(ref, argnums=(0, 1))(x, gamma)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex), rtol=2e-4,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(gg), np.asarray(eg), rtol=2e-4,
                               atol=2e-5)


@pytest.mark.slow
def test_spmm_dx_exact_dew_noisy():
    N, E, d = 30, 150, 16
    src = jax.random.randint(KEY, (E,), 0, N)
    dst = jax.random.randint(jax.random.fold_in(KEY, 1), (E,), 0, N)
    ew = jax.random.uniform(jax.random.fold_in(KEY, 2), (E,))
    x = jax.random.normal(KEY, (N, d))

    def ref(x_, ew_):
        return (jax.ops.segment_sum(x_[src] * ew_[:, None], dst,
                                    num_segments=N) ** 2).sum()

    def act(x_, ew_, pol):
        return (act_spmm(x_, src, dst, ew_, num_nodes=N, key=KEY,
                         policy=pol) ** 2).sum()

    ex, eew = jax.grad(ref, argnums=(0, 1))(x, ew)
    gx, gew = jax.grad(act, argnums=(0, 1))(x, ew, INT8)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex), rtol=1e-5,
                               atol=1e-5)  # dx needs no activation
    rel = float(jnp.abs(gew - eew).max() / jnp.abs(eew).max())
    assert rel < 0.05, rel  # dew reads the INT8 x̂


def test_act_remat_grad_close_and_fp32_exact():
    w = jax.random.normal(KEY, (32, 32))
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (8, 32))

    def block(p, x_, consts):
        return jnp.tanh(x_ @ p) + x_

    exact = jax.grad(lambda p: block(p, x, None).sum())(w)
    for pol, tol in ((FP32, 1e-6), (INT8, 0.05)):
        f = act_remat(block, pol)
        g = jax.grad(lambda p: f(p, x, KEY).sum())(w)
        rel = float(jnp.abs(g - exact).max() / jnp.abs(exact).max())
        assert rel < tol, (pol.bits, rel)
