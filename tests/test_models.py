"""Model-correctness tests beyond smoke: oracles and invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep — skip module when absent
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.policy import FP32  # noqa: E402

KEY = jax.random.PRNGKey(0)


# --- attention -------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    s=st.integers(4, 48),
    h=st.sampled_from([2, 4]),
    g=st.sampled_from([1, 2]),
    qc=st.sampled_from([4, 8, 16]),
    kc=st.sampled_from([4, 8, 16]),
)
def test_chunked_attention_matches_naive(s, h, g, qc, kc):
    from repro.models.attention import chunked_causal_attention
    B, D = 2, 8
    kh = h // g
    q = jax.random.normal(KEY, (B, s, h, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, s, kh, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, s, kh, D))
    out = chunked_causal_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * D ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    ref = jnp.einsum("bhqk,bkhd->bqhd",
                     jax.nn.softmax(jnp.where(mask[None, None], sc, -1e30),
                                    -1), vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_attention_masks_beyond_len():
    from repro.models.attention import decode_attention
    B, S, H, D = 2, 16, 4, 8
    q = jax.random.normal(KEY, (B, 1, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, D))
    out_5 = decode_attention(q, k, v, jnp.asarray(5))
    # zero out cache beyond 5 — must not change the result
    k2 = k.at[:, 5:].set(99.0)
    v2 = v.at[:, 5:].set(-99.0)
    out_5b = decode_attention(q, k2, v2, jnp.asarray(5))
    np.testing.assert_allclose(np.asarray(out_5), np.asarray(out_5b),
                               atol=1e-6)


def test_rope_preserves_norm_and_relativity():
    from repro.models.attention import rope
    x = jax.random.normal(KEY, (1, 8, 2, 16))
    pos = jnp.arange(8)
    r = rope(x, pos, 1e4)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(r, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 1, 1, 16))
    def dot(m, n):
        qm = rope(jnp.broadcast_to(q, (1, 1, 1, 16)), jnp.asarray([m]), 1e4)
        kn = rope(jnp.broadcast_to(k, (1, 1, 1, 16)), jnp.asarray([n]), 1e4)
        return float(jnp.sum(qm * kn))
    assert dot(3, 1) == pytest.approx(dot(7, 5), rel=1e-4)


# --- MoE -------------------------------------------------------------------


@pytest.mark.parametrize("groups", [1, 2, 4])
def test_moe_grouped_dispatch_consistency(groups):
    """With ample capacity, grouped == global == dense-gated reference."""
    from repro.models.moe import MoEConfig, moe_ffn, moe_params
    T, d = 32, 16
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=4.0,
                    n_groups=groups)
    params = moe_params(KEY, d, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (T, d))
    y, aux = moe_ffn(params, x, cfg)
    # dense reference: full softmax-top2 gating, no capacity
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(4):
        h = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_up"][e])
        out_e = h @ params["w_down"][e]
        w_e = jnp.sum(jnp.where(idx == e, gates, 0.0), -1)
        ref = ref + out_e * w_e[:, None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    from repro.models.moe import MoEConfig, moe_ffn, moe_params
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff=8, capacity_factor=0.25)
    params = moe_params(KEY, 4, cfg)
    x = jax.random.normal(KEY, (16, 4))
    y, _ = moe_ffn(params, x, cfg)
    # capacity 2/expert, 16 tokens -> at most 4 processed, rest exactly 0
    nonzero = jnp.sum(jnp.any(y != 0, axis=-1))
    assert int(nonzero) <= 4


# --- KGNN ------------------------------------------------------------------


def test_segment_softmax_sums_to_one():
    from repro.models.kgnn import segment_softmax
    logits = jax.random.normal(KEY, (100,))
    seg = jax.random.randint(jax.random.fold_in(KEY, 1), (100,), 0, 10)
    p = segment_softmax(logits, seg, 10)
    sums = jax.ops.segment_sum(p, seg, num_segments=10)
    present = jax.ops.segment_sum(jnp.ones(100), seg, num_segments=10) > 0
    np.testing.assert_allclose(np.asarray(sums[present]), 1.0, rtol=1e-5)


def test_kgat_attention_normalized():
    from repro.models import kgnn
    cfg = kgnn.KGNNConfig(model="kgat", n_users=10, n_entities=20,
                          n_relations=4, dim=8, n_layers=2, n_bases=2)
    E = 80
    g = kgnn.CKG(
        src=jax.random.randint(KEY, (E,), 0, 30),
        dst=jax.random.randint(jax.random.fold_in(KEY, 1), (E,), 0, 30),
        rel=jax.random.randint(jax.random.fold_in(KEY, 2), (E,), 0, 4),
        n_nodes=30, n_relations=4)
    p = kgnn.init_params(KEY, cfg)
    from repro.models.kgnn import FullGraphView, _kgat_attention
    att = _kgat_attention(p, p["entity"], FullGraphView(g))
    sums = jax.ops.segment_sum(att, g.dst, num_segments=30)
    has_in = jax.ops.segment_sum(jnp.ones(E), g.dst, num_segments=30) > 0
    np.testing.assert_allclose(np.asarray(sums[has_in]), 1.0, rtol=1e-4)


@pytest.mark.parametrize("model,readout,expect_dim", [
    ("kgat", "concat", 8 * 3), ("kgcn", "sum", 8),
    ("kgin", "sum", 8), ("rgcn", "last", 8)])
def test_propagate_readout_dims(model, readout, expect_dim):
    from repro.models import kgnn
    cfg = kgnn.KGNNConfig(model=model, n_users=5, n_entities=15,
                          n_relations=4, dim=8, n_layers=2, n_bases=2,
                          readout=readout)
    g = kgnn.CKG(
        src=jax.random.randint(KEY, (60,), 0, 20),
        dst=jax.random.randint(jax.random.fold_in(KEY, 1), (60,), 0, 20),
        rel=jax.random.randint(jax.random.fold_in(KEY, 2), (60,), 0, 4),
        n_nodes=20, n_relations=4)
    p = kgnn.init_params(KEY, cfg)
    reps = kgnn.propagate(p, g, cfg, policy=FP32)
    assert reps.shape == (20, expect_dim)


# --- recsys ----------------------------------------------------------------


def test_fm_sum_square_trick_vs_bruteforce():
    from repro.models.recsys import _fm_pairwise
    emb = jax.random.normal(KEY, (4, 6, 8))
    fast = _fm_pairwise(emb)
    brute = jnp.zeros(4)
    for i in range(6):
        for j in range(i + 1, 6):
            brute += jnp.sum(emb[:, i] * emb[:, j], -1)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(brute),
                               rtol=1e-4)


def test_embedding_bag_combiners():
    from repro.models.layers import embedding_bag
    table = jnp.arange(20.0).reshape(10, 2)
    idx = jnp.array([0, 1, 2, 5])
    seg = jnp.array([0, 0, 1, 1])
    s = embedding_bag(table, idx, seg, 2, combiner="sum")
    m = embedding_bag(table, idx, seg, 2, combiner="mean")
    np.testing.assert_allclose(np.asarray(s[0]), [2.0, 4.0])
    np.testing.assert_allclose(np.asarray(m[1]), [7.0, 8.0])


def test_dlrm_interaction_size():
    from repro.models.recsys import _dot_interaction
    v = jax.random.normal(KEY, (3, 5, 8))
    out = _dot_interaction(v)
    assert out.shape == (3, 10)  # 5*4/2


def test_cin_output_shape():
    from repro.models import recsys
    cfg = recsys.RecsysConfig(model="xdeepfm", n_sparse=6,
                              vocab_sizes=(50,) * 6, embed_dim=8,
                              cin_layers=(5, 3), mlp=(16,))
    p = recsys.init_params(KEY, cfg)
    batch = {"sparse": jax.random.randint(KEY, (4, 6), 0, 50)}
    out = recsys.forward(p, batch, cfg, key=KEY)
    assert out.shape == (4,)


# --- GCN -------------------------------------------------------------------


def test_gcn_learns_homophilous_labels():
    from repro.data.synthetic import cora_like
    from repro.models import gnn
    from repro.training.optimizer import adam
    feats, src, dst, labels = cora_like(n_nodes=200, d_feat=16,
                                        n_classes=4, avg_deg=6, seed=0)
    cfg = gnn.GCNConfig(n_layers=2, d_in=16, d_hidden=16, n_classes=4)
    params = gnn.init_params(KEY, cfg)
    opt = adam(0.02)
    state = opt.init(params)
    x, s, d_, y = map(jnp.asarray, (feats, src, dst, labels))

    @jax.jit
    def step(params, state):
        def loss_fn(p):
            logits = gnn.gcn_forward(p, x, s, d_, n_nodes=200, cfg=cfg)
            oh = jax.nn.one_hot(y, 4)
            return -jnp.mean(jnp.sum(oh * jax.nn.log_softmax(logits), -1))
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
        return params, state, loss

    for _ in range(60):
        params, state, loss = step(params, state)
    logits = gnn.gcn_forward(params, x, s, d_, n_nodes=200, cfg=cfg)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    assert acc > 0.8, acc
