"""Serving subsystem: store round-trip/ledger, chunked top-K exactness
(incl. chunk-boundary ties), fused-vs-fallback bit parity, exclusion
semantics, streaming-vs-dense eval, and the micro-batching engine."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.synthetic import gen_kg_dataset
from repro.kernels.ops import TRACE_COUNTS
from repro.models import kgnn
from repro.serving import (
    QuantizedEmbeddingStore,
    ServingEngine,
    build_kgnn_store,
    merge_topk,
    padded_pos_lists,
    streaming_eval_dataset,
    streaming_recall_ndcg,
    topk_scores,
)
from repro.training.metrics import recall_ndcg_at_k

RNG = np.random.default_rng(7)
U, I, D, K = 16, 257, 64, 20     # I deliberately not a block multiple
USERS = RNG.normal(size=(U, D)).astype(np.float32)
ITEMS = RNG.normal(size=(I, D)).astype(np.float32)


def _assert_matches_dense(v, ix, dv, di):
    """Vs the dense reference: indices exactly, values to fp32 matmul
    tolerance — XLA may accumulate the dense matmul in a different order
    than the per-chunk dot, so VALUES can differ in ulps even though the
    chunked merge itself is exact (the integer-valued tie tests below
    are bit-for-bit)."""
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(di))
    np.testing.assert_allclose(np.asarray(v), np.asarray(dv),
                               rtol=1e-6, atol=1e-6)


def _dense_topk(store, k, exclude=None):
    """Reference: dense masked score matrix + lax.top_k."""
    scores = store.user_vectors(jnp.arange(store.n_users)) \
        @ store.item_matrix().T
    if exclude is not None:
        mask = np.zeros((store.n_users, store.n_items), bool)
        for u, row in enumerate(np.asarray(exclude)):
            for i in row[row >= 0]:
                mask[u, i] = True
        scores = jnp.where(jnp.asarray(mask), -jnp.inf, scores)
    return jax.lax.top_k(scores, k)


# --- store ------------------------------------------------------------------


@pytest.mark.parametrize("bits,bound_codes", [(8, 255), (4, 15)])
def test_store_roundtrip_bound(bits, bound_codes):
    """Nearest rounding: |x - x_hat| <= scale/2 per element."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=bits)
    xhat = np.asarray(st.item_matrix())
    err = np.abs(xhat - ITEMS)
    scale = np.asarray(st.items.scale)          # (I, 1)
    assert (err <= scale / 2 + 1e-6).all()
    # and the quantizer actually used the full code range per row
    assert st.items.bits == bits


def test_store_memory_report_ratios():
    st8 = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    st4 = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=4)
    stf = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=None)
    m8, m4, mf = (s.memory_report() for s in (st8, st4, stf))
    assert mf["compression_ratio"] == 1.0
    assert m8["compression_ratio"] >= 3.5       # acceptance bar (d=64)
    assert m4["compression_ratio"] >= 6.0
    # ledger adds up and the fp32 column is the real array size
    for m in (m8, m4):
        assert m["packed_bytes"] + m["scale_zero_bytes"] == m["total_bytes"]
    assert mf["total_bytes"] == (U + I) * D * 4


def test_store_fp32_users_packed_items():
    """quantize_users=False: query tower stays exact, items packed."""
    from repro.core.quant import QTensor
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8,
                                             quantize_users=False)
    assert not isinstance(st.users, QTensor)
    assert isinstance(st.items, QTensor)
    np.testing.assert_array_equal(
        np.asarray(st.user_vectors(jnp.arange(U))), USERS)
    v, ix = topk_scores(st.user_vectors(jnp.arange(U)), st.items, K,
                        backend="pallas", block_i=64)
    dv, di = _dense_topk(st, K)
    _assert_matches_dense(v, ix, dv, di)


def test_store_pytree_roundtrip():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    st2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert st2.bits == 8 and st2.n_items == I
    np.testing.assert_array_equal(np.asarray(st.items.packed),
                                  np.asarray(st2.items.packed))


# --- chunked top-K ----------------------------------------------------------


@pytest.mark.parametrize("block_i", [20, 33, 64, 300])
def test_chunked_topk_equals_global_fp32(block_i):
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=None)
    v, ix = topk_scores(st.users, st.items, K, block_i=block_i)
    dv, di = _dense_topk(st, K)
    _assert_matches_dense(v, ix, dv, di)


def test_chunked_topk_boundary_ties():
    """Duplicated scores straddling chunk boundaries must keep the
    global lowest-index-first tie order."""
    q = np.eye(3, 8, dtype=np.float32)
    items = np.zeros((100, 8), np.float32)
    items[::2, :3] = 1.0      # every even item ties at score 1 for all rows
    st = QuantizedEmbeddingStore.from_arrays(q, items, bits=None)
    for block_i in (16, 25, 50):     # boundaries land on tied items
        v, ix = topk_scores(jnp.asarray(q), st.items, 40, block_i=block_i)
        dv, di = jax.lax.top_k(jnp.asarray(q) @ jnp.asarray(items).T, 40)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(dv))
        np.testing.assert_array_equal(np.asarray(ix), np.asarray(di))


def test_chunked_topk_ties_property():
    """Property sweep: tiny value alphabet -> massive tie mass; every
    (block size, k) must reproduce global lax.top_k exactly."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st_

    @settings(max_examples=25, deadline=None)
    @given(seed=st_.integers(0, 2**31 - 1), block_i=st_.integers(4, 40),
           k=st_.integers(1, 30), n_items=st_.integers(30, 90))
    def prop(seed, block_i, k, n_items):
        rng = np.random.default_rng(seed)
        q = rng.integers(-2, 3, (3, 4)).astype(np.float32)
        items = rng.integers(-2, 3, (n_items, 4)).astype(np.float32)
        k = min(k, n_items)
        v, ix = topk_scores(jnp.asarray(q), jnp.asarray(items), k,
                            block_i=block_i)
        dv, di = jax.lax.top_k(jnp.asarray(q) @ jnp.asarray(items).T, k)
        assert np.array_equal(np.asarray(v), np.asarray(dv))
        assert np.array_equal(np.asarray(ix), np.asarray(di))

    prop()


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("block_i", [40, 257])
def test_fused_vs_jnp_parity(bits, block_i):
    """The Pallas kernel and the jnp fallback run the same op schedule —
    interpret mode must agree to zero ulps, indices included."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=bits)
    q = st.user_vectors(jnp.arange(U))
    excl = jnp.asarray(RNG.integers(0, I, (U, 5)), jnp.int32)
    vf, xf = topk_scores(q, st.items, K, exclude=excl, backend="pallas",
                         block_i=block_i)
    vj, xj = topk_scores(q, st.items, K, exclude=excl, backend="jnp",
                         block_i=block_i)
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vj))
    np.testing.assert_array_equal(np.asarray(xf), np.asarray(xj))


def test_fused_matches_dense_reference():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    q = st.user_vectors(jnp.arange(U))
    v, ix = topk_scores(q, st.items, K, backend="pallas", block_i=64)
    dv, di = _dense_topk(st, K)
    _assert_matches_dense(v, ix, dv, di)


def test_exclusion_matches_dense_mask():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    q = st.user_vectors(jnp.arange(U))
    excl = RNG.integers(0, I, (U, 9)).astype(np.int32)
    excl[:, -2:] = -1                                  # padding entries
    v, ix = topk_scores(q, st.items, K, exclude=jnp.asarray(excl),
                        backend="pallas", block_i=50)
    dv, di = _dense_topk(st, K, exclude=excl)
    _assert_matches_dense(v, ix, dv, di)


def test_merge_topk_shards_equal_global():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=None)
    bounds = [0, 57, 130, 201, I]                      # uneven shards
    parts_v, parts_i = [], []
    for a, b in zip(bounds[:-1], bounds[1:]):
        kk = min(K, b - a)
        v, ix = topk_scores(st.users, st.items[a:b], kk, block_i=31)
        parts_v.append(np.asarray(v))
        parts_i.append(np.asarray(ix) + a)
    mv, mi = merge_topk(parts_v, parts_i, K)
    dv, di = _dense_topk(st, K)
    _assert_matches_dense(mv, mi, dv, di)


# --- streaming eval ---------------------------------------------------------


@pytest.fixture(scope="module")
def kg_setup():
    ds = gen_kg_dataset(n_users=60, n_items=90, n_attrs=40, n_relations=4,
                        n_triples=500, inter_per_user=10, seed=11)
    cfg = kgnn.KGNNConfig(model="kgat", n_users=ds.n_users,
                          n_entities=ds.n_entities,
                          n_relations=ds.n_relations, dim=16, n_layers=2,
                          readout="concat")
    params = kgnn.init_params(jax.random.PRNGKey(3), cfg)
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    return ds, cfg, params, g


def test_streaming_eval_matches_dense(kg_setup):
    """fp32 store: streaming evaluator == dense recall_ndcg_at_k <= 1e-6."""
    ds, cfg, params, g = kg_setup
    store = build_kgnn_store(params, g, cfg, ds.n_items, bits=None)
    r_s, n_s = streaming_eval_dataset(store, ds, k=20, user_chunk=23,
                                      backend="jnp", block_i=32)
    reps = kgnn.propagate(params, g, cfg)
    scores = reps[:ds.n_users] @ reps[ds.n_users:ds.n_users + ds.n_items].T
    tr, te = ds.interaction_matrices()
    r_d, n_d = recall_ndcg_at_k(scores, jnp.asarray(te), jnp.asarray(tr),
                                k=20)
    assert abs(r_s - float(r_d)) <= 1e-6
    assert abs(n_s - float(n_d)) <= 1e-6


def test_streaming_eval_quantized_matches_dense_on_dequant(kg_setup):
    """INT8 store: streaming eval == dense reference applied to the
    SAME dequantized tables (the store is the model being measured)."""
    ds, cfg, params, g = kg_setup
    store = build_kgnn_store(params, g, cfg, ds.n_items, bits=8)
    r_s, n_s = streaming_eval_dataset(store, ds, k=20, backend="pallas",
                                      block_i=40)
    scores = store.user_vectors(jnp.arange(ds.n_users)) \
        @ store.item_matrix().T
    tr, te = ds.interaction_matrices()
    r_d, n_d = recall_ndcg_at_k(scores, jnp.asarray(te), jnp.asarray(tr),
                                k=20)
    assert abs(r_s - float(r_d)) <= 1e-6
    assert abs(n_s - float(n_d)) <= 1e-6


def test_streaming_eval_excludes_train_positives():
    """A train positive must never be recommended, even at rank k."""
    users = np.eye(4, 8, dtype=np.float32)
    items = np.tile(np.eye(4, 8, dtype=np.float32), (3, 1))  # 12 items
    store = QuantizedEmbeddingStore.from_arrays(users, items, bits=None)
    train = np.array([[u, u] for u in range(4)])   # item u is train pos
    test = np.array([[u, u + 4] for u in range(4)])
    excl = padded_pos_lists(train, 4)
    _, idx = topk_scores(jnp.asarray(users), store.items, 5,
                         exclude=jnp.asarray(excl), block_i=5)
    for u in range(4):
        assert u not in np.asarray(idx)[u]
    r, n = streaming_recall_ndcg(store, train, test, k=5, block_i=5)
    assert r == 1.0                                # test item promoted


# --- engine -----------------------------------------------------------------


def test_engine_bucketed_padding_never_retraces():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    excl = padded_pos_lists(
        np.stack([np.arange(U), RNG.integers(0, I, U)], 1), U)
    with ServingEngine(st, k=K, exclude=excl, backend="pallas",
                       buckets=(1, 4, 8), block_i=64) as eng:
        eng.warmup()                  # traces each bucket shape once
        traced = TRACE_COUNTS["topk_fused"]
        futs = [eng.submit(int(u)) for u in RNG.integers(0, U, 40)]
        for f in futs:
            f.result(timeout=120)
    # arbitrary arrival batch sizes all padded onto warm bucket shapes
    assert TRACE_COUNTS["topk_fused"] == traced
    st_stats = eng.stats()
    assert st_stats.n_requests == 40
    assert st_stats.p99_ms >= st_stats.p50_ms >= 0.0
    assert st_stats.qps > 0


def test_engine_responses_exact():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    excl = padded_pos_lists(
        np.stack([np.arange(U), np.arange(U) % I], 1), U)
    q = st.user_vectors(jnp.arange(U))
    dv, di = topk_scores(q, st.items, K, exclude=jnp.asarray(excl),
                         backend="pallas", block_i=64)
    dv, di = np.asarray(dv), np.asarray(di)
    uids = RNG.integers(0, U, 30)
    with ServingEngine(st, k=K, exclude=excl, backend="pallas",
                       buckets=(1, 4, 8), block_i=64) as eng:
        futs = [(int(u), eng.submit(int(u))) for u in uids]
        for u, fut in futs:
            vals, idx = fut.result(timeout=120)
            np.testing.assert_array_equal(vals, dv[u])
            np.testing.assert_array_equal(idx, di[u])


def test_engine_exit_resolves_or_cancels_every_future():
    """Shutdown must never strand a future: after __exit__ every submit
    is either served or cancelled (regression: requests queued behind
    the stop sentinel used to hang their callers)."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    with ServingEngine(st, k=K, backend="pallas", buckets=(4,),
                       block_i=64) as eng:
        futs = [eng.submit(int(u)) for u in RNG.integers(0, U, 25)]
        # exit immediately: the sentinel races the worker mid-drain
    assert all(f.done() for f in futs)
    served = sum(1 for f in futs if not f.cancelled())
    for f in futs:
        if not f.cancelled():
            vals, idx = f.result(timeout=1)
            assert vals.shape == (K,) and idx.shape == (K,)
    assert served >= 1          # the worker was actively serving


def test_engine_item_shards_exact():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    with ServingEngine(st, k=K, backend="pallas", buckets=(4,),
                       item_shards=3, block_i=50) as eng:
        fut = eng.submit(2)
        vals, idx = fut.result(timeout=120)
    dv, di = _dense_topk(st, K)
    _assert_matches_dense(vals[None], idx[None],
                          np.asarray(dv)[2][None], np.asarray(di)[2][None])
