"""Serving subsystem: store round-trip/ledger, chunked top-K exactness
(incl. chunk-boundary ties), fused-vs-fallback bit parity, exclusion
semantics, streaming-vs-dense eval, and the micro-batching engine."""

import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.synthetic import gen_kg_dataset
from repro.kernels.ops import TRACE_COUNTS
from repro.models import kgnn
from repro.serving import (
    BackpressureError,
    QuantizedEmbeddingStore,
    ServingEngine,
    apply_delta,
    build_kgnn_store,
    coarse_topm,
    merge_topk,
    padded_pos_lists,
    store_delta,
    streaming_eval_dataset,
    streaming_recall_ndcg,
    topk_scores,
    two_stage_topk,
)
from repro.training.metrics import recall_ndcg_at_k

RNG = np.random.default_rng(7)
U, I, D, K = 16, 257, 64, 20     # I deliberately not a block multiple
USERS = RNG.normal(size=(U, D)).astype(np.float32)
ITEMS = RNG.normal(size=(I, D)).astype(np.float32)


def _assert_matches_dense(v, ix, dv, di):
    """Vs the dense reference: indices exactly, values to fp32 matmul
    tolerance — XLA may accumulate the dense matmul in a different order
    than the per-chunk dot, so VALUES can differ in ulps even though the
    chunked merge itself is exact (the integer-valued tie tests below
    are bit-for-bit)."""
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(di))
    np.testing.assert_allclose(np.asarray(v), np.asarray(dv),
                               rtol=1e-6, atol=1e-6)


def _dense_topk(store, k, exclude=None):
    """Reference: dense masked score matrix + lax.top_k."""
    scores = store.user_vectors(jnp.arange(store.n_users)) \
        @ store.item_matrix().T
    if exclude is not None:
        mask = np.zeros((store.n_users, store.n_items), bool)
        for u, row in enumerate(np.asarray(exclude)):
            for i in row[row >= 0]:
                mask[u, i] = True
        scores = jnp.where(jnp.asarray(mask), -jnp.inf, scores)
    return jax.lax.top_k(scores, k)


# --- store ------------------------------------------------------------------


@pytest.mark.parametrize("bits,bound_codes", [(8, 255), (4, 15)])
def test_store_roundtrip_bound(bits, bound_codes):
    """Nearest rounding: |x - x_hat| <= scale/2 per element."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=bits)
    xhat = np.asarray(st.item_matrix())
    err = np.abs(xhat - ITEMS)
    scale = np.asarray(st.items.scale)          # (I, 1)
    assert (err <= scale / 2 + 1e-6).all()
    # and the quantizer actually used the full code range per row
    assert st.items.bits == bits


def test_store_memory_report_ratios():
    st8 = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    st4 = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=4)
    stf = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=None)
    m8, m4, mf = (s.memory_report() for s in (st8, st4, stf))
    assert mf["compression_ratio"] == 1.0
    assert m8["compression_ratio"] >= 3.5       # acceptance bar (d=64)
    assert m4["compression_ratio"] >= 6.0
    # ledger adds up and the fp32 column is the real array size
    for m in (m8, m4):
        assert m["packed_bytes"] + m["scale_zero_bytes"] == m["total_bytes"]
    assert mf["total_bytes"] == (U + I) * D * 4


def test_store_fp32_users_packed_items():
    """quantize_users=False: query tower stays exact, items packed."""
    from repro.core.quant import QTensor
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8,
                                             quantize_users=False)
    assert not isinstance(st.users, QTensor)
    assert isinstance(st.items, QTensor)
    np.testing.assert_array_equal(
        np.asarray(st.user_vectors(jnp.arange(U))), USERS)
    v, ix = topk_scores(st.user_vectors(jnp.arange(U)), st.items, K,
                        backend="pallas", block_i=64)
    dv, di = _dense_topk(st, K)
    _assert_matches_dense(v, ix, dv, di)


def test_store_pytree_roundtrip():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    st2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert st2.bits == 8 and st2.n_items == I
    np.testing.assert_array_equal(np.asarray(st.items.packed),
                                  np.asarray(st2.items.packed))


# --- chunked top-K ----------------------------------------------------------


@pytest.mark.parametrize("block_i", [20, 33, 64, 300])
def test_chunked_topk_equals_global_fp32(block_i):
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=None)
    v, ix = topk_scores(st.users, st.items, K, block_i=block_i)
    dv, di = _dense_topk(st, K)
    _assert_matches_dense(v, ix, dv, di)


def test_chunked_topk_boundary_ties():
    """Duplicated scores straddling chunk boundaries must keep the
    global lowest-index-first tie order."""
    q = np.eye(3, 8, dtype=np.float32)
    items = np.zeros((100, 8), np.float32)
    items[::2, :3] = 1.0      # every even item ties at score 1 for all rows
    st = QuantizedEmbeddingStore.from_arrays(q, items, bits=None)
    for block_i in (16, 25, 50):     # boundaries land on tied items
        v, ix = topk_scores(jnp.asarray(q), st.items, 40, block_i=block_i)
        dv, di = jax.lax.top_k(jnp.asarray(q) @ jnp.asarray(items).T, 40)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(dv))
        np.testing.assert_array_equal(np.asarray(ix), np.asarray(di))


def test_chunked_topk_ties_property():
    """Property sweep: tiny value alphabet -> massive tie mass; every
    (block size, k) must reproduce global lax.top_k exactly."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st_

    @settings(max_examples=25, deadline=None)
    @given(seed=st_.integers(0, 2**31 - 1), block_i=st_.integers(4, 40),
           k=st_.integers(1, 30), n_items=st_.integers(30, 90))
    def prop(seed, block_i, k, n_items):
        rng = np.random.default_rng(seed)
        q = rng.integers(-2, 3, (3, 4)).astype(np.float32)
        items = rng.integers(-2, 3, (n_items, 4)).astype(np.float32)
        k = min(k, n_items)
        v, ix = topk_scores(jnp.asarray(q), jnp.asarray(items), k,
                            block_i=block_i)
        dv, di = jax.lax.top_k(jnp.asarray(q) @ jnp.asarray(items).T, k)
        assert np.array_equal(np.asarray(v), np.asarray(dv))
        assert np.array_equal(np.asarray(ix), np.asarray(di))

    prop()


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("block_i", [40, 257])
def test_fused_vs_jnp_parity(bits, block_i):
    """The Pallas kernel and the jnp fallback run the same op schedule —
    interpret mode must agree to zero ulps, indices included."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=bits)
    q = st.user_vectors(jnp.arange(U))
    excl = jnp.asarray(RNG.integers(0, I, (U, 5)), jnp.int32)
    vf, xf = topk_scores(q, st.items, K, exclude=excl, backend="pallas",
                         block_i=block_i)
    vj, xj = topk_scores(q, st.items, K, exclude=excl, backend="jnp",
                         block_i=block_i)
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vj))
    np.testing.assert_array_equal(np.asarray(xf), np.asarray(xj))


def test_fused_matches_dense_reference():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    q = st.user_vectors(jnp.arange(U))
    v, ix = topk_scores(q, st.items, K, backend="pallas", block_i=64)
    dv, di = _dense_topk(st, K)
    _assert_matches_dense(v, ix, dv, di)


def test_exclusion_matches_dense_mask():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    q = st.user_vectors(jnp.arange(U))
    excl = RNG.integers(0, I, (U, 9)).astype(np.int32)
    excl[:, -2:] = -1                                  # padding entries
    v, ix = topk_scores(q, st.items, K, exclude=jnp.asarray(excl),
                        backend="pallas", block_i=50)
    dv, di = _dense_topk(st, K, exclude=excl)
    _assert_matches_dense(v, ix, dv, di)


def test_merge_topk_shards_equal_global():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=None)
    bounds = [0, 57, 130, 201, I]                      # uneven shards
    parts_v, parts_i = [], []
    for a, b in zip(bounds[:-1], bounds[1:]):
        kk = min(K, b - a)
        v, ix = topk_scores(st.users, st.items[a:b], kk, block_i=31)
        parts_v.append(np.asarray(v))
        parts_i.append(np.asarray(ix) + a)
    mv, mi = merge_topk(parts_v, parts_i, K)
    dv, di = _dense_topk(st, K)
    _assert_matches_dense(mv, mi, dv, di)


# --- streaming eval ---------------------------------------------------------


@pytest.fixture(scope="module")
def kg_setup():
    ds = gen_kg_dataset(n_users=60, n_items=90, n_attrs=40, n_relations=4,
                        n_triples=500, inter_per_user=10, seed=11)
    cfg = kgnn.KGNNConfig(model="kgat", n_users=ds.n_users,
                          n_entities=ds.n_entities,
                          n_relations=ds.n_relations, dim=16, n_layers=2,
                          readout="concat")
    params = kgnn.init_params(jax.random.PRNGKey(3), cfg)
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    return ds, cfg, params, g


def test_streaming_eval_matches_dense(kg_setup):
    """fp32 store: streaming evaluator == dense recall_ndcg_at_k <= 1e-6."""
    ds, cfg, params, g = kg_setup
    store = build_kgnn_store(params, g, cfg, ds.n_items, bits=None)
    r_s, n_s = streaming_eval_dataset(store, ds, k=20, user_chunk=23,
                                      backend="jnp", block_i=32)
    reps = kgnn.propagate(params, g, cfg)
    scores = reps[:ds.n_users] @ reps[ds.n_users:ds.n_users + ds.n_items].T
    tr, te = ds.interaction_matrices()
    r_d, n_d = recall_ndcg_at_k(scores, jnp.asarray(te), jnp.asarray(tr),
                                k=20)
    assert abs(r_s - float(r_d)) <= 1e-6
    assert abs(n_s - float(n_d)) <= 1e-6


def test_streaming_eval_quantized_matches_dense_on_dequant(kg_setup):
    """INT8 store: streaming eval == dense reference applied to the
    SAME dequantized tables (the store is the model being measured)."""
    ds, cfg, params, g = kg_setup
    store = build_kgnn_store(params, g, cfg, ds.n_items, bits=8)
    r_s, n_s = streaming_eval_dataset(store, ds, k=20, backend="pallas",
                                      block_i=40)
    scores = store.user_vectors(jnp.arange(ds.n_users)) \
        @ store.item_matrix().T
    tr, te = ds.interaction_matrices()
    r_d, n_d = recall_ndcg_at_k(scores, jnp.asarray(te), jnp.asarray(tr),
                                k=20)
    assert abs(r_s - float(r_d)) <= 1e-6
    assert abs(n_s - float(n_d)) <= 1e-6


def test_streaming_eval_excludes_train_positives():
    """A train positive must never be recommended, even at rank k."""
    users = np.eye(4, 8, dtype=np.float32)
    items = np.tile(np.eye(4, 8, dtype=np.float32), (3, 1))  # 12 items
    store = QuantizedEmbeddingStore.from_arrays(users, items, bits=None)
    train = np.array([[u, u] for u in range(4)])   # item u is train pos
    test = np.array([[u, u + 4] for u in range(4)])
    excl = padded_pos_lists(train, 4)
    _, idx = topk_scores(jnp.asarray(users), store.items, 5,
                         exclude=jnp.asarray(excl), block_i=5)
    for u in range(4):
        assert u not in np.asarray(idx)[u]
    r, n = streaming_recall_ndcg(store, train, test, k=5, block_i=5)
    assert r == 1.0                                # test item promoted


# --- engine -----------------------------------------------------------------


def test_engine_bucketed_padding_never_retraces():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    excl = padded_pos_lists(
        np.stack([np.arange(U), RNG.integers(0, I, U)], 1), U)
    with ServingEngine(st, k=K, exclude=excl, backend="pallas",
                       buckets=(1, 4, 8), block_i=64) as eng:
        eng.warmup()                  # traces each bucket shape once
        traced = TRACE_COUNTS["topk_fused"]
        futs = [eng.submit(int(u)) for u in RNG.integers(0, U, 40)]
        for f in futs:
            f.result(timeout=120)
    # arbitrary arrival batch sizes all padded onto warm bucket shapes
    assert TRACE_COUNTS["topk_fused"] == traced
    st_stats = eng.stats()
    assert st_stats.n_requests == 40
    assert st_stats.p99_ms >= st_stats.p50_ms >= 0.0
    assert st_stats.qps > 0


def test_engine_score_batch_oversized_chunks_no_retrace():
    """Direct score_batch callers with n > max(buckets) get chunked at
    the largest bucket — correct results, no per-size retracing."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    eng = ServingEngine(st, k=K, backend="pallas", buckets=(1, 4, 8),
                        block_i=64)
    eng.warmup()
    traced = TRACE_COUNTS["topk_fused"]
    dv, di = _dense_topk(st, K)
    dv, di = np.asarray(dv), np.asarray(di)
    for n in (9, 13, 27):             # three distinct oversized sizes
        uids = RNG.integers(0, U, n).astype(np.int32)
        vals, idx = eng.score_batch(uids)
        assert vals.shape == (n, K) and idx.shape == (n, K)
        _assert_matches_dense(vals, idx, dv[uids], di[uids])
    assert TRACE_COUNTS["topk_fused"] == traced


def test_engine_responses_exact():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    excl = padded_pos_lists(
        np.stack([np.arange(U), np.arange(U) % I], 1), U)
    q = st.user_vectors(jnp.arange(U))
    dv, di = topk_scores(q, st.items, K, exclude=jnp.asarray(excl),
                         backend="pallas", block_i=64)
    dv, di = np.asarray(dv), np.asarray(di)
    uids = RNG.integers(0, U, 30)
    with ServingEngine(st, k=K, exclude=excl, backend="pallas",
                       buckets=(1, 4, 8), block_i=64) as eng:
        futs = [(int(u), eng.submit(int(u))) for u in uids]
        for u, fut in futs:
            vals, idx = fut.result(timeout=120)
            np.testing.assert_array_equal(vals, dv[u])
            np.testing.assert_array_equal(idx, di[u])


def test_engine_exit_resolves_or_cancels_every_future():
    """Shutdown must never strand a future: after __exit__ every submit
    is either served or cancelled (regression: requests queued behind
    the stop sentinel used to hang their callers)."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    t0 = time.perf_counter()
    with ServingEngine(st, k=K, backend="pallas", buckets=(4,),
                       block_i=64) as eng:
        futs = [eng.submit(int(u)) for u in RNG.integers(0, U, 25)]
        # exit immediately: the sentinel races the worker mid-drain
    # the worker must see the sentinel and exit promptly — a pass that
    # leans on __exit__'s 60s join timeout (leaked daemon thread) is a
    # regression, not a pass (sentinel once swallowed when dequeued
    # mid-batch-collection)
    assert time.perf_counter() - t0 < 30
    assert all(f.done() for f in futs)
    served = sum(1 for f in futs if not f.cancelled())
    for f in futs:
        if not f.cancelled():
            vals, idx = f.result(timeout=1)
            assert vals.shape == (K,) and idx.shape == (K,)
    assert served >= 1          # the worker was actively serving


def test_engine_item_shards_exact():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    with ServingEngine(st, k=K, backend="pallas", buckets=(4,),
                       item_shards=3, block_i=50) as eng:
        fut = eng.submit(2)
        vals, idx = fut.result(timeout=120)
    dv, di = _dense_topk(st, K)
    _assert_matches_dense(vals[None], idx[None],
                          np.asarray(dv)[2][None], np.asarray(di)[2][None])


# --- two-stage retrieval (tier 2) -------------------------------------------


def test_two_stage_anchor_exact_at_full_candidates():
    """C large enough that m = n_items: candidates are ALL items, so the
    re-rank must reproduce single-stage indices exactly (values to
    reduction-order ulps — einsum vs chunked dot)."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8,
                                             quantize_users=False)
    q = st.user_vectors(jnp.arange(U))
    v1, x1 = topk_scores(q, st.items, K, backend="jnp")
    c_all = -(-I // K)
    v2, x2 = two_stage_topk(q, st.items, K, c=c_all, backend="jnp")
    np.testing.assert_array_equal(np.asarray(x2), np.asarray(x1))
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v1),
                               rtol=1e-6, atol=1e-6)


def test_two_stage_anchor_bitexact_integer_embeddings():
    """On embeddings that survive quantization exactly (each row spans
    [0, 255] -> scale 1, zero 0) every path computes exact fp32 integer
    arithmetic, so the C -> n/k anchor is bit-for-bit, values included —
    and the 0/255 rows tie heavily, exercising the global tie order."""
    rng = np.random.default_rng(5)
    q = rng.integers(-3, 4, (7, 16)).astype(np.float32)
    items = (255 * rng.integers(0, 2, (83, 16))).astype(np.float32)
    items[:, 0], items[:, 1] = 0.0, 255.0   # force exact per-row span
    st = QuantizedEmbeddingStore.from_arrays(q, items, bits=8,
                                             quantize_users=False)
    v1, x1 = topk_scores(jnp.asarray(q), st.items, 10, backend="jnp")
    v2, x2 = two_stage_topk(jnp.asarray(q), st.items, 10, c=9,
                            backend="jnp")     # 9*10 >= 83 -> all items
    np.testing.assert_array_equal(np.asarray(x2), np.asarray(x1))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v1))


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("block_i", [40, 257])
def test_coarse_pallas_jnp_bitexact(bits, block_i):
    """The fused coarse kernel and its jnp mirror run the identical op
    schedule on integer-valued fp32 inputs -> zero-ulp agreement."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=bits)
    q = st.user_vectors(jnp.arange(U))
    excl = jnp.asarray(RNG.integers(0, I, (U, 5)), jnp.int32)
    vf, xf = coarse_topm(q, st.items, 37, exclude=excl, backend="pallas",
                         block_i=block_i)
    vj, xj = coarse_topm(q, st.items, 37, exclude=excl, backend="jnp",
                         block_i=block_i)
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vj))
    np.testing.assert_array_equal(np.asarray(xf), np.asarray(xj))


def test_two_stage_candidate_sets_nested():
    """The coarse stage is a deterministic top-m: growing the budget can
    only ADD candidates (top-m1 is a prefix of top-m2's ranking)."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8,
                                             quantize_users=False)
    q = st.user_vectors(jnp.arange(U))
    prev = None
    for m in (10, 20, 40, 80, 160):
        _, idx = coarse_topm(q, st.items, m, backend="jnp")
        cur = [set(row) for row in np.asarray(idx)]
        if prev is not None:
            for a, b in zip(prev, cur):
                assert a <= b, "candidate sets must be nested in m"
        prev = cur


def test_two_stage_recall_monotone_in_c():
    """Nested candidates => recall against the exact top-K is
    nondecreasing in C (checked on the fixed test matrices)."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8,
                                             quantize_users=False)
    q = st.user_vectors(jnp.arange(U))
    _, x1 = topk_scores(q, st.items, K, backend="jnp")
    x1 = np.asarray(x1)
    last = -1.0
    for c in (1, 2, 4, 8, 13):
        _, x2 = two_stage_topk(q, st.items, K, c=c, backend="jnp")
        hits = (np.asarray(x2)[:, :, None] == x1[:, None, :]).any(-1)
        rec = float(hits.mean())
        assert rec >= last - 1e-12, f"recall fell from {last} at C={c}"
        last = rec
    assert last == 1.0        # C=13 -> 260 >= 257 items: exact


def test_two_stage_exclusion_both_stages():
    """Excluded ids must neither be served NOR consume candidate slots:
    at anchor C the excluded result equals the single-stage excluded
    ranking exactly."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8,
                                             quantize_users=False)
    q = st.user_vectors(jnp.arange(U))
    excl = RNG.integers(0, I, (U, 9)).astype(np.int32)
    excl[:, -2:] = -1
    v1, x1 = topk_scores(q, st.items, K, exclude=jnp.asarray(excl),
                         backend="jnp")
    v2, x2 = two_stage_topk(q, st.items, K, c=-(-I // K),
                            exclude=jnp.asarray(excl), backend="jnp")
    np.testing.assert_array_equal(np.asarray(x2), np.asarray(x1))
    for u in range(U):
        banned = set(excl[u][excl[u] >= 0].tolist())
        assert banned.isdisjoint(np.asarray(x2)[u].tolist())
    # and at a small budget the exclusions still never leak through
    _, x3 = two_stage_topk(q, st.items, K, c=2,
                           exclude=jnp.asarray(excl), backend="jnp")
    for u in range(U):
        banned = set(excl[u][excl[u] >= 0].tolist())
        assert banned.isdisjoint(np.asarray(x3)[u].tolist())


# --- merge_topk ordering contract -------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_merge_topk_tie_contract_shard_invariant(n_shards):
    """Deterministic (score desc, index asc) tie-break: on integer
    embeddings (massive tie mass, exact fp32) the sharded merge must be
    BIT-identical to the single-shard ranking for every shard count."""
    rng = np.random.default_rng(21)
    q = rng.integers(-2, 3, (9, 8)).astype(np.float32)
    items = rng.integers(-2, 3, (120, 8)).astype(np.float32)
    ref_v, ref_i = jax.lax.top_k(jnp.asarray(q) @ jnp.asarray(items).T, 15)
    bounds = np.linspace(0, 120, n_shards + 1, dtype=int)
    parts_v, parts_i = [], []
    for a, b in zip(bounds[:-1], bounds[1:]):
        v, ix = topk_scores(jnp.asarray(q), jnp.asarray(items[a:b]),
                            min(15, b - a), block_i=17)
        parts_v.append(np.asarray(v))
        parts_i.append(np.asarray(ix) + a)
    mv, mi = merge_topk(parts_v, parts_i, 15)
    np.testing.assert_array_equal(mv, np.asarray(ref_v))
    np.testing.assert_array_equal(mi, np.asarray(ref_i))


def test_engine_sharded_bitexact_on_ties():
    """End-to-end shard-count invariance through the engine on tied
    integer scores: 1, 2 and 4 shards serve identical bits."""
    rng = np.random.default_rng(33)
    users = rng.integers(-2, 3, (12, 8)).astype(np.float32)
    items = rng.integers(-2, 3, (96, 8)).astype(np.float32)
    st = QuantizedEmbeddingStore.from_arrays(users, items, bits=None)
    results = {}
    for shards in (1, 2, 4):
        with ServingEngine(st, k=12, backend="jnp", buckets=(4,),
                           item_shards=shards) as eng:
            futs = [eng.submit(u) for u in range(12)]
            results[shards] = [f.result(timeout=120) for f in futs]
    for shards in (2, 4):
        for (v1, i1), (vs, is_) in zip(results[1], results[shards]):
            np.testing.assert_array_equal(i1, is_)
            np.testing.assert_array_equal(v1, vs)


# --- engine tier 2: two-stage, cache, refresh, backpressure -----------------


def test_engine_two_stage_sharded_burst():
    """Fast-tier smoke: a 2-shard two-stage burst through the engine —
    at anchor C the responses equal the single-stage dense ranking."""
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8,
                                             quantize_users=False)
    dv, di = _dense_topk(st, K)
    with ServingEngine(st, k=K, backend="jnp", buckets=(1, 4, 8),
                       item_shards=2, two_stage_c=-(-I // K)) as eng:
        eng.warmup()
        futs = [(u, eng.submit(u)) for u in range(10)]
        for u, f in futs:
            vals, idx = f.result(timeout=120)
            np.testing.assert_array_equal(idx, np.asarray(di)[u])
            np.testing.assert_allclose(vals, np.asarray(dv)[u],
                                       rtol=1e-5, atol=1e-5)
    assert eng.stats().n_requests == 10


def test_engine_two_stage_requires_packed_store():
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=None)
    with pytest.raises(ValueError, match="packed"):
        ServingEngine(st, k=K, two_stage_c=4)


def test_engine_cache_replays_identical_results():
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    with ServingEngine(st, k=K, backend="jnp", buckets=(1, 4, 8),
                       cache_size=16, registry=reg) as eng:
        eng.warmup()
        first = [eng.submit(u).result(timeout=120) for u in range(8)]
        again = [eng.submit(u).result(timeout=120) for u in range(8)]
    for (v1, i1), (v2, i2) in zip(first, again):
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(v1, v2)
    hits = reg.counter("serve/cache_hits", engine=eng.label).value
    assert hits == 8                       # every replayed user hit
    assert eng.stats().cache_hit_rate == pytest.approx(0.5)


def test_engine_backpressure_named_and_metered():
    """A full bounded queue raises BackpressureError (not a bare Full)
    and counts the shed; accepted requests still complete."""
    import threading

    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()
    st = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    gate = threading.Event()
    with ServingEngine(st, k=K, backend="jnp", buckets=(1,),
                       max_pending=2, registry=reg) as eng:
        eng.warmup()
        orig = eng.score_batch
        eng.score_batch = lambda ids: (gate.wait(30), orig(ids))[1]
        accepted, shed = [], 0
        for u in range(10):
            try:
                accepted.append(eng.submit(u))
            except BackpressureError:
                shed += 1
        gate.set()
        for f in accepted:
            assert f.result(timeout=120)[1].shape == (K,)
    assert shed >= 10 - 2 - 1 - 1          # queue cap + in-flight slack
    assert reg.counter("serve/backpressure", engine=eng.label).value == shed
    assert eng.stats().n_requests == len(accepted)


# --- delta refresh ----------------------------------------------------------


def _perturbed(items, rows):
    out = items.copy()
    out[rows] += 1.0
    return out


def test_store_delta_roundtrip_bit_identical():
    """apply_delta(old, store_delta(old, new)) == new, bit for bit, for
    packed and fp32 tables; untouched rows are not shipped."""
    for bits in (8, None):
        old = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=bits)
        new = QuantizedEmbeddingStore.from_arrays(
            _perturbed(USERS, [3]), _perturbed(ITEMS, [7, 100]), bits=bits)
        d = store_delta(old, new)
        assert set(d.user_ids.tolist()) <= set(range(U))
        assert 7 in d.item_ids.tolist() and 100 in d.item_ids.tolist()
        assert d.stats()["rows_total"] == U + I
        patched = apply_delta(old, d)
        for t_new, t_pat in ((new.users, patched.users),
                             (new.items, patched.items)):
            if bits is None:
                np.testing.assert_array_equal(np.asarray(t_new),
                                              np.asarray(t_pat))
            else:
                np.testing.assert_array_equal(np.asarray(t_new.packed),
                                              np.asarray(t_pat.packed))
                np.testing.assert_array_equal(np.asarray(t_new.scale),
                                              np.asarray(t_pat.scale))
                np.testing.assert_array_equal(np.asarray(t_new.zero),
                                              np.asarray(t_pat.zero))


def test_store_delta_named_mismatch_errors():
    st8 = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8)
    st4 = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=4)
    with pytest.raises(ValueError, match="bits"):
        store_delta(st8, st4)
    small = QuantizedEmbeddingStore.from_arrays(USERS[:4], ITEMS, bits=8)
    with pytest.raises(ValueError, match="shapes"):
        store_delta(st8, small)
    d = store_delta(st8, st8)
    assert d.n_changed == 0
    with pytest.raises(ValueError, match="delta targets"):
        apply_delta(small, d)


def test_engine_refresh_serves_new_store_atomically():
    """refresh(new_store): the delta applies between batches, the store
    version bumps, and every post-refresh response equals a fresh
    engine on the new store."""
    old = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8,
                                              quantize_users=False)
    new = QuantizedEmbeddingStore.from_arrays(
        USERS, _perturbed(ITEMS, list(range(0, I, 3))), bits=8,
        quantize_users=False)
    with ServingEngine(old, k=K, backend="jnp", buckets=(1, 4)) as eng:
        eng.warmup()
        pre = eng.submit(1).result(timeout=120)
        stats = eng.refresh(new).result(timeout=120)
        post = eng.submit(1).result(timeout=120)
    assert stats["version"] == 1 and stats["items_changed"] > 0
    assert eng.version == 1
    ref_pre = topk_scores(old.user_vectors(jnp.arange(U)), old.items, K,
                          backend="jnp")
    ref_post = topk_scores(new.user_vectors(jnp.arange(U)), new.items, K,
                           backend="jnp")
    np.testing.assert_array_equal(pre[1], np.asarray(ref_pre[1])[1])
    np.testing.assert_array_equal(post[1], np.asarray(ref_post[1])[1])


def test_engine_cache_invalidation_on_refresh():
    """User-row delta drops exactly the changed users (unchanged users
    keep serving identical cached bits); any item-row delta clears the
    whole cache and post-refresh results reflect the new table."""
    base = QuantizedEmbeddingStore.from_arrays(USERS, ITEMS, bits=8,
                                               quantize_users=False)
    user_only = QuantizedEmbeddingStore.from_arrays(
        _perturbed(USERS, [0]), ITEMS, bits=8, quantize_users=False)
    item_too = QuantizedEmbeddingStore.from_arrays(
        _perturbed(USERS, [0]), _perturbed(ITEMS, [5]), bits=8,
        quantize_users=False)
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()
    with ServingEngine(base, k=K, backend="jnp", buckets=(1, 4),
                       cache_size=16, registry=reg) as eng:
        eng.warmup()
        r0 = {u: eng.submit(u).result(timeout=120) for u in (0, 1, 2)}
        eng.refresh(user_only).result(timeout=120)
        r1 = {u: eng.submit(u).result(timeout=120) for u in (0, 1, 2)}
        # unchanged users: identical bits (served from cache, stamped v1)
        for u in (1, 2):
            np.testing.assert_array_equal(r0[u][0], r1[u][0])
            np.testing.assert_array_equal(r0[u][1], r1[u][1])
        # changed user 0: rescored against its new row
        ref = topk_scores(user_only.user_vectors(jnp.arange(U)),
                          user_only.items, K, backend="jnp")
        np.testing.assert_array_equal(r1[0][1], np.asarray(ref[1])[0])
        hits_before_clear = reg.counter("serve/cache_hits",
                                        engine=eng.label).value
        assert hits_before_clear >= 2      # users 1, 2 replayed from cache
        eng.refresh(item_too).result(timeout=120)
        r2 = {u: eng.submit(u).result(timeout=120) for u in (0, 1, 2)}
        ref2 = topk_scores(item_too.user_vectors(jnp.arange(U)),
                           item_too.items, K, backend="jnp")
        for u in (0, 1, 2):                # all rescored: cache was cleared
            np.testing.assert_array_equal(r2[u][1], np.asarray(ref2[1])[u])
    assert eng.version == 2


def test_streaming_eval_two_stage_routing(kg_setup):
    """two_stage_c at anchor C routes through coarse+rerank and must
    reproduce the single-stage eval metrics exactly."""
    ds, cfg, params, g = kg_setup
    store = build_kgnn_store(params, g, cfg, ds.n_items, bits=8)
    r1, n1 = streaming_eval_dataset(store, ds, k=20, backend="jnp")
    r2, n2 = streaming_eval_dataset(store, ds, k=20, backend="jnp",
                                    two_stage_c=-(-ds.n_items // 20))
    assert r2 == pytest.approx(r1, abs=1e-9)
    assert n2 == pytest.approx(n1, abs=1e-9)
    # small budget: a real subset scan still produces sane metrics
    r3, _ = streaming_eval_dataset(store, ds, k=20, backend="jnp",
                                   two_stage_c=2)
    assert 0.0 <= r3 <= 1.0
