"""int8 KV cache (beyond-paper): decode parity with the bf16 cache."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tf

KEY = jax.random.PRNGKey(0)

CFG = tf.TransformerConfig(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, q_chunk=8, kv_chunk=8)
CFG_Q8 = dataclasses.replace(CFG, kv_cache_bits=8)


def test_cache_bytes_halved():
    c16 = tf.init_cache(CFG, batch=2, max_len=64)
    c8 = tf.init_cache(CFG_Q8, batch=2, max_len=64)
    b16 = sum(np.asarray(v).nbytes for v in jax.tree_util.tree_leaves(c16))
    b8 = sum(np.asarray(v).nbytes for v in jax.tree_util.tree_leaves(c8))
    # f32 model: 4B -> 1B codes + 8B/16 row stats = ~1.5B/elt
    assert b8 < 0.5 * b16, (b8, b16)


@pytest.mark.slow
def test_decode_parity_int8_vs_fp_cache():
    params = tf.init_params(KEY, CFG)
    prompt = jax.random.randint(KEY, (2, 16), 0, CFG.vocab)

    lg16, c16 = tf.prefill(params, prompt, CFG, tf.init_cache(CFG, 2, 32))
    lg8, c8 = tf.prefill(params, prompt, CFG_Q8, tf.init_cache(CFG_Q8, 2, 32))
    # prefill logits come from the exact (unquantized) forward in both
    np.testing.assert_allclose(np.asarray(lg16), np.asarray(lg8), atol=1e-5)

    nxt = jnp.argmax(lg16, -1)[:, None]
    d16, c16 = tf.decode_step(params, c16, nxt, CFG)
    d8, c8 = tf.decode_step(params, c8, nxt, CFG_Q8)
    # int8 cache adds bounded noise; rankings should agree
    rel = float(jnp.abs(d8 - d16).max() /
                (jnp.abs(d16).max() + 1e-9))
    assert rel < 0.05, rel
    agree = float((jnp.argmax(d8, -1) == jnp.argmax(d16, -1)).mean())
    assert agree == 1.0

    # a second step still consistent (quantized re-reads)
    d8b, _ = tf.decode_step(params, c8, jnp.argmax(d8, -1)[:, None], CFG_Q8)
    assert np.isfinite(np.asarray(d8b)).all()


def test_q8_roundtrip_error_bounded():
    x = jax.random.normal(KEY, (5, 7, 16))
    codes, scale, zero = tf._q8(x)
    xhat = tf._dq8(codes, scale, zero, jnp.float32)
    rng = x.max(-1, keepdims=True) - x.min(-1, keepdims=True)
    assert bool(jnp.all(jnp.abs(xhat - x) <= rng / 255.0 * 0.51 + 1e-6))
