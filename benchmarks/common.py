"""Shared KGNN training harness for the paper-table benchmarks.

Trains real KGNNs (KGAT / KGCN / KGIN) on the synthetic KG dataset with a
planted latent-factor signal, evaluates Recall@20 / NDCG@20 with the
paper's protocol (via the streaming full-ranking evaluator — no dense
(U, I) score matrix), and reports per-step wall time + activation memory
derived from the residual trace (the ops record what they save while the
loss is traced under a recording ``ActContext`` — no hand-maintained
shape tables). Policies may be uniform (``bits=``) or a per-site
``PolicySchedule`` (``schedule=``).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import act_context, step_key, traced_activation_report
from repro.core.policy import as_schedule, policy_for_bits
from repro.data.synthetic import KGDataset, gen_kg_dataset
from repro.models import kgnn
from repro.models.registry import build_step
from repro.serving import QuantizedEmbeddingStore, streaming_eval_dataset
from repro.training.optimizer import adam

_DS_CACHE: dict = {}


def dataset(*, seed=0, scale=1.0) -> KGDataset:
    key = (seed, scale)
    if key not in _DS_CACHE:
        _DS_CACHE[key] = gen_kg_dataset(
            n_users=int(200 * scale), n_items=int(300 * scale),
            n_attrs=int(150 * scale), n_relations=6,
            n_triples=int(2000 * scale), inter_per_user=20, seed=seed)
    return _DS_CACHE[key]


def make_cfg(model: str, ds: KGDataset, *, dim=32, n_layers=3) -> kgnn.KGNNConfig:
    return kgnn.KGNNConfig(
        model=model, n_users=ds.n_users, n_entities=ds.n_entities,
        n_relations=ds.n_relations, dim=dim, n_layers=n_layers,
        readout="concat" if model == "kgat" else "sum", l2=1e-5)


def evaluate(params, g, cfg, ds: KGDataset, k=20):
    """Full-ranking Recall/NDCG via the STREAMING evaluator.

    The dense ``(U, I)`` path (``training.metrics.recall_ndcg_at_k``)
    stays as the exactness reference in tests; the benchmarks use the
    serving-side streaming evaluator (fp32 store — no quantization of
    the eval itself), which matches it to <= 1e-6 and scales past graphs
    where a dense score matrix fits in memory.
    """
    reps = kgnn.propagate(params, g, cfg)
    store = QuantizedEmbeddingStore.from_arrays(
        reps[:ds.n_users], reps[ds.n_users:ds.n_users + ds.n_items],
        bits=None)
    r, n = streaming_eval_dataset(store, ds, k=k, backend="jnp")
    return float(r), float(n)


def train_kgnn(model: str, *, bits: int | None, stochastic: bool = True,
               steps: int = 200, dim: int = 32, batch: int = 256,
               lr: float = 5e-3, seed: int = 0, ds: KGDataset | None = None,
               eval_every: int = 0, kernel: str = "jnp",
               schedule=None) -> dict:
    """Train one (model × policy) cell; returns metrics + timings + curves.

    ``schedule`` (an ``ACTPolicy`` or ``PolicySchedule``) overrides the
    uniform policy built from ``bits``; either way each step runs inside an
    ``act_context`` so per-site policies and scope-hashed SR keys apply.
    """
    ds = ds or dataset(seed=0)
    cfg = make_cfg(model, ds, dim=dim)
    mixed = schedule is not None
    if schedule is None:
        schedule = policy_for_bits(bits, stochastic=stochastic, kernel=kernel)
    schedule = as_schedule(schedule)
    # one step definition per arch, from the registry (DESIGN.md §9) —
    # the same loss/init the launcher and the DP wrapper trace
    mstep = build_step(model, schedule=schedule, ds=ds, cfg=cfg,
                       batch_size=batch, data_seed=seed)
    g = mstep.data["graph"]
    params = mstep.init(jax.random.PRNGKey(seed))
    opt = adam(lr)
    opt_state = opt.init(params)
    root = jax.random.PRNGKey(1000 + seed)

    @jax.jit
    def train_step(params, opt_state, batch_, key):
        def loss_fn(p):
            return mstep.loss(p, batch_, ctx=act_context(schedule, key))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    it = mstep.batches()  # the registry step's own stream (batch, seed)
    losses, curve = [], []
    t_total = 0.0
    b0 = None
    for step in range(steps):
        b = next(it)
        b0 = b if b0 is None else b0
        t0 = time.perf_counter()
        params, opt_state, loss = train_step(params, opt_state, b,
                                             step_key(root, step))
        loss.block_until_ready()
        if step > 0:  # skip compile step
            t_total += time.perf_counter() - t0
        losses.append(float(loss))
        if eval_every and (step + 1) % eval_every == 0:
            r, n = evaluate(params, g, cfg, ds)
            curve.append({"step": step + 1, "recall": r, "ndcg": n})
    recall, ndcg = evaluate(params, g, cfg, ds)
    # activation memory from the residual trace (shape-only eval_shape
    # pass); step.loss with ctx=None resolves from the ambient recording
    # context the report enters
    mem = traced_activation_report(
        lambda p: mstep.loss(p, b0), params, schedule=schedule)
    return {
        # a per-site schedule is not a uniform bit-width — don't label it
        # as one in persisted results
        "model": model, "bits": None if mixed else bits,
        "schedule": repr(schedule) if mixed else None,
        "stochastic": stochastic,
        "recall@20": recall, "ndcg@20": ndcg,
        "final_loss": float(np.mean(losses[-10:])),
        "losses": losses, "eval_curve": curve,
        "step_ms": 1e3 * t_total / max(steps - 1, 1),
        "act_mem_bytes": mem["total_bytes"],
        "act_mem_fp32_bytes": mem["total_fp32_bytes"],
        "act_mem_ratio": mem["compression_ratio"],
    }
