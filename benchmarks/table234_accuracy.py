"""Paper Tables 2-4: KGNN accuracy vs activation precision.

Trains KGAT / KGCN / KGIN at FP32 (baseline) and INT8/4/2/1 compressed
activations on the synthetic KG dataset, reporting Recall@20 / NDCG@20.
Claims under test (paper §4.2.1): INT8 ≤ 0.3% relative loss, INT2 < 2%,
INT1 < 6% (vs ≫6% drops typical for CNNs).

Metrics come from the streaming full-ranking evaluator
(``repro.serving.eval`` via ``common.evaluate``) — exact-equivalent to
the dense ``recall_ndcg_at_k`` reference (tests/test_serving.py) but
without materializing the (U, I) score matrix.
"""

from __future__ import annotations

from repro.models.registry import kg_archs

from .common import train_kgnn

MODELS = kg_archs()  # the registered KG archs: kgat / kgcn / kgin
BITS = (None, 8, 4, 2, 1)


def run(*, steps=200, dim=32, models=MODELS, seeds=(0,)) -> list[dict]:
    rows = []
    for model in models:
        base = None
        for bits in BITS:
            rs, ns = [], []
            for seed in seeds:
                r = train_kgnn(model, bits=bits, steps=steps, dim=dim,
                               seed=seed)
                rs.append(r["recall@20"])
                ns.append(r["ndcg@20"])
            rec = sum(rs) / len(rs)
            ndcg = sum(ns) / len(ns)
            if bits is None:
                base = rec
            rows.append({
                "model": model, "bits": bits or "fp32",
                "recall@20": round(rec, 4), "ndcg@20": round(ndcg, 4),
                "rel_drop_%": round(100 * (base - rec) / max(base, 1e-9), 2),
            })
            print(f"[table234] {model} bits={bits or 'fp32'}: "
                  f"recall={rec:.4f} ndcg={ndcg:.4f} "
                  f"drop={rows[-1]['rel_drop_%']}%", flush=True)
    return rows
