"""Paper Table 5: activation memory / step time / accuracy trade-off.

"Act Mem" is byte accounting derived from the residual trace — the ops
record exactly what they save for backward while the loss is traced under
a recording ``ActContext`` (the same O(L·N·d) tensors the paper prices);
ratios reproduce the paper's 2.2×/3×/7×/10× ladder. Step time measures
the real (de)quant overhead of the jnp path on this host (paper reports
8-55% on GPU). ``mixed_schedule=True`` appends the tiered
first-layer-INT8/rest-INT2 preset row per model (per-site bits via
``PolicySchedule``).
"""

from __future__ import annotations

from repro.core.policy import first_layer_int8_rest_int2

from .common import train_kgnn

BITS = (None, 8, 4, 2, 1)


def run(*, steps=60, dim=32, models=("kgat", "kgcn", "kgin"),
        mixed_schedule: bool = False) -> list[dict]:
    rows = []
    for model in models:
        base_ms = base_rec = base_mem = None
        cells = [(bits, None) for bits in BITS]
        if mixed_schedule:
            cells.append(("8/2", first_layer_int8_rest_int2()))
        for bits, sched in cells:
            r = train_kgnn(model, bits=bits if sched is None else 2,
                           steps=steps, dim=dim, schedule=sched)
            if bits is None:
                base_ms, base_rec = r["step_ms"], r["recall@20"]
                base_mem = r["act_mem_fp32_bytes"]
            rows.append({
                "model": model, "bits": bits or "fp32",
                "act_mem_mb": round(r["act_mem_bytes"] / 2**20, 2),
                "mem_ratio": round(base_mem / r["act_mem_bytes"], 2),
                "step_ms": round(r["step_ms"], 1),
                "time_overhead_%": round(
                    100 * (r["step_ms"] - base_ms) / base_ms, 1),
                "acc_loss_%": round(
                    100 * (base_rec - r["recall@20"]) / max(base_rec, 1e-9),
                    2),
            })
            print(f"[table5] {model} bits={bits or 'fp32'}: "
                  f"mem={rows[-1]['act_mem_mb']}MB "
                  f"({rows[-1]['mem_ratio']}x) step={rows[-1]['step_ms']}ms "
                  f"(+{rows[-1]['time_overhead_%']}%) "
                  f"acc_loss={rows[-1]['acc_loss_%']}%", flush=True)
    return rows
