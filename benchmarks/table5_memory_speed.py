"""Paper Table 5: activation memory / step time / accuracy trade-off.

"Act Mem" is analytic byte accounting over the exact saved-activation
shapes (the same O(L·N·d) tensors the paper prices); ratios reproduce the
paper's 2.2×/3×/7×/10× ladder. Step time measures the real (de)quant
overhead of the jnp path on this host (paper reports 8-55% on GPU).
"""

from __future__ import annotations

from .common import train_kgnn

BITS = (None, 8, 4, 2, 1)


def run(*, steps=60, dim=32, models=("kgat", "kgcn", "kgin")) -> list[dict]:
    rows = []
    for model in models:
        base_ms = base_rec = base_mem = None
        for bits in BITS:
            r = train_kgnn(model, bits=bits, steps=steps, dim=dim)
            if bits is None:
                base_ms, base_rec = r["step_ms"], r["recall@20"]
                base_mem = r["act_mem_fp32_bytes"]
            rows.append({
                "model": model, "bits": bits or "fp32",
                "act_mem_mb": round(r["act_mem_bytes"] / 2**20, 2),
                "mem_ratio": round(base_mem / r["act_mem_bytes"], 2),
                "step_ms": round(r["step_ms"], 1),
                "time_overhead_%": round(
                    100 * (r["step_ms"] - base_ms) / base_ms, 1),
                "acc_loss_%": round(
                    100 * (base_rec - r["recall@20"]) / max(base_rec, 1e-9),
                    2),
            })
            print(f"[table5] {model} bits={bits or 'fp32'}: "
                  f"mem={rows[-1]['act_mem_mb']}MB "
                  f"({rows[-1]['mem_ratio']}x) step={rows[-1]['step_ms']}ms "
                  f"(+{rows[-1]['time_overhead_%']}%) "
                  f"acc_loss={rows[-1]['acc_loss_%']}%", flush=True)
    return rows
