"""Nightly perf gate: kernel ratios must not regress vs the committed
baseline (``BENCH_kernels.json`` at the repo root).

``benchmarks/run.py`` refreshes its rows in the repo-root file in place
(merged by row key, so a partial ``--only`` run keeps the other job's
rows), and the nightly workflow (.github/workflows/nightly.yml)
snapshots the committed baseline first and compares — reproduce a gate
failure locally with the same sequence:

    cp BENCH_kernels.json /tmp/bench_baseline.json
    PYTHONPATH=src python -m benchmarks.run --quick
    python benchmarks/check_regression.py \
        --baseline /tmp/bench_baseline.json --current BENCH_kernels.json

Gating policy:

  * every ``*_ratio`` field (e.g. ``fused_traffic_ratio``, the modeled
    HBM-traffic saving of the fused SPMM path, or the serving rows'
    ``store_bytes_ratio`` — fp32 bytes over packed store bytes from
    ``QuantizedEmbeddingStore.memory_report()``, acceptance bar INT8
    >= 3.5x — both deterministic, derived from shapes) is
    higher-is-better and HARD-fails when it drops more than ``--tol``
    (default 10%) below baseline;
  * serving-SLO latency: a row's ``p99_ms`` is compared LOWER-is-better
    and HARD-fails when it rises more than ``--tol`` above baseline —
    but only for rows measured as ``mode == "jnp"`` on ``backend ==
    "cpu"`` (plain XLA-compiled host timing, the one serving number
    that is stable run-to-run); pallas-interp and accelerator rows are
    report-only, for the same reason interpret-mode speedups don't
    gate. The sustained serving rows' ``qps_ratio`` (tier2 QPS over the
    single-stage baseline engine, measured in the same process on the
    same traffic) gates through the standard ``*_ratio`` rule;
  * jnp-vs-pallas timing speedups are derived and REPORTED for every
    ``<x>_jnp_us`` / ``<x>_pallas_interp_us`` pair (and for the roofline
    rows' explicit ``speedup_vs_jnp``) but only gate under
    ``--strict-timing`` AND only on rows whose ``mode`` field is
    ``"compiled"`` — interpret-mode wall-clock measures the Pallas
    interpreter, not the kernel, so gating it would make the nightly
    flake on every runner without a Mosaic/Triton backend. This makes
    ``--strict-timing`` safe to leave ON unconditionally: on an
    interpret-only runner it is a structural no-op;
  * a baseline row with no matching current row is a coverage
    regression and fails.
"""

from __future__ import annotations

import argparse
import json
import sys

# "k" keys the serving top-K rows (serve_bench.py), "bench" separates the
# roofline rows from the microbenchmark rows for the same op, "mode"
# keeps compiled and interpret measurements of one op as distinct rows,
# "config" separates the sustained-serving baseline/tier2 rows and "C"
# the two-stage candidate-budget rows; absent fields are simply
# skipped, so legacy rows are unaffected
_KEY_FIELDS = ("bench", "op", "mode", "bits", "dim", "rows", "n",
               "n_edges", "n_nodes", "model", "k", "config", "C")

# Every BENCH record must carry these (identity fields — a row without
# them can silently collide with or shadow another row under _key).
_REQUIRED_FIELDS = ("op", "mode", "backend")


class BenchSchemaError(ValueError):
    """A BENCH record is missing identity fields; message names them."""


def validate_bench_rows(rows: list) -> None:
    """Raise ``BenchSchemaError`` naming every row/field violation.

    Each record must carry ``op`` (what was measured), ``mode``
    (compiled | interp | host | ...) and ``backend`` (pallas | jnp |
    cpu | ...) so the merge key is total and the timing-gate logic can
    trust ``mode``.
    """
    problems = []
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            problems.append(f"row {i}: not an object "
                            f"({type(row).__name__})")
            continue
        missing = [f for f in _REQUIRED_FIELDS if f not in row]
        if missing:
            tag = ",".join(f"{f}={v}" for f, v in _key(row)) or f"row {i}"
            problems.append(f"{tag}: missing required keys {missing}")
    if problems:
        raise BenchSchemaError(
            "BENCH record schema violations: " + "; ".join(problems))


def _key(row: dict) -> tuple:
    return tuple((f, row[f]) for f in _KEY_FIELDS if f in row)


def _ratios(row: dict) -> dict:
    """Gateable ratios: explicit ``*_ratio`` fields plus derived
    jnp/pallas speedups (all higher-is-better)."""
    out = {}
    for k, v in row.items():
        if k.endswith("_ratio") and isinstance(v, (int, float)):
            out[k] = float(v)
    for k, v in row.items():
        if not k.endswith("_jnp_us"):
            continue
        mate = k[:-len("_jnp_us")] + "_pallas_interp_us"
        if isinstance(v, (int, float)) and row.get(mate):
            out[k[:-len("_jnp_us")] + "_speedup"] = \
                float(v) / float(row[mate])
    if isinstance(row.get("speedup_vs_jnp"), (int, float)):
        out["pallas_speedup"] = float(row["speedup_vs_jnp"])
    return out


def _timing_gated(row: dict, *, strict_timing: bool) -> bool:
    """Timing metrics gate only for genuinely compiled Pallas records."""
    return (strict_timing
            and row.get("mode") == "compiled"
            and str(row.get("impl", "pallas")).startswith("pallas"))


def compare(baseline: list, current: list, *, tol: float,
            strict_timing: bool) -> list[str]:
    cur_by_key = {_key(r): r for r in current}
    failures = []
    for brow in baseline:
        key = _key(brow)
        crow = cur_by_key.get(key)
        tag = ",".join(f"{f}={v}" for f, v in key) or "<unkeyed>"
        if crow is None:
            failures.append(f"{tag}: row missing from current run "
                            "(benchmark coverage regressed)")
            continue
        base_r, cur_r = _ratios(brow), _ratios(crow)
        for name, bval in base_r.items():
            cval = cur_r.get(name)
            if cval is None:
                failures.append(f"{tag}: metric {name} missing")
                continue
            drop = 1.0 - cval / bval if bval else 0.0
            line = (f"{tag}: {name} {bval:.3f} -> {cval:.3f} "
                    f"({'-' if drop > 0 else '+'}{abs(drop) * 100:.1f}%)")
            is_ratio = name.endswith("_ratio")
            gate = is_ratio or _timing_gated(
                crow, strict_timing=strict_timing)
            if drop > tol and gate:
                failures.append("REGRESSION " + line)
            else:
                print(("  " if drop <= tol else "  (timing, not gated) ")
                      + line)
        _check_p99(tag, brow, crow, tol=tol, failures=failures)
    return failures


def _check_p99(tag: str, brow: dict, crow: dict, *, tol: float,
               failures: list[str]) -> None:
    """Lower-is-better p99 latency gate for stable-timing rows.

    Only ``mode == "jnp"`` + ``backend == "cpu"`` rows gate (compiled
    XLA host timing); everything else — pallas interpret (interpreter
    wall-clock, not the kernel) and accelerator rows (runner-dependent)
    — is report-only.
    """
    bval, cval = brow.get("p99_ms"), crow.get("p99_ms")
    if not (isinstance(bval, (int, float)) and isinstance(cval, (int, float))
            and bval > 0):
        return
    rise = cval / bval - 1.0
    line = (f"{tag}: p99_ms {bval:.3f} -> {cval:.3f} "
            f"({'+' if rise > 0 else '-'}{abs(rise) * 100:.1f}%)")
    gated = crow.get("mode") == "jnp" and crow.get("backend") == "cpu"
    if rise > tol and gated:
        failures.append("REGRESSION " + line)
    else:
        print(("  " if rise <= tol else "  (p99, not gated) ") + line)


def _validate_schema(args) -> None:
    """--validate-schema: structural checks, no baseline comparison.

    Validates every given BENCH rows file (missing op/mode/backend is a
    named failure) and, with ``--summary``, a telemetry summary.json
    against repro.obs.sinks.SUMMARY_SCHEMA.
    """
    checked = 0
    for path in (args.baseline, args.current):
        if not path:
            continue
        with open(path) as f:
            validate_bench_rows(json.load(f))
        print(f"[check_regression] schema OK: {path}")
        checked += 1
    if args.summary:
        import os
        sys.path.insert(0, os.path.join(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), "src"))
        from repro.obs import validate_summary

        with open(args.summary) as f:
            validate_summary(json.load(f))
        print(f"[check_regression] schema OK: {args.summary}")
        checked += 1
    if not checked:
        raise SystemExit("--validate-schema needs --baseline, --current "
                         "and/or --summary")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--current", default=None)
    ap.add_argument("--tol", type=float, default=0.10,
                    help="allowed fractional drop before failing (0.10)")
    ap.add_argument("--strict-timing", action="store_true",
                    help="also gate on jnp/pallas wall-clock speedups")
    ap.add_argument("--validate-schema", action="store_true",
                    help="only validate file schemas (BENCH rows must "
                         "carry op/mode/backend; --summary validates a "
                         "telemetry summary.json), no ratio comparison")
    ap.add_argument("--summary", default=None, metavar="SUMMARY.json",
                    help="with --validate-schema: a launch --metrics-out "
                         "summary to validate")
    args = ap.parse_args()
    if args.validate_schema:
        _validate_schema(args)
        return
    if not args.baseline or not args.current:
        ap.error("--baseline and --current are required "
                 "(unless --validate-schema)")
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    for name, rows in (("baseline", baseline), ("current", current)):
        try:
            validate_bench_rows(rows)
        except BenchSchemaError as e:
            raise SystemExit(f"{name} {e}")
    failures = compare(baseline, current, tol=args.tol,
                       strict_timing=args.strict_timing)
    if failures:
        print(f"\n{len(failures)} kernel-ratio regression(s) > "
              f"{args.tol * 100:.0f}%:", file=sys.stderr)
        for msg in failures:
            print("  " + msg, file=sys.stderr)
        raise SystemExit(1)
    print(f"[check_regression] OK: no ratio regressed more than "
          f"{args.tol * 100:.0f}% across {len(baseline)} rows")


if __name__ == "__main__":
    main()
