"""Minibatch-subsystem bench: device footprint + host-device transfer.

The tiering acceptance bar (ISSUE 7 / ROADMAP item 1): on a
zipfian-degree KG whose full entity table does NOT need to be device
resident, sampled training with a ``hot_frac=0.1`` frequency-ranked hot
tier must (a) keep the hot-cache hit rate >= 80% of row requests,
(b) move >= 2x fewer rows per step than the same run with no hot tier,
and (c) train with peak device bytes under the full-table budget.

Rows land in ``BENCH_kernels.json`` keyed by
``bench="minibatch"``/``model``/``n_nodes``/``dim``;
``rows_transferred_per_step_ratio`` (no-cache over hot, higher is
better) is gated by ``check_regression.py``. All gated numbers are
deterministic (seeded sampler + seeded init); only ``step_ms`` varies
with the runner.
"""

from __future__ import annotations

import numpy as np

ZIPF = dict(n_users=3000, n_items=70000, n_attrs=27000, n_relations=6,
            n_triples=100000, inter_per_user=12, zipf_a=2.0, seed=0)
FANOUTS = (10, 5)
DIM = 16
BATCH = 64
HOT_FRAC = 0.1
LR = 0.01


def run(steps: int = 40) -> list:
    import jax

    from repro.data.synthetic import gen_zipf_kg_dataset
    from repro.models.registry import build_step
    from repro.training.tiering import run_sampled_training

    ds = gen_zipf_kg_dataset(**ZIPF)
    reports = {}
    for hot_frac in (HOT_FRAC, 0.0):
        step = build_step("kgat", ds=ds, batch_size=BATCH,
                          n_layers=len(FANOUTS), dim=DIM,
                          device_graph=False)
        rep, _, store = run_sampled_training(
            step, fanouts=FANOUTS, steps=steps, batch_size=BATCH,
            hot_frac=hot_frac, lr=LR, seed=0,
            init_key=jax.random.PRNGKey(0), measure_bytes=True)
        reports[hot_frac] = (rep, store)
        print(f"  hot_frac={hot_frac}: hit {rep.hit_rate:.2%}  "
              f"rows/step {rep.rows_transferred_per_step:.0f}  "
              f"peak {rep.peak_device_bytes / 2**20:.2f} MiB  "
              f"step {rep.step_ms:.1f} ms")
    hot, _ = reports[HOT_FRAC]
    cold, _ = reports[0.0]
    ratio = (cold.rows_transferred_per_step
             / max(hot.rows_transferred_per_step, 1.0))
    row = {
        "bench": "minibatch",
        "op": "sampled_step",
        "mode": "jnp",
        "backend": "cpu",
        "model": "kgat",
        "n_nodes": ds.graph.n_nodes,
        "n_edges": int(np.asarray(ds.graph.src).shape[0]),
        "dim": DIM,
        "fanouts": list(FANOUTS),
        "batch": BATCH,
        "hot_frac": HOT_FRAC,
        "steps": hot.n_steps,
        "hit_rate": round(hot.hit_rate, 4),
        "rows_transferred_per_step": round(
            hot.rows_transferred_per_step, 1),
        "rows_transferred_per_step_nocache": round(
            cold.rows_transferred_per_step, 1),
        "rows_transferred_per_step_ratio": round(ratio, 3),
        "peak_device_bytes": int(hot.peak_device_bytes),
        "hot_tier_bytes": int(hot.store_device_bytes),
        "table_bytes": int(hot.table_bytes),
        "step_ms": round(hot.step_ms, 2),
        "step_time_p99_ms": round(hot.step_ms_p99, 2),
        "loss_first": round(float(np.mean(hot.losses[:10])), 4),
        "loss_last": round(float(np.mean(hot.losses[-10:])), 4),
    }
    print(f"  transfer ratio (no-cache / hot) {ratio:.2f}x  "
          f"hit {hot.hit_rate:.2%}")
    return [row]


if __name__ == "__main__":
    print(run())
