"""Paper Table 6: stochastic vs nearest rounding.

The paper's key ablation — NR's bias accumulates and training degrades or
diverges below INT8, while SR (unbiased, Proposition 1) tracks FP32.
"""

from __future__ import annotations

from .common import train_kgnn

BITS = (8, 4, 2, 1)


def run(*, steps=200, dim=32, models=("kgat",)) -> list[dict]:
    rows = []
    for model in models:
        fp32 = train_kgnn(model, bits=None, steps=steps, dim=dim)
        rows.append({"model": model, "bits": "fp32", "rounding": "-",
                     "recall@20": round(fp32["recall@20"], 4),
                     "final_loss": round(fp32["final_loss"], 4)})
        for bits in BITS:
            for sr in (True, False):
                r = train_kgnn(model, bits=bits, stochastic=sr, steps=steps,
                               dim=dim)
                rows.append({
                    "model": model, "bits": bits,
                    "rounding": "SR" if sr else "NR",
                    "recall@20": round(r["recall@20"], 4),
                    "final_loss": round(r["final_loss"], 4),
                })
                print(f"[table6] {model} bits={bits} "
                      f"{'SR' if sr else 'NR'}: recall={r['recall@20']:.4f} "
                      f"loss={r['final_loss']:.4f}", flush=True)
    return rows
