"""Measured kernel roofline: achieved bytes/s and FLOP/s vs modeled peaks.

``launch/roofline.py`` models roofline terms from compiled HLO; this
module closes the loop by RUNNING the fused kernels and dividing the
analytic per-call byte/FLOP counts (same accounting as the modeled
terms) by measured wall-clock, yielding attainment percentages against
a hardware profile (``HW_PROFILES``) matched to the runtime:

    tpu  -> tpu-v5e      gpu -> a100      cpu -> host

Every row is honest about its execution mode (``mode`` field, from
``kernels.backend.resolve_mode``):

  * ``compiled`` — native Mosaic/Triton lowering; wall-clock and
    attainment are real kernel performance. These are the ONLY rows the
    nightly ``--strict-timing`` gate blocks on.
  * ``interpret`` — Pallas interpreter (CPU CI). Interpreter wall-clock
    says nothing about kernel quality, so attainment is computed from
    the best honest executable path (usually the unfused jnp/XLA
    reference) and ``why_not`` records, with measured numbers, why the
    fused kernel did not beat jnp wall-clock on this runner — the
    per-op explanation the acceptance criteria ask for when no compiled
    backend exists.

Run directly (``python -m benchmarks.roofline_bench``) or via
``python -m benchmarks.run --only roofline``; rows land in
``BENCH_kernels.json`` keyed with ``bench="roofline"`` so they never
collide with the kernel microbenchmark rows for the same op.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import dequantize as core_deq
from repro.core.quant import quantize as core_q
from repro.data.csr import build_spmm_layout
from repro.kernels import backend as kbackend
from repro.kernels import ops as kops
from repro.kernels import spmm as ksp
from repro.kernels import topk_score as ktk
from repro.launch.roofline import HW_PROFILES

_PLATFORM_HW = {"tpu": "tpu-v5e", "gpu": "a100", "cuda": "a100",
                "rocm": "a100", "cpu": "host"}


def _median_us(fn, *args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))  # compile outside timing
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _row(op: str, *, pallas_fn, jnp_fn, model_bytes: float,
         model_flops: float, hw_name: str, reps: int, **dims) -> dict:
    """Measure one op and fill the normalized roofline record."""
    info = kbackend.probe_backend()
    mode = kbackend.resolve_mode("auto", op=op)
    impl = (f"pallas-{info.lowering}" if mode == "compiled"
            else "pallas-interpret")
    hw = HW_PROFILES[hw_name]

    pallas_us = _median_us(pallas_fn, reps=reps)
    jnp_us = _median_us(jnp_fn, reps=reps)

    # attainment is only meaningful for a path that actually executes
    # natively: the compiled kernel when available, else the fastest
    # honest executable (XLA's unfused jnp lowering)
    if mode == "compiled":
        att_us, att_impl = pallas_us, impl
    else:
        att_us, att_impl = ((pallas_us, impl) if pallas_us < jnp_us
                            else (jnp_us, "xla-jnp"))
    att_s = att_us * 1e-6
    achieved_bw = model_bytes / att_s
    achieved_fl = model_flops / att_s

    row = {
        "bench": "roofline", "op": op, **dims,
        "mode": mode, "backend": info.platform, "impl": impl,
        "hw_profile": hw_name,
        "pallas_us": round(pallas_us, 1),
        "jnp_us": round(jnp_us, 1),
        "speedup_vs_jnp": round(jnp_us / pallas_us, 3),
        "model_bytes": int(model_bytes),
        "model_flops": int(model_flops),
        "attainment_impl": att_impl,
        "achieved_gbs": round(achieved_bw / 1e9, 3),
        "achieved_gflops": round(achieved_fl / 1e9, 3),
        "hbm_attainment_pct": round(100 * achieved_bw / hw["hbm_bw"], 2),
        "flops_attainment_pct": round(100 * achieved_fl
                                      / hw["peak_flops"], 3),
    }
    if mode != "compiled" and pallas_us >= jnp_us:
        row["why_not"] = (
            f"no compiled Pallas lowering on backend={info.platform} "
            f"(interpret mode executes the kernel op-by-op in Python): "
            f"fused interpret {pallas_us:.0f}us vs unfused jnp "
            f"{jnp_us:.0f}us; attainment measured on {att_impl}")
    return row


def run(*, reps: int = 5, quick: bool = False) -> list[dict]:
    info = kbackend.probe_backend()
    hw_name = _PLATFORM_HW.get(info.platform, "host")
    scale = 2 if quick else 1
    rows_n = 4096 // scale
    dim = 256
    n_nodes = 2048 // scale
    n_edges = 16384 // scale
    bits = 4
    key = jax.random.PRNGKey(0)

    out = []

    # --- quant / dequant -------------------------------------------------
    x = jax.random.normal(key, (rows_n, dim))
    dp = dim * bits // 8
    out.append(_row(
        "quant_pack",
        pallas_fn=lambda: kops.quantize(x, key, bits=bits),
        jnp_fn=lambda: core_q(x, key, bits=bits),
        model_bytes=rows_n * dim * 4 + rows_n * dp + 8 * rows_n,
        model_flops=4.0 * rows_n * dim,
        hw_name=hw_name, reps=reps, bits=bits, dim=dim, rows=rows_n))
    q = kops.quantize(x, key, bits=bits)
    out.append(_row(
        "dequant_unpack",
        pallas_fn=lambda: kops.dequantize(q),
        jnp_fn=lambda: core_deq(q),
        model_bytes=rows_n * dp + 8 * rows_n + rows_n * dim * 4,
        model_flops=2.0 * rows_n * dim,
        hw_name=hw_name, reps=reps, bits=bits, dim=dim, rows=rows_n))

    # --- dequant matmul (∂W path) ---------------------------------------
    n_cols = 64
    g = jax.random.normal(key, (rows_n, n_cols))
    out.append(_row(
        "dequant_matmul",
        pallas_fn=lambda: kops.dequant_matmul(q, g),
        jnp_fn=lambda: core_deq(q).T @ g,
        model_bytes=(rows_n * dp + 8 * rows_n + rows_n * n_cols * 4
                     + dim * n_cols * 4),
        model_flops=2.0 * rows_n * dim * n_cols + 2.0 * rows_n * dim,
        hw_name=hw_name, reps=reps, bits=bits, dim=dim, rows=rows_n,
        n=n_cols))

    # --- SPMM forward + ∂ew ---------------------------------------------
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, n_nodes, n_edges))
    dst = jnp.asarray(rng.integers(0, n_nodes, n_edges))
    d2 = 128
    xs = jax.random.normal(jax.random.PRNGKey(1), (n_nodes, d2))
    ew = jax.random.uniform(jax.random.PRNGKey(2), (n_edges,))
    gs = jax.random.normal(jax.random.PRNGKey(3), (n_nodes, d2))
    layout = build_spmm_layout(src, dst, n_dst=n_nodes)
    out.append(_row(
        "spmm",
        pallas_fn=lambda: kops.spmm(xs, ew, layout),
        jnp_fn=lambda: jax.ops.segment_sum(
            xs[src] * ew[:, None], dst, num_segments=n_nodes),
        model_bytes=(n_edges * d2 * 4 + n_nodes * d2 * 4
                     + n_edges * 4 + 2 * n_edges * 4),
        model_flops=2.0 * n_edges * d2,
        hw_name=hw_name, reps=reps, dim=d2, n_edges=n_edges,
        n_nodes=n_nodes))
    qs = kops.quantize(xs, jax.random.PRNGKey(4), bits=bits)
    dp2 = d2 * bits // 8
    out.append(_row(
        "dequant_sddmm",
        pallas_fn=lambda: kops.spmm_grad_ew(qs, gs, layout),
        jnp_fn=lambda: jnp.sum(core_deq(qs)[src] * gs[dst], -1),
        model_bytes=(n_nodes * dp2 + 8 * n_nodes + n_nodes * d2 * 4
                     + n_edges * 4 + 2 * n_edges * 4),
        model_flops=2.0 * n_edges * d2 + 2.0 * n_nodes * d2,
        hw_name=hw_name, reps=reps, bits=bits, dim=d2, n_edges=n_edges,
        n_nodes=n_nodes))

    # --- fused top-K retrieval ------------------------------------------
    n_items, b, k = 4096 // scale, 64, 20
    xi = jax.random.normal(jax.random.PRNGKey(5), (n_items, d2))
    qi = kops.quantize(xi, jax.random.PRNGKey(6), bits=8)
    qv = jax.random.normal(jax.random.PRNGKey(7), (b, d2))
    excl = jnp.full((b, 8), -1, jnp.int32)
    dpi = qi.packed.shape[-1]

    def jnp_topk():
        scores = qv @ core_deq(qi).T
        return jax.lax.top_k(scores, k)

    out.append(_row(
        "topk_score",
        pallas_fn=lambda: ktk.fused_topk_scores(
            qv, qi.packed, qi.scale, qi.zero, excl, bits=8, dim=d2,
            k=k, n_items=n_items, interpret=kops.INTERPRET),
        jnp_fn=jnp_topk,
        model_bytes=(n_items * dpi + 8 * n_items + b * d2 * 4
                     + b * 8 * 4 + b * k * 8),
        model_flops=2.0 * b * n_items * d2,
        hw_name=hw_name, reps=reps, bits=8, dim=d2, k=k, rows=n_items))

    for r in out:
        note = f" ({r['why_not'][:40]}...)" if "why_not" in r else ""
        print(f"[roofline] {r['op']}: mode={r['mode']} "
              f"pallas {r['pallas_us']:.0f}us jnp {r['jnp_us']:.0f}us | "
              f"{r['achieved_gbs']:.1f} GB/s = {r['hbm_attainment_pct']}% "
              f"of {r['hw_profile']} HBM{note}", flush=True)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
