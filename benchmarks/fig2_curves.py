"""Paper Figure 2: training-loss curves with/without TinyKG (INT2)."""

from __future__ import annotations

from .common import train_kgnn


def run(*, steps=200, dim=32, models=("kgat", "kgcn", "kgin")) -> list[dict]:
    rows = []
    for model in models:
        for bits in (None, 2):
            r = train_kgnn(model, bits=bits, steps=steps, dim=dim)
            for i, loss in enumerate(r["losses"]):
                if i % 10 == 0:
                    rows.append({"model": model, "bits": bits or "fp32",
                                 "step": i, "loss": round(loss, 5)})
            print(f"[fig2] {model} bits={bits or 'fp32'}: "
                  f"final loss {r['final_loss']:.4f}", flush=True)
    return rows
