"""Benchmark harness — one entry per paper table/figure.

``python -m benchmarks.run [--quick] [--only tableX]``

Prints one ``name,us_per_call,derived`` CSV block per artifact and writes
full JSON to artifacts/bench/. ``us_per_call`` is the measured train-step
time where applicable (CPU host), ``derived`` the table's headline number.
"""

from __future__ import annotations

import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer steps (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="table234|table5|table6|fig2|fig3|kernels|serve|"
                         "roofline|minibatch|mesh2d")
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    steps = 60 if args.quick else 200

    from . import (fig2_curves, fig3_ratio, kernel_bench, mesh2d_bench,
                   minibatch_bench, roofline_bench, serve_bench,
                   table5_memory_speed, table6_rounding, table234_accuracy)

    jobs = {
        "table234": lambda: table234_accuracy.run(steps=steps),
        "table5": lambda: table5_memory_speed.run(steps=max(steps // 3, 30)),
        "table6": lambda: table6_rounding.run(steps=steps),
        "fig2": lambda: fig2_curves.run(steps=steps),
        "fig3": lambda: fig3_ratio.run(steps=max(steps * 3 // 4, 40)),
        "kernels": lambda: kernel_bench.run(),
        "serve": lambda: serve_bench.run(requests=60 if args.quick else 200,
                                         quick=args.quick),
        "roofline": lambda: roofline_bench.run(quick=args.quick),
        "minibatch": lambda: minibatch_bench.run(
            steps=15 if args.quick else 40),
        "mesh2d": lambda: mesh2d_bench.run(steps=6 if args.quick else 10),
    }
    if args.only:
        jobs = {args.only: jobs[args.only]}

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    summary = {}
    gated_rows = []   # kernels + serve rows feed the regression-gated file
    for name, fn in jobs.items():
        print(f"=== {name} ===", flush=True)
        rows = fn()
        summary[name] = rows
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump(rows, f, indent=1)
        if name in ("kernels", "serve", "roofline", "minibatch", "mesh2d"):
            gated_rows.extend(rows)
    if gated_rows:
        # perf trajectory tracked across PRs: committed at repo root.
        # Rows are MERGED by identity key into the existing file, so a
        # partial run (--only kernels / --only serve) refreshes its own
        # rows without dropping the other job's — dropping them would
        # read as a coverage regression at the nightly gate.
        from .check_regression import _key, validate_bench_rows
        validate_bench_rows(gated_rows)  # fail the producer, not the gate
        path = os.path.join(repo_root, "BENCH_kernels.json")
        merged = {}
        if os.path.exists(path):
            with open(path) as f:
                merged = {_key(r): r for r in json.load(f)}
        merged.update({_key(r): r for r in gated_rows})
        with open(path, "w") as f:
            json.dump(list(merged.values()), f, indent=1)
        print("name,us_per_call,derived")
        for row in rows:
            us = row.get("step_ms", 0) * 1e3 if "step_ms" in row else \
                row.get("quant_jnp_us", row.get("fwd_jnp_us",
                        row.get("topk_jnp_us", 0)))
            derived = row.get("recall@20", row.get("mem_ratio",
                              row.get("loss", row.get("rel_drop_%",
                              row.get("fused_traffic_ratio",
                              row.get("rows_transferred_per_step_ratio",
                                      ""))))))
            tag = "/".join(str(row.get(k)) for k in
                           ("model", "bits", "rounding", "dim", "step")
                           if k in row)
            print(f"{name}:{tag},{us:.0f},{derived}")
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    # registry snapshot of everything the benches incremented (tiering
    # counters, step-time reservoirs, ...) — "summary.json" above is the
    # per-table rows, so the telemetry snapshot gets its own name
    from repro.obs import write_summary
    write_summary(args.out, {"kind": "bench", "quick": bool(args.quick),
                             "only": args.only},
                  filename="obs_summary.json")
    print("[bench] wrote", args.out)


if __name__ == "__main__":
    main()
