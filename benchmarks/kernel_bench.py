"""Kernel microbenchmarks: fused Pallas quant/dequant/SPMM vs unfused jnp.

On this CPU container Pallas runs in interpret mode, so wall-times are NOT
TPU-representative; the derived column reports the analytic HBM-traffic
ratio of fused vs unfused (the quantity the fusion actually buys on TPU).

The SPMM section additionally reports measured interpret-mode parity
(max |fused - segment_sum|) — the correctness number the perf claim
stands on — and the traffic ratio of the fused kernels vs the unfused
``x[src] * ew -> segment_sum`` path, whose ``(E, d)`` message tensor
costs a 3·E·d·4-byte HBM round trip per direction.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import dequantize as core_deq
from repro.core.quant import quantize as core_q
from repro.data.csr import build_spmm_layout
from repro.kernels import backend as kbackend
from repro.kernels import ops as kops
from repro.kernels import spmm as ksp


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _mode_fields(op: str) -> dict:
    """Normalized schema: every record states what actually executed."""
    info = kbackend.probe_backend()
    return {"op": op, "mode": kbackend.resolve_mode("auto", op=op),
            "backend": info.platform}


def run_spmm(*, n_nodes=2048, n_edges=16384, dim=128, bits=4) -> list[dict]:
    """SPMM section: fused blocked-CSR kernels vs the (E, d) jnp path."""
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, n_nodes, n_edges))
    dst = jnp.asarray(rng.integers(0, n_nodes, n_edges))
    x = jax.random.normal(jax.random.PRNGKey(1), (n_nodes, dim))
    ew = jax.random.uniform(jax.random.PRNGKey(2), (n_edges,))
    g = jax.random.normal(jax.random.PRNGKey(3), (n_nodes, dim))
    layout = build_spmm_layout(src, dst, n_dst=n_nodes)

    def unfused(x_, ew_):
        return jax.ops.segment_sum(x_[src] * ew_[:, None], dst,
                                   num_segments=n_nodes)

    jnp_fwd = _time(unfused, x, ew, reps=3)
    pal_fwd = _time(lambda x_, ew_: kops.spmm(x_, ew_, layout), x, ew,
                    reps=3)
    fused_out = kops.spmm(x, ew, layout)
    parity = float(jnp.abs(fused_out - unfused(x, ew)).max())

    q = kops.quantize(x, jax.random.PRNGKey(4), bits=bits)
    pal_dew = _time(lambda g_: kops.spmm_grad_ew(q, g_, layout), g, reps=3)
    jnp_dew = _time(lambda g_: jnp.sum(core_deq(q)[src] * g_[dst], -1), g,
                    reps=3)

    # analytic HBM traffic, fp32 bytes. Unfused forward round-trips the
    # (E, d) message tensor: gather-read E·d·4, write E·d·4, re-read
    # E·d·4 into the scatter, plus the (N, d) output write. The fused
    # kernel does the gather-read and output write only.
    e_d = n_edges * dim * 4
    n_d = n_nodes * dim * 4
    unfused_traffic = 3 * e_d + n_d
    fused_traffic = e_d + n_d
    # backward ∇ew: unfused dequantizes x̂ to fp32 (N·d·4 write+read) and
    # round-trips x̂[src]·g[dst] products; fused reads packed codes only.
    packed_bytes = n_nodes * dim * bits // 8 + n_nodes * 8
    unfused_dew = packed_bytes + 2 * n_d + 3 * e_d + n_edges * 4
    fused_dew = packed_bytes + n_d + n_edges * 4
    row = {
        **_mode_fields("spmm"),
        "n_nodes": n_nodes, "n_edges": n_edges, "dim": dim,
        "bits": bits,
        "fwd_jnp_us": round(jnp_fwd, 1),
        "fwd_pallas_interp_us": round(pal_fwd, 1),
        "dew_jnp_us": round(jnp_dew, 1),
        "dew_pallas_interp_us": round(pal_dew, 1),
        "parity_max_abs": parity,
        "fused_traffic_ratio": round(unfused_traffic / fused_traffic, 2),
        "dew_traffic_ratio": round(unfused_dew / fused_dew, 2),
    }
    print(f"[kernel] spmm E={n_edges} d={dim}: parity {parity:.2e} | "
          f"fwd traffic win {row['fused_traffic_ratio']}x | "
          f"dew traffic win {row['dew_traffic_ratio']}x", flush=True)
    return [row]


def run(*, rows=4096, dim=256) -> list[dict]:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (rows, dim))
    out = []
    for bits in (8, 4, 2, 1):
        jnp_q = _time(lambda x_: core_q(x_, key, bits=bits), x)
        pal_q = _time(lambda x_: kops.quantize(x_, key, bits=bits), x)
        q = core_q(x, key, bits=bits)
        jnp_d = _time(core_deq, q)
        pal_d = _time(kops.dequantize, q)
        g = jax.random.normal(key, (rows, 64))
        pal_mm = _time(kops.dequant_matmul, q, g)
        jnp_mm = _time(lambda q_, g_: core_deq(q_).T @ g_, q, g)
        # analytic HBM traffic: unfused writes+reads the fp32 codes tensor
        fp32_bytes = rows * dim * 4
        packed = rows * dim * bits // 8 + rows * 8
        fused_traffic = fp32_bytes + packed            # read x, write packed
        unfused_traffic = fp32_bytes * 3 + packed      # + codes roundtrip
        out.append({
            **_mode_fields("quant_pack"),
            "bits": bits, "dim": dim,
            "quant_jnp_us": round(jnp_q, 1),
            "quant_pallas_interp_us": round(pal_q, 1),
            "dequant_jnp_us": round(jnp_d, 1),
            "dequant_pallas_interp_us": round(pal_d, 1),
            "dqmm_jnp_us": round(jnp_mm, 1),
            "dqmm_pallas_interp_us": round(pal_mm, 1),
            "fused_traffic_ratio": round(unfused_traffic / fused_traffic, 2),
        })
        print(f"[kernel] bits={bits}: quant jnp {jnp_q:.0f}us | "
              f"fused-traffic win {out[-1]['fused_traffic_ratio']}x",
              flush=True)
    out.extend(run_spmm(n_nodes=rows // 2, n_edges=rows * 4, dim=dim // 2))
    return out
