"""Kernel microbenchmarks: fused Pallas quant/dequant vs unfused jnp path.

On this CPU container Pallas runs in interpret mode, so wall-times are NOT
TPU-representative; the derived column reports the analytic HBM-traffic
ratio of fused vs unfused (the quantity the fusion actually buys on TPU).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.quant import dequantize as core_deq
from repro.core.quant import quantize as core_q
from repro.kernels import ops as kops


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(*, rows=4096, dim=256) -> list[dict]:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (rows, dim))
    out = []
    for bits in (8, 4, 2, 1):
        jnp_q = _time(lambda x_: core_q(x_, key, bits=bits), x)
        pal_q = _time(lambda x_: kops.quantize(x_, key, bits=bits), x)
        q = core_q(x, key, bits=bits)
        jnp_d = _time(core_deq, q)
        pal_d = _time(kops.dequantize, q)
        g = jax.random.normal(key, (rows, 64))
        pal_mm = _time(kops.dequant_matmul, q, g)
        jnp_mm = _time(lambda q_, g_: core_deq(q_).T @ g_, q, g)
        # analytic HBM traffic: unfused writes+reads the fp32 codes tensor
        fp32_bytes = rows * dim * 4
        packed = rows * dim * bits // 8 + rows * 8
        fused_traffic = fp32_bytes + packed            # read x, write packed
        unfused_traffic = fp32_bytes * 3 + packed      # + codes roundtrip
        out.append({
            "bits": bits,
            "quant_jnp_us": round(jnp_q, 1),
            "quant_pallas_interp_us": round(pal_q, 1),
            "dequant_jnp_us": round(jnp_d, 1),
            "dequant_pallas_interp_us": round(pal_d, 1),
            "dqmm_jnp_us": round(jnp_mm, 1),
            "dqmm_pallas_interp_us": round(pal_mm, 1),
            "fused_traffic_ratio": round(unfused_traffic / fused_traffic, 2),
        })
        print(f"[kernel] bits={bits}: quant jnp {jnp_q:.0f}us | "
              f"fused-traffic win {out[-1]['fused_traffic_ratio']}x",
              flush=True)
    return out
