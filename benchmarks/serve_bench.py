"""Serving-subsystem benchmark: store bytes, QPS/latency, fused parity,
two-stage recall-vs-candidates, and a sustained zipfian SLO run.

One row per store precision (fp32 / INT8 / INT4) on the standard
synthetic KG benchmark graph (KGAT rollout, dim 32 × 4-layer concat
readout = 128-dim representations):

  * ``store_bytes_ratio``   — fp32 bytes / packed bytes from
    ``memory_report()`` (deterministic, shape-derived; nightly-gated
    like every ``*_ratio`` via benchmarks/check_regression.py; the
    acceptance bar is INT8 >= 3.5x);
  * ``topk_jnp_us`` / ``topk_pallas_interp_us`` — chunked scorer wall
    time per batch, fused kernel vs jnp fallback (check_regression
    derives the speedup; report-only, interpret-mode timings are noise);
  * ``qps`` / ``p50_ms`` / ``p99_ms`` — micro-batching engine under a
    burst of single-user requests. Percentiles are read from the
    engine's bounded obs reservoir (``serve/latency_ms``) — the SAME
    snapshot ``obs_summary.json`` persists, unrounded, so the BENCH row
    and the telemetry summary agree to the last bit (each row also
    carries ``engine_label`` naming its series there, and the values
    are mirrored onto ``serve/bench_*`` gauges);
  * ``fused_jnp_bitexact`` — the fused/fallback parity contract,
    asserted (not just reported) while measuring;
  * ``stream_dense_max_diff`` — streaming evaluator vs the dense
    reference on the same store (exactness check, asserted <= 1e-6).

Tier-2 rows (DESIGN.md §14):

  * ``op=serve_two_stage`` — recall@k of two-stage retrieval (coarse
    packed-domain scan keeping C·k candidates -> fp32 re-rank) against
    the single-stage exact ranking of the SAME packed store, measured
    on a large item table so the headline C=4 point dequantizes < 10%
    of items. ``two_stage_recall_ratio`` (gated, asserted >= 0.99),
    ``candidate_ratio`` (asserted <= 0.10), the full ``recall_curve``
    over C, and the C = n/k anchor where indices must match EXACTLY.
  * ``op=serve_sustained`` — closed-loop zipfian traffic for a fixed
    wall-clock window against (a) the baseline single-stage unsharded
    uncached engine and (b) the tier-2 engine (2 item shards +
    two-stage C=4 + hot-user cache). The tier-2 row's ``qps_ratio``
    (tier2/baseline, higher-is-better) is nightly-gated, and its
    ``p99_ms`` is gated lower-is-better for mode=="jnp" cpu rows (see
    check_regression.py). Exact row values are mirrored onto
    ``serve/sustained_*`` gauges so ``obs_summary.json`` agrees <= 1e-6.

Standalone sustained run:

    PYTHONPATH=src python -m benchmarks.serve_bench \
        --sustained --duration-s 10 --zipf-a 1.1
"""

from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import backend as kbackend
from repro.models import kgnn
from repro.obs import get_registry
from repro.serving import (BackpressureError, QuantizedEmbeddingStore,
                           ServingEngine, build_kgnn_store, padded_pos_lists,
                           streaming_eval_dataset, topk_scores,
                           two_stage_topk)
from repro.training.metrics import recall_ndcg_at_k

from .common import dataset, make_cfg

K = 20
BATCH = 64          # scorer batch for the timing measurement


def _time_scorer(q, items, excl, backend, *, reps=3) -> float:
    out = topk_scores(q, items, K, exclude=excl, backend=backend)
    jax.block_until_ready(out)                       # compile outside timing
    t0 = time.perf_counter()
    for _ in range(reps):
        out = topk_scores(q, items, K, exclude=excl, backend=backend)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # us / batch


def _mirror(gauge_name: str, value: float, **labels) -> None:
    """Pin a row value onto a gauge so obs_summary.json carries the
    exact same number (the <=1e-6 agreement the tests check)."""
    get_registry().gauge(gauge_name, **labels).set(float(value))


def run(*, requests: int = 200, seed: int = 0, quick: bool = False
        ) -> list[dict]:
    ds = dataset(seed=seed)
    cfg = make_cfg("kgat", ds)
    params = kgnn.init_params(jax.random.PRNGKey(seed), cfg)
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    exclude = padded_pos_lists(ds.train_pos, ds.n_users)
    rng = np.random.default_rng(seed)
    uids = rng.integers(0, ds.n_users, BATCH)
    excl_b = jnp.asarray(exclude[uids])

    rows = []
    for bits in (None, 8, 4):
        store = build_kgnn_store(params, g, cfg, ds.n_items, bits=bits)
        mem = store.memory_report()
        q = store.user_vectors(jnp.asarray(uids))
        backend = "pallas" if bits is not None else "jnp"

        info = kbackend.probe_backend()
        row = {
            "op": "serve_topk", "model": "kgat",
            # fp32 stores score via plain jnp (no fused kernel involved)
            "mode": ("jnp" if bits is None
                     else kbackend.resolve_mode("auto", op="serve_topk")),
            "backend": info.platform,
            "bits": bits or "fp32", "dim": mem["dim"], "k": K,
            "store_total_bytes": mem["total_bytes"],
            "store_fp32_bytes": mem["fp32_bytes"],
            "store_bytes_ratio": round(mem["compression_ratio"], 4),
            "topk_jnp_us": _time_scorer(q, store.items, excl_b, "jnp"),
        }
        if bits is not None:
            row["topk_pallas_interp_us"] = _time_scorer(
                q, store.items, excl_b, "pallas")
            vf, xf = topk_scores(q, store.items, K, exclude=excl_b,
                                 backend="pallas")
            vj, xj = topk_scores(q, store.items, K, exclude=excl_b,
                                 backend="jnp")
            exact = bool(jnp.array_equal(vf, vj)) and \
                bool(jnp.array_equal(xf, xj))
            assert exact, "fused/fallback parity broken"
            row["fused_jnp_bitexact"] = exact

        with ServingEngine(store, k=K, exclude=exclude, backend=backend,
                           buckets=(1, 4, 16, 64)) as eng:
            eng.warmup()
            futs = [eng.submit(int(u))
                    for u in rng.integers(0, ds.n_users, requests)]
            for f in futs:
                f.result(timeout=300)
        # UNROUNDED, straight off the obs reservoir (EngineStats reads
        # serve/latency_ms) — rounding here would break the bench-row /
        # obs_summary.json single-source-of-truth agreement
        st = eng.stats()
        row.update(qps=st.qps, p50_ms=st.p50_ms, p99_ms=st.p99_ms,
                   engine_label=eng.label)
        for metric in ("qps", "p50_ms", "p99_ms"):
            _mirror(f"serve/bench_{metric}", row[metric],
                    op="serve_topk", bits=str(row["bits"]))

        # streaming evaluator vs dense reference ON THE SAME STORE
        r_s, n_s = streaming_eval_dataset(store, ds, k=K, backend=backend)
        reps_u = store.user_vectors(jnp.arange(ds.n_users))
        scores = reps_u @ store.item_matrix().T
        tr, te = ds.interaction_matrices()
        r_d, n_d = recall_ndcg_at_k(scores, jnp.asarray(te),
                                    jnp.asarray(tr), k=K)
        diff = max(abs(r_s - float(r_d)), abs(n_s - float(n_d)))
        assert diff <= 1e-6, f"streaming/dense eval diverged: {diff}"
        row.update({"recall@20": round(r_s, 4), "ndcg@20": round(n_s, 4),
                    "stream_dense_max_diff": diff})
        rows.append(row)
        print(f"[serve_bench] bits={row['bits']}: "
              f"bytes_ratio={row['store_bytes_ratio']} "
              f"qps={row['qps']:.1f} p99={row['p99_ms']:.3f}ms "
              f"stream|dense diff={diff:.1e}", flush=True)

    rows.append(two_stage_row(seed=seed, quick=quick))
    rows.extend(run_sustained(duration_s=2.0 if quick else 6.0,
                              seed=seed, quick=quick))
    return rows


# -- two-stage recall vs candidate budget ------------------------------------


def two_stage_row(*, seed: int = 0, quick: bool = False) -> dict:
    """Recall@K of two-stage retrieval vs the exact single-stage ranking
    of the same packed store, over the candidate budget C.

    The item table is sized so the headline C=4 point re-ranks < 10% of
    items (i.e. >= 90% of the catalog is scanned packed-only); the
    C = ceil(n/k) anchor must reproduce single-stage indices EXACTLY
    (candidates = all items — only query-rounding-free fp32 re-rank
    remains, same merge contract).
    """
    rng = np.random.default_rng(seed + 17)
    n_items = 2048 if quick else 4096
    n_q = 64
    dim = 128
    users = rng.normal(size=(n_q, dim)).astype(np.float32)
    items = rng.normal(size=(n_items, dim)).astype(np.float32)
    store = QuantizedEmbeddingStore.from_arrays(users, items, bits=8,
                                                quantize_users=False)
    q = store.user_vectors(jnp.arange(n_q))
    v1, x1 = topk_scores(q, store.items, K, backend="jnp")
    x1 = np.asarray(x1)

    def _recall(x2) -> float:
        """Set overlap with the exact top-K, averaged over queries."""
        hits = (np.asarray(x2)[:, :, None] == x1[:, None, :]).any(-1)
        return float(hits.mean())

    curve = []
    for c in (1, 2, 4, 8, 16):
        _, x2 = two_stage_topk(q, store.items, K, c=c, backend="jnp")
        m = min(c * K, n_items)
        curve.append({"C": c, "recall_at_k": _recall(x2),
                      "candidate_frac": m / n_items})

    # exactness anchor: candidates == all items
    c_all = -(-n_items // K)
    _, x_all = two_stage_topk(q, store.items, K, c=c_all, backend="jnp")
    anchor_exact = bool(np.array_equal(np.asarray(x_all), x1))
    assert anchor_exact, "C=n/k two-stage must reproduce single-stage indices"

    head = next(p for p in curve if p["C"] == 4)
    ratio = head["recall_at_k"]          # single-stage recall of itself = 1
    assert ratio >= 0.99, \
        f"two-stage C=4 recall ratio {ratio:.4f} < 0.99"
    assert head["candidate_frac"] <= 0.10, \
        f"C=4 re-ranks {head['candidate_frac']:.1%} of items (> 10%)"

    # scan cost: coarse+rerank vs single-stage, same jnp mode
    def _t(fn, *, reps=3):
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / reps * 1e6

    row = {
        "op": "serve_two_stage", "mode": "jnp", "backend": "cpu",
        "bits": 8, "dim": dim, "k": K, "C": 4, "n": n_items,
        "two_stage_recall_ratio": ratio,
        "candidate_ratio": head["candidate_frac"],
        "anchor_exact": anchor_exact,
        "recall_curve": curve,
        "scan_jnp_us": _t(lambda: topk_scores(
            q, store.items, K, backend="jnp")),
        "two_stage_jnp_us": _t(lambda: two_stage_topk(
            q, store.items, K, c=4, backend="jnp")),
    }
    _mirror("serve/two_stage_recall_ratio", ratio, C="4")
    _mirror("serve/two_stage_candidate_ratio", head["candidate_frac"], C="4")
    print(f"[serve_bench] two-stage: C=4 recall_ratio={ratio:.4f} "
          f"candidate_ratio={head['candidate_frac']:.3f} "
          f"anchor_exact={anchor_exact} "
          f"curve={[round(p['recall_at_k'], 3) for p in curve]}", flush=True)
    return row


# -- sustained zipfian SLO run -----------------------------------------------


def _zipf_stream(n_users: int, n: int, *, a: float, seed: int) -> np.ndarray:
    """n user ids drawn from a zipf(a) popularity law over a fixed
    permutation of the user set (same seed -> same stream, so baseline
    and tier-2 serve IDENTICAL traffic)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(n_users)
    pmf = 1.0 / np.arange(1, n_users + 1) ** a
    pmf /= pmf.sum()
    return order[rng.choice(n_users, size=n, p=pmf)].astype(np.int32)


def _drive_one(eng: ServingEngine, stream: np.ndarray, *,
               duration_s: float, window: int) -> int:
    """Closed-loop driver: keep <= ``window`` requests outstanding for
    ``duration_s`` of wall clock (cycling the stream), then drain.

    When the window fills, HALF of it is collected at once — waiting
    for one future per submit would make the driver ping-pong with the
    worker on every request and measure thread wakeup latency instead
    of engine throughput."""
    outstanding: deque = deque()
    n = 0
    t_end = time.perf_counter() + duration_s
    while time.perf_counter() < t_end:
        if len(outstanding) >= window:
            for _ in range(window // 2):
                outstanding.popleft().result(timeout=300)
        try:
            outstanding.append(eng.submit(int(stream[n % len(stream)])))
            n += 1
        except BackpressureError:      # bounded queue: drain some, go on
            for _ in range(len(outstanding) // 2):
                outstanding.popleft().result(timeout=300)
    while outstanding:
        outstanding.popleft().result(timeout=300)
    return n


def _drive(eng: ServingEngine, stream: np.ndarray, *, duration_s: float,
           window: int = 1024, clients: int = 2) -> int:
    """``clients`` concurrent closed-loop drivers over disjoint slices
    of the stream. One python client thread saturates before the engine
    does once cache hits make service times ~free — submission cost
    would then cap measured QPS and understate a fast engine, so the
    load is generated from several threads, like real traffic."""
    from concurrent.futures import ThreadPoolExecutor
    with ThreadPoolExecutor(max_workers=clients,
                            thread_name_prefix="client") as pool:
        futs = [pool.submit(_drive_one, eng, stream[i::clients],
                            duration_s=duration_s, window=window // clients)
                for i in range(clients)]
        return sum(f.result() for f in futs)


def run_sustained(*, duration_s: float = 6.0, zipf_a: float = 1.1,
                  seed: int = 0, quick: bool = False) -> list[dict]:
    """Sustained-QPS comparison under zipfian traffic: baseline
    single-stage/unsharded/uncached engine vs the tier-2 engine
    (2 item shards, two-stage C=4, hot-user cache). Both run the SAME
    request stream for the same wall-clock window in jnp mode (CPU
    timing of interpret-mode pallas measures the interpreter, not the
    kernel — repo convention). Equal-recall is pinned separately by the
    serve_two_stage row's >= 0.99 recall-ratio assert.

    The store is a serving-scale synthetic catalog (the standard bench
    graph's 300 items make a full fp32 scan so cheap that any retrieval
    structure is pure overhead — the regime tier 2 targets is the one
    where the scan is the cost). At this size the tier-2 engine
    dequantizes < 10% of the catalog per miss and the zipf head lands
    in the cache."""
    rng = np.random.default_rng(seed + 23)
    n_users = 1024 if quick else 2048
    n_items = 4096 if quick else 8192
    dim = 128
    store = QuantizedEmbeddingStore.from_arrays(
        rng.normal(size=(n_users, dim)).astype(np.float32),
        rng.normal(size=(n_items, dim)).astype(np.float32),
        bits=8, quantize_users=False)
    exclude = None
    stream = _zipf_stream(n_users, 4096, a=zipf_a, seed=seed + 31)

    configs = {
        "baseline": dict(),
        "tier2": dict(item_shards=2, two_stage_c=4,
                      cache_size=n_users // 4),
    }
    rows = []
    for name, extra in configs.items():
        with ServingEngine(store, k=K, exclude=exclude, backend="jnp",
                           buckets=(1, 4, 16, 64), **extra) as eng:
            eng.warmup()
            _drive(eng, stream, duration_s=duration_s)
        st = eng.stats()
        row = {
            "op": "serve_sustained", "config": name,
            "mode": "jnp", "backend": "cpu", "bits": 8, "k": K,
            "n": n_items, "duration_s": duration_s, "zipf_a": zipf_a,
            "qps": st.qps, "p50_ms": st.p50_ms, "p99_ms": st.p99_ms,
            "cache_hit_rate": st.cache_hit_rate,
            "candidate_ratio": (
                float(eng._m_cand.value) if extra.get("two_stage_c")
                else 1.0),
            "n_requests": st.n_requests,
            "engine_label": eng.label,
        }
        if name == "tier2":
            row["qps_ratio"] = row["qps"] / rows[0]["qps"]
            # the acceptance bar is >= 1.5x (see committed BENCH rows,
            # regression-gated); assert a looser floor here so a broken
            # cache/drain path fails the bench itself without making it
            # flake on a noisy runner
            assert row["qps_ratio"] >= 1.2, \
                f"tier-2 engine no faster than baseline " \
                f"({row['qps_ratio']:.2f}x < 1.2x)"
        for metric in ("qps", "p50_ms", "p99_ms", "cache_hit_rate",
                       "candidate_ratio"):
            _mirror(f"serve/sustained_{metric}", row[metric], config=name)
        if "qps_ratio" in row:
            _mirror("serve/sustained_qps_ratio", row["qps_ratio"],
                    config=name)
        rows.append(row)
        print(f"[serve_bench] sustained/{name}: qps={row['qps']:.0f} "
              f"p50={row['p50_ms']:.2f}ms p99={row['p99_ms']:.2f}ms "
              f"cache={row['cache_hit_rate']:.0%} "
              f"cand={row['candidate_ratio']:.2f}"
              + (f" qps_ratio={row['qps_ratio']:.2f}x"
                 if "qps_ratio" in row else ""), flush=True)
    return rows


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sustained", action="store_true",
                    help="run only the sustained zipfian SLO comparison")
    ap.add_argument("--duration-s", type=float, default=6.0,
                    help="wall-clock window per engine config")
    ap.add_argument("--zipf-a", type=float, default=1.1,
                    help="zipf exponent of the user popularity law")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, metavar="ROWS.json",
                    help="also write the rows as JSON")
    args = ap.parse_args()

    if args.sustained:
        rows = run_sustained(duration_s=args.duration_s, zipf_a=args.zipf_a,
                             seed=args.seed, quick=args.quick)
    else:
        rows = run(requests=args.requests, seed=args.seed, quick=args.quick)
    from .check_regression import validate_bench_rows
    validate_bench_rows(rows)            # op/mode/backend schema, always
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"[serve_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
