"""Serving-subsystem benchmark: store bytes, QPS/latency, fused parity.

One row per store precision (fp32 / INT8 / INT4) on the standard
synthetic KG benchmark graph (KGAT rollout, dim 32 × 4-layer concat
readout = 128-dim representations):

  * ``store_bytes_ratio``   — fp32 bytes / packed bytes from
    ``memory_report()`` (deterministic, shape-derived; nightly-gated
    like every ``*_ratio`` via benchmarks/check_regression.py; the
    acceptance bar is INT8 >= 3.5x);
  * ``topk_jnp_us`` / ``topk_pallas_interp_us`` — chunked scorer wall
    time per batch, fused kernel vs jnp fallback (check_regression
    derives the speedup; report-only, interpret-mode timings are noise);
  * ``qps`` / ``p50_ms`` / ``p99_ms`` — micro-batching engine under a
    burst of single-user requests;
  * ``fused_jnp_bitexact`` — the fused/fallback parity contract,
    asserted (not just reported) while measuring;
  * ``stream_dense_max_diff`` — streaming evaluator vs the dense
    reference on the same store (exactness check, asserted <= 1e-6).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import backend as kbackend
from repro.models import kgnn
from repro.serving import (ServingEngine, build_kgnn_store,
                           padded_pos_lists, streaming_eval_dataset,
                           topk_scores)
from repro.training.metrics import recall_ndcg_at_k

from .common import dataset, make_cfg

K = 20
BATCH = 64          # scorer batch for the timing measurement


def _time_scorer(q, items, excl, backend, *, reps=3) -> float:
    out = topk_scores(q, items, K, exclude=excl, backend=backend)
    jax.block_until_ready(out)                       # compile outside timing
    t0 = time.perf_counter()
    for _ in range(reps):
        out = topk_scores(q, items, K, exclude=excl, backend=backend)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # us / batch


def run(*, requests: int = 200, seed: int = 0) -> list[dict]:
    ds = dataset(seed=seed)
    cfg = make_cfg("kgat", ds)
    params = kgnn.init_params(jax.random.PRNGKey(seed), cfg)
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    exclude = padded_pos_lists(ds.train_pos, ds.n_users)
    rng = np.random.default_rng(seed)
    uids = rng.integers(0, ds.n_users, BATCH)
    excl_b = jnp.asarray(exclude[uids])

    rows = []
    for bits in (None, 8, 4):
        store = build_kgnn_store(params, g, cfg, ds.n_items, bits=bits)
        mem = store.memory_report()
        q = store.user_vectors(jnp.asarray(uids))
        backend = "pallas" if bits is not None else "jnp"

        info = kbackend.probe_backend()
        row = {
            "op": "serve_topk", "model": "kgat",
            # fp32 stores score via plain jnp (no fused kernel involved)
            "mode": ("jnp" if bits is None
                     else kbackend.resolve_mode("auto", op="serve_topk")),
            "backend": info.platform,
            "bits": bits or "fp32", "dim": mem["dim"], "k": K,
            "store_total_bytes": mem["total_bytes"],
            "store_fp32_bytes": mem["fp32_bytes"],
            "store_bytes_ratio": round(mem["compression_ratio"], 4),
            "topk_jnp_us": _time_scorer(q, store.items, excl_b, "jnp"),
        }
        if bits is not None:
            row["topk_pallas_interp_us"] = _time_scorer(
                q, store.items, excl_b, "pallas")
            vf, xf = topk_scores(q, store.items, K, exclude=excl_b,
                                 backend="pallas")
            vj, xj = topk_scores(q, store.items, K, exclude=excl_b,
                                 backend="jnp")
            exact = bool(jnp.array_equal(vf, vj)) and \
                bool(jnp.array_equal(xf, xj))
            assert exact, "fused/fallback parity broken"
            row["fused_jnp_bitexact"] = exact

        with ServingEngine(store, k=K, exclude=exclude, backend=backend,
                           buckets=(1, 4, 16, 64)) as eng:
            eng.warmup()
            futs = [eng.submit(int(u))
                    for u in rng.integers(0, ds.n_users, requests)]
            for f in futs:
                f.result(timeout=300)
        st = eng.stats()
        row.update(qps=round(st.qps, 1), p50_ms=round(st.p50_ms, 3),
                   p99_ms=round(st.p99_ms, 3))

        # streaming evaluator vs dense reference ON THE SAME STORE
        r_s, n_s = streaming_eval_dataset(store, ds, k=K, backend=backend)
        reps_u = store.user_vectors(jnp.arange(ds.n_users))
        scores = reps_u @ store.item_matrix().T
        tr, te = ds.interaction_matrices()
        r_d, n_d = recall_ndcg_at_k(scores, jnp.asarray(te),
                                    jnp.asarray(tr), k=K)
        diff = max(abs(r_s - float(r_d)), abs(n_s - float(n_d)))
        assert diff <= 1e-6, f"streaming/dense eval diverged: {diff}"
        row.update({"recall@20": round(r_s, 4), "ndcg@20": round(n_s, 4),
                    "stream_dense_max_diff": diff})
        rows.append(row)
        print(f"[serve_bench] bits={row['bits']}: "
              f"bytes_ratio={row['store_bytes_ratio']} "
              f"qps={row['qps']} p99={row['p99_ms']}ms "
              f"stream|dense diff={diff:.1e}", flush=True)
    return rows
