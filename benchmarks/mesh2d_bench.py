"""2D data×model mesh bench: row-sharded table footprint + step time.

The ISSUE 8 acceptance bar, recorded as a nightly-gated row: on the 2D
mesh the entity table row-shards over the ``model`` axis, so (a) each
data shard assembles only the table rows its local edges touch — the
``table_rows_gathered_per_step_ratio`` (full padded table rows over
rows one device gathers per fetch, higher is better) must hold at the
``data`` extent — and (b) a ``data=1,model=16`` layout trains a KG
whose entity table is >= 8x a simulated per-device parameter budget
while the measured resident block (live ``addressable_shards`` bytes)
stays UNDER that budget (``table_bytes_over_resident_ratio``, higher
is better).

The nightly bench step runs ``python -m benchmarks.run --quick``
without forcing host devices, and the XLA device count locks at first
jax init — so ``run()`` re-execs this module in a child process with
16 forced devices and parses the JSON row it prints (same pattern as
tests/_subproc.py). Both gated ratios are deterministic geometry /
placement measurements; only ``step_ms`` varies with the runner.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

MESH_2D = "data=4,model=2"      # the gathered-rows leg (8 devices)
MESH_BUDGET = "data=1,model=16"  # the 8x-budget leg (16 devices)
DIM = 16
BATCH = 64
DATASET = dict(n_users=64, n_items=1500, n_attrs=500, seed=0)


def _child(steps: int) -> dict:
    import time

    import jax
    import numpy as np

    from repro.data.synthetic import gen_kg_dataset
    from repro.models.registry import build_step, kg_dp_spec
    from repro.sharding.mesh_spec import MeshSpec
    from repro.training import data_parallel as dp
    from repro.training.optimizer import adam

    ds = gen_kg_dataset(**DATASET)

    def train(mesh_str: str, n_steps: int):
        step = build_step("kgat", ds=ds, dim=DIM, n_layers=2,
                          batch_size=BATCH)
        spec = kg_dp_spec(step.cfg, step.data["graph"])
        ms = MeshSpec.parse(mesh_str)
        mesh = ms.build_sim()
        part = dp.partition_graph(step.data["graph"], mesh, axis="data")
        n_model = ms.extent("model")
        params = dp.pad_row_sharded(
            step.init(jax.random.PRNGKey(0)), spec, part, n_model)
        opt = adam(step.lr)
        ts = dp.make_dp_step(spec, part, mesh, opt,
                             root_key=jax.random.PRNGKey(1), mesh_spec=ms,
                             compress_grads=False)
        state = (params, opt.init(params))
        it = iter(step.batches())
        losses, t0 = [], None
        for i in range(n_steps):
            state, m = ts(state, next(it), i)
            losses.append(float(m["loss"]))
            if i == 0:           # exclude compile from the step timing
                jax.block_until_ready(state)
                t0 = time.perf_counter()
        jax.block_until_ready(state)
        step_ms = (time.perf_counter() - t0) / max(n_steps - 1, 1) * 1e3
        return step.cfg, part, state, losses, step_ms

    # leg 1 — data=4,model=2: each data shard gathers 1/4 of the padded
    # table per fetch_rows call (its dst block), not the full table
    cfg, part, state, losses, step_ms = train(MESH_2D, steps)
    gathered_ratio = part.n_nodes_padded / part.rows_per_shard

    # leg 2 — data=1,model=16: the >=8x-budget demonstration, resident
    # bytes measured from the live sharded entity table
    cfg_b, _, state_b, losses_b, _ = train(MESH_BUDGET, 4)
    table_bytes = cfg_b.n_nodes * cfg_b.dim * 4
    budget = table_bytes // 8
    ent = state_b[0]["entity"]
    resident = max(s.data.nbytes for s in ent.addressable_shards)
    assert resident <= budget, (resident, budget)
    assert all(np.isfinite(losses)) and all(np.isfinite(losses_b))

    return {
        "bench": "mesh2d",
        "op": "dp2d_step",
        "mode": "jnp",
        "backend": "cpu",
        "model": "kgat",
        "mesh": MESH_2D,
        "n_nodes": cfg.n_nodes,
        "dim": DIM,
        "batch": BATCH,
        "steps": steps,
        "table_rows_gathered_per_step_ratio": round(gathered_ratio, 3),
        "budget_mesh": MESH_BUDGET,
        "table_bytes": table_bytes,
        "device_budget_bytes": budget,
        "resident_bytes_per_device": int(resident),
        "table_bytes_over_resident_ratio": round(table_bytes / resident, 3),
        "step_ms": round(step_ms, 2),
        "loss_first": round(losses[0], 4),
        "loss_last": round(losses[-1], 4),
    }


def run(steps: int = 10) -> list:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo, "src"), repo,
                    env.get("PYTHONPATH")) if p)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.mesh2d_bench", "--child",
         str(steps)],
        env=env, capture_output=True, text=True, timeout=1200, cwd=repo)
    if out.returncode != 0:
        raise RuntimeError(f"mesh2d bench child failed:\n{out.stderr[-3000:]}")
    row = json.loads(out.stdout.strip().splitlines()[-1])
    print(f"  {row['mesh']}: gathered ratio "
          f"{row['table_rows_gathered_per_step_ratio']}x  "
          f"{row['budget_mesh']}: table {row['table_bytes']/2**20:.2f} MiB "
          f"vs budget {row['device_budget_bytes']/2**20:.2f} MiB/dev, "
          f"resident {row['resident_bytes_per_device']/2**20:.2f} MiB "
          f"({row['table_bytes_over_resident_ratio']}x)  "
          f"step {row['step_ms']:.1f} ms  "
          f"loss {row['loss_first']} -> {row['loss_last']}")
    return [row]


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        print(json.dumps(_child(int(sys.argv[2]))))
    else:
        print(run())
