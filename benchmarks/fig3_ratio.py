"""Paper Figure 3: sensitivity of INT2 TinyKG to the variance ratio d/B².

Proposition 1 bounds the quantizer variance by d·R²/(4B²): at fixed B,
accuracy degradation should scale gently with embedding dim d.
"""

from __future__ import annotations

from .common import train_kgnn

DIMS = (16, 32, 64, 96)


def run(*, steps=150, models=("kgat",)) -> list[dict]:
    rows = []
    for model in models:
        for d in DIMS:
            fp32 = train_kgnn(model, bits=None, steps=steps, dim=d)
            int2 = train_kgnn(model, bits=2, steps=steps, dim=d)
            drop = 100 * (fp32["recall@20"] - int2["recall@20"]) / \
                max(fp32["recall@20"], 1e-9)
            rows.append({
                "model": model, "dim": d, "ratio_d_B2": round(d / 9.0, 2),
                "recall_fp32": round(fp32["recall@20"], 4),
                "recall_int2": round(int2["recall@20"], 4),
                "rel_drop_%": round(drop, 2),
            })
            print(f"[fig3] {model} d={d}: fp32={fp32['recall@20']:.4f} "
                  f"int2={int2['recall@20']:.4f} drop={drop:.2f}%",
                  flush=True)
    return rows
