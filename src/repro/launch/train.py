"""Training launcher: ``--arch <id>`` + shape -> fault-tolerant train loop.

On real hardware the mesh comes from ``make_production_mesh``; on this CPU
host it builds a 1x1 mesh and runs the reduced config end-to-end (the full
configs are exercised via dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch kgat --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch fm --steps 50 --bits 4
  PYTHONPATH=src python -m repro.launch.train --arch kgat \
      --schedule first_layer_int8_rest_int2
  PYTHONPATH=src python -m repro.launch.train --arch kgat --mesh data=8

``--schedule`` takes a ``PolicySchedule`` spec (preset name, uniform
bit-width, or ordered ``[kind:]glob=bits`` rules — see
``repro.core.policy.parse_schedule``); each train step then runs inside an
``act_context`` so every op site resolves its own policy and
stochastic-rounding key (scope-hashed, replay-exact). ``--bits`` remains
the uniform fast path.

``--mesh data=N`` (KGAT only) runs the data-parallel shard_map path
(DESIGN.md §7): edges dst-partitioned over N shards, per-shard ACT-
compressed propagation, gradients all-reduced through the INT8
compressed psum (``--allreduce fp32`` for the exact baseline). On a CPU
host the N simulated devices are forced automatically — provided no jax
call has initialized the backend first.
"""

from __future__ import annotations

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get
from repro.configs.smoke import reduced
from repro.core import act_context
from repro.core.policy import PolicySchedule, schedule_from_cli
from repro.training.optimizer import adam
from repro.training.trainer import Trainer, TrainerConfig


def _parse_mesh(spec: str) -> tuple[str, int]:
    """``"data=8"`` -> ``("data", 8)``."""
    try:
        axis, n = spec.split("=")
        return axis, int(n)
    except ValueError:
        raise SystemExit(f"--mesh expects AXIS=N (e.g. data=8), got {spec!r}")


def _force_host_devices(n: int) -> None:
    """Request ``n`` simulated CPU devices — only effective before the
    first jax call initializes the backend (``make_sim_mesh`` raises the
    honest error with the manual fix if it is too late)."""
    cur = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in cur:
        os.environ["XLA_FLAGS"] = \
            (cur + f" --xla_force_host_platform_device_count={n}").strip()


def _kgat_dp_job(arch, schedule: PolicySchedule, args):
    """--mesh data=N: the shard_map data-parallel path (DESIGN.md §7)."""
    from repro.data.synthetic import bpr_batches, gen_kg_dataset
    from repro.models import kgnn
    from repro.sharding.compat import make_sim_mesh
    from repro.training import data_parallel as dp

    axis, n = _parse_mesh(args.mesh)
    mesh = make_sim_mesh(n, (axis,))
    ds = gen_kg_dataset(n_users=120, n_items=200, n_attrs=80, seed=0)
    cfg = kgnn.KGNNConfig(
        model="kgat", n_users=ds.n_users, n_entities=ds.n_entities,
        n_relations=ds.n_relations, dim=32, n_layers=3, readout="concat")
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    part = dp.partition_graph(g, mesh, axis=axis)
    params = kgnn.init_params(jax.random.PRNGKey(0), cfg)
    opt = adam(3e-3)
    train_step = dp.make_kgat_dp_step(
        cfg, part, mesh, opt, schedule=schedule,
        root_key=jax.random.PRNGKey(1), axis=axis,
        compress_grads=args.allreduce == "int8")

    def data():
        for b in bpr_batches(ds, 512, seed=2):
            yield jax.tree_util.tree_map(jnp.asarray, b)

    print(f"[train] data-parallel kgat: mesh {axis}={n}, "
          f"allreduce={args.allreduce}, "
          f"edges/shard≤{part.e_cap}, halo/shard≤{part.h_cap}")
    return train_step, (params, opt.init(params)), data()


def _kgnn_job(arch, schedule: PolicySchedule, args):
    from repro.data.csr import maybe_attach_layout
    from repro.data.synthetic import bpr_batches, gen_kg_dataset
    from repro.models import kgnn
    if args.mesh:
        if arch.model_cfg.model != "kgat":
            raise SystemExit("--mesh is implemented for --arch kgat")
        return _kgat_dp_job(arch, schedule, args)
    ds = gen_kg_dataset(n_users=120, n_items=200, n_attrs=80, seed=0)
    cfg = kgnn.KGNNConfig(
        model=arch.model_cfg.model, n_users=ds.n_users,
        n_entities=ds.n_entities, n_relations=ds.n_relations,
        dim=32, n_layers=3,
        readout="concat" if arch.model_cfg.model == "kgat" else "sum")
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    g = maybe_attach_layout(g, schedule, model=cfg.model)
    params = kgnn.init_params(jax.random.PRNGKey(0), cfg)
    opt = adam(3e-3)
    root = jax.random.PRNGKey(1)

    @jax.jit
    def train_step(state, batch, step):
        params, opt_state = state

        def loss_fn(p):
            with act_context(schedule, root, step=step):
                return kgnn.bpr_loss(p, g, batch, cfg)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), {"loss": loss}

    def data():
        for b in bpr_batches(ds, 512, seed=2):
            yield jax.tree_util.tree_map(jnp.asarray, b)

    return train_step, (params, opt.init(params)), data()


def _lm_job(arch, schedule: PolicySchedule, args):
    from repro.data.synthetic import lm_batches
    from repro.models import transformer as tf
    cfg = reduced(arch).model_cfg
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adam(1e-3)
    root = jax.random.PRNGKey(1)

    @jax.jit
    def train_step(state, batch, step):
        params, opt_state = state

        def loss_fn(p):
            with act_context(schedule, root, step=step):
                return tf.lm_loss(p, batch, cfg=cfg)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), {"loss": loss}

    def data():
        for b in lm_batches(vocab=cfg.vocab, batch=8, seq=64, seed=0):
            yield {"tokens": jnp.asarray(b["tokens"])}

    return train_step, (params, opt.init(params)), data()


def _recsys_job(arch, schedule: PolicySchedule, args):
    from repro.data.synthetic import criteo_batches
    from repro.models import recsys
    cfg = reduced(arch).model_cfg
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    opt = adam(1e-3)
    root = jax.random.PRNGKey(1)

    @jax.jit
    def train_step(state, batch, step):
        params, opt_state = state

        def loss_fn(p):
            with act_context(schedule, root, step=step):
                logits = recsys.forward(p, batch, cfg)
            lab = batch["label"]
            return -jnp.mean(lab * jax.nn.log_sigmoid(logits)
                             + (1 - lab) * jax.nn.log_sigmoid(-logits))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), {"loss": loss}

    def data():
        for b in criteo_batches(batch=256, n_dense=max(cfg.n_dense, 1),
                                vocab_sizes=cfg.vocab_sizes, seed=3):
            yield jax.tree_util.tree_map(jnp.asarray, b)

    return train_step, (params, opt.init(params)), data()


def _gnn_job(arch, schedule: PolicySchedule, args):
    from repro.data.csr import build_spmm_layout
    from repro.data.synthetic import cora_like
    from repro.models import gnn
    cfg = reduced(arch).model_cfg
    feats, src, dst, labels = cora_like(n_nodes=300, d_feat=cfg.d_in)
    x, s, d, y = map(jnp.asarray, (feats, src, dst, labels))
    layout = build_spmm_layout(src, dst, n_dst=300) \
        if schedule.kernel == "pallas" else None
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    opt = adam(1e-2)
    root = jax.random.PRNGKey(1)

    @jax.jit
    def train_step(state, batch, step):
        params, opt_state = state

        def loss_fn(p):
            with act_context(schedule, root, step=step):
                logits = gnn.gcn_forward(p, x, s, d, n_nodes=300, cfg=cfg,
                                         layout=layout)
            oh = jax.nn.one_hot(y, cfg.n_classes)
            return -jnp.mean(jnp.sum(oh * jax.nn.log_softmax(logits), -1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), {"loss": loss}

    def data():
        while True:
            yield {}

    return train_step, (params, opt.init(params)), data()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--bits", type=int, default=2, help="0 = FP32 baseline")
    ap.add_argument("--schedule", default=None,
                    help="PolicySchedule spec (preset | intN/fp32 | "
                         "'[kind:]glob=bits,...'); overrides --bits")
    ap.add_argument("--kernel", default="jnp", choices=["jnp", "pallas"],
                    help="ACT backend: jnp reference or fused Pallas kernels")
    ap.add_argument("--mesh", default=None,
                    help="AXIS=N, e.g. data=8: shard_map data-parallel "
                         "training on a simulated N-device mesh (kgat)")
    ap.add_argument("--allreduce", default="int8", choices=["int8", "fp32"],
                    help="gradient all-reduce wire format on the --mesh "
                         "path (int8 = compressed SR psum)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    if args.mesh:
        # must precede every jax call: the device count locks at first init
        _force_host_devices(_parse_mesh(args.mesh)[1])
    arch = get(args.arch)
    if args.mesh and arch.family != "kgnn":
        raise SystemExit("--mesh (shard_map data parallelism) is "
                         "implemented for the kgnn family (--arch kgat)")
    schedule = schedule_from_cli(args.schedule, args.bits, kernel=args.kernel)

    job = {
        "kgnn": _kgnn_job, "lm": _lm_job, "moe_lm": _lm_job,
        "recsys": _recsys_job, "gnn": _gnn_job,
    }[arch.family]
    train_step, state, data = job(arch, schedule, args)
    n = sum(x.size for x in jax.tree_util.tree_leaves(state[0]))
    print(f"[train] {args.arch} ({arch.family}) {n/1e6:.2f}M params "
          f"schedule={args.schedule or ('fp32' if not args.bits else f'int{args.bits}')}")
    cfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt or tempfile.mkdtemp(prefix="repro_ckpt_"),
        ckpt_every=max(args.steps // 4, 10), log_every=max(args.steps // 8, 5))
    trainer = Trainer(train_step, state, data, cfg).restore_if_available()
    trainer.run()
    losses = [h["loss"] for h in trainer.history]
    print(f"[train] done; loss {losses[0]:.4f} -> {losses[-1]:.4f}"
          if losses else "[train] done")


if __name__ == "__main__":
    main()
