"""Training launcher: ``--arch <id>`` + shape -> fault-tolerant train loop.

Every arch resolves through the model-step registry
(``repro.models.registry.build_step``, DESIGN.md §9) to ONE ``ModelStep``
— there is no per-family job wiring here anymore. On real hardware the
mesh comes from ``make_production_mesh``; on this CPU host it builds a
1x1 mesh and runs the reduced config end-to-end (the full configs are
exercised via dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch kgat --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch fm --steps 50 --bits 4
  PYTHONPATH=src python -m repro.launch.train --arch kgat \
      --schedule first_layer_int8_rest_int2
  PYTHONPATH=src python -m repro.launch.train --arch kgin --mesh data=8

``--schedule`` takes a ``PolicySchedule`` spec (preset name, uniform
bit-width, or ordered ``[kind:]glob=bits`` rules — see
``repro.core.policy.parse_schedule``); each train step then runs inside an
``act_context`` so every op site resolves its own policy and
stochastic-rounding key (scope-hashed, replay-exact). ``--bits`` remains
the uniform fast path.

``--mesh`` takes a ``MeshSpec`` layout (``sharding/mesh_spec.py`` —
the one parser shared with dryrun and ``make_dp_step``) and runs the
shard_map path (DESIGN.md §7, §12) for EVERY arch whose step registers
a ``ShardSpec`` — all KG archs (kgat, kgcn, kgin):

  * ``--mesh data=N`` — 1D data parallelism: edges dst-partitioned
    over N shards, params replicated, per-shard ACT-compressed
    propagation through the same ``propagate_view`` layer math as the
    single-device step, gradients all-reduced through the INT8
    compressed psum (``--allreduce fp32`` for the exact baseline).
  * ``--mesh data=N,model=M`` — the 2D data×model mesh: additionally
    row-shards the embedding tables the step's placement marks
    (entity) over M model shards, so each device holds 1/M of the
    dominant table; gradients reduce per-axis.

Archs without a ShardSpec (lm / recsys / gcn) fail fast with the
reason. On a CPU host the N×M simulated devices are forced
automatically — provided no jax call has initialized the backend first.

Checkpoints carry the run identity (arch id + schedule spec + mesh
topology + table placement): restoring from a directory written by a
different arch, schedule or mesh layout is refused instead of silently
resuming the wrong run. ``--reshard-from <dir>`` is the explicit
migration hatch: it restores a checkpoint IGNORING its mesh layout
(arch/schedule still checked) and re-pads the row-sharded tables onto
the current layout.
"""

from __future__ import annotations

import argparse
import itertools
import os
import tempfile

import jax

from repro.configs import ARCHS, get
from repro.core.policy import schedule_from_cli, schedule_label
from repro.obs import StepLogWriter, get_tracer, log, write_summary
from repro.training.optimizer import adam
from repro.training.step import make_train_step, step_metadata
from repro.training.trainer import Trainer, TrainerConfig


def _parse_mesh(spec: str):
    """``--mesh`` string -> validated ``MeshSpec`` (SystemExit on junk)."""
    from repro.sharding.mesh_spec import MeshSpec, MeshSpecError

    try:
        return MeshSpec.parse(spec).check_axes(("data", "model"),
                                               required=("data",))
    except MeshSpecError as e:
        raise SystemExit(f"--mesh: {e}")


def _force_host_devices(n: int) -> None:
    """Request ``n`` simulated CPU devices — only effective before the
    first jax call initializes the backend (``make_sim_mesh`` raises the
    honest error with the manual fix if it is too late)."""
    cur = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in cur:
        os.environ["XLA_FLAGS"] = \
            (cur + f" --xla_force_host_platform_device_count={n}").strip()


def _dp_train_step(step, mesh_spec, args, opt, root_key, schedule):
    """--mesh: the generic shard_map path (1D data / 2D data×model)."""
    from repro.training import data_parallel as dp

    mesh = mesh_spec.build_sim()
    part = dp.partition_graph(step.dp_spec.graph, mesh, axis="data")
    train_step = dp.make_dp_step(
        step, part, mesh, opt, schedule=schedule, root_key=root_key,
        mesh_spec=mesh_spec, compress_grads=args.allreduce == "int8")
    tables = ""
    if "model" in mesh_spec.names:
        tables = (f", row-sharded [{step.dp_spec.placement_str()}] over "
                  f"model={mesh_spec.extent('model')}")
    log(f"[train] data-parallel {step.arch}: mesh {mesh_spec}, "
        f"allreduce={args.allreduce}, "
        f"edges/shard≤{part.e_cap}, halo/shard≤{part.h_cap}{tables}")
    return train_step, part, mesh


def _run_sampled(arch, args, schedule, schedule_spec) -> None:
    """--sample: minibatch KG training through the tiered row store."""
    from repro.data.minibatch import parse_fanouts
    from repro.models.registry import build_step
    from repro.training import tiering

    try:
        fanouts = parse_fanouts(args.sample)
    except ValueError as e:
        raise SystemExit(f"error: {e}")
    kwargs = {"n_layers": len(fanouts)} if arch.family == "kgnn" else {}
    step = build_step(arch, schedule=schedule, **kwargs)
    log(f"[train] sampled {args.arch} ({arch.family}) "
        f"fanouts={fanouts} hot_frac={args.hot_frac} "
        f"schedule={schedule_spec}")
    try:
        report, _, store = tiering.run_sampled_training(
            step, fanouts=fanouts, steps=args.steps,
            batch_size=args.batch, hot_frac=args.hot_frac,
            schedule=schedule, root_key=jax.random.PRNGKey(1),
            init_key=jax.random.PRNGKey(0), log_fn=log)
    except ValueError as e:
        raise SystemExit(f"error: {e}")
    print(f"[train] done; loss {report.losses[0]:.4f} -> "
          f"{report.losses[-1]:.4f}  hit-rate {report.hit_rate:.2%}  "
          f"rows/step {report.rows_transferred_per_step:.0f}  "
          f"hot-tier {report.store_device_bytes/2**20:.2f} MiB of "
          f"{report.table_bytes/2**20:.2f} MiB table")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--bits", type=int, default=2, help="0 = FP32 baseline")
    ap.add_argument("--schedule", default=None,
                    help="PolicySchedule spec (preset | intN/fp32 | "
                         "'[kind:]glob=bits,...'); overrides --bits")
    ap.add_argument("--kernel", default="jnp", choices=["jnp", "pallas"],
                    help="ACT backend: jnp reference or fused Pallas kernels")
    ap.add_argument("--mesh", default=None,
                    help="MeshSpec layout, e.g. data=8 (1D data-parallel) "
                         "or data=4,model=2 (2D mesh with row-sharded "
                         "tables) on simulated devices (any arch with a "
                         "registered ShardSpec — kgat, kgcn, kgin)")
    ap.add_argument("--allreduce", default="int8", choices=["int8", "fp32"],
                    help="gradient all-reduce wire format on the --mesh "
                         "path (int8 = compressed SR psum)")
    ap.add_argument("--sample", default=None,
                    help="fanout=F1,F2,...: neighbor-sampled minibatch "
                         "training with hot/cold embedding tiering (KG "
                         "archs; one fanout per layer, seed-adjacent "
                         "first), e.g. --sample fanout=15,10")
    ap.add_argument("--hot-frac", type=float, default=0.1,
                    help="--sample: fraction of entity rows kept device-"
                         "resident (frequency-ranked hot tier)")
    ap.add_argument("--batch", type=int, default=256,
                    help="--sample: BPR batch size per sampled step")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome-trace/Perfetto JSON of the run's "
                         "host spans (train/step/... nesting; on TPU also "
                         "brackets StepTraceAnnotation)")
    ap.add_argument("--metrics-out", default=None, metavar="DIR",
                    help="write steps.jsonl (per-step timeline) and the "
                         "schema-validated summary.json registry snapshot "
                         "under DIR")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--reshard-from", default=None, metavar="DIR",
                    help="restore this checkpoint dir IGNORING its mesh "
                         "layout (arch/schedule still checked) and re-pad "
                         "row-sharded tables onto the current --mesh — the "
                         "explicit 1D->2D migration hatch")
    args = ap.parse_args()
    if args.sample and args.mesh:
        from repro.training.data_parallel import check_no_sampled_dp
        try:
            check_no_sampled_dp(args.sample, mesh_spec=args.mesh)
        except NotImplementedError as e:
            raise SystemExit(f"error: {e}")
    mesh_spec = _parse_mesh(args.mesh) if args.mesh else None
    if mesh_spec is not None:
        # must precede every jax call: the device count locks at first init
        _force_host_devices(mesh_spec.size)
    arch = get(args.arch)
    schedule = schedule_from_cli(args.schedule, args.bits, kernel=args.kernel)
    schedule_spec = schedule_label(args.schedule, args.bits)

    from repro.models.registry import build_step

    if args.trace:
        get_tracer().enable()
    run = {"kind": "train", "arch": args.arch, "family": arch.family,
           "schedule": schedule_spec, "steps": args.steps,
           "mesh": str(mesh_spec) if mesh_spec is not None else None,
           "sample": args.sample}

    if args.sample:
        _run_sampled(arch, args, schedule, schedule_spec)
        _finish_telemetry(args, run)
        return
    step = build_step(arch, schedule=schedule)
    opt = adam(step.lr)
    root = jax.random.PRNGKey(1)
    part = None
    if mesh_spec is not None:
        if step.dp_spec is None:
            raise SystemExit(
                f"--mesh: data parallelism is not implemented for --arch "
                f"{args.arch} ({arch.family}): {step.dp_unsupported}")
        train_step, part, _ = _dp_train_step(step, mesh_spec, args, opt,
                                             root, schedule)
    else:
        train_step = make_train_step(step, opt, schedule=schedule,
                                     root_key=root)
    n_model = (mesh_spec.extent("model")
               if mesh_spec is not None and "model" in mesh_spec.names
               else None)
    placement = (step.dp_spec.placement_str() if n_model is not None
                 else None)
    params = step.init(jax.random.PRNGKey(0))
    if args.reshard_from:
        from repro.training import data_parallel as dp
        from repro.training.checkpoint import restore_checkpoint

        # template + expected meta deliberately carry NO mesh/placement:
        # resharding reads the source layout-agnostically (row tables at
        # their real row count), then re-pads for the current layout.
        template = (params, opt.init(params))
        rstep, state = restore_checkpoint(args.reshard_from, template,
                                          expect_meta=step_metadata(
                                              step, schedule_spec))
        if rstep is None:
            raise SystemExit(f"--reshard-from: no checkpoint found under "
                             f"{args.reshard_from}")
        # the template has no shardings, so restore committed every leaf
        # to one device; gather to host (uncommitted) so the train step's
        # shard_map is free to lay the tree out on the new mesh
        import numpy as np
        state = jax.tree_util.tree_map(np.asarray, state)
        if n_model is not None:
            state = dp.pad_row_sharded(state, step.dp_spec, part, n_model)
        log(f"[train] resharded checkpoint step {rstep} from "
            f"{args.reshard_from} onto mesh "
            f"{mesh_spec if mesh_spec is not None else '1 device'}")
    else:
        if n_model is not None:
            from repro.training import data_parallel as dp

            params = dp.pad_row_sharded(params, step.dp_spec, part, n_model)
        state = (params, opt.init(params))

    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    log(f"[train] {args.arch} ({arch.family}) {n/1e6:.2f}M params "
        f"schedule={schedule_spec}")
    data_iter = step.batches()
    step_writer = None
    if args.metrics_out:
        step_writer = StepLogWriter(os.path.join(args.metrics_out,
                                                 "steps.jsonl"))
        if mesh_spec is None:
            # Table-5 pricing of THIS run's loss trace: peek the first
            # batch, price the residuals the compressed ops would save
            # (eval_shape — no FLOPs), publish as act/* gauges, and stamp
            # the total onto every step line so steps.jsonl doubles as
            # the activation-bytes timeline. Mesh runs skip it: per-shard
            # residual shapes live inside the shard_map body.
            from repro.core.memory import (publish_activation_report,
                                           traced_activation_report)

            first = next(data_iter)
            data_iter = itertools.chain([first], data_iter)
            act = traced_activation_report(step.loss, params, first,
                                           schedule=schedule, key=root)
            publish_activation_report(act)
            step_writer.extras["act_total_bytes"] = act["total_bytes"]
            log(f"[train] activation footprint "
                f"{act['total_bytes']/2**20:.2f} MiB "
                f"({act['compression_ratio']:.1f}x vs fp32)")
    n_edges = (int(step.dp_spec.graph.src.shape[0])
               if step.dp_spec is not None else None)
    cfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt or tempfile.mkdtemp(prefix="repro_ckpt_"),
        ckpt_every=max(args.steps // 4, 10), log_every=max(args.steps // 8, 5))
    trainer = Trainer(train_step, state, data_iter, cfg,
                      ckpt_meta=step_metadata(step, schedule_spec,
                                              mesh_spec=mesh_spec,
                                              placement=placement),
                      step_writer=step_writer, items_per_step=n_edges
                      ).restore_if_available()
    try:
        trainer.run()
    finally:
        if step_writer is not None:
            step_writer.close()
    _finish_telemetry(args, run)
    losses = [h["loss"] for h in trainer.history]
    print(f"[train] done; loss {losses[0]:.4f} -> {losses[-1]:.4f}"
          if losses else "[train] done")


def _finish_telemetry(args, run: dict) -> None:
    """End-of-run artifacts: Perfetto trace and/or summary snapshot."""
    if args.trace:
        path = get_tracer().save(args.trace, run=run)
        log(f"[train] trace written to {path}")
    if args.metrics_out:
        path = write_summary(args.metrics_out, run)
        log(f"[train] metrics summary written to {path}")


if __name__ == "__main__":
    main()
