import math
import os
import sys


def _early_device_count() -> int:
    """512 covers both production meshes; ``--sim NxM`` forces only what
    the simulated mesh needs (parsed pre-argparse: the device count locks
    at first jax init, before main() runs). Handles both the space and
    ``--sim=NxM`` spellings argparse accepts."""
    shape = None
    for i, arg in enumerate(sys.argv):
        if arg == "--sim" and i + 1 < len(sys.argv):
            shape = sys.argv[i + 1]
        elif arg.startswith("--sim="):
            shape = arg.split("=", 1)[1]
    if shape is not None:
        try:
            return math.prod(int(s) for s in shape.split("x"))
        except ValueError:
            return 8
    return 512


# append rather than overwrite/setdefault: unrelated user XLA_FLAGS must
# survive, and an existing device-count forcing must win
_FLAGS = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _FLAGS:
    os.environ["XLA_FLAGS"] = (
        _FLAGS
        + f" --xla_force_host_platform_device_count={_early_device_count()}"
    ).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The lines above MUST run before any other import (jax locks the device
count at first init). 512 host devices cover both the 16×16 single-pod
mesh (first 256) and the 2×16×16 multi-pod mesh; ``--sim 2x4`` dry-runs
the same cells on a laptop-sized simulated mesh via the
``make_production_mesh(sim=...)`` escape hatch.

Per cell this records: memory_analysis (proves it fits), cost_analysis,
and the trip-count-corrected roofline terms parsed from the partitioned
HLO (launch/roofline.py). Artifacts land in ``artifacts/dryrun/`` as JSON
— EXPERIMENTS.md §Dry-run/§Roofline/§Perf are generated from them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch fm --shape train_batch
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --bits 2
"""

import argparse
import contextlib
import json
import re
import time
import traceback

import jax

from repro.configs import ARCHS, ASSIGNED, get
from repro.core import act_context
from repro.core.policy import parse_schedule, policy_for_bits
from repro.launch.mesh import make_production_mesh
from repro.launch.partition import build_cell
from repro.launch.roofline import (HW, HW_PROFILES, get_hw, parse_hlo,
                                   roofline_terms)
from repro.sharding.mesh_spec import MeshSpec


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             bits: int | None, out_dir: str, verbose: bool = True,
             schedule: str | None = None,
             sim: "tuple | MeshSpec | None" = None, hw: dict = HW) -> dict:
    # route bare extents through the shared MeshSpec type so a wrong
    # extent count fails with the same named error as --mesh parsing
    if sim is not None and not isinstance(sim, MeshSpec):
        sim = MeshSpec.from_shape(
            sim, ("pod", "data", "model") if multi_pod
            else ("data", "model"))
    mesh = make_production_mesh(multi_pod=multi_pod, sim=sim)
    n_dev = mesh.devices.size
    arch = get(arch_name)
    # With --schedule, the cell is lowered inside an ambient act_context
    # (policy=None rides down to the models, which resolve per-site); the
    # uniform --bits path keeps passing the explicit policy.
    if schedule is not None:
        policy = None
        cm = act_context(parse_schedule(schedule), jax.random.PRNGKey(0))
    else:
        policy = policy_for_bits(bits)
        cm = contextlib.nullcontext()
    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        # --schedule overrides --bits; never attribute a mixed-schedule
        # cell's numbers to a uniform bit-width in the artifact
        "bits": None if schedule is not None else bits,
        "schedule": schedule, "n_devices": n_dev,
    }
    t0 = time.time()
    try:
        with cm:
            cell = build_cell(arch, shape_name, mesh, policy=policy)
            lowered = cell.lower(mesh)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gb": ma.argument_size_in_bytes / 2**30,
            "output_gb": ma.output_size_in_bytes / 2**30,
            "temp_gb": ma.temp_size_in_bytes / 2**30,
            "alias_gb": ma.alias_size_in_bytes / 2**30,
            "peak_gb": (ma.argument_size_in_bytes
                        + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes
                        - ma.alias_size_in_bytes) / 2**30,
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # JAX 0.4.x: one dict per device
            ca = ca[0] if ca else {}
        rec["cost_analysis"] = {
            "flops": ca.get("flops"),
            "bytes_accessed": ca.get("bytes accessed"),
            "note": "XLA counts while bodies once; see roofline.*",
        }
        stats = parse_hlo(compiled.as_text(), n_devices=n_dev)
        rec["roofline"] = roofline_terms(stats, hw=hw)
        if verbose:
            m = rec["memory"]
            r = rec["roofline"]
            print(f"[dryrun] {arch_name}/{shape_name} mesh={rec['mesh']} "
                  f"bits={bits}: compile {rec['compile_s']}s | "
                  f"peak {m['peak_gb']:.2f} GB/dev | "
                  f"compute {r['compute_s']*1e3:.2f}ms "
                  f"memory {r['memory_s']*1e3:.2f}ms "
                  f"collective {r['collective_s']*1e3:.2f}ms "
                  f"-> {r['dominant']}", flush=True)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch_name}/{shape_name} FAILED: {rec['error']}",
                  flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        if schedule is not None:  # distinct artifact per schedule spec
            suffix = "s" + re.sub(r"[^A-Za-z0-9._-]", "_", schedule)
        else:
            suffix = f"b{bits}"
        tag = f"{arch_name}__{shape_name}__{rec['mesh']}__{suffix}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=float)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="single arch id (default: all assigned)")
    ap.add_argument("--shape", default=None,
                    help="single shape name (default: all for the arch)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--bits", type=int, default=2,
                    help="ACT bit-width (0 = FP32 baseline)")
    ap.add_argument("--schedule", default=None,
                    help="PolicySchedule spec (preset | intN/fp32 | rules); "
                         "overrides --bits, lowers cells under act_context")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--include-kgnn", action="store_true",
                    help="also dry-run the paper's KGAT/KGCN/KGIN at "
                         "Amazon-Book scale")
    ap.add_argument("--sim", default=None,
                    help="simulated mesh extents 'DxM' (or 'PxDxM' with "
                         "--multi-pod), e.g. --sim 2x4 — lowers the same "
                         "cells without 512 host devices")
    ap.add_argument("--hw", default="tpu-v5e",
                    choices=sorted(HW_PROFILES),
                    help="hardware profile for the roofline denominators")
    args = ap.parse_args()
    hw = get_hw(args.hw)
    sim = tuple(int(s) for s in args.sim.split("x")) if args.sim else None
    if sim is not None and args.both_meshes:
        # sim extents can match only one of the two axis layouts; the
        # other leg would die outside run_cell's try, discarding results
        raise SystemExit("--sim fixes one mesh layout; drop --both-meshes "
                         "and pass --multi-pod explicitly if wanted")
    bits = args.bits if args.bits else None

    arch_names = [args.arch] if args.arch else list(ASSIGNED)
    if args.include_kgnn and not args.arch:
        arch_names += ["kgat", "kgcn", "kgin"]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    for mp in meshes:
        for an in arch_names:
            arch = ARCHS[an]
            shape_names = [args.shape] if args.shape else \
                [s.name for s in arch.shapes]
            for sn in shape_names:
                results.append(run_cell(an, sn, multi_pod=mp, bits=bits,
                                        out_dir=args.out,
                                        schedule=args.schedule, sim=sim,
                                        hw=hw))
    ok = sum(r["ok"] for r in results)
    print(f"[dryrun] {ok}/{len(results)} cells compiled "
          f"(hw {args.hw}: {hw['peak_flops']/1e12:.0f} TF/s, "
          f"{hw['hbm_bw']/1e9:.0f} GB/s HBM, {hw['ici_bw']/1e9:.0f} GB/s ICI)")
    if ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
