"""Serving launcher: prefill + batched decode for LM archs, batched
scoring for recsys archs, and the quantized retrieval engine for the
paper's KGNNs (reduced configs on this CPU host).

  PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b --tokens 32
  PYTHONPATH=src python -m repro.launch.serve --arch dlrm-mlperf --requests 20
  PYTHONPATH=src python -m repro.launch.serve --arch kgat --bits 8

The KGNN path is the full serving subsystem (DESIGN.md §8 + tier-2
§14): offline rollout into a packed ``QuantizedEmbeddingStore`` at
``--bits``, the fused dequant·score·top-K scorer, the micro-batching
engine (QPS + latency percentiles), two-stage quantized retrieval,
item-sharded scoring, the hot-user cache, incremental refresh, and the
streaming full-ranking evaluator checked against the dense reference.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get
from repro.configs.smoke import reduced
from repro.obs import get_tracer, log, write_summary


def serve_lm(arch, args) -> None:
    from repro.models import transformer as tf
    cfg = reduced(arch).model_cfg
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    B = args.batch
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0, cfg.vocab)
    cache = tf.init_cache(cfg, B, 16 + args.tokens)
    prefill = jax.jit(tf.prefill, static_argnames="cfg")
    decode = jax.jit(tf.decode_step, static_argnames="cfg")

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompt, cfg=cfg, cache=cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = decode(params, cache, toks, cfg)
        toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(out[-1])
    dt = time.perf_counter() - t0
    seq = jnp.concatenate(out, 1)
    print(f"[serve] {arch.name}: prefill({B}x16) {t_prefill*1e3:.1f}ms | "
          f"{args.tokens-1} decode steps {dt*1e3:.1f}ms "
          f"({dt/(args.tokens-1)*1e3:.2f} ms/tok/batch)")
    print(f"[serve] sample tokens: {np.asarray(seq[0, :12])}")


def serve_recsys(arch, args) -> None:
    from repro.models import recsys
    cfg = reduced(arch).model_cfg
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def score(params, batch):
        return recsys.forward(params, batch, cfg, key=None)

    rng = np.random.default_rng(0)

    def request(n):
        return {"sparse": jnp.asarray(rng.integers(
                    0, min(cfg.vocab_sizes), (n, cfg.n_sparse)), jnp.int32),
                "dense": jnp.asarray(rng.normal(
                    size=(n, max(cfg.n_dense, 1))), jnp.float32)}

    score(params, request(args.batch)).block_until_ready()
    lat = []
    for _ in range(args.requests):
        b = request(args.batch)
        t0 = time.perf_counter()
        score(params, b).block_until_ready()
        lat.append((time.perf_counter() - t0) * 1e3)
    lat = np.sort(lat)
    print(f"[serve] {arch.name}: batch={args.batch} "
          f"p50={lat[len(lat)//2]:.2f}ms p99={lat[-max(len(lat)//100,1)]:.2f}ms")


def serve_kgnn(arch, args) -> None:
    from repro.data.synthetic import bpr_batches, gen_kg_dataset
    from repro.models import kgnn
    from repro.serving import (ServingEngine, build_kgnn_store,
                               padded_pos_lists, streaming_eval_dataset)
    from repro.training.metrics import recall_ndcg_at_k
    from repro.training.optimizer import adam

    cfg = reduced(arch).model_cfg
    # synthetic CKG sized to the reduced config's node/relation space
    ds = gen_kg_dataset(n_users=cfg.n_users, n_items=cfg.n_entities * 3 // 5,
                        n_attrs=cfg.n_entities - cfg.n_entities * 3 // 5,
                        n_relations=(cfg.n_relations - 2) // 2,
                        n_triples=400, inter_per_user=8, seed=0)
    g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
    params = kgnn.init_params(jax.random.PRNGKey(0), cfg)

    opt = adam(5e-3)
    opt_state = opt.init(params)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: kgnn.bpr_loss(p, g, batch, cfg))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    it = bpr_batches(ds, 128, seed=0)
    if args.train_steps:
        for _ in range(args.train_steps):
            b = jax.tree_util.tree_map(jnp.asarray, next(it))
            params, opt_state, loss = train_step(params, opt_state, b)
        print(f"[serve] rollout after {args.train_steps} BPR steps "
              f"(loss {float(loss):.4f})")

    bits = None if args.bits == "fp32" else int(args.bits)
    store = build_kgnn_store(params, g, cfg, ds.n_items, bits=bits)
    mem = store.memory_report()
    print(f"[serve] store: bits={args.bits} "
          f"{mem['total_bytes']} B total "
          f"({mem['packed_bytes']} packed + {mem['scale_zero_bytes']} "
          f"scale/zero) vs {mem['fp32_bytes']} B fp32 — "
          f"{mem['compression_ratio']:.2f}x")

    k = min(args.k, ds.n_items)
    exclude = padded_pos_lists(ds.train_pos, ds.n_users)
    backend = "pallas" if bits is not None else "jnp"
    two_stage = args.two_stage if (args.two_stage and bits is not None) else None
    if args.two_stage and bits is None:
        print("[serve] --two-stage needs a packed store; ignored at fp32")
    rng = np.random.default_rng(0)

    def burst(eng, n):
        futs = [eng.submit(int(u))
                for u in rng.integers(0, ds.n_users, n)]
        return [f.result(timeout=120) for f in futs]

    with ServingEngine(store, k=k, exclude=exclude, backend=backend,
                       buckets=(1, 2, 4, 8), two_stage_c=two_stage,
                       item_shards=args.item_shards, cache_size=args.cache,
                       max_pending=args.max_pending) as eng:
        eng.warmup()
        results = burst(eng, args.requests)
        print(f"[serve] {arch.name}: {eng.stats()}")
        print(f"[serve] sample top-{min(k, 10)}: {results[0][1][:10]}")

        if args.refresh_steps:
            # keep training, re-roll the store, and hot-swap it via delta
            # refresh while the engine stays up — then serve again
            for _ in range(args.refresh_steps):
                b = jax.tree_util.tree_map(jnp.asarray, next(it))
                params, opt_state, loss = train_step(params, opt_state, b)
            new_store = build_kgnn_store(params, g, cfg, ds.n_items,
                                         bits=bits)
            d = eng.refresh(new_store).result(timeout=300)
            print(f"[serve] refresh v{d['version']}: "
                  f"{d['rows_changed']}/{d['rows_total']} rows changed "
                  f"({d['changed_frac']:.1%}), {d['delta_bytes']} delta B")
            burst(eng, args.requests)
            print(f"[serve] post-refresh: {eng.stats()}")
            store = eng.store              # eval the live (refreshed) table

    # streaming full-ranking eval vs the dense reference
    r_s, n_s = streaming_eval_dataset(store, ds, k=k, backend=backend)
    reps_u = store.user_vectors(jnp.arange(ds.n_users))
    scores = reps_u @ store.item_matrix().T
    tr, te = ds.interaction_matrices()
    r_d, n_d = recall_ndcg_at_k(scores, jnp.asarray(te), jnp.asarray(tr), k=k)
    print(f"[serve] streaming eval recall@{k}={r_s:.4f} ndcg@{k}={n_s:.4f} "
          f"| dense reference {float(r_d):.4f}/{float(n_d):.4f} "
          f"(|Δ| {max(abs(r_s - float(r_d)), abs(n_s - float(n_d))):.2e})")


_EPILOG = """\
serving tier 2 (kgnn archs — DESIGN.md §14)
-------------------------------------------
The engine composes four independent features; each has a flag and all
of them can be stacked:

  --two-stage C     two-stage retrieval: coarse scan in the packed
                    INT8/INT4 domain keeps C*k candidates, only those
                    are dequantized for the fp32 re-rank. C=4 recovers
                    >=0.99x single-stage recall@20 on the bench graphs
                    while scanning >=90%% of items packed-only.
  --item-shards S   row-split the item table into S shards scored in
                    parallel and host-merged (bit-identical ranking;
                    deterministic tie-break — see scorer.merge_topk).
  --cache N         hot-user LRU of N results, version-stamped and
                    invalidated on refresh.
  --max-pending N   bounded submit queue; overload raises the named
                    BackpressureError instead of buffering forever.
  --refresh-steps T after the first burst, train T more BPR steps,
                    re-roll the store, and hot-swap it atomically via
                    delta refresh (only changed rows ship), then serve
                    another burst from the new version.

examples:
  # two-stage + 2 shards + hot-user cache, metered:
  python -m repro.launch.serve --arch kgat --bits 8 --two-stage 4 \\
      --item-shards 2 --cache 64 --metrics-out runs/serve
  # live refresh mid-serving (30 initial + 30 more steps):
  python -m repro.launch.serve --arch kgat --bits 8 --refresh-steps 30
"""


def main() -> None:
    ap = argparse.ArgumentParser(
        epilog=_EPILOG, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--bits", default="8", choices=["8", "4", "fp32"],
                    help="KGNN store precision (kgnn archs only)")
    ap.add_argument("--k", type=int, default=20,
                    help="top-K size for KGNN retrieval")
    ap.add_argument("--train-steps", type=int, default=30,
                    help="quick BPR steps before the serving rollout")
    ap.add_argument("--two-stage", type=int, default=None, metavar="C",
                    help="two-stage retrieval: coarse-scan packed codes, "
                         "re-rank C*k candidates in fp32 (kgnn, packed "
                         "stores only)")
    ap.add_argument("--item-shards", type=int, default=1, metavar="S",
                    help="score S item shards in parallel, host-merge "
                         "(bit-identical to single-shard)")
    ap.add_argument("--cache", type=int, default=0, metavar="N",
                    help="hot-user result cache capacity (0 = off)")
    ap.add_argument("--max-pending", type=int, default=1024, metavar="N",
                    help="submit-queue bound; full queue raises "
                         "BackpressureError")
    ap.add_argument("--refresh-steps", type=int, default=0, metavar="T",
                    help="after the first burst, train T more steps and "
                         "hot-swap the store via delta refresh")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome-trace/Perfetto JSON of the host "
                         "spans (serve/batch drains etc.)")
    ap.add_argument("--metrics-out", default=None, metavar="DIR",
                    help="write the schema-validated summary.json registry "
                         "snapshot (serve/latency_ms, queue depth, ...) "
                         "under DIR")
    args = ap.parse_args()
    arch = get(args.arch)
    if args.trace:
        get_tracer().enable()
    if arch.family in ("lm", "moe_lm"):
        serve_lm(arch, args)
    elif arch.family == "recsys":
        serve_recsys(arch, args)
    elif arch.family == "kgnn":
        serve_kgnn(arch, args)
    else:
        raise SystemExit(f"{arch.family} has no serve path "
                         "(GNNs are training workloads)")
    run = {"kind": "serve", "arch": args.arch, "family": arch.family,
           "requests": args.requests, "bits": args.bits}
    if args.trace:
        log(f"[serve] trace written to "
            f"{get_tracer().save(args.trace, run=run)}")
    if args.metrics_out:
        log(f"[serve] metrics summary written to "
            f"{write_summary(args.metrics_out, run)}")


if __name__ == "__main__":
    main()
