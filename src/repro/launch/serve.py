"""Serving launcher: prefill + batched decode for LM archs, batched
scoring for recsys archs (reduced configs on this CPU host).

  PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b --tokens 32
  PYTHONPATH=src python -m repro.launch.serve --arch dlrm-mlperf --requests 20
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get
from repro.configs.smoke import reduced


def serve_lm(arch, args) -> None:
    from repro.models import transformer as tf
    cfg = reduced(arch).model_cfg
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    B = args.batch
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0, cfg.vocab)
    cache = tf.init_cache(cfg, B, 16 + args.tokens)
    prefill = jax.jit(tf.prefill, static_argnames="cfg")
    decode = jax.jit(tf.decode_step, static_argnames="cfg")

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompt, cfg=cfg, cache=cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = decode(params, cache, toks, cfg)
        toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(out[-1])
    dt = time.perf_counter() - t0
    seq = jnp.concatenate(out, 1)
    print(f"[serve] {arch.name}: prefill({B}x16) {t_prefill*1e3:.1f}ms | "
          f"{args.tokens-1} decode steps {dt*1e3:.1f}ms "
          f"({dt/(args.tokens-1)*1e3:.2f} ms/tok/batch)")
    print(f"[serve] sample tokens: {np.asarray(seq[0, :12])}")


def serve_recsys(arch, args) -> None:
    from repro.models import recsys
    cfg = reduced(arch).model_cfg
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def score(params, batch):
        return recsys.forward(params, batch, cfg, key=None)

    rng = np.random.default_rng(0)

    def request(n):
        return {"sparse": jnp.asarray(rng.integers(
                    0, min(cfg.vocab_sizes), (n, cfg.n_sparse)), jnp.int32),
                "dense": jnp.asarray(rng.normal(
                    size=(n, max(cfg.n_dense, 1))), jnp.float32)}

    score(params, request(args.batch)).block_until_ready()
    lat = []
    for _ in range(args.requests):
        b = request(args.batch)
        t0 = time.perf_counter()
        score(params, b).block_until_ready()
        lat.append((time.perf_counter() - t0) * 1e3)
    lat = np.sort(lat)
    print(f"[serve] {arch.name}: batch={args.batch} "
          f"p50={lat[len(lat)//2]:.2f}ms p99={lat[-max(len(lat)//100,1)]:.2f}ms")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=20)
    args = ap.parse_args()
    arch = get(args.arch)
    if arch.family in ("lm", "moe_lm"):
        serve_lm(arch, args)
    elif arch.family == "recsys":
        serve_recsys(arch, args)
    else:
        raise SystemExit(f"{arch.family} has no serve path "
                         "(GNN/KGNN are training workloads)")


if __name__ == "__main__":
    main()
