"""Roofline-term extraction from compiled (SPMD-partitioned) HLO.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE, so a scan-over-88-
layers model would look 88× cheaper than it is. This module re-derives the
three roofline terms from ``compiled.as_text()`` with loop-trip-count
multipliers:

  * computations are parsed into blocks; ``while`` ops carry
    ``known_trip_count`` in backend_config — multipliers propagate through
    nested scans (layer scan × attention chunk scan)
  * compute term     : Σ dot-op FLOPs (2·M·N·K) × multiplier
  * memory term      : Σ op result bytes × 2 (read+write proxy) ×
    multiplier, skipping tuple/GTE/parameter/constant plumbing and
    fusion-internal ops (fused intermediates stay in registers/VMEM)
  * collective term  : per-kind byte model over result shapes:
      all-reduce      2·S·(G-1)/G     (ring: reduce-scatter + all-gather)
      all-gather      S·(G-1)/G
      reduce-scatter  S·(G-1)         (operand = G · result)
      all-to-all      S·(G-1)/G
      collective-permute  S

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""

from __future__ import annotations

import dataclasses
import json
import re

__all__ = ["HW", "HW_PROFILES", "get_hw", "parse_hlo", "roofline_terms",
           "HLOStats"]

# Hardware profiles for the roofline denominator. "tpu-v5e" is the
# production target (per the assignment); "a100" lets the same terms be
# sanity-checked against the paper's GPU numbers; "host" is a deliberately
# conservative envelope for the CPU CI container so measured-attainment
# percentages stay meaningful (not 0.001%) on interpret-mode runs.
HW_PROFILES = {
    "tpu-v5e": {
        "peak_flops": 197e12,   # bf16 per chip
        "hbm_bw": 819e9,        # bytes/s per chip
        "ici_bw": 50e9,         # bytes/s per link
    },
    "a100": {
        "peak_flops": 312e12,   # bf16 tensor-core, 80GB SXM
        "hbm_bw": 2039e9,       # HBM2e
        "ici_bw": 300e9,        # NVLink3 aggregate per direction
    },
    "host": {
        "peak_flops": 0.2e12,   # few-core AVX2 envelope
        "hbm_bw": 20e9,         # DDR4 single-socket envelope
        "ici_bw": 5e9,          # loopback/PCIe stand-in
    },
}


def get_hw(name: str) -> dict:
    """Resolve a ``--hw`` profile name; KeyError lists the choices."""
    try:
        return HW_PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown hw profile {name!r}; "
                       f"choose from {sorted(HW_PROFILES)}") from None


HW = HW_PROFILES["tpu-v5e"]  # back-compat default (dryrun, report)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1,
    "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_OPS = ("parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id",
             # plumbing whose "result" is not HBM traffic: a while's result
             # signature is the whole carried state; copies of carried
             # tuples are XLA-CPU artifacts
             "while", "conditional", "copy", "call")


def _shape_bytes(sig: str) -> int:
    """Total bytes of all array shapes appearing in a result signature."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(sig: str) -> list[list[int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt in _DTYPE_BYTES:
            out.append([int(d) for d in dims.split(",") if d])
    return out


@dataclasses.dataclass
class HLOStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_kind: dict = dataclasses.field(default_factory=dict)
    n_collectives: int = 0


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", stripped)
        if m and not stripped.startswith("ROOT"):
            cur = m.group(1)
            if stripped.startswith("ENTRY") or line.startswith("ENTRY"):
                cur = "ENTRY"
            comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _group_size(line: str, n_devices: int) -> int:
    # replica_groups=[8,4]<=[...] => 8 groups of 4; or explicit {{0,1},{2,3}}
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _line_result_sig(line: str) -> str:
    # "%name = f32[8,128]{1,0} op(...)" or "%n = (f32[...], u8[...]) op(...)"
    m = re.match(r"(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+(.*)",
                 line)
    return m.group(1) if m else ""


def _line_op(line: str) -> str:
    m = re.match(r"(?:ROOT\s+)?%[\w.\-]+\s*=\s*(?:\([^)]*\)|[^ ]+)\s+"
                 r"([\w\-]+)\(", line)
    return m.group(1) if m else ""


def _dot_flops(line: str, symbols: dict[str, str]) -> float:
    """2·prod(result)·prod(contracted lhs dims).

    Operands may be printed as bare names (``dot(%a, %b)``) — resolve their
    shapes through the per-computation symbol table.
    """
    res_sig = _line_result_sig(line)
    res_dims = _shape_dims(res_sig)
    if not res_dims:
        return 0.0
    out_n = 1
    for d in res_dims[0]:
        out_n *= d
    m = re.search(r"dot\((.*?)\)", line)
    operand_sig = m.group(1) if m else ""
    op_dims = _shape_dims(operand_sig)
    if not op_dims:  # bare operand names: resolve the lhs via symbols
        names = re.findall(r"%([\w.\-]+)", operand_sig)
        if names and names[0] in symbols:
            op_dims = _shape_dims(symbols[names[0]])
    lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    k = 1
    if lc and op_dims:
        lhs = op_dims[0]
        for idx in lc.group(1).split(","):
            if idx:
                k *= lhs[int(idx)]
    return 2.0 * out_n * k


def parse_hlo(text: str, *, n_devices: int) -> HLOStats:
    comps = _split_computations(text)

    # pass 1: which computations are while bodies/conds and their trip counts
    multipliers = {name: 0.0 for name in comps}
    multipliers["ENTRY"] = 1.0
    # build (caller -> [(callee, trip)]) from while ops
    calls: dict[str, list[tuple[str, float]]] = {n: [] for n in comps}
    fusion_bodies: set[str] = set()
    for name, lines in comps.items():
        for line in lines:
            if " while(" in line:
                body = re.search(r"body=%?([\w.\-]+)", line)
                cond = re.search(r"condition=%?([\w.\-]+)", line)
                trip = re.search(r'known_trip_count[="{:\s]+n["\s:]+"?(\d+)',
                                 line)
                t = float(trip.group(1)) if trip else 1.0
                if body:
                    calls[name].append((body.group(1), t))
                if cond:
                    calls[name].append((cond.group(1), t))
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
            if m and " while(" not in line:
                fusion_bodies.add(m.group(1))

    # propagate multipliers from ENTRY (iterate to fixpoint over DAG);
    # also record each body's own trip count (for in-place stack writes)
    own_trip = {n: 1.0 for n in comps}
    changed = True
    while changed:
        changed = False
        for caller, edges in calls.items():
            cm = multipliers.get(caller, 0.0)
            if cm <= 0:
                continue
            for callee, trip in edges:
                newm = cm * trip
                if callee in multipliers and multipliers[callee] < newm:
                    multipliers[callee] = newm
                    own_trip[callee] = trip
                    changed = True

    stats = HLOStats()
    for name, lines in comps.items():
        mult = multipliers.get(name, 0.0)
        if mult <= 0 or name in fusion_bodies:
            continue
        symbols = {}
        for line in lines:
            m = re.match(r"(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
                         r"(\([^)]*\)|[^ ]+)\s+", line)
            if m:
                symbols[m.group(1)] = m.group(2)
        for line in lines:
            op = _line_op(line)
            if not op:
                continue
            sig = _line_result_sig(line)
            nbytes = _shape_bytes(sig)
            if op in ("dot", "convolution"):
                stats.flops += mult * _dot_flops(line, symbols)
            if op not in _SKIP_OPS:
                eff = nbytes
                if op == "dynamic-update-slice":
                    # in-place slice write: charge the update operand only
                    names = re.findall(r"%([\w.\-]+)",
                                       line.split("(", 1)[-1])
                    if len(names) >= 2 and names[1] in symbols:
                        eff = _shape_bytes(symbols[names[1]])
                elif "output_to_operand_aliasing" in line:
                    # aliased in-place fusion (scan stacking): the written
                    # slice is 1/trip of the buffer per iteration
                    eff = nbytes / max(own_trip.get(name, 1.0), 1.0)
                stats.hbm_bytes += mult * 2.0 * eff
            for kind in _COLLECTIVES:
                if op == kind or op.startswith(kind + "-start"):
                    g = _group_size(line, n_devices)
                    if kind == "all-reduce":
                        moved = 2.0 * nbytes * (g - 1) / max(g, 1)
                    elif kind == "all-gather":
                        moved = nbytes * (g - 1) / max(g, 1)
                    elif kind == "reduce-scatter":
                        moved = nbytes * (g - 1)
                    elif kind == "all-to-all":
                        moved = nbytes * (g - 1) / max(g, 1)
                    else:
                        moved = nbytes
                    stats.collective_bytes += mult * moved
                    stats.per_kind[kind] = stats.per_kind.get(kind, 0.0) \
                        + mult * moved
                    stats.n_collectives += 1
                    break
    return stats


def roofline_terms(stats: HLOStats, *, model_flops_per_device: float = 0.0,
                   hw: dict = HW) -> dict:
    """The three per-device roofline terms in seconds + the bottleneck."""
    compute_s = stats.flops / hw["peak_flops"]
    memory_s = stats.hbm_bytes / hw["hbm_bw"]
    collective_s = stats.collective_bytes / hw["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    useful = (model_flops_per_device / stats.flops
              if stats.flops > 0 and model_flops_per_device else None)
    bound = max(terms.values())
    return {
        **terms,
        "dominant": dominant,
        "hlo_flops": stats.flops,
        "hlo_bytes": stats.hbm_bytes,
        "collective_bytes": stats.collective_bytes,
        "per_kind": stats.per_kind,
        "model_flops_ratio": useful,
        "roofline_fraction": (compute_s / bound) if bound > 0 else None,
    }


def dump(obj, path: str) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
