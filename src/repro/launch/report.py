"""Generate the EXPERIMENTS.md roofline tables from dry-run artifacts.

  PYTHONPATH=src python -m repro.launch.report \
      --baseline artifacts/dryrun --optimized artifacts/dryrun_opt
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_dir(d: str) -> dict:
    out = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(p))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.2f}ms"


def roofline_table(recs: dict, mesh: str) -> list[str]:
    lines = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "peak GB/dev | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh or not r.get("ok"):
            continue
        rf = r["roofline"]
        mem = r["memory"]["peak_gb"]
        frac = rf.get("roofline_fraction")
        lines.append(
            f"| {arch} | {shape} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['dominant'].replace('_s','')} | {mem:.2f} | "
            f"{'' if frac is None else f'{100*frac:.1f}%'} |")
    return lines


def metrics_table(summary: dict) -> list[str]:
    """Render a run's ``summary.json`` (repro.obs.sinks) as markdown.

    Counters and gauges get one row each; histograms render their
    count and p50/p95/p99 — the table EXPERIMENTS.md embeds next to the
    roofline numbers for telemetry-bearing runs.
    """
    run = summary.get("run", {})
    ident = " ".join(f"{k}={v}" for k, v in sorted(run.items())
                     if v is not None)
    lines = [f"run: `{ident}`" if ident else "run: `?`", "",
             "| metric | type | value | p50 | p95 | p99 |",
             "|---|---|---|---|---|---|"]
    for k, v in sorted(summary.get("counters", {}).items()):
        lines.append(f"| {k} | counter | {v:g} | - | - | - |")
    for k, v in sorted(summary.get("gauges", {}).items()):
        lines.append(f"| {k} | gauge | {v:g} | - | - | - |")
    for k, h in sorted(summary.get("histograms", {}).items()):
        lines.append(f"| {k} | histogram | n={h['count']} | "
                     f"{h['p50']:.3g} | {h['p95']:.3g} | {h['p99']:.3g} |")
    return lines


def dryrun_table(recs: dict) -> list[str]:
    lines = [
        "| arch | shape | mesh | compile | peak GB/dev | arg GB | status |",
        "|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), r in sorted(recs.items()):
        if r.get("ok"):
            lines.append(
                f"| {arch} | {shape} | {m} | {r['compile_s']}s | "
                f"{r['memory']['peak_gb']:.2f} | "
                f"{r['memory']['argument_gb']:.2f} | ok |")
        else:
            lines.append(f"| {arch} | {shape} | {m} | - | - | - | "
                         f"FAIL: {r.get('error','?')[:60]} |")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="artifacts/dryrun")
    ap.add_argument("--optimized", default="artifacts/dryrun_opt")
    ap.add_argument("--out", default="artifacts/report.md")
    ap.add_argument("--metrics", default=None, metavar="SUMMARY.json",
                    help="also render a telemetry summary.json "
                         "(launch --metrics-out) as a metrics table")
    args = ap.parse_args()
    base = load_dir(args.baseline)
    opt = load_dir(args.optimized)

    parts = ["## Dry-run (optimized framework, both meshes)\n"]
    parts += dryrun_table(opt)
    parts.append("\n## Roofline — single-pod 16x16, optimized\n")
    parts += roofline_table(opt, "16x16")
    parts.append("\n## Roofline — multi-pod 2x16x16, optimized\n")
    parts += roofline_table(opt, "2x16x16")
    parts.append("\n## Baseline (paper-faithful, pre-§Perf) single-pod\n")
    parts += roofline_table(base, "16x16")
    if args.metrics:
        from repro.obs import validate_summary

        summary = json.load(open(args.metrics))
        validate_summary(summary)
        parts.append("\n## Run telemetry\n")
        parts += metrics_table(summary)
    with open(args.out, "w") as f:
        f.write("\n".join(parts) + "\n")
    print("wrote", args.out, f"({len(opt)} optimized, {len(base)} baseline "
          f"cells)")


if __name__ == "__main__":
    main()
