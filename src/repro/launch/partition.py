"""Per-family partitioning: param specs, input specs, step builders.

This is the distribution layer the dry-run (and a real launch) consumes:
for every (arch × shape) it yields a jittable step function plus
ShapeDtypeStruct arguments carrying NamedShardings — lower/compile without
allocating anything.

Sharding schemes (see DESIGN.md §5):
  LM train     : FSDP(+TP) — weights sharded (batch-axes × model), activations
                 batch-sharded, scan-over-layers
  LM serve     : TP (model axis); 123B/314B use 2D weight sharding
                 (`serve_weight_2d`) so bf16 weights fit the chip set
  decode cache : batch over data; sequence over model (context parallelism;
                 long_500k uses every axis for the 500k-token cache)
  GNN          : edge/node row sharding over batch axes, replicated weights
  RecSys       : embedding rows over ALL axes (DLRM hybrid parallelism),
                 MLPs data-parallel
  KGNN (paper) : entity table rows + edges over batch axes
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ArchSpec, ShapeSpec
from repro.core.policy import ACTPolicy, INT2
from repro.sharding.compat import P
from repro.sharding.logical import axis_rules
from repro.training.optimizer import adam

from .mesh import batch_axes

__all__ = ["build_cell", "Cell", "lm_rules_for"]


@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch × shape × mesh) combination."""
    arch: ArchSpec
    shape: ShapeSpec
    step_fn: Callable
    args: tuple          # ShapeDtypeStructs (with shardings)
    donate: tuple = ()
    rules: dict | None = None
    meta: dict | None = None

    def lower(self, mesh):
        ctx = axis_rules(mesh, self.rules or {})
        with mesh, ctx:
            return jax.jit(self.step_fn,
                           donate_argnums=self.donate).lower(*self.args)


def _ru(n: int, m: int = 512) -> int:
    """Round up to a mesh-divisible size (input/edge padding — the same
    padding a production pipeline applies to keep shapes static)."""
    return -(-n // m) * m


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _shape_tree(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _apply_specs(shapes, specs, mesh):
    return jax.tree_util.tree_map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def lm_rules_for(mesh, cfg, *, shape_kind: str, b1: bool = False) -> dict:
    """Logical-axis rules specialized per arch/shape (kv-head divisibility,
    long-context cache sharding, single-sample batches)."""
    msize = mesh.shape["model"]
    batch = batch_axes(mesh)
    ep = cfg.moe is not None and cfg.moe.n_experts % msize == 0
    rules = {
        "batch": batch,
        # Megatron sequence parallelism: the residual stream between blocks
        # shards seq over `model` — row-parallel all-reduces decompose into
        # reduce-scatter(+all-gather at the next consumer), and block-level
        # ACT residuals shrink by the model-axis size
        "seq": "model" if shape_kind in ("train", "prefill") else None,
        "embed": None,
        "heads": "model" if cfg.n_heads % msize == 0 else None,
        "kv_heads": "model" if cfg.n_kv_heads % msize == 0 else None,
        # EP: the expert dim owns the model axis, expert-internal ff stays
        # local; TP (few wide experts / dense): shard ff over model
        "ff": None if ep else "model",
        "vocab": "model",
        "expert": "model" if ep else None,
        "cache_seq": "model",
    }
    if shape_kind == "decode" and b1:
        # batch=1 long-context: throw every axis at the KV cache sequence
        rules["batch"] = None
        rules["cache_seq"] = batch + ("model",)
    return rules


def _lm_param_specs(cfg, mesh, *, two_d: bool):
    """two_d: additionally shard over the batch axes (FSDP / 2D-serve)."""
    msize = mesh.shape["model"]
    fsdp = batch_axes(mesh) if two_d else None
    kvshard = "model" if cfg.n_kv_heads % msize == 0 else None
    ep = cfg.moe is not None and cfg.moe.n_experts % msize == 0

    def spec(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        nd = len(leaf.shape)
        if name == "emb":
            return P("model", fsdp)
        if name == "head":
            return P(fsdp, "model")
        if "ln" in name:
            return P(*([None] * nd))
        if "router" in name:
            return P(None, fsdp, None)
        if "moe" in name and nd == 4:      # (L, E, a, b)
            if ep:
                return P(None, "model", fsdp, None)
            if "w_down" in name:
                return P(None, None, "model", fsdp)
            return P(None, None, fsdp, "model")
        if nd == 3:                        # (L, a, b) dense block weights
            if "wo" in name or "w_down" in name:
                return P(None, "model", fsdp)
            if "wk" in name or "wv" in name:
                return P(None, fsdp, kvshard)
            return P(None, fsdp, "model")
        return P(*([None] * nd))

    from repro.models import transformer as tf
    shapes = jax.eval_shape(lambda k: tf.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = jax.tree_util.tree_map_with_path(spec, shapes)
    return shapes, specs


def _lm_cell(arch: ArchSpec, shape: ShapeSpec, mesh,
             policy: ACTPolicy) -> Cell:
    from repro.models import transformer as tf
    cfg = arch.model_cfg
    p = shape.p()
    kind = shape.kind
    batch = batch_axes(mesh)
    rules = lm_rules_for(mesh, cfg, shape_kind=kind,
                         b1=p.get("global_batch") == 1)
    if cfg.moe is not None:
        # bind MoE dispatch groups to the data-shard count so every
        # sort/scatter stays device-local (see models/moe.py)
        nb = 1
        for a in batch:
            nb *= mesh.shape[a]
        tokens = p["global_batch"] * (p["seq_len"] if kind in
                                      ("train", "prefill") else 1)
        groups = nb if tokens % nb == 0 and tokens >= nb else 1
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_groups=groups))

    if kind == "train":
        two_d = True  # FSDP always for train
        shapes, specs = _lm_param_specs(cfg, mesh, two_d=two_d)
        params = _apply_specs(shapes, specs, mesh)
        opt = adam(3e-4)
        opt_shapes = jax.eval_shape(opt.init, shapes)
        opt_sh = {
            "step": _sds((), jnp.int32, mesh, P()),
            "mu": _apply_specs(opt_shapes["mu"], specs, mesh),
            "nu": _apply_specs(opt_shapes["nu"], specs, mesh),
        }
        gb, seq = p["global_batch"], p["seq_len"]
        tokens = _sds((gb, seq + 1), jnp.int32, mesh, P(batch, None))
        key = _sds((2,), jnp.uint32, mesh, P(None))

        def train_step(state, batch_, key_):
            params_, opt_state = state
            loss, grads = jax.value_and_grad(tf.lm_loss)(
                params_, batch_, cfg=cfg, policy=policy, key=key_)
            new_params, new_opt = opt.update(grads, opt_state, params_)
            return (new_params, new_opt), {"loss": loss}

        return Cell(arch, shape, train_step,
                    ((params, opt_sh), {"tokens": tokens}, key),
                    donate=(0,), rules=rules)

    two_d = arch.serve_weight_2d
    # int8 KV cache on serve shapes (beyond-paper: the paper's quantizer
    # applied to the serving path — halves cache HBM vs bf16)
    cfg = dataclasses.replace(cfg, kv_cache_bits=8)
    shapes, specs = _lm_param_specs(cfg, mesh, two_d=two_d)
    params = _apply_specs(shapes, specs, mesh)
    gb, seq = p["global_batch"], p["seq_len"]
    cache_shapes = _shape_tree(
        jax.eval_shape(lambda: tf.init_cache(cfg, gb, seq)))
    cache = jax.tree_util.tree_map(
        lambda s: _sds(
            s.shape, s.dtype, mesh,
            P(None, rules["batch"], rules["cache_seq"], None, None)
            if len(s.shape) == 5 else P()),
        cache_shapes)

    if kind == "prefill":
        tokens = _sds((gb, seq), jnp.int32, mesh, P(rules["batch"], None))

        def prefill_step(params_, tokens_, cache_):
            return tf.prefill(params_, tokens_, cfg, cache_)

        return Cell(arch, shape, prefill_step, (params, tokens, cache),
                    donate=(2,), rules=rules)

    # decode: one new token against a seq_len cache
    tokens = _sds((gb, 1), jnp.int32, mesh, P(rules["batch"], None))

    def decode(params_, cache_, tokens_):
        return tf.decode_step(params_, cache_, tokens_, cfg)

    return Cell(arch, shape, decode, (params, cache, tokens),
                donate=(1,), rules=rules)


# ---------------------------------------------------------------------------
# GNN family (gcn-cora)
# ---------------------------------------------------------------------------


def _gnn_cell(arch: ArchSpec, shape: ShapeSpec, mesh,
              policy: ACTPolicy) -> Cell:
    from repro.models import gnn
    cfg = arch.model_cfg
    p = shape.p()
    batch = batch_axes(mesh)
    rules = {"batch": batch}
    opt = adam(1e-2)

    shapes = jax.eval_shape(lambda k: gnn.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    rep = jax.tree_util.tree_map(
        lambda s: _sds(s.shape, s.dtype, mesh, P(*([None] * len(s.shape)))),
        shapes)
    opt_shapes = jax.eval_shape(opt.init, shapes)
    opt_sh = jax.tree_util.tree_map(
        lambda s: _sds(s.shape, s.dtype, mesh, P(*([None] * len(s.shape)))),
        opt_shapes)
    key = _sds((2,), jnp.uint32, mesh, P(None))

    if shape.kind == "full_graph":
        # self-loops are appended to the edge list by the data pipeline;
        # node/edge counts pad up to mesh-divisible sizes (isolated pad
        # nodes / self-loop pad edges are semantically inert)
        n, e, d = _ru(p["n_nodes"]), _ru(p["n_edges"] + p["n_nodes"]), \
            p["d_feat"]
        cfg = dataclasses.replace(cfg, d_in=d,
                                  n_classes=p.get("n_classes",
                                                  cfg.n_classes))
        shapes = jax.eval_shape(lambda k: gnn.init_params(k, cfg),
                                jax.random.PRNGKey(0))
        rep = jax.tree_util.tree_map(
            lambda s: _sds(s.shape, s.dtype, mesh,
                           P(*([None] * len(s.shape)))), shapes)
        opt_sh = jax.tree_util.tree_map(
            lambda s: _sds(s.shape, s.dtype, mesh,
                           P(*([None] * len(s.shape)))),
            jax.eval_shape(opt.init, shapes))
        x = _sds((n, d), jnp.float32, mesh, P(batch, None))
        src = _sds((e,), jnp.int32, mesh, P(batch))
        dst = _sds((e,), jnp.int32, mesh, P(batch))
        deg = _sds((n,), jnp.float32, mesh, P(batch))
        labels = _sds((n,), jnp.int32, mesh, P(batch))

        def train_step(state, x_, src_, dst_, deg_, labels_, key_):
            params_, opt_state = state

            def loss_fn(pp):
                # shard_map path: dst-partitioned edges, local scatter
                # (hillclimb #3 iter 3; GSPMD gcn_forward is the baseline)
                logits = gnn.gcn_forward_spmd(
                    pp, x_, src_, dst_, deg_, mesh=mesh, axes=batch,
                    cfg=cfg, policy=policy, key=key_)
                onehot = jax.nn.one_hot(labels_, cfg.n_classes)
                return -jnp.mean(jnp.sum(
                    onehot * jax.nn.log_softmax(logits), -1))

            loss, grads = jax.value_and_grad(loss_fn)(params_)
            new_p, new_o = opt.update(grads, opt_state, params_)
            return (new_p, new_o), {"loss": loss}

        return Cell(arch, shape, train_step,
                    ((rep, opt_sh), x, src, dst, deg, labels, key),
                    donate=(0,), rules=rules)

    if shape.kind == "minibatch":
        seeds = p["batch_nodes"]
        fanouts = list(p["fanouts"])
        d_feat = 602  # reddit-scale features (232,965 nodes / 114M edges)
        blocks = []
        # build outermost-first static block shapes
        sizes = [seeds]
        for f in fanouts:
            sizes.append(sizes[-1] * (f + 1))
        # sizes = [1024, 1024*16, 1024*16*11] for fanouts (15, 10)
        sizes = sizes[::-1]
        for i in range(len(fanouts)):
            n_src_b, n_dst_b = sizes[i], sizes[i + 1]
            f = list(reversed(fanouts))[i]
            e_b = n_dst_b * (f + 1)
            blocks.append({
                "src": _sds((e_b,), jnp.int32, mesh, P(batch)),
                "dst": _sds((e_b,), jnp.int32, mesh, P(batch)),
                "n_src": n_src_b, "n_dst": n_dst_b,
            })
        x = _sds((sizes[0], d_feat), jnp.float32, mesh, P(batch, None))
        labels = _sds((seeds,), jnp.int32, mesh, P(batch))
        cfg_mb = dataclasses.replace(cfg, d_in=d_feat, n_classes=41)

        def train_step(state, x_, b0_src, b0_dst, b1_src, b1_dst, labels_,
                       key_):
            params_, opt_state = state
            jb = [
                {"src": b0_src, "dst": b0_dst,
                 "n_src": blocks[0]["n_src"], "n_dst": blocks[0]["n_dst"]},
                {"src": b1_src, "dst": b1_dst,
                 "n_src": blocks[1]["n_src"], "n_dst": blocks[1]["n_dst"]},
            ]

            def loss_fn(pp):
                logits = gnn.gcn_forward_blocks(pp, x_, jb, cfg=cfg_mb,
                                                policy=policy, key=key_)
                onehot = jax.nn.one_hot(labels_, cfg_mb.n_classes)
                return -jnp.mean(jnp.sum(
                    onehot * jax.nn.log_softmax(logits), -1))

            loss, grads = jax.value_and_grad(loss_fn)(params_)
            new_p, new_o = opt.update(grads, opt_state, params_)
            return (new_p, new_o), {"loss": loss}

        mb_shapes = jax.eval_shape(lambda k: gnn.init_params(k, cfg_mb),
                                   jax.random.PRNGKey(0))
        mb_rep = jax.tree_util.tree_map(
            lambda s: _sds(s.shape, s.dtype, mesh,
                           P(*([None] * len(s.shape)))), mb_shapes)
        mb_opt = jax.tree_util.tree_map(
            lambda s: _sds(s.shape, s.dtype, mesh,
                           P(*([None] * len(s.shape)))),
            jax.eval_shape(opt.init, mb_shapes))
        return Cell(arch, shape, train_step,
                    ((mb_rep, mb_opt), x,
                     blocks[0]["src"], blocks[0]["dst"],
                     blocks[1]["src"], blocks[1]["dst"], labels, key),
                    donate=(0,), rules=rules)

    # molecule: batched small graphs
    B, n, e = p["batch"], p["n_nodes"], p["n_edges"]
    d_feat = 32
    cfg_m = dataclasses.replace(cfg, d_in=d_feat, n_classes=2)
    x = _sds((B * n, d_feat), jnp.float32, mesh, P(batch, None))
    src = _sds((B * (e + n),), jnp.int32, mesh, P(batch))
    dst = _sds((B * (e + n),), jnp.int32, mesh, P(batch))
    gid = _sds((B * n,), jnp.int32, mesh, P(batch))
    labels = _sds((B,), jnp.int32, mesh, P(batch))
    m_shapes = jax.eval_shape(lambda k: gnn.init_params(k, cfg_m),
                              jax.random.PRNGKey(0))
    m_rep = jax.tree_util.tree_map(
        lambda s: _sds(s.shape, s.dtype, mesh, P(*([None] * len(s.shape)))),
        m_shapes)
    m_opt = jax.tree_util.tree_map(
        lambda s: _sds(s.shape, s.dtype, mesh, P(*([None] * len(s.shape)))),
        jax.eval_shape(opt.init, m_shapes))

    def train_step(state, x_, src_, dst_, gid_, labels_, key_):
        params_, opt_state = state

        def loss_fn(pp):
            logits = gnn.gcn_forward_batched(
                pp, x_, src_, dst_, gid_, n_graphs=B, n_nodes=B * n,
                cfg=cfg_m, policy=policy, key=key_)
            onehot = jax.nn.one_hot(labels_, cfg_m.n_classes)
            return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))

        loss, grads = jax.value_and_grad(loss_fn)(params_)
        new_p, new_o = opt.update(grads, opt_state, params_)
        return (new_p, new_o), {"loss": loss}

    return Cell(arch, shape, train_step,
                ((m_rep, m_opt), x, src, dst, gid, labels, key),
                donate=(0,), rules=rules)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


def _recsys_cell(arch: ArchSpec, shape: ShapeSpec, mesh,
                 policy: ACTPolicy) -> Cell:
    from repro.models import recsys
    cfg = arch.model_cfg
    p = shape.p()
    batch = batch_axes(mesh)
    allaxes = batch + ("model",)
    rules = {"batch": batch}
    opt = adam(1e-3)

    def spec(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if name.startswith("table") or name.startswith("linear"):
            return P(allaxes, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    shapes = jax.eval_shape(lambda k: recsys.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = jax.tree_util.tree_map_with_path(spec, shapes)
    params = _apply_specs(shapes, specs, mesh)
    key = _sds((2,), jnp.uint32, mesh, P(None))

    if shape.kind == "train":
        B = p["batch"]
        opt_shapes = jax.eval_shape(opt.init, shapes)
        opt_sh = {
            "step": _sds((), jnp.int32, mesh, P()),
            "mu": _apply_specs(opt_shapes["mu"], specs, mesh),
            "nu": _apply_specs(opt_shapes["nu"], specs, mesh),
        }
        batch_in = {
            "sparse": _sds((B, cfg.n_sparse), jnp.int32, mesh,
                           P(batch, None)),
            "dense": _sds((B, max(cfg.n_dense, 1)), jnp.float32, mesh,
                          P(batch, None)),
            "label": _sds((B,), jnp.float32, mesh, P(batch)),
        }

        def train_step(state, batch_, key_):
            params_, opt_state = state

            def loss_fn(pp):
                logits = recsys.forward(pp, batch_, cfg, policy=policy,
                                        key=key_)
                lab = batch_["label"]
                return -jnp.mean(lab * jax.nn.log_sigmoid(logits)
                                 + (1 - lab) * jax.nn.log_sigmoid(-logits))

            loss, grads = jax.value_and_grad(loss_fn)(params_)
            new_p, new_o = opt.update(grads, opt_state, params_)
            return (new_p, new_o), {"loss": loss}

        return Cell(arch, shape, train_step,
                    ((params, opt_sh), batch_in, key),
                    donate=(0,), rules=rules)

    if shape.kind == "serve":
        B = p["batch"]
        batch_in = {
            "sparse": _sds((B, cfg.n_sparse), jnp.int32, mesh,
                           P(batch, None)),
            "dense": _sds((B, max(cfg.n_dense, 1)), jnp.float32, mesh,
                          P(batch, None)),
        }

        def serve_step(params_, batch_):
            return recsys.forward(params_, batch_, cfg, key=None)

        return Cell(arch, shape, serve_step, (params, batch_in),
                    rules=rules)

    # retrieval: one query vs n_candidates (padded to shard over all axes)
    n_cand = _ru(p["n_candidates"])
    query = {"sparse": _sds((cfg.n_sparse,), jnp.int32, mesh, P(None))}
    cand = _sds((n_cand,), jnp.int32, mesh, P(allaxes))

    def retrieval_step(params_, query_, cand_):
        return recsys.retrieval_scores(params_, query_, cand_, cfg)

    return Cell(arch, shape, retrieval_step, (params, query, cand),
                rules=rules)


# ---------------------------------------------------------------------------
# KGNN (the paper's own architectures, at Amazon-Book scale)
# ---------------------------------------------------------------------------


def _kgnn_cell(arch: ArchSpec, shape: ShapeSpec, mesh,
               policy: ACTPolicy) -> Cell:
    from repro.models import kgnn
    cfg = arch.model_cfg
    p = shape.p()
    batch = batch_axes(mesh)
    rules = {"batch": batch}
    opt = adam(1e-3)
    n_tri = _ru(p["n_triples"])
    B = p["batch"]
    # pad the node space so the entity table row-shards over the model axis
    pad_nodes = _ru(cfg.n_nodes) - cfg.n_nodes
    cfg = dataclasses.replace(cfg, n_entities=cfg.n_entities + pad_nodes)

    shapes = jax.eval_shape(lambda k: kgnn.init_params(k, cfg),
                            jax.random.PRNGKey(0))

    # the registry's ShardSpec placement is the one source of truth for
    # which tables row-shard (DESIGN.md §12); here they shard over the
    # mesh's model axis — same contract as make_dp_step's 2D path
    from repro.models.registry import kg_dp_spec
    row_sharded = kg_dp_spec(cfg).row_sharded()

    def spec(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if name in row_sharded:
            return P("model", None)
        return P(*([None] * len(leaf.shape)))

    specs = jax.tree_util.tree_map_with_path(spec, shapes)
    params = _apply_specs(shapes, specs, mesh)
    opt_shapes = jax.eval_shape(opt.init, shapes)
    opt_sh = {
        "step": _sds((), jnp.int32, mesh, P()),
        "mu": _apply_specs(opt_shapes["mu"], specs, mesh),
        "nu": _apply_specs(opt_shapes["nu"], specs, mesh),
    }
    g = kgnn.CKG(
        src=_sds((n_tri,), jnp.int32, mesh, P(batch)),
        dst=_sds((n_tri,), jnp.int32, mesh, P(batch)),
        rel=_sds((n_tri,), jnp.int32, mesh, P(batch)),
        n_nodes=cfg.n_nodes, n_relations=cfg.n_relations)
    batch_in = {
        "user": _sds((B,), jnp.int32, mesh, P(batch)),
        "pos": _sds((B,), jnp.int32, mesh, P(batch)),
        "neg": _sds((B,), jnp.int32, mesh, P(batch)),
    }
    key = _sds((2,), jnp.uint32, mesh, P(None))

    if cfg.model == "kgat":
        # dst-partitioned shard_map propagation (§Perf hillclimb #3
        # applied to the paper's own arch)
        def train_step(state, g_, batch_, key_):
            params_, opt_state = state

            def loss_fn(pp):
                reps = kgnn.propagate_spmd(pp, g_, cfg, mesh=mesh,
                                           axes=batch, policy=policy,
                                           key=key_)
                pos = kgnn.score_pairs(reps, batch_["user"], batch_["pos"],
                                       cfg.n_users)
                neg = kgnn.score_pairs(reps, batch_["user"], batch_["neg"],
                                       cfg.n_users)
                loss = -jnp.mean(jax.nn.log_sigmoid(pos - neg))
                reg = sum(jnp.sum(x ** 2)
                          for x in jax.tree_util.tree_leaves(pp))
                return loss + cfg.l2 * reg

            loss, grads = jax.value_and_grad(loss_fn)(params_)
            new_p, new_o = opt.update(grads, opt_state, params_)
            return (new_p, new_o), {"loss": loss}
    else:
        def train_step(state, g_, batch_, key_):
            params_, opt_state = state
            loss, grads = jax.value_and_grad(kgnn.bpr_loss)(
                params_, g_, batch_, cfg, policy=policy, key=key_)
            new_p, new_o = opt.update(grads, opt_state, params_)
            return (new_p, new_o), {"loss": loss}

    return Cell(arch, shape, train_step, ((params, opt_sh), g, batch_in, key),
                donate=(0,), rules=rules)


# ---------------------------------------------------------------------------


def build_cell(arch: ArchSpec, shape_name: str, mesh, *,
               policy: ACTPolicy | None = INT2) -> Cell:
    # policy=None defers per-site policy resolution to the ambient
    # ActContext at lowering time (dryrun --schedule path)
    shape = arch.shape(shape_name)
    fam = arch.family
    if fam in ("lm", "moe_lm"):
        return _lm_cell(arch, shape, mesh, policy)
    if fam == "gnn":
        return _gnn_cell(arch, shape, mesh, policy)
    if fam == "recsys":
        return _recsys_cell(arch, shape, mesh, policy)
    if fam == "kgnn":
        return _kgnn_cell(arch, shape, mesh, policy)
    raise ValueError(fam)
