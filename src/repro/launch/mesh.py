"""Production meshes (TPU v5e pods: 16×16 = 256 chips/pod, 2 pods = 512).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single-pod uses the first 256 devices so both meshes can
be built in one 512-device dry-run process.

Mesh construction routes through ``repro.sharding.compat`` so the same
code builds on JAX 0.4.x (no axis types) and current releases. On hosts
with fewer devices than a pod, the honest failure mode is an error that
names the fix; ``sim=`` is the dry-run escape hatch that keeps the axis
names (so every ``PartitionSpec`` downstream still resolves) while
shrinking the per-axis extents to what the host can simulate.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.sharding.compat import (
    auto_axis_types,
    host_device_count,
    mesh_from_devices,
    sim_mesh_env_hint,
)

__all__ = ["make_production_mesh", "batch_axes"]


def make_production_mesh(*, multi_pod: bool = False,
                         sim: tuple | None = None):
    """The 16×16 (data, model) pod mesh, or 2×16×16 with ``multi_pod``.

    ``sim`` substitutes per-axis extents (same axis names, same order) so
    dry-run tests can exercise the full partition machinery on a handful
    of forced host devices — a tuple of extents (``sim=(2, 4)``, or
    ``sim=(2, 2, 2)`` with ``multi_pod=True``) or a
    ``sharding.mesh_spec.MeshSpec`` whose axis names must match the
    layout exactly. Production callers leave it ``None`` and get a real
    error, not a silent downsize, when the host cannot back the pod.
    """
    from repro.sharding.mesh_spec import MeshSpec

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if isinstance(sim, MeshSpec):
        if sim.names != axes:
            raise ValueError(
                f"sim mesh spec {sim} names axes {sim.names}; this layout "
                f"needs {axes} (in order)")
        sim = sim.shape
    if sim is not None:
        sim = tuple(int(s) for s in sim)
        if len(sim) != len(axes):
            raise ValueError(
                f"sim mesh shape {sim} must name {len(axes)} extents for "
                f"axes {axes} (got {len(sim)})")
        shape = sim
    n = int(np.prod(shape))
    avail = host_device_count()
    if avail < n:
        raise RuntimeError(
            f"make_production_mesh(multi_pod={multi_pod}, sim={sim}) needs "
            f"{n} devices but this host exposes {avail}. On real hardware "
            "check the slice topology; for a simulated run either pass "
            "sim=<smaller per-axis extents> or force host devices via "
            + sim_mesh_env_hint(n))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return mesh_from_devices(devices, axes,
                             axis_types=auto_axis_types(len(axes)))


def batch_axes(mesh) -> tuple:
    """The data-parallel axes: pods compose with data for pure DP."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))
