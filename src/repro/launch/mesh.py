"""Production meshes (TPU v5e pods: 16×16 = 256 chips/pod, 2 pods = 512).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single-pod uses the first 256 devices so both meshes can
be built in one 512-device dry-run process.
"""

from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "batch_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(
        devices, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def batch_axes(mesh) -> tuple:
    """The data-parallel axes: pods compose with data for pure DP."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))
