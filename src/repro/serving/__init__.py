"""Quantized recommendation serving (DESIGN.md §8, tier 2 §14).

The training side of this repo compresses *activations*; serving
compresses the *final representations* the recommender actually ships:

  store.py   offline rollout -> packed ``QuantizedEmbeddingStore``
             (INT8/INT4 via the quant_pack kernel, fp32 escape hatch)
  scorer.py  chunked dequant·score·top-K — never builds (U, I); fused
             Pallas kernel (kernels/topk_score.py) + jnp fallback;
             two-stage retrieval (packed-domain coarse scan -> fp32
             re-rank of C·k survivors) and the deterministic
             ``merge_topk`` shard-merge contract
  engine.py  micro-batching request engine: bounded queue with named
             backpressure, bucketed padding (no retraces), item-sharded
             parallel scoring, hot-user result cache, incremental
             refresh, QPS + latency percentiles
  cache.py   version-stamped LRU of per-user results
  refresh.py delta rollout of changed rows between store versions
  eval.py    streaming full-ranking Recall@K/NDCG@K over the scorer,
             exact-equivalent to training.metrics.recall_ndcg_at_k
"""

from .cache import ResultCache
from .engine import BackpressureError, EngineStats, ServingEngine
from .eval import streaming_eval_dataset, streaming_recall_ndcg
from .refresh import StoreDelta, apply_delta, store_delta
from .scorer import (coarse_topm, merge_topk, quantize_query, topk_scores,
                     two_stage_topk)
from .store import QuantizedEmbeddingStore, build_kgnn_store, padded_pos_lists

__all__ = [
    "QuantizedEmbeddingStore", "build_kgnn_store", "padded_pos_lists",
    "topk_scores", "merge_topk", "two_stage_topk", "coarse_topm",
    "quantize_query",
    "ServingEngine", "EngineStats", "BackpressureError",
    "ResultCache", "StoreDelta", "store_delta", "apply_delta",
    "streaming_recall_ndcg", "streaming_eval_dataset",
]
