"""Quantized recommendation serving (DESIGN.md §8).

The training side of this repo compresses *activations*; serving
compresses the *final representations* the recommender actually ships:

  store.py   offline rollout -> packed ``QuantizedEmbeddingStore``
             (INT8/INT4 via the quant_pack kernel, fp32 escape hatch)
  scorer.py  chunked dequant·score·top-K — never builds (U, I); fused
             Pallas kernel (kernels/topk_score.py) + jnp fallback
  engine.py  micro-batching request engine: bounded queue, bucketed
             padding (no retraces), QPS + latency percentiles
  eval.py    streaming full-ranking Recall@K/NDCG@K over the scorer,
             exact-equivalent to training.metrics.recall_ndcg_at_k
"""

from .engine import EngineStats, ServingEngine
from .eval import streaming_eval_dataset, streaming_recall_ndcg
from .scorer import merge_topk, topk_scores
from .store import QuantizedEmbeddingStore, build_kgnn_store, padded_pos_lists

__all__ = [
    "QuantizedEmbeddingStore", "build_kgnn_store", "padded_pos_lists",
    "topk_scores", "merge_topk",
    "ServingEngine", "EngineStats",
    "streaming_recall_ndcg", "streaming_eval_dataset",
]
