"""Incremental store refresh: delta rollout of changed rows.

A training job periodically re-rolls the serving tables
(``build_kgnn_store`` from the latest checkpoint); between consecutive
rollouts most rows are identical — only the entities touched by recent
gradient steps move. Shipping the full table per refresh would make
refresh cost O(store); ``store_delta`` diffs two rollouts ROW-wise (on
the packed bytes + scale/zero for quantized tables — byte equality is
exactly "serves identically") and packages only the changed rows, and
``apply_delta`` splices them into the live store functionally. The
result is BIT-identical to the new rollout (pinned by tests), so delta
refresh is purely a transfer/cost optimization, never an approximation.

The engine applies a delta on its worker thread between batches
(serving/engine.py:refresh): requests enqueued before the refresh are
scored against the old store, requests after against the new — an
atomic version swap with no dropped and no torn-store-served requests.
The store version counter increments per applied delta and stamps both
cache entries and responses.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.quant import QTensor

from .store import QuantizedEmbeddingStore

__all__ = ["StoreDelta", "store_delta", "apply_delta"]


@dataclasses.dataclass(frozen=True)
class StoreDelta:
    """Changed rows between two same-shape, same-bits store rollouts.

    ``user_rows``/``item_rows`` hold, for each changed row id, the new
    payload: ``(packed, scale, zero)`` numpy arrays for quantized
    tables, ``(rows,)`` for fp32 tables.
    """

    user_ids: np.ndarray        # (nu,) int32 changed user row ids
    item_ids: np.ndarray        # (ni,) int32 changed item row ids
    user_rows: tuple
    item_rows: tuple
    n_users: int                # identity guard: target store shape
    n_items: int
    bits: int | None

    @property
    def n_changed(self) -> int:
        return len(self.user_ids) + len(self.item_ids)

    def nbytes(self) -> int:
        """Wire cost of the delta (what a full push would multiply)."""
        return sum(int(a.nbytes) for part in (self.user_rows, self.item_rows)
                   for a in part)

    def stats(self) -> dict:
        return {
            "users_changed": int(len(self.user_ids)),
            "items_changed": int(len(self.item_ids)),
            "rows_changed": self.n_changed,
            "rows_total": self.n_users + self.n_items,
            "delta_bytes": self.nbytes(),
            "changed_frac": self.n_changed / max(self.n_users
                                                 + self.n_items, 1),
        }


def _table_leaves(t):
    """The per-row leaves whose byte equality defines "unchanged"."""
    if isinstance(t, QTensor):
        return (np.asarray(t.packed), np.asarray(t.scale),
                np.asarray(t.zero))
    return (np.asarray(t),)


def _diff_rows(old_t, new_t):
    leaves_o, leaves_n = _table_leaves(old_t), _table_leaves(new_t)
    changed = np.zeros(leaves_o[0].shape[0], bool)
    for lo, ln in zip(leaves_o, leaves_n):
        changed |= (lo != ln).reshape(lo.shape[0], -1).any(axis=1)
    ids = np.nonzero(changed)[0].astype(np.int32)
    rows = tuple(ln[ids] for ln in leaves_n)
    return ids, rows


def store_delta(old: QuantizedEmbeddingStore,
                new: QuantizedEmbeddingStore) -> StoreDelta:
    """Row-wise diff of two rollouts; raises on incompatible stores."""
    if old.bits != new.bits:
        raise ValueError(f"delta refresh needs matching precision: "
                         f"old bits={old.bits} new bits={new.bits} "
                         f"(a precision change is a full re-deploy)")
    if old.n_users != new.n_users or old.n_items != new.n_items or \
            old.dim != new.dim:
        raise ValueError(
            f"delta refresh needs matching table shapes: old "
            f"(U={old.n_users}, I={old.n_items}, d={old.dim}) vs new "
            f"(U={new.n_users}, I={new.n_items}, d={new.dim})")
    uids, urows = _diff_rows(old.users, new.users)
    iids, irows = _diff_rows(old.items, new.items)
    return StoreDelta(user_ids=uids, item_ids=iids, user_rows=urows,
                      item_rows=irows, n_users=old.n_users,
                      n_items=old.n_items, bits=old.bits)


def _patch_table(t, ids, rows):
    if len(ids) == 0:
        return t
    idx = jnp.asarray(ids)
    if isinstance(t, QTensor):
        packed, scale, zero = rows
        return QTensor(packed=t.packed.at[idx].set(jnp.asarray(packed)),
                       scale=t.scale.at[idx].set(jnp.asarray(scale)),
                       zero=t.zero.at[idx].set(jnp.asarray(zero)),
                       bits=t.bits, dim=t.dim, dtype=t.dtype)
    return t.at[idx].set(jnp.asarray(rows[0]))


def apply_delta(store: QuantizedEmbeddingStore,
                delta: StoreDelta) -> QuantizedEmbeddingStore:
    """Splice changed rows in; bit-identical to the rollout that made
    the delta (``store_delta(old, new); apply_delta(old, d) == new``)."""
    if store.n_users != delta.n_users or store.n_items != delta.n_items \
            or store.bits != delta.bits:
        raise ValueError(
            f"delta targets (U={delta.n_users}, I={delta.n_items}, "
            f"bits={delta.bits}), store is (U={store.n_users}, "
            f"I={store.n_items}, bits={store.bits})")
    return QuantizedEmbeddingStore(
        users=_patch_table(store.users, delta.user_ids, delta.user_rows),
        items=_patch_table(store.items, delta.item_ids, delta.item_rows),
        bits=store.bits, dim=store.dim)
