"""Micro-batching request engine over the chunked top-K scorer.

Production retrieval traffic arrives one user at a time; the scorer
wants batches. The engine sits between: a bounded request queue, a
worker that drains up to ``max(buckets)`` requests per iteration, pads
the batch up to the nearest BUCKET size (so the jitted scorer sees only
``len(buckets)`` distinct shapes and never retraces after warmup), and
fans per-request top-K results back through futures.

Padding repeats the batch's last user id — rows are independent in the
scorer, pad rows are simply dropped on the way out. Per-request latency
is measured submit→result; QPS over the serving window. ``warmup()``
traces every bucket up front so p99 reflects steady state, not compile.

Tier-2 serving features (DESIGN.md §14), all composable:

  * **Two-stage retrieval** (``two_stage_c=C``): per shard, a coarse
    scan in the packed code domain keeps ``C·k`` candidates and only
    those are dequantized for the fp32 re-rank (scorer.two_stage_topk);
    per-stage latency lands on ``serve/stage_ms{stage=coarse|rerank}``
    reservoirs and the dequantized fraction on ``serve/candidate_frac``.
  * **Item shards** (``item_shards=S``): the item table is row-split
    and the shards scored CONCURRENTLY (thread pool; with
    ``shard_devices=True`` each shard is placed on its own jax device
    of a simulated/real mesh), then host-merged via ``merge_topk`` —
    bit-identical to single-shard ranking (ordering contract there).
  * **Hot-user cache** (``cache_size=N``): version-stamped LRU of
    per-user results, looked up at batch-drain time (cache.py has the
    invalidation rules).
  * **Incremental refresh** (``refresh(new_store_or_delta)``): a delta
    is applied on the worker thread BETWEEN batches — requests enqueued
    before the refresh see the old store, after it the new one; nothing
    is dropped and nothing is served from a torn store. Bumps the store
    version, invalidates cache entries per the delta.
  * **Backpressure** (``max_pending=N``): ``submit`` never blocks; a
    full queue raises the named ``BackpressureError`` (and counts
    ``serve/backpressure``) so the caller sheds load explicitly instead
    of growing an unbounded queue.
"""

from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QTensor
from repro.obs import get_registry, span

from .cache import ResultCache
from .refresh import StoreDelta, apply_delta, store_delta
from .scorer import merge_topk, topk_scores, two_stage_topk
from .store import QuantizedEmbeddingStore

__all__ = ["ServingEngine", "EngineStats", "BackpressureError"]


class BackpressureError(RuntimeError):
    """The engine's bounded submit queue is full; shed or retry later."""


@dataclasses.dataclass(frozen=True)
class EngineStats:
    n_requests: int
    qps: float
    p50_ms: float
    p99_ms: float
    n_batches: int
    cache_hit_rate: float = 0.0
    store_version: int = 0

    def __str__(self) -> str:
        return (f"{self.n_requests} req | {self.qps:.0f} QPS | "
                f"p50 {self.p50_ms:.2f}ms p99 {self.p99_ms:.2f}ms | "
                f"{self.n_batches} batches | "
                f"cache {self.cache_hit_rate:.0%} | v{self.store_version}")


def _shard_items(items, n_shards: int):
    """Split the item table into row-shards (global ids preserved by
    offsetting scorer indices)."""
    if n_shards == 1:
        return [items]
    if isinstance(items, QTensor):
        n = items.packed.shape[0]
        bounds = np.linspace(0, n, n_shards + 1, dtype=int)
        return [QTensor(packed=items.packed[a:b], scale=items.scale[a:b],
                        zero=items.zero[a:b], bits=items.bits,
                        dim=items.dim, dtype=items.dtype)
                for a, b in zip(bounds[:-1], bounds[1:])]
    n = items.shape[0]
    bounds = np.linspace(0, n, n_shards + 1, dtype=int)
    return [items[a:b] for a, b in zip(bounds[:-1], bounds[1:])]


# queue message kinds
_REQ, _REFRESH = "req", "refresh"


class ServingEngine:
    """Bounded-queue micro-batching server over a packed store.

    exclude : optional (U, P) int32 per-user item-id lists (-1 pads) —
              typically the train positives (``store.padded_pos_lists``)
              — excluded from every response for that user.
    buckets : ascending padded batch sizes; ``max(buckets)`` is also the
              per-iteration drain limit.
    two_stage_c : candidate multiplier C for two-stage retrieval (None =
              single-stage exact scan; requires a packed store).
    item_shards : row-split the item table into S shards scored
              concurrently and host-merged (bit-exact, see merge_topk).
    shard_devices : place each shard on its own jax device when the
              runtime exposes enough (simulated mesh or real); shards
              then score genuinely in parallel rather than merely on
              concurrent host threads.
    cache_size : capacity of the hot-user result cache (0 = off).
    max_pending : submit-queue bound; a full queue raises the named
              ``BackpressureError`` instead of growing without bound.
    """

    _SEQ = itertools.count()

    def __init__(self, store: QuantizedEmbeddingStore, *, k: int = 20,
                 exclude=None, buckets=(1, 4, 16, 64),
                 backend: str = "pallas", block_i: int = 1024,
                 item_shards: int = 1, two_stage_c: int | None = None,
                 shard_devices: bool = False, cache_size: int = 0,
                 max_pending: int = 1024, lat_capacity: int = 4096,
                 registry=None):
        if two_stage_c is not None:
            if two_stage_c < 1:
                raise ValueError(f"two_stage_c must be >= 1, "
                                 f"got {two_stage_c}")
            if not isinstance(store.items, QTensor):
                raise ValueError(
                    "two-stage retrieval needs a packed (INT8/INT4) item "
                    "table; an fp32 store has no packed domain to "
                    "coarse-scan — drop two_stage_c or quantize the store")
        self.store = store
        self.k = k
        self.buckets = tuple(sorted(buckets))
        self.backend = backend
        self.block_i = block_i
        self.two_stage_c = two_stage_c
        self.n_shards = item_shards
        self.max_pending = max_pending
        self.exclude = (jnp.asarray(exclude, jnp.int32) if exclude is not None
                        else jnp.full((store.n_users, 1), -1, jnp.int32))
        self._devices = None
        if shard_devices and item_shards > 1:
            devs = jax.devices()
            if len(devs) >= item_shards:
                self._devices = devs[:item_shards]
        self._build_shards()
        self._pool = (ThreadPoolExecutor(max_workers=item_shards,
                                         thread_name_prefix="shard")
                      if item_shards > 1 else None)
        self._queue: queue.Queue = queue.Queue(maxsize=max_pending)
        self._thread: threading.Thread | None = None
        self.version = 0
        # latency lives on a bounded reservoir, not an unbounded list — a
        # long-lived engine's memory no longer grows with request count
        # (percentiles stay exact up to lat_capacity, sampled past it)
        reg = registry if registry is not None else get_registry()
        label = f"engine{next(self._SEQ)}"
        self.label = label
        self._cache = (ResultCache(cache_size, registry=reg, label=label)
                       if cache_size else None)
        self._m_lat = reg.histogram("serve/latency_ms", engine=label,
                                    capacity=lat_capacity)
        self._m_stage = {
            s: reg.histogram("serve/stage_ms", engine=label, stage=s,
                             capacity=lat_capacity)
            for s in ("coarse", "rerank")} if two_stage_c else {}
        self._m_queue = reg.gauge("serve/queue_depth", engine=label)
        self._m_requests = reg.counter("serve/requests", engine=label)
        self._m_batches = reg.counter("serve/batches", engine=label)
        self._m_shed = reg.counter("serve/backpressure", engine=label)
        self._m_cand = reg.gauge("serve/candidate_frac", engine=label)
        self._m_version = reg.gauge("serve/store_version", engine=label)
        self._m_refresh_rows = reg.counter("serve/refresh_rows",
                                           engine=label)
        self._n_batches = 0
        self._t_first = self._t_last = None
        self._t_lock = threading.Lock()

    def _build_shards(self) -> None:
        shards = _shard_items(self.store.items, self.n_shards)
        if self._devices is not None:
            shards = [jax.device_put(s, d)
                      for s, d in zip(shards, self._devices)]
        self._shards = shards
        self._shard_offsets = np.cumsum(
            [0] + [s.packed.shape[0] if isinstance(s, QTensor) else s.shape[0]
                   for s in shards])[:-1]

    # -- scoring ------------------------------------------------------------

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _score_shard(self, q, excl, shard, off):
        """One shard's local top-k (global exclusion ids shifted into
        shard space; out-of-range never matches).

        Stage timings are RETURNED, not observed here: this runs on
        shard pool threads, and Histogram.observe is single-writer —
        the caller folds them into the reservoirs on its own thread.
        """
        rows = (shard.packed if isinstance(shard, QTensor)
                else shard).shape[0]
        k = min(self.k, rows)
        if self._devices is not None:
            dev = shard.packed.devices() if isinstance(shard, QTensor) \
                else shard.devices()
            dev = next(iter(dev))
            q = jax.device_put(q, dev)
            excl = jax.device_put(excl, dev)
        stage_t: list = []
        if self.two_stage_c is not None:
            cb = ((lambda stage, dt: stage_t.append((stage, dt)))
                  if self._m_stage else None)
            v, i = two_stage_topk(q, shard, k, c=self.two_stage_c,
                                  exclude=excl - int(off),
                                  backend=self.backend,
                                  block_i=self.block_i, stage_cb=cb)
        else:
            v, i = topk_scores(q, shard, k, exclude=excl - int(off),
                               backend=self.backend, block_i=self.block_i)
        return np.asarray(v), np.asarray(i) + int(off), stage_t

    def _observe_stages(self, stage_t) -> None:
        for stage, dt in stage_t:
            self._m_stage[stage].observe(dt * 1e3)

    def score_batch(self, user_ids: np.ndarray):
        """Top-K for a batch of user ids, padded to the nearest bucket.

        Returns (values (n, k), indices (n, k)) numpy arrays for the n
        REAL requests (pad rows stripped). Always scores — the cache
        sits in the drain loop, not here. Batches larger than
        ``max(buckets)`` are chunked at the largest bucket, so the
        jitted scorer only ever sees bucketed shapes and direct callers
        with varying oversized batches never retrace.
        """
        n = len(user_ids)
        max_b = self.buckets[-1]
        if n > max_b:
            parts = [self.score_batch(user_ids[a:a + max_b])
                     for a in range(0, n, max_b)]
            return (np.concatenate([p[0] for p in parts]),
                    np.concatenate([p[1] for p in parts]))
        b = self._bucket(n)
        padded = np.asarray(user_ids, np.int32)
        if b > n:
            padded = np.concatenate([padded, np.full(b - n, padded[-1],
                                                     np.int32)])
        q = self.store.user_vectors(jnp.asarray(padded))
        excl = self.exclude[jnp.asarray(padded)]
        if self.two_stage_c is not None:
            m = sum(min(self.two_stage_c * self.k,
                        (s.packed if isinstance(s, QTensor) else s).shape[0])
                    for s in self._shards)
            self._m_cand.set(m / max(self.store.n_items, 1))
        if len(self._shards) == 1:
            vals, idx, stage_t = self._score_shard(q, excl, self._shards[0], 0)
            self._observe_stages(stage_t)
            return vals[:n], idx[:n]
        futs = [self._pool.submit(self._score_shard, q, excl, shard, off)
                for off, shard in zip(self._shard_offsets, self._shards)]
        parts = [f.result() for f in futs]
        for p in parts:
            self._observe_stages(p[2])
        vals, idx = merge_topk([p[0] for p in parts], [p[1] for p in parts],
                               self.k)
        return vals[:n], idx[:n]

    def warmup(self) -> None:
        """Trace the scorer for every bucket so serving never compiles."""
        for b in self.buckets:
            self.score_batch(np.zeros(b, np.int32))

    # -- request loop -------------------------------------------------------

    def submit(self, user_id: int) -> Future:
        """Enqueue one request; resolves to (values (k,), indices (k,)).

        Raises ``BackpressureError`` (named, metered) when the bounded
        queue is full — the engine sheds rather than buffering without
        bound under overload.
        """
        if self._thread is None:
            raise RuntimeError("engine not started (use `with engine:`)")
        fut: Future = Future()
        now = time.perf_counter()
        try:
            self._queue.put_nowait((_REQ, int(user_id), now, fut))
        except queue.Full:
            self._m_shed.inc()
            raise BackpressureError(
                f"serving queue full ({self.max_pending} pending); "
                f"request shed — retry with backoff or raise max_pending"
            ) from None
        # window opens at the first ACCEPTED submit (a shed request must
        # not start the clock); locked — submit runs on client threads
        if self._t_first is None:
            with self._t_lock:
                if self._t_first is None:
                    self._t_first = now
        # queue depth is metered from the worker loop per drain, not per
        # submit — qsize() takes the queue lock and submit is a hot path
        return fut

    def refresh(self, new_store_or_delta) -> Future:
        """Schedule an incremental store refresh; resolves to delta stats.

        Accepts a full re-rolled ``QuantizedEmbeddingStore`` (the delta
        is computed against the live store) or a precomputed
        ``StoreDelta``. Applied on the worker thread BETWEEN batches:
        every request enqueued before this call is served from the old
        store, every one after from the new — atomic swap, no drops.
        Control messages use a blocking put: they are never shed.
        """
        if self._thread is None:
            raise RuntimeError("engine not started (use `with engine:`)")
        fut: Future = Future()
        self._queue.put((_REFRESH, new_store_or_delta, fut))
        return fut

    def _serve_loop(self) -> None:
        """Drain policy: cache hits resolve IMMEDIATELY and do not
        consume scoring-batch slots — the batch fills with up to
        ``max(buckets)`` MISSES. Under hot (zipfian) traffic one catalog
        scan therefore amortizes over every hit drained alongside it,
        which is where the tier-2 sustained-QPS win comes from; with the
        cache off every request is a miss and this is the plain
        batching loop."""
        max_b = self.buckets[-1]
        while True:
            msg = self._queue.get()
            if msg is None:
                self._cancel_pending()
                return
            if msg[0] == _REFRESH:
                self._apply_refresh(msg[1], msg[2])
                continue
            misses = []
            refresh = None
            stop = False      # sentinel tracked apart from refresh control:
            # a None captured here must not look like "no control message"
            self._hit_or_collect(msg, misses)
            while len(misses) < max_b:
                try:
                    nxt = self._queue.get_nowait()
                except queue.Empty:
                    break
                if nxt is None:
                    stop = True       # ordering: serve the batch first
                    break
                if nxt[0] == _REFRESH:
                    refresh = nxt
                    break
                self._hit_or_collect(nxt, misses)
            if misses:
                self._drain_batch(misses)
            self._m_queue.set(float(self._queue.qsize()))
            if stop:
                self._cancel_pending()
                return
            if refresh is not None:
                self._apply_refresh(refresh[1], refresh[2])

    def _hit_or_collect(self, msg, misses: list) -> None:
        """Resolve a request from the cache now, or queue it for the
        scoring batch."""
        if self._cache is not None:
            ent = self._cache.get(msg[1])
            if ent is not None:
                self._resolve(msg, (ent[1], ent[2]))
                return
        misses.append(msg)

    def _resolve(self, msg, result) -> None:
        _, _, t0, fut = msg
        now = time.perf_counter()
        self._t_last = now
        self._m_lat.observe((now - t0) * 1e3)
        self._m_requests.inc()
        fut.set_result(result)

    def _cancel_pending(self) -> None:
        """Shutdown: anything still queued behind the sentinel must fail
        fast (cancelled), not leave its future blocking forever."""
        while True:
            try:
                msg = self._queue.get_nowait()
            except queue.Empty:
                return
            if msg is not None:
                msg[-1].cancel()

    def _apply_refresh(self, payload, fut: Future) -> None:
        """Worker-thread delta application + cache invalidation."""
        try:
            delta = (payload if isinstance(payload, StoreDelta)
                     else store_delta(self.store, payload))
            self.store = apply_delta(self.store, delta)
            self._build_shards()
            self.version += 1
            if self._cache is not None:
                if len(delta.item_ids):
                    # item rows changed: every ranking is stale
                    self._cache.clear()
                elif len(delta.user_ids):
                    self._cache.drop(delta.user_ids)
            self._m_version.set(float(self.version))
            self._m_refresh_rows.inc(delta.n_changed)
            fut.set_result({**delta.stats(), "version": self.version})
        except Exception as e:           # surface to the caller, keep serving
            fut.set_exception(e)

    def _drain_batch(self, batch) -> None:
        """Score a batch of cache misses and resolve their futures."""
        ids = np.array([m[1] for m in batch], np.int32)
        with span("serve/batch", n=len(batch)):
            vals, idx = self.score_batch(ids)
        self._n_batches += 1
        self._m_batches.inc()
        for pos, msg in enumerate(batch):
            if self._cache is not None:
                self._cache.put(msg[1], self.version, vals[pos], idx[pos])
            self._resolve(msg, (vals[pos], idx[pos]))

    def __enter__(self) -> "ServingEngine":
        self._thread = threading.Thread(target=self._serve_loop, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._queue.put(None)
        self._thread.join(timeout=60.0)
        self._thread = None
        if self._pool is not None:
            self._pool.shutdown(wait=False)

    def stats(self) -> EngineStats:
        h = self._m_lat.snapshot()
        n = int(self._m_requests.value)
        window = max((self._t_last or 0) - (self._t_first or 0), 1e-9)
        return EngineStats(
            n_requests=n,
            qps=n / window if n else 0.0,
            p50_ms=h["p50"] if n else 0.0,
            p99_ms=h["p99"] if n else 0.0,
            n_batches=self._n_batches,
            cache_hit_rate=(self._cache.hit_rate if self._cache else 0.0),
            store_version=self.version)
