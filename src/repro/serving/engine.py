"""Micro-batching request engine over the chunked top-K scorer.

Production retrieval traffic arrives one user at a time; the scorer
wants batches. The engine sits between: a bounded request queue, a
worker that drains up to ``max(buckets)`` requests per iteration, pads
the batch up to the nearest BUCKET size (so the jitted scorer sees only
``len(buckets)`` distinct shapes and never retraces after warmup), and
fans per-request top-K results back through futures.

Padding repeats the batch's last user id — rows are independent in the
scorer, pad rows are simply dropped on the way out. Per-request latency
is measured submit→result; QPS over the serving window. ``warmup()``
traces every bucket up front so p99 reflects steady state, not compile.

Item shards: a store too big for one scorer call can be split into
row-shards scored per call and merged host-side with
``scorer.merge_topk`` (exact — same tie rule); the engine keeps the
single-shard fast path when ``item_shards == 1``.
"""

from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from concurrent.futures import Future

import jax.numpy as jnp
import numpy as np

from repro.core.quant import QTensor
from repro.obs import get_registry, span

from .scorer import merge_topk, topk_scores
from .store import QuantizedEmbeddingStore

__all__ = ["ServingEngine", "EngineStats"]


@dataclasses.dataclass(frozen=True)
class EngineStats:
    n_requests: int
    qps: float
    p50_ms: float
    p99_ms: float
    n_batches: int

    def __str__(self) -> str:
        return (f"{self.n_requests} req | {self.qps:.0f} QPS | "
                f"p50 {self.p50_ms:.2f}ms p99 {self.p99_ms:.2f}ms | "
                f"{self.n_batches} batches")


def _shard_items(items, n_shards: int):
    """Split the item table into row-shards (global ids preserved by
    offsetting scorer indices)."""
    if n_shards == 1:
        return [items]
    if isinstance(items, QTensor):
        n = items.packed.shape[0]
        bounds = np.linspace(0, n, n_shards + 1, dtype=int)
        return [QTensor(packed=items.packed[a:b], scale=items.scale[a:b],
                        zero=items.zero[a:b], bits=items.bits,
                        dim=items.dim, dtype=items.dtype)
                for a, b in zip(bounds[:-1], bounds[1:])]
    n = items.shape[0]
    bounds = np.linspace(0, n, n_shards + 1, dtype=int)
    return [items[a:b] for a, b in zip(bounds[:-1], bounds[1:])]


class ServingEngine:
    """Bounded-queue micro-batching server over a packed store.

    exclude : optional (U, P) int32 per-user item-id lists (-1 pads) —
              typically the train positives (``store.padded_pos_lists``)
              — excluded from every response for that user.
    buckets : ascending padded batch sizes; ``max(buckets)`` is also the
              per-iteration drain limit.
    """

    _SEQ = itertools.count()

    def __init__(self, store: QuantizedEmbeddingStore, *, k: int = 20,
                 exclude=None, buckets=(1, 4, 16, 64),
                 backend: str = "pallas", block_i: int = 1024,
                 item_shards: int = 1, max_queue: int = 1024,
                 lat_capacity: int = 4096, registry=None):
        self.store = store
        self.k = k
        self.buckets = tuple(sorted(buckets))
        self.backend = backend
        self.block_i = block_i
        self.exclude = (jnp.asarray(exclude, jnp.int32) if exclude is not None
                        else jnp.full((store.n_users, 1), -1, jnp.int32))
        self._shards = _shard_items(store.items, item_shards)
        self._shard_offsets = np.cumsum(
            [0] + [s.packed.shape[0] if isinstance(s, QTensor) else s.shape[0]
                   for s in self._shards])[:-1]
        self._queue: queue.Queue = queue.Queue(maxsize=max_queue)
        self._thread: threading.Thread | None = None
        # latency lives on a bounded reservoir, not an unbounded list — a
        # long-lived engine's memory no longer grows with request count
        # (percentiles stay exact up to lat_capacity, sampled past it)
        reg = registry if registry is not None else get_registry()
        label = f"engine{next(self._SEQ)}"
        self._m_lat = reg.histogram("serve/latency_ms", engine=label,
                                    capacity=lat_capacity)
        self._m_queue = reg.gauge("serve/queue_depth", engine=label)
        self._m_requests = reg.counter("serve/requests", engine=label)
        self._m_batches = reg.counter("serve/batches", engine=label)
        self._n_batches = 0
        self._t_first = self._t_last = None

    # -- scoring ------------------------------------------------------------

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def score_batch(self, user_ids: np.ndarray):
        """Top-K for a batch of user ids, padded to the nearest bucket.

        Returns (values (n, k), indices (n, k)) numpy arrays for the n
        REAL requests (pad rows stripped).
        """
        n = len(user_ids)
        b = self._bucket(n)
        padded = np.asarray(user_ids, np.int32)
        if b > n:
            padded = np.concatenate([padded, np.full(b - n, padded[-1],
                                                     np.int32)])
        q = self.store.user_vectors(jnp.asarray(padded))
        excl = self.exclude[jnp.asarray(padded)]
        if len(self._shards) == 1:
            vals, idx = topk_scores(q, self._shards[0], self.k, exclude=excl,
                                    backend=self.backend,
                                    block_i=self.block_i)
            return np.asarray(vals)[:n], np.asarray(idx)[:n]
        parts_v, parts_i = [], []
        for off, shard in zip(self._shard_offsets, self._shards):
            # shard-local exclusion: shift ids into shard space; out-of-
            # range entries never match (ids in [0, shard_rows))
            v, i = topk_scores(q, shard, self.k, exclude=excl - int(off),
                               backend=self.backend, block_i=self.block_i)
            parts_v.append(np.asarray(v))
            parts_i.append(np.asarray(i) + int(off))
        vals, idx = merge_topk(parts_v, parts_i, self.k)
        return vals[:n], idx[:n]

    def warmup(self) -> None:
        """Trace the scorer for every bucket so serving never compiles."""
        for b in self.buckets:
            self.score_batch(np.zeros(b, np.int32))

    # -- request loop -------------------------------------------------------

    def submit(self, user_id: int) -> Future:
        """Enqueue one request; resolves to (values (k,), indices (k,))."""
        if self._thread is None:
            raise RuntimeError("engine not started (use `with engine:`)")
        fut: Future = Future()
        now = time.perf_counter()
        if self._t_first is None:
            self._t_first = now          # serving window opens at first submit
        self._queue.put((int(user_id), now, fut))
        self._m_queue.set(float(self._queue.qsize()))
        return fut

    def _serve_loop(self) -> None:
        max_b = self.buckets[-1]
        while True:
            req = self._queue.get()
            if req is None:
                self._cancel_pending()
                return
            batch = [req]
            stop = False
            while len(batch) < max_b:
                try:
                    nxt = self._queue.get_nowait()
                except queue.Empty:
                    break
                if nxt is None:
                    stop = True
                    break
                batch.append(nxt)
            self._drain_batch(batch)
            if stop:
                self._cancel_pending()
                return

    def _cancel_pending(self) -> None:
        """Shutdown: anything still queued behind the sentinel must fail
        fast (cancelled), not leave its future blocking forever."""
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            if req is not None:
                req[2].cancel()

    def _drain_batch(self, batch) -> None:
        ids = np.array([r[0] for r in batch], np.int32)
        with span("serve/batch", n=len(batch)):
            vals, idx = self.score_batch(ids)
        now = time.perf_counter()
        self._n_batches += 1
        self._m_batches.inc()
        self._t_last = now
        self._m_queue.set(float(self._queue.qsize()))
        for j, (_, t0, fut) in enumerate(batch):
            self._m_lat.observe((now - t0) * 1e3)
            self._m_requests.inc()
            fut.set_result((vals[j], idx[j]))

    def __enter__(self) -> "ServingEngine":
        self._thread = threading.Thread(target=self._serve_loop, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._queue.put(None)
        self._thread.join(timeout=60.0)
        self._thread = None

    def stats(self) -> EngineStats:
        h = self._m_lat.snapshot()
        n = int(self._m_requests.value)
        window = max((self._t_last or 0) - (self._t_first or 0), 1e-9)
        return EngineStats(
            n_requests=n,
            qps=n / window if n else 0.0,
            p50_ms=h["p50"] if n else 0.0,
            p99_ms=h["p99"] if n else 0.0,
            n_batches=self._n_batches)
