"""Chunked top-K candidate scoring over a packed store.

Never builds the ``(U, I)`` score matrix: items stream through in
``block_i``-row chunks and only a running top-K per query survives each
merge. Two backends with a BIT-EXACT contract between them:

  * ``pallas`` — the fused dequant·score·top-K kernel
    (``kernels/topk_score.py``): packed uint8 rows are shift+mask
    unpacked in VMEM, scored on the MXU, merged in-kernel.
  * ``jnp``    — the same chunk/merge schedule in plain jnp (and the
    only path for fp32 stores / odd-dim padded packs). Both backends
    run the identical op sequence per chunk, so in interpret mode the
    results match bit-for-bit — the parity test in
    tests/test_serving.py holds to zero ulps.

Tie semantics are those of ``jax.lax.top_k`` (lowest index wins), which
the chunked merge preserves exactly — see kernels/topk_score.py for the
argument, tests/test_serving.py for the boundary-tie property test.

``merge_topk`` is the HOST-side merge for results that were produced by
*separate* scorer calls (item shards too big for one call, or the
engine fanning a store across devices): same (value desc, index asc)
order, so composing call-level merges stays exact.

Two-stage retrieval (``two_stage_topk``, DESIGN.md §14): a COARSE scan
over all items in the packed integer-code domain (symmetric-INT8 query,
per-row affine correction — kernels/topk_score.py:fused_coarse_topm or
the bit-exact jnp mirror here) keeps the top ``c·k`` candidate ids, and
only those rows are dequantized to fp32 for the exact re-rank. At
``c·k >= n_items`` the candidate set is every item, so the result is
exactly the single-stage ranking (the C→∞ anchor the tests pin); at
small ``c`` the coarse error bound (qs/2 per query element) keeps
recall within a fraction of single-stage measured by the bench's
recall-vs-C curve.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QTensor, unpack_bits
from repro.kernels import topk_score as _tk
from repro.kernels.ops import INTERPRET, TRACE_COUNTS

__all__ = ["topk_scores", "merge_topk", "two_stage_topk", "quantize_query",
           "coarse_topm"]

_NEG_INF = float("-inf")


def _chunk_merge(b, excl, k, n_items, block_i, chunk_scores):
    """Shared jnp chunk loop: ``chunk_scores(c0, c1) -> (B, c1-c0) fp32``.

    Mirrors the kernel exactly, including -inf/ghost-id padding of the
    tail chunk, so interpret-mode parity is bit-for-bit.
    """
    grid = -(-n_items // block_i)
    vals = idx = None
    for c in range(grid):
        c0, c1 = c * block_i, min((c + 1) * block_i, n_items)
        s = chunk_scores(c0, c1)                       # (B, c1-c0)
        if c1 - c0 < block_i:                          # tail: ghost rows
            s = jnp.pad(s, ((0, 0), (0, block_i - (c1 - c0))),
                        constant_values=-jnp.inf)
        ids = c0 + jnp.arange(block_i, dtype=jnp.int32)
        ids = jnp.broadcast_to(ids[None, :], (b, block_i))
        hit = jnp.any(excl[:, :, None] == ids[:, None, :], axis=1)
        s = jnp.where(hit, _NEG_INF, s)
        if vals is None:
            vals, p = jax.lax.top_k(s, k)
            idx = jnp.take_along_axis(ids, p, axis=1)
        else:
            all_v = jnp.concatenate([vals, s], axis=1)
            all_i = jnp.concatenate([idx, ids], axis=1)
            vals, p = jax.lax.top_k(all_v, k)
            idx = jnp.take_along_axis(all_i, p, axis=1)
    return vals, idx


def _dot(q, xhat):
    return jax.lax.dot_general(
        q, xhat, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "dim", "k", "n_items",
                                             "block_i", "interpret"))
def _fused(q, packed, scale, zero, excl, *, bits, dim, k, n_items, block_i,
           interpret):
    TRACE_COUNTS["topk_fused"] += 1   # trace-time: engine no-retrace tests
    return _tk.fused_topk_scores(
        q, packed, scale, zero, excl, bits=bits, dim=dim, k=k,
        n_items=n_items, block_i=block_i, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "dim", "k", "n_items",
                                             "block_i"))
def _jnp_packed(q, packed, scale, zero, excl, *, bits, dim, k, n_items,
                block_i):
    TRACE_COUNTS["topk_jnp"] += 1

    def chunk_scores(c0, c1):
        codes = unpack_bits(packed[c0:c1], bits, dim).astype(jnp.float32)
        return _dot(q, codes * scale[c0:c1] + zero[c0:c1])

    return _chunk_merge(q.shape[0], excl, k, n_items, block_i, chunk_scores)


@functools.partial(jax.jit, static_argnames=("k", "n_items", "block_i"))
def _jnp_dense(q, items, excl, *, k, n_items, block_i):
    TRACE_COUNTS["topk_jnp"] += 1
    return _chunk_merge(q.shape[0], excl, k, n_items, block_i,
                        lambda c0, c1: _dot(q, items[c0:c1]
                                            .astype(jnp.float32)))


@functools.partial(jax.jit, static_argnames=("bits", "dim", "m", "n_items",
                                             "block_i", "interpret"))
def _coarse_fused(q8, qmeta, packed, scale, zero, excl, *, bits, dim, m,
                  n_items, block_i, interpret):
    TRACE_COUNTS["coarse_fused"] += 1
    return _tk.fused_coarse_topm(
        q8, qmeta, packed, scale, zero, excl, bits=bits, dim=dim, m=m,
        n_items=n_items, block_i=block_i, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "dim", "m", "n_items",
                                             "block_i"))
def _coarse_jnp(q8, qmeta, packed, scale, zero, excl, *, bits, dim, m,
                n_items, block_i):
    TRACE_COUNTS["coarse_jnp"] += 1

    def chunk_scores(c0, c1):
        codes = unpack_bits(packed[c0:c1], bits, dim).astype(jnp.float32)
        dot = _dot(q8, codes)        # integer-valued fp32: exact
        scale_t = jnp.transpose(scale[c0:c1])          # (1, c1-c0)
        zero_t = jnp.transpose(zero[c0:c1])
        # identical op sequence to _coarse_kernel -> zero-ulp parity
        return dot * (qmeta[:, 0:1] * scale_t) + qmeta[:, 1:2] * zero_t

    return _chunk_merge(q8.shape[0], excl, m, n_items, block_i, chunk_scores)


@jax.jit
def quantize_query(q: jax.Array):
    """Symmetric INT8 query codes for the coarse scan.

    Returns ``(q8, qmeta)``: ``q8`` the rounded codes as integer-valued
    fp32 in [-127, 127], ``qmeta`` (B, 2) holding per-row ``[qs, Σ_j
    q_j]`` with ``qs = max|q|/127``. The coarse score's only deviation
    from the true fp32 score is the rounding of ``q`` — |q_j - qs·q8_j|
    <= qs/2 per element (DESIGN.md §14 turns that into the candidate-
    miss bound).
    """
    q = jnp.asarray(q, jnp.float32)
    qs = jnp.maximum(jnp.max(jnp.abs(q), axis=-1, keepdims=True),
                     1e-12) / 127.0
    q8 = jnp.clip(jnp.round(q / qs), -127.0, 127.0)
    qmeta = jnp.concatenate([qs, jnp.sum(q, axis=-1, keepdims=True)],
                            axis=-1)
    return q8, qmeta


@functools.partial(jax.jit, static_argnames=("bits", "dim", "k"))
def _rerank(q, packed, scale, zero, cand, excl, *, bits, dim, k):
    """fp32 dequant·score·top-k over the per-user candidate rows only.

    ``cand`` (B, m) MUST be ascending per row: ``lax.top_k`` breaks ties
    by lowest position, so ascending candidates make the local tie order
    the global lowest-index order — the single-stage contract.
    """
    codes = unpack_bits(packed[cand], bits, dim).astype(jnp.float32)
    xhat = codes * scale[cand] + zero[cand]            # (B, m, dim)
    s = jnp.einsum("bd,bmd->bm", q, xhat,
                   preferred_element_type=jnp.float32)
    # re-apply exclusions: the coarse stage already -inf'd them, but when
    # m exceeds the non-excluded item count they still occupy slots
    hit = jnp.any(excl[:, :, None] == cand[:, None, :], axis=1)
    s = jnp.where(hit, _NEG_INF, s)
    v, p = jax.lax.top_k(s, k)
    return v, jnp.take_along_axis(cand, p, axis=1)


def coarse_topm(q: jax.Array, items: QTensor, m: int, *, exclude=None,
                backend: str = "pallas", block_i: int = 1024,
                interpret: bool | None = None):
    """Top-``m`` candidate ids by coarse packed-domain score.

    The jnp and pallas backends agree BIT-exactly (integer-valued fp32
    arithmetic end to end — see kernels/topk_score.py). Returns
    (coarse values (B, m) fp32, indices (B, m) int32).
    """
    if not isinstance(items, QTensor):
        raise ValueError("coarse_topm needs a packed (QTensor) item table; "
                         "fp32 stores have no packed domain to scan")
    q8, qmeta = quantize_query(q)
    b = q8.shape[0]
    if exclude is None:
        exclude = jnp.full((b, 1), -1, jnp.int32)
    exclude = jnp.asarray(exclude, jnp.int32)
    n_items = items.packed.shape[0]
    assert m <= n_items, (m, n_items)
    block_i = max(min(block_i, n_items), m)
    whole = items.packed.shape[-1] * (8 // items.bits) == items.dim
    if backend == "pallas" and whole:
        return _coarse_fused(q8, qmeta, items.packed, items.scale,
                             items.zero, exclude, bits=items.bits,
                             dim=items.dim, m=m, n_items=n_items,
                             block_i=block_i,
                             interpret=INTERPRET if interpret is None
                             else interpret)
    return _coarse_jnp(q8, qmeta, items.packed, items.scale, items.zero,
                       exclude, bits=items.bits, dim=items.dim, m=m,
                       n_items=n_items, block_i=block_i)


def two_stage_topk(q: jax.Array, items: QTensor, k: int, *, c: int = 4,
                   exclude=None, backend: str = "pallas",
                   block_i: int = 1024, stage_cb=None):
    """Two-stage retrieval: coarse packed scan -> fp32 re-rank of c·k.

    q       : (B, d) fp32 query rows
    items   : packed ``QTensor`` store table (fp32 stores must use
              single-stage ``topk_scores`` — there is no packed domain)
    c       : candidate multiplier; ``m = min(c*k, n_items)`` rows are
              dequantized, every other row is touched ONLY as packed
              codes. ``c*k >= n_items`` reproduces single-stage results
              exactly (all items become candidates).
    stage_cb: optional ``f(stage_name, seconds)`` — when set, each stage
              is synchronized and timed (the engine's per-stage latency
              reservoirs); leave None for async dispatch.
    returns (values (B, k) fp32, indices (B, k) int32).
    """
    import time as _time

    if not isinstance(items, QTensor):
        raise ValueError("two_stage_topk needs a packed (QTensor) item "
                         "table; use topk_scores for fp32 stores")
    q = jnp.asarray(q, jnp.float32)
    b = q.shape[0]
    if exclude is None:
        exclude = jnp.full((b, 1), -1, jnp.int32)
    exclude = jnp.asarray(exclude, jnp.int32)
    n_items = items.packed.shape[0]
    assert k <= n_items, (k, n_items)
    m = max(k, min(c * k, n_items))

    t0 = _time.perf_counter() if stage_cb else None
    _, cand = coarse_topm(q, items, m, exclude=exclude, backend=backend,
                          block_i=block_i)
    # ascending candidate ids per row: local top_k tie order == global
    cand = jnp.sort(cand, axis=1)
    if stage_cb:
        cand.block_until_ready()
        stage_cb("coarse", _time.perf_counter() - t0)
        t0 = _time.perf_counter()
    out = _rerank(q, items.packed, items.scale, items.zero, cand, exclude,
                  bits=items.bits, dim=items.dim, k=k)
    if stage_cb:
        jax.block_until_ready(out)
        stage_cb("rerank", _time.perf_counter() - t0)
    return out


def topk_scores(q: jax.Array, items, k: int, *, exclude=None,
                backend: str = "pallas", block_i: int = 1024,
                interpret: bool | None = None):
    """Top-K items for a batch of query vectors against a store table.

    q       : (B, d) fp32 query rows (``store.user_vectors(...)``)
    items   : ``QTensor`` (packed store table) or fp32 ``(I, d)`` array
    exclude : optional (B, P) int32 per-row item-id lists (-1 pads) whose
              scores are forced to -inf BEFORE the merge — exactly the
              dense reference's ``where(train_mask, -inf)``
    backend : "pallas" (fused kernel; packed whole-chunk stores only) or
              "jnp". fp32 tables and odd-dim padded packs always take
              the jnp path.
    returns (values (B, k) fp32, indices (B, k) int32) — the chunked
    merge is lossless (== ``jax.lax.top_k`` over the chunk-computed
    score row, ties included); vs an independently-computed dense score
    matrix, values agree to fp32 matmul tolerance (reduction order).
    """
    q = jnp.asarray(q, jnp.float32)
    b = q.shape[0]
    if exclude is None:
        exclude = jnp.full((b, 1), -1, jnp.int32)
    exclude = jnp.asarray(exclude, jnp.int32)
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")

    if isinstance(items, QTensor):
        n_items = items.packed.shape[0]
        assert k <= n_items, (k, n_items)
        whole = items.packed.shape[-1] * (8 // items.bits) == items.dim
        if backend == "pallas" and whole:
            return _fused(q, items.packed, items.scale, items.zero, exclude,
                          bits=items.bits, dim=items.dim, k=k,
                          n_items=n_items,
                          block_i=max(min(block_i, n_items), k),
                          interpret=INTERPRET if interpret is None
                          else interpret)
        if whole:
            return _jnp_packed(q, items.packed, items.scale, items.zero,
                               exclude, bits=items.bits, dim=items.dim, k=k,
                               n_items=n_items,
                               block_i=max(min(block_i, n_items), k))
        # odd-dim padded pack: per-row dequant, dense-chunk path
        from repro.core.quant import dequantize
        items = dequantize(items).astype(jnp.float32)

    items = jnp.asarray(items, jnp.float32)
    n_items = items.shape[0]
    assert k <= n_items, (k, n_items)
    return _jnp_dense(q, items, exclude, k=k, n_items=n_items,
                      block_i=max(min(block_i, n_items), k))


def merge_topk(vals_parts, idx_parts, k: int):
    """Host-side merge of per-shard top-K results (numpy).

    Each part is (B, k_i) from a scorer call over a disjoint item shard
    (indices already global).

    ORDERING CONTRACT (deterministic, shard-count invariant): the merged
    result is sorted by ``(score descending, global index ascending)`` —
    the same tie rule as ``jax.lax.top_k`` and the in-call chunk merge.
    ``np.lexsort((idx, -vals))`` sorts primarily on ``-vals`` (score
    desc) and breaks EXACT score ties on the global index (asc),
    regardless of which shard part a candidate arrived in or the order
    the parts were concatenated. Because per-item scores are computed
    independently of shard geometry, merging S shard results is
    bit-identical to the single-shard ranking — ties included — for any
    S; composing merges (shards of shards) preserves the same order.
    Pinned by ``tests/test_serving.py`` at 1/2/4 shards on exact
    (integer-valued) inputs with massive tie mass.
    """
    vals = np.concatenate([np.asarray(v) for v in vals_parts], axis=1)
    idx = np.concatenate([np.asarray(i) for i in idx_parts], axis=1)
    order = np.lexsort((idx, -vals), axis=-1)[:, :k]
    return (np.take_along_axis(vals, order, axis=1),
            np.take_along_axis(idx, order, axis=1))
