"""Chunked top-K candidate scoring over a packed store.

Never builds the ``(U, I)`` score matrix: items stream through in
``block_i``-row chunks and only a running top-K per query survives each
merge. Two backends with a BIT-EXACT contract between them:

  * ``pallas`` — the fused dequant·score·top-K kernel
    (``kernels/topk_score.py``): packed uint8 rows are shift+mask
    unpacked in VMEM, scored on the MXU, merged in-kernel.
  * ``jnp``    — the same chunk/merge schedule in plain jnp (and the
    only path for fp32 stores / odd-dim padded packs). Both backends
    run the identical op sequence per chunk, so in interpret mode the
    results match bit-for-bit — the parity test in
    tests/test_serving.py holds to zero ulps.

Tie semantics are those of ``jax.lax.top_k`` (lowest index wins), which
the chunked merge preserves exactly — see kernels/topk_score.py for the
argument, tests/test_serving.py for the boundary-tie property test.

``merge_topk`` is the HOST-side merge for results that were produced by
*separate* scorer calls (item shards too big for one call, or the
engine fanning a store across processes): same (value desc, index asc)
order, so composing call-level merges stays exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QTensor, unpack_bits
from repro.kernels import topk_score as _tk
from repro.kernels.ops import INTERPRET, TRACE_COUNTS

__all__ = ["topk_scores", "merge_topk"]

_NEG_INF = float("-inf")


def _chunk_merge(q, excl, k, n_items, block_i, chunk_rows):
    """Shared jnp chunk loop: ``chunk_rows(c0, c1) -> (rows, dim) fp32``.

    Mirrors the kernel exactly, including -inf/ghost-id padding of the
    tail chunk, so interpret-mode parity is bit-for-bit.
    """
    b = q.shape[0]
    grid = -(-n_items // block_i)
    vals = idx = None
    for c in range(grid):
        c0, c1 = c * block_i, min((c + 1) * block_i, n_items)
        xhat = chunk_rows(c0, c1)
        s = jax.lax.dot_general(
            q, xhat, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (B, c1-c0)
        if c1 - c0 < block_i:                          # tail: ghost rows
            s = jnp.pad(s, ((0, 0), (0, block_i - (c1 - c0))),
                        constant_values=-jnp.inf)
        ids = c0 + jnp.arange(block_i, dtype=jnp.int32)
        ids = jnp.broadcast_to(ids[None, :], (b, block_i))
        hit = jnp.any(excl[:, :, None] == ids[:, None, :], axis=1)
        s = jnp.where(hit, _NEG_INF, s)
        if vals is None:
            vals, p = jax.lax.top_k(s, k)
            idx = jnp.take_along_axis(ids, p, axis=1)
        else:
            all_v = jnp.concatenate([vals, s], axis=1)
            all_i = jnp.concatenate([idx, ids], axis=1)
            vals, p = jax.lax.top_k(all_v, k)
            idx = jnp.take_along_axis(all_i, p, axis=1)
    return vals, idx


@functools.partial(jax.jit, static_argnames=("bits", "dim", "k", "n_items",
                                             "block_i", "interpret"))
def _fused(q, packed, scale, zero, excl, *, bits, dim, k, n_items, block_i,
           interpret):
    TRACE_COUNTS["topk_fused"] += 1   # trace-time: engine no-retrace tests
    return _tk.fused_topk_scores(
        q, packed, scale, zero, excl, bits=bits, dim=dim, k=k,
        n_items=n_items, block_i=block_i, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "dim", "k", "n_items",
                                             "block_i"))
def _jnp_packed(q, packed, scale, zero, excl, *, bits, dim, k, n_items,
                block_i):
    TRACE_COUNTS["topk_jnp"] += 1

    def chunk_rows(c0, c1):
        codes = unpack_bits(packed[c0:c1], bits, dim).astype(jnp.float32)
        return codes * scale[c0:c1] + zero[c0:c1]

    return _chunk_merge(q, excl, k, n_items, block_i, chunk_rows)


@functools.partial(jax.jit, static_argnames=("k", "n_items", "block_i"))
def _jnp_dense(q, items, excl, *, k, n_items, block_i):
    TRACE_COUNTS["topk_jnp"] += 1
    return _chunk_merge(q, excl, k, n_items, block_i,
                        lambda c0, c1: items[c0:c1].astype(jnp.float32))


def topk_scores(q: jax.Array, items, k: int, *, exclude=None,
                backend: str = "pallas", block_i: int = 1024,
                interpret: bool | None = None):
    """Top-K items for a batch of query vectors against a store table.

    q       : (B, d) fp32 query rows (``store.user_vectors(...)``)
    items   : ``QTensor`` (packed store table) or fp32 ``(I, d)`` array
    exclude : optional (B, P) int32 per-row item-id lists (-1 pads) whose
              scores are forced to -inf BEFORE the merge — exactly the
              dense reference's ``where(train_mask, -inf)``
    backend : "pallas" (fused kernel; packed whole-chunk stores only) or
              "jnp". fp32 tables and odd-dim padded packs always take
              the jnp path.
    returns (values (B, k) fp32, indices (B, k) int32) — the chunked
    merge is lossless (== ``jax.lax.top_k`` over the chunk-computed
    score row, ties included); vs an independently-computed dense score
    matrix, values agree to fp32 matmul tolerance (reduction order).
    """
    q = jnp.asarray(q, jnp.float32)
    b = q.shape[0]
    if exclude is None:
        exclude = jnp.full((b, 1), -1, jnp.int32)
    exclude = jnp.asarray(exclude, jnp.int32)
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")

    if isinstance(items, QTensor):
        n_items = items.packed.shape[0]
        assert k <= n_items, (k, n_items)
        whole = items.packed.shape[-1] * (8 // items.bits) == items.dim
        if backend == "pallas" and whole:
            return _fused(q, items.packed, items.scale, items.zero, exclude,
                          bits=items.bits, dim=items.dim, k=k,
                          n_items=n_items,
                          block_i=max(min(block_i, n_items), k),
                          interpret=INTERPRET if interpret is None
                          else interpret)
        if whole:
            return _jnp_packed(q, items.packed, items.scale, items.zero,
                               exclude, bits=items.bits, dim=items.dim, k=k,
                               n_items=n_items,
                               block_i=max(min(block_i, n_items), k))
        # odd-dim padded pack: per-row dequant, dense-chunk path
        from repro.core.quant import dequantize
        items = dequantize(items).astype(jnp.float32)

    items = jnp.asarray(items, jnp.float32)
    n_items = items.shape[0]
    assert k <= n_items, (k, n_items)
    return _jnp_dense(q, items, exclude, k=k, n_items=n_items,
                      block_i=max(min(block_i, n_items), k))


def merge_topk(vals_parts, idx_parts, k: int):
    """Host-side merge of per-shard top-K results (numpy).

    Each part is (B, k_i) from a scorer call over a disjoint item shard
    (indices already global). Order is (value desc, index asc) — the
    same tie rule as ``jax.lax.top_k`` — so shard-merge composes exactly
    with the in-call chunk merge.
    """
    vals = np.concatenate([np.asarray(v) for v in vals_parts], axis=1)
    idx = np.concatenate([np.asarray(i) for i in idx_parts], axis=1)
    order = np.lexsort((idx, -vals), axis=-1)[:, :k]
    return (np.take_along_axis(vals, order, axis=1),
            np.take_along_axis(idx, order, axis=1))
