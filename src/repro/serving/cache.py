"""Hot-user result cache: version-stamped LRU over engine responses.

Zipfian retrieval traffic concentrates on a small head of hot users; a
recommendation for a user is a pure function of (user row, item table),
so until a refresh changes either, the engine can replay the previous
answer instead of re-scanning the store. The cache is a bounded LRU of
``user_id -> (store_version, values, indices)``:

  * entries are stamped with the store version that produced them, and
    the engine invalidates EAGERLY at refresh time (`drop` for changed
    user rows, `clear` when any item row changed) — a stale entry is
    structurally unreachable, and the stamp makes the protocol auditable
    (tests assert a served hit's stamp matches the live version);
  * all mutation happens on the engine's single worker thread (lookups
    during batch drain, invalidation during refresh application), so the
    cache itself needs no lock; the hit/miss counters it feeds are
    registry metrics, safe to read from any thread.

Invalidation rules (DESIGN.md §14): a refresh that touches item rows
invalidates EVERY entry (all rankings depend on the whole item table); a
refresh that touches only user rows invalidates exactly those users.
Unchanged users therefore keep serving identical, still-correct results
across a user-delta refresh — the property the tier-2 tests pin.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.obs import get_registry

__all__ = ["ResultCache"]


class ResultCache:
    """Bounded LRU of per-user top-K results (see module docstring)."""

    def __init__(self, capacity: int, *, registry=None, label: str = "cache"):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._od: OrderedDict[int, tuple] = OrderedDict()
        reg = registry if registry is not None else get_registry()
        self._m_hits = reg.counter("serve/cache_hits", engine=label)
        self._m_misses = reg.counter("serve/cache_misses", engine=label)
        self._m_size = reg.gauge("serve/cache_size", engine=label)
        self._m_evict = reg.counter("serve/cache_evictions", engine=label)
        self._m_inval = reg.counter("serve/cache_invalidations", engine=label)

    def __len__(self) -> int:
        return len(self._od)

    def get(self, user_id: int):
        """Hit -> (version, values, indices); miss -> None. Meters both."""
        ent = self._od.get(int(user_id))
        if ent is None:
            self._m_misses.inc()
            return None
        self._od.move_to_end(int(user_id))
        self._m_hits.inc()
        return ent

    def put(self, user_id: int, version: int, vals, idx) -> None:
        uid = int(user_id)
        self._od[uid] = (int(version), np.asarray(vals), np.asarray(idx))
        self._od.move_to_end(uid)
        while len(self._od) > self.capacity:
            self._od.popitem(last=False)
            self._m_evict.inc()
        self._m_size.set(float(len(self._od)))

    def drop(self, user_ids) -> int:
        """Invalidate specific users (user-row delta); returns # dropped."""
        n = 0
        for uid in user_ids:
            if self._od.pop(int(uid), None) is not None:
                n += 1
        self._m_inval.inc(n)
        self._m_size.set(float(len(self._od)))
        return n

    def clear(self) -> int:
        """Invalidate everything (item rows changed); returns # dropped."""
        n = len(self._od)
        self._od.clear()
        self._m_inval.inc(n)
        self._m_size.set(0.0)
        return n

    @property
    def hit_rate(self) -> float:
        h, m = self._m_hits.value, self._m_misses.value
        return h / (h + m) if (h + m) else 0.0
