"""Streaming full-ranking evaluation over the chunked scorer.

``training.metrics.recall_ndcg_at_k`` is the exactness reference; its
dense protocol materializes a ``(U, I)`` score matrix plus two ``(U, I)``
boolean masks, which caps full-ranking eval at toy graphs. This
evaluator computes the SAME quantities user-chunk by user-chunk over
``scorer.topk_scores``:

  * scores stream item-chunk-wise (never (U, I));
  * train-positive exclusion is the scorer's per-user index lists — the
    -inf placement is identical to the dense ``where(train_mask, -inf)``;
  * the retrieved top-K preserves dense ``lax.top_k`` semantics exactly
    (lowest-index tie order survives every chunk merge — see scorer.py);
    score values can differ from a dense matmul by reduction-order ulps,
    which moves hit positions only on sub-ulp near-ties;
  * per-user recall/NDCG use the reference formulas verbatim and are
    sum-accumulated, with the valid-user division at the end — the same
    mean over the same user set.

With an fp32 store the two paths agree to <= 1e-6 (tested); with a
quantized store the evaluator reports the metrics of the embeddings the
server actually ships, i.e. it agrees with the dense reference applied
to the dequantized tables.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .scorer import topk_scores, two_stage_topk
from .store import QuantizedEmbeddingStore, padded_pos_lists

__all__ = ["streaming_recall_ndcg", "streaming_eval_dataset"]


def streaming_recall_ndcg(store: QuantizedEmbeddingStore,
                          train_pos: np.ndarray, test_pos: np.ndarray, *,
                          k: int = 20, user_chunk: int = 128,
                          backend: str = "pallas", block_i: int = 1024,
                          two_stage_c: int | None = None):
    """Recall@k / NDCG@k over the full item set, streamed.

    train_pos/test_pos : (n, 2) int [user, item] pairs. Training
    positives are excluded from ranking (paper protocol); users with no
    test positive are excluded from the mean. Returns (recall, ndcg).

    two_stage_c routes retrieval through the two-stage path (coarse
    packed-domain scan keeping C·k candidates, fp32 re-rank) so the
    recall-vs-C tradeoff is measured with the exact eval protocol; at
    C >= n_items/k it matches the single-stage result.
    """
    n_users = store.n_users
    excl = padded_pos_lists(train_pos, n_users)            # (U, P)
    test = padded_pos_lists(test_pos, n_users)             # (U, T)
    n_test = (test >= 0).sum(axis=1)                       # (U,)

    discounts = 1.0 / np.log2(np.arange(k) + 2.0)          # (k,)
    sum_recall = sum_ndcg = 0.0
    n_valid = 0
    excl_j = jnp.asarray(excl)
    for u0 in range(0, n_users, user_chunk):
        u1 = min(u0 + user_chunk, n_users)
        q = store.user_vectors(jnp.arange(u0, u1))
        if two_stage_c is not None:
            _, idx = two_stage_topk(q, store.items, k, c=two_stage_c,
                                    exclude=excl_j[u0:u1],
                                    backend=backend, block_i=block_i)
        else:
            _, idx = topk_scores(q, store.items, k, exclude=excl_j[u0:u1],
                                 backend=backend, block_i=block_i)
        idx = np.asarray(idx)                              # (B, k)
        # hit iff the retrieved id is one of the user's test positives
        hits = (idx[:, :, None] == test[u0:u1, None, :]).any(-1)  # (B, k)
        nt = n_test[u0:u1]
        valid = nt > 0
        recall_u = hits.sum(1) / np.maximum(nt, 1)
        dcg = (hits * discounts).sum(1)
        ideal = np.arange(k)[None, :] < nt[:, None]
        idcg = (ideal * discounts).sum(1)
        ndcg_u = dcg / np.maximum(idcg, 1e-9)
        sum_recall += float(recall_u[valid].sum())
        sum_ndcg += float(ndcg_u[valid].sum())
        n_valid += int(valid.sum())
    denom = max(n_valid, 1)
    return sum_recall / denom, sum_ndcg / denom


def streaming_eval_dataset(store: QuantizedEmbeddingStore, ds, *,
                           k: int = 20, **kw):
    """Convenience wrapper over a ``data.synthetic.KGDataset``."""
    return streaming_recall_ndcg(store, ds.train_pos, ds.test_pos, k=k, **kw)
