"""Packed embedding store: the serving-side rollout (DESIGN.md §8).

A KGNN serves recommendations from its *final* user/item representations
— the model forward is an offline batch job, not a request-time cost. So
the serving artifact is two row tables: run ``kgnn.propagate`` once
(fp32, no ACT policy — the rollout is not a training step), slice users
and items out of the node space, and pack each table into the SAME
chunk-interleaved QTensor layout the training kernels read
(``kernels/quant_pack``, per-row scale/zero). INT8/INT4 by default;
``bits=None`` keeps fp32 rows (escape hatch and exactness baseline).

Rounding is NEAREST by default: stochastic rounding buys unbiasedness
*in expectation over training steps*; a serving store is quantized once,
so the lower-MSE deterministic rounding is the right default (the
``stochastic`` flag exists for ablations).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QTensor
from repro.core.quant import dequantize as core_dequantize
from repro.kernels import ops as kops

__all__ = ["QuantizedEmbeddingStore", "build_kgnn_store", "padded_pos_lists"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QuantizedEmbeddingStore:
    """User + item representation tables, packed for serving.

    ``users``/``items`` are either ``QTensor`` (packed, per-row
    scale/zero) or plain fp32 arrays (``bits=None`` escape hatch). Both
    are pytree children, so a store passes through ``jax.jit`` whole.
    """

    users: QTensor | jax.Array   # (U, d)
    items: QTensor | jax.Array   # (I, d)
    bits: int | None             # item-table bits; None = fp32 (static)
    dim: int

    def tree_flatten(self):
        return (self.users, self.items), (self.bits, self.dim)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def n_users(self) -> int:
        t = self.users
        return (t.packed if isinstance(t, QTensor) else t).shape[0]

    @property
    def n_items(self) -> int:
        t = self.items
        return (t.packed if isinstance(t, QTensor) else t).shape[0]

    @classmethod
    def from_arrays(cls, users: jax.Array, items: jax.Array, *,
                    bits: int | None = None, quantize_users: bool = True,
                    stochastic: bool = False,
                    seed: int = 0) -> "QuantizedEmbeddingStore":
        """Pack fp32 row tables at ``bits`` (None = keep fp32).

        ``quantize_users=False`` packs only the item table — the right
        call when "users" are per-request query vectors computed fresh
        (nothing stored long-term, so quantizing them only adds error);
        stored user-embedding tables keep the default and share the
        memory win.
        """
        users = jnp.asarray(users, jnp.float32)
        items = jnp.asarray(items, jnp.float32)
        assert users.shape[-1] == items.shape[-1], (users.shape, items.shape)
        dim = int(items.shape[-1])
        if bits is None:
            return cls(users, items, None, dim)
        key = jax.random.PRNGKey(seed)
        if quantize_users:
            users = kops.quantize(users, key, bits=bits,
                                  stochastic=stochastic)
        return cls(
            users=users,
            items=kops.quantize(items, jax.random.fold_in(key, 1), bits=bits,
                                stochastic=stochastic),
            bits=bits, dim=dim)

    def user_vectors(self, user_ids: jax.Array) -> jax.Array:
        """Dequantized fp32 query rows for a batch of user ids."""
        q = self.users
        if not isinstance(q, QTensor):
            return q[user_ids]
        rows = QTensor(packed=q.packed[user_ids], scale=q.scale[user_ids],
                       zero=q.zero[user_ids], bits=q.bits, dim=q.dim,
                       dtype=q.dtype)
        return core_dequantize(rows).astype(jnp.float32)

    def item_matrix(self) -> jax.Array:
        """Full dequantized (I, d) item table — test/debug only; the
        serving path reads the packed table directly."""
        if not isinstance(self.items, QTensor):
            return self.items
        return core_dequantize(self.items).astype(jnp.float32)

    def memory_report(self) -> dict:
        """Bytes ledger: packed payload + scale/zero overhead vs fp32."""
        def table_bytes(t):
            if isinstance(t, QTensor):
                payload = t.packed.size * t.packed.dtype.itemsize
                overhead = (t.scale.size + t.zero.size) * 4
                rows = t.packed.shape[0]
            else:
                payload = t.size * jnp.dtype(jnp.float32).itemsize
                overhead = 0
                rows = t.shape[0]
            return payload, overhead, rows

        up, uo, u_rows = table_bytes(self.users)
        ip, io_, i_rows = table_bytes(self.items)
        total = up + uo + ip + io_
        fp32 = (u_rows + i_rows) * self.dim * 4
        return {
            "bits": self.bits, "dim": self.dim,
            "n_users": u_rows, "n_items": i_rows,
            "packed_bytes": up + ip,
            "scale_zero_bytes": uo + io_,
            "total_bytes": total,
            "fp32_bytes": fp32,
            "compression_ratio": fp32 / total,
        }


def build_kgnn_store(params: dict, g, cfg, n_items: int, *,
                     bits: int | None = 8, stochastic: bool = False,
                     seed: int = 0) -> QuantizedEmbeddingStore:
    """Offline rollout: one fp32 ``propagate`` pass -> packed store.

    The CKG node space is [users | items | attrs] (data/synthetic.py);
    only users and items are served — attribute entities exist to shape
    the representations during propagation, not to be recommended.
    """
    from repro.models import kgnn

    reps = kgnn.propagate(params, g, cfg)   # fp32: no ambient ACT context
    users = reps[:cfg.n_users]
    items = reps[cfg.n_users:cfg.n_users + n_items]
    return QuantizedEmbeddingStore.from_arrays(
        users, items, bits=bits, stochastic=stochastic, seed=seed)


def padded_pos_lists(pos: np.ndarray, n_users: int, *,
                     pad: int = -1, min_width: int = 1) -> np.ndarray:
    """(n, 2) [user, item] pairs -> (U, P) per-user padded index lists.

    P = max positives per user (>= ``min_width`` so the array is never
    zero-width); pad value -1 never matches a real item id, so the lists
    drop straight into the scorer's exclusion input or the evaluator's
    membership test.
    """
    counts = np.zeros(n_users, np.int64)
    np.add.at(counts, pos[:, 0], 1)
    width = max(int(counts.max(initial=0)), min_width)
    out = np.full((n_users, width), pad, np.int32)
    cursor = np.zeros(n_users, np.int64)
    for u, i in pos:
        out[u, cursor[u]] = i
        cursor[u] += 1
    return out
