"""Reduced configs for CPU smoke tests (same family/topology, tiny dims).

``reduced(arch)`` preserves structure (GQA ratio, MoE routing, CIN stack,
field count) while shrinking width/depth/vocab so one forward/train step
runs on CPU in seconds. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct — no allocation), per the assignment.
"""

from __future__ import annotations

import dataclasses

from repro.models.moe import MoEConfig
from repro.models.recsys import RecsysConfig

from .base import ArchSpec


def reduced(arch: ArchSpec) -> ArchSpec:
    cfg = arch.model_cfg
    if arch.family in ("lm", "moe_lm"):
        kv_ratio = max(cfg.n_heads // cfg.n_kv_heads, 1)
        moe = None
        d_ff = 128
        if cfg.moe is not None:
            moe = MoEConfig(n_experts=min(cfg.moe.n_experts, 8),
                            top_k=min(cfg.moe.top_k, 2), d_ff=64)
            d_ff = 0
        small = dataclasses.replace(
            cfg, n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=max(4 // kv_ratio, 1), d_head=16, d_ff=d_ff,
            vocab=512, moe=moe, dtype="float32", q_chunk=16, kv_chunk=16)
        return dataclasses.replace(arch, model_cfg=small)
    if arch.family == "gnn":
        small = dataclasses.replace(cfg, d_in=32, d_hidden=16, n_classes=7)
        return dataclasses.replace(arch, model_cfg=small)
    if arch.family == "recsys":
        embed_dim = min(cfg.embed_dim, 16)
        bot = tuple(min(d, 32) for d in cfg.bot_mlp)
        if bot:
            bot = bot[:-1] + (embed_dim,)  # DLRM: bot output == embed dim
        small = RecsysConfig(
            model=cfg.model,
            n_sparse=cfg.n_sparse,
            vocab_sizes=tuple(min(v, 1000) for v in cfg.vocab_sizes),
            embed_dim=embed_dim,
            n_dense=cfg.n_dense,
            bot_mlp=bot,
            top_mlp=tuple(min(d, 32) for d in cfg.top_mlp),
            mlp=tuple(min(d, 32) for d in cfg.mlp),
            cin_layers=tuple(min(d, 16) for d in cfg.cin_layers),
            interaction=cfg.interaction,
        )
        return dataclasses.replace(arch, model_cfg=small)
    if arch.family == "kgnn":
        small = dataclasses.replace(cfg, n_users=40, n_entities=80,
                                    n_relations=10, dim=16, n_layers=2)
        return dataclasses.replace(arch, model_cfg=small)
    raise ValueError(arch.family)
