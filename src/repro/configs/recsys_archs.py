"""The four assigned recsys architectures with realistic vocabularies.

dlrm-mlperf uses the canonical MLPerf Criteo-1TB table sizes (26 tables,
~188M rows total). xdeepfm/fm use the 26 public Criteo-Kaggle field
cardinalities + 13 bucketized-dense fields = 39 sparse fields (the
standard treatment that matches n_sparse=39). wide-deep uses 40 fields
mixing user/context/item vocabularies per the paper's app-store setting.
"""

from repro.models.recsys import RecsysConfig

from .base import RECSYS_SHAPES, ArchSpec

# MLPerf DLRM (Criteo Terabyte, day-based, capped at 40M rows/table)
_MLPERF_TABLES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771, 25641295,
    39664984, 585935, 12972, 108, 36)

# Criteo-Kaggle categorical cardinalities (26 fields, public statistics)
_KAGGLE_TABLES = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572)
_DENSE_BUCKETS = (128,) * 13  # bucketized dense -> 13 small categorical
_KAGGLE39 = _KAGGLE_TABLES + _DENSE_BUCKETS

# wide&deep (Google Play setting): 40 fields — a few huge id spaces
# (user, item, developer), the rest small demographics/context
_WD_TABLES = (10_000_000, 2_000_000, 500_000, 100_000) + (10_000,) * 8 + \
    (1_000,) * 12 + (100,) * 16

WIDE_DEEP = ArchSpec(
    name="wide-deep",
    family="recsys",
    source="arXiv:1606.07792",
    model_cfg=RecsysConfig(
        model="wide_deep", n_sparse=40, vocab_sizes=_WD_TABLES,
        embed_dim=32, mlp=(1024, 512, 256), interaction="concat"),
    shapes=RECSYS_SHAPES,
)

DLRM_MLPERF = ArchSpec(
    name="dlrm-mlperf",
    family="recsys",
    source="arXiv:1906.00091 (MLPerf config)",
    model_cfg=RecsysConfig(
        model="dlrm", n_sparse=26, vocab_sizes=_MLPERF_TABLES,
        embed_dim=128, n_dense=13, bot_mlp=(512, 256, 128),
        top_mlp=(1024, 1024, 512, 256, 1), interaction="dot"),
    shapes=RECSYS_SHAPES,
)

XDEEPFM = ArchSpec(
    name="xdeepfm",
    family="recsys",
    source="arXiv:1803.05170",
    model_cfg=RecsysConfig(
        model="xdeepfm", n_sparse=39, vocab_sizes=_KAGGLE39,
        embed_dim=10, cin_layers=(200, 200, 200), mlp=(400, 400),
        interaction="cin"),
    shapes=RECSYS_SHAPES,
)

FM = ArchSpec(
    name="fm",
    family="recsys",
    source="Rendle ICDM'10",
    model_cfg=RecsysConfig(
        model="fm", n_sparse=39, vocab_sizes=_KAGGLE39, embed_dim=10,
        interaction="fm-2way"),
    shapes=RECSYS_SHAPES,
)
