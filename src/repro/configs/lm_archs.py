"""The five assigned LM architectures (exact configs from the pool).

d_head derivations: d_model / n_heads unless the source specifies
otherwise (mistral-large: 12288/96 = 128; codeqwen: 4096/32 = 128;
stablelm-12b: 5120/32 = 160; moonshot: 2048/16 = 128; grok: 6144/48 = 128).
"""

from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

from .base import LM_SHAPES, ArchSpec

MISTRAL_LARGE_123B = ArchSpec(
    name="mistral-large-123b",
    family="lm",
    source="hf:mistralai/Mistral-Large-Instruct-2407 (unverified)",
    serve_weight_2d=True,  # 123B bf16 does not fit 16 chips alone
    model_cfg=TransformerConfig(
        n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_head=128,
        d_ff=28672, vocab=32768, rope_theta=1e6, dtype="bfloat16",
        q_chunk=512, kv_chunk=1024),
    shapes=LM_SHAPES,
)

CODEQWEN15_7B = ArchSpec(
    name="codeqwen1.5-7b",
    family="lm",
    source="hf:Qwen/CodeQwen1.5-7B (qwen1.5 arch)",
    model_cfg=TransformerConfig(
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_head=128,
        d_ff=13440, vocab=92416, rope_theta=1e6, dtype="bfloat16",
        q_chunk=512, kv_chunk=1024),
    shapes=LM_SHAPES,
)

STABLELM_12B = ArchSpec(
    name="stablelm-12b",
    family="lm",
    source="hf:stabilityai/stablelm-2-12b",
    model_cfg=TransformerConfig(
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=160,
        d_ff=13824, vocab=100352, rope_theta=1e6, dtype="bfloat16",
        q_chunk=512, kv_chunk=1024),
    shapes=LM_SHAPES,
)

MOONSHOT_V1_16B_A3B = ArchSpec(
    name="moonshot-v1-16b-a3b",
    family="moe_lm",
    source="hf:moonshotai/Moonlight-16B-A3B (64e top-6)",
    model_cfg=TransformerConfig(
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=0, vocab=163840, rope_theta=1e6, dtype="bfloat16",
        q_chunk=512, kv_chunk=1024,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408)),
    shapes=LM_SHAPES,
)

GROK_1_314B = ArchSpec(
    name="grok-1-314b",
    family="moe_lm",
    source="hf:xai-org/grok-1 (8e top-2, unverified)",
    serve_weight_2d=True,  # 314B bf16 needs the full 256-chip set
    model_cfg=TransformerConfig(
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=0, vocab=131072, rope_theta=1e6, dtype="bfloat16",
        q_chunk=512, kv_chunk=1024,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32768)),
    shapes=LM_SHAPES,
)
