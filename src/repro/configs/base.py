"""Architecture + shape registry.

Every assigned architecture is one ``ArchSpec`` in its own module; the
registry in ``repro.configs`` resolves ``--arch <id>`` for the launcher,
dry-run, smoke tests and benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["ShapeSpec", "ArchSpec", "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode | serve | retrieval |
    #                  full_graph | minibatch | batched_graphs
    params: tuple    # sorted (key, value) pairs — hashable

    def p(self) -> dict:
        return dict(self.params)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str      # lm | moe_lm | gnn | recsys | kgnn
    model_cfg: Any
    shapes: tuple
    source: str = ""
    # weight sharding for serve shapes: big models need the full device set
    serve_weight_2d: bool = False

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name} has no shape {name}; "
                       f"have {[s.name for s in self.shapes]}")


def _s(name, kind, **kw) -> ShapeSpec:
    return ShapeSpec(name, kind, tuple(sorted(kw.items())))


LM_SHAPES = (
    _s("train_4k", "train", seq_len=4096, global_batch=256),
    _s("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    _s("decode_32k", "decode", seq_len=32768, global_batch=128),
    _s("long_500k", "decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES = (
    _s("full_graph_sm", "full_graph", n_nodes=2708, n_edges=10556,
       d_feat=1433, n_classes=7),
    _s("minibatch_lg", "minibatch", n_nodes=232965, n_edges=114615892,
       batch_nodes=1024, fanouts=(15, 10)),
    _s("ogb_products", "full_graph", n_nodes=2449029, n_edges=61859140,
       d_feat=100, n_classes=47),
    _s("molecule", "batched_graphs", n_nodes=30, n_edges=64, batch=128),
)

RECSYS_SHAPES = (
    _s("train_batch", "train", batch=65536),
    _s("serve_p99", "serve", batch=512),
    _s("serve_bulk", "serve", batch=262144),
    _s("retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000),
)
