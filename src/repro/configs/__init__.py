"""--arch registry: the 10 assigned architectures + the paper's own KGNNs."""

from .base import ArchSpec, ShapeSpec
from .gcn_cora import GCN_CORA
from .kgnn_paper import KGAT, KGCN, KGIN
from .lm_archs import (
    CODEQWEN15_7B,
    GROK_1_314B,
    MISTRAL_LARGE_123B,
    MOONSHOT_V1_16B_A3B,
    STABLELM_12B,
)
from .recsys_archs import DLRM_MLPERF, FM, WIDE_DEEP, XDEEPFM

ARCHS = {a.name: a for a in [
    MISTRAL_LARGE_123B, CODEQWEN15_7B, STABLELM_12B, MOONSHOT_V1_16B_A3B,
    GROK_1_314B,
    GCN_CORA,
    WIDE_DEEP, DLRM_MLPERF, XDEEPFM, FM,
    KGAT, KGCN, KGIN,
]}

ASSIGNED = [n for n in ARCHS if n not in ("kgat", "kgcn", "kgin")]


def get(name: str) -> ArchSpec:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name}; have {sorted(ARCHS)}")
    return ARCHS[name]
