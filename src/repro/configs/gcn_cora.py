"""gcn-cora [arXiv:1609.02907]: 2 layers, d_hidden=16, mean/sym-norm."""

from repro.models.gnn import GCNConfig

from .base import GNN_SHAPES, ArchSpec

GCN_CORA = ArchSpec(
    name="gcn-cora",
    family="gnn",
    source="arXiv:1609.02907 (Kipf & Welling)",
    model_cfg=GCNConfig(n_layers=2, d_in=1433, d_hidden=16, n_classes=7,
                        aggregator="mean", norm="sym"),
    shapes=GNN_SHAPES,
)
