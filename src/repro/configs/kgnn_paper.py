"""The paper's own KGNN configs (§4.1.4: dim 64, 3 layers, Amazon-Book-scale)."""

from repro.models.kgnn import KGNNConfig

from .base import ArchSpec, _s

# Amazon-Book statistics from paper Table 1
_AB = dict(n_users=70679, n_entities=88572 + 24915, n_relations=2 * 39 + 2)

_KG_SHAPES = (
    _s("paper_full", "kgnn_train", n_triples=2 * 2557746 + 2 * 847733,
       batch=1024),
    _s("bench_small", "kgnn_train", n_triples=40000, batch=1024),
)

KGAT = ArchSpec(
    name="kgat", family="kgnn", source="arXiv:1905.07854 / paper §4.1.2",
    model_cfg=KGNNConfig(model="kgat", dim=64, n_layers=3, n_bases=4,
                         readout="concat", **_AB),
    shapes=_KG_SHAPES,
)
KGCN = ArchSpec(
    name="kgcn", family="kgnn", source="KGNN-LS arXiv:1905.04413",
    model_cfg=KGNNConfig(model="kgcn", dim=64, n_layers=3, readout="sum",
                         **_AB),
    shapes=_KG_SHAPES,
)
KGIN = ArchSpec(
    name="kgin", family="kgnn", source="arXiv:2102.07057",
    model_cfg=KGNNConfig(model="kgin", dim=64, n_layers=3, n_intents=4,
                         readout="sum", **_AB),
    shapes=_KG_SHAPES,
)
