"""Knowledge Graph Neural Networks (the paper's evaluation targets, §4.1.2).

Implements the three baselines TinyKG is evaluated on — KGAT, KGCN/KGNN-LS,
KGIN — plus R-GCN, over a collaborative knowledge graph (CKG): users, items
and attribute entities are one node space; user-item interactions are
`interact` relations merged with the item KG (paper §3.1).

Message passing defaults to ``jax.ops.segment_sum`` over COO edge lists,
with a blocked-CSR fused-Pallas path (``repro.data.csr`` + DESIGN.md §4)
under ``kernel="pallas"`` policies, and is ACT-compressed end-to-end:

  * ``act_spmm``    — weighted neighbor aggregation; saves Quant(E^(l))
  * ``act_matmul``  — layer transform ∇Θ = Ĥᵀ∇J; saves Quant(H^(l))
  * ``act_nonlin``  — σ(J); saves Quant(J^(l))

which is exactly the ctx(·) chain in paper Eq. (2). Edge-softmax
probabilities are (E,)-scalars (no feature dim) and stay fp32 — they are
O(E) not O(N·d), i.e. the "trivial" footprint class of the paper's
memory analysis.

Every op site carries a named scope (``"kgat/layer2/spmm"``): the ambient
``ActContext`` resolves its per-site policy from a ``PolicySchedule`` and
derives its stochastic-rounding key from the scope hash (DESIGN.md §6),
and the residual trace replaces the old hand-maintained
``activation_shapes`` tables for memory accounting.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import (
    ACTPolicy,
    FP32,
    PolicySchedule,
    act_matmul,
    act_nonlin,
    act_spmm,
    model_context,
)
from .layers import glorot, normal_init

__all__ = [
    "KGNNConfig", "CKG", "segment_softmax", "kgat_bi_interaction",
    "init_params", "propagate", "score_pairs", "bpr_loss",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CKG:
    """Collaborative knowledge graph in COO form (inverse edges included).

    ``n_nodes``/``n_relations`` are pytree aux data — static under jit
    (segment_sum needs static segment counts). ``layout`` optionally
    carries the blocked-CSR arrangement of the same edge list
    (``repro.data.csr.attach_layout``) that routes ``act_spmm`` through
    the fused Pallas kernels under ``ACTPolicy(kernel="pallas")``.
    """

    src: jax.Array  # (E,) int32 node ids
    dst: jax.Array  # (E,) int32 node ids
    rel: jax.Array  # (E,) int32 relation ids
    n_nodes: int    # users + entities (static)
    n_relations: int
    layout: object | None = None  # SpmmLayout (itself a pytree) or None

    def tree_flatten(self):
        return (self.src, self.dst, self.rel, self.layout), (
            self.n_nodes, self.n_relations)

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, rel, layout = children
        return cls(src, dst, rel, aux[0], aux[1], layout)


@dataclasses.dataclass(frozen=True)
class KGNNConfig:
    model: str = "kgat"          # kgat | kgcn | kgin | rgcn
    n_users: int = 0
    n_entities: int = 0          # items + attribute entities
    n_relations: int = 0         # incl. `interact`, both directions
    dim: int = 64                # embedding size (paper fixes 64)
    n_layers: int = 3            # paper fixes 3
    layer_dims: tuple = ()       # per-layer out dims; default = dim each
    n_intents: int = 4           # KGIN
    n_bases: int = 4             # R-GCN basis decomposition
    l2: float = 1e-5
    readout: str = "concat"      # concat (KGAT) | sum (KGIN) | last

    @property
    def n_nodes(self) -> int:
        return self.n_users + self.n_entities

    @property
    def dims(self) -> tuple:
        return self.layer_dims or (self.dim,) * self.n_layers


def segment_softmax(logits: jax.Array, seg: jax.Array, num_segments: int):
    """Numerically-stable softmax over segments (edge softmax)."""
    mx = jax.ops.segment_max(logits, seg, num_segments=num_segments)
    ex = jnp.exp(logits - mx[seg])
    den = jax.ops.segment_sum(ex, seg, num_segments=num_segments)
    return ex / (den[seg] + 1e-16)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: KGNNConfig) -> dict:
    ks = iter(jax.random.split(key, 64))
    d = cfg.dim
    p = {
        "entity": normal_init(next(ks), (cfg.n_nodes, d), 0.1),
        "relation": normal_init(next(ks), (cfg.n_relations, d), 0.1),
    }
    dims = (d,) + cfg.dims
    if cfg.model == "kgat":
        # relation-space projection for attention (TransR style). The paper
        # uses a dense d×d W_r per relation; gathering it per edge is an
        # (E,d,d) tensor — infeasible at industry scale. We keep the
        # relation-specific d×d structure via basis decomposition
        # W_r = Σ_b a_rb V_b (R-GCN trick): project once per basis (B·N·d),
        # mix per edge with (E,B) coefficients. See DESIGN.md §3.
        p["att_basis"] = normal_init(next(ks), (cfg.n_bases, d, d), 0.1)
        p["att_coef"] = normal_init(next(ks), (cfg.n_relations, cfg.n_bases), 0.1)
        p["w1"] = [glorot(next(ks), (a, b)) for a, b in zip(dims[:-1], dims[1:])]
        p["w2"] = [glorot(next(ks), (a, b)) for a, b in zip(dims[:-1], dims[1:])]
    elif cfg.model == "kgcn":
        p["w"] = [glorot(next(ks), (a, b)) for a, b in zip(dims[:-1], dims[1:])]
        p["b"] = [jnp.zeros((b,)) for b in dims[1:]]
    elif cfg.model == "kgin":
        p["intent"] = normal_init(next(ks), (cfg.n_intents, cfg.n_relations), 0.1)
    elif cfg.model == "rgcn":
        p["basis"] = normal_init(next(ks), (cfg.n_bases, d, d), 0.1)
        p["coef"] = normal_init(next(ks), (cfg.n_relations, cfg.n_bases), 0.1)
        p["w_self"] = [glorot(next(ks), (d, d)) for _ in range(cfg.n_layers)]
    else:
        raise ValueError(cfg.model)
    return p


# ---------------------------------------------------------------------------
# propagation (paper Eq. 1/2)
# ---------------------------------------------------------------------------


def kgat_bi_interaction(p, layer: int, e: jax.Array, e_n: jax.Array, *,
                        keys: dict | None = None,
                        policies: dict | None = None) -> jax.Array:
    """Bi-interaction aggregator: LeakyReLU(W1(e+eN)) + LeakyReLU(W2(e⊙eN)).

    The single source of the (e, e_n) -> layer-output math for every
    KGAT path. With ``keys``/``policies`` omitted the ``w1``/``w2``/
    ``act1``/``act2`` sites resolve from the ambient ActContext; the
    explicitly-partitioned paths (shard_map bodies, where ambient
    resolution can't reach) pass per-site dicts instead — the DP
    bit-exactness contract rests on both paths running THIS code.
    """
    k = keys or {}
    po = policies or {}
    add = act_matmul(e + e_n, p["w1"][layer], scope="w1",
                     key=k.get("w1"), policy=po.get("w1"))
    mul = act_matmul(e * e_n, p["w2"][layer], scope="w2",
                     key=k.get("w2"), policy=po.get("w2"))
    add = act_nonlin(add, fn="leaky_relu", scope="act1",
                     key=k.get("act1"), policy=po.get("act1"))
    mul = act_nonlin(mul, fn="leaky_relu", scope="act2",
                     key=k.get("act2"), policy=po.get("act2"))
    return add + mul


def _kgat_layer(p, layer: int, e: jax.Array, g: CKG,
                att: jax.Array) -> jax.Array:
    """One KGAT layer; policies/keys resolve from the ambient ActContext
    at the scoped sites (``.../spmm``, ``.../w1`` ...)."""
    e_n = act_spmm(e, g.src, g.dst, att, num_nodes=g.n_nodes,
                   scope="spmm", layout=g.layout)
    return kgat_bi_interaction(p, layer, e, e_n)


def _kgat_attention(p, e: jax.Array, g: CKG) -> jax.Array:
    """π(h,r,t) = (W_r e_t)ᵀ tanh(W_r e_h + e_r), softmaxed over dst.

    W_r = Σ_b a_rb V_b: basis-projected node tables (B, N, d) are computed
    once, then mixed per edge — O(B·N·d² + E·B·d) instead of O(E·d²).
    """
    proj = jnp.einsum("nd,bdk->bnk", e, p["att_basis"])  # (B, N, d)
    coef = p["att_coef"][g.rel]                          # (E, B)
    eh = jnp.einsum("eb,bed->ed", coef, proj[:, g.src])  # (E, d)
    et = jnp.einsum("eb,bed->ed", coef, proj[:, g.dst])
    logits = jnp.sum(et * jnp.tanh(eh + p["relation"][g.rel]), axis=-1)
    return segment_softmax(logits, g.dst, g.n_nodes)


def _kgcn_layer(p, layer: int, e: jax.Array, g: CKG,
                ew: jax.Array) -> jax.Array:
    """KGNN-LS graph convolution: σ((Â E)Θ + b) with relation-scored Â."""
    h = act_spmm(e, g.src, g.dst, ew, num_nodes=g.n_nodes,
                 scope="spmm", layout=g.layout)
    j = act_matmul(h + e, p["w"][layer], scope="dense")
    j = j + p["b"][layer]
    return act_nonlin(j, scope="act",
                      fn="tanh" if layer == len(p["w"]) - 1 else "sigmoid")


def _kgin_layer(p, e: jax.Array, r_emb: jax.Array, g: CKG) -> jax.Array:
    """Relational path aggregation: e_h' = Σ_{(r,t)} e_r ⊙ e_t (KGIN eq. 8)."""
    msgs_src = e * 1.0  # (N, d)
    # modulate by relation embedding per edge: gather-then-scale is O(E d);
    # act_spmm with per-edge weights handles the scalar part, the vector
    # modulation composes as two spmm passes over (e ⊙ e_r)-projected feats.
    gathered = msgs_src[g.src] * r_emb[g.rel]     # (E, d)
    deg = jax.ops.segment_sum(jnp.ones_like(g.dst, dtype=e.dtype), g.dst,
                              num_segments=g.n_nodes)
    agg = jax.ops.segment_sum(gathered, g.dst, num_segments=g.n_nodes)
    agg = agg / jnp.maximum(deg, 1.0)[:, None]
    return act_nonlin(agg, fn="leaky_relu", scope="act")


def _rgcn_layer(p, layer: int, e: jax.Array, g: CKG) -> jax.Array:
    """Basis-decomposed R-GCN: W_r = Σ_b a_rb V_b (basis-first projection)."""
    # project once per basis: (N, B, d)
    proj = jnp.stack([
        act_matmul(e, p["basis"][b], scope=f"basis{b}")
        for b in range(p["basis"].shape[0])
    ], axis=1)
    coef_e = p["coef"][g.rel]                     # (E, B)
    msgs = jnp.einsum("eb,ebd->ed", coef_e, proj[g.src])
    deg = jax.ops.segment_sum(jnp.ones_like(g.dst, dtype=e.dtype), g.dst,
                              num_segments=g.n_nodes)
    agg = jax.ops.segment_sum(msgs, g.dst, num_segments=g.n_nodes)
    agg = agg / jnp.maximum(deg, 1.0)[:, None]
    self_t = act_matmul(e, p["w_self"][layer], scope="self")
    return act_nonlin(agg + self_t, fn="leaky_relu", scope="act")


def propagate(params: dict, g: CKG, cfg: KGNNConfig, *,
              policy: ACTPolicy | PolicySchedule | None = None,
              key: jax.Array | None = None):
    """Run L layers of message passing; returns final node representations.

    ``policy``/``key`` omitted resolve from the ambient ``ActContext``
    (explicit kwargs build a local one; no context at all means FP32).
    Under an active stochastic policy a key (or a context root key) is
    REQUIRED — there is no silent constant-key fallback, which would
    replay identical rounding noise every step and void the
    unbiasedness-in-expectation argument (Proposition 1).
    """
    ctx = model_context(policy, key)
    ctx.check_key(f"propagate({cfg.model})")
    e = params["entity"]
    outs = [e]

    with ctx, ctx.scope(cfg.model):
        if cfg.model == "kgat":
            att = _kgat_attention(params, e, g)
            for l in range(cfg.n_layers):
                with ctx.scope(f"layer{l}"):
                    e = _kgat_layer(params, l, e, g, att)
                outs.append(e)
        elif cfg.model == "kgcn":
            # relation scores are user-agnostic at graph level (KGNN-LS's
            # label-smoothed global graph); per-edge weight = softmax over
            # dst of r·mean
            logits = jnp.sum(params["relation"][g.rel] * e[g.src], axis=-1)
            ew = segment_softmax(logits, g.dst, g.n_nodes)
            for l in range(cfg.n_layers):
                with ctx.scope(f"layer{l}"):
                    e = _kgcn_layer(params, l, e, g, ew)
                outs.append(e)
        elif cfg.model == "kgin":
            # intent-weighted relation embeddings
            alpha = jax.nn.softmax(params["intent"], axis=-1)   # (P, R)
            r_int = alpha @ params["relation"]                  # (P, d)
            r_emb = params["relation"] + jnp.mean(r_int, 0)     # broadcast
            for l in range(cfg.n_layers):
                with ctx.scope(f"layer{l}"):
                    e = _kgin_layer(params, e, r_emb, g)
                outs.append(e)
        elif cfg.model == "rgcn":
            for l in range(cfg.n_layers):
                with ctx.scope(f"layer{l}"):
                    e = _rgcn_layer(params, l, e, g)
                outs.append(e)
        else:
            raise ValueError(cfg.model)

    if cfg.readout == "concat":
        return jnp.concatenate(outs, axis=-1)
    if cfg.readout == "sum":
        return sum(outs)
    return outs[-1]


# ---------------------------------------------------------------------------
# recommendation head (BPR)
# ---------------------------------------------------------------------------


def propagate_spmd(params: dict, g: CKG, cfg: KGNNConfig, *, mesh, axes,
                   policy: ACTPolicy | PolicySchedule | None = None,
                   key: jax.Array | None = None):
    """Explicitly-partitioned KGAT propagation (shard_map).

    Layout (same scheme as gnn.gcn_forward_spmd, §Perf hillclimb #3):
    entity rows sharded over ``axes``; edges partitioned BY DESTINATION
    shard (``g.src`` global ids, ``g.dst`` LOCAL row ids). Per layer: one
    tiled all-gather of the (N, d) entity matrix; edge attention, edge
    softmax and the weighted scatter all run shard-local. The layer
    transforms stay GSPMD (row-sharded matmuls).

    Keys/policies resolve per scoped site like ``propagate``; the SPMM key
    is derived OUTSIDE shard_map (``ctx.scope_path`` + ``key_for``) and
    rides in replicated — closed-over tracers are off-limits inside a
    shard_map body. The in-body ``act_spmm`` still records its residual
    under the same site name: what each device buffers is Quant(e_full),
    the all-gathered table, which is exactly the recorded shape.
    """
    from repro.sharding.compat import P, shard_map

    assert cfg.model == "kgat", "spmd propagate implemented for KGAT"
    ctx = model_context(policy, key)
    ctx.check_key("propagate_spmd(kgat)")
    e = params["entity"]

    def layer_local(e_loc, basis, src_g, dst_l, rel, coef, r_emb, att_key,
                    *, spmm_policy):
        # e_loc (N/D, d) local entity rows; src_g GLOBAL ids, dst_l LOCAL
        # dst rows (edges pre-partitioned by destination shard)
        proj_loc = jnp.einsum("nd,bdk->bnk", e_loc, basis)  # (B, N/D, d)
        proj_full = jax.lax.all_gather(proj_loc, axes, axis=1, tiled=True)
        e_full = jax.lax.all_gather(e_loc, axes, axis=0, tiled=True)
        eh = jnp.einsum("eb,bed->ed", coef[rel], proj_full[:, src_g])
        et = jnp.einsum("eb,bed->ed", coef[rel], proj_loc[:, dst_l])
        logits = jnp.sum(et * jnp.tanh(eh + r_emb[rel]), axis=-1)
        att = segment_softmax(logits, dst_l, e_loc.shape[0])
        return act_spmm(e_full, src_g, dst_l, att,
                        num_nodes=e_loc.shape[0], key=att_key,
                        policy=spmm_policy)

    outs = [e]
    with ctx, ctx.scope(cfg.model):
        for l in range(cfg.n_layers):
            with ctx.scope(f"layer{l}"):
                site = ctx.scope_path("spmm")  # not registered: the op
                pol = ctx.policy_for("spmm", site)  # inside claims the name
                k_spmm = ctx.key_for(site)
                spmd_layer = shard_map(
                    functools.partial(layer_local, spmm_policy=pol or FP32),
                    mesh=mesh,
                    in_specs=(P(axes, None), P(None, None, None), P(axes),
                              P(axes), P(axes), P(None, None), P(None, None),
                              P()),
                    out_specs=P(axes, None))
                e_n = spmd_layer(e, params["att_basis"], g.src, g.dst, g.rel,
                                 params["att_coef"], params["relation"],
                                 k_spmm if k_spmm is not None
                                 else jax.random.PRNGKey(0))
                e = kgat_bi_interaction(params, l, e, e_n)
            outs.append(e)
    return jnp.concatenate(outs, axis=-1) if cfg.readout == "concat" \
        else sum(outs)


def score_pairs(reps: jax.Array, users: jax.Array, items: jax.Array,
                n_users: int) -> jax.Array:
    """ŷ_uv = e_uᵀ e_v; item node ids are offset by n_users in the CKG."""
    return jnp.sum(reps[users] * reps[items + n_users], axis=-1)


def bpr_loss(params: dict, g: CKG, batch: dict, cfg: KGNNConfig, *,
             policy: ACTPolicy | PolicySchedule | None = None,
             key: jax.Array | None = None):
    """BPR pairwise ranking loss + L2 (the KGAT/KGIN objective)."""
    reps = propagate(params, g, cfg, policy=policy, key=key)
    pos = score_pairs(reps, batch["user"], batch["pos"], cfg.n_users)
    neg = score_pairs(reps, batch["user"], batch["neg"], cfg.n_users)
    loss = -jnp.mean(jax.nn.log_sigmoid(pos - neg))
    reg = sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(params))
    return loss + cfg.l2 * reg


# Memory accounting (paper Table 5) is derived from the residual trace —
# run the loss under a recording ActContext (or use
# ``repro.core.traced_activation_report``) instead of the old
# hand-maintained ``activation_shapes`` table, which had already drifted
# from the real ctx chain (it priced a phantom spmm residual for KGIN,
# whose aggregation never routes through act_spmm).
