"""Knowledge Graph Neural Networks (the paper's evaluation targets, §4.1.2).

Implements the three baselines TinyKG is evaluated on — KGAT, KGCN/KGNN-LS,
KGIN — plus R-GCN, over a collaborative knowledge graph (CKG): users, items
and attribute entities are one node space; user-item interactions are
`interact` relations merged with the item KG (paper §3.1).

Message passing defaults to ``jax.ops.segment_sum`` over COO edge lists,
with a blocked-CSR fused-Pallas path (``repro.data.csr`` + DESIGN.md §4)
under ``kernel="pallas"`` policies, and is ACT-compressed end-to-end:

  * ``act_spmm``    — weighted neighbor aggregation; saves Quant(E^(l))
  * ``act_matmul``  — layer transform ∇Θ = Ĥᵀ∇J; saves Quant(H^(l))
  * ``act_nonlin``  — σ(J); saves Quant(J^(l))

which is exactly the ctx(·) chain in paper Eq. (2). Edge-softmax
probabilities are (E,)-scalars (no feature dim) and stay fp32 — they are
O(E) not O(N·d), i.e. the "trivial" footprint class of the paper's
memory analysis.

Every op site carries a named scope (``"kgat/layer2/spmm"``): the ambient
``ActContext`` resolves its per-site policy from a ``PolicySchedule`` and
derives its stochastic-rounding key from the scope hash (DESIGN.md §6).

**One step definition per arch** (DESIGN.md §9): every model's layer math
is written ONCE against a ``GraphView`` — ``FullGraphView`` for the
single-device COO path, ``ShardGraphView`` for the dst-partitioned
``shard_map`` path (``repro.training.data_parallel``). The view supplies
the gatherable source-side table (identity vs all-gather + halo shrink),
pad-edge masking (identity vs mask), and local destination rows; the
layer functions (``_kgat_layer`` …) and edge-weight functions are shared
verbatim, so the DP parity contracts rest on both paths running THIS
code rather than a hand-inlined copy.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import (
    ACTPolicy,
    FP32,
    PolicySchedule,
    act_matmul,
    act_nonlin,
    act_spmm,
    model_context,
)
from .layers import glorot, normal_init

__all__ = [
    "KGNNConfig", "CKG", "segment_softmax", "kgat_bi_interaction",
    "init_params", "propagate", "score_pairs", "bpr_loss",
    "FullGraphView", "ShardGraphView", "Shard2DGraphView", "BlockView",
    "SampledGraphView",
    "model_sites", "propagate_view", "kg_shard_loss", "readout",
    "sampled_bpr_loss", "sampled_reps",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CKG:
    """Collaborative knowledge graph in COO form (inverse edges included).

    ``n_nodes``/``n_relations`` are pytree aux data — static under jit
    (segment_sum needs static segment counts). ``layout`` optionally
    carries the blocked-CSR arrangement of the same edge list
    (``repro.data.csr.attach_layout``) that routes ``act_spmm`` through
    the fused Pallas kernels under ``ACTPolicy(kernel="pallas")``.
    """

    src: jax.Array  # (E,) int32 node ids
    dst: jax.Array  # (E,) int32 node ids
    rel: jax.Array  # (E,) int32 relation ids
    n_nodes: int    # users + entities (static)
    n_relations: int
    layout: object | None = None  # SpmmLayout (itself a pytree) or None

    def tree_flatten(self):
        return (self.src, self.dst, self.rel, self.layout), (
            self.n_nodes, self.n_relations)

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, rel, layout = children
        return cls(src, dst, rel, aux[0], aux[1], layout)


@dataclasses.dataclass(frozen=True)
class KGNNConfig:
    model: str = "kgat"          # kgat | kgcn | kgin | rgcn
    n_users: int = 0
    n_entities: int = 0          # items + attribute entities
    n_relations: int = 0         # incl. `interact`, both directions
    dim: int = 64                # embedding size (paper fixes 64)
    n_layers: int = 3            # paper fixes 3
    layer_dims: tuple = ()       # per-layer out dims; default = dim each
    n_intents: int = 4           # KGIN
    n_bases: int = 4             # R-GCN basis decomposition
    l2: float = 1e-5
    readout: str = "concat"      # concat (KGAT) | sum (KGIN) | last

    @property
    def n_nodes(self) -> int:
        return self.n_users + self.n_entities

    @property
    def dims(self) -> tuple:
        return self.layer_dims or (self.dim,) * self.n_layers


def segment_softmax(logits: jax.Array, seg: jax.Array, num_segments: int):
    """Numerically-stable softmax over segments (edge softmax)."""
    mx = jax.ops.segment_max(logits, seg, num_segments=num_segments)
    ex = jnp.exp(logits - mx[seg])
    den = jax.ops.segment_sum(ex, seg, num_segments=num_segments)
    return ex / (den[seg] + 1e-16)


# ---------------------------------------------------------------------------
# graph views: one set of layer functions, three execution layouts
# ---------------------------------------------------------------------------


class _ViewDefaults:
    """Hooks every view shares; identity for the whole-graph views.

    The sampled-minibatch path (``SampledGraphView``) is the only one
    that overrides them: its edge set *changes per layer* (per-hop
    fanout blocks) and its row space *shrinks toward the seeds*, so the
    shared layer functions ask the view instead of assuming one static
    edge list. On ``FullGraphView``/``ShardGraphView`` every hook
    returns its argument unchanged — the jaxpr is identical to the
    pre-hook code, which the pinned bit-exact step regression relies on.
    """

    def layer_view(self, layer: int):
        """The view layer ``layer`` aggregates over (self for the
        whole-graph views; hop block ``layer`` for the sampled view)."""
        return self

    def layer_weights(self, weights, layer: int):
        """Slice the once-computed edge-weight data for one layer."""
        return weights

    def self_rows(self, e):
        """Restrict a source-row table to this layer's destination rows
        (the self/residual term of kgat/kgcn/rgcn)."""
        return e

    def seed_rows(self, e):
        """Restrict a layer output to the rows the readout keeps."""
        return e

    def param_l2(self, params):
        """Full-model L2 of the parameter pytree as this view sees it.

        Every view but the 2D mesh view sums leaves directly; the 2D
        view holds row-sharded tables as model-axis blocks and must
        psum their sum-of-squares so each shard sees the same scalar
        the replicated path would.
        """
        return sum(jnp.sum(x ** 2)
                   for x in jax.tree_util.tree_leaves(params))


@dataclasses.dataclass(frozen=True)
class FullGraphView(_ViewDefaults):
    """The whole COO graph on one device — every hook is the identity.

    ``src`` indexes the table returned by ``table`` (== the node table
    itself), ``dst`` indexes local rows (== all rows), no pad edges.
    """

    g: CKG

    @property
    def src(self):
        return self.g.src

    @property
    def dst(self):
        return self.g.dst

    @property
    def rel(self):
        return self.g.rel

    @property
    def num_rows(self) -> int:
        return self.g.n_nodes

    @property
    def layout(self):
        return self.g.layout

    def local_rows(self, table):
        return table

    def table(self, x, axis: int = 0):
        return x

    def unshard(self, x, axis: int = 0):
        return x

    def mask_logits(self, logits):
        return logits

    def mask_weights(self, w):
        return w

    def mask_messages(self, m):
        return m

    def edge_ones(self, dtype):
        return jnp.ones_like(self.g.dst, dtype=dtype)


@dataclasses.dataclass(frozen=True)
class ShardGraphView(_ViewDefaults):
    """One shard of a dst-partitioned graph, inside a ``shard_map`` body.

    Built from one row of ``repro.data.csr.EdgePartition``: ``src`` is
    halo-LOCAL (indexes the ``(h_cap, d)`` table ``table`` returns after
    the all-gather + halo shrink), ``dst`` is shard-local, pad edges
    carry ``mask == 0``. ``local_rows`` slices this shard's rows out of
    a replicated node table (pad-extended to ``n_nodes_padded``).
    """

    src: jax.Array        # (Ec,) halo-local source index
    dst: jax.Array        # (Ec,) local dst row
    rel: jax.Array        # (Ec,)
    mask: jax.Array       # (Ec,) 1=real edge, 0=pad
    halo: jax.Array       # (Hc,) unique global src ids for this shard
    axis: str             # mesh axis name
    num_rows: int         # rows per shard
    n_nodes_padded: int   # num_rows * n_shards
    layout = None         # blocked-CSR stays single-device (DESIGN.md §7.4)

    @classmethod
    def from_shard(cls, sh: dict, *, axis: str, num_rows: int,
                   n_nodes_padded: int) -> "ShardGraphView":
        return cls(src=sh["src_h"], dst=sh["dst_l"], rel=sh["rel"],
                   mask=sh["mask"], halo=sh["halo"], axis=axis,
                   num_rows=num_rows, n_nodes_padded=n_nodes_padded)

    def local_rows(self, table):
        pad = jnp.pad(table, ((0, self.n_nodes_padded - table.shape[0]),
                              (0, 0)))
        i = jax.lax.axis_index(self.axis)
        return jax.lax.dynamic_slice_in_dim(pad, i * self.num_rows,
                                            self.num_rows)

    def table(self, x, axis: int = 0):
        full = jax.lax.all_gather(x, self.axis, axis=axis, tiled=True)
        return jnp.take(full, self.halo, axis=axis)

    def unshard(self, x, axis: int = 0):
        return jax.lax.all_gather(x, self.axis, axis=axis, tiled=True)

    def mask_logits(self, logits):
        return jnp.where(self.mask > 0, logits, -1e30)

    def mask_weights(self, w):
        return w * self.mask

    def mask_messages(self, m):
        return m * self.mask[:, None]

    def edge_ones(self, dtype):
        return self.mask.astype(dtype)


@dataclasses.dataclass(frozen=True)
class Shard2DGraphView(ShardGraphView):
    """A ``ShardGraphView`` whose embedding tables are row-sharded over
    a second mesh axis (the 2D ``data×model`` mesh, DESIGN.md §12).

    Row-sharded parameters (``row_sharded``, e.g. ``"entity"``) enter
    the ``shard_map`` body as ``(table_rows, d)`` model-axis blocks
    instead of replicated ``(N, d)`` tables. Only two hooks differ from
    the 1D view:

      * ``local_rows`` — the data shard's dst rows are the contiguous
        global ids ``[s*num_rows, (s+1)*num_rows)``; ``fetch_rows``
        assembles them from the model-axis blocks (one psum), pulling
        exactly the rows this shard's edges touch. Since each fetched
        value is one real row plus zeros, the result is bit-exact
        against slicing a replicated table — so everything downstream
        (halo gathers over the data axis, layer math, ``unshard``) is
        byte-for-byte the 1D computation.
      * ``param_l2`` — sharded tables contribute through
        ``rowshard_l2`` (a psum of block sums) so the regularizer is
        the full-table L2 on every shard.

    Everything after the fetch must stay replicated over the model
    axis; the custom VJPs of both ops rely on that contract (their
    backward passes are local reduce-scatter shares).
    """

    model_axis: str = "model"
    table_rows: int = 0    # block rows per model shard
    n_valid_rows: int = 0  # real node count; padded ids fetch as zero
    row_sharded: tuple = ()  # top-level param names stored as blocks

    @classmethod
    def from_shard2d(cls, sh: dict, *, axis: str, num_rows: int,
                     n_nodes_padded: int, model_axis: str, table_rows: int,
                     n_valid_rows: int, row_sharded: tuple):
        return cls(src=sh["src_h"], dst=sh["dst_l"], rel=sh["rel"],
                   mask=sh["mask"], halo=sh["halo"], axis=axis,
                   num_rows=num_rows, n_nodes_padded=n_nodes_padded,
                   model_axis=model_axis, table_rows=table_rows,
                   n_valid_rows=n_valid_rows,
                   row_sharded=tuple(row_sharded))

    def local_rows(self, table):
        from repro.sharding.rowshard import fetch_rows

        s = jax.lax.axis_index(self.axis)
        ids = s * self.num_rows + jnp.arange(self.num_rows)
        return fetch_rows(table, ids, axis=self.model_axis,
                          rows_per_shard=self.table_rows,
                          n_valid=self.n_valid_rows)

    def param_l2(self, params):
        from repro.sharding.rowshard import rowshard_l2

        total = 0.0
        for name, sub in params.items():
            if name in self.row_sharded:
                total = total + rowshard_l2(sub, axis=self.model_axis)
            else:
                total = total + sum(jnp.sum(x ** 2)
                                    for x in jax.tree_util.tree_leaves(sub))
        return total


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockView(_ViewDefaults):
    """One sampled fanout hop: a bipartite edge block, view-shaped.

    Built host-side by ``repro.data.minibatch.sample_kg_blocks``. Local
    indexing rides on the *seeds-prefix invariant*: the hop's
    destination frontier is a prefix of its source frontier (which is a
    prefix of the outermost gathered node set), so

      * ``src`` indexes the CURRENT layer input table (``n_src`` rows),
      * ``dst`` indexes the same table's first ``n_dst`` rows,
      * both remain valid positions into the outermost layer-0 table —
        which is what lets per-hop KGAT/KGCN edge weights be computed
        once from the layer-0 embeddings, exactly like the full-graph
        semantics.

    ``mask`` zeroes pad edges (zero-degree destinations); ``layout`` is
    an optional static-geometry blocked-CSR ``SpmmLayout`` over the
    SAME slot order, so the fused Pallas SPMM runs unchanged on the
    sampled subgraph. ``n_src``/``n_dst`` are pytree aux data — static
    under jit, so a stream of same-shape blocks never retraces.
    """

    src: jax.Array        # (Eb,) block-local source index
    dst: jax.Array        # (Eb,) block-local destination index (< n_dst)
    rel: jax.Array        # (Eb,) relation ids
    mask: jax.Array       # (Eb,) 1=real sampled edge, 0=pad
    layout: object | None  # SpmmLayout over this block's edges, or None
    n_src: int            # static source-frontier size
    n_dst: int            # static destination-frontier size

    def tree_flatten(self):
        return (self.src, self.dst, self.rel, self.mask, self.layout), (
            self.n_src, self.n_dst)

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, rel, mask, layout = children
        return cls(src, dst, rel, mask, layout, *aux)

    @property
    def num_rows(self) -> int:
        return self.n_dst

    def local_rows(self, table):
        return table

    def table(self, x, axis: int = 0):
        return x

    def unshard(self, x, axis: int = 0):
        return x

    def mask_logits(self, logits):
        return jnp.where(self.mask > 0, logits, -1e30)

    def mask_weights(self, w):
        return w * self.mask

    def mask_messages(self, m):
        return m * self.mask[:, None]

    def edge_ones(self, dtype):
        return self.mask.astype(dtype)

    def self_rows(self, e):
        return e[: self.n_dst]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SampledGraphView(_ViewDefaults):
    """Neighbor-sampled minibatch: one ``BlockView`` per layer.

    ``blocks[l]`` is the hop layer ``l`` consumes (outermost hop first —
    the layer-0 aggregation reads the largest frontier); the innermost
    hop's destination set is exactly the seed set, whose first
    ``n_seeds`` rows the readout keeps. ``params["entity"]`` is expected
    to ALREADY be the gathered outermost row table — the tier cache
    (``repro.training.tiering``) resolves global entity ids to rows
    before the jitted step, so ``local_rows`` is the identity and the
    step never sees the full table.
    """

    blocks: tuple         # (BlockView, ...) one per layer, outermost first
    n_seeds: int          # rows of every hop frontier that are seeds

    def tree_flatten(self):
        return (self.blocks,), (self.n_seeds,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    @property
    def n_input_rows(self) -> int:
        """Rows of the gathered entity table the step expects."""
        return self.blocks[0].n_src

    def layer_view(self, layer: int):
        return self.blocks[layer]

    def layer_weights(self, weights, layer: int):
        # kgat/kgcn: per-hop edge weights (a tuple); kgin: the
        # hop-independent intent-weighted relation table; rgcn: None
        return weights[layer] if isinstance(weights, tuple) else weights

    def local_rows(self, table):
        return table

    def seed_rows(self, e):
        return e[: self.n_seeds]


def model_sites(cfg: KGNNConfig) -> tuple[tuple[str, str], ...]:
    """Per-layer ``(site_name, op_kind)`` table for a model — the ACT
    sites a data-parallel step must pre-resolve outside ``shard_map``."""
    if cfg.model == "kgat":
        return (("spmm", "spmm"), ("w1", "matmul"), ("w2", "matmul"),
                ("act1", "nonlin"), ("act2", "nonlin"))
    if cfg.model == "kgcn":
        return (("spmm", "spmm"), ("dense", "matmul"), ("act", "nonlin"))
    if cfg.model == "kgin":
        return (("act", "nonlin"),)
    if cfg.model == "rgcn":
        return tuple((f"basis{b}", "matmul") for b in range(cfg.n_bases)) \
            + (("self", "matmul"), ("act", "nonlin"))
    raise ValueError(cfg.model)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: KGNNConfig) -> dict:
    ks = iter(jax.random.split(key, 64))
    d = cfg.dim
    p = {
        "entity": normal_init(next(ks), (cfg.n_nodes, d), 0.1),
        "relation": normal_init(next(ks), (cfg.n_relations, d), 0.1),
    }
    dims = (d,) + cfg.dims
    if cfg.model == "kgat":
        # relation-space projection for attention (TransR style). The paper
        # uses a dense d×d W_r per relation; gathering it per edge is an
        # (E,d,d) tensor — infeasible at industry scale. We keep the
        # relation-specific d×d structure via basis decomposition
        # W_r = Σ_b a_rb V_b (R-GCN trick): project once per basis (B·N·d),
        # mix per edge with (E,B) coefficients. See DESIGN.md §3.
        p["att_basis"] = normal_init(next(ks), (cfg.n_bases, d, d), 0.1)
        p["att_coef"] = normal_init(next(ks), (cfg.n_relations, cfg.n_bases), 0.1)
        p["w1"] = [glorot(next(ks), (a, b)) for a, b in zip(dims[:-1], dims[1:])]
        p["w2"] = [glorot(next(ks), (a, b)) for a, b in zip(dims[:-1], dims[1:])]
    elif cfg.model == "kgcn":
        p["w"] = [glorot(next(ks), (a, b)) for a, b in zip(dims[:-1], dims[1:])]
        p["b"] = [jnp.zeros((b,)) for b in dims[1:]]
    elif cfg.model == "kgin":
        p["intent"] = normal_init(next(ks), (cfg.n_intents, cfg.n_relations), 0.1)
    elif cfg.model == "rgcn":
        p["basis"] = normal_init(next(ks), (cfg.n_bases, d, d), 0.1)
        p["coef"] = normal_init(next(ks), (cfg.n_relations, cfg.n_bases), 0.1)
        p["w_self"] = [glorot(next(ks), (d, d)) for _ in range(cfg.n_layers)]
    else:
        raise ValueError(cfg.model)
    return p


# ---------------------------------------------------------------------------
# propagation (paper Eq. 1/2) — layer math written once, against a view
# ---------------------------------------------------------------------------


def kgat_bi_interaction(p, layer: int, e: jax.Array, e_n: jax.Array, *,
                        keys: dict | None = None,
                        policies: dict | None = None) -> jax.Array:
    """Bi-interaction aggregator: LeakyReLU(W1(e+eN)) + LeakyReLU(W2(e⊙eN)).

    The single source of the (e, e_n) -> layer-output math for every
    KGAT path. With ``keys``/``policies`` omitted the ``w1``/``w2``/
    ``act1``/``act2`` sites resolve from the ambient ActContext; the
    explicitly-partitioned paths (shard_map bodies, where ambient
    resolution can't reach) pass per-site dicts instead — the DP
    bit-exactness contract rests on both paths running THIS code.
    """
    k = keys or {}
    po = policies or {}
    add = act_matmul(e + e_n, p["w1"][layer], scope="w1",
                     key=k.get("w1"), policy=po.get("w1"))
    mul = act_matmul(e * e_n, p["w2"][layer], scope="w2",
                     key=k.get("w2"), policy=po.get("w2"))
    add = act_nonlin(add, fn="leaky_relu", scope="act1",
                     key=k.get("act1"), policy=po.get("act1"))
    mul = act_nonlin(mul, fn="leaky_relu", scope="act2",
                     key=k.get("act2"), policy=po.get("act2"))
    return add + mul


def _kgat_attention(p, e: jax.Array, view) -> jax.Array:
    """π(h,r,t) = (W_r e_t)ᵀ tanh(W_r e_h + e_r), softmaxed over dst.

    W_r = Σ_b a_rb V_b: basis-projected node tables (B, N, d) are computed
    once, then mixed per edge — O(B·N·d² + E·B·d) instead of O(E·d²).
    On a shard view the projection runs on local rows, the source side
    reads the all-gathered + halo-shrunk table, pad edges are masked out
    of the softmax normalization.
    """
    proj = jnp.einsum("nd,bdk->bnk", e, p["att_basis"])   # (B, rows, d)
    proj_t = view.table(proj, axis=1)                     # (B, H, d)
    coef = p["att_coef"][view.rel]                        # (E, B)
    eh = jnp.einsum("eb,bed->ed", coef, proj_t[:, view.src])
    et = jnp.einsum("eb,bed->ed", coef, proj[:, view.dst])
    logits = jnp.sum(et * jnp.tanh(eh + p["relation"][view.rel]), axis=-1)
    logits = view.mask_logits(logits)
    return view.mask_weights(
        segment_softmax(logits, view.dst, view.num_rows))


def _kgat_layer(p, layer: int, e: jax.Array, view, att: jax.Array, *,
                keys: dict | None = None,
                policies: dict | None = None) -> jax.Array:
    """One KGAT layer; keys/policies omitted resolve from the ambient
    ActContext at the scoped sites (``.../spmm``, ``.../w1`` ...)."""
    k = keys or {}
    po = policies or {}
    e_n = act_spmm(view.table(e), view.src, view.dst, att,
                   num_nodes=view.num_rows, scope="spmm",
                   layout=view.layout, key=k.get("spmm"),
                   policy=po.get("spmm"))
    return kgat_bi_interaction(p, layer, view.self_rows(e), e_n, keys=keys,
                               policies=policies)


def _kgcn_layer(p, layer: int, e: jax.Array, view, ew: jax.Array, *,
                keys: dict | None = None,
                policies: dict | None = None) -> jax.Array:
    """KGNN-LS graph convolution: σ((Â E)Θ + b) with relation-scored Â."""
    k = keys or {}
    po = policies or {}
    h = act_spmm(view.table(e), view.src, view.dst, ew,
                 num_nodes=view.num_rows, scope="spmm", layout=view.layout,
                 key=k.get("spmm"), policy=po.get("spmm"))
    j = act_matmul(h + view.self_rows(e), p["w"][layer], scope="dense",
                   key=k.get("dense"), policy=po.get("dense"))
    j = j + p["b"][layer]
    return act_nonlin(j, scope="act",
                      fn="tanh" if layer == len(p["w"]) - 1 else "sigmoid",
                      key=k.get("act"), policy=po.get("act"))


def _kgin_layer(p, e: jax.Array, r_emb: jax.Array, view, *,
                keys: dict | None = None,
                policies: dict | None = None) -> jax.Array:
    """Relational path aggregation: e_h' = Σ_{(r,t)} e_r ⊙ e_t (KGIN eq. 8)."""
    k = keys or {}
    po = policies or {}
    # modulate by relation embedding per edge: gather-then-scale is O(E d);
    # act_spmm with per-edge weights handles the scalar part, the vector
    # modulation composes as two spmm passes over (e ⊙ e_r)-projected feats.
    gathered = view.table(e)[view.src] * r_emb[view.rel]      # (E, d)
    gathered = view.mask_messages(gathered)
    deg = jax.ops.segment_sum(view.edge_ones(e.dtype), view.dst,
                              num_segments=view.num_rows)
    agg = jax.ops.segment_sum(gathered, view.dst,
                              num_segments=view.num_rows)
    agg = agg / jnp.maximum(deg, 1.0)[:, None]
    return act_nonlin(agg, fn="leaky_relu", scope="act",
                      key=k.get("act"), policy=po.get("act"))


def _rgcn_layer(p, layer: int, e: jax.Array, view, *,
                keys: dict | None = None,
                policies: dict | None = None) -> jax.Array:
    """Basis-decomposed R-GCN: W_r = Σ_b a_rb V_b (basis-first projection)."""
    k = keys or {}
    po = policies or {}
    # project once per basis: (rows, B, d)
    proj = jnp.stack([
        act_matmul(e, p["basis"][b], scope=f"basis{b}",
                   key=k.get(f"basis{b}"), policy=po.get(f"basis{b}"))
        for b in range(p["basis"].shape[0])
    ], axis=1)
    coef_e = p["coef"][view.rel]                     # (E, B)
    msgs = jnp.einsum("eb,ebd->ed", coef_e, view.table(proj)[view.src])
    msgs = view.mask_messages(msgs)
    deg = jax.ops.segment_sum(view.edge_ones(e.dtype), view.dst,
                              num_segments=view.num_rows)
    agg = jax.ops.segment_sum(msgs, view.dst, num_segments=view.num_rows)
    agg = agg / jnp.maximum(deg, 1.0)[:, None]
    self_t = act_matmul(view.self_rows(e), p["w_self"][layer], scope="self",
                        key=k.get("self"), policy=po.get("self"))
    return act_nonlin(agg + self_t, fn="leaky_relu", scope="act",
                      key=k.get("act"), policy=po.get("act"))


def _edge_weights(params: dict, e0: jax.Array, view, cfg: KGNNConfig):
    """Per-edge weighting data, computed ONCE from the layer-0 embeddings.

    kgat: attention probabilities (E,); kgcn: relation-scored adjacency
    (E,); kgin: the intent-weighted relation table (R, d) its per-layer
    modulation reads; rgcn: nothing (coefficients are per-layer params).

    On a ``SampledGraphView`` the edge set differs per hop, so the
    edge-space weightings (kgat/kgcn) come back as a per-hop tuple —
    each hop's weights still computed from the SAME layer-0 embeddings
    (every hop frontier is a prefix of the outermost gathered table, so
    block-local indices are valid positions into ``e0``), preserving
    the once-from-layer-0 semantics the full-graph and DP paths pin.
    ``view.layer_weights`` slices the tuple back out per layer.
    """
    if isinstance(view, SampledGraphView) and cfg.model in ("kgat", "kgcn"):
        return tuple(_edge_weights(params, e0, b, cfg) for b in view.blocks)
    if cfg.model == "kgat":
        return _kgat_attention(params, e0, view)
    if cfg.model == "kgcn":
        # relation scores are user-agnostic at graph level (KGNN-LS's
        # label-smoothed global graph); per-edge weight = softmax over
        # dst of r·mean
        logits = jnp.sum(params["relation"][view.rel]
                         * view.table(e0)[view.src], axis=-1)
        logits = view.mask_logits(logits)
        return view.mask_weights(
            segment_softmax(logits, view.dst, view.num_rows))
    if cfg.model == "kgin":
        # intent-weighted relation embeddings
        alpha = jax.nn.softmax(params["intent"], axis=-1)   # (P, R)
        r_int = alpha @ params["relation"]                  # (P, d)
        return params["relation"] + jnp.mean(r_int, 0)      # broadcast
    if cfg.model == "rgcn":
        return None
    raise ValueError(cfg.model)


def propagate_view(params: dict, view, cfg: KGNNConfig, *, ctx=None,
                   site_keys=None, site_policies=None) -> list:
    """L layers of message passing against a view; returns per-layer outs.

    Exactly one of two resolution modes:
      * ``ctx`` (an entered ``ActContext``) — ambient per-site resolution
        under ``layer<l>`` scopes, the single-device path;
      * ``site_keys``/``site_policies`` — per-layer ``{site: ...}`` dicts
        pre-derived OUTSIDE a ``shard_map`` body (closed-over tracers are
        off-limits inside one), the data-parallel path.
    """
    e = view.local_rows(params["entity"])
    outs = [view.seed_rows(e)]
    weights = _edge_weights(params, e, view, cfg)
    for l in range(cfg.n_layers):
        lview = view.layer_view(l)
        w = view.layer_weights(weights, l)
        keys = site_keys[l] if site_keys is not None else None
        pols = site_policies[l] if site_policies is not None else None
        scope = ctx.scope(f"layer{l}") if ctx is not None \
            else contextlib.nullcontext()
        with scope:
            if cfg.model == "kgat":
                e = _kgat_layer(params, l, e, lview, w,
                                keys=keys, policies=pols)
            elif cfg.model == "kgcn":
                e = _kgcn_layer(params, l, e, lview, w,
                                keys=keys, policies=pols)
            elif cfg.model == "kgin":
                e = _kgin_layer(params, e, w, lview,
                                keys=keys, policies=pols)
            elif cfg.model == "rgcn":
                e = _rgcn_layer(params, l, e, lview,
                                keys=keys, policies=pols)
            else:
                raise ValueError(cfg.model)
        outs.append(view.seed_rows(e))
    return outs


def readout(outs: list, cfg: KGNNConfig) -> jax.Array:
    if cfg.readout == "concat":
        return jnp.concatenate(outs, axis=-1)
    if cfg.readout == "sum":
        return sum(outs)
    return outs[-1]


def propagate(params: dict, g: CKG, cfg: KGNNConfig, *,
              policy: ACTPolicy | PolicySchedule | None = None,
              key: jax.Array | None = None):
    """Run L layers of message passing; returns final node representations.

    ``policy``/``key`` omitted resolve from the ambient ``ActContext``
    (explicit kwargs build a local one; no context at all means FP32).
    Under an active stochastic policy a key (or a context root key) is
    REQUIRED — there is no silent constant-key fallback, which would
    replay identical rounding noise every step and void the
    unbiasedness-in-expectation argument (Proposition 1).
    """
    ctx = model_context(policy, key)
    ctx.check_key(f"propagate({cfg.model})")
    view = FullGraphView(g)
    with ctx, ctx.scope(cfg.model):
        outs = propagate_view(params, view, cfg, ctx=ctx)
    return readout(outs, cfg)


# ---------------------------------------------------------------------------
# recommendation head (BPR)
# ---------------------------------------------------------------------------


def propagate_spmd(params: dict, g: CKG, cfg: KGNNConfig, *, mesh, axes,
                   policy: ACTPolicy | PolicySchedule | None = None,
                   key: jax.Array | None = None):
    """Explicitly-partitioned KGAT propagation (shard_map).

    Layout (same scheme as gnn.gcn_forward_spmd, §Perf hillclimb #3):
    entity rows sharded over ``axes``; edges partitioned BY DESTINATION
    shard (``g.src`` global ids, ``g.dst`` LOCAL row ids). Attention is
    computed ONCE from the layer-0 embeddings — the same semantics as
    single-device ``propagate`` and the generic DP step (it used to be
    recomputed per layer from the evolving embeddings, a silent semantic
    fork; tests/test_distributed.py pins the aligned behavior against
    ``propagate``). Per layer: one tiled all-gather of the (N, d) entity
    matrix; the weighted scatter runs shard-local. The layer transforms
    stay GSPMD (row-sharded matmuls).

    Keys/policies resolve per scoped site like ``propagate``; the SPMM key
    is derived OUTSIDE shard_map (``ctx.scope_path`` + ``key_for``) and
    rides in replicated — closed-over tracers are off-limits inside a
    shard_map body. The in-body ``act_spmm`` still records its residual
    under the same site name: what each device buffers is Quant(e_full),
    the all-gathered table, which is exactly the recorded shape.

    For end-to-end data-parallel *training* prefer
    ``repro.training.data_parallel.make_dp_step`` (halo-shrunk gathers,
    compressed gradient all-reduce, any registered KG arch).
    """
    from repro.sharding.compat import P, shard_map

    assert cfg.model == "kgat", "spmd propagate implemented for KGAT"
    ctx = model_context(policy, key)
    ctx.check_key("propagate_spmd(kgat)")
    e = params["entity"]

    def att_local(e_loc, basis, src_g, dst_l, rel, coef, r_emb):
        # e_loc (N/D, d) local entity rows; src_g GLOBAL ids, dst_l LOCAL
        # dst rows (edges pre-partitioned by destination shard)
        proj_loc = jnp.einsum("nd,bdk->bnk", e_loc, basis)  # (B, N/D, d)
        proj_full = jax.lax.all_gather(proj_loc, axes, axis=1, tiled=True)
        eh = jnp.einsum("eb,bed->ed", coef[rel], proj_full[:, src_g])
        et = jnp.einsum("eb,bed->ed", coef[rel], proj_loc[:, dst_l])
        logits = jnp.sum(et * jnp.tanh(eh + r_emb[rel]), axis=-1)
        return segment_softmax(logits, dst_l, e_loc.shape[0])

    att_fn = shard_map(
        att_local, mesh=mesh,
        in_specs=(P(axes, None), P(None, None, None), P(axes), P(axes),
                  P(axes), P(None, None), P(None, None)),
        out_specs=P(axes))
    att = att_fn(e, params["att_basis"], g.src, g.dst, g.rel,
                 params["att_coef"], params["relation"])

    def layer_local(e_loc, src_g, dst_l, att_e, att_key, *, spmm_policy):
        e_full = jax.lax.all_gather(e_loc, axes, axis=0, tiled=True)
        return act_spmm(e_full, src_g, dst_l, att_e,
                        num_nodes=e_loc.shape[0], key=att_key,
                        policy=spmm_policy)

    outs = [e]
    with ctx, ctx.scope(cfg.model):
        for l in range(cfg.n_layers):
            with ctx.scope(f"layer{l}"):
                site = ctx.scope_path("spmm")  # not registered: the op
                pol = ctx.policy_for("spmm", site)  # inside claims the name
                k_spmm = ctx.key_for(site)
                spmd_layer = shard_map(
                    functools.partial(layer_local, spmm_policy=pol or FP32),
                    mesh=mesh,
                    in_specs=(P(axes, None), P(axes), P(axes), P(axes), P()),
                    out_specs=P(axes, None))
                e_n = spmd_layer(e, g.src, g.dst, att,
                                 k_spmm if k_spmm is not None
                                 else jax.random.PRNGKey(0))
                e = kgat_bi_interaction(params, l, e, e_n)
            outs.append(e)
    return jnp.concatenate(outs, axis=-1) if cfg.readout == "concat" \
        else sum(outs)


def score_pairs(reps: jax.Array, users: jax.Array, items: jax.Array,
                n_users: int) -> jax.Array:
    """ŷ_uv = e_uᵀ e_v; item node ids are offset by n_users in the CKG."""
    return jnp.sum(reps[users] * reps[items + n_users], axis=-1)


def bpr_loss(params: dict, g: CKG, batch: dict, cfg: KGNNConfig, *,
             policy: ACTPolicy | PolicySchedule | None = None,
             key: jax.Array | None = None):
    """BPR pairwise ranking loss + L2 (the KGAT/KGIN objective)."""
    reps = propagate(params, g, cfg, policy=policy, key=key)
    pos = score_pairs(reps, batch["user"], batch["pos"], cfg.n_users)
    neg = score_pairs(reps, batch["user"], batch["neg"], cfg.n_users)
    loss = -jnp.mean(jax.nn.log_sigmoid(pos - neg))
    reg = sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(params))
    return loss + cfg.l2 * reg


def sampled_reps(params: dict, view: "SampledGraphView", cfg: KGNNConfig, *,
                 policy: ACTPolicy | PolicySchedule | None = None,
                 key: jax.Array | None = None) -> jax.Array:
    """Seed-row readout representations from a sampled minibatch.

    ``params["entity"]`` must already be the gathered outermost row
    table (``view.n_input_rows`` rows) — the tier cache's job. Scopes
    are the SAME ``<model>/layer<l>/<site>`` paths as ``propagate``, so
    an ACT schedule and its scope-hashed SR keys apply unchanged to
    sampled training.
    """
    ctx = model_context(policy, key)
    ctx.check_key(f"sampled_reps({cfg.model})")
    with ctx, ctx.scope(cfg.model):
        outs = propagate_view(params, view, cfg, ctx=ctx)
    return readout(outs, cfg)


def sampled_bpr_loss(params: dict, view: "SampledGraphView", cfg: KGNNConfig,
                     *, policy: ACTPolicy | PolicySchedule | None = None,
                     key: jax.Array | None = None):
    """BPR over a seed layout of ``[users | pos items | neg items]``.

    The sampler packs the three BPR roles as the seed set in fixed
    thirds (``B = n_seeds // 3``), so scoring is position-based — no
    global-id indexing into a full rep table exists on this path.
    L2 regularization covers the touched parameters only (the gathered
    entity rows + dense params), the sampled-approximate counterpart of
    the full-table term; see DESIGN.md §11 exactness ledger.
    """
    reps = sampled_reps(params, view, cfg, policy=policy, key=key)
    b = view.n_seeds // 3
    pos = jnp.sum(reps[:b] * reps[b:2 * b], axis=-1)
    neg = jnp.sum(reps[:b] * reps[2 * b:3 * b], axis=-1)
    loss = -jnp.mean(jax.nn.log_sigmoid(pos - neg))
    reg = sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(params))
    return loss + cfg.l2 * reg


def kg_shard_loss(params: dict, view, batch: dict, cfg: KGNNConfig, *,
                  site_keys=None, site_policies=None):
    """One shard's slice of the global BPR objective (plus full L2 reg).

    Runs the SAME ``propagate_view`` layer math as single-device
    ``propagate`` — there is no hand-inlined DP forward. Returns
    ``(local_batch_mean_bpr + reg, local_batch_mean_bpr)``; with the
    batch sharded evenly and params replicated, the shard-mean of the
    first element is exactly the global objective.
    """
    outs = propagate_view(params, view, cfg, site_keys=site_keys,
                          site_policies=site_policies)
    reps = view.unshard(readout(outs, cfg))
    pos = score_pairs(reps, batch["user"], batch["pos"], cfg.n_users)
    neg = score_pairs(reps, batch["user"], batch["neg"], cfg.n_users)
    loss_loc = -jnp.mean(jax.nn.log_sigmoid(pos - neg))
    # view.param_l2 == plain leaf sum-of-squares everywhere except the
    # 2D mesh view, which psums row-sharded tables to the same scalar.
    reg = view.param_l2(params)
    return loss_loc + cfg.l2 * reg, loss_loc


def kg_shard_reps(params: dict, view, cfg: KGNNConfig, *,
                  site_keys=None, site_policies=None) -> jax.Array:
    """This shard's rows of the readout representations (parity tests)."""
    return readout(propagate_view(params, view, cfg, site_keys=site_keys,
                                  site_policies=site_policies), cfg)


# Memory accounting (paper Table 5) is derived from the residual trace —
# run the loss under a recording ActContext (or use
# ``repro.core.traced_activation_report``) instead of the old
# hand-maintained ``activation_shapes`` table, which had already drifted
# from the real ctx chain (it priced a phantom spmm residual for KGIN,
# whose aggregation never routes through act_spmm).
