"""Knowledge Graph Neural Networks (the paper's evaluation targets, §4.1.2).

Implements the three baselines TinyKG is evaluated on — KGAT, KGCN/KGNN-LS,
KGIN — plus R-GCN, over a collaborative knowledge graph (CKG): users, items
and attribute entities are one node space; user-item interactions are
`interact` relations merged with the item KG (paper §3.1).

Message passing is built on ``jax.ops.segment_sum`` over COO edge lists
(JAX has no CSR) and is ACT-compressed end-to-end:

  * ``act_spmm``    — weighted neighbor aggregation; saves Quant(E^(l))
  * ``act_matmul``  — layer transform ∇Θ = Ĥᵀ∇J; saves Quant(H^(l))
  * ``act_nonlin``  — σ(J); saves Quant(J^(l))

which is exactly the ctx(·) chain in paper Eq. (2). Edge-softmax
probabilities are (E,)-scalars (no feature dim) and stay fp32 — they are
O(E) not O(N·d), i.e. the "trivial" footprint class of the paper's
memory analysis.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import (
    ACTPolicy,
    FP32,
    KeyChain,
    act_matmul,
    act_nonlin,
    act_spmm,
)
from .layers import glorot, normal_init

__all__ = [
    "KGNNConfig", "CKG", "segment_softmax",
    "init_params", "propagate", "score_pairs", "bpr_loss",
    "activation_shapes",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CKG:
    """Collaborative knowledge graph in COO form (inverse edges included).

    ``n_nodes``/``n_relations`` are pytree aux data — static under jit
    (segment_sum needs static segment counts). ``layout`` optionally
    carries the blocked-CSR arrangement of the same edge list
    (``repro.data.csr.attach_layout``) that routes ``act_spmm`` through
    the fused Pallas kernels under ``ACTPolicy(kernel="pallas")``.
    """

    src: jax.Array  # (E,) int32 node ids
    dst: jax.Array  # (E,) int32 node ids
    rel: jax.Array  # (E,) int32 relation ids
    n_nodes: int    # users + entities (static)
    n_relations: int
    layout: object | None = None  # SpmmLayout (itself a pytree) or None

    def tree_flatten(self):
        return (self.src, self.dst, self.rel, self.layout), (
            self.n_nodes, self.n_relations)

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, rel, layout = children
        return cls(src, dst, rel, aux[0], aux[1], layout)


@dataclasses.dataclass(frozen=True)
class KGNNConfig:
    model: str = "kgat"          # kgat | kgcn | kgin | rgcn
    n_users: int = 0
    n_entities: int = 0          # items + attribute entities
    n_relations: int = 0         # incl. `interact`, both directions
    dim: int = 64                # embedding size (paper fixes 64)
    n_layers: int = 3            # paper fixes 3
    layer_dims: tuple = ()       # per-layer out dims; default = dim each
    n_intents: int = 4           # KGIN
    n_bases: int = 4             # R-GCN basis decomposition
    l2: float = 1e-5
    readout: str = "concat"      # concat (KGAT) | sum (KGIN) | last

    @property
    def n_nodes(self) -> int:
        return self.n_users + self.n_entities

    @property
    def dims(self) -> tuple:
        return self.layer_dims or (self.dim,) * self.n_layers


def segment_softmax(logits: jax.Array, seg: jax.Array, num_segments: int):
    """Numerically-stable softmax over segments (edge softmax)."""
    mx = jax.ops.segment_max(logits, seg, num_segments=num_segments)
    ex = jnp.exp(logits - mx[seg])
    den = jax.ops.segment_sum(ex, seg, num_segments=num_segments)
    return ex / (den[seg] + 1e-16)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: KGNNConfig) -> dict:
    ks = iter(jax.random.split(key, 64))
    d = cfg.dim
    p = {
        "entity": normal_init(next(ks), (cfg.n_nodes, d), 0.1),
        "relation": normal_init(next(ks), (cfg.n_relations, d), 0.1),
    }
    dims = (d,) + cfg.dims
    if cfg.model == "kgat":
        # relation-space projection for attention (TransR style). The paper
        # uses a dense d×d W_r per relation; gathering it per edge is an
        # (E,d,d) tensor — infeasible at industry scale. We keep the
        # relation-specific d×d structure via basis decomposition
        # W_r = Σ_b a_rb V_b (R-GCN trick): project once per basis (B·N·d),
        # mix per edge with (E,B) coefficients. See DESIGN.md §3.
        p["att_basis"] = normal_init(next(ks), (cfg.n_bases, d, d), 0.1)
        p["att_coef"] = normal_init(next(ks), (cfg.n_relations, cfg.n_bases), 0.1)
        p["w1"] = [glorot(next(ks), (a, b)) for a, b in zip(dims[:-1], dims[1:])]
        p["w2"] = [glorot(next(ks), (a, b)) for a, b in zip(dims[:-1], dims[1:])]
    elif cfg.model == "kgcn":
        p["w"] = [glorot(next(ks), (a, b)) for a, b in zip(dims[:-1], dims[1:])]
        p["b"] = [jnp.zeros((b,)) for b in dims[1:]]
    elif cfg.model == "kgin":
        p["intent"] = normal_init(next(ks), (cfg.n_intents, cfg.n_relations), 0.1)
    elif cfg.model == "rgcn":
        p["basis"] = normal_init(next(ks), (cfg.n_bases, d, d), 0.1)
        p["coef"] = normal_init(next(ks), (cfg.n_relations, cfg.n_bases), 0.1)
        p["w_self"] = [glorot(next(ks), (d, d)) for _ in range(cfg.n_layers)]
    else:
        raise ValueError(cfg.model)
    return p


# ---------------------------------------------------------------------------
# propagation (paper Eq. 1/2)
# ---------------------------------------------------------------------------


def _kgat_layer(p, layer: int, e: jax.Array, g: CKG, att: jax.Array,
                policy: ACTPolicy, keys: KeyChain) -> jax.Array:
    """Bi-interaction aggregator: LeakyReLU(W1(e+eN)) + LeakyReLU(W2(e⊙eN))."""
    e_n = act_spmm(e, g.src, g.dst, att, num_nodes=g.n_nodes,
                   key=keys.next(), policy=policy, layout=g.layout)
    add = act_matmul(e + e_n, p["w1"][layer], key=keys.next(), policy=policy)
    mul = act_matmul(e * e_n, p["w2"][layer], key=keys.next(), policy=policy)
    add = act_nonlin(add, key=keys.next(), policy=policy, fn="leaky_relu")
    mul = act_nonlin(mul, key=keys.next(), policy=policy, fn="leaky_relu")
    return add + mul


def _kgat_attention(p, e: jax.Array, g: CKG) -> jax.Array:
    """π(h,r,t) = (W_r e_t)ᵀ tanh(W_r e_h + e_r), softmaxed over dst.

    W_r = Σ_b a_rb V_b: basis-projected node tables (B, N, d) are computed
    once, then mixed per edge — O(B·N·d² + E·B·d) instead of O(E·d²).
    """
    proj = jnp.einsum("nd,bdk->bnk", e, p["att_basis"])  # (B, N, d)
    coef = p["att_coef"][g.rel]                          # (E, B)
    eh = jnp.einsum("eb,bed->ed", coef, proj[:, g.src])  # (E, d)
    et = jnp.einsum("eb,bed->ed", coef, proj[:, g.dst])
    logits = jnp.sum(et * jnp.tanh(eh + p["relation"][g.rel]), axis=-1)
    return segment_softmax(logits, g.dst, g.n_nodes)


def _kgcn_layer(p, layer: int, e: jax.Array, g: CKG, ew: jax.Array,
                policy: ACTPolicy, keys: KeyChain) -> jax.Array:
    """KGNN-LS graph convolution: σ((Â E)Θ + b) with relation-scored Â."""
    h = act_spmm(e, g.src, g.dst, ew, num_nodes=g.n_nodes,
                 key=keys.next(), policy=policy, layout=g.layout)
    j = act_matmul(h + e, p["w"][layer], key=keys.next(), policy=policy)
    j = j + p["b"][layer]
    return act_nonlin(j, key=keys.next(), policy=policy,
                      fn="tanh" if layer == len(p["w"]) - 1 else "sigmoid")


def _kgin_layer(p, e: jax.Array, r_emb: jax.Array, g: CKG,
                policy: ACTPolicy, keys: KeyChain) -> jax.Array:
    """Relational path aggregation: e_h' = Σ_{(r,t)} e_r ⊙ e_t (KGIN eq. 8)."""
    msgs_src = e * 1.0  # (N, d)
    # modulate by relation embedding per edge: gather-then-scale is O(E d);
    # act_spmm with per-edge weights handles the scalar part, the vector
    # modulation composes as two spmm passes over (e ⊙ e_r)-projected feats.
    gathered = msgs_src[g.src] * r_emb[g.rel]     # (E, d)
    deg = jax.ops.segment_sum(jnp.ones_like(g.dst, dtype=e.dtype), g.dst,
                              num_segments=g.n_nodes)
    agg = jax.ops.segment_sum(gathered, g.dst, num_segments=g.n_nodes)
    agg = agg / jnp.maximum(deg, 1.0)[:, None]
    return act_nonlin(agg, key=keys.next(), policy=policy, fn="leaky_relu")


def _rgcn_layer(p, layer: int, e: jax.Array, g: CKG,
                policy: ACTPolicy, keys: KeyChain) -> jax.Array:
    """Basis-decomposed R-GCN: W_r = Σ_b a_rb V_b (basis-first projection)."""
    # project once per basis: (N, B, d)
    proj = jnp.stack([
        act_matmul(e, p["basis"][b], key=keys.next(), policy=policy)
        for b in range(p["basis"].shape[0])
    ], axis=1)
    coef_e = p["coef"][g.rel]                     # (E, B)
    msgs = jnp.einsum("eb,ebd->ed", coef_e, proj[g.src])
    deg = jax.ops.segment_sum(jnp.ones_like(g.dst, dtype=e.dtype), g.dst,
                              num_segments=g.n_nodes)
    agg = jax.ops.segment_sum(msgs, g.dst, num_segments=g.n_nodes)
    agg = agg / jnp.maximum(deg, 1.0)[:, None]
    self_t = act_matmul(e, p["w_self"][layer], key=keys.next(), policy=policy)
    return act_nonlin(agg + self_t, key=keys.next(), policy=policy, fn="leaky_relu")


def propagate(params: dict, g: CKG, cfg: KGNNConfig, *,
              policy: ACTPolicy = FP32, key: jax.Array | None = None):
    """Run L layers of message passing; returns final node representations."""
    keys = KeyChain(key if key is not None else jax.random.PRNGKey(0))
    e = params["entity"]
    outs = [e]

    if cfg.model == "kgat":
        att = _kgat_attention(params, e, g)
        for l in range(cfg.n_layers):
            e = _kgat_layer(params, l, e, g, att, policy, keys)
            outs.append(e)
    elif cfg.model == "kgcn":
        # relation scores are user-agnostic at graph level (KGNN-LS's label-
        # smoothed global graph); per-edge weight = softmax over dst of r·mean
        logits = jnp.sum(params["relation"][g.rel] * e[g.src], axis=-1)
        ew = segment_softmax(logits, g.dst, g.n_nodes)
        for l in range(cfg.n_layers):
            e = _kgcn_layer(params, l, e, g, ew, policy, keys)
            outs.append(e)
    elif cfg.model == "kgin":
        # intent-weighted relation embeddings
        alpha = jax.nn.softmax(params["intent"], axis=-1)       # (P, R)
        r_int = alpha @ params["relation"]                      # (P, d)
        r_emb = params["relation"] + jnp.mean(r_int, 0)         # broadcast intent
        for _ in range(cfg.n_layers):
            e = _kgin_layer(params, e, r_emb, g, policy, keys)
            outs.append(e)
    elif cfg.model == "rgcn":
        for l in range(cfg.n_layers):
            e = _rgcn_layer(params, l, e, g, policy, keys)
            outs.append(e)
    else:
        raise ValueError(cfg.model)

    if cfg.readout == "concat":
        return jnp.concatenate(outs, axis=-1)
    if cfg.readout == "sum":
        return sum(outs)
    return outs[-1]


# ---------------------------------------------------------------------------
# recommendation head (BPR)
# ---------------------------------------------------------------------------


def propagate_spmd(params: dict, g: CKG, cfg: KGNNConfig, *, mesh, axes,
                   policy: ACTPolicy = FP32, key: jax.Array | None = None):
    """Explicitly-partitioned KGAT propagation (shard_map).

    Layout (same scheme as gnn.gcn_forward_spmd, §Perf hillclimb #3):
    entity rows sharded over ``axes``; edges partitioned BY DESTINATION
    shard (``g.src`` global ids, ``g.dst`` LOCAL row ids). Per layer: one
    tiled all-gather of the (N, d) entity matrix; edge attention, edge
    softmax and the weighted scatter all run shard-local. The layer
    transforms stay GSPMD (row-sharded matmuls).
    """
    from jax.sharding import PartitionSpec as P

    assert cfg.model == "kgat", "spmd propagate implemented for KGAT"
    keys = KeyChain(key if key is not None else jax.random.PRNGKey(0))
    e = params["entity"]

    def layer_local(e_loc, basis, src_g, dst_l, rel, coef, r_emb, att_key):
        # e_loc (N/D, d) local entity rows; src_g GLOBAL ids, dst_l LOCAL
        # dst rows (edges pre-partitioned by destination shard)
        proj_loc = jnp.einsum("nd,bdk->bnk", e_loc, basis)  # (B, N/D, d)
        proj_full = jax.lax.all_gather(proj_loc, axes, axis=1, tiled=True)
        e_full = jax.lax.all_gather(e_loc, axes, axis=0, tiled=True)
        eh = jnp.einsum("eb,bed->ed", coef[rel], proj_full[:, src_g])
        et = jnp.einsum("eb,bed->ed", coef[rel], proj_loc[:, dst_l])
        logits = jnp.sum(et * jnp.tanh(eh + r_emb[rel]), axis=-1)
        att = segment_softmax(logits, dst_l, e_loc.shape[0])
        return act_spmm(e_full, src_g, dst_l, att,
                        num_nodes=e_loc.shape[0], key=att_key,
                        policy=policy)

    spmd_layer = jax.shard_map(
        layer_local, mesh=mesh,
        in_specs=(P(axes, None), P(None, None, None), P(axes), P(axes),
                  P(axes), P(None, None), P(None, None), P()),
        out_specs=P(axes, None))

    outs = [e]
    for l in range(cfg.n_layers):
        e_n = spmd_layer(e, params["att_basis"], g.src, g.dst, g.rel,
                         params["att_coef"], params["relation"],
                         keys.next())
        add = act_matmul(e + e_n, params["w1"][l], key=keys.next(),
                         policy=policy)
        mul = act_matmul(e * e_n, params["w2"][l], key=keys.next(),
                         policy=policy)
        e = act_nonlin(add, key=keys.next(), policy=policy, fn="leaky_relu") \
            + act_nonlin(mul, key=keys.next(), policy=policy,
                         fn="leaky_relu")
        outs.append(e)
    return jnp.concatenate(outs, axis=-1) if cfg.readout == "concat" \
        else sum(outs)


def score_pairs(reps: jax.Array, users: jax.Array, items: jax.Array,
                n_users: int) -> jax.Array:
    """ŷ_uv = e_uᵀ e_v; item node ids are offset by n_users in the CKG."""
    return jnp.sum(reps[users] * reps[items + n_users], axis=-1)


def bpr_loss(params: dict, g: CKG, batch: dict, cfg: KGNNConfig, *,
             policy: ACTPolicy = FP32, key: jax.Array | None = None):
    """BPR pairwise ranking loss + L2 (the KGAT/KGIN objective)."""
    reps = propagate(params, g, cfg, policy=policy, key=key)
    pos = score_pairs(reps, batch["user"], batch["pos"], cfg.n_users)
    neg = score_pairs(reps, batch["user"], batch["neg"], cfg.n_users)
    loss = -jnp.mean(jax.nn.log_sigmoid(pos - neg))
    reg = sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(params))
    return loss + cfg.l2 * reg


def activation_shapes(cfg: KGNNConfig, n_edges: int) -> dict:
    """Saved-activation shapes per train step (paper Table 5 accounting).

    Per layer the ctx chain stores: E^(l) for spmm's ∇ew, H^(l) for the
    transform's ∇Θ, and J^(l) for σ'. KGAT's bi-interaction doubles the
    matmul/nonlin entries.
    """
    n, dims = cfg.n_nodes, cfg.dims
    shapes = {}
    per_layer = {"kgat": 4, "kgcn": 2, "kgin": 1, "rgcn": 2}[cfg.model]
    d_in = cfg.dim
    for l, d_out in enumerate(dims):
        shapes[f"E_{l}"] = (n, d_in)                   # spmm input
        for j in range(per_layer):
            shapes[f"HJ_{l}_{j}"] = (n, d_out if j % 2 else d_in)
        d_in = d_out
    return shapes
