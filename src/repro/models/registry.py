"""Model-step registry: ``--arch <id>`` -> one ``ModelStep`` (DESIGN.md §9).

One builder per family turns an ``ArchSpec`` (plus optional data
overrides) into the single step definition everything consumes — the
launcher's generic driver, ``make_train_step``, the data-parallel
wrapper, the examples and the paper-table benchmarks. The builders are
thin: they bind the EXISTING layer functions (``models.kgnn``,
``models.gnn``, ``models.transformer``, ``models.recsys``) to a dataset
and a config; no model math lives here.

KG steps carry a ``DPSpec`` (edges dst-sharded, params replicated,
``kg_shard_loss`` as the in-``shard_map`` objective), so kgat, kgcn and
kgin all get compressed-gradient data parallelism through the same
``make_dp_step``. Non-graph families carry an honest
``dp_unsupported`` reason instead.

    step = build_step("kgcn", schedule=schedule)
    train_step = make_train_step(step, adam(step.lr), schedule=schedule,
                                 root_key=root)
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get
from repro.configs.base import ArchSpec
from repro.training.step import (DPSpec, ModelStep, ROW_SHARDED,
                                 enter_or_null)

__all__ = ["build_step", "register_family", "kg_dp_spec", "kg_archs",
           "FAMILY_BUILDERS"]

FAMILY_BUILDERS: dict[str, Callable] = {}


def register_family(*families: str):
    def deco(fn):
        for f in families:
            FAMILY_BUILDERS[f] = fn
        return fn
    return deco


def build_step(arch: str | ArchSpec, *, schedule=None, **overrides) -> ModelStep:
    """Resolve an arch id (or spec) to its registered ``ModelStep``.

    ``schedule`` is the run's policy schedule — builders that need it at
    data-build time (blocked-CSR layout attachment) look at its
    ``kernel``; per-step policy resolution still happens inside the
    ``ActContext`` at trace time. ``overrides`` are family-specific
    (e.g. ``ds=``/``cfg=``/``batch_size=`` for KG steps) so examples and
    benchmarks can bring their own sizes while reusing the same wiring.
    """
    spec = get(arch) if isinstance(arch, str) else arch
    if spec.family not in FAMILY_BUILDERS:
        raise KeyError(f"no step builder registered for family "
                       f"{spec.family!r} (arch {spec.name!r}); have "
                       f"{sorted(FAMILY_BUILDERS)}")
    return FAMILY_BUILDERS[spec.family](spec, schedule=schedule, **overrides)


def kg_archs() -> tuple[str, ...]:
    """Registered KG arch ids (the paper's models), registry order."""
    return tuple(n for n, a in ARCHS.items() if a.family == "kgnn")


# ---------------------------------------------------------------------------
# kgnn family: kgat / kgcn / kgin — BPR over a collaborative KG
# ---------------------------------------------------------------------------


def kg_dp_spec(cfg, graph=None) -> DPSpec:
    """The KG mesh contract: edges dst-sharded over ``data``, batch
    sharded; the in-shard objective is ``kgnn.kg_shard_loss`` running
    the same ``propagate_view`` layer math as the single-device step.
    ``placement`` marks the entity table row-sharded over the ``model``
    axis — the dominant footprint at scale; on a 1D ``data=N`` mesh the
    placement is inert and everything is replicated, as before."""
    from repro.models import kgnn

    return DPSpec(
        graph=graph, scope=cfg.model, sites=kgnn.model_sites(cfg),
        n_layers=cfg.n_layers,
        shard_loss=functools.partial(kgnn.kg_shard_loss, cfg=cfg),
        shard_reps=functools.partial(kgnn.kg_shard_reps, cfg=cfg),
        placement=(("entity", ROW_SHARDED),))


@register_family("kgnn")
def _kgnn_step(arch: ArchSpec, *, schedule=None, ds=None, cfg=None,
               batch_size: int = 512, data_seed: int = 2,
               lr: float = 3e-3, dim: int = 32,
               n_layers: int = 3, device_graph: bool = True) -> ModelStep:
    from repro.data.csr import maybe_attach_layout
    from repro.data.synthetic import bpr_batches, gen_kg_dataset
    from repro.models import kgnn

    if ds is None:
        ds = gen_kg_dataset(n_users=120, n_items=200, n_attrs=80, seed=0)
    model = arch.model_cfg.model
    if cfg is None:
        cfg = kgnn.KGNNConfig(
            model=model, n_users=ds.n_users, n_entities=ds.n_entities,
            n_relations=ds.n_relations, dim=dim, n_layers=n_layers,
            readout="concat" if model == "kgat" else "sum")
    if device_graph:
        g = jax.tree_util.tree_map(jnp.asarray, ds.graph)
        g = maybe_attach_layout(g, schedule, model=cfg.model)
    else:
        # sampled-minibatch runs (training.tiering) never touch the full
        # edge list on device — keep the COO host-side so the device
        # budget holds only the hot tier + gathered batch rows
        g = ds.graph

    def init(key, data_spec=None):
        return kgnn.init_params(key, cfg)

    def loss(params, batch, *, ctx=None):
        with enter_or_null(ctx):
            return kgnn.bpr_loss(params, g, batch, cfg)

    def batches():
        for b in bpr_batches(ds, batch_size, seed=data_seed):
            yield jax.tree_util.tree_map(jnp.asarray, b)

    return ModelStep(
        arch=arch.name, family="kgnn", cfg=cfg, init=init, loss=loss,
        batches=batches, lr=lr, dp_spec=kg_dp_spec(cfg, g),
        data={"graph": g, "dataset": ds},
        data_spec={"n_users": ds.n_users, "n_entities": ds.n_entities,
                   "n_relations": ds.n_relations,
                   "n_edges": int(g.src.shape[0])})


# ---------------------------------------------------------------------------
# gnn family: gcn-cora — full-graph node classification
# ---------------------------------------------------------------------------


@register_family("gnn")
def _gnn_step(arch: ArchSpec, *, schedule=None, n_nodes: int = 300,
              lr: float = 1e-2) -> ModelStep:
    from repro.configs.smoke import reduced
    from repro.data.csr import build_spmm_layout
    from repro.data.synthetic import cora_like
    from repro.models import gnn

    cfg = reduced(arch).model_cfg
    feats, src, dst, labels = cora_like(n_nodes=n_nodes, d_feat=cfg.d_in)
    x, s, d, y = map(jnp.asarray, (feats, src, dst, labels))
    layout = build_spmm_layout(src, dst, n_dst=n_nodes) \
        if getattr(schedule, "kernel", "jnp") == "pallas" else None

    def init(key, data_spec=None):
        return gnn.init_params(key, cfg)

    def loss(params, batch, *, ctx=None):
        with enter_or_null(ctx):
            logits = gnn.gcn_forward(params, x, s, d, n_nodes=n_nodes,
                                     cfg=cfg, layout=layout)
        oh = jax.nn.one_hot(y, cfg.n_classes)
        return -jnp.mean(jnp.sum(oh * jax.nn.log_softmax(logits), -1))

    def batches():
        while True:
            yield {}

    return ModelStep(
        arch=arch.name, family="gnn", cfg=cfg, init=init, loss=loss,
        batches=batches, lr=lr, dp_spec=None,
        dp_unsupported=(
            "gcn-cora trains full-graph from dense node features; the "
            "edge-sharded DP path covers the KG entity-embedding steps "
            "(sharded GCN lives in gnn.gcn_forward_spmd via "
            "launch.partition)"),
        data={"features": x, "labels": y},
        data_spec={"n_nodes": n_nodes, "d_in": cfg.d_in})


# ---------------------------------------------------------------------------
# lm / moe_lm families: next-token CE on synthetic streams
# ---------------------------------------------------------------------------


@register_family("lm", "moe_lm")
def _lm_step(arch: ArchSpec, *, schedule=None, batch: int = 8,
             seq: int = 64, lr: float = 1e-3) -> ModelStep:
    from repro.configs.smoke import reduced
    from repro.data.synthetic import lm_batches
    from repro.models import transformer as tf

    cfg = reduced(arch).model_cfg

    def init(key, data_spec=None):
        return tf.init_params(key, cfg)

    def loss(params, batch_, *, ctx=None):
        with enter_or_null(ctx):
            return tf.lm_loss(params, batch_, cfg=cfg)

    def batches():
        for b in lm_batches(vocab=cfg.vocab, batch=batch, seq=seq, seed=0):
            yield {"tokens": jnp.asarray(b["tokens"])}

    return ModelStep(
        arch=arch.name, family=arch.family, cfg=cfg, init=init, loss=loss,
        batches=batches, lr=lr, dp_spec=None,
        dp_unsupported=(
            "the transformer step shards by batch/sequence, not by graph "
            "edges; LM data parallelism needs batch-sharded loss plus "
            "replicated-optimizer wiring the edge-sharded SPMD path does "
            "not provide (use launch.partition's GSPMD cells)"),
        data_spec={"vocab": cfg.vocab, "batch": batch, "seq": seq})


# ---------------------------------------------------------------------------
# recsys family: fm / wide&deep / dlrm / xdeepfm — CTR logistic loss
# ---------------------------------------------------------------------------


@register_family("recsys")
def _recsys_step(arch: ArchSpec, *, schedule=None, batch: int = 256,
                 lr: float = 1e-3) -> ModelStep:
    from repro.configs.smoke import reduced
    from repro.data.synthetic import criteo_batches
    from repro.models import recsys

    cfg = reduced(arch).model_cfg

    def init(key, data_spec=None):
        return recsys.init_params(key, cfg)

    def loss(params, batch_, *, ctx=None):
        with enter_or_null(ctx):
            logits = recsys.forward(params, batch_, cfg)
        lab = batch_["label"]
        return -jnp.mean(lab * jax.nn.log_sigmoid(logits)
                         + (1 - lab) * jax.nn.log_sigmoid(-logits))

    def batches():
        for b in criteo_batches(batch=batch, n_dense=max(cfg.n_dense, 1),
                                vocab_sizes=cfg.vocab_sizes, seed=3):
            yield jax.tree_util.tree_map(jnp.asarray, b)

    return ModelStep(
        arch=arch.name, family="recsys", cfg=cfg, init=init, loss=loss,
        batches=batches, lr=lr, dp_spec=None,
        dp_unsupported=(
            "recsys steps have no graph to edge-shard; DLRM-scale "
            "parallelism is embedding-table model parallelism (a "
            "different axis — see launch.partition), not this "
            "edge-sharded DP path"),
        data_spec={"batch": batch, "n_sparse": cfg.n_sparse})
