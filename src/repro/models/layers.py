"""Shared functional layers (no flax available — params are plain pytrees).

Every layer is a pure function ``f(params, x, ...)``; initializers return
nested dicts of jnp arrays. ACT integration: layers accept an ``ACTPolicy``
and a ``KeyChain`` and route through ``repro.core.act`` ops, so any model
built from these layers is TinyKG-compressible end to end.
"""

from __future__ import annotations

import contextlib
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import ACTPolicy, KeyChain, act_dense, act_nonlin, act_relu
from repro.core.context import current_context

__all__ = [
    "glorot", "lecun", "normal_init",
    "dense_params", "mlp_params", "mlp_apply",
    "embedding_bag",
]


def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def lecun(key, shape, dtype=jnp.float32):
    fan_in = shape[-2]
    return jax.random.normal(key, shape, dtype) / math.sqrt(fan_in)


def normal_init(key, shape, stddev=0.02, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * stddev


def dense_params(key, d_in: int, d_out: int, *, bias: bool = True,
                 dtype=jnp.float32) -> dict:
    p = {"w": glorot(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def mlp_params(key, dims: Sequence[int], *, bias: bool = True,
               dtype=jnp.float32) -> list:
    keys = jax.random.split(key, len(dims) - 1)
    return [dense_params(k, a, b, bias=bias, dtype=dtype)
            for k, a, b in zip(keys, dims[:-1], dims[1:])]


def mlp_apply(params: list, x: jax.Array, *, policy: ACTPolicy | None = None,
              keys: KeyChain | None = None, act: str = "relu",
              final_act: bool = False, scope: str = "mlp") -> jax.Array:
    """MLP with ACT-compressed matmuls + activations.

    ReLU uses the exact 1-bit mask path; other activations store quantized
    inputs per the policy. Two key regimes: pass a legacy ``KeyChain``
    (positional keys, explicit ``policy``) or pass neither and let the
    ambient ``ActContext`` resolve per-site at ``<scope>/fc<i>``.
    """
    n = len(params)
    ctx = current_context()
    with ctx.scope(scope) if ctx is not None else contextlib.nullcontext():
        for i, p in enumerate(params):
            k = keys.next() if keys is not None else None
            x = act_dense(x, p["w"], p.get("b"), key=k, policy=policy,
                          scope=f"fc{i}")
            if i < n - 1 or final_act:
                if act == "relu":
                    x = act_relu(x, scope=f"relu{i}")
                else:
                    k = keys.next() if keys is not None else None
                    x = act_nonlin(x, key=k, policy=policy, fn=act,
                                   scope=f"act{i}")
    return x


def embedding_bag(table: jax.Array, idx: jax.Array, segment_ids: jax.Array,
                  num_segments: int, *, weights: jax.Array | None = None,
                  combiner: str = "sum") -> jax.Array:
    """EmbeddingBag built from gather + segment_sum (JAX has no native op).

    table        : (vocab, dim)
    idx          : (nnz,) int — which rows to look up
    segment_ids  : (nnz,) int — which output bag each lookup belongs to
    num_segments : number of bags (static)

    Lookup gradients flow through jnp.take's scatter-add — index residuals
    only, so there is no activation map to compress here (see DESIGN.md).
    """
    rows = jnp.take(table, idx, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if combiner == "mean":
        counts = jax.ops.segment_sum(jnp.ones_like(idx, dtype=rows.dtype),
                                     segment_ids, num_segments=num_segments)
        out = out / jnp.maximum(counts, 1.0)[:, None]
    elif combiner != "sum":
        raise ValueError(f"unknown combiner {combiner}")
    return out
