"""Model substrates: KGNNs (paper targets), LM transformers, GNN, recsys."""
