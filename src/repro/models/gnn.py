"""GCN (Kipf & Welling, arXiv:1609.02907) — assigned arch ``gcn-cora``.

Three execution regimes per the assigned shapes:
  * full-batch     (full_graph_sm / ogb_products): propagate over all nodes
  * sampled        (minibatch_lg): fanout-sampled block adjacencies from
                    ``repro.data.sampler`` (15-10 two-hop)
  * batched graphs (molecule): block-diagonal edge offsets, graph readout

Symmetric normalization D^-1/2 A D^-1/2 is folded into node scalings around
an unweighted ``act_spmm`` (exact — the aggregation is linear, so only the
transform/nonlinearity activations are compressed, matching paper Eq. 2
where ∇E = ctx(Â, ∇H) needs no activation).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import (
    ACTPolicy,
    PolicySchedule,
    act_matmul,
    act_relu,
    act_spmm,
    model_context,
)
from repro.sharding.logical import constraint

from .layers import glorot

__all__ = ["GCNConfig", "init_params", "gcn_forward", "gcn_forward_blocks",
           "gcn_forward_batched"]


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    n_layers: int = 2
    d_in: int = 1433        # cora features
    d_hidden: int = 16
    n_classes: int = 7
    aggregator: str = "mean"   # paper config: mean with sym norm
    norm: str = "sym"
    # Â(XW) == (ÂX)W — when d_in > d_out, transforming BEFORE aggregating
    # moves 6-90x less data through the gather/scatter collectives
    # (EXPERIMENTS.md §Perf hillclimb #3). False reproduces the naive order.
    transform_first: bool = True
    # all-gather node features in bf16 (TinyKG's compression premise
    # applied to the fabric); accumulation stays f32
    compressed_gather: bool = True


def init_params(key: jax.Array, cfg: GCNConfig) -> dict:
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, len(dims) - 1)
    return {"w": [glorot(k, (a, b)) for k, a, b in zip(keys, dims[:-1], dims[1:])]}


def _sym_norm(src, dst, n_nodes, dtype=jnp.float32):
    deg = jax.ops.segment_sum(jnp.ones_like(src, dtype=dtype), dst,
                              num_segments=n_nodes)
    return jax.lax.rsqrt(jnp.maximum(deg, 1.0))


def gcn_forward(params, x, src, dst, *, n_nodes: int, cfg: GCNConfig,
                policy: ACTPolicy | PolicySchedule | None = None, key=None,
                layout=None):
    """Full-batch GCN: Z = Â ... σ(Â X W0) W1 with self-loops assumed in edges.

    ``layout`` optionally carries the blocked-CSR arrangement of the edge
    list; under ``ACTPolicy(kernel="pallas")`` the (linear) aggregation
    then runs through the fused Pallas SPMM in both directions.
    ``policy``/``key`` omitted resolve from the ambient ``ActContext`` at
    the ``gcn/layer<l>/...`` sites.
    """
    ctx = model_context(policy, key)
    ctx.check_key("gcn_forward")
    dinv = _sym_norm(src, dst, n_nodes, x.dtype)
    h = x
    with ctx, ctx.scope("gcn"):
        for l, w in enumerate(params["w"]):
            with ctx.scope(f"layer{l}"):
                pre = cfg.transform_first and w.shape[0] > w.shape[1]
                if pre:  # (ÂX)W == Â(XW): aggregate the narrow side
                    h = act_matmul(h, w, scope="dense")
                h = h * dinv[:, None]
                h = act_spmm(h, src, dst, None, num_nodes=n_nodes,
                             scope="agg", layout=layout)
                # pin the aggregation output row-sharded: GSPMD then emits
                # reduce-scatter (1x payload) instead of all-reduce (2x)
                h = constraint(h, "batch", None)
                h = h * dinv[:, None]
                if not pre:
                    h = act_matmul(h, w, scope="dense")
                if l < len(params["w"]) - 1:
                    h = act_relu(h, scope="relu")
    return h


def gcn_forward_spmd(params, x, src_g, dst_l, deg, *, mesh, axes,
                     cfg: GCNConfig,
                     policy: ACTPolicy | PolicySchedule | None = None,
                     key=None):
    """Explicitly-partitioned full-graph GCN (shard_map aggregation).

    Production layout (EXPERIMENTS.md §Perf hillclimb #3, iter 3):
      * node rows sharded over ``axes``; edges partitioned BY DESTINATION
        shard by the input pipeline (sorted + padded to equal counts)
      * ``src_g`` holds GLOBAL source ids, ``dst_l`` LOCAL destination rows
      * per layer: one tiled all-gather of the (narrow) feature matrix;
        gather + segment_sum run entirely shard-local — no all-reduce.
    Autodiff through shard_map gives the transposed schedule for free
    (all-gatherᵀ = reduce-scatter).
    """
    from repro.sharding.compat import P, shard_map

    ctx = model_context(policy, key)
    ctx.check_key("gcn_forward_spmd")
    dinv = jax.lax.rsqrt(jnp.maximum(deg, 1.0))

    def agg_local(x_loc, src_, dst_):
        # bf16 wire format: the upcast must sit AFTER the segment_sum or
        # XLA's convert-mover hoists it back across the all-gather (the
        # scatter-add is the commute barrier). Accumulating ~deg values in
        # bf16 costs <0.4% error at deg≈25 — same class as ACT noise.
        xs = x_loc.astype(jnp.bfloat16) if cfg.compressed_gather else x_loc
        x_full = jax.lax.all_gather(xs, axes, axis=0, tiled=True)
        agg_v = jax.ops.segment_sum(x_full[src_], dst_,
                                    num_segments=x_loc.shape[0])
        return agg_v.astype(x_loc.dtype)

    agg = shard_map(
        agg_local, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(axes)),
        out_specs=P(axes, None))

    h = x
    with ctx, ctx.scope("gcn"):
        for l, w in enumerate(params["w"]):
            with ctx.scope(f"layer{l}"):
                pre = cfg.transform_first and w.shape[0] > w.shape[1]
                if pre:
                    h = act_matmul(h, w, scope="dense")
                h = h * dinv[:, None]
                h = agg(h, src_g, dst_l)
                h = h * dinv[:, None]
                if not pre:
                    h = act_matmul(h, w, scope="dense")
                if l < len(params["w"]) - 1:
                    h = act_relu(h, scope="relu")
    return h


def gcn_forward_blocks(params, x, blocks, *, cfg: GCNConfig,
                       policy: ACTPolicy | PolicySchedule | None = None,
                       key=None):
    """Sampled-minibatch GCN over fanout blocks (GraphSAGE-style training).

    ``blocks``: list (outermost hop first) of dicts with
      src, dst : int32 (E_b,) indices LOCAL to the block's src/dst node sets
      n_src, n_dst : static sizes (padded)
    ``x``: features of the outermost src node set.
    """
    ctx = model_context(policy, key)
    ctx.check_key("gcn_forward_blocks")
    h = x
    with ctx, ctx.scope("gcn_blocks"):
        for l, (w, blk) in enumerate(zip(params["w"], blocks)):
            with ctx.scope(f"layer{l}"):
                deg = jax.ops.segment_sum(
                    jnp.ones_like(blk["src"], dtype=h.dtype), blk["dst"],
                    num_segments=blk["n_dst"])
                agg = act_spmm(h, blk["src"], blk["dst"], None,
                               num_nodes=blk["n_dst"], scope="agg")
                h = agg / jnp.maximum(deg, 1.0)[:, None]
                h = act_matmul(h, w, scope="dense")
                if l < len(params["w"]) - 1:
                    h = act_relu(h, scope="relu")
    return h


def gcn_forward_batched(params, x, src, dst, graph_ids, *, n_graphs: int,
                        n_nodes: int, cfg: GCNConfig,
                        policy: ACTPolicy | PolicySchedule | None = None,
                        key=None, layout=None):
    """Batched small graphs (molecule): block-diag edges + mean readout."""
    node_logits = gcn_forward(params, x, src, dst, n_nodes=n_nodes, cfg=cfg,
                              policy=policy, key=key, layout=layout)
    pooled = jax.ops.segment_sum(node_logits, graph_ids, num_segments=n_graphs)
    counts = jax.ops.segment_sum(jnp.ones((n_nodes,), x.dtype), graph_ids,
                                 num_segments=n_graphs)
    return pooled / jnp.maximum(counts, 1.0)[:, None]


# Activation-memory accounting is trace-derived: run the forward under a
# recording ActContext (``repro.core.traced_activation_report``). The old
# hand-maintained ``activation_shapes`` table is gone.
