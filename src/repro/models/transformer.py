"""Decoder-only LM (dense + MoE): GQA, RoPE, RMSNorm, SwiGLU.

Scale discipline:
  * ``jax.lax.scan`` over layers (stacked params) — HLO size and compile
    time are O(1) in depth; mandatory for 88/64-layer dry-runs.
  * per-block ACT: each block is wrapped in ``act_remat`` — the backward
    recomputes the block from a b-bit quantized copy of its input, so the
    only per-layer residual is the compressed residual stream (the TinyKG
    mechanism applied block-wise, GACT/Mesa-style; policy "none" degrades
    to plain ``jax.checkpoint`` — the FP32 baseline).
  * attention is the chunked online-softmax form (attention.py) — no S×S
    materialization.

Serve path: ``init_cache`` + ``prefill`` + ``decode_step`` with a KV cache
laid out (L, B, Smax, Kh, Dh); for ``long_500k`` the cache shards over the
sequence axis (context parallelism — see launch/partition.py).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import ACTPolicy, PolicySchedule, act_remat, current_context
from repro.sharding.logical import constraint

from .attention import chunked_causal_attention, decode_attention, rope
from .moe import MoEConfig, moe_ffn, moe_params

__all__ = ["TransformerConfig", "init_params", "forward", "lm_loss",
           "init_cache", "prefill", "decode_step"]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    rope_theta: float = 1e6
    moe: MoEConfig | None = None
    dtype: str = "float32"          # "float32" | "bfloat16"
    q_chunk: int = 512
    kv_chunk: int = 1024
    norm_eps: float = 1e-5
    # int8 KV cache (beyond-paper: TinyKG's quantizer on the serve path).
    # Per-(token, head) row quantization over d_head, nearest rounding
    # (inference — no gradient unbiasedness requirement). Halves cache
    # HBM vs bf16; enabled per-shape by the launcher for decode cells.
    kv_cache_bits: int | None = None

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS = 6·N·D roofline term)."""
        d, L = self.d_model, self.n_layers
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
            + self.n_heads * self.d_head * d
        if self.moe is not None:
            ffn = self.moe.n_experts * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        else:
            ffn = 3 * d * self.d_ff
        return L * (attn + ffn + 2 * d) + 2 * self.vocab * d + d

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.n_params
        d, L = self.d_model, self.n_layers
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
            + self.n_heads * self.d_head * d
        ffn = self.moe.top_k * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        return L * (attn + ffn + 2 * d) + 2 * self.vocab * d + d


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _block_params(key: jax.Array, cfg: TransformerConfig) -> dict:
    ks = jax.random.split(key, 8)
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = cfg.jdtype
    s = d ** -0.5
    p = {
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "wq": jax.random.normal(ks[0], (d, h * dh), dt) * s,
        "wk": jax.random.normal(ks[1], (d, kh * dh), dt) * s,
        "wv": jax.random.normal(ks[2], (d, kh * dh), dt) * s,
        "wo": jax.random.normal(ks[3], (h * dh, d), dt) * (h * dh) ** -0.5,
    }
    if cfg.moe is not None:
        p["moe"] = moe_params(ks[4], d, cfg.moe, dt)
    else:
        p["w_gate"] = jax.random.normal(ks[5], (d, cfg.d_ff), dt) * s
        p["w_up"] = jax.random.normal(ks[6], (d, cfg.d_ff), dt) * s
        p["w_down"] = jax.random.normal(ks[7], (cfg.d_ff, d), dt) * cfg.d_ff ** -0.5
    return p


def init_params(key: jax.Array, cfg: TransformerConfig) -> dict:
    k_emb, k_head, k_blocks = jax.random.split(key, 3)
    dt = cfg.jdtype
    blocks = jax.vmap(lambda k: _block_params(k, cfg))(
        jax.random.split(k_blocks, cfg.n_layers))
    return {
        "emb": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), dt) * 0.02,
        "blocks": blocks,   # every leaf stacked: (L, ...)
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "head": jax.random.normal(k_head, (cfg.d_model, cfg.vocab), dt)
        * cfg.d_model ** -0.5,
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _rmsnorm(x, gamma, eps):
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * r).astype(x.dtype) * gamma


def _block_fwd(cfg: TransformerConfig):
    """Returns fn(params_l, x, positions) -> y; closed over static cfg only."""
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    def fn(p, x, positions):
        B, S, d = x.shape
        x = constraint(x, "batch", "seq", "embed")
        y = _rmsnorm(x, p["ln1"], cfg.norm_eps)
        q = (y @ p["wq"]).reshape(B, S, h, dh)
        k = (y @ p["wk"]).reshape(B, S, kh, dh)
        v = (y @ p["wv"]).reshape(B, S, kh, dh)
        # attention internals run over the FULL sequence: Megatron-SP
        # all-gathers q/k/v ONCE here (otherwise every kv-chunk slice of a
        # seq-sharded tensor re-gathers — measured collective blow-up)
        q = constraint(rope(q, positions, cfg.rope_theta),
                       "batch", None, "heads", None)
        k = constraint(rope(k, positions, cfg.rope_theta),
                       "batch", None, "kv_heads", None)
        v = constraint(v, "batch", None, "kv_heads", None)
        attn = chunked_causal_attention(q, k, v, q_chunk=cfg.q_chunk,
                                        kv_chunk=cfg.kv_chunk)
        attn = constraint(attn, "batch", None, "heads", None)
        x = x + attn.reshape(B, S, h * dh) @ p["wo"]
        x = constraint(x, "batch", "seq", "embed")

        y = _rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            out, _aux = moe_ffn(p["moe"], y.reshape(B * S, d), cfg.moe)
            x = x + out.reshape(B, S, d)
        else:
            g = constraint(jax.nn.silu(y @ p["w_gate"]) * (y @ p["w_up"]),
                           "batch", None, "ff")
            x = x + g @ p["w_down"]
        return constraint(x, "batch", "seq", "embed")

    return fn


def forward(params: dict, tokens: jax.Array, cfg: TransformerConfig, *,
            policy: ACTPolicy | PolicySchedule | None = None,
            key: jax.Array | None = None):
    """tokens (B, S) -> logits (B, S, vocab).

    ``policy``/``key`` omitted resolve from the ambient ``ActContext``:
    the block policy at the (scope-stacked, #k-deduped) site
    ``.../lm/block`` inside ``act_remat``, and the per-layer SR keys from
    a root keyed at the registered site ``.../lm`` — so two forwards
    under one recording context get distinct rounding noise, like every
    other op.
    """
    B, S = tokens.shape
    if isinstance(policy, PolicySchedule):
        # the whole stack is one remat site — resolve the schedule here
        policy = policy.resolve("remat", "lm/block")
    ctx = current_context()
    if key is None:
        if ctx is not None and ctx.root_key is not None:
            key = ctx.key_for(ctx.qualify("lm"))
        else:
            if ctx is not None:
                ctx.check_key("transformer.forward")
            if policy is not None and policy.requires_key:
                raise ValueError(
                    "transformer.forward: stochastic rounding under an "
                    "active policy needs a PRNG key — pass key=, or run "
                    "inside act_context(..., root_key=...)")
            key = jax.random.PRNGKey(0)
    x = constraint(jnp.take(params["emb"], tokens, axis=0),
                   "batch", "seq", "embed")
    positions = jnp.arange(S)
    # all layers share one scan body: one act_remat site, `repeat` records
    block = act_remat(_block_fwd(cfg), policy, scope="lm/block",
                      repeat=cfg.n_layers)
    layer_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(cfg.n_layers))

    def scan_fn(x, layer):
        p_l, k_l = layer
        return block(p_l, x, k_l, positions), None

    x, _ = jax.lax.scan(scan_fn, x, (params["blocks"], layer_keys))
    x = _rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return constraint(x @ params["head"], "batch", None, "vocab")


def lm_loss(params: dict, batch: dict, cfg: TransformerConfig, *,
            policy: ACTPolicy | PolicySchedule | None = None,
            key: jax.Array | None = None):
    """Next-token cross entropy. batch: tokens (B, S), loss on shifted."""
    tokens = batch["tokens"]
    logits = forward(params, tokens[:, :-1], cfg, policy=policy, key=key)
    targets = tokens[:, 1:]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def _q8(x: jax.Array):
    """Per-row (last axis) int8 quantization, nearest rounding.

    Returns (codes int8-as-uint8, scale, zero) with fp32 row stats."""
    xf = x.astype(jnp.float32)
    lo = jnp.min(xf, axis=-1, keepdims=True)
    hi = jnp.max(xf, axis=-1, keepdims=True)
    scale = (hi - lo) / 255.0
    codes = jnp.round((xf - lo) / jnp.maximum(hi - lo, 1e-12) * 255.0)
    return codes.astype(jnp.uint8), scale, lo


def _dq8(codes: jax.Array, scale: jax.Array, zero: jax.Array, dtype):
    return (codes.astype(jnp.float32) * scale + zero).astype(dtype)


def init_cache(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    if cfg.kv_cache_bits == 8:
        stat = shape[:-1] + (1,)
        return {
            "k": jnp.zeros(shape, jnp.uint8),
            "v": jnp.zeros(shape, jnp.uint8),
            "k_s": jnp.zeros(stat, jnp.float32),
            "k_z": jnp.zeros(stat, jnp.float32),
            "v_s": jnp.zeros(stat, jnp.float32),
            "v_z": jnp.zeros(stat, jnp.float32),
            "len": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, cfg.jdtype),
        "v": jnp.zeros(shape, cfg.jdtype),
        "len": jnp.zeros((), jnp.int32),
    }


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                cfg: TransformerConfig):
    """One decode step. tokens (B, 1) -> (logits (B, vocab), new cache)."""
    B = tokens.shape[0]
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q8 = cfg.kv_cache_bits == 8
    x = jnp.take(params["emb"], tokens, axis=0)  # (B, 1, d)
    pos = cache["len"][None]                     # (1,)

    def _dus(buf, upd):
        buf = jax.lax.dynamic_update_slice_in_dim(buf, upd, cache["len"],
                                                  axis=1)
        return constraint(buf, "batch", "cache_seq", None, None)

    def scan_fn(carry, layer):
        x, li = carry
        if q8:
            p, kc, ks, kz, vc, vs, vz = layer
        else:
            p, kc, vc = layer
        x = constraint(x, "batch", None, "embed")
        y = _rmsnorm(x, p["ln1"], cfg.norm_eps)
        q = rope((y @ p["wq"]).reshape(B, 1, h, dh), pos, cfg.rope_theta)
        k_new = rope((y @ p["wk"]).reshape(B, 1, kh, dh), pos, cfg.rope_theta)
        v_new = (y @ p["wv"]).reshape(B, 1, kh, dh)
        if q8:
            kq, ksn, kzn = _q8(k_new)
            vq, vsn, vzn = _q8(v_new)
            kc, ks, kz = _dus(kc, kq), _dus(ks, ksn), _dus(kz, kzn)
            vc, vs, vz = _dus(vc, vq), _dus(vs, vsn), _dus(vz, vzn)
            k_use = _dq8(kc, ks, kz, cfg.jdtype)
            v_use = _dq8(vc, vs, vz, cfg.jdtype)
            out_caches = (kc, ks, kz, vc, vs, vz)
        else:
            kc, vc = _dus(kc, k_new), _dus(vc, v_new)
            k_use, v_use = kc, vc
            out_caches = (kc, vc)
        attn = decode_attention(q, k_use, v_use, cache["len"] + 1)
        x = x + attn.reshape(B, 1, h * dh) @ p["wo"]
        y = _rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            out, _ = moe_ffn(p["moe"], y.reshape(B, -1), cfg.moe)
            x = x + out.reshape(B, 1, -1)
        else:
            x = x + (jax.nn.silu(y @ p["w_gate"]) * (y @ p["w_up"])) @ p["w_down"]
        return (x, li + 1), out_caches

    if q8:
        xs = (params["blocks"], cache["k"], cache["k_s"], cache["k_z"],
              cache["v"], cache["v_s"], cache["v_z"])
    else:
        xs = (params["blocks"], cache["k"], cache["v"])
    (x, _), outs = jax.lax.scan(scan_fn, (x, 0), xs)
    x = _rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x @ params["head"])[:, 0]
    if q8:
        new_cache = dict(zip(("k", "k_s", "k_z", "v", "v_s", "v_z"), outs))
    else:
        new_cache = dict(zip(("k", "v"), outs))
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache


def prefill(params: dict, tokens: jax.Array, cfg: TransformerConfig,
            cache: dict):
    """Prompt ingestion: runs the train-style forward while filling the cache."""
    B, S = tokens.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    x = jnp.take(params["emb"], tokens, axis=0)
    positions = jnp.arange(S)

    q8 = cfg.kv_cache_bits == 8

    def _fill(buf, new):
        buf = jax.lax.dynamic_update_slice_in_dim(buf, new, 0, axis=1)
        return constraint(buf, "batch", "cache_seq", None, None)

    def scan_fn(x, layer):
        if q8:
            p, kc, ks, kz, vc, vs, vz = layer
        else:
            p, kc, vc = layer
        x = constraint(x, "batch", "seq", "embed")
        y = _rmsnorm(x, p["ln1"], cfg.norm_eps)
        q = rope((y @ p["wq"]).reshape(B, S, h, dh), positions, cfg.rope_theta)
        k = rope((y @ p["wk"]).reshape(B, S, kh, dh), positions, cfg.rope_theta)
        v = (y @ p["wv"]).reshape(B, S, kh, dh)
        q = constraint(q, "batch", None, "heads", None)
        k = constraint(k, "batch", None, "kv_heads", None)
        v = constraint(v, "batch", None, "kv_heads", None)
        if q8:
            kq, ksn, kzn = _q8(k)
            vq, vsn, vzn = _q8(v)
            out_caches = (_fill(kc, kq), _fill(ks, ksn), _fill(kz, kzn),
                          _fill(vc, vq), _fill(vs, vsn), _fill(vz, vzn))
        else:
            out_caches = (_fill(kc, k), _fill(vc, v))
        attn = chunked_causal_attention(q, k, v, q_chunk=cfg.q_chunk,
                                        kv_chunk=cfg.kv_chunk)
        attn = constraint(attn, "batch", None, "heads", None)
        x = x + attn.reshape(B, S, h * dh) @ p["wo"]
        x = constraint(x, "batch", "seq", "embed")
        y = _rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            out, _ = moe_ffn(p["moe"], y.reshape(B * S, -1), cfg.moe)
            x = x + out.reshape(B, S, -1)
        else:
            g = constraint(jax.nn.silu(y @ p["w_gate"]) * (y @ p["w_up"]),
                           "batch", None, "ff")
            x = x + g @ p["w_down"]
        return constraint(x, "batch", "seq", "embed"), out_caches

    if q8:
        xs = (params["blocks"], cache["k"], cache["k_s"], cache["k_z"],
              cache["v"], cache["v_s"], cache["v_z"])
        names = ("k", "k_s", "k_z", "v", "v_s", "v_z")
    else:
        xs = (params["blocks"], cache["k"], cache["v"])
        names = ("k", "v")
    x, outs = jax.lax.scan(scan_fn, x, xs)
    x = _rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x @ params["head"])[:, -1]
    new_cache = dict(zip(names, outs))
    new_cache["len"] = jnp.asarray(S, jnp.int32)
    return logits, new_cache
