"""Mixture-of-Experts FFN: top-k token-choice, grouped sort-based dispatch.

GShard-style *grouped* dispatch: tokens are split into ``n_groups`` groups
(bound to the data-parallel mesh axis by the launcher) and each group
sorts/capacity-drops its own tokens:

  1. top-k gates per token
  2. per-group stable argsort of expert assignments; position-within-
     expert = rank − segment start (a vmapped searchsorted)
  3. tokens beyond the per-group capacity C are dropped (GShard semantics)
  4. scatter into a (G, E, C, d) buffer, batched expert SwiGLU, scatter
     back weighted by gates.

Why groups matter at scale: a single global argsort over B·S·k ≈ 6M
assignments cannot shard — GSPMD replicates the sort and the (E, C, d)
buffer on every device (measured: 316 GB/device for moonshot train_4k).
With G bound to the data axis every sort/scatter is device-local and the
buffer shards as (G/data, E/model, C, d) — the classic dispatch layout.
All shapes stay static; the all-to-all from data-grouped to expert-sharded
layout is inserted by GSPMD exactly where a hand-written dispatch would
put it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.sharding.logical import constraint

__all__ = ["MoEConfig", "moe_params", "moe_ffn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    n_groups: int = 1        # bound to the data-shard count by the launcher


def moe_params(key: jax.Array, d_model: int, cfg: MoEConfig,
               dtype=jnp.float32) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    E, F = cfg.n_experts, cfg.d_ff
    s_in = d_model ** -0.5
    s_ff = F ** -0.5
    return {
        "router": jax.random.normal(k1, (d_model, E), dtype) * s_in,
        "w_gate": jax.random.normal(k2, (E, d_model, F), dtype) * s_in,
        "w_up": jax.random.normal(k3, (E, d_model, F), dtype) * s_in,
        "w_down": jax.random.normal(k4, (E, F, d_model), dtype) * s_ff,
    }


def moe_ffn(params: dict, x: jax.Array, cfg: MoEConfig):
    """x: (T, d) token-major; T must divide by cfg.n_groups.

    Returns (y, aux_loss)."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    G = cfg.n_groups if T % cfg.n_groups == 0 else 1
    Tg = T // G
    C = max(int(Tg * k * cfg.capacity_factor / E), 1)

    xg = constraint(x.reshape(G, Tg, d), "batch", None, None)
    logits = jnp.einsum("gtd,de->gte", xg, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                    # (G, Tg, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch/GShard), global over groups
    density = jnp.mean(jax.nn.one_hot(idx[..., 0], E), axis=(0, 1))
    density_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * density_prob)

    expert_flat = idx.reshape(G, Tg * k)                    # (G, Tg*k)
    gate_flat = gates.reshape(G, Tg * k)
    order = jnp.argsort(expert_flat, axis=-1, stable=True)  # per-group sort
    se = jnp.take_along_axis(expert_flat, order, axis=-1)
    st = order // k                                         # token in group
    sg = jnp.take_along_axis(gate_flat, order, axis=-1)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)
    pos = jnp.arange(Tg * k)[None, :] - jnp.take_along_axis(starts, se,
                                                            axis=-1)
    keep = pos < C
    safe_pos = jnp.where(keep, pos, C)

    def scatter_group(xg_, se_, pos_, st_):
        return jnp.zeros((E, C + 1, d), x.dtype).at[se_, pos_].set(xg_[st_])

    buf = jax.vmap(scatter_group)(xg, se, safe_pos, st)[:, :, :C]
    buf = constraint(buf, "batch", "expert", None, None)    # (G, E, C, d)

    # batched expert SwiGLU
    g = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"].astype(x.dtype))
    h = constraint(jax.nn.silu(g) * u, "batch", "expert", None, "ff")
    out = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(x.dtype))
    out = constraint(out, "batch", "expert", None, None)

    # combine back per group, gate-weighted; dropped tokens contribute 0.
    # Gates are cast to the activation dtype BEFORE the multiply — an f32
    # gate promotes the whole (G·Tg·k, d) combine chain (and its backward
    # cotangents, which cross the EP all-to-all) to f32: measured 2x
    # collective bytes on moonshot train_4k (§Perf hillclimb #2).
    def combine_group(out_, se_, pos_, st_, sg_, keep_):
        gate = (sg_ * keep_).astype(x.dtype)
        contrib = out_[se_, jnp.minimum(pos_, C - 1)] * gate[:, None]
        return jnp.zeros((Tg, d), x.dtype).at[st_].add(contrib)

    y = jax.vmap(combine_group)(out, se, safe_pos, st, sg, keep)
    y = constraint(y, "batch", None, None)
    return y.reshape(T, d), aux.astype(jnp.float32)
