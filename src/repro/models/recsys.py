"""RecSys architectures: FM, Wide&Deep, DLRM, xDeepFM.

Embedding storage is ONE fused table ``(Σ vocab_f, dim)`` with static
per-field offsets — the production layout that row-shards cleanly over the
`model` mesh axis (DLRM hybrid parallelism). Lookups are ``jnp.take`` and
multi-hot bags use ``embedding_bag`` (gather + segment_sum — JAX has no
native EmbeddingBag; built here per the assignment).

TinyKG integration: the interaction ops and MLPs run through the ACT layer
(`act_matmul`/`act_relu`), compressing the activations that dominate train
memory (batch 65,536 × wide MLPs). Embedding lookups themselves need no
activation storage (index residuals only — same class as the paper's Â).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import (
    ACTPolicy,
    PolicySchedule,
    act_matmul,
    model_context,
)

from .layers import embedding_bag, mlp_apply, mlp_params, normal_init

__all__ = ["RecsysConfig", "init_params", "forward", "retrieval_scores",
           "retrieval_towers"]


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    model: str                      # fm | wide_deep | dlrm | xdeepfm
    n_sparse: int
    vocab_sizes: tuple              # per-field vocab sizes
    embed_dim: int
    n_dense: int = 0
    bot_mlp: tuple = ()             # dlrm bottom MLP dims (excl. input)
    top_mlp: tuple = ()             # dlrm top MLP dims (incl. final 1)
    mlp: tuple = ()                 # deep branch dims (wide_deep/xdeepfm)
    cin_layers: tuple = ()          # xdeepfm CIN layer widths
    interaction: str = "dot"
    vocab_pad: int = 512            # fused table rows round up to this —
    #                                 lets the table row-shard over any mesh

    @property
    def total_vocab(self) -> int:
        n = int(sum(self.vocab_sizes))
        return -(-n // self.vocab_pad) * self.vocab_pad

    @property
    def field_offsets(self) -> tuple:
        off, acc = [], 0
        for v in self.vocab_sizes:
            off.append(acc)
            acc += v
        return tuple(off)


def init_params(key: jax.Array, cfg: RecsysConfig) -> dict:
    ks = iter(jax.random.split(key, 16))
    F, k = cfg.n_sparse, cfg.embed_dim
    p = {
        "table": normal_init(next(ks), (cfg.total_vocab, k), 1.0 / k**0.5),
        "linear": normal_init(next(ks), (cfg.total_vocab, 1), 0.01),
        "bias": jnp.zeros(()),
    }
    if cfg.model == "wide_deep":
        p["deep"] = mlp_params(next(ks), (F * k + cfg.n_dense,) + cfg.mlp + (1,))
    elif cfg.model == "dlrm":
        p["bot"] = mlp_params(next(ks), (cfg.n_dense,) + cfg.bot_mlp)
        n_vec = F + 1  # embeddings + bottom-MLP output
        d_int = n_vec * (n_vec - 1) // 2 + cfg.bot_mlp[-1]
        p["top"] = mlp_params(next(ks), (d_int,) + cfg.top_mlp)
    elif cfg.model == "xdeepfm":
        h_prev = F
        p["cin"] = []
        for h in cfg.cin_layers:
            p["cin"].append(normal_init(next(ks), (h_prev * F, h), 0.1))
            h_prev = h
        p["cin_out"] = normal_init(next(ks), (int(sum(cfg.cin_layers)), 1), 0.1)
        p["deep"] = mlp_params(next(ks), (F * k,) + cfg.mlp + (1,))
    elif cfg.model != "fm":
        raise ValueError(cfg.model)
    return p


def _lookup(params, sparse_ids: jax.Array, cfg: RecsysConfig):
    """(B, F) field-local ids -> (B, F, k) embeddings + (B,) linear term."""
    offs = jnp.asarray(cfg.field_offsets, dtype=sparse_ids.dtype)
    flat = sparse_ids + offs[None, :]
    emb = jnp.take(params["table"], flat, axis=0)          # (B, F, k)
    lin = jnp.take(params["linear"], flat, axis=0)[..., 0]  # (B, F)
    return emb, jnp.sum(lin, axis=-1)


def _fm_pairwise(emb: jax.Array) -> jax.Array:
    """Σ_{i<j} <v_i, v_j> via the O(Fk) sum-square trick (Rendle '10)."""
    s = jnp.sum(emb, axis=1)            # (B, k)
    sq = jnp.sum(emb * emb, axis=1)     # (B, k)
    return 0.5 * jnp.sum(s * s - sq, axis=-1)


def _dot_interaction(vectors: jax.Array) -> jax.Array:
    """DLRM: upper-triangle pairwise dots of (B, n, k) -> (B, n(n-1)/2)."""
    gram = jnp.einsum("bnk,bmk->bnm", vectors, vectors)
    n = vectors.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    return gram[:, iu, ju]


def _cin(params, x0: jax.Array, cfg: RecsysConfig):
    """Compressed Interaction Network: x^l_h = Σ_{ij} W^l_{h,ij}(x^{l-1}_i ⊙ x^0_j)."""
    B, F, k = x0.shape
    xs, pooled = x0, []
    for i, w in enumerate(params["cin"]):
        # outer product along fields, contracted against W via one matmul:
        # z (B, H_prev*F, k) -> transpose to (B, k, H_prev*F) @ (H_prev*F, H)
        z = jnp.einsum("bhk,bfk->bhfk", xs, x0).reshape(B, -1, k)
        zt = jnp.swapaxes(z, 1, 2)                       # (B, k, H_prev*F)
        xs = jnp.swapaxes(
            act_matmul(zt, w, scope=f"cin{i}"), 1, 2)    # (B, H, k)
        pooled.append(jnp.sum(xs, axis=-1))              # (B, H)
    return jnp.concatenate(pooled, axis=-1)


def forward(params: dict, batch: dict, cfg: RecsysConfig, *,
            policy: ACTPolicy | PolicySchedule | None = None,
            key: jax.Array | None = None):
    """Returns logits (B,). batch: sparse (B,F) int32 [+ dense (B,n_dense)].

    ``policy``/``key`` omitted resolve from the ambient ``ActContext`` at
    the ``<model>/...`` sites.
    """
    ctx = model_context(policy, key)
    ctx.check_key(f"recsys.forward({cfg.model})")
    with ctx, ctx.scope(cfg.model):
        emb, lin = _lookup(params, batch["sparse"], cfg)
        B = emb.shape[0]

        if cfg.model == "fm":
            return params["bias"] + lin + _fm_pairwise(emb)

        if cfg.model == "wide_deep":
            x = emb.reshape(B, -1)
            if cfg.n_dense:
                x = jnp.concatenate([x, batch["dense"]], axis=-1)
            deep = mlp_apply(params["deep"], x, scope="deep")[:, 0]
            return params["bias"] + lin + deep

        if cfg.model == "dlrm":
            bot = mlp_apply(params["bot"], batch["dense"], scope="bot",
                            final_act=True)              # (B, k)
            vecs = jnp.concatenate([bot[:, None, :], emb], axis=1)
            inter = _dot_interaction(vecs)               # (B, n(n-1)/2)
            top_in = jnp.concatenate([bot, inter], axis=-1)
            return mlp_apply(params["top"], top_in, scope="top")[:, 0]

        if cfg.model == "xdeepfm":
            cin_feats = _cin(params, emb, cfg)
            cin_logit = act_matmul(cin_feats, params["cin_out"],
                                   scope="cin_out")[:, 0]
            deep = mlp_apply(params["deep"], emb.reshape(B, -1),
                             scope="deep")[:, 0]
            return params["bias"] + lin + cin_logit + deep

    raise ValueError(cfg.model)


def retrieval_scores(params: dict, query: dict, cand_ids: jax.Array,
                     cfg: RecsysConfig, *, item_field: int = 0):
    """Score ONE query against N candidates as a single batched dot.

    Two-tower factorization: user vector = Σ field embeddings of the query
    (candidate field excluded); candidate vector = its embedding row. This
    is the standard retrieval head — full interaction models re-rank the
    top-K afterwards (serve_p99 path).
    """
    emb, _ = _lookup(params, query["sparse"][None, :], cfg)   # (1, F, k)
    mask = jnp.arange(cfg.n_sparse) != item_field
    user_vec = jnp.sum(emb[0] * mask[:, None], axis=0)        # (k,)
    off = cfg.field_offsets[item_field]
    cand = jnp.take(params["table"], cand_ids + off, axis=0)  # (N, k)
    cand_lin = jnp.take(params["linear"], cand_ids + off, axis=0)[:, 0]
    return cand @ user_vec + cand_lin


def retrieval_towers(params: dict, query_sparse: jax.Array,
                     cand_ids: jax.Array, cfg: RecsysConfig, *,
                     item_field: int = 0):
    """The two towers behind :func:`retrieval_scores`, as row tables.

    Factorizes ``score(u, v) = cand_emb_v · user_vec_u + cand_lin_v``
    into a single dot product by augmenting both sides with one extra
    dim (user side gets a constant 1, item side its linear term) — the
    layout the quantized serving store wants (DESIGN.md §8): the item
    tower is packed once offline, the user tower is the per-request
    query vector.

    query_sparse : (B, F) field-local ids
    returns (user_aug (B, k+1) fp32, cand_aug (N, k+1) fp32) with
    ``cand_aug @ user_aug[i]`` == ``retrieval_scores`` for query i.
    """
    emb, _ = _lookup(params, query_sparse, cfg)               # (B, F, k)
    mask = jnp.arange(cfg.n_sparse) != item_field
    user_vec = jnp.sum(emb * mask[None, :, None], axis=1)     # (B, k)
    user_aug = jnp.concatenate(
        [user_vec, jnp.ones((user_vec.shape[0], 1), user_vec.dtype)], axis=-1)
    off = cfg.field_offsets[item_field]
    cand = jnp.take(params["table"], cand_ids + off, axis=0)  # (N, k)
    cand_lin = jnp.take(params["linear"], cand_ids + off, axis=0)  # (N, 1)
    cand_aug = jnp.concatenate([cand, cand_lin], axis=-1)
    return user_aug.astype(jnp.float32), cand_aug.astype(jnp.float32)


# Activation-memory accounting is trace-derived: run ``forward`` under a
# recording ActContext (``repro.core.traced_activation_report``). The old
# hand-maintained ``activation_shapes`` table is gone.
