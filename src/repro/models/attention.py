"""Attention: chunked-causal (train/prefill) + cached decode, GQA + RoPE.

Training attention is a pure-JAX flash-style double scan (online softmax
over KV chunks inside a scan over Q chunks) so the S×S score matrix is
never materialized — per-step working set is O(q_chunk × kv_chunk). This
is the memory-safe formulation the dry-run compiles at seq 4k–32k.

GQA uses the grouped einsum formulation (no materialized KV repeat).
Decode attends one query against a (possibly sequence-sharded) KV cache;
GSPMD turns the softmax reductions over a sharded seq axis into the
partial-max/partial-sum collectives of flash-decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.logical import constraint

__all__ = ["rope", "chunked_causal_attention", "decode_attention"]

_NEG = -1e30


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D), positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over heads: (..., S, 1, half)
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             q_chunk: int = 512, kv_chunk: int = 512):
    """Causal attention. q: (B,S,H,D); k,v: (B,S,Kh,D); returns (B,S,H,D)."""
    B, S, H, D = q.shape
    Kh = k.shape[2]
    G = H // Kh
    scale = D ** -0.5
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    # pad S up to a chunk multiple; padded keys sit at positions > every real
    # query so the causal mask hides them, padded query rows are sliced off
    import math as _math
    lcm = q_chunk * kv_chunk // _math.gcd(q_chunk, kv_chunk)
    S_pad = -(-S // lcm) * lcm
    if S_pad != S:
        pad = [(0, 0), (0, S_pad - S), (0, 0), (0, 0)]
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    S_orig, S = S, S_pad
    nq, nk = S // q_chunk, S // kv_chunk

    # Two structural choices measured in EXPERIMENTS.md §Perf hillclimb #1:
    #
    # 1. Flat-head formulation: KV is repeated to H inside each chunk
    #    (local — KV heads are replicated under TP) so every big tensor
    #    carries the H dim, which shards over `model`. The grouped (Kh, G)
    #    form leaves GSPMD nothing divisible (e.g. 8×12 on a 16-way axis)
    #    and replicates the score tensors — 13.7x memory-term blowup on
    #    mistral prefill_32k.
    #
    # 2. Triangular block schedule: the outer q loop is UNROLLED (python)
    #    so each q-chunk's inner scan has a STATIC triangular length —
    #    fully-masked blocks are never traced at all (the naive nq×nk
    #    double scan wastes ~2x FLOPs and bytes on causal masking), and
    #    the online-softmax carry stays chunk-local (a full-width carry
    #    variant measured +26% memory-term — see §Perf hillclimb #1).
    p_dt = jnp.bfloat16 if q.dtype == jnp.bfloat16 else jnp.float32

    def kv_block(carry, kj, qb, qpos):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, kj * kv_chunk, kv_chunk, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, kj * kv_chunk, kv_chunk, 1)
        if G > 1:
            kb = jnp.repeat(kb, G, axis=2)                 # (B,kc,H,D)
            vb = jnp.repeat(vb, G, axis=2)
        kb = constraint(kb, "batch", None, "heads", None)
        vb = constraint(vb, "batch", None, "heads", None)
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        s = constraint(s, "batch", "heads", None, None)
        kpos = kj * kv_chunk + jnp.arange(kv_chunk)
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0).astype(p_dt)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(p_dt),
            preferred_element_type=jnp.float32)
        acc_new = constraint(acc_new, "batch", "heads", None, None)
        return (m_new, l_new, acc_new), None

    outs = []
    for qi in range(nq):
        qb = jax.lax.slice_in_dim(q, qi * q_chunk, (qi + 1) * q_chunk,
                                  axis=1)
        qb = constraint(qb, "batch", None, "heads", None)
        qpos = qi * q_chunk + jnp.arange(q_chunk)
        nk_i = min(((qi + 1) * q_chunk - 1) // kv_chunk + 1, nk)
        init = (
            jnp.full((B, H, q_chunk), _NEG, jnp.float32),
            jnp.zeros((B, H, q_chunk), jnp.float32),
            jnp.zeros((B, H, q_chunk, D), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            lambda c, kj: kv_block(c, kj, qb, qpos), init,
            jnp.arange(nk_i))
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,H,qc,D)
        outs.append(jnp.transpose(out_i, (0, 2, 1, 3)))    # (B,qc,H,D)
    out = jnp.concatenate(outs, axis=1).astype(q.dtype)
    return out[:, :S_orig]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array):
    """One-token decode. q: (B,1,H,D); caches: (B,Smax,Kh,D).

    Positions >= cache_len are masked. Over a sequence-sharded cache this
    lowers to flash-decode-style partial softmax collectives under GSPMD.
    """
    B, _, H, D = q.shape
    Smax, Kh = k_cache.shape[1], k_cache.shape[2]
    G = H // Kh
    qg = q.reshape(B, Kh, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    mask = jnp.arange(Smax)[None, None, None, :] < cache_len
    s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)
