"""Leveled stderr logging for progress lines.

Benchmarks and launchers print machine-parseable result lines on
stdout; everything narrative ("[trainer] step 50: ...") goes through
``log(msg, level)`` to **stderr**, filtered by ``REPRO_LOG_LEVEL``
(debug | info | warning | error, default info). ``set_log_level``
overrides the environment for the process (tests, notebooks).

Deliberately not the stdlib ``logging`` module: no handler graph, no
global config mutation on import, one function — the call sites here
were bare ``print``s and need exactly one step up from that.
"""

from __future__ import annotations

import os
import sys

__all__ = ["log", "set_log_level", "log_level"]

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}

_OVERRIDE: str | None = None


def set_log_level(level: str | None) -> None:
    """Process-wide override; ``None`` returns control to the env var."""
    global _OVERRIDE
    if level is not None and level not in LEVELS:
        raise ValueError(f"unknown log level {level!r} "
                         f"(have {sorted(LEVELS)})")
    _OVERRIDE = level


def log_level() -> str:
    """Effective level: ``set_log_level`` beats ``REPRO_LOG_LEVEL``."""
    if _OVERRIDE is not None:
        return _OVERRIDE
    env = os.environ.get("REPRO_LOG_LEVEL", "info").lower()
    return env if env in LEVELS else "info"


def log(msg: str, level: str = "info") -> None:
    """Print ``msg`` to stderr iff ``level`` clears the threshold."""
    if LEVELS.get(level, 20) >= LEVELS[log_level()]:
        print(msg, file=sys.stderr, flush=True)
