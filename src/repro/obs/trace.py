"""Host-side span tracer with Chrome-trace / Perfetto JSON export.

The tracer answers "where did the step's wall-clock go" at the *host*
level — data fetch, device dispatch, host gather/scatter in the tiered
store, serving batch drains — the seams the device profiler cannot see.
Spans are plain ``(name, ts, dur, tid)`` complete events ("ph": "X"),
so the export loads directly in Perfetto / chrome://tracing and nests
by timestamp containment per thread.

Design constraints (DESIGN.md §13):

  * **near-zero overhead when disabled** — ``span()`` on a disabled
    tracer is one attribute check plus returning a shared no-op context
    manager (no allocation, no clock read). The <2% tracing-off budget
    is asserted in tests/test_obs.py.
  * **thread-aware** — events carry ``tid`` (``threading.get_ident``),
    so the prefetch producer, the serving worker and the main loop land
    on separate tracks.
  * **device bracket** — ``step_span`` additionally enters
    ``jax.profiler.StepTraceAnnotation`` when available, so a
    simultaneously captured device profile aligns its steps with the
    host spans (a no-op when no device profiler is collecting).

Span names reuse the ACT scope grammar (``/``-joined path components,
e.g. ``train/step/gather`` — see DESIGN.md §6, §13).
"""

from __future__ import annotations

import contextlib
import functools
import json
import os
import threading
import time

__all__ = ["Tracer", "get_tracer", "enable", "disable", "span", "traced",
           "step_span", "save"]


class _NullSpan:
    """Shared do-nothing context manager — the disabled fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL = _NullSpan()


class _Span:
    """One live span: clock read on enter, event append on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        tr = self._tracer
        ev = {"name": self._name, "ph": "X", "cat": "host",
              "ts": (self._t0 - tr._epoch) * 1e6,
              "dur": (t1 - self._t0) * 1e6,
              "pid": tr._pid, "tid": threading.get_ident()}
        if self._args:
            ev["args"] = self._args
        with tr._lock:
            tr._events.append(ev)
        return False


class Tracer:
    """Collects host spans; exports the Chrome-trace event list.

    One tracer instance is process-global (``get_tracer()``); tests may
    build private instances. ``enabled`` is the only state the hot path
    reads.
    """

    def __init__(self):
        self.enabled = False
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._pid = os.getpid()
        self._epoch = time.perf_counter()

    # -- lifecycle ----------------------------------------------------------

    def enable(self) -> "Tracer":
        """Start (or restart) collection; clears prior events."""
        with self._lock:
            self._events = []
            self._epoch = time.perf_counter()
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    # -- spans --------------------------------------------------------------

    def span(self, name: str, **args):
        """Context manager timing the enclosed block as one complete
        event. Returns a shared no-op when disabled."""
        if not self.enabled:
            return _NULL
        return _Span(self, name, args or None)

    def step_span(self, name: str, step: int):
        """A per-step span that also brackets the device profiler's
        ``StepTraceAnnotation`` (aligns host and device timelines when a
        jax profile is being captured simultaneously)."""
        if not self.enabled:
            return _NULL
        try:
            from jax.profiler import StepTraceAnnotation
        except Exception:  # pragma: no cover - jax always has it today
            return _Span(self, name, {"step": step})
        stack = contextlib.ExitStack()
        stack.enter_context(_Span(self, name, {"step": step}))
        stack.enter_context(StepTraceAnnotation(name, step_num=step))
        return stack

    # -- export -------------------------------------------------------------

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def to_chrome_trace(self, *, run: dict | None = None) -> dict:
        """The Perfetto/chrome://tracing JSON object."""
        return {"traceEvents": self.events(),
                "displayTimeUnit": "ms",
                "metadata": {"tracer": "repro.obs.trace",
                             **(run or {})}}

    def save(self, path: str, *, run: dict | None = None) -> str:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(run=run), f)
        return path


# -- module-level convenience over the process tracer -----------------------

_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def enable() -> Tracer:
    return _TRACER.enable()


def disable() -> Tracer:
    return _TRACER.disable()


def span(name: str, **args):
    return _TRACER.span(name, **args)


def step_span(name: str, step: int):
    return _TRACER.step_span(name, step)


def save(path: str, *, run: dict | None = None) -> str:
    return _TRACER.save(path, run=run)


def traced(fn_or_name=None):
    """Decorator form: ``@traced`` or ``@traced("serve/score")``.

    Disabled tracing costs one bool check per call — safe on warm paths.
    """
    def deco(fn, label=None):
        label = label or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not _TRACER.enabled:
                return fn(*a, **kw)
            with _TRACER.span(label):
                return fn(*a, **kw)
        return wrapper

    if callable(fn_or_name):
        return deco(fn_or_name)
    return lambda fn: deco(fn, fn_or_name)
