"""Telemetry sinks: JSONL step log + schema-validated run summary.

Two artifacts per run (``--metrics-out DIR`` in the launchers):

  * ``steps.jsonl`` — one JSON object per step (``StepLogWriter``):
    append-only, crash-tolerant (every line flushed), the raw timeline
    that p99 analyses and the activation-bytes timeline read.
  * ``summary.json`` — the end-of-run registry snapshot plus run
    identity, validated against ``SUMMARY_SCHEMA`` **before** it is
    written: a malformed summary fails the producing run, not the
    nightly job that consumes it three hours later.

Consumers: ``launch/report.py --metrics`` renders a summary as a
markdown table; ``benchmarks/check_regression.py --validate-schema``
re-validates emitted files in CI; the nightly SLO gates (ROADMAP item
4) will read ``histograms["serve/latency_ms{...}"]["p99"]``.
"""

from __future__ import annotations

import json
import os
from typing import IO

__all__ = ["SCHEMA_VERSION", "SUMMARY_SCHEMA", "SummarySchemaError",
           "validate_summary", "build_summary", "write_summary",
           "StepLogWriter"]

SCHEMA_VERSION = 1

_HIST_KEYS = ("count", "sum", "min", "max", "p50", "p95", "p99")

# Declarative top-level shape (documentation + the validator's source of
# truth): section -> required type. ``run`` must carry a string ``kind``
# ("train" | "serve" | "bench" | ...); metric sections map series keys
# (``name`` or ``name{label=v,...}``) to numbers / histogram dicts.
SUMMARY_SCHEMA = {
    "schema_version": int,
    "run": dict,
    "counters": dict,
    "gauges": dict,
    "histograms": dict,
}


class SummarySchemaError(ValueError):
    """A summary violated SUMMARY_SCHEMA; message lists every problem."""


def validate_summary(obj) -> None:
    """Raise ``SummarySchemaError`` naming ALL violations, or return.

    Pure-python structural validation (no jsonschema dependency in the
    container): required keys, section types, numeric metric values,
    histogram field completeness.
    """
    problems: list[str] = []
    if not isinstance(obj, dict):
        raise SummarySchemaError(
            f"summary must be a JSON object, got {type(obj).__name__}")
    for key, typ in SUMMARY_SCHEMA.items():
        if key not in obj:
            problems.append(f"missing required key {key!r}")
        elif not isinstance(obj[key], typ):
            problems.append(f"{key!r} must be {typ.__name__}, got "
                            f"{type(obj[key]).__name__}")
    if isinstance(obj.get("schema_version"), int) and \
            obj["schema_version"] != SCHEMA_VERSION:
        problems.append(f"schema_version {obj['schema_version']} != "
                        f"supported {SCHEMA_VERSION}")
    run = obj.get("run")
    if isinstance(run, dict) and not isinstance(run.get("kind"), str):
        problems.append("run.kind must be a string "
                        "(e.g. 'train', 'serve', 'bench')")
    for section in ("counters", "gauges"):
        vals = obj.get(section)
        if isinstance(vals, dict):
            for k, v in vals.items():
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    problems.append(f"{section}[{k!r}] must be a number, "
                                    f"got {type(v).__name__}")
    hists = obj.get("histograms")
    if isinstance(hists, dict):
        for k, h in hists.items():
            if not isinstance(h, dict):
                problems.append(f"histograms[{k!r}] must be an object")
                continue
            missing = [f for f in _HIST_KEYS if f not in h]
            if missing:
                problems.append(f"histograms[{k!r}] missing {missing}")
    if problems:
        raise SummarySchemaError(
            "summary schema violations: " + "; ".join(problems))


def build_summary(run: dict, registry=None, *, extra: dict | None = None):
    """Assemble (and validate) the summary object for ``run``.

    ``run`` is free-form identity (arch, schedule, mesh, argv, ...) but
    must carry ``kind``. ``registry`` defaults to the process registry.
    ``extra`` top-level keys are merged last (e.g. a bench's own rows).
    """
    from .metrics import get_registry

    reg = registry if registry is not None else get_registry()
    summary = {"schema_version": SCHEMA_VERSION, "run": dict(run),
               **reg.snapshot()}
    if extra:
        summary.update(extra)
    validate_summary(summary)
    return summary


def write_summary(out_dir: str, run: dict, registry=None, *,
                  extra: dict | None = None,
                  filename: str = "summary.json") -> str:
    """Validate then write ``<out_dir>/<filename>``; returns the path."""
    summary = build_summary(run, registry, extra=extra)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, filename)
    with open(path, "w") as f:
        json.dump(summary, f, indent=1)
    return path


class StepLogWriter:
    """Append-only JSONL step log; every record flushed on write.

    ``extras`` is a dict merged into every record — the launcher parks
    per-run constants there (e.g. the traced activation-bytes total) so
    each step line is self-describing and the file reads as a timeline
    without a join against the summary.
    """

    def __init__(self, path: str):
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self.path = path
        self.extras: dict = {}
        self._f: IO | None = open(path, "w")
        self.n_records = 0

    def write(self, record: dict) -> None:
        if self._f is None:
            raise ValueError(f"StepLogWriter({self.path}) is closed")
        self._f.write(json.dumps({**self.extras, **record}) + "\n")
        self._f.flush()
        self.n_records += 1

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "StepLogWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
