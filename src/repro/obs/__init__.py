"""Unified telemetry: span tracer, metrics registry, sinks, leveled log.

One layer (DESIGN.md §13) replaces the scattered channels the repo grew
— trainer prints, the tiered store's bare stats dict, the serving
engine's private latency list, hand-invoked Table-5 reports:

  trace:   ``span("train/step/gather")`` / ``@traced`` host spans →
           Chrome-trace/Perfetto JSON (``--trace OUT.json``); brackets
           ``jax.profiler.StepTraceAnnotation`` per step.
  metrics: process-wide ``MetricsRegistry`` — counters, gauges, bounded
           p50/p95/p99 reservoirs, labeled series, snapshot/diff.
  sinks:   JSONL step log + schema-validated end-of-run ``summary.json``
           (``--metrics-out DIR``), consumed by launch/report.py and
           benchmarks/check_regression.py --validate-schema.
  log:     leveled stderr progress lines (``REPRO_LOG_LEVEL``), keeping
           stdout machine-parseable.

This package imports neither jax nor numpy at module scope — it must be
importable (and near-free) everywhere, including kernels and launchers
that manage backend initialization order carefully.
"""

from .log import log, log_level, set_log_level
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    diff,
    get_registry,
    reset_registry,
    series_key,
)
from .sinks import (
    SCHEMA_VERSION,
    SUMMARY_SCHEMA,
    StepLogWriter,
    SummarySchemaError,
    build_summary,
    validate_summary,
    write_summary,
)
from .trace import Tracer, get_tracer, span, step_span, traced

__all__ = [
    "log", "log_level", "set_log_level",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "diff",
    "get_registry", "reset_registry", "series_key",
    "SCHEMA_VERSION", "SUMMARY_SCHEMA", "StepLogWriter",
    "SummarySchemaError", "build_summary", "validate_summary",
    "write_summary",
    "Tracer", "get_tracer", "span", "step_span", "traced",
]
