"""Process-wide metrics registry: counters, gauges, bounded reservoirs.

Every subsystem that used to keep its own ad-hoc numbers (the trainer's
loss prints, ``TieredEmbeddingStore.stats``, the serving engine's
latency list) now registers **labeled series** here, so one snapshot at
end-of-run captures the whole resource story the paper's tables argue
about — and ``repro.obs.sinks`` can write it in one schema.

Series identity is ``name`` plus a sorted label set (``arch``, ``mesh``,
``bits``, ...), rendered ``name{k=v,...}`` in snapshots (prometheus
style). Three instrument kinds:

  * ``Counter`` — monotone float; ``inc(n)``.
  * ``Gauge`` — last-write-wins float; ``set(v)``.
  * ``Histogram`` — a **bounded reservoir** (Vitter's algorithm R with a
    deterministic per-series PRNG): O(capacity) memory regardless of
    stream length, exact percentiles while ``count <= capacity``,
    uniform-sample estimates after. ``count``/``sum``/``min``/``max``
    stay exact forever. This is what fixes the serving engine's
    linearly-growing latency list.

``snapshot()`` returns plain JSON-able dicts; ``diff(before, after)``
subtracts counters and histogram counts — the primitive nightly gates
and soak monitors window on.
"""

from __future__ import annotations

import random
import threading
import zlib

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "get_registry", "reset_registry", "diff", "series_key"]


def series_key(name: str, labels: dict | None = None) -> str:
    """Canonical series id: ``name`` or ``name{k=v,...}`` (sorted)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded-reservoir distribution tracker (see module docstring).

    The reservoir PRNG is seeded from the series key, so a replayed run
    produces a bit-identical snapshot — determinism is part of the
    telemetry contract, same as everywhere else in this repo.
    """

    __slots__ = ("capacity", "count", "total", "vmin", "vmax", "_buf",
                 "_rng")

    def __init__(self, capacity: int = 1024, *, seed: int | str = 0):
        if capacity < 1:
            raise ValueError(f"reservoir capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = int(capacity)
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._buf: list[float] = []
        if isinstance(seed, str):
            seed = zlib.crc32(seed.encode())
        self._rng = random.Random(seed)

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        if x < self.vmin:
            self.vmin = x
        if x > self.vmax:
            self.vmax = x
        if len(self._buf) < self.capacity:
            self._buf.append(x)
        else:
            # algorithm R: keep each of the n seen values with prob cap/n
            j = self._rng.randrange(self.count)
            if j < self.capacity:
                self._buf[j] = x

    def percentile(self, q: float) -> float:
        """q in [0, 100]; nearest-rank over the reservoir sample."""
        if not self._buf:
            return 0.0
        s = sorted(self._buf)
        idx = min(int(len(s) * q / 100.0), len(s) - 1)
        return s[idx]

    def snapshot(self) -> dict:
        return {"count": self.count,
                "sum": self.total,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "p50": self.percentile(50),
                "p95": self.percentile(95),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Get-or-create registry of labeled series (thread-safe creation).

    Instrument mutation itself is unlocked: counters/gauges are single
    float writes (atomic enough under the GIL for telemetry), and the
    hot paths that feed them are single-writer by construction.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def _get(self, table: dict, key: str, make):
        obj = table.get(key)
        if obj is None:
            with self._lock:
                obj = table.setdefault(key, make())
        return obj

    def counter(self, name: str, **labels) -> Counter:
        return self._get(self._counters, series_key(name, labels), Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(self._gauges, series_key(name, labels), Gauge)

    def histogram(self, name: str, capacity: int = 1024,
                  **labels) -> Histogram:
        key = series_key(name, labels)
        return self._get(self._histograms, key,
                         lambda: Histogram(capacity, seed=key))

    # -- snapshots ----------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain JSON-able view: the summary schema's metric sections."""
        with self._lock:
            return {
                "counters": {k: c.value
                             for k, c in sorted(self._counters.items())},
                "gauges": {k: g.value
                           for k, g in sorted(self._gauges.items())},
                "histograms": {k: h.snapshot()
                               for k, h in sorted(self._histograms.items())},
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


def diff(before: dict, after: dict) -> dict:
    """Windowed view between two ``snapshot()`` dicts.

    Counters and histogram counts subtract (series absent from
    ``before`` diff against zero); gauges report ``after``'s value —
    they are instantaneous, not cumulative.
    """
    out = {"counters": {}, "gauges": dict(after.get("gauges", {})),
           "histograms": {}}
    bc = before.get("counters", {})
    for k, v in after.get("counters", {}).items():
        out["counters"][k] = v - bc.get(k, 0.0)
    bh = before.get("histograms", {})
    for k, h in after.get("histograms", {}).items():
        prev = bh.get(k, {})
        out["histograms"][k] = dict(h)
        out["histograms"][k]["count"] = h["count"] - prev.get("count", 0)
        out["histograms"][k]["sum"] = h["sum"] - prev.get("sum", 0.0)
    return out


_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide registry every instrumented seam writes to."""
    return _DEFAULT


def reset_registry() -> None:
    """Drop all series on the process registry (test isolation)."""
    _DEFAULT.reset()
