"""Top-K ranking metrics (paper §4.1.3: Recall@20, NDCG@20) + AUC."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["recall_ndcg_at_k", "auc"]


def recall_ndcg_at_k(scores: jax.Array, test_pos: jax.Array,
                     train_mask: jax.Array, k: int = 20):
    """Per the paper's protocol: rank all items except training positives.

    scores     : (U, I) predicted scores
    test_pos   : (U, I) bool — held-out positives
    train_mask : (U, I) bool — training positives (excluded from ranking)
    returns (recall@k, ndcg@k) averaged over users with ≥1 test positive.
    """
    scores = jnp.where(train_mask, -jnp.inf, scores)
    _, topk = jax.lax.top_k(scores, k)                    # (U, k)
    hits = jnp.take_along_axis(test_pos, topk, axis=1)    # (U, k) bool
    n_test = jnp.sum(test_pos, axis=1)                    # (U,)
    valid = n_test > 0

    recall_u = jnp.sum(hits, axis=1) / jnp.maximum(n_test, 1)

    discounts = 1.0 / jnp.log2(jnp.arange(k) + 2.0)       # (k,)
    dcg = jnp.sum(hits * discounts, axis=1)
    ideal_hits = jnp.arange(k)[None, :] < n_test[:, None]
    idcg = jnp.sum(ideal_hits * discounts, axis=1)
    ndcg_u = dcg / jnp.maximum(idcg, 1e-9)

    denom = jnp.maximum(jnp.sum(valid), 1)
    return (jnp.sum(jnp.where(valid, recall_u, 0)) / denom,
            jnp.sum(jnp.where(valid, ndcg_u, 0)) / denom)


def auc(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Rank-based AUC for binary CTR labels (recsys eval).

    Ties get AVERAGE ranks (the Mann-Whitney convention): a tied
    pos/neg pair then contributes exactly 1/2, so the estimate is
    deterministic and unbiased no matter how ``argsort`` happens to
    order equal logits. (The old raw-argsort ranks made AUC depend on
    the in-memory order of tied examples — e.g. all-equal logits could
    score anywhere in [0, 1] instead of 0.5.)
    """
    n = logits.shape[0]
    order = jnp.argsort(logits)
    sorted_x = logits[order]
    # tie groups over the sorted array: average the 0-based positions
    # within each run of equal values
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_x[1:] != sorted_x[:-1]])
    group = jnp.cumsum(is_start) - 1                       # (n,) group ids
    pos_in_sort = jnp.arange(n, dtype=logits.dtype)
    g_sum = jax.ops.segment_sum(pos_in_sort, group, num_segments=n)
    g_cnt = jax.ops.segment_sum(jnp.ones_like(pos_in_sort), group,
                                num_segments=n)
    avg_rank_sorted = g_sum[group] / jnp.maximum(g_cnt[group], 1)
    ranks = jnp.zeros_like(avg_rank_sorted).at[order].set(avg_rank_sorted)
    n_pos = jnp.sum(labels)
    n_neg = n - n_pos
    pos_rank_sum = jnp.sum(jnp.where(labels > 0, ranks, 0))
    return (pos_rank_sum - n_pos * (n_pos - 1) / 2) / jnp.maximum(
        n_pos * n_neg, 1)
