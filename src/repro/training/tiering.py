"""Two-tier embedding store + the sampled-minibatch training loop.

The companion of ``repro.data.minibatch`` (DESIGN.md §11): once batches
are neighbor-sampled, the full entity table no longer needs to live on
device. Following the data-tiering observation (Min et al. 2022) that
recommender-graph row access is heavily skewed, the table splits into

  * a **hot tier** — the top ``hot_frac`` rows by access frequency
    (seeded with in-degree at load, LFU-refreshed from live counters),
    resident on device; and
  * a **cold tier** — the authoritative host copy, gathered on demand.

``gather`` assembles a batch's row table on device by scattering the
(few) cold rows fetched from host and the (many) hot rows copied
device-to-device; index buffers are padded to power-of-two buckets with
out-of-range slots (``mode="drop"``) so the number of distinct eager
shapes — and hence compiles — stays logarithmic in batch size.
``apply_grads`` is the sparse scatter-back: only touched rows update
(duplicate row ids accumulate, matching dense-gradient semantics), SGD
on rows while the dense params run under the step's regular optimizer.

``run_sampled_training`` overlaps the NEXT batch's gather with the
current device step, then repairs the overlap: after scatter-back, rows
that were both prefetched and just updated are re-gathered (a small
"patch" transfer), so the loop is bit-exact with the sequential
schedule — determinism is a property we test, not a hope.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import act_context
from repro.obs import get_registry, span
from repro.training.step import ModelStep, enter_or_null
from repro.training.optimizer import Optimizer, adam

__all__ = ["TieredEmbeddingStore", "make_sampled_train_step",
           "run_sampled_training", "SampledTrainReport", "live_device_bytes",
           "node_in_degree"]


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def live_device_bytes() -> int:
    """Bytes held by live jax arrays (our peak-memory probe; the CPU
    backend has no allocator high-water-mark API)."""
    try:
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in jax.live_arrays())
    except Exception:
        return 0


def node_in_degree(src, dst, rel, n_nodes: int) -> np.ndarray:
    """Initial hot-ranking signal: in-degree ≈ expected sample frequency
    (uniform fanout sampling hits a node proportionally to how many
    frontier nodes list it as a neighbor)."""
    del src, rel
    return np.bincount(np.asarray(dst, np.int64),
                       minlength=n_nodes).astype(np.float64)


class TieredEmbeddingStore:
    """Device-hot / host-cold entity table with LFU refresh.

    The host array is authoritative for cold rows; the device cache is
    authoritative for hot rows (flushed back on ``refresh``/``flush``).
    All statistics count ROWS actually moved across the host-device
    boundary, including bucket padding and scatter-back — the honest
    transfer cost the minibatch bench gates on. They live as labeled
    counters on the metrics registry (``tiering/*``, DESIGN.md §13);
    the ``stats`` dict and ``hit_rate`` remain the public read API.
    """

    # the registry label that keeps concurrent stores' series apart
    _SEQ = itertools.count()

    # the legacy ``stats`` dict keys, now registry counters
    _STAT_KEYS = ("gathers", "rows_requested", "hot_hits",
                  "rows_transferred", "refreshes", "patch_rows",
                  "cold_rows")

    def __init__(self, table: np.ndarray, freq: np.ndarray | None = None, *,
                 hot_frac: float = 0.1, refresh_every: int = 0,
                 lfu_decay: float = 0.5, registry=None):
        self._host = np.array(table, np.float32, copy=True)
        n, d = self._host.shape
        if not 0.0 <= hot_frac <= 1.0:
            raise ValueError(f"hot_frac must be in [0, 1], got {hot_frac}")
        self.n_rows, self.dim = n, d
        self.n_hot = int(round(hot_frac * n))
        self.refresh_every = int(refresh_every)
        self.lfu_decay = float(lfu_decay)
        self._counts = (np.zeros(n, np.float64) if freq is None
                        else np.asarray(freq, np.float64).copy())
        self._hot_ids = np.empty(0, np.int64)
        self._hot_slot = np.full(n, -1, np.int64)
        self._hot = jnp.zeros((0, d), jnp.float32)
        self._rebuild_hot()
        # Registry-backed counters (DESIGN.md §13): ``tiering/<name>``
        # labeled per store instance. ``rows_transferred`` counts rows
        # including pow2 bucket padding (the honest boundary cost the
        # bench gates on); ``cold_rows`` is the exact unpadded cold-miss
        # count per boundary event (gather/apply_grads dedup first;
        # patch re-fetches once per overlapping position) — the
        # invariant tests/test_obs.py pins is
        # rows_transferred == Σ next_pow2(per-event cold_rows).
        self._registry = registry if registry is not None else get_registry()
        label = f"tier{next(self._SEQ)}"
        self._m = {k: self._registry.counter(f"tiering/{k}", store=label)
                   for k in self._STAT_KEYS}

    @property
    def stats(self) -> dict:
        """The legacy stats view (ints), derived from the registry
        counters — same keys the pre-telemetry dict carried, plus
        ``cold_rows``."""
        return {k: int(c.value) for k, c in self._m.items()}

    # -- tier management ---------------------------------------------------

    def _rebuild_hot(self) -> None:
        if self.n_hot:
            # stable ranking: frequency desc, id asc — deterministic
            order = np.lexsort((np.arange(self.n_rows), -self._counts))
            self._hot_ids = np.sort(order[: self.n_hot])
        else:
            self._hot_ids = np.empty(0, np.int64)
        self._hot_slot.fill(-1)
        self._hot_slot[self._hot_ids] = np.arange(len(self._hot_ids))
        self._hot = jnp.asarray(self._host[self._hot_ids])

    def flush(self) -> np.ndarray:
        """Write hot rows back to host; returns the full (authoritative)
        table — what eval and checkpointing read."""
        if len(self._hot_ids):
            self._host[self._hot_ids] = np.asarray(self._hot)
        return self._host

    def refresh(self) -> None:
        """LFU re-rank: flush, decay counters, rebuild the hot set."""
        self.flush()
        self._counts *= self.lfu_decay
        self._rebuild_hot()
        self._m["refreshes"].inc()

    # -- gather / scatter --------------------------------------------------

    def _scatter_rows(self, out: jax.Array, rows: np.ndarray,
                      targets: np.ndarray, *, count: bool) -> jax.Array:
        """Assemble ``out[targets] = table[rows]`` through the tiers."""
        slots = self._hot_slot[rows]
        cold = np.nonzero(slots < 0)[0]
        hot = np.nonzero(slots >= 0)[0]
        n_out = out.shape[0]
        if len(cold):
            bc = _next_pow2(len(cold))
            tgt = np.full(bc, n_out, np.int64)
            tgt[: len(cold)] = targets[cold]
            vals = np.zeros((bc, self.dim), np.float32)
            vals[: len(cold)] = self._host[rows[cold]]
            out = out.at[jnp.asarray(tgt)].set(jnp.asarray(vals),
                                               mode="drop")
            if count:
                self._m["rows_transferred"].inc(bc)
                self._m["cold_rows"].inc(len(cold))
        if len(hot):
            bh = _next_pow2(len(hot))
            tgt = np.full(bh, n_out, np.int64)
            tgt[: len(hot)] = targets[hot]
            sl = np.zeros(bh, np.int64)
            sl[: len(hot)] = slots[hot]
            out = out.at[jnp.asarray(tgt)].set(self._hot[jnp.asarray(sl)],
                                               mode="drop")
        return out

    def gather(self, rows: np.ndarray,
               requests: np.ndarray | None = None) -> jax.Array:
        """Device row table for global ids ``rows`` (duplicates fine).

        Deduplicated: each distinct row crosses the host-device boundary
        at most once per gather, then expands to positions on device
        (``take``). ``requests`` is the access stream the LFU counters
        and hit-rate stats are measured over — the sampler's
        seeds + real-edge draws (defaults to ``rows``, which on heavily
        padded small-graph frontiers under-reports skew).
        """
        rows = np.asarray(rows, np.int64)
        req = rows if requests is None else np.asarray(requests, np.int64)
        np.add.at(self._counts, req, 1.0)
        self._m["gathers"].inc()
        self._m["rows_requested"].inc(len(req))
        self._m["hot_hits"].inc(int((self._hot_slot[req] >= 0).sum()))
        uniq, inv = np.unique(rows, return_inverse=True)
        bu = _next_pow2(len(uniq))
        ut = jnp.zeros((bu, self.dim), jnp.float32)
        ut = self._scatter_rows(ut, uniq, np.arange(len(uniq)), count=True)
        out = jnp.take(ut, jnp.asarray(inv), axis=0)
        if self.refresh_every and \
                int(self._m["gathers"].value) % self.refresh_every == 0:
            self.refresh()
        return out

    def patch(self, out: jax.Array, rows: np.ndarray,
              updated: np.ndarray) -> jax.Array:
        """Repair a prefetched gather: re-fetch the rows of ``rows``
        whose global ids are in ``updated`` (just scattered-back), so
        ``out`` matches a sequential gather-after-update."""
        rows = np.asarray(rows, np.int64)
        idx = np.nonzero(np.isin(rows, updated))[0]
        if not len(idx):
            return out
        self._m["patch_rows"].inc(len(idx))
        return self._scatter_rows(out, rows[idx], idx, count=True)

    def apply_grads(self, rows: np.ndarray, grads: jax.Array,
                    lr: float) -> np.ndarray:
        """Sparse SGD scatter-back for the touched rows. Duplicate ids
        accumulate their gradients (device ``segment_sum`` over the
        unique-row map), matching what a dense gradient over the full
        table would produce; each updated row crosses the boundary once.
        Returns the unique global ids updated (the patch set)."""
        rows = np.asarray(rows, np.int64)
        uniq, inv = np.unique(rows, return_inverse=True)
        bu = _next_pow2(len(uniq))
        # per-unique-row gradient sum on device (duplicate accumulation)
        delta = jax.ops.segment_sum((-lr * grads).astype(jnp.float32),
                                    jnp.asarray(inv), num_segments=bu)
        slots = self._hot_slot[uniq]
        cold = np.nonzero(slots < 0)[0]
        hot = np.nonzero(slots >= 0)[0]
        if len(hot):
            bh = _next_pow2(len(hot))
            sl = np.full(bh, len(self._hot_ids), np.int64)
            sl[: len(hot)] = slots[hot]
            src = np.zeros(bh, np.int64)
            src[: len(hot)] = hot
            self._hot = self._hot.at[jnp.asarray(sl)].add(
                delta[jnp.asarray(src)], mode="drop")
        if len(cold):
            bc = _next_pow2(len(cold))
            src = np.zeros(bc, np.int64)
            src[: len(cold)] = cold
            d_host = np.asarray(delta[jnp.asarray(src)])[: len(cold)]
            self._host[uniq[cold]] += d_host
            self._m["rows_transferred"].inc(bc)
            self._m["cold_rows"].inc(len(cold))
        return uniq

    # -- accounting --------------------------------------------------------

    @property
    def hit_rate(self) -> float:
        req = self._m["rows_requested"].value
        return self._m["hot_hits"].value / req if req else 0.0

    @property
    def rows_transferred_per_step(self) -> float:
        g = self._m["gathers"].value
        return self._m["rows_transferred"].value / g if g else 0.0

    @property
    def device_bytes(self) -> int:
        return int(self._hot.size) * 4

    @property
    def table_bytes(self) -> int:
        return int(self._host.size) * 4


# ---------------------------------------------------------------------------
# sampled train step + loop
# ---------------------------------------------------------------------------


def make_sampled_train_step(step: ModelStep, opt: Optimizer, *,
                            schedule=None, root_key=None) -> Callable:
    """Jitted ``train_step(state, rows, view, i)`` for sampled batches.

    ``state = (dense_params, opt_state)`` excludes the entity table —
    the tier store owns it; ``rows`` is the gathered row table for this
    batch's outermost frontier. Returns ``(state, row_grads, metrics)``;
    the caller scatters ``row_grads`` back through the store. ACT
    resolution is the standard ``act_context(schedule, root, step=i)``
    entry — same scope paths, policies and stochastic-rounding keys as
    the full-graph step (``make_train_step``).
    """
    from repro.models.kgnn import sampled_bpr_loss

    cfg = step.cfg

    @jax.jit
    def train_step(state, rows, view, i):
        dense, opt_state = state

        def loss_fn(d, r):
            params = {**d, "entity": r}
            ctx = act_context(schedule, root_key, step=i)
            with enter_or_null(ctx):
                return sampled_bpr_loss(params, view, cfg)

        loss, (g_dense, g_rows) = jax.value_and_grad(
            loss_fn, argnums=(0, 1))(dense, rows)
        dense, opt_state = opt.update(g_dense, opt_state, dense)
        return (dense, opt_state), g_rows, {"loss": loss}

    return train_step


@dataclasses.dataclass
class SampledTrainReport:
    losses: list
    hit_rate: float
    rows_transferred_per_step: float
    peak_device_bytes: int
    store_device_bytes: int
    table_bytes: int
    step_ms: float
    n_steps: int
    stats: dict
    step_ms_p99: float = 0.0   # per-step wall-time tail (report-only)


def run_sampled_training(step: ModelStep, *, fanouts: tuple[int, ...],
                         steps: int = 50, batch_size: int = 256,
                         hot_frac: float = 0.1, refresh_every: int = 16,
                         lr: float | None = None, schedule=None,
                         root_key=None, seed: int = 0,
                         block_e: int = 256, block_rows: int = 256,
                         measure_bytes: bool = False,
                         init_key: jax.Array | None = None,
                         log_fn: Callable | None = None):
    """Train a KG step end-to-end on sampled minibatches.

    Pipeline per step (DESIGN.md §11 overlap timeline):

      1. dispatch the jitted step on batch *k* (async);
      2. pull batch *k+1* from the background sampler and gather its
         rows — overlaps the running device step, but is stale with
         respect to step *k*'s pending row update;
      3. scatter step *k*'s row gradients back (the first sync point);
      4. ``patch`` the prefetched table: re-gather only the rows batch
         *k+1* shares with the rows just updated.

    Step 4 restores exact sequential semantics, so the whole loop is
    deterministic given (seed, schedule) — pinned by the replay test.

    Returns ``(report, dense_params, store)``; ``store.flush()`` is the
    full entity table for eval/checkpointing.
    """
    from repro.data.minibatch import MinibatchStream

    if step.family != "kgnn" or "dataset" not in step.data:
        raise ValueError(
            f"sampled minibatch training (--sample) is defined for the "
            f"kgnn family with a bound KG dataset; arch {step.arch!r} "
            f"(family {step.family!r}) has none. Train it full-batch "
            f"instead (drop --sample).")
    cfg = step.cfg
    if cfg.n_layers != len(fanouts):
        raise ValueError(
            f"--sample needs one fanout per layer: model has "
            f"{cfg.n_layers} layers but got fanouts {tuple(fanouts)} "
            f"(pass e.g. --sample fanout="
            f"{','.join(['10'] * cfg.n_layers)})")

    ds = step.data["dataset"]
    g = ds.graph
    params = step.init(init_key if init_key is not None
                       else jax.random.PRNGKey(0))
    # the full entity table moves host-side NOW and its device buffer is
    # dropped — from here on the device never holds more than the hot
    # tier + the gathered batch rows (the whole point of the subsystem)
    entity_host = np.asarray(params.pop("entity"))
    dense = params
    freq = node_in_degree(g.src, g.dst, g.rel, g.n_nodes)
    store = TieredEmbeddingStore(
        entity_host, freq, hot_frac=hot_frac,
        refresh_every=refresh_every)
    del entity_host
    lr = step.lr if lr is None else lr
    opt = adam(lr)
    state = (dense, opt.init(dense))
    train_step = make_sampled_train_step(step, opt, schedule=schedule,
                                         root_key=root_key)
    build_layouts = getattr(schedule, "kernel", "jnp") == "pallas"

    losses, peak_bytes, step_ms = [], 0, []
    hist = get_registry().histogram("train/step_ms", arch=step.arch,
                                    mode="sampled")
    t0 = time.perf_counter()
    with MinibatchStream(ds, tuple(fanouts), batch_size=batch_size,
                         seed=seed, build_layouts=build_layouts,
                         block_e=block_e, block_rows=block_rows) as stream:
        item = stream.next()
        rows = store.gather(item.input_nodes, item.requests)
        for t in range(steps):
            ts = time.perf_counter()
            with span("train/step", step=t):
                with span("train/step/dispatch"):
                    state, g_rows, metrics = train_step(
                        state, rows, item.view, jnp.asarray(t, jnp.int32))
                nxt = stream.next()
                with span("train/step/gather"):  # overlaps the step
                    pre = store.gather(nxt.input_nodes, nxt.requests)
                with span("train/step/scatter"):
                    updated = store.apply_grads(item.input_nodes, g_rows,
                                                lr)
                with span("train/step/patch"):
                    pre = store.patch(pre, nxt.input_nodes, updated)
                losses.append(float(metrics["loss"]))
            dt = (time.perf_counter() - ts) * 1e3
            step_ms.append(dt)
            hist.observe(dt)
            if measure_bytes:
                peak_bytes = max(peak_bytes, live_device_bytes())
            if log_fn is not None and (t % 10 == 0 or t == steps - 1):
                log_fn(f"step {t:4d}  loss {losses[-1]:.4f}  "
                       f"hit {store.hit_rate:.2%}")
            item, rows = nxt, pre
    dt_ms = (time.perf_counter() - t0) * 1e3 / max(steps, 1)

    report = SampledTrainReport(
        losses=losses, hit_rate=store.hit_rate,
        rows_transferred_per_step=store.rows_transferred_per_step,
        peak_device_bytes=peak_bytes,
        store_device_bytes=store.device_bytes,
        table_bytes=store.table_bytes, step_ms=dt_ms, n_steps=steps,
        stats=dict(store.stats),
        step_ms_p99=float(np.percentile(step_ms, 99)) if step_ms else 0.0)
    return report, state[0], store
