"""The model-step registry's step type: ONE step definition per arch.

TinyKG's claim is framework-level — *any* KGNN trains with compressed
activations — and scaling work (Data Tiering, Min et al. 2022) assumes
the training step is a reusable unit. ``ModelStep`` is that unit
(DESIGN.md §9): the launcher, the ``Trainer``, the data-parallel wrapper
(``repro.training.data_parallel.make_dp_step``), the examples and the
benchmarks all consume the same object instead of re-deriving a step per
model.

Protocol (structural — ``repro.models.registry`` builds concrete
instances from the existing layer functions):

  * ``init(key, data_spec=None) -> params`` — parameter pytree;
  * ``loss(params, batch, *, ctx=None) -> scalar`` — the training
    objective, with every ACT site resolved through the ordinary
    ``ActContext`` scopes. ``ctx`` is entered by the step (pass a fresh
    ``act_context(schedule, root, step=i)`` per trace); ``ctx=None``
    leaves ambient resolution to the caller (e.g. a recording context
    for ``traced_activation_report``);
  * ``dp_spec`` — what is replicated vs edge-sharded (``DPSpec``), or
    ``None`` with ``dp_unsupported`` naming why data parallelism does
    not apply;
  * ``batches() -> iterator`` — the step's default data stream (the
    launcher's; examples/benchmarks bring their own sizes).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Iterator, Protocol, runtime_checkable

import jax

from repro.core import act_context

__all__ = ["DPSpec", "ModelStep", "ModelStepProtocol", "make_train_step",
           "step_metadata"]


@dataclasses.dataclass(frozen=True)
class DPSpec:
    """What a step shards vs replicates under data parallelism.

    Params stay replicated (gradients all-reduce through the compressed
    psum); ``graph`` is the COO edge structure to dst-partition
    (``repro.data.csr.partition_edges``); the batch shards evenly over
    the mesh axis. ``sites`` lists the per-layer ACT sites
    ``(name, op_kind)`` whose policies/keys must be pre-resolved OUTSIDE
    the ``shard_map`` body, under ``<scope>/layer<l>/<site>`` scopes —
    the same paths the single-device step uses, so a DP step replays the
    same rounding noise at the same sites.
    """

    graph: Any                     # CKG to dst-partition
    scope: str                     # root scope name (e.g. "kgat")
    sites: tuple                   # ((site_name, op_kind), ...) per layer
    n_layers: int
    # (params, view, batch, *, site_keys, site_policies)
    #   -> (local objective incl. reg, local batch loss)
    shard_loss: Callable = None
    # (params, view, *, site_keys, site_policies) -> local readout rows;
    # optional, used by the forward-parity tests
    shard_reps: Callable | None = None


@runtime_checkable
class ModelStepProtocol(Protocol):
    arch: str
    dp_spec: DPSpec | None

    def init(self, key, data_spec=None): ...

    def loss(self, params, batch, *, ctx=None): ...


@dataclasses.dataclass(frozen=True)
class ModelStep:
    """Concrete step record the registry builds (see module docstring).

    ``init``/``loss``/``batches`` are plain callables bound over the
    step's config and data, so the dataclass satisfies
    ``ModelStepProtocol`` by attribute access.
    """

    arch: str                      # registry id ("kgat", "fm", ...)
    family: str                    # kgnn | gnn | recsys | lm | moe_lm
    cfg: Any                       # model config dataclass
    init: Callable                 # init(key, data_spec=None) -> params
    loss: Callable                 # loss(params, batch, *, ctx=None)
    batches: Callable[[], Iterator]
    lr: float = 1e-3               # launcher default learning rate
    dp_spec: DPSpec | None = None
    dp_unsupported: str | None = None   # why dp_spec is None, for errors
    data: dict = dataclasses.field(default_factory=dict)  # bound data refs
    data_spec: dict = dataclasses.field(default_factory=dict)  # shapes/sizes

    def metadata(self) -> dict:
        """Checkpoint-facing identity (see ``step_metadata``)."""
        return {"arch": self.arch, "family": self.family,
                "model": getattr(self.cfg, "model", self.family)}


def step_metadata(step: ModelStep, schedule_spec: str | None = None) -> dict:
    """Identity a checkpoint carries so restore can't silently mismatch.

    ``schedule_spec`` is the CLI-level policy string (``"int8"``,
    ``"first_layer_int8_rest_int2"``, ...): restoring a run under a
    different arch or schedule is almost always a mistake — the
    ``CheckpointManager`` refuses it instead of producing silently-wrong
    training.
    """
    meta = step.metadata()
    if schedule_spec is not None:
        meta["schedule"] = str(schedule_spec)
    return meta


def make_train_step(step: ModelStep, opt, *, schedule=None,
                    root_key: jax.Array | None = None):
    """Jitted single-device ``train_step(state, batch, i)`` for ``Trainer``.

    Each trace enters a fresh ``act_context(schedule, root_key, step=i)``
    so every ACT site resolves its per-site policy and scope-hashed,
    replay-exact stochastic-rounding key — identical wiring for every
    registered arch.
    """

    @jax.jit
    def train_step(state, batch, i):
        params, opt_state = state

        def loss_fn(p):
            ctx = act_context(schedule, root_key, step=i)
            return step.loss(p, batch, ctx=ctx)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), {"loss": loss}

    return train_step


def enter_or_null(ctx) -> contextlib.AbstractContextManager:
    """``with enter_or_null(ctx):`` — ambient entry when a context is
    given, no-op otherwise (the ``loss(..., ctx=None)`` contract)."""
    return ctx if ctx is not None else contextlib.nullcontext()
