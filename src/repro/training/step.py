"""The model-step registry's step type: ONE step definition per arch.

TinyKG's claim is framework-level — *any* KGNN trains with compressed
activations — and scaling work (Data Tiering, Min et al. 2022) assumes
the training step is a reusable unit. ``ModelStep`` is that unit
(DESIGN.md §9): the launcher, the ``Trainer``, the data-parallel wrapper
(``repro.training.data_parallel.make_dp_step``), the examples and the
benchmarks all consume the same object instead of re-deriving a step per
model.

Protocol (structural — ``repro.models.registry`` builds concrete
instances from the existing layer functions):

  * ``init(key, data_spec=None) -> params`` — parameter pytree;
  * ``loss(params, batch, *, ctx=None) -> scalar`` — the training
    objective, with every ACT site resolved through the ordinary
    ``ActContext`` scopes. ``ctx`` is entered by the step (pass a fresh
    ``act_context(schedule, root, step=i)`` per trace); ``ctx=None``
    leaves ambient resolution to the caller (e.g. a recording context
    for ``traced_activation_report``);
  * ``dp_spec`` — what is edge-sharded over the data axis and how each
    parameter lays out over the model axis (``ShardSpec``; ``DPSpec``
    is its pre-2D alias), or ``None`` with ``dp_unsupported`` naming
    why mesh parallelism does not apply;
  * ``batches() -> iterator`` — the step's default data stream (the
    launcher's; examples/benchmarks bring their own sizes).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Iterator, Protocol, runtime_checkable

import jax

from repro.core import act_context

__all__ = ["ShardSpec", "DPSpec", "ROW_SHARDED", "REPLICATED", "ModelStep",
           "ModelStepProtocol", "make_train_step", "step_metadata"]

# Per-parameter placement kinds for ``ShardSpec.placement`` (DESIGN.md
# §12). REPLICATED is the default for any parameter not listed.
ROW_SHARDED = "rows"
REPLICATED = "replicated"


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """What a step shards vs replicates under mesh parallelism.

    The data axis: ``graph`` is the COO edge structure to dst-partition
    (``repro.data.csr.partition_edges``); the batch shards evenly over
    the mesh's data axis. ``sites`` lists the per-layer ACT sites
    ``(name, op_kind)`` whose policies/keys must be pre-resolved OUTSIDE
    the ``shard_map`` body, under ``<scope>/layer<l>/<site>`` scopes —
    the same paths the single-device step uses, so a sharded step
    replays the same rounding noise at the same sites.

    The model axis: ``placement`` declares, per top-level parameter
    name, how the parameter lays out over the mesh's model axis —
    ``(name, ROW_SHARDED)`` splits dim 0 into per-shard row blocks
    (embedding tables); anything not listed is REPLICATED. On a 1D
    ``data=N`` mesh the placement is inert and every parameter is
    replicated, which is exactly the pre-2D behavior.
    """

    graph: Any                     # CKG to dst-partition
    scope: str                     # root scope name (e.g. "kgat")
    sites: tuple                   # ((site_name, op_kind), ...) per layer
    n_layers: int
    # (params, view, batch, *, site_keys, site_policies)
    #   -> (local objective incl. reg, local batch loss)
    shard_loss: Callable = None
    # (params, view, *, site_keys, site_policies) -> local readout rows;
    # optional, used by the forward-parity tests
    shard_reps: Callable | None = None
    # ((top_level_param_name, ROW_SHARDED), ...); unlisted => replicated
    placement: tuple = ()

    def row_sharded(self) -> tuple:
        """Top-level param names row-sharded over the model axis."""
        return tuple(n for n, kind in self.placement if kind == ROW_SHARDED)

    def placement_str(self) -> str:
        """Stable string form for checkpoint metadata (``"entity=rows"``)."""
        return ",".join(f"{n}={kind}" for n, kind in self.placement)


# The pre-2D name: ShardSpec generalizes DPSpec (placement defaults to
# all-replicated), so every existing DPSpec(...) construction and
# isinstance check keeps working unchanged.
DPSpec = ShardSpec


@runtime_checkable
class ModelStepProtocol(Protocol):
    arch: str
    dp_spec: DPSpec | None

    def init(self, key, data_spec=None): ...

    def loss(self, params, batch, *, ctx=None): ...


@dataclasses.dataclass(frozen=True)
class ModelStep:
    """Concrete step record the registry builds (see module docstring).

    ``init``/``loss``/``batches`` are plain callables bound over the
    step's config and data, so the dataclass satisfies
    ``ModelStepProtocol`` by attribute access.
    """

    arch: str                      # registry id ("kgat", "fm", ...)
    family: str                    # kgnn | gnn | recsys | lm | moe_lm
    cfg: Any                       # model config dataclass
    init: Callable                 # init(key, data_spec=None) -> params
    loss: Callable                 # loss(params, batch, *, ctx=None)
    batches: Callable[[], Iterator]
    lr: float = 1e-3               # launcher default learning rate
    dp_spec: DPSpec | None = None
    dp_unsupported: str | None = None   # why dp_spec is None, for errors
    data: dict = dataclasses.field(default_factory=dict)  # bound data refs
    data_spec: dict = dataclasses.field(default_factory=dict)  # shapes/sizes

    def metadata(self) -> dict:
        """Checkpoint-facing identity (see ``step_metadata``)."""
        return {"arch": self.arch, "family": self.family,
                "model": getattr(self.cfg, "model", self.family)}


def step_metadata(step: ModelStep, schedule_spec: str | None = None, *,
                  mesh_spec=None, placement: str | None = None) -> dict:
    """Identity a checkpoint carries so restore can't silently mismatch.

    ``schedule_spec`` is the CLI-level policy string (``"int8"``,
    ``"first_layer_int8_rest_int2"``, ...): restoring a run under a
    different arch or schedule is almost always a mistake — the
    ``CheckpointManager`` refuses it instead of producing silently-wrong
    training.

    ``mesh_spec`` (a ``MeshSpec`` or its string form) and ``placement``
    (``ShardSpec.placement_str()``) record the mesh topology and
    per-table layout of sharded runs: a 2D checkpoint's row-sharded
    tables are padded to the mesh's block geometry, so restoring onto a
    different layout is a shape-silent corruption — ``check_meta``
    refuses it naming both topologies (``--reshard-from`` is the
    explicit migration path).
    """
    meta = step.metadata()
    if schedule_spec is not None:
        meta["schedule"] = str(schedule_spec)
    if mesh_spec is not None:
        meta["mesh"] = str(mesh_spec)
    if placement is not None:
        meta["placement"] = str(placement)
    return meta


def make_train_step(step: ModelStep, opt, *, schedule=None,
                    root_key: jax.Array | None = None):
    """Jitted single-device ``train_step(state, batch, i)`` for ``Trainer``.

    Each trace enters a fresh ``act_context(schedule, root_key, step=i)``
    so every ACT site resolves its per-site policy and scope-hashed,
    replay-exact stochastic-rounding key — identical wiring for every
    registered arch.
    """

    @jax.jit
    def train_step(state, batch, i):
        params, opt_state = state

        def loss_fn(p):
            ctx = act_context(schedule, root_key, step=i)
            return step.loss(p, batch, ctx=ctx)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), {"loss": loss}

    return train_step


def enter_or_null(ctx) -> contextlib.AbstractContextManager:
    """``with enter_or_null(ctx):`` — ambient entry when a context is
    given, no-op otherwise (the ``loss(..., ctx=None)`` contract)."""
    return ctx if ctx is not None else contextlib.nullcontext()
