"""Optimizers from scratch (optax is not available in this environment).

Optax-style (init, update) pairs over arbitrary pytrees, with fp32 master
accumulators when params are bf16 (mixed-precision training), global-norm
clipping, decoupled weight decay, and lr schedules.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adam", "adamw", "sgd", "global_norm",
           "cosine_warmup", "constant_lr"]


class Optimizer(NamedTuple):
    init: Callable  # params -> state
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.0) -> Callable:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (peak_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return sched


def _as_sched(lr) -> Callable:
    return lr if callable(lr) else constant_lr(lr)


def adam(lr, *, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, clip_norm: float | None = None,
         decoupled_wd: bool = False) -> Optimizer:
    """Adam / AdamW (``decoupled_wd=True``) with fp32 master moments."""
    sched = _as_sched(lr)

    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree_util.tree_map(f32, params),
            "nu": jax.tree_util.tree_map(f32, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
        if clip_norm is not None:
            norm = global_norm(grads)
            factor = jnp.minimum(1.0, clip_norm / (norm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * factor, grads)
        if weight_decay and not decoupled_wd:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(jnp.float32),
                grads, params)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and decoupled_wd:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def adamw(lr, *, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, decoupled_wd=True, **kw)


def sgd(lr, *, momentum: float = 0.0, nesterov: bool = False,
        clip_norm: float | None = None) -> Optimizer:
    sched = _as_sched(lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mom": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if clip_norm is not None:
            norm = global_norm(grads)
            factor = jnp.minimum(1.0, clip_norm / (norm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * factor, grads)
        mom = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g, state["mom"], grads)
        eff = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g, mom, grads) if nesterov else mom
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) - lr_t * u).astype(p.dtype),
            params, eff)
        return new_params, {"step": step, "mom": mom}

    return Optimizer(init, update)
