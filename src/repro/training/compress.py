"""Gradient compression for the mesh-parallel all-reduce (beyond-paper).

The paper (§5) lists gradient compression as orthogonal future work; since
TinyKG's own SR quantizer is exactly the unbiased compressor needed, we
reuse it for the cross-replica gradient all-reduce:

  1. agree on a per-tensor scale: ``pmax`` of |g|  (one scalar per leaf)
  2. SR-quantize g/scale to int8 — unbiased (Proposition 1 applies)
  3. ``psum`` the int32-widened codes  (8/32 of the fp32 ring bytes; the
     wire format on a real fabric is int8 — XLA transfers the narrow type
     when the reduce is expressible; we model the int32 accumulate)
  4. dequantize by scale/replica-count

Used inside ``shard_map`` (via ``repro.sharding.compat``) — the live call
site is the generic data-parallel step in
``repro.training.data_parallel``. At 2+ pods the inter-pod (DCN) hop is
the slow link — compressing it 4× moves the collective roofline term
directly (see EXPERIMENTS.md §Perf).

Axis-awareness (2D ``data×model`` mesh, DESIGN.md §12): ``axis_name``
may be a tuple of mesh axes, and ``all_reduce_grads`` takes a
``placement`` map assigning top-level parameter names to the axis they
are row-sharded over. A row-sharded table's gradient is already the
shard's exact block gradient (the fetch VJP's local scatter IS the
model-axis reduce-scatter, see ``repro.sharding.rowshard``), so it must
NOT be reduced over that axis again — it reduces only over the
remaining axes (``psum`` over ``data``). Replicated parameters reduce
over every axis: their per-shard gradients are identical across the
model axis, so the extra reduction is exact in fp32 and, compressed,
averages more independent SR draws (variance ↓).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["all_reduce_grads", "compressed_psum_mean", "psum_mean",
           "allreduce_byte_report"]


def _axes(axis_name) -> tuple:
    return (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)


def _reduce_groups(grads, axes: tuple, placement: dict | None) -> dict:
    """``reduced-axes tuple -> top-level names`` — the same grouping
    ``all_reduce_grads`` reduces by (placement skips the sharded axis)."""
    if not placement:
        return {axes: sorted(grads) if isinstance(grads, dict) else None}
    groups: dict = {}
    for name in grads:
        r_axes = tuple(a for a in axes if a != placement.get(name))
        groups.setdefault(r_axes, []).append(name)
    return groups


def allreduce_byte_report(grads, axis_name, *, placement: dict | None = None,
                          compressed: bool = True) -> list[dict]:
    """Analytic per-step wire bytes of :func:`all_reduce_grads`.

    Static accounting over leaf shapes (no tracing): the INT8 path ships
    one byte per element plus a 4-byte fp32 scale per leaf (the agreed
    per-tensor scale); the fp32 baseline ships 4 bytes per element.
    Bytes are the per-device reduce *payload* — one full traversal of
    the group's tree — not a fabric/ring model (that lives in
    ``launch/roofline.py``). Groups mirror ``all_reduce_grads``: a
    row-sharded table skips its placement axis, so on a 2D mesh its
    bytes report under ``axes="data"`` while replicated params report
    under ``axes="data+model"``. Feeds the ``allreduce/*`` registry
    series (DESIGN.md §13).
    """
    axes = _axes(axis_name)
    if placement and not isinstance(grads, dict):
        raise TypeError(
            "allreduce_byte_report placement= requires a dict of "
            f"top-level param subtrees, got {type(grads).__name__}")
    wire = "int8" if compressed else "fp32"
    out = []
    for r_axes, names in sorted(_reduce_groups(grads, axes,
                                               placement).items()):
        sub = grads if names is None else {n: grads[n] for n in names}
        leaves = jax.tree_util.tree_leaves(sub)
        n_elems = sum(int(x.size) for x in leaves)
        if not r_axes:
            nbytes = 0      # sharded over every reduced axis: no wire hop
        elif compressed:
            nbytes = n_elems + 4 * len(leaves)
        else:
            nbytes = 4 * n_elems
        out.append({"axes": "+".join(r_axes) if r_axes else "none",
                    "wire": wire, "bytes": int(nbytes),
                    "params": names})
    return out


def _sr_quantize_int8(g: jax.Array, scale: jax.Array, key: jax.Array):
    gn = g / jnp.maximum(scale, 1e-12) * 127.0
    floor = jnp.floor(gn)
    u = jax.random.uniform(key, g.shape, jnp.float32)
    q = floor + (u < (gn - floor)).astype(jnp.float32)
    return jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def compressed_psum_mean(grads, axis_name, key: jax.Array):
    """Mean-all-reduce each leaf with int8 SR compression (unbiased).

    ``axis_name`` is one mesh axis or a tuple of them (the reduce then
    spans their product). ``key`` may be replicated: each replica folds
    in its own index along every reduced axis, so rounding noise is
    independent across replicas and averages down ~1/√n in the psum
    instead of adding coherently (shard gradients are near-equal batch
    estimates — with a shared draw the identical components, e.g. the
    L2 term, would round identically on every replica and the mean
    would keep the full single-replica error).
    """
    axes = _axes(axis_name)
    for ax in axes:
        key = jax.random.fold_in(key, jax.lax.axis_index(ax))
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    n = jax.lax.psum(1, axes)
    out = []
    for i, g in enumerate(leaves):
        gf = g.astype(jnp.float32)
        scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axes)
        q = _sr_quantize_int8(gf, scale, jax.random.fold_in(key, i))
        total = jax.lax.psum(q.astype(jnp.int32), axes)
        out.append((total.astype(jnp.float32) * scale / 127.0 / n)
                   .astype(g.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def psum_mean(grads, axis_name):
    """Uncompressed baseline (``axis_name``: one axis or a tuple)."""
    axes = _axes(axis_name)
    n = jax.lax.psum(1, axes)
    return jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g, axes) / n, grads)


def all_reduce_grads(grads, axis_name, *, key: jax.Array | None = None,
                     compressed: bool = True, placement: dict | None = None):
    """The one gradient all-reduce entry point for shard_map train steps.

    ``compressed=False`` (or no key) is the exact fp32 path — the
    bit-verification baseline; ``compressed=True`` needs a per-step key
    (reusing one would replay identical rounding noise every step and
    void unbiasedness-in-expectation, same rule as the ACT sites).

    ``placement`` maps top-level param names (``grads`` must then be a
    dict) to the mesh axis each is row-sharded over; those subtrees
    skip that axis in their reduce (their in-body gradient is already
    the exact block gradient — see module docstring). ``None`` or an
    empty map is the classic everything-over-every-axis behavior.
    """
    axes = _axes(axis_name)
    if compressed and key is None:
        raise ValueError(
            "compressed grad all-reduce needs a per-step PRNG key "
            "(pass compressed=False for the exact baseline)")
    if not placement:
        if not compressed:
            return psum_mean(grads, axes if len(axes) > 1 else axes[0])
        return compressed_psum_mean(
            grads, axes if len(axes) > 1 else axes[0], key)
    if not isinstance(grads, dict):
        raise TypeError(
            "all_reduce_grads placement= requires a dict of top-level "
            f"param subtrees, got {type(grads).__name__}")
    unknown = sorted(set(placement) - set(grads))
    if unknown:
        raise ValueError(
            f"placement names parameters not in the gradient tree: "
            f"{unknown} (have {sorted(grads)})")
    # Group param names by the axes they actually reduce over, reduce
    # each group in one call (per-leaf key folding stays i-indexed
    # within the group; a per-group salt keeps draws independent).
    groups: dict = {}
    for name in grads:
        r_axes = tuple(a for a in axes if a != placement.get(name))
        groups.setdefault(r_axes, []).append(name)
    out = {}
    for j, r_axes in enumerate(sorted(groups)):
        sub = {n: grads[n] for n in groups[r_axes]}
        if not r_axes:
            out.update(sub)  # sharded over every reduced axis: already local
        elif not compressed:
            out.update(psum_mean(sub, r_axes))
        else:
            out.update(compressed_psum_mean(sub, r_axes,
                                            jax.random.fold_in(key, j)))
    return {name: out[name] for name in grads}
