"""Gradient compression for the data-parallel all-reduce (beyond-paper).

The paper (§5) lists gradient compression as orthogonal future work; since
TinyKG's own SR quantizer is exactly the unbiased compressor needed, we
reuse it for the cross-replica gradient all-reduce:

  1. agree on a per-tensor scale: ``pmax`` of |g|  (one scalar per leaf)
  2. SR-quantize g/scale to int8 — unbiased (Proposition 1 applies)
  3. ``psum`` the int32-widened codes  (8/32 of the fp32 ring bytes; the
     wire format on a real fabric is int8 — XLA transfers the narrow type
     when the reduce is expressible; we model the int32 accumulate)
  4. dequantize by scale/replica-count

Used inside ``shard_map`` (via ``repro.sharding.compat``) over the
`data`/`pod` mesh axes — the live call site is the data-parallel KGAT
step in ``repro.training.data_parallel``. At 2+ pods the inter-pod (DCN)
hop is the slow link — compressing it 4× moves the collective roofline
term directly (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["all_reduce_grads", "compressed_psum_mean", "psum_mean"]


def _sr_quantize_int8(g: jax.Array, scale: jax.Array, key: jax.Array):
    gn = g / jnp.maximum(scale, 1e-12) * 127.0
    floor = jnp.floor(gn)
    u = jax.random.uniform(key, g.shape, jnp.float32)
    q = floor + (u < (gn - floor)).astype(jnp.float32)
    return jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def compressed_psum_mean(grads, axis_name: str, key: jax.Array):
    """Mean-all-reduce each leaf with int8 SR compression (unbiased).

    ``key`` may be replicated: each replica folds in its own axis index,
    so rounding noise is independent across replicas and averages down
    ~1/√n in the psum instead of adding coherently (shard gradients are
    near-equal batch estimates — with a shared draw the identical
    components, e.g. the L2 term, would round identically on every
    replica and the mean would keep the full single-replica error).
    """
    key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    n = jax.lax.psum(1, axis_name)
    out = []
    for i, g in enumerate(leaves):
        gf = g.astype(jnp.float32)
        scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        q = _sr_quantize_int8(gf, scale, jax.random.fold_in(key, i))
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        out.append((total.astype(jnp.float32) * scale / 127.0 / n)
                   .astype(g.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def psum_mean(grads, axis_name: str):
    """Uncompressed baseline."""
    n = jax.lax.psum(1, axis_name)
    return jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g, axis_name) / n, grads)


def all_reduce_grads(grads, axis_name: str, *, key: jax.Array | None = None,
                     compressed: bool = True):
    """The one gradient all-reduce entry point for shard_map train steps.

    ``compressed=False`` (or no key) is the exact fp32 path — the
    bit-verification baseline; ``compressed=True`` needs a per-step key
    (reusing one would replay identical rounding noise every step and
    void unbiasedness-in-expectation, same rule as the ACT sites).
    """
    if not compressed:
        return psum_mean(grads, axis_name)
    if key is None:
        raise ValueError(
            "compressed grad all-reduce needs a per-step PRNG key "
            "(pass compressed=False for the exact baseline)")
    return compressed_psum_mean(grads, axis_name, key)
