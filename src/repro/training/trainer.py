"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested in tests/test_trainer.py):
  * checkpoint/restart: periodic async checkpoints; on step failure the
    loop restores the last good checkpoint and replays. SR randomness is
    keyed by global step (``step_key``), so replayed steps reproduce the
    same stochastic rounding — restarts are bit-deterministic.
  * bounded retries: ``max_failures`` consecutive failures aborts.
  * straggler mitigation: the host data queue has a fetch timeout; a
    straggling shard is skipped (batch re-sampled) rather than stalling
    the step, and slow-step telemetry (EMA) is logged.
  * elastic scaling hook: on restore, a new mesh/template may be supplied
    (fewer/more hosts) — the checkpoint reshards via device_put.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Iterator

import jax

from repro.obs import get_registry, log as obs_log, step_span, span

from .checkpoint import CheckpointManager

__all__ = ["TrainerConfig", "Trainer", "PrefetchIterator"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 50
    max_failures: int = 3
    fetch_timeout_s: float = 30.0


class PrefetchIterator:
    """Background-thread prefetch with timeout — the straggler guard.

    A data shard that exceeds ``timeout_s`` is skipped (the producer keeps
    running; the consumer just takes the next ready batch).

    ``close()`` genuinely stops the producer: puts use a bounded-timeout
    loop that re-checks the done flag, so a producer blocked on a full
    queue (the common steady state — the consumer is the slow side)
    observes shutdown instead of outliving the trainer. A plain
    ``Queue.put`` would block forever once the consumer stops taking.
    """

    _PUT_POLL_S = 0.05

    def __init__(self, it: Iterator, depth: int = 2,
                 timeout_s: float = 30.0):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._timeout = timeout_s
        self._done = False

        def worker():
            for item in it:
                if not self._put(item):
                    return
            self._put(StopIteration)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Bounded-timeout put; False once the iterator is closed."""
        while not self._done:
            try:
                self._q.put(item, timeout=self._PUT_POLL_S)
                return True
            except queue.Full:
                continue
        return False

    def next(self):
        item = self._q.get(timeout=self._timeout)
        if item is StopIteration:
            raise StopIteration
        return item

    def close(self, join_timeout_s: float = 5.0):
        """Stop the producer thread and drain pending items."""
        self._done = True
        while True:  # unblock a producer waiting on a full queue
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=join_timeout_s)


class Trainer:
    """Drives ``train_step(state, batch, step) -> (state, metrics)``.

    ``state`` is any pytree (params + optimizer state). The step function
    must be jitted by the caller (the trainer is model-agnostic).
    """

    def __init__(self, train_step: Callable, state, data_iter: Iterator,
                 cfg: TrainerConfig, *, eval_fn: Callable | None = None,
                 log_fn: Callable | None = None,
                 ckpt_meta: dict | None = None, step_writer=None,
                 items_per_step: int | None = None,
                 item_unit: str = "edges"):
        self.train_step = train_step
        self.state = state
        self.cfg = cfg
        self.data = PrefetchIterator(data_iter, timeout_s=cfg.fetch_timeout_s)
        # ckpt_meta (arch id + schedule spec, see step_metadata) rides in
        # every manifest and is enforced on restore — a checkpoint from a
        # different arch/schedule fails loudly instead of resuming wrong
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep,
                                      meta=ckpt_meta)
        self.eval_fn = eval_fn
        # progress lines go to stderr through the leveled obs log (stdout
        # stays machine-parseable); callers may still inject their own
        self.log = log_fn if log_fn is not None else obs_log
        self.step = 0
        self.history: list[dict] = []
        self._failures = 0
        self._step_ema: float | None = None
        # failure-injection hook for tests: fn(step) -> bool (raise?)
        self.failure_injector: Callable | None = None
        # telemetry: per-step wall time (dispatch-side, same quantity the
        # straggler EMA watches), loss at log points, throughput when the
        # caller supplies the per-step work size (edges/tokens)
        self.step_writer = step_writer
        self.items_per_step = items_per_step
        self.item_unit = item_unit
        reg = get_registry()
        self._m_steps = reg.counter("train/steps")
        self._m_step_ms = reg.histogram("train/step_ms")
        self._m_loss = reg.gauge("train/loss")
        self._m_tput = (reg.gauge(f"train/{item_unit}_per_sec")
                        if items_per_step else None)

    def restore_if_available(self):
        step, state = self.ckpt.restore(self.state)
        if step is not None:
            self.step, self.state = step, state
            self.log(f"[trainer] restored checkpoint at step {step}")
        return self

    def run(self):
        # one-line topology breadcrumb: SPMD steps (shard_map over a
        # simulated or real mesh) look identical from here, so make the
        # device layout part of the log contract for post-mortems
        self.log(f"[trainer] {jax.device_count()} device(s), "
                 f"backend={jax.default_backend()}, "
                 f"start step {self.step}/{self.cfg.total_steps}")
        try:
            with span("train"):
                return self._run()
        finally:
            self.data.close()  # don't leak the prefetch producer thread

    def _run(self):
        cfg = self.cfg
        while self.step < cfg.total_steps:
            try:
                with step_span("train/step", self.step):
                    with span("train/step/data"):
                        batch = self.data.next()
                    if self.failure_injector is not None and \
                            self.failure_injector(self.step):
                        raise RuntimeError(
                            f"injected failure at step {self.step}")
                    t0 = time.perf_counter()
                    with span("train/step/update"):
                        self.state, metrics = self.train_step(
                            self.state, batch, self.step)
                    dt = time.perf_counter() - t0
                self._step_ema = dt if self._step_ema is None else \
                    0.9 * self._step_ema + 0.1 * dt
                self._m_steps.inc()
                self._m_step_ms.observe(dt * 1e3)
                if self._m_tput is not None and dt > 0:
                    self._m_tput.set(self.items_per_step / dt)
                # straggler telemetry: flag steps 3x slower than EMA
                if dt > 3.0 * self._step_ema and self.step > 10:
                    self.log(f"[trainer] straggler step {self.step}: "
                             f"{dt:.3f}s vs ema {self._step_ema:.3f}s")
                self.step += 1
                self._failures = 0
                record = None
                if self.step_writer is not None:
                    record = {"step": self.step,
                              "wall_ms": round(dt * 1e3, 4)}
                if self.step % cfg.log_every == 0:
                    m = {k: float(v) for k, v in metrics.items()}
                    self.history.append({"step": self.step, **m})
                    self.log(f"[trainer] step {self.step}: {m}")
                    if "loss" in m:
                        self._m_loss.set(m["loss"])
                    if record is not None:
                        record.update(m)
                if record is not None:
                    self.step_writer.write(record)
                if self.step % cfg.ckpt_every == 0:
                    self.ckpt.save(self.step, self.state)
            except StopIteration:
                break
            except Exception as e:  # noqa: BLE001 — fault tolerance boundary
                self._failures += 1
                self.log(f"[trainer] step {self.step} failed "
                         f"({self._failures}/{cfg.max_failures}): {e}")
                if self._failures >= cfg.max_failures:
                    raise
                step, state = self.ckpt.restore(self.state)
                if step is not None:
                    self.step, self.state = step, state
                    self.log(f"[trainer] rolled back to step {step}")
        self.ckpt.save(self.step, self.state)
        self.ckpt.wait()
        return self.state
