"""Compressed-gradient data parallelism for ANY registered KG step
(DESIGN.md §7 + §9).

The end-to-end story: edges dst-partitioned by
``repro.data.csr.partition_edges``, the full step (edge weights, edge
softmax, ACT-compressed SPMM + transforms, BPR loss, backward) runs
per-shard inside one ``shard_map``, and gradients of the replicated
params all-reduce through the INT8 stochastic-rounding ``psum`` of
``repro.training.compress``.

There is no per-model DP forward here anymore: the ``shard_map`` body
builds a ``kgnn.ShardGraphView`` and runs the step's own
``DPSpec.shard_loss`` — the SAME ``propagate_view`` layer functions the
single-device step traces — so kgat, kgcn and kgin (and any future
registered KG arch) share one wrapper. ``propagate_spmd`` now matches
these semantics too (attention once, from the layer-0 embeddings); the
old per-layer-recomputed-attention fork is gone.

Exactness contract (pinned by tests/test_data_parallel.py per arch):

  * edge weights are computed ONCE from the layer-0 embeddings;
  * within a shard, edges keep their original relative order, so each
    destination row accumulates in the same order as the unsharded
    ``segment_sum`` — with exact compression and ``compress_grads=False``
    a step is bit-verifiable against the single-device step;
  * with stochastic policies the per-shard quantizers use shard-local
    scales and scope-hashed keys, so the step is not bit-identical but
    every estimator stays unbiased (Proposition 1 per shard + unbiased
    INT8 gradient all-reduce) — the multi-seed mean test pins this.

Per-site ACT policies and stochastic-rounding keys resolve through the
ordinary ``ActContext`` machinery (same ``<arch>/layer<l>/<site>``
scopes as ``propagate``, with the site table supplied by
``DPSpec.sites``) but are derived OUTSIDE the shard_map body and ride
in as replicated args: closed-over tracers are off-limits inside a body.

Each shard's SPMM gathers only its halo rows (the unique remote sources
``partition_edges`` precomputed) out of the all-gathered table, so the
inner gather/scatter works over ``(h_cap, d)``, not ``(N, d)`` — the
shape the halo-exchange roofline term is priced on. ``act_spmm`` runs
its jnp backend here; the blocked-CSR Pallas path stays single-device
(per-shard layouts have unequal block counts; see DESIGN.md §7.4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import FP32
from repro.core.context import ActContext
from repro.core.policy import as_schedule
from repro.core.rng import scope_key
from repro.data.csr import EdgePartition, partition_edges
from repro.models.kgnn import ShardGraphView
from repro.sharding.compat import P, shard_map
from repro.training.step import DPSpec, ModelStep

__all__ = ["partition_graph", "dp_loss_and_grads", "make_dp_step",
           "dp_forward_reps", "dp_bpr_loss_and_grads", "make_kgat_dp_step",
           "check_no_sampled_dp"]


def check_no_sampled_dp(batch_or_view, *, mesh_spec: str = "data=N") -> None:
    """Refuse sampled minibatches on the DP path with a NAMED error.

    ``--mesh data=N`` dst-partitions the FULL edge list once at launch;
    a neighbor-sampled batch (``SampledGraphView`` / ``--sample``) has a
    fresh per-hop edge set every step, so the partition, the halo caps
    and the per-shard block layouts are all undefined for it. Until
    sharded sampling lands, the combination must fail loudly here — not
    as a shape mismatch three layers deep in a ``shard_map`` body.
    """
    from repro.models.kgnn import SampledGraphView

    inner = getattr(batch_or_view, "view", None)  # unwrap a SampledItem
    if isinstance(batch_or_view, SampledGraphView) \
            or isinstance(inner, SampledGraphView) or (
            isinstance(batch_or_view, str) and batch_or_view):
        raise NotImplementedError(
            f"sampled minibatch training (--sample) cannot be combined "
            f"with data parallelism (--mesh {mesh_spec}): edges are "
            f"dst-partitioned once at launch, but sampled batches carry "
            f"a fresh per-hop edge set every step. Drop --mesh to train "
            f"sampled on one device, or drop --sample for full-graph "
            f"data parallelism.")


def partition_graph(g, mesh, *, axis: str = "data") -> EdgePartition:
    """``partition_edges`` sized to one mesh axis (edges by dst shard)."""
    import numpy as np

    return partition_edges(
        np.asarray(g.src), np.asarray(g.dst), np.asarray(g.rel),
        n_nodes=g.n_nodes, n_shards=int(mesh.shape[axis]))


def _as_dp_spec(step: ModelStep | DPSpec) -> DPSpec:
    if isinstance(step, DPSpec):
        return step
    if getattr(step, "dp_spec", None) is None:
        arch = getattr(step, "arch", "<unknown>")
        why = getattr(step, "dp_unsupported", None) or \
            "the step registered no DPSpec"
        raise NotImplementedError(
            f"data parallelism is not implemented for arch {arch!r}: {why}")
    return step.dp_spec


def _site_policies(schedule, spec: DPSpec) -> list[dict]:
    """Per-layer {site: ACTPolicy} via the normal scope-glob resolution."""
    sched = as_schedule(schedule) if schedule is not None else None
    ctx = ActContext(sched)
    out = []
    with ctx, ctx.scope(spec.scope):
        for l in range(spec.n_layers):
            with ctx.scope(f"layer{l}"):
                out.append({
                    site: (ctx.policy_for(kind, ctx.scope_path(site))
                           or FP32)
                    for site, kind in spec.sites})
    return out


def _site_keys(root: jax.Array | None, step_idx, spec: DPSpec) -> list[dict]:
    """Per-layer {site: key}, identical derivation to the ambient context
    (``fold_in(fold_in(root, crc32(scope)), step)``) so a DP step replays
    the same rounding noise as a single-device step at the same scope.
    With no root key (exact-compression runs) every site key is None."""
    if root is None:
        return [{site: None for site, _ in spec.sites}
                for _ in range(spec.n_layers)]
    ctx = ActContext(None, root, step=step_idx)
    out = []
    with ctx, ctx.scope(spec.scope):
        for l in range(spec.n_layers):
            with ctx.scope(f"layer{l}"):
                out.append({site: ctx.key_for(ctx.scope_path(site))
                            for site, _ in spec.sites})
    return out


def _check_contract(part: EdgePartition, mesh, axis: str, batch,
                    root_key, *, need_key: bool) -> None:
    n_shards = int(mesh.shape[axis])
    if part.n_shards != n_shards:
        raise ValueError(
            f"partition built for {part.n_shards} shards, mesh axis "
            f"{axis!r} has {n_shards}")
    if batch is not None:
        b = batch["user"].shape[0]
        if b % n_shards:
            raise ValueError(
                f"batch {b} not divisible by {n_shards} shards")
    if need_key and root_key is None:
        raise ValueError("dp step needs a root key (per-step SR + psum "
                         "compression keys derive from it)")


def _part_leaves(part: EdgePartition) -> dict:
    return {"src_h": part.src_h, "dst_l": part.dst_l,
            "rel": part.rel, "mask": part.mask, "halo": part.halo}


def dp_loss_and_grads(step: ModelStep | DPSpec, params,
                      part: EdgePartition, batch, *, mesh,
                      axis: str = "data", schedule=None,
                      root_key: jax.Array | None = None, step_idx=0,
                      compress_grads: bool = True):
    """Sharded step core for any registered KG arch: ``(loss, grads)``.

    ``params`` replicated; ``part`` dst-sharded over ``axis``; ``batch``
    (user/pos/neg, each divisible by the shard count) sharded over
    ``axis``. ``grads`` come back replicated — already mean-reduced
    through the compressed (or exact) psum — so the optimizer update
    stays a plain replicated computation. ``loss`` is the shard-mean of
    the local objectives (local batch BPR + full L2), i.e. the global
    objective.
    """
    from repro.training.compress import all_reduce_grads

    spec = _as_dp_spec(step)
    _check_contract(part, mesh, axis, batch, root_key, need_key=True)
    policies = _site_policies(schedule, spec)
    site_keys = _site_keys(root_key, step_idx, spec)
    psum_key = scope_key(root_key, f"{spec.scope}/dp_psum", step_idx)

    def body(params_, part_leaves, batch_, site_keys_, psum_key_):
        sh = {k: v[0] for k, v in part_leaves.items()}  # (1, …) -> (…)
        view = ShardGraphView.from_shard(
            sh, axis=axis, num_rows=part.rows_per_shard,
            n_nodes_padded=part.n_nodes_padded)

        def loss_fn(p):
            return spec.shard_loss(p, view, batch_, site_keys=site_keys_,
                                   site_policies=policies)

        (total, _), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params_)
        grads = all_reduce_grads(grads, axis, key=psum_key_,
                                 compressed=compress_grads)
        loss = jax.lax.pmean(total, axis)
        return loss, grads

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(), P()),
        out_specs=(P(), P()))
    return mapped(params, _part_leaves(part), batch, site_keys, psum_key)


def dp_forward_reps(step: ModelStep | DPSpec, params,
                    part: EdgePartition, *, mesh, axis: str = "data",
                    schedule=None, root_key: jax.Array | None = None,
                    step_idx=0) -> jax.Array:
    """Readout representations from the sharded forward (parity tests).

    Returns the (n_nodes, D) table — rows beyond ``part.n_nodes`` (node-
    space padding) are dropped. With exact compression this is
    bit-comparable against single-device ``propagate``.
    """
    spec = _as_dp_spec(step)
    if spec.shard_reps is None:
        raise NotImplementedError(f"{spec.scope}: DPSpec has no shard_reps")
    _check_contract(part, mesh, axis, None, root_key, need_key=False)
    policies = _site_policies(schedule, spec)
    site_keys = _site_keys(root_key, step_idx, spec)

    def body(params_, part_leaves, site_keys_):
        sh = {k: v[0] for k, v in part_leaves.items()}
        view = ShardGraphView.from_shard(
            sh, axis=axis, num_rows=part.rows_per_shard,
            n_nodes_padded=part.n_nodes_padded)
        return spec.shard_reps(params_, view, site_keys=site_keys_,
                               site_policies=policies)

    mapped = shard_map(body, mesh=mesh, in_specs=(P(), P(axis), P()),
                       out_specs=P(axis, None))
    reps = mapped(params, _part_leaves(part), site_keys)
    return reps[:part.n_nodes]


def make_dp_step(step: ModelStep | DPSpec, part: EdgePartition, mesh, opt,
                 *, schedule=None, root_key: jax.Array,
                 axis: str = "data", compress_grads: bool = True):
    """Jitted ``train_step(state, batch, step)`` for ``Trainer``, for any
    KG arch with a ``DPSpec``.

    One ``shard_map`` spans loss, backward, and the compressed gradient
    all-reduce; the (replicated) optimizer update runs outside it.
    Raises ``NotImplementedError`` (naming the arch and why) for steps
    without a ``DPSpec``.
    """
    spec = _as_dp_spec(step)

    def train_step(state, batch, step_idx):
        check_no_sampled_dp(batch)
        return _jit_step(state, batch, step_idx)

    @jax.jit
    def _jit_step(state, batch, step_idx):
        params, opt_state = state
        loss, grads = dp_loss_and_grads(
            spec, params, part, batch, mesh=mesh, axis=axis,
            schedule=schedule, root_key=root_key, step_idx=step_idx,
            compress_grads=compress_grads)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), {"loss": loss}

    return train_step


# ---------------------------------------------------------------------------
# legacy KGAT-shaped entry points (thin wrappers over the generic path)
# ---------------------------------------------------------------------------


def dp_bpr_loss_and_grads(params, part: EdgePartition, batch, *, cfg,
                          mesh, axis: str = "data", schedule=None,
                          root_key: jax.Array | None = None, step=0,
                          compress_grads: bool = True):
    """Config-shaped wrapper around ``dp_loss_and_grads`` (any KG model)."""
    from repro.models.registry import kg_dp_spec

    return dp_loss_and_grads(
        kg_dp_spec(cfg), params, part, batch, mesh=mesh, axis=axis,
        schedule=schedule, root_key=root_key, step_idx=step,
        compress_grads=compress_grads)


def make_kgat_dp_step(cfg, part: EdgePartition, mesh, opt, *,
                      schedule=None, root_key: jax.Array,
                      axis: str = "data", compress_grads: bool = True):
    """Config-shaped wrapper around ``make_dp_step`` (any KG model)."""
    from repro.models.registry import kg_dp_spec

    return make_dp_step(
        kg_dp_spec(cfg), part, mesh, opt, schedule=schedule,
        root_key=root_key, axis=axis, compress_grads=compress_grads)
