"""Compressed-gradient data-parallel KGAT training (DESIGN.md §7).

The end-to-end story the compat layer unlocks: edges dst-partitioned by
``repro.data.csr.partition_edges``, the full KGAT step (attention, edge
softmax, ACT-compressed SPMM + transforms, BPR loss, backward) runs
per-shard inside one ``shard_map``, and gradients of the replicated
params all-reduce through the INT8 stochastic-rounding ``psum`` of
``repro.training.compress``.

Semantics are pinned to the single-device ``kgnn.propagate``/``bpr_loss``
pair, not to ``propagate_spmd`` (which recomputes attention per layer):

  * attention is computed ONCE from the layer-0 embeddings;
  * within a shard, edges keep their original relative order, so each
    destination row accumulates in the same order as the unsharded
    ``segment_sum`` — with exact compression and ``compress_grads=False``
    a step is bit-verifiable against the single-device step;
  * with stochastic policies the per-shard quantizers use shard-local
    scales and scope-hashed keys, so the step is not bit-identical but
    every estimator stays unbiased (Proposition 1 per shard + unbiased
    INT8 gradient all-reduce) — the multi-seed mean test pins this.

Per-site ACT policies and stochastic-rounding keys resolve through the
ordinary ``ActContext`` machinery (same ``kgat/layer<l>/<site>`` scopes
as ``propagate``) but are derived OUTSIDE the shard_map body and ride in
as replicated args: closed-over tracers are off-limits inside a body.

Each shard's SPMM gathers only its halo rows (the unique remote sources
``partition_edges`` precomputed) out of the all-gathered table, so the
inner gather/scatter works over ``(h_cap, d)``, not ``(N, d)`` — the
shape the halo-exchange roofline term is priced on. ``act_spmm`` runs
its jnp backend here; the blocked-CSR Pallas path stays single-device
(per-shard layouts have unequal block counts; see DESIGN.md §7.4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import FP32, act_spmm
from repro.core.context import ActContext
from repro.core.policy import as_schedule
from repro.core.rng import scope_key
from repro.data.csr import EdgePartition, partition_edges
from repro.models.kgnn import (
    KGNNConfig,
    kgat_bi_interaction,
    score_pairs,
    segment_softmax,
)
from repro.sharding.compat import P, shard_map
from repro.training.compress import all_reduce_grads

__all__ = ["partition_graph", "dp_bpr_loss_and_grads", "make_kgat_dp_step"]

_SITES = (("spmm", "spmm"), ("w1", "matmul"), ("w2", "matmul"),
          ("act1", "nonlin"), ("act2", "nonlin"))


def partition_graph(g, mesh, *, axis: str = "data") -> EdgePartition:
    """``partition_edges`` sized to one mesh axis (edges by dst shard)."""
    import numpy as np

    return partition_edges(
        np.asarray(g.src), np.asarray(g.dst), np.asarray(g.rel),
        n_nodes=g.n_nodes, n_shards=int(mesh.shape[axis]))


def _site_policies(schedule, n_layers: int) -> list[dict]:
    """Per-layer {site: ACTPolicy} via the normal scope-glob resolution."""
    sched = as_schedule(schedule) if schedule is not None else None
    ctx = ActContext(sched)
    out = []
    with ctx, ctx.scope("kgat"):
        for l in range(n_layers):
            with ctx.scope(f"layer{l}"):
                out.append({
                    site: (ctx.policy_for(kind, ctx.scope_path(site))
                           or FP32)
                    for site, kind in _SITES})
    return out


def _site_keys(root: jax.Array, step, n_layers: int) -> list[dict]:
    """Per-layer {site: key}, identical derivation to the ambient context
    (``fold_in(fold_in(root, crc32(scope)), step)``) so a DP step replays
    the same rounding noise as a single-device step at the same scope."""
    ctx = ActContext(None, root, step=step)
    out = []
    with ctx, ctx.scope("kgat"):
        for l in range(n_layers):
            with ctx.scope(f"layer{l}"):
                out.append({site: ctx.key_for(ctx.scope_path(site))
                            for site, _ in _SITES})
    return out


def _local_loss(params, sh: dict, batch, *, cfg: KGNNConfig, axis: str,
                rows: int, n_pad: int, site_keys, policies):
    """One shard's slice of the global BPR loss (plus full L2 reg).

    ``sh`` holds this shard's edge arrays (squeezed); returns
    ``(local_batch_mean_bpr + reg, local_batch_mean_bpr)`` so the mean
    over shards is exactly the global objective.
    """
    e_tab = params["entity"]
    e_pad = jnp.pad(e_tab, ((0, n_pad - e_tab.shape[0]), (0, 0)))
    i = jax.lax.axis_index(axis)
    e_loc = jax.lax.dynamic_slice_in_dim(e_pad, i * rows, rows)

    # attention once, from layer-0 embeddings (matches propagate):
    # basis-projected tables all-gather tiled, then shrink to the halo
    proj_loc = jnp.einsum("nd,bdk->bnk", e_loc, params["att_basis"])
    proj_full = jax.lax.all_gather(proj_loc, axis, axis=1, tiled=True)
    proj_halo = proj_full[:, sh["halo"]]                     # (B, Hc, d)
    coef = params["att_coef"][sh["rel"]]                     # (Ec, B)
    eh = jnp.einsum("eb,bed->ed", coef, proj_halo[:, sh["src_h"]])
    et = jnp.einsum("eb,bed->ed", coef, proj_loc[:, sh["dst_l"]])
    logits = jnp.sum(et * jnp.tanh(eh + params["relation"][sh["rel"]]), -1)
    logits = jnp.where(sh["mask"] > 0, logits, -1e30)        # pad edges out
    att = segment_softmax(logits, sh["dst_l"], rows) * sh["mask"]

    outs = [e_loc]
    e = e_loc
    for l in range(cfg.n_layers):
        keys, pols = site_keys[l], policies[l]
        e_full = jax.lax.all_gather(e, axis, axis=0, tiled=True)
        e_halo = e_full[sh["halo"]]                          # (Hc, d_l)
        e_n = act_spmm(e_halo, sh["src_h"], sh["dst_l"], att,
                       num_nodes=rows, key=keys["spmm"], policy=pols["spmm"])
        e = kgat_bi_interaction(params, l, e, e_n, keys=keys, policies=pols)
        outs.append(e)

    reps_loc = jnp.concatenate(outs, axis=-1) if cfg.readout == "concat" \
        else sum(outs)
    reps = jax.lax.all_gather(reps_loc, axis, axis=0, tiled=True)
    pos = score_pairs(reps, batch["user"], batch["pos"], cfg.n_users)
    neg = score_pairs(reps, batch["user"], batch["neg"], cfg.n_users)
    loss_loc = -jnp.mean(jax.nn.log_sigmoid(pos - neg))
    reg = sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(params))
    return loss_loc + cfg.l2 * reg, loss_loc


def dp_bpr_loss_and_grads(params, part: EdgePartition, batch, *,
                          cfg: KGNNConfig, mesh, axis: str = "data",
                          schedule=None, root_key: jax.Array | None = None,
                          step=0, compress_grads: bool = True):
    """Sharded KGAT BPR step core: ``(loss, grads)``, grads all-reduced.

    ``params`` replicated; ``part`` dst-sharded over ``axis``; ``batch``
    (user/pos/neg, each divisible by the shard count) sharded over
    ``axis``. ``grads`` come back replicated — already mean-reduced
    through the compressed (or exact) psum — so the optimizer update
    stays a plain replicated computation.
    """
    n_shards = int(mesh.shape[axis])
    if part.n_shards != n_shards:
        raise ValueError(
            f"partition built for {part.n_shards} shards, mesh axis "
            f"{axis!r} has {n_shards}")
    b = batch["user"].shape[0]
    if b % n_shards:
        raise ValueError(f"batch {b} not divisible by {n_shards} shards")
    if root_key is None:
        raise ValueError("dp step needs a root key (per-step SR + psum "
                         "compression keys derive from it)")
    policies = _site_policies(schedule, cfg.n_layers)
    site_keys = _site_keys(root_key, step, cfg.n_layers)
    psum_key = scope_key(root_key, "kgat/dp_psum", step)

    def body(params_, part_leaves, batch_, site_keys_, psum_key_):
        sh = {k: v[0] for k, v in part_leaves.items()}  # (1, …) -> (…)
        loss_fn = functools.partial(
            _local_loss, sh=sh, batch=batch_, cfg=cfg, axis=axis,
            rows=part.rows_per_shard, n_pad=part.n_nodes_padded,
            site_keys=site_keys_, policies=policies)
        (_, loss_loc), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params_)
        grads = all_reduce_grads(grads, axis, key=psum_key_,
                                 compressed=compress_grads)
        loss = jax.lax.pmean(loss_loc, axis)
        return loss, grads

    part_leaves = {"src_h": part.src_h, "dst_l": part.dst_l,
                   "rel": part.rel, "mask": part.mask, "halo": part.halo}
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(), P()),
        out_specs=(P(), P()))
    loss, grads = mapped(params, part_leaves, batch, site_keys, psum_key)
    reg = sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(params))
    return loss + cfg.l2 * reg, grads


def make_kgat_dp_step(cfg: KGNNConfig, part: EdgePartition, mesh, opt, *,
                      schedule=None, root_key: jax.Array,
                      axis: str = "data", compress_grads: bool = True):
    """Jitted ``train_step(state, batch, step)`` for ``Trainer``.

    One ``shard_map`` spans loss, backward, and the compressed gradient
    all-reduce; the (replicated) optimizer update runs outside it.
    """

    @jax.jit
    def train_step(state, batch, step):
        params, opt_state = state
        loss, grads = dp_bpr_loss_and_grads(
            params, part, batch, cfg=cfg, mesh=mesh, axis=axis,
            schedule=schedule, root_key=root_key, step=step,
            compress_grads=compress_grads)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), {"loss": loss}

    return train_step
