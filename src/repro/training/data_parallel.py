"""Compressed-gradient mesh parallelism for ANY registered KG step
(DESIGN.md §7, §9, §12).

The end-to-end story: edges dst-partitioned by
``repro.data.csr.partition_edges``, the full step (edge weights, edge
softmax, ACT-compressed SPMM + transforms, BPR loss, backward) runs
per-shard inside one ``shard_map``, and gradients all-reduce through the
INT8 stochastic-rounding ``psum`` of ``repro.training.compress``.

There is no per-model DP forward here anymore: the ``shard_map`` body
builds a ``kgnn.ShardGraphView`` and runs the step's own
``ShardSpec.shard_loss`` — the SAME ``propagate_view`` layer functions
the single-device step traces — so kgat, kgcn and kgin (and any future
registered KG arch) share one wrapper.

Two mesh layouts, one wrapper (``model_axis`` selects):

  * **1D ``data=N``** (``model_axis=None``, the PR 3/5 path, unchanged):
    params replicated, gradients mean-reduced over ``data``.
  * **2D ``data×model``** (``model_axis="model"``): parameters the
    step's ``ShardSpec.placement`` marks ROW_SHARDED (the embedding
    tables) enter the body as per-shard row blocks — each device holds
    ``1/M`` of the table. The body uses ``kgnn.Shard2DGraphView``,
    whose ``fetch_rows`` assembles each data shard's dst rows from the
    blocks with one model-axis psum (values bit-exact vs the replicated
    slice), and whose custom VJP reduce-scatters the row gradients
    locally. ``all_reduce_grads`` then runs per-axis: row-shard grads
    psum over ``data`` only, replicated grads over both axes.

Exactness contract (pinned by tests/test_data_parallel.py +
tests/test_mesh2d.py per arch):

  * edge weights are computed ONCE from the layer-0 embeddings;
  * within a shard, edges keep their original relative order, so each
    destination row accumulates in the same order as the unsharded
    ``segment_sum`` — with exact compression and ``compress_grads=False``
    a step is bit-verifiable against the single-device step (forward
    reps bit-exact on 1D AND 2D meshes);
  * with stochastic policies the per-shard quantizers use shard-local
    scales and scope-hashed keys, so the step is not bit-identical but
    every estimator stays unbiased (Proposition 1 per shard + unbiased
    INT8 gradient all-reduce) — the multi-seed mean test pins this.

Per-site ACT policies and stochastic-rounding keys resolve through the
ordinary ``ActContext`` machinery (same ``<arch>/layer<l>/<site>``
scopes as ``propagate``, with the site table supplied by
``ShardSpec.sites``) but are derived OUTSIDE the shard_map body and ride
in as replicated args: closed-over tracers are off-limits inside a body.

Each shard's SPMM gathers only its halo rows (the unique remote sources
``partition_edges`` precomputed) out of the all-gathered table, so the
inner gather/scatter works over ``(h_cap, d)``, not ``(N, d)`` — the
shape the halo-exchange roofline term is priced on. ``act_spmm`` runs
its jnp backend here; the blocked-CSR Pallas path stays single-device
(per-shard layouts have unequal block counts; see DESIGN.md §7.4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import FP32
from repro.core.context import ActContext
from repro.core.policy import as_schedule
from repro.core.rng import scope_key
from repro.data.csr import EdgePartition, partition_edges, row_partition
from repro.models.kgnn import Shard2DGraphView, ShardGraphView
from repro.sharding.compat import P, shard_map
from repro.sharding.mesh_spec import MeshSpec
from repro.training.compress import allreduce_byte_report
from repro.training.step import DPSpec, ModelStep

__all__ = ["partition_graph", "dp_loss_and_grads", "make_dp_step",
           "dp_forward_reps", "pad_row_sharded", "unpad_row_sharded",
           "check_no_sampled_dp"]


def check_no_sampled_dp(batch_or_view, *, mesh_spec: str = "data=N") -> None:
    """Refuse sampled minibatches on the DP path with a NAMED error.

    ``--mesh data=N`` dst-partitions the FULL edge list once at launch;
    a neighbor-sampled batch (``SampledGraphView`` / ``--sample``) has a
    fresh per-hop edge set every step, so the partition, the halo caps
    and the per-shard block layouts are all undefined for it. Until
    sharded sampling lands, the combination must fail loudly here — not
    as a shape mismatch three layers deep in a ``shard_map`` body.
    """
    from repro.models.kgnn import SampledGraphView

    inner = getattr(batch_or_view, "view", None)  # unwrap a SampledItem
    if isinstance(batch_or_view, SampledGraphView) \
            or isinstance(inner, SampledGraphView) or (
            isinstance(batch_or_view, str) and batch_or_view):
        raise NotImplementedError(
            f"sampled minibatch training (--sample) cannot be combined "
            f"with data parallelism (--mesh {mesh_spec}): edges are "
            f"dst-partitioned once at launch, but sampled batches carry "
            f"a fresh per-hop edge set every step. Drop --mesh to train "
            f"sampled on one device, or drop --sample for full-graph "
            f"data parallelism.")


def partition_graph(g, mesh, *, axis: str = "data") -> EdgePartition:
    """``partition_edges`` sized to one mesh axis (edges by dst shard)."""
    import numpy as np

    return partition_edges(
        np.asarray(g.src), np.asarray(g.dst), np.asarray(g.rel),
        n_nodes=g.n_nodes, n_shards=int(mesh.shape[axis]))


def _as_dp_spec(step: ModelStep | DPSpec) -> DPSpec:
    if isinstance(step, DPSpec):
        return step
    if getattr(step, "dp_spec", None) is None:
        arch = getattr(step, "arch", "<unknown>")
        why = getattr(step, "dp_unsupported", None) or \
            "the step registered no ShardSpec"
        raise NotImplementedError(
            f"data parallelism is not implemented for arch {arch!r}: {why}")
    return step.dp_spec


def _site_policies(schedule, spec: DPSpec) -> list[dict]:
    """Per-layer {site: ACTPolicy} via the normal scope-glob resolution."""
    sched = as_schedule(schedule) if schedule is not None else None
    ctx = ActContext(sched)
    out = []
    with ctx, ctx.scope(spec.scope):
        for l in range(spec.n_layers):
            with ctx.scope(f"layer{l}"):
                out.append({
                    site: (ctx.policy_for(kind, ctx.scope_path(site))
                           or FP32)
                    for site, kind in spec.sites})
    return out


def _site_keys(root: jax.Array | None, step_idx, spec: DPSpec) -> list[dict]:
    """Per-layer {site: key}, identical derivation to the ambient context
    (``fold_in(fold_in(root, crc32(scope)), step)``) so a DP step replays
    the same rounding noise as a single-device step at the same scope.
    With no root key (exact-compression runs) every site key is None."""
    if root is None:
        return [{site: None for site, _ in spec.sites}
                for _ in range(spec.n_layers)]
    ctx = ActContext(None, root, step=step_idx)
    out = []
    with ctx, ctx.scope(spec.scope):
        for l in range(spec.n_layers):
            with ctx.scope(f"layer{l}"):
                out.append({site: ctx.key_for(ctx.scope_path(site))
                            for site, _ in spec.sites})
    return out


def _check_contract(part: EdgePartition, mesh, axis: str, batch,
                    root_key, *, need_key: bool) -> None:
    n_shards = int(mesh.shape[axis])
    if part.n_shards != n_shards:
        raise ValueError(
            f"partition built for {part.n_shards} shards, mesh axis "
            f"{axis!r} has {n_shards}")
    if batch is not None:
        b = batch["user"].shape[0]
        if b % n_shards:
            raise ValueError(
                f"batch {b} not divisible by {n_shards} shards")
    if need_key and root_key is None:
        raise ValueError("dp step needs a root key (per-step SR + psum "
                         "compression keys derive from it)")


def _part_leaves(part: EdgePartition) -> dict:
    return {"src_h": part.src_h, "dst_l": part.dst_l,
            "rel": part.rel, "mask": part.mask, "halo": part.halo}


# ---------------------------------------------------------------------------
# 2D row-sharded placement plumbing
# ---------------------------------------------------------------------------


def _spec_row_sharded(spec_or_names) -> tuple:
    if isinstance(spec_or_names, (list, tuple, set, frozenset)):
        return tuple(spec_or_names)
    return _as_dp_spec(spec_or_names).row_sharded()


def _row_geometry(part: EdgePartition, n_model: int):
    """Block geometry of the row-sharded tables on an ``n_model`` axis:
    the padded row space must cover every data shard's dst rows
    (``n_nodes_padded``), so each data shard's contiguous id range has
    an owner."""
    return row_partition(part.n_nodes, n_model, pad_to=part.n_nodes_padded)


def _check_row_sharded(params, sharded, rp, model_axis: str) -> None:
    for name in sharded:
        if name not in params:
            raise ValueError(
                f"ShardSpec places {name!r} on the model axis but params "
                f"has no such top-level entry (have {sorted(params)})")
        leaf = params[name]
        if getattr(leaf, "ndim", 0) < 2:
            raise ValueError(
                f"row-sharded param {name!r} must be a (rows, d) array, "
                f"got ndim={getattr(leaf, 'ndim', None)}")
        if leaf.shape[0] != rp.n_rows_padded:
            raise ValueError(
                f"row-sharded param {name!r} has {leaf.shape[0]} rows; a "
                f"{model_axis}={rp.n_shards} mesh needs {rp.n_rows_padded} "
                f"({rp.n_shards}×{rp.rows_per_shard}) — pad the state with "
                f"pad_row_sharded() before building the step")


def _param_specs(params, sharded, model_axis: str) -> dict:
    """Per-top-level-name in/out specs: row blocks over ``model_axis``,
    everything else replicated (a ``P()`` prefix covers the subtree)."""
    return {name: (P(model_axis, *(None,) * (params[name].ndim - 1))
                   if name in sharded else P())
            for name in params}


def pad_row_sharded(tree, spec_or_names, part: EdgePartition, n_model: int):
    """Zero-pad every row-sharded leaf in ``tree`` to the 2D mesh's
    padded row count (``n_model × rows_per_block``).

    Matches leaves by dict key anywhere in the tree, so one call fixes
    both the params dict and an optimizer state whose moments mirror it
    (adam's ``mu``/``nu``). Padded rows are zero and — because
    ``fetch_rows`` drops their cotangents — receive zero gradient, so
    adam keeps them at zero forever.
    """
    names = set(_spec_row_sharded(spec_or_names))
    if not names or n_model is None:
        return tree
    rp = _row_geometry(part, n_model)

    def fix(path, leaf):
        keys = {k.key for k in path
                if isinstance(k, jax.tree_util.DictKey)}
        if not (keys & names) or getattr(leaf, "ndim", 0) < 2:
            return leaf
        rows = leaf.shape[0]
        if rows == rp.n_rows_padded:
            return leaf
        if rows != part.n_nodes:
            raise ValueError(
                f"row-sharded leaf at {jax.tree_util.keystr(path)} has "
                f"{rows} rows; expected {part.n_nodes} (unpadded) or "
                f"{rp.n_rows_padded} (already padded for model={n_model})")
        pad = [(0, rp.n_rows_padded - rows)] + [(0, 0)] * (leaf.ndim - 1)
        return jnp.pad(leaf, pad)

    return jax.tree_util.tree_map_with_path(fix, tree)


def unpad_row_sharded(tree, spec_or_names, n_rows: int):
    """Inverse of :func:`pad_row_sharded`: slice row-sharded leaves back
    to the real row count (checkpoint gather-back, parity tests)."""
    names = set(_spec_row_sharded(spec_or_names))
    if not names:
        return tree

    def fix(path, leaf):
        keys = {k.key for k in path
                if isinstance(k, jax.tree_util.DictKey)}
        if not (keys & names) or getattr(leaf, "ndim", 0) < 2:
            return leaf
        return leaf[:n_rows]

    return jax.tree_util.tree_map_with_path(fix, tree)


def _make_view(sh, part, axis, model_axis, rp, sharded):
    if model_axis is None:
        return ShardGraphView.from_shard(
            sh, axis=axis, num_rows=part.rows_per_shard,
            n_nodes_padded=part.n_nodes_padded)
    return Shard2DGraphView.from_shard2d(
        sh, axis=axis, num_rows=part.rows_per_shard,
        n_nodes_padded=part.n_nodes_padded, model_axis=model_axis,
        table_rows=rp.rows_per_shard, n_valid_rows=part.n_nodes,
        row_sharded=sharded)


def dp_loss_and_grads(step: ModelStep | DPSpec, params,
                      part: EdgePartition, batch, *, mesh,
                      axis: str = "data", model_axis: str | None = None,
                      schedule=None, root_key: jax.Array | None = None,
                      step_idx=0, compress_grads: bool = True):
    """Sharded step core for any registered KG arch: ``(loss, grads)``.

    ``part`` dst-sharded over ``axis``; ``batch`` (user/pos/neg, each
    divisible by the shard count) sharded over ``axis``. With
    ``model_axis=None`` params are replicated and ``grads`` come back
    replicated — already mean-reduced through the compressed (or exact)
    psum — so the optimizer update stays a plain replicated computation.
    With ``model_axis`` set, ROW_SHARDED params (and their grads) are
    laid out as row blocks over that axis (pad the state with
    :func:`pad_row_sharded` first); the optimizer update still runs
    outside the shard_map — elementwise updates commute with the row
    layout. ``loss`` is the shard-mean of the local objectives (local
    batch BPR + full L2), i.e. the global objective.
    """
    from repro.training.compress import all_reduce_grads

    spec = _as_dp_spec(step)
    _check_contract(part, mesh, axis, batch, root_key, need_key=True)
    sharded = spec.row_sharded() if model_axis is not None else ()
    rp = None
    if model_axis is not None:
        rp = _row_geometry(part, int(mesh.shape[model_axis]))
        _check_row_sharded(params, sharded, rp, model_axis)
    policies = _site_policies(schedule, spec)
    site_keys = _site_keys(root_key, step_idx, spec)
    psum_key = scope_key(root_key, f"{spec.scope}/dp_psum", step_idx)
    axes = (axis, model_axis) if model_axis is not None else axis
    placement = {n: model_axis for n in sharded} or None

    def body(params_, part_leaves, batch_, site_keys_, psum_key_):
        sh = {k: v[0] for k, v in part_leaves.items()}  # (1, …) -> (…)
        view = _make_view(sh, part, axis, model_axis, rp, sharded)

        def loss_fn(p):
            return spec.shard_loss(p, view, batch_, site_keys=site_keys_,
                                   site_policies=policies)

        (total, _), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params_)
        grads = all_reduce_grads(grads, axes, key=psum_key_,
                                 compressed=compress_grads,
                                 placement=placement)
        loss = jax.lax.pmean(total, axis)
        return loss, grads

    param_specs = (P() if model_axis is None
                   else _param_specs(params, sharded, model_axis))
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(param_specs, P(axis), P(axis), P(), P()),
        out_specs=(P(), param_specs))
    return mapped(params, _part_leaves(part), batch, site_keys, psum_key)


def dp_forward_reps(step: ModelStep | DPSpec, params,
                    part: EdgePartition, *, mesh, axis: str = "data",
                    model_axis: str | None = None, schedule=None,
                    root_key: jax.Array | None = None,
                    step_idx=0) -> jax.Array:
    """Readout representations from the sharded forward (parity tests).

    Returns the (n_nodes, D) table — rows beyond ``part.n_nodes`` (node-
    space padding) are dropped. With exact compression this is
    bit-comparable against single-device ``propagate`` on 1D and 2D
    meshes alike (the 2D fetch is one-real-row-plus-zeros psums).
    """
    spec = _as_dp_spec(step)
    if spec.shard_reps is None:
        raise NotImplementedError(f"{spec.scope}: ShardSpec has no "
                                  f"shard_reps")
    _check_contract(part, mesh, axis, None, root_key, need_key=False)
    sharded = spec.row_sharded() if model_axis is not None else ()
    rp = None
    if model_axis is not None:
        rp = _row_geometry(part, int(mesh.shape[model_axis]))
        _check_row_sharded(params, sharded, rp, model_axis)
    policies = _site_policies(schedule, spec)
    site_keys = _site_keys(root_key, step_idx, spec)

    def body(params_, part_leaves, site_keys_):
        sh = {k: v[0] for k, v in part_leaves.items()}
        view = _make_view(sh, part, axis, model_axis, rp, sharded)
        return spec.shard_reps(params_, view, site_keys=site_keys_,
                               site_policies=policies)

    param_specs = (P() if model_axis is None
                   else _param_specs(params, sharded, model_axis))
    mapped = shard_map(body, mesh=mesh,
                       in_specs=(param_specs, P(axis), P()),
                       out_specs=P(axis, None))
    reps = mapped(params, _part_leaves(part), site_keys)
    return reps[:part.n_nodes]


def make_dp_step(step: ModelStep | DPSpec, part: EdgePartition, mesh, opt,
                 *, schedule=None, root_key: jax.Array,
                 axis: str = "data", model_axis: str | None = None,
                 mesh_spec: "MeshSpec | str | None" = None,
                 compress_grads: bool = True):
    """Jitted ``train_step(state, batch, step)`` for ``Trainer``, for any
    KG arch with a ``ShardSpec``.

    One ``shard_map`` spans loss, backward, and the compressed gradient
    all-reduce; the optimizer update runs outside it (replicated params
    update replicated, row-sharded tables update block-wise — adam is
    elementwise, so the update commutes with the layout). Raises
    ``NotImplementedError`` (naming the arch and why) for steps without
    a ``ShardSpec``.

    ``mesh_spec`` (a ``MeshSpec`` or its ``"data=4,model=2"`` string) is
    the launcher-facing way to pick the layout: it is validated against
    ``mesh`` and sets ``axis``/``model_axis`` — a ``model`` axis in the
    spec selects the 2D row-sharded path.
    """
    spec = _as_dp_spec(step)
    if mesh_spec is not None:
        ms = MeshSpec.parse(mesh_spec)
        ms.check_axes(("data", "model"), required=("data",))
        ms.check_mesh(mesh)
        axis = "data"
        model_axis = "model" if "model" in ms.names else None

    # All-reduce byte telemetry: the reduce runs inside jit/shard_map, so
    # it traces ONCE — per-step accounting must live out here. Shapes are
    # static, so the per-step payload is analytic (allreduce_byte_report)
    # and we price it lazily from the first state's params.
    _byte_meters: list = []

    def _init_byte_meters(params):
        from repro.obs import get_registry

        axes = (axis, model_axis) if model_axis is not None else axis
        sharded = spec.row_sharded() if model_axis is not None else ()
        placement = {n: model_axis for n in sharded} or None
        reg = get_registry()
        for row in allreduce_byte_report(params, axes, placement=placement,
                                         compressed=compress_grads):
            labels = dict(arch=spec.scope, axes=row["axes"],
                          wire=row["wire"])
            reg.gauge("allreduce/bytes_per_step", **labels) \
                .set(float(row["bytes"]))
            _byte_meters.append(
                (reg.counter("allreduce/bytes", **labels), row["bytes"]))

    def train_step(state, batch, step_idx):
        check_no_sampled_dp(batch)
        if not _byte_meters:
            _init_byte_meters(state[0])
        for ctr, nbytes in _byte_meters:
            ctr.inc(nbytes)
        return _jit_step(state, batch, step_idx)

    @jax.jit
    def _jit_step(state, batch, step_idx):
        params, opt_state = state
        loss, grads = dp_loss_and_grads(
            spec, params, part, batch, mesh=mesh, axis=axis,
            model_axis=model_axis, schedule=schedule, root_key=root_key,
            step_idx=step_idx, compress_grads=compress_grads)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), {"loss": loss}

    return train_step
