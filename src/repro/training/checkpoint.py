"""Fault-tolerant checkpointing (no orbax available — built from scratch).

Layout per step:  <dir>/step_<N>/arrays.npz + manifest.json
  * atomic: written to ``step_<N>.tmp`` then os.rename'd — a crash mid-save
    never corrupts the latest good checkpoint
  * keep-last-k garbage collection
  * optional async save on a background thread (training continues while
    the previous step serializes)
  * restore places leaves onto the shardings of a caller-provided template
    (so a checkpoint written on one mesh restores onto another — the
    elastic re-mesh path; leaves are full logical arrays, resharding is a
    device_put)
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "check_meta", "CheckpointManager"]


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:010d}")


def save_checkpoint(base: str, step: int, tree, *, keep: int = 3,
                    meta: dict | None = None) -> str:
    """Synchronous atomic save. Returns the checkpoint directory.

    ``meta`` is an arbitrary JSON-serializable identity dict (arch id,
    schedule spec, ... — see ``repro.training.step.step_metadata``)
    stored in the manifest; ``restore_checkpoint`` refuses checkpoints
    whose stored identity contradicts the expected one.
    """
    os.makedirs(base, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    final = _step_dir(base, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
    }
    if meta is not None:
        manifest["meta"] = meta
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(base, keep)
    return final


def _gc(base: str, keep: int) -> None:
    steps = sorted(_list_steps(base))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(base, s), ignore_errors=True)


def _list_steps(base: str) -> list[int]:
    if not os.path.isdir(base):
        return []
    out = []
    for name in os.listdir(base):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(base, name, "manifest.json")):
                out.append(int(name[5:]))
    return out


def latest_step(base: str) -> int | None:
    steps = _list_steps(base)
    return max(steps) if steps else None


def check_meta(stored: dict | None, expected: dict | None,
               where: str = "") -> None:
    """Refuse a checkpoint whose stored identity contradicts the run's.

    Only keys present in BOTH dicts are compared (legacy checkpoints
    without metadata restore as before; extra keys on either side are
    informational, not contractual).
    """
    if not stored or not expected:
        return
    bad = {k: (stored[k], expected[k]) for k in stored
           if k in expected and stored[k] != expected[k]}
    if bad:
        detail = ", ".join(f"{k}: checkpoint={s!r} run={e!r}"
                           for k, (s, e) in sorted(bad.items()))
        hint = ""
        if "mesh" in bad or "placement" in bad:
            # a topology mismatch has a sanctioned migration path; name it
            hint = (" (for a mesh/placement change, launch.train's "
                    "--reshard-from gathers the old layout onto the new "
                    "mesh instead of resuming in place)")
        raise ValueError(
            f"checkpoint{' at ' + where if where else ''} was written for "
            f"a different run ({detail}); refusing a silent mismatch — "
            "point --ckpt at a fresh directory or match the original "
            "arch/schedule" + hint)


def restore_checkpoint(base: str, template, *, step: int | None = None,
                       expect_meta: dict | None = None):
    """Restore onto ``template``'s structure/dtypes/shardings.

    Returns (step, tree) or (None, template) when no checkpoint exists.
    ``expect_meta`` (arch id, schedule spec, ...) is validated against
    the manifest's stored metadata via ``check_meta``.
    """
    if step is None:
        step = latest_step(base)
    if step is None:
        return None, template
    d = _step_dir(base, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    check_meta(manifest.get("meta"), expect_meta, where=d)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    assert len(leaves) == len(t_leaves), (
        f"checkpoint has {len(leaves)} leaves, template {len(t_leaves)}")
    placed = []
    for arr, t in zip(leaves, t_leaves):
        arr = arr.astype(t.dtype)
        if hasattr(t, "sharding") and t.sharding is not None:
            placed.append(jax.device_put(arr, t.sharding))
        else:
            placed.append(jax.device_put(arr))
    return step, jax.tree_util.tree_unflatten(treedef, placed)


class CheckpointManager:
    """Async keep-k checkpointing with a single background writer thread.

    ``meta`` (e.g. ``step_metadata(step, schedule_spec)``) is stamped
    into every save and enforced on every restore, so a checkpoint
    written for one arch/schedule can't silently resume another.
    """

    def __init__(self, base: str, *, keep: int = 3,
                 asynchronous: bool = True, meta: dict | None = None):
        self.base = base
        self.keep = keep
        self.asynchronous = asynchronous
        self.meta = meta
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree) -> None:
        # materialize on host BEFORE handing to the thread so training can
        # donate/overwrite device buffers immediately
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self.wait()
        if self.asynchronous:
            self._thread = threading.Thread(
                target=save_checkpoint, args=(self.base, step, host_tree),
                kwargs={"keep": self.keep, "meta": self.meta}, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.base, step, host_tree, keep=self.keep,
                            meta=self.meta)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template, *, step: int | None = None):
        self.wait()
        return restore_checkpoint(self.base, template, step=step,
                                  expect_meta=self.meta)
