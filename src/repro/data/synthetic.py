"""Synthetic dataset generators (offline container — no downloads).

Each generator plants a *learnable* signal so accuracy benchmarks measure
real optimization, not noise:

  * ``gen_kg_dataset``  — latent-factor user/item affinities + a KG whose
    relations link items sharing latent factors (so KG message passing
    genuinely helps, mirroring the paper's setting); Zipf popularity.
  * ``lm_batches``      — noisy affine-bigram language (next = a·prev+c
    mod V with ε-noise): a 2-layer LM drops loss fast, fixed point known.
  * ``criteo_batches``  — planted sparse-logistic CTR with Zipf ids.
  * ``cora_like``       — class-conditional Gaussian features + homophilous
    edges (GCN separates classes well above chance).

All numpy-based (host-side, like a real input pipeline), deterministic by
seed, emitting device-ready dict batches.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.kgnn import CKG

__all__ = ["KGDataset", "gen_kg_dataset", "gen_zipf_kg_dataset",
           "bpr_batches", "lm_batches", "criteo_batches", "cora_like"]


@dataclasses.dataclass
class KGDataset:
    graph: CKG
    n_users: int
    n_items: int
    n_entities: int           # items + attributes
    n_relations: int
    train_pos: np.ndarray     # (n_train, 2) user, item
    test_pos: np.ndarray      # (n_test, 2)

    def interaction_matrices(self):
        """Dense bool (U, I) train/test matrices for Recall/NDCG eval."""
        tr = np.zeros((self.n_users, self.n_items), bool)
        te = np.zeros((self.n_users, self.n_items), bool)
        tr[self.train_pos[:, 0], self.train_pos[:, 1]] = True
        te[self.test_pos[:, 0], self.test_pos[:, 1]] = True
        return tr, te


def gen_kg_dataset(*, n_users=200, n_items=300, n_attrs=150, n_relations=6,
                   n_triples=2000, inter_per_user=20, d_latent=8,
                   test_frac=0.2, seed=0) -> KGDataset:
    """User-item interactions + item KG with shared latent structure."""
    rng = np.random.default_rng(seed)
    u_lat = rng.normal(size=(n_users, d_latent)).astype(np.float32)
    i_lat = rng.normal(size=(n_items, d_latent)).astype(np.float32)
    a_lat = rng.normal(size=(n_attrs, d_latent)).astype(np.float32)

    if n_users * n_items <= 4_000_000:
        # small graphs (benchmarks): exact per-user top items
        scores = u_lat @ i_lat.T \
            + 0.5 * rng.gumbel(size=(n_users, n_items)).astype(np.float32)
        items = np.argsort(-scores, axis=1)[:, :inter_per_user]
        inter = np.stack([
            np.repeat(np.arange(n_users), inter_per_user),
            items.reshape(-1)], axis=1).astype(np.int64)
    else:
        # large graphs (100M-param example): per-user top items among a
        # candidate sample, chunked — the dense users×items score matrix
        # would be O(100 GB)
        n_cand = min(max(8 * inter_per_user, 64), n_items)
        chunk = max(1, min(n_users, (1 << 22) // n_cand))
        inter_u, inter_i = [], []
        for u0 in range(0, n_users, chunk):
            u1 = min(u0 + chunk, n_users)
            cand = rng.integers(0, n_items, (u1 - u0, n_cand))
            scores = np.einsum("ud,ucd->uc", u_lat[u0:u1], i_lat[cand]) \
                + 0.5 * rng.gumbel(
                    size=(u1 - u0, n_cand)).astype(np.float32)
            top = np.argpartition(-scores, inter_per_user - 1,
                                  axis=1)[:, :inter_per_user]
            inter_u.append(np.repeat(np.arange(u0, u1), inter_per_user))
            inter_i.append(np.take_along_axis(cand, top, axis=1).reshape(-1))
        inter = np.stack([np.concatenate(inter_u),
                          np.concatenate(inter_i)], axis=1).astype(np.int64)
        inter = np.unique(inter, axis=0)  # candidate sampling can repeat
    rng.shuffle(inter)
    n_test = int(len(inter) * test_frac)
    test_pos, train_pos = inter[:n_test], inter[n_test:]

    # KG triples: relation r links item->attr when their latents align on
    # a relation-specific direction (so relations carry signal)
    rel_dirs = rng.normal(size=(n_relations, d_latent))
    heads = rng.integers(0, n_items, n_triples)
    rels = rng.integers(0, n_relations, n_triples)
    # pick tail attr maximizing alignment among a small candidate set
    cand = rng.integers(0, n_attrs, (n_triples, 8))
    align = np.einsum("td,tcd->tc", i_lat[heads] * rel_dirs[rels],
                      a_lat[cand])
    tails = cand[np.arange(n_triples), np.argmax(align, 1)]

    # CKG node space: [users | items | attrs]
    n_entities = n_items + n_attrs
    src_list, dst_list, rel_list = [], [], []
    # interact relation = 0 (both directions); KG relations shifted by 1
    u_nodes = train_pos[:, 0]
    i_nodes = n_users + train_pos[:, 1]
    src_list += [u_nodes, i_nodes]
    dst_list += [i_nodes, u_nodes]
    rel_list += [np.zeros(len(train_pos), np.int64)] * 2
    h_nodes = n_users + heads
    t_nodes = n_users + n_items + tails
    src_list += [h_nodes, t_nodes]
    dst_list += [t_nodes, h_nodes]
    rel_list += [rels + 1, rels + 1 + n_relations]  # inverse rels distinct
    # self loops (relation id = last)
    n_nodes = n_users + n_entities
    loops = np.arange(n_nodes)
    src_list.append(loops)
    dst_list.append(loops)
    rel_list.append(np.full(n_nodes, 2 * n_relations + 1, np.int64))

    graph = CKG(
        src=np.concatenate(src_list).astype(np.int32),
        dst=np.concatenate(dst_list).astype(np.int32),
        rel=np.concatenate(rel_list).astype(np.int32),
        n_nodes=n_nodes,
        n_relations=2 * n_relations + 2,
    )
    return KGDataset(graph, n_users, n_items, n_entities,
                     graph.n_relations, train_pos, test_pos)


def gen_zipf_kg_dataset(*, n_users=300, n_items=500, n_attrs=200,
                        n_relations=6, n_triples=6000, inter_per_user=20,
                        zipf_a=1.1, test_frac=0.2, seed=0) -> KGDataset:
    """KG with Zipf-skewed in-degree — the data-tiering setting.

    Item/attr popularity follows a power law (``p(rank) ∝ rank^-a``), so
    a small fraction of entity rows receives most neighbor-sample
    requests; this is the graph the hot/cold tier cache is benchmarked
    on (hit rate ≥ 80% at ``hot_frac=0.1``). Same node space and
    relation layout as ``gen_kg_dataset``:
    ``[users | items | attrs]``, interact=0 both ways, KG relations
    shifted (+inverse), self-loops last.
    """
    rng = np.random.default_rng(seed)

    def zipf_choice(n, size):
        p = 1.0 / np.arange(1, n + 1) ** zipf_a
        return rng.choice(n, size=size, p=p / p.sum())

    inter = np.stack([
        np.repeat(np.arange(n_users), inter_per_user),
        zipf_choice(n_items, n_users * inter_per_user)], axis=1)
    inter = np.unique(inter.astype(np.int64), axis=0)
    rng.shuffle(inter)
    n_test = int(len(inter) * test_frac)
    test_pos, train_pos = inter[:n_test], inter[n_test:]

    heads = zipf_choice(n_items, n_triples).astype(np.int64)
    rels = rng.integers(0, n_relations, n_triples)
    tails = zipf_choice(n_attrs, n_triples).astype(np.int64)

    n_entities = n_items + n_attrs
    n_nodes = n_users + n_entities
    u_nodes, i_nodes = train_pos[:, 0], n_users + train_pos[:, 1]
    h_nodes, t_nodes = n_users + heads, n_users + n_items + tails
    loops = np.arange(n_nodes)
    graph = CKG(
        src=np.concatenate([u_nodes, i_nodes, h_nodes, t_nodes,
                            loops]).astype(np.int32),
        dst=np.concatenate([i_nodes, u_nodes, t_nodes, h_nodes,
                            loops]).astype(np.int32),
        rel=np.concatenate([
            np.zeros(2 * len(train_pos), np.int64), rels + 1,
            rels + 1 + n_relations,
            np.full(n_nodes, 2 * n_relations + 1)]).astype(np.int32),
        n_nodes=n_nodes, n_relations=2 * n_relations + 2)
    return KGDataset(graph, n_users, n_items, n_entities,
                     graph.n_relations, train_pos, test_pos)


def bpr_batches(ds: KGDataset, batch_size: int, *, seed=0):
    """Infinite (user, pos, neg) sampler with rejection on train positives."""
    rng = np.random.default_rng(seed)
    pos_set = set(map(tuple, ds.train_pos))
    n = len(ds.train_pos)
    while True:
        idx = rng.integers(0, n, batch_size)
        users = ds.train_pos[idx, 0]
        pos = ds.train_pos[idx, 1]
        neg = rng.integers(0, ds.n_items, batch_size)
        for i in range(batch_size):  # cheap rejection (sparse interactions)
            while (users[i], neg[i]) in pos_set:
                neg[i] = rng.integers(0, ds.n_items)
        yield {"user": users.astype(np.int32), "pos": pos.astype(np.int32),
               "neg": neg.astype(np.int32)}


def lm_batches(*, vocab: int, batch: int, seq: int, seed=0,
               noise: float = 0.1):
    """Noisy affine-bigram token stream: next = (a·prev + c) mod V w.p. 1-ε."""
    rng = np.random.default_rng(seed)
    a, c = 31, 7
    while True:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, batch)
        for t in range(1, seq + 1):
            nxt = (a * toks[:, t - 1] + c) % vocab
            flip = rng.random(batch) < noise
            nxt = np.where(flip, rng.integers(0, vocab, batch), nxt)
            toks[:, t] = nxt
        yield {"tokens": toks}


def criteo_batches(*, batch: int, n_dense: int, vocab_sizes, seed=0,
                   zipf_a: float = 1.2):
    """Planted-logistic CTR batches with Zipf-distributed categorical ids."""
    rng = np.random.default_rng(seed)
    vocab_sizes = np.asarray(vocab_sizes)
    F = len(vocab_sizes)
    w_dense = rng.normal(size=n_dense) * 0.5
    # planted per-field hash weights (cheap stand-in for per-id weights)
    w_field = rng.normal(size=(F, 64)) * 0.6
    while True:
        dense = rng.lognormal(0.0, 1.0, (batch, n_dense)).astype(np.float32)
        dense = np.log1p(dense)
        sparse = np.empty((batch, F), np.int64)
        for f, v in enumerate(vocab_sizes):
            z = rng.zipf(zipf_a, batch)
            sparse[:, f] = np.minimum(z - 1, v - 1)
        logit = dense @ w_dense + sum(
            w_field[f, sparse[:, f] % 64] for f in range(F))
        prob = 1 / (1 + np.exp(-(logit - logit.mean())))
        labels = (rng.random(batch) < prob).astype(np.float32)
        yield {"sparse": sparse.astype(np.int32), "dense": dense,
               "label": labels}


def cora_like(*, n_nodes=500, d_feat=64, n_classes=7, avg_deg=4, seed=0):
    """Homophilous graph with class-Gaussian features (+ self loops)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes)
    centers = rng.normal(size=(n_classes, d_feat)) * 2.0
    feats = centers[labels] + rng.normal(size=(n_nodes, d_feat))
    n_edges = n_nodes * avg_deg // 2
    src = rng.integers(0, n_nodes, 4 * n_edges)
    dst = rng.integers(0, n_nodes, 4 * n_edges)
    same = labels[src] == labels[dst]
    keep = same | (rng.random(4 * n_edges) < 0.15)  # mostly homophilous
    src, dst = src[keep][:n_edges], dst[keep][:n_edges]
    src_all = np.concatenate([src, dst, np.arange(n_nodes)])
    dst_all = np.concatenate([dst, src, np.arange(n_nodes)])
    return (feats.astype(np.float32), src_all.astype(np.int32),
            dst_all.astype(np.int32), labels.astype(np.int32))
