"""KG-aware neighbor-sampled minibatches with blocked-CSR hop layouts.

The full-graph training path requires the whole entity table and edge
set on one device — the exact ceiling TinyKG's activation compression
was meant to lift for industry-scale graphs. This module removes it for
every registered KG arch at once (DESIGN.md §11):

  * ``build_kg_csr`` — one-time host CSR over incoming edges, carrying
    relation ids (the KG-aware extension of ``data/sampler.py``);
  * ``sample_kg_blocks`` — per-hop fanout sampling that emits
    ``models.kgnn.BlockView`` bipartite blocks with STATIC padded
    shapes, honoring the **seeds-prefix invariant**: each hop's
    destination frontier is the leading prefix of its source frontier,
    so block-local indices are simultaneously valid positions into the
    outermost gathered table (per-hop KGAT/KGCN edge weights stay
    once-from-layer-0) and seed rows are ``[:n_seeds]`` of every layer
    output — concat readout works unchanged;
  * per-hop **blocked-CSR layouts** (``data/csr.py`` with
    ``pad_static=True``) whose geometry depends only on the static
    block shape, so the fused Pallas SPMM and ACT compression run
    unchanged on sampled subgraphs without retracing;
  * ``MinibatchStream`` — a background-thread pipeline (bounded queue,
    clean shutdown, in the style of ``trainer.PrefetchIterator``) that
    pairs BPR batches with freshly sampled blocks so host-side sampling
    overlaps device compute.

Sampling semantics: per destination node, ``fanout`` incoming edges are
drawn **with replacement** when the in-degree exceeds the fanout, and
taken exactly (without replacement, remainder masked) otherwise — so a
fanout at least the max in-degree reproduces the full neighborhood
exactly, which is what the gradient-parity tests pin. All our KG
aggregations normalize per destination (edge softmax or degree mean),
so masked uniform sampling keeps the neighbor-mean estimator unbiased;
softmax attention over a sampled subset is the standard
sampled-softmax approximation (see the DESIGN.md §11 exactness ledger).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.models.kgnn import BlockView, SampledGraphView

__all__ = ["KGAdjacency", "build_kg_csr", "sample_kg_blocks",
           "SampledItem", "sampled_items", "MinibatchStream",
           "parse_fanouts"]


@dataclasses.dataclass(frozen=True)
class KGAdjacency:
    """CSR over incoming edges: for each dst node its (src, rel) pairs."""

    indptr: np.ndarray    # (n_nodes + 1,) int64
    src: np.ndarray       # (E,) int64 source node per slot, dst-sorted
    rel: np.ndarray       # (E,) int64 relation id per slot
    n_nodes: int

    @property
    def max_in_degree(self) -> int:
        return int(np.max(self.indptr[1:] - self.indptr[:-1], initial=0))


def build_kg_csr(src, dst, rel, n_nodes: int) -> KGAdjacency:
    """Host-side CSR (incoming edges, relation ids along for the ride)."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    rel = np.asarray(rel, np.int64)
    order = np.argsort(dst, kind="stable")
    counts = np.bincount(dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return KGAdjacency(indptr=indptr, src=src[order], rel=rel[order],
                      n_nodes=n_nodes)


def parse_fanouts(spec: str) -> tuple[int, ...]:
    """``"fanout=15,10"`` or ``"15,10"`` -> ``(15, 10)``."""
    body = spec.split("=", 1)[1] if "=" in spec else spec
    try:
        fanouts = tuple(int(x) for x in body.split(",") if x)
        if not fanouts or any(f < 1 for f in fanouts):
            raise ValueError
    except ValueError:
        raise ValueError(
            f"--sample expects fanout=F1,F2,... (one positive fanout per "
            f"layer, seed-adjacent hop first), got {spec!r}")
    return fanouts


def _one_hop(adj: KGAdjacency, frontier: np.ndarray, fanout: int,
             rng: np.random.Generator):
    """Sample one hop. Returns (nbr, rel, mask) each (n_dst, fanout)."""
    n_dst = len(frontier)
    deg = adj.indptr[frontier + 1] - adj.indptr[frontier]
    ar = np.arange(fanout)[None, :]
    # always draw, for stream determinism independent of degree layout
    drawn = rng.integers(0, np.maximum(deg, 1)[:, None], (n_dst, fanout))
    exact = deg[:, None] <= fanout
    offs = np.where(exact, np.minimum(ar, np.maximum(deg - 1, 0)[:, None]),
                    drawn)
    mask = np.where(exact, ar < deg[:, None], True)
    e_ix = np.minimum(adj.indptr[frontier][:, None] + offs,
                      len(adj.src) - 1)
    nbr = adj.src[e_ix]
    rel = adj.rel[e_ix]
    # masked slots become weight-0 self-edges: their endpoint MUST be a
    # member of the next frontier, and the dst's own id always is
    nbr = np.where(mask, nbr, frontier[:, None])
    rel = np.where(mask, rel, 0)
    return nbr, rel, mask


def _extend_frontier(frontier: np.ndarray, nbr: np.ndarray,
                     mask: np.ndarray, fanout: int) -> np.ndarray:
    """Next frontier ``[frontier | new unique neighbors | pad]`` with a
    static length ``len(frontier) * (fanout + 1)``; order-preserving
    dedup keeps the seeds-prefix invariant, pads cycle frontier ids."""
    cand = nbr.reshape(-1)[mask.reshape(-1)]
    cand = cand[~np.isin(cand, frontier)]
    _, first = np.unique(cand, return_index=True)
    new = cand[np.sort(first)]
    n_src = len(frontier) * (fanout + 1)
    pad = n_src - len(frontier) - len(new)
    return np.concatenate([frontier, new, np.resize(frontier, pad)]) \
        if pad else np.concatenate([frontier, new])


def sample_kg_blocks(adj: KGAdjacency, seeds: np.ndarray,
                     fanouts: tuple[int, ...], *,
                     rng: np.random.Generator, build_layouts: bool = False,
                     block_e: int = 256, block_rows: int = 256):
    """Sample ``len(fanouts)`` hops outward from ``seeds``.

    Returns ``(view, input_nodes, requests)``: a ``SampledGraphView``
    whose blocks are in EXECUTION order (outermost hop first — what
    layer 0 consumes), the outermost frontier's global node ids (the
    rows the tier cache must resolve), and the row-access stream WITH
    multiplicity (seeds + every real edge draw — what LFU frequency
    ranking and hit-rate accounting are measured over; the padded
    frontier would drown the signal in cycled duplicates on small
    graphs). ``fanouts`` are listed seed-outward:
    ``fanouts[0]`` is the hop adjacent to the seeds, consumed by the
    LAST layer. With ``build_layouts`` each block carries a
    static-geometry blocked-CSR ``SpmmLayout`` for the fused Pallas
    SPMM (``csr.build_spmm_layout(pad_static=True)``).
    """
    import jax.numpy as jnp

    from repro.data.csr import build_spmm_layout

    seeds = np.asarray(seeds, np.int64)
    if seeds.ndim != 1 or not len(seeds):
        raise ValueError(f"seeds must be a non-empty 1-D id array, "
                         f"got shape {seeds.shape}")
    if seeds.size and (seeds.min() < 0 or seeds.max() >= adj.n_nodes):
        raise ValueError(
            f"seed ids outside [0, {adj.n_nodes}): "
            f"[{seeds.min()}, {seeds.max()}]")
    blocks = []
    requests = [seeds]  # true row-access stream: seeds + real edge draws
    frontier = seeds
    for fanout in fanouts:
        n_dst = len(frontier)
        nbr, rel, mask = _one_hop(adj, frontier, fanout, rng)
        requests.append(nbr.reshape(-1)[mask.reshape(-1)])
        nxt = _extend_frontier(frontier, nbr, mask, fanout)
        # first occurrence position of every id present in nxt
        uq, first_pos = np.unique(nxt, return_index=True)
        e_src = first_pos[np.searchsorted(uq, nbr.reshape(-1))]
        e_dst = np.repeat(np.arange(n_dst, dtype=np.int64), fanout)
        layout = build_spmm_layout(
            e_src, e_dst, n_dst=n_dst, n_src=len(nxt),
            block_e=block_e, block_rows=block_rows, pad_static=True) \
            if build_layouts else None
        blocks.append(BlockView(
            src=jnp.asarray(e_src, jnp.int32),
            dst=jnp.asarray(e_dst, jnp.int32),
            rel=jnp.asarray(rel.reshape(-1), jnp.int32),
            mask=jnp.asarray(mask.reshape(-1), jnp.float32),
            layout=layout, n_src=len(nxt), n_dst=n_dst))
        frontier = nxt
    blocks.reverse()  # outermost hop first = execution order for layer 0
    return (SampledGraphView(blocks=tuple(blocks), n_seeds=len(seeds)),
            frontier, np.concatenate(requests))


# ---------------------------------------------------------------------------
# streaming loader
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SampledItem:
    """One prepared minibatch: blocks + the rows the tier cache must
    resolve. Seeds are packed ``[user nodes | pos item nodes | neg item
    nodes]`` (each a third), matching ``kgnn.sampled_bpr_loss``."""

    view: SampledGraphView
    input_nodes: np.ndarray    # (n_input_rows,) global entity ids
    requests: np.ndarray       # row-access stream with multiplicity
    batch: dict                # the raw BPR batch (user/pos/neg)
    index: int                 # stream position, for logging/replay


def sampled_items(ds, fanouts: tuple[int, ...], *, batch_size: int,
                  seed: int = 0, build_layouts: bool = False,
                  block_e: int = 256, block_rows: int = 256) -> Iterator:
    """Infinite deterministic stream of ``SampledItem``s for a
    ``KGDataset`` — BPR batch sampling and block sampling share one
    seeded generator, so a stream is replay-exact given its seed."""
    from repro.data.synthetic import bpr_batches

    g = ds.graph
    adj = build_kg_csr(np.asarray(g.src), np.asarray(g.dst),
                       np.asarray(g.rel), g.n_nodes)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xB10C]))
    for i, batch in enumerate(bpr_batches(ds, batch_size, seed=seed)):
        seeds = np.concatenate([
            batch["user"].astype(np.int64),
            ds.n_users + batch["pos"].astype(np.int64),
            ds.n_users + batch["neg"].astype(np.int64)])
        view, input_nodes, requests = sample_kg_blocks(
            adj, seeds, fanouts, rng=rng, build_layouts=build_layouts,
            block_e=block_e, block_rows=block_rows)
        yield SampledItem(view=view, input_nodes=input_nodes,
                          requests=requests, batch=batch, index=i)


class MinibatchStream:
    """Background-thread minibatch pipeline with bounded queue and clean
    shutdown — ``PrefetchIterator`` machinery applied to the sampler, so
    CSR traversal / dedup / layout construction overlap device compute.
    """

    def __init__(self, ds, fanouts: tuple[int, ...], *, batch_size: int,
                 seed: int = 0, build_layouts: bool = False,
                 block_e: int = 256, block_rows: int = 256,
                 depth: int = 2, timeout_s: float = 60.0):
        from repro.training.trainer import PrefetchIterator

        self.fanouts = tuple(fanouts)
        self._pf = PrefetchIterator(
            sampled_items(ds, self.fanouts, batch_size=batch_size,
                          seed=seed, build_layouts=build_layouts,
                          block_e=block_e, block_rows=block_rows),
            depth=depth, timeout_s=timeout_s)

    def next(self) -> SampledItem:
        return self._pf.next()

    def close(self) -> None:
        self._pf.close()

    def __enter__(self) -> "MinibatchStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
