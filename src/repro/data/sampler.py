"""Fanout neighbor sampler (GraphSAGE-style) — the ``minibatch_lg`` path.

Host-side numpy over a CSR adjacency; emits per-hop "blocks" with STATIC
shapes (padded with self-loops) so the jitted train step never retraces:

  block h: src set  = frontier ∪ sampled neighbors   (n_dst * (fanout+1))
           edges    = (local_src -> local_dst)
  outermost block first; features are gathered for the outermost src set.

This is a genuine production component: sampling 1024 seeds with fanout
15-10 touches ~170k nodes of a 233M-edge graph per step.
"""

from __future__ import annotations

import numpy as np

__all__ = ["build_csr", "sample_blocks"]


def build_csr(src: np.ndarray, dst: np.ndarray, n_nodes: int):
    """CSR over incoming edges: for each dst node, its src neighbors."""
    order = np.argsort(dst, kind="stable")
    src_sorted = src[order]
    counts = np.bincount(dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, src_sorted


def sample_blocks(indptr: np.ndarray, indices: np.ndarray,
                  seeds: np.ndarray, fanouts: list[int], *,
                  rng: np.random.Generator):
    """Returns (blocks, input_nodes). blocks[0] is the outermost hop.

    Each block dict: src, dst (int32 local edge endpoints), n_src, n_dst
    (static), plus 'src_nodes'/'dst_nodes' global id arrays (padded by
    repeating the node itself — self-loop padding keeps means unbiased
    enough and shapes static).
    """
    blocks = []
    frontier = seeds.astype(np.int64)
    for fanout in fanouts:
        n_dst = len(frontier)
        # sample `fanout` in-neighbors per frontier node (with replacement;
        # isolated nodes self-loop)
        deg = indptr[frontier + 1] - indptr[frontier]
        offs = rng.integers(0, np.maximum(deg, 1)[:, None],
                            (n_dst, fanout))
        nbr = indices[np.minimum(indptr[frontier, None] + offs,
                                 len(indices) - 1)]
        nbr = np.where(deg[:, None] > 0, nbr, frontier[:, None])
        # src node set = frontier (self) + sampled neighbors, deduped but
        # PADDED back to static size n_dst*(fanout+1)
        src_nodes = np.concatenate([frontier, nbr.reshape(-1)])
        uniq, inv = np.unique(src_nodes, return_inverse=True)
        n_src_static = n_dst * (fanout + 1)
        pad = n_src_static - len(uniq)
        # pad by cycling the FRONTIER's own node ids. The old
        # ``np.full(pad, uniq[0])`` repeated whichever node happened to
        # have the smallest global id — when a zero-degree seed
        # contributed only its self-loop, that id need not be a frontier
        # member at all, breaking the "pad = the node itself" self-loop
        # semantics the docstring promises. Frontier ids are always
        # legitimate members of the next hop's node set.
        src_nodes_padded = np.concatenate(
            [uniq, np.resize(frontier, pad) if pad else
             np.empty(0, np.int64)])
        # edges: neighbor j of frontier i -> edge (local(nbr), i); plus self
        loc_nbr = inv[n_dst:].reshape(n_dst, fanout)
        loc_self = inv[:n_dst]
        e_src = np.concatenate([loc_self, loc_nbr.reshape(-1)])
        e_dst = np.concatenate([np.arange(n_dst),
                                np.repeat(np.arange(n_dst), fanout)])
        blocks.append({
            "src": e_src.astype(np.int32),
            "dst": e_dst.astype(np.int32),
            "n_src": n_src_static,
            "n_dst": n_dst,
            "src_nodes": src_nodes_padded,
            "dst_nodes": frontier.copy(),
        })
        frontier = src_nodes_padded
    blocks.reverse()  # outermost hop first (matches gcn_forward_blocks)
    return blocks, frontier
