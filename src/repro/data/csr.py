"""Blocked-CSR edge layout for the fused Pallas SPMM kernels.

The COO path (``x[src] * ew -> segment_sum``) materializes the full
``(E, d)`` message tensor in HBM twice per step (forward messages,
backward ``g[dst]``). The fused kernels in ``repro.kernels.spmm`` never
form it — but they need edges pre-arranged so that each kernel grid step
touches one destination tile. That arrangement is this module's job, done
once per graph on the host (numpy), like any real input pipeline.

Construction (see DESIGN.md §4):

1. **Stable-sort edges by destination.** Per-destination contributions
   keep their original relative order, so the kernel walks each
   destination's edges in the same order as the COO ``segment_sum``
   reference (exact agreement up to fp32 reduction associativity inside
   a block's dot product).
2. **Tile destinations** into blocks of ``block_rows`` rows. Each tile's
   run of sorted edges is padded up to a multiple of ``block_e`` slots;
   tiles with no edges get one all-pad block so every output tile is
   initialized by exactly one contiguous run of grid steps (the Pallas
   output-revisiting contract).
3. **Emit per-slot arrays** reshaped ``(n_blocks, block_e)`` — 2-D so TPU
   BlockSpecs tile them directly — plus ``tile_of_blk``, the per-block
   destination-tile id that rides in SMEM via scalar prefetch and steers
   the output index map.

Pad slots carry ``perm = n_edges`` (one past the last real edge), so a
single gather from ``append(ew, 0)`` both permutes edge weights into slot
order and zeroes pad lanes; scatters of per-slot results through ``perm``
with out-of-bounds drop discard pad contributions for free.

The same machinery, run on the reversed edges, yields the **transpose
layout** that the backward scatter (``∇x = Aᵀ(g · ew)``) uses — one kernel
serves both directions.

``SpmmLayout`` is a registered pytree (arrays are children, the
``CSRMeta`` block geometry is hashable aux data) so it rides through
``jax.jit`` / ``grad`` untouched.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CSRMeta", "SpmmLayout", "build_spmm_layout", "attach_layout",
           "maybe_attach_layout", "static_block_caps", "EdgePartition",
           "partition_edges", "unpartition_edges",
           "RowPartition", "row_partition"]

# KGNN propagation rules that aggregate through act_spmm (and therefore
# benefit from a blocked-CSR layout). KGIN/R-GCN modulate messages with
# per-edge *vectors* and aggregate via raw segment_sum — a layout would
# be dead weight there.
SPMM_MODELS = ("kgat", "kgcn")


@dataclasses.dataclass(frozen=True)
class CSRMeta:
    """Static block geometry — pytree aux data, hashable under jit."""

    n_src: int        # rows of the gathered-from table (x fwd, g bwd)
    n_dst: int        # output segment count of the forward aggregation
    n_edges: int      # real (unpadded) edge count E
    block_e: int      # edge slots per block
    block_rows: int   # destination rows per output tile
    n_blocks: int     # forward-direction edge blocks (incl. pad blocks)
    n_tiles: int      # forward-direction destination tiles
    t_n_blocks: int   # transpose-direction edge blocks
    t_n_tiles: int    # transpose-direction tiles (cover n_src rows)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SpmmLayout:
    """Blocked-CSR slots for one graph, forward + transpose directions.

    All arrays int32. ``*_blk`` arrays are ``(n_blocks, block_e)``; pad
    slots hold gather-index 0 / local-row 0 / perm ``n_edges``.
    """

    # forward direction: edges stable-sorted by dst
    src_blk: jax.Array    # global src id per slot — gather rows of x
    dstg_blk: jax.Array   # global dst id per slot — gather rows of g (SDDMM)
    ldst_blk: jax.Array   # dst id local to its tile — in-kernel one-hot row
    perm_blk: jax.Array   # original edge index per slot; n_edges for pads
    tile_of_blk: jax.Array  # (n_blocks,) destination tile per edge block
    # transpose direction: edges stable-sorted by src (drives ∇x)
    t_src_blk: jax.Array    # global dst id per slot — gather rows of g
    t_ldst_blk: jax.Array   # src id local to its tile
    t_perm_blk: jax.Array   # original edge index per slot
    t_tile_of_blk: jax.Array  # (t_n_blocks,)
    meta: CSRMeta

    def tree_flatten(self):
        return (self.src_blk, self.dstg_blk, self.ldst_blk, self.perm_blk,
                self.tile_of_blk, self.t_src_blk, self.t_ldst_blk,
                self.t_perm_blk, self.t_tile_of_blk), (self.meta,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def nbytes(self) -> int:
        return sum(a.size * 4 for a in self.tree_flatten()[0])


def static_block_caps(n_edges: int, n_out: int, *, block_e: int = 256,
                      block_rows: int = 256) -> int:
    """Worst-case block count of ``_build_direction`` for ANY assignment
    of ``n_edges`` edges to ``n_out`` output rows.

    ``sum_i ceil(c_i / block_e) <= floor(E / block_e) + n_tiles`` (each
    tile wastes < 1 block, the floors sum below the global floor), and
    every tile emits at least one block. Padding a layout to this cap
    (``build_spmm_layout(pad_static=True)``) makes the layout geometry a
    function of (n_edges, n_out, block sizes) alone — the property the
    neighbor-sampled minibatch path needs so a stream of same-shape
    sampled subgraphs shares ONE jit trace of the fused SPMM.
    """
    n_tiles = max(1, -(-n_out // block_rows))
    return n_edges // block_e + n_tiles


def _build_direction(gather_ids: np.ndarray, out_ids: np.ndarray,
                     n_out: int, block_e: int, block_rows: int,
                     pad_to_blocks: int | None = None):
    """Slot arrays for one aggregation direction (into ``n_out`` rows).

    ``pad_to_blocks`` appends all-pad edge blocks (``perm == n_edges``,
    zero contribution) assigned to the LAST output tile — contiguous
    with its existing run, so the kernel's init-on-first-block-of-tile
    contract still holds — until the block count reaches the given
    static capacity.
    """
    E = int(out_ids.shape[0])
    n_tiles = max(1, -(-n_out // block_rows))
    order = np.argsort(out_ids, kind="stable").astype(np.int64)
    gat_s = gather_ids[order]
    out_s = out_ids[order]
    tile_of_edge = out_s // block_rows

    counts = np.bincount(tile_of_edge, minlength=n_tiles)
    blocks_per_tile = np.maximum(1, -(-counts // block_e))
    n_blocks = int(blocks_per_tile.sum())
    cap = blocks_per_tile * block_e                       # slots per tile
    tile_slot0 = np.concatenate([[0], np.cumsum(cap)[:-1]])
    edge_start = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = tile_slot0[tile_of_edge] + (np.arange(E) - edge_start[tile_of_edge])

    total = n_blocks * block_e
    gat_blk = np.zeros(total, np.int32)
    outg_blk = np.zeros(total, np.int32)
    lrow_blk = np.zeros(total, np.int32)
    perm_blk = np.full(total, E, np.int32)
    gat_blk[slot] = gat_s
    outg_blk[slot] = out_s
    lrow_blk[slot] = out_s - tile_of_edge * block_rows
    perm_blk[slot] = order
    tile_of_blk = np.repeat(np.arange(n_tiles, dtype=np.int32),
                            blocks_per_tile)
    if pad_to_blocks is not None:
        if n_blocks > pad_to_blocks:
            raise ValueError(
                f"layout needs {n_blocks} blocks, static cap is "
                f"{pad_to_blocks} (E={E}, n_out={n_out})")
        extra = pad_to_blocks - n_blocks
        if extra:
            pad_slots = extra * block_e
            gat_blk = np.concatenate([gat_blk, np.zeros(pad_slots, np.int32)])
            outg_blk = np.concatenate([outg_blk,
                                       np.zeros(pad_slots, np.int32)])
            lrow_blk = np.concatenate([lrow_blk,
                                       np.zeros(pad_slots, np.int32)])
            perm_blk = np.concatenate([perm_blk,
                                       np.full(pad_slots, E, np.int32)])
            tile_of_blk = np.concatenate([
                tile_of_blk, np.full(extra, n_tiles - 1, np.int32)])
        n_blocks = pad_to_blocks
    shape = (n_blocks, block_e)
    return (gat_blk.reshape(shape), outg_blk.reshape(shape),
            lrow_blk.reshape(shape), perm_blk.reshape(shape),
            tile_of_blk, n_blocks, n_tiles)


def build_spmm_layout(src, dst, *, n_dst: int, n_src: int | None = None,
                      block_e: int = 256, block_rows: int = 256,
                      pad_static: bool = False) -> SpmmLayout:
    """One-time host-side preprocessing of a COO edge list.

    src / dst : (E,) integer endpoints (any array-like).
    n_dst     : forward output segment count (``num_nodes`` of act_spmm).
    n_src     : row count of the gathered table; defaults to ``n_dst``
                (set explicitly when x is a gathered global table wider
                than the local output shard).
    pad_static: pad both directions' block counts to the data-independent
                ``static_block_caps`` worst case, so every layout built
                for the same (E, n_src, n_dst, block sizes) has identical
                pytree shapes — required when layouts stream through a
                jitted step per minibatch (``repro.data.minibatch``).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError(f"bad edge list shapes {src.shape}/{dst.shape}")
    n_src = int(n_src if n_src is not None else n_dst)
    E = int(src.shape[0])
    cap = (lambda n_out: static_block_caps(
        E, n_out, block_e=block_e, block_rows=block_rows)) \
        if pad_static else (lambda n_out: None)

    (src_blk, dstg_blk, ldst_blk, perm_blk, tile_of_blk,
     n_blocks, n_tiles) = _build_direction(src, dst, n_dst,
                                           block_e, block_rows,
                                           pad_to_blocks=cap(n_dst))
    # transpose: gather rows of g at dst, accumulate into src rows
    (t_src_blk, _t_outg, t_ldst_blk, t_perm_blk, t_tile_of_blk,
     t_n_blocks, t_n_tiles) = _build_direction(dst, src, n_src,
                                               block_e, block_rows,
                                               pad_to_blocks=cap(n_src))

    meta = CSRMeta(n_src=n_src, n_dst=int(n_dst), n_edges=int(src.shape[0]),
                   block_e=block_e, block_rows=block_rows,
                   n_blocks=n_blocks, n_tiles=n_tiles,
                   t_n_blocks=t_n_blocks, t_n_tiles=t_n_tiles)
    as_j = jnp.asarray
    return SpmmLayout(
        src_blk=as_j(src_blk), dstg_blk=as_j(dstg_blk),
        ldst_blk=as_j(ldst_blk), perm_blk=as_j(perm_blk),
        tile_of_blk=as_j(tile_of_blk),
        t_src_blk=as_j(t_src_blk), t_ldst_blk=as_j(t_ldst_blk),
        t_perm_blk=as_j(t_perm_blk), t_tile_of_blk=as_j(t_tile_of_blk),
        meta=meta)


def attach_layout(g, *, block_e: int = 256, block_rows: int = 256):
    """Return a copy of a graph dataclass (e.g. ``models.kgnn.CKG``) with
    its ``layout`` field populated from its COO edge list."""
    layout = build_spmm_layout(
        np.asarray(g.src), np.asarray(g.dst), n_dst=g.n_nodes,
        block_e=block_e, block_rows=block_rows)
    return dataclasses.replace(g, layout=layout)


def maybe_attach_layout(g, policy, *, model: str | None = None, **kw):
    """``attach_layout`` iff the policy selects the Pallas backend AND the
    model's propagation actually aggregates through ``act_spmm``.

    The single guard shared by the training entry points (launcher,
    example driver, benchmark harness). No-op when the layout is already
    attached, the policy runs the jnp backend, or ``model`` names a rule
    (kgin/rgcn) whose aggregation never routes through ``act_spmm``.
    """
    if getattr(policy, "kernel", "jnp") != "pallas":
        return g
    if g.layout is not None or (model is not None
                                and model not in SPMM_MODELS):
        return g
    return attach_layout(g, **kw)


# ---------------------------------------------------------------------------
# Destination-sharded edge partition (data-parallel shard_map, DESIGN.md §7)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class EdgePartition:
    """Edges of one graph split by destination shard, shard_map-ready.

    Destination rows are tiled contiguously: shard ``s`` owns rows
    ``[s*rows_per_shard, (s+1)*rows_per_shard)`` of the (padded) node
    space. Every per-edge array is stacked ``(n_shards, e_cap)`` so a
    ``P(axis)`` prefix spec hands each device its own slice; pad slots
    are masked, not dropped, because shard_map needs equal shapes.

    The halo is the per-shard set of *remote* reads: the unique global
    source ids a shard gathers before its local scatter. ``src_h``
    indexes into the shard's own ``halo`` row order, so the inner SPMM
    touches only an ``(h_cap, d)`` table — the gather working set the
    halo-exchange roofline term is priced on — instead of ``(N, d)``.

    Within a shard, edges keep their original relative order
    (stable partition), so per-destination accumulation order matches
    the unsharded ``segment_sum`` — the partition-invariance tests rely
    on this being bit-exact, not merely close.
    """

    src_g: jax.Array      # (S, Ec) int32 global source ids (pads: 0)
    src_h: jax.Array      # (S, Ec) int32 halo-local source index
    dst_l: jax.Array      # (S, Ec) int32 dst row local to the shard
    rel: jax.Array        # (S, Ec) int32 relation ids (pads: 0)
    mask: jax.Array       # (S, Ec) float32 1=real edge, 0=pad
    perm: jax.Array       # (S, Ec) int32 original edge index; pads: n_edges
    halo: jax.Array       # (S, Hc) int32 unique global src ids per shard
    halo_count: jax.Array  # (S,) int32 real halo rows (rest repeat slot 0)
    n_shards: int = 1     # static aux
    rows_per_shard: int = 0
    n_nodes: int = 0      # original (unpadded) node count
    n_edges: int = 0

    def tree_flatten(self):
        return (self.src_g, self.src_h, self.dst_l, self.rel, self.mask,
                self.perm, self.halo, self.halo_count), (
            self.n_shards, self.rows_per_shard, self.n_nodes, self.n_edges)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def n_nodes_padded(self) -> int:
        return self.n_shards * self.rows_per_shard

    @property
    def e_cap(self) -> int:
        return int(self.src_g.shape[1])

    @property
    def h_cap(self) -> int:
        return int(self.halo.shape[1])


def partition_edges(src, dst, rel=None, *, n_nodes: int, n_shards: int,
                    pad_multiple: int = 8) -> EdgePartition:
    """Split a COO edge list by destination shard (host-side, once).

    Returns per-shard CSR-style blocks (dst-contiguous, original
    relative edge order preserved) plus halo gather indices. Shards are
    padded to a common edge capacity (``pad_multiple``-aligned) and halo
    capacity; ``unpartition_edges`` is the exact inverse over real
    edges, which the round-trip test pins down.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    rel = np.zeros_like(src) if rel is None else np.asarray(rel, np.int64)
    if not (src.shape == dst.shape == rel.shape) or src.ndim != 1:
        raise ValueError(
            f"bad edge list shapes {src.shape}/{dst.shape}/{rel.shape}")
    if n_shards < 1:
        raise ValueError(f"n_shards={n_shards}")
    if src.size and not (0 <= src.min() and src.max() < n_nodes
                         and 0 <= dst.min() and dst.max() < n_nodes):
        # an out-of-range dst would fall in no shard and vanish silently
        raise ValueError(
            f"edge endpoints outside [0, {n_nodes}): src range "
            f"[{src.min()}, {src.max()}], dst range "
            f"[{dst.min()}, {dst.max()}]")
    E = int(src.shape[0])
    rows = -(-n_nodes // n_shards)            # ceil; node space pads to S*rows
    shard_of = dst // rows
    per = [np.flatnonzero(shard_of == s) for s in range(n_shards)]
    e_cap = max(1, max((len(ix) for ix in per), default=1))
    e_cap = -(-e_cap // pad_multiple) * pad_multiple

    halos = [np.unique(src[ix]) if len(ix) else np.zeros(1, np.int64)
             for ix in per]
    h_cap = max(1, max(len(h) for h in halos))
    h_cap = -(-h_cap // pad_multiple) * pad_multiple

    src_g = np.zeros((n_shards, e_cap), np.int32)
    src_h = np.zeros((n_shards, e_cap), np.int32)
    dst_l = np.zeros((n_shards, e_cap), np.int32)
    rel_a = np.zeros((n_shards, e_cap), np.int32)
    mask = np.zeros((n_shards, e_cap), np.float32)
    perm = np.full((n_shards, e_cap), E, np.int32)
    halo = np.zeros((n_shards, h_cap), np.int32)
    halo_n = np.zeros((n_shards,), np.int32)
    for s, ix in enumerate(per):
        k = len(ix)
        src_g[s, :k] = src[ix]
        src_h[s, :k] = np.searchsorted(halos[s], src[ix])
        dst_l[s, :k] = dst[ix] - s * rows
        rel_a[s, :k] = rel[ix]
        mask[s, :k] = 1.0
        perm[s, :k] = ix
        halo[s, :len(halos[s])] = halos[s]
        halo_n[s] = len(halos[s])

    as_j = jnp.asarray
    return EdgePartition(
        src_g=as_j(src_g), src_h=as_j(src_h), dst_l=as_j(dst_l),
        rel=as_j(rel_a), mask=as_j(mask), perm=as_j(perm),
        halo=as_j(halo), halo_count=as_j(halo_n),
        n_shards=n_shards, rows_per_shard=int(rows),
        n_nodes=int(n_nodes), n_edges=E)


def unpartition_edges(part: EdgePartition):
    """Reassemble the original (src, dst, rel) COO lists from a partition.

    Pad slots (``perm == n_edges``) are dropped; real edges scatter back
    to their original positions, so the output is elementwise equal to
    the ``partition_edges`` input — the round-trip CI check.
    """
    E = part.n_edges
    perm = np.asarray(part.perm).ravel()
    keep = perm < E
    if int(keep.sum()) != E:
        raise ValueError(
            f"partition covers {int(keep.sum())} edges, expected {E}")
    src = np.zeros(E, np.int32)
    dst = np.zeros(E, np.int32)
    rel = np.zeros(E, np.int32)
    shard_ix = np.repeat(np.arange(part.n_shards), part.e_cap)[keep]
    p = perm[keep]
    src[p] = np.asarray(part.src_g).ravel()[keep]
    dst[p] = (np.asarray(part.dst_l).ravel()[keep]
              + shard_ix * part.rows_per_shard)
    rel[p] = np.asarray(part.rel).ravel()[keep]
    return src, dst, rel


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Host-side geometry of a dim-0 row-sharded table over a mesh axis.

    Global row ``i`` lives on shard ``i // rows_per_shard`` at local
    offset ``i % rows_per_shard``; rows ``>= n_rows`` are padding (zero,
    zero-grad). The device-side twin of this addressing is
    ``repro.sharding.rowshard.fetch_rows`` — tests check the two agree
    against a ``np.add.at`` reference.
    """

    n_rows: int          # real rows (e.g. n_nodes)
    n_shards: int        # model-axis extent
    rows_per_shard: int  # block rows per shard (includes padding)

    @property
    def n_rows_padded(self) -> int:
        return self.n_shards * self.rows_per_shard

    def owner_of(self, ids):
        return np.asarray(ids) // self.rows_per_shard

    def local_of(self, ids):
        ids = np.asarray(ids)
        return ids - self.owner_of(ids) * self.rows_per_shard

    def pad_table(self, table):
        """Zero-pad a host ``(n_rows, ...)`` table to ``n_rows_padded``."""
        table = np.asarray(table)
        if table.shape[0] != self.n_rows:
            raise ValueError(
                f"table has {table.shape[0]} rows, partition built for "
                f"{self.n_rows}")
        pad = [(0, self.n_rows_padded - self.n_rows)]
        pad += [(0, 0)] * (table.ndim - 1)
        return np.pad(table, pad)

    def blocks(self, table):
        """Padded table reshaped ``(n_shards, rows_per_shard, ...)``."""
        padded = self.pad_table(table)
        return padded.reshape(
            self.n_shards, self.rows_per_shard, *padded.shape[1:])


def row_partition(n_rows: int, n_shards: int, *, pad_to: int | None = None):
    """Split ``n_rows`` table rows evenly over ``n_shards`` mesh shards.

    ``pad_to`` widens the addressable row space before splitting — the
    2D mesh passes the data partition's ``n_nodes_padded`` so every
    data-shard dst row (including edge-partition padding) has an owner.
    """
    if n_rows < 1 or n_shards < 1:
        raise ValueError(
            f"row_partition needs n_rows >= 1 and n_shards >= 1, got "
            f"{n_rows} rows over {n_shards} shards")
    span = max(int(n_rows), int(pad_to or 0))
    return RowPartition(
        n_rows=int(n_rows), n_shards=int(n_shards),
        rows_per_shard=-(-span // int(n_shards)))
