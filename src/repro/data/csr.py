"""Blocked-CSR edge layout for the fused Pallas SPMM kernels.

The COO path (``x[src] * ew -> segment_sum``) materializes the full
``(E, d)`` message tensor in HBM twice per step (forward messages,
backward ``g[dst]``). The fused kernels in ``repro.kernels.spmm`` never
form it — but they need edges pre-arranged so that each kernel grid step
touches one destination tile. That arrangement is this module's job, done
once per graph on the host (numpy), like any real input pipeline.

Construction (see DESIGN.md §4):

1. **Stable-sort edges by destination.** Per-destination contributions
   keep their original relative order, so the kernel walks each
   destination's edges in the same order as the COO ``segment_sum``
   reference (exact agreement up to fp32 reduction associativity inside
   a block's dot product).
2. **Tile destinations** into blocks of ``block_rows`` rows. Each tile's
   run of sorted edges is padded up to a multiple of ``block_e`` slots;
   tiles with no edges get one all-pad block so every output tile is
   initialized by exactly one contiguous run of grid steps (the Pallas
   output-revisiting contract).
3. **Emit per-slot arrays** reshaped ``(n_blocks, block_e)`` — 2-D so TPU
   BlockSpecs tile them directly — plus ``tile_of_blk``, the per-block
   destination-tile id that rides in SMEM via scalar prefetch and steers
   the output index map.

Pad slots carry ``perm = n_edges`` (one past the last real edge), so a
single gather from ``append(ew, 0)`` both permutes edge weights into slot
order and zeroes pad lanes; scatters of per-slot results through ``perm``
with out-of-bounds drop discard pad contributions for free.

The same machinery, run on the reversed edges, yields the **transpose
layout** that the backward scatter (``∇x = Aᵀ(g · ew)``) uses — one kernel
serves both directions.

``SpmmLayout`` is a registered pytree (arrays are children, the
``CSRMeta`` block geometry is hashable aux data) so it rides through
``jax.jit`` / ``grad`` untouched.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CSRMeta", "SpmmLayout", "build_spmm_layout", "attach_layout",
           "maybe_attach_layout"]

# KGNN propagation rules that aggregate through act_spmm (and therefore
# benefit from a blocked-CSR layout). KGIN/R-GCN modulate messages with
# per-edge *vectors* and aggregate via raw segment_sum — a layout would
# be dead weight there.
SPMM_MODELS = ("kgat", "kgcn")


@dataclasses.dataclass(frozen=True)
class CSRMeta:
    """Static block geometry — pytree aux data, hashable under jit."""

    n_src: int        # rows of the gathered-from table (x fwd, g bwd)
    n_dst: int        # output segment count of the forward aggregation
    n_edges: int      # real (unpadded) edge count E
    block_e: int      # edge slots per block
    block_rows: int   # destination rows per output tile
    n_blocks: int     # forward-direction edge blocks (incl. pad blocks)
    n_tiles: int      # forward-direction destination tiles
    t_n_blocks: int   # transpose-direction edge blocks
    t_n_tiles: int    # transpose-direction tiles (cover n_src rows)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SpmmLayout:
    """Blocked-CSR slots for one graph, forward + transpose directions.

    All arrays int32. ``*_blk`` arrays are ``(n_blocks, block_e)``; pad
    slots hold gather-index 0 / local-row 0 / perm ``n_edges``.
    """

    # forward direction: edges stable-sorted by dst
    src_blk: jax.Array    # global src id per slot — gather rows of x
    dstg_blk: jax.Array   # global dst id per slot — gather rows of g (SDDMM)
    ldst_blk: jax.Array   # dst id local to its tile — in-kernel one-hot row
    perm_blk: jax.Array   # original edge index per slot; n_edges for pads
    tile_of_blk: jax.Array  # (n_blocks,) destination tile per edge block
    # transpose direction: edges stable-sorted by src (drives ∇x)
    t_src_blk: jax.Array    # global dst id per slot — gather rows of g
    t_ldst_blk: jax.Array   # src id local to its tile
    t_perm_blk: jax.Array   # original edge index per slot
    t_tile_of_blk: jax.Array  # (t_n_blocks,)
    meta: CSRMeta

    def tree_flatten(self):
        return (self.src_blk, self.dstg_blk, self.ldst_blk, self.perm_blk,
                self.tile_of_blk, self.t_src_blk, self.t_ldst_blk,
                self.t_perm_blk, self.t_tile_of_blk), (self.meta,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def nbytes(self) -> int:
        return sum(a.size * 4 for a in self.tree_flatten()[0])


def _build_direction(gather_ids: np.ndarray, out_ids: np.ndarray,
                     n_out: int, block_e: int, block_rows: int):
    """Slot arrays for one aggregation direction (into ``n_out`` rows)."""
    E = int(out_ids.shape[0])
    n_tiles = max(1, -(-n_out // block_rows))
    order = np.argsort(out_ids, kind="stable").astype(np.int64)
    gat_s = gather_ids[order]
    out_s = out_ids[order]
    tile_of_edge = out_s // block_rows

    counts = np.bincount(tile_of_edge, minlength=n_tiles)
    blocks_per_tile = np.maximum(1, -(-counts // block_e))
    n_blocks = int(blocks_per_tile.sum())
    cap = blocks_per_tile * block_e                       # slots per tile
    tile_slot0 = np.concatenate([[0], np.cumsum(cap)[:-1]])
    edge_start = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = tile_slot0[tile_of_edge] + (np.arange(E) - edge_start[tile_of_edge])

    total = n_blocks * block_e
    gat_blk = np.zeros(total, np.int32)
    outg_blk = np.zeros(total, np.int32)
    lrow_blk = np.zeros(total, np.int32)
    perm_blk = np.full(total, E, np.int32)
    gat_blk[slot] = gat_s
    outg_blk[slot] = out_s
    lrow_blk[slot] = out_s - tile_of_edge * block_rows
    perm_blk[slot] = order
    tile_of_blk = np.repeat(np.arange(n_tiles, dtype=np.int32),
                            blocks_per_tile)
    shape = (n_blocks, block_e)
    return (gat_blk.reshape(shape), outg_blk.reshape(shape),
            lrow_blk.reshape(shape), perm_blk.reshape(shape),
            tile_of_blk, n_blocks, n_tiles)


def build_spmm_layout(src, dst, *, n_dst: int, n_src: int | None = None,
                      block_e: int = 256, block_rows: int = 256) -> SpmmLayout:
    """One-time host-side preprocessing of a COO edge list.

    src / dst : (E,) integer endpoints (any array-like).
    n_dst     : forward output segment count (``num_nodes`` of act_spmm).
    n_src     : row count of the gathered table; defaults to ``n_dst``
                (set explicitly when x is a gathered global table wider
                than the local output shard).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError(f"bad edge list shapes {src.shape}/{dst.shape}")
    n_src = int(n_src if n_src is not None else n_dst)

    (src_blk, dstg_blk, ldst_blk, perm_blk, tile_of_blk,
     n_blocks, n_tiles) = _build_direction(src, dst, n_dst,
                                           block_e, block_rows)
    # transpose: gather rows of g at dst, accumulate into src rows
    (t_src_blk, _t_outg, t_ldst_blk, t_perm_blk, t_tile_of_blk,
     t_n_blocks, t_n_tiles) = _build_direction(dst, src, n_src,
                                               block_e, block_rows)

    meta = CSRMeta(n_src=n_src, n_dst=int(n_dst), n_edges=int(src.shape[0]),
                   block_e=block_e, block_rows=block_rows,
                   n_blocks=n_blocks, n_tiles=n_tiles,
                   t_n_blocks=t_n_blocks, t_n_tiles=t_n_tiles)
    as_j = jnp.asarray
    return SpmmLayout(
        src_blk=as_j(src_blk), dstg_blk=as_j(dstg_blk),
        ldst_blk=as_j(ldst_blk), perm_blk=as_j(perm_blk),
        tile_of_blk=as_j(tile_of_blk),
        t_src_blk=as_j(t_src_blk), t_ldst_blk=as_j(t_ldst_blk),
        t_perm_blk=as_j(t_perm_blk), t_tile_of_blk=as_j(t_tile_of_blk),
        meta=meta)


def attach_layout(g, *, block_e: int = 256, block_rows: int = 256):
    """Return a copy of a graph dataclass (e.g. ``models.kgnn.CKG``) with
    its ``layout`` field populated from its COO edge list."""
    layout = build_spmm_layout(
        np.asarray(g.src), np.asarray(g.dst), n_dst=g.n_nodes,
        block_e=block_e, block_rows=block_rows)
    return dataclasses.replace(g, layout=layout)


def maybe_attach_layout(g, policy, *, model: str | None = None, **kw):
    """``attach_layout`` iff the policy selects the Pallas backend AND the
    model's propagation actually aggregates through ``act_spmm``.

    The single guard shared by the training entry points (launcher,
    example driver, benchmark harness). No-op when the layout is already
    attached, the policy runs the jnp backend, or ``model`` names a rule
    (kgin/rgcn) whose aggregation never routes through ``act_spmm``.
    """
    if getattr(policy, "kernel", "jnp") != "pallas":
        return g
    if g.layout is not None or (model is not None
                                and model not in SPMM_MODELS):
        return g
    return attach_layout(g, **kw)
