"""Row-sharded table access inside `shard_map` bodies (DESIGN.md §12).

A row-sharded table lives as a ``(table_rows, d)`` block per shard of a
mesh axis (global row ``i`` belongs to shard ``i // table_rows`` at
local offset ``i % table_rows``). The two ops here are the ONLY places
the 2D data×model path touches such a block; everything downstream of
them is replicated over the model axis, which is the layout contract
their custom VJPs rely on.

Why custom VJPs instead of plain autodiff through the collectives: the
2D body computes the loss redundantly on every model shard (activations
are model-replicated), so differentiating through a forward ``psum``
over the model axis would transpose into a second psum and overcount
the block gradient by the model extent. The VJP of ``fetch_rows`` is
instead a LOCAL scatter of the (replicated) cotangent into the rows
this shard owns — which is exactly the shard's reduce-scatter share of
the global row-gradient, computed with zero model-axis traffic. That is
the "reduce-scatter of row-shard grads over `model`" of the per-axis
reduction order: it is fused into the fetch VJP rather than issued as a
separate collective.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fetch_rows", "rowshard_l2"]


def fetch_rows(block, ids, *, axis, rows_per_shard, n_valid=None):
    """Gather global rows ``ids`` from a dim-0 row-sharded table.

    Forward: each shard of ``axis`` contributes the rows it owns (a
    masked local gather), and one ``psum`` over ``axis`` assembles the
    full gather. Per id exactly one shard contributes a nonzero row, so
    the sum is ``x + 0.0 + ...`` — bit-exact against indexing a
    replicated table. Ids ``>= n_valid`` (node-space padding) come back
    as zero rows, matching the zero-pad-extended replicated table of
    the 1D path; their cotangents are dropped in the backward pass, so
    padded block rows never receive gradient and stay zero forever.

    Backward: requires the cotangent to be replicated over ``axis``
    (the 2D body contract). Each shard scatter-adds the cotangent rows
    it owns into a zero block — its reduce-scatter share, locally.
    """
    ids = jnp.asarray(ids)

    def _mine(m):
        owner = ids // rows_per_shard
        off = ids - owner * rows_per_shard
        ok = owner == m
        if n_valid is not None:
            ok = ok & (ids < n_valid)
        return ok, off

    @jax.custom_vjp
    def gather(b):
        ok, off = _mine(jax.lax.axis_index(axis))
        rows = jnp.where(ok[:, None], b[off], jnp.zeros((), b.dtype))
        return jax.lax.psum(rows, axis)

    def fwd(b):
        return gather(b), None

    def bwd(_, ct):
        ok, off = _mine(jax.lax.axis_index(axis))
        contrib = jnp.where(ok[:, None], ct, jnp.zeros((), ct.dtype))
        zeros = jnp.zeros((rows_per_shard, ct.shape[-1]), ct.dtype)
        return (zeros.at[off].add(contrib),)

    gather.defvjp(fwd, bwd)
    return gather(block)


def rowshard_l2(block, *, axis):
    """``sum(x**2)`` over the FULL row-sharded table.

    Forward psums the per-block sums over ``axis`` so every shard sees
    the same scalar the replicated path would (padded rows are zero and
    contribute nothing). The VJP is ``2 * block * ct`` — the full-table
    L2 gradient restricted to the local block, under the same
    replicated-cotangent contract as :func:`fetch_rows`.
    """

    @jax.custom_vjp
    def l2(b):
        return jax.lax.psum(jnp.sum(b * b), axis)

    def fwd(b):
        return l2(b), b

    def bwd(b, ct):
        return (2.0 * b * ct,)

    l2.defvjp(fwd, bwd)
    return l2(block)
