"""JAX-version portability shim for the SPMD layer (DESIGN.md §7.5).

The distribution code targets the *current* JAX SPMD API surface
(``jax.shard_map``, ``jax.sharding.AxisType``, meshes with
``axis_types``, ``jax.sharding.reshard``), but the pinned toolchain is
JAX 0.4.37 where none of those names exist yet — ``shard_map`` lives in
``jax.experimental.shard_map`` and meshes carry no axis types. Upstream
has renamed these entry points more than once; every rename used to kill
the whole distributed layer at import time.

This module is the single place that knows about those renames. Policy:

  * supported range: JAX 0.4.30 → current release (the CI fast matrix
    pins 0.4.37 and latest; a rename upstream breaks the ``latest`` leg
    here, not at 40 call sites)
  * resolution happens ONCE at import via feature probes
    (``hasattr``/``inspect.signature``), never by version-string
    comparison — prereleases and vendor forks misreport versions
  * every exported symbol keeps the NEW (current-JAX) calling
    convention; the shim adapts it down to what the pinned runtime
    accepts (e.g. ``check_rep`` is dropped/renamed as needed, an
    ``axis_types`` request is silently elided on meshes that predate
    axis types — semantically safe, 0.4.x meshes are all ``Auto``)

Everything SPMD in the repo imports from here:

    from repro.sharding.compat import shard_map, make_sim_mesh, P
"""

from __future__ import annotations

import enum
import inspect
import math

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "AxisType", "HAS_AXIS_TYPES", "HAS_NATIVE_SHARD_MAP", "P",
    "auto_axis_types", "host_device_count", "make_mesh",
    "make_sim_mesh", "mesh_from_devices", "reshard", "shard_map",
    "sim_mesh_env_hint",
]


# --- feature probes (import-time, hasattr-based — never version strings) ---

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


class _AxisTypeShim(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` on JAX < axis-types.

    Pre-axis-type meshes behave exactly like all-``Auto`` meshes, so
    carrying the enum purely as documentation is sound: requesting
    ``Auto`` is a no-op and requesting ``Explicit``/``Manual`` on a
    runtime that cannot honor it raises at mesh construction.
    """

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = jax.sharding.AxisType if HAS_AXIS_TYPES else _AxisTypeShim


def auto_axis_types(n_axes: int):
    """``(AxisType.Auto,) * n_axes`` in whichever enum this JAX speaks."""
    return (AxisType.Auto,) * n_axes


def _kwarg_names(fn) -> frozenset:
    try:
        return frozenset(inspect.signature(fn).parameters)
    except (TypeError, ValueError):  # C-level / pybind signatures
        return frozenset()


# --- shard_map -------------------------------------------------------------

if HAS_NATIVE_SHARD_MAP:
    _SM = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _SM  # noqa: F401

_SM_KWARGS = _kwarg_names(_SM)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = False):
    """``jax.shard_map`` with one calling convention across JAX versions.

    ``check_rep`` maps onto whatever the runtime calls replication
    checking (``check_rep`` in 0.4.x, ``check_vma`` today). It defaults
    OFF because our bodies differentiate through ``custom_vjp`` ops
    (``act_spmm``), for which old JAX has no replication rule — the
    out_specs are the ground truth either way.
    """
    kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if "check_vma" in _SM_KWARGS:
        kw["check_vma"] = check_rep
    elif "check_rep" in _SM_KWARGS:
        kw["check_rep"] = check_rep
    return _SM(f, **kw)


# --- meshes ----------------------------------------------------------------


def _native_axis_types(axis_types):
    """Translate shim enum members to the native enum (when both exist)."""
    if axis_types is None:
        return None
    out = []
    for t in axis_types:
        if isinstance(t, _AxisTypeShim):
            if not HAS_AXIS_TYPES:
                out.append(t)
                continue
            t = getattr(jax.sharding.AxisType, t.name)
        out.append(t)
    return tuple(out)


def mesh_from_devices(devices, axis_names, *, axis_types=None):
    """``jax.sharding.Mesh`` that tolerates runtimes without axis types.

    On pre-axis-type JAX an all-``Auto`` request is elided (0.4.x meshes
    ARE auto meshes); any other request cannot be honored and raises.
    """
    devices = np.asarray(devices)
    axis_types = _native_axis_types(axis_types)
    if axis_types is not None and HAS_AXIS_TYPES:
        return jax.sharding.Mesh(devices, axis_names, axis_types=axis_types)
    if axis_types is not None and any(
            getattr(t, "name", str(t)) != "Auto" for t in axis_types):
        raise NotImplementedError(
            f"axis_types={axis_types} need jax.sharding.AxisType, which "
            f"this JAX ({jax.__version__}) predates; only Auto meshes are "
            "expressible here")
    return jax.sharding.Mesh(devices, axis_names)


_MAKE_MESH = getattr(jax, "make_mesh", None)
_MAKE_MESH_KWARGS = _kwarg_names(_MAKE_MESH) if _MAKE_MESH else frozenset()


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    """``jax.make_mesh`` signature, portable down to manual construction."""
    if _MAKE_MESH is not None:
        kw = {}
        if devices is not None:
            kw["devices"] = devices
        if axis_types is not None and "axis_types" in _MAKE_MESH_KWARGS:
            kw["axis_types"] = _native_axis_types(axis_types)
            return _MAKE_MESH(tuple(axis_shapes), tuple(axis_names), **kw)
        m = _MAKE_MESH(tuple(axis_shapes), tuple(axis_names), **kw)
        if axis_types is None:
            return m
        # native make_mesh predates axis_types: rebuild through the
        # validating constructor (honors them when Mesh can, raises on a
        # non-Auto request this runtime cannot express — never elides)
        return mesh_from_devices(m.devices, tuple(axis_names),
                                 axis_types=axis_types)
    n = math.prod(axis_shapes)
    devs = list(devices) if devices is not None else jax.devices()[:n]
    return mesh_from_devices(
        np.asarray(devs[:n]).reshape(tuple(axis_shapes)), tuple(axis_names),
        axis_types=axis_types)


def host_device_count() -> int:
    return len(jax.devices())


def sim_mesh_env_hint(n: int) -> str:
    """The incantation for an n-way simulated CPU mesh, for error text."""
    return (f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "(must be set before the first jax call in the process)")


def make_sim_mesh(shape, axis_names=("data",), *, axis_types=None):
    """Test/dev mesh over forced host (CPU) devices.

    ``shape`` is an int (1-D mesh) or a tuple matching ``axis_names``.
    Raises with the exact ``XLA_FLAGS`` fix when the process has fewer
    devices than the mesh needs — the number-one SPMD test footgun (the
    device count locks at first jax init, so pytest main processes
    usually sit at 1).
    """
    if isinstance(shape, int):
        shape = (shape,)
    if len(shape) != len(axis_names):
        raise ValueError(f"mesh shape {shape} vs axis names {axis_names}")
    n = math.prod(shape)
    avail = host_device_count()
    if avail < n:
        raise RuntimeError(
            f"make_sim_mesh({shape}) needs {n} devices but this process "
            f"has {avail}; run under {sim_mesh_env_hint(n)}")
    if axis_types is None:
        axis_types = auto_axis_types(len(axis_names))
    return mesh_from_devices(
        np.asarray(jax.devices()[:n]).reshape(shape), tuple(axis_names),
        axis_types=axis_types)


# --- resharding ------------------------------------------------------------


def reshard(x, mesh, spec):
    """Place ``x`` onto ``NamedSharding(mesh, spec)``.

    Uses ``jax.sharding.reshard`` where it exists (explicit-sharding
    API); ``device_put`` is the portable equivalent for Auto meshes.
    """
    sharding = NamedSharding(mesh, spec)
    native = getattr(jax.sharding, "reshard", None)
    if native is not None and HAS_AXIS_TYPES:
        try:
            return native(x, sharding)
        except (TypeError, ValueError):
            pass  # reshard refuses non-explicit meshes; fall through
    return jax.device_put(x, sharding)
