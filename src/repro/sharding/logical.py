"""Logical-axis sharding constraints (flax-style, hand-rolled).

Models annotate activations with *logical* axis names
(``constraint(x, "batch", "seq", "embed")``); the launcher binds logical
names to mesh axes for the current mesh. Outside any binding (CPU unit
tests) constraints are no-ops, so model code stays mesh-agnostic.

GSPMD propagation from param/input shardings alone lets giant activations
(scan-carried residual streams, logits) go replicated; these constraints
pin them down — measured on codeqwen-7b train_4k: per-device temp drops
from 161 GB to < 1 GB (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["axis_rules", "constraint", "logical_spec", "current_rules"]

_STATE = threading.local()


def current_rules():
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(mesh, rules: dict):
    """Bind logical names -> mesh axis (str | tuple | None) under ``mesh``."""
    prev = current_rules()
    _STATE.rules = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.rules = prev


def logical_spec(*names) -> P:
    ctx = current_rules()
    assert ctx is not None
    _, rules = ctx
    return P(*[rules.get(n) if n is not None else None for n in names])


def constraint(x: jax.Array, *names):
    """with_sharding_constraint by logical names; no-op when unbound."""
    ctx = current_rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = P(*[rules.get(n) if n is not None else None for n in names])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# canonical rule sets -------------------------------------------------------


def lm_rules(mesh) -> dict:
    """batch->data(+pod), model dims->model. seq unsharded by default."""
    batch = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return {
        "batch": batch,
        "seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": None,       # kv heads < model size: replicated
        "ff": "model",
        "vocab": "model",
        "expert": "model",
        "cache_seq": "model",   # context parallelism for long decode
        "everything": batch + ("model",),
    }
