"""One mesh-layout spec type for every consumer (DESIGN.md §12).

``MeshSpec`` replaces the private ``AXIS=N`` parsers that had started to
accrete per entry point (the launcher's ``_parse_mesh``, dryrun's
``NxM`` tuple): the launcher (``--mesh``), the dry-run driver
(``--sim``), ``launch.mesh.make_production_mesh`` and the data-parallel
wrappers (``make_dp_step``) all consume this one type, so a layout
string means the same thing everywhere and a malformed one fails with
ONE honest named error (``MeshSpecError``) instead of a per-caller
variant.

Grammar::

    SPEC  := ENTRY ("," ENTRY)*
    ENTRY := AXIS "=" N          # AXIS an identifier, N a positive int

``"data=8"`` is the 1D data-parallel layout (unchanged from PR 3);
``"data=4,model=2"`` is the 2D data×model mesh with row-sharded tables.
Axis order is significant — it is the device-grid order
``make_sim_mesh``/``mesh_from_devices`` build.

This module imports no jax: constructing/printing/validating a spec
never initializes a backend (the launcher must force the simulated
device count BEFORE the first jax call). ``build_sim`` imports the
compat layer lazily at mesh-construction time.
"""

from __future__ import annotations

import dataclasses
import math
import re

__all__ = ["MeshSpec", "MeshSpecError"]


class MeshSpecError(ValueError):
    """A malformed mesh layout spec (the one named parse error)."""


_AXIS_RE = re.compile(r"[A-Za-z_][A-Za-z0-9_]*$")


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """An ordered mesh layout: ``((axis_name, extent), ...)``.

    ``str(spec)`` round-trips through ``parse`` exactly, which is what
    lets checkpoint metadata store the topology as a plain string and
    refusal messages name both sides literally.
    """

    axes: tuple  # ((name, extent), ...), order = device-grid order

    @classmethod
    def parse(cls, spec: "str | MeshSpec") -> "MeshSpec":
        if isinstance(spec, MeshSpec):
            return spec

        def die(why: str):
            raise MeshSpecError(
                f"mesh spec must be comma-separated AXIS=N entries (e.g. "
                f"'data=8' or 'data=4,model=2'), got {spec!r}: {why}")

        if not isinstance(spec, str) or not spec.strip():
            die("empty spec")
        axes, seen = [], set()
        for ent in spec.split(","):
            name, eq, num = ent.strip().partition("=")
            name = name.strip()
            if not eq:
                die(f"entry {ent.strip()!r} has no '='")
            if not _AXIS_RE.match(name):
                die(f"bad axis name {name!r}")
            if name in seen:
                die(f"duplicate axis {name!r}")
            try:
                n = int(num.strip())
            except ValueError:
                die(f"extent {num.strip()!r} is not an integer")
            if n < 1:
                die(f"axis {name!r} extent must be >= 1, got {n}")
            seen.add(name)
            axes.append((name, n))
        return cls(tuple(axes))

    @classmethod
    def from_shape(cls, shape, names) -> "MeshSpec":
        """Pair per-axis extents with axis names (dryrun's ``--sim NxM``)."""
        shape, names = tuple(shape), tuple(names)
        if len(shape) != len(names):
            raise MeshSpecError(
                f"mesh shape {shape} must name {len(names)} extents for "
                f"axes {names} (got {len(shape)})")
        return cls(tuple((str(n), int(s)) for n, s in zip(names, shape)))

    def __str__(self) -> str:
        return ",".join(f"{n}={e}" for n, e in self.axes)

    @property
    def names(self) -> tuple:
        return tuple(n for n, _ in self.axes)

    @property
    def shape(self) -> tuple:
        return tuple(e for _, e in self.axes)

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    def extent(self, name: str, default: int = 1) -> int:
        """Extent of ``name``, or ``default`` when the axis is absent —
        so 1D ``data=N`` specs answer ``extent("model") == 1``."""
        for n, e in self.axes:
            if n == name:
                return e
        return default

    def check_axes(self, allowed, required=()) -> "MeshSpec":
        """Refuse axis names outside ``allowed`` / missing ``required``
        with the same named error as a parse failure."""
        allowed, required = tuple(allowed), tuple(required)
        for n in self.names:
            if n not in allowed:
                raise MeshSpecError(
                    f"mesh spec {self} names axis {n!r}; this path "
                    f"supports axes {allowed}")
        for n in required:
            if n not in self.names:
                raise MeshSpecError(
                    f"mesh spec {self} is missing required axis {n!r}")
        return self

    def check_mesh(self, mesh) -> "MeshSpec":
        """Validate an already-built mesh against this spec."""
        got = {str(n): int(mesh.shape[n]) for n in mesh.axis_names}
        want = {n: e for n, e in self.axes}
        if got != want:
            raise ValueError(
                f"mesh spec {self} does not match the mesh's axes {got}")
        return self

    def build_sim(self):
        """Simulated host mesh with this layout (forced-device tests,
        launcher); lazy compat import keeps this module jax-free."""
        from repro.sharding.compat import make_sim_mesh

        return make_sim_mesh(self.shape, self.names)
