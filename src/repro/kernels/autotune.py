"""Tile-size autotuner for the Pallas kernels, with an on-disk cache.

The kernels used to hard-code their tile sizes (``_pick_block(dp, 512)``,
``min(d, 512)``, ``block_r = 256`` …). Those are fine defaults for one
shape on one backend and wrong everywhere else; on TPU the difference
between a good and a bad ``block_e`` is a VMEM spill. This module makes
tile selection measured:

  * a **key** is ``(op, shape-bucket, bits, params-domain)`` — shapes are
    bucketed to the next power of two so one sweep serves a family of
    nearby shapes instead of re-timing every batch size;
  * winners live in a JSON cache keyed by the **backend fingerprint**
    (``backend.probe_backend().fingerprint``), so values tuned on CPU
    interpret never leak onto a TPU and vice versa;
  * ``pick()`` is pure-python over *static* shapes, so kernel wrappers
    may call it while being traced under ``jax.jit`` — a cache hit (or
    the heuristic default) resolves without running anything. Sweeps only
    happen when explicitly enabled (``sweep=True`` / ``REPRO_AUTOTUNE=1``)
    and the wrapper passes a ``measure`` callable, which requires
    concrete inputs — the benchmarks and the nightly do this; unit tests
    and jitted training steps ride the cache.

Cache format (versioned, one file, atomic rewrite):

    {"version": 1,
     "<fingerprint>": {
        "<key>": {"winner": {...params}, "us": {...per-candidate}}}}

Determinism contract (tested): the same fingerprint + key never
re-sweeps — a second process loading the file returns the stored winner
with zero measurements.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Callable, Sequence

from . import backend as _backend

__all__ = ["Autotuner", "get", "reset", "shape_bucket", "DEFAULT_CACHE_PATH"]

DEFAULT_CACHE_PATH = os.environ.get(
    "REPRO_AUTOTUNE_CACHE",
    os.path.join("artifacts", "autotune_cache.json"))

_CACHE_VERSION = 1


def shape_bucket(n: int) -> int:
    """Next power of two >= n (1 for n <= 1) — the shape-family key."""
    if n <= 1:
        return 1
    return 1 << (int(n - 1)).bit_length()


def _sweep_enabled_default() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "0") == "1"


class Autotuner:
    """Measured tile selection with an on-disk, fingerprint-keyed cache."""

    def __init__(self, path: str | None = None, *,
                 sweep: bool | None = None,
                 fingerprint: str | None = None,
                 reps: int = 3):
        self.path = DEFAULT_CACHE_PATH if path is None else path
        self.sweep = _sweep_enabled_default() if sweep is None else sweep
        self.fingerprint = (fingerprint or
                            _backend.probe_backend().fingerprint)
        self.reps = reps
        self.n_sweeps = 0          # measurements performed (test observable)
        self._cache = self._load()

    # -- persistence ------------------------------------------------------

    def _load(self) -> dict:
        if self.path and os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    data = json.load(f)
                if data.get("version") == _CACHE_VERSION:
                    return data
            except (OSError, ValueError):
                pass
        return {"version": _CACHE_VERSION}

    def _save(self) -> None:
        if not self.path:
            return
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(self.path) or ".",
                                   suffix=".autotune")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._cache, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            if os.path.exists(tmp):
                os.unlink(tmp)

    # -- keys -------------------------------------------------------------

    @staticmethod
    def key(op: str, shapes: Sequence[int], *, bits: int | None = None,
            extra: str = "") -> str:
        dims = "x".join(str(shape_bucket(int(s))) for s in shapes)
        parts = [op, dims]
        if bits is not None:
            parts.append(f"b{bits}")
        if extra:
            parts.append(extra)
        return "|".join(parts)

    # -- selection --------------------------------------------------------

    def lookup(self, key: str) -> dict | None:
        entry = self._cache.get(self.fingerprint, {}).get(key)
        return dict(entry["winner"]) if entry else None

    def pick(self, op: str, *, shapes: Sequence[int],
             bits: int | None = None, extra: str = "",
             candidates: Sequence[dict] = (),
             measure: Callable[[dict], None] | None = None,
             default: dict) -> dict:
        """Cached winner for (op, shape-bucket, bits) or sweep/default.

        ``measure(params)`` runs the op once with ``params`` (the caller
        blocks on the result); it is only invoked when sweeping is
        enabled AND candidates exist — otherwise ``default`` wins. Safe
        to call under a jit trace (pure dict/cache work on a hit/miss).
        """
        key = self.key(op, shapes, bits=bits, extra=extra)
        hit = self.lookup(key)
        if hit is not None:
            return hit
        if not (self.sweep and measure is not None and candidates):
            return dict(default)
        timings: dict[str, float] = {}
        best, best_us = dict(default), float("inf")
        for params in candidates:
            try:
                measure(params)                      # compile / warm
                t0 = time.perf_counter()
                for _ in range(self.reps):
                    measure(params)
                us = (time.perf_counter() - t0) / self.reps * 1e6
            except Exception:                        # candidate invalid on
                continue                             # this backend/shape
            self.n_sweeps += 1
            timings[json.dumps(params, sort_keys=True)] = round(us, 1)
            if us < best_us:
                best, best_us = dict(params), us
        self._cache.setdefault(self.fingerprint, {})[key] = {
            "winner": best, "us": timings}
        self._save()
        return dict(best)


_singleton: Autotuner | None = None


def get() -> Autotuner:
    """Process-wide autotuner over the default cache path."""
    global _singleton
    if _singleton is None:
        _singleton = Autotuner()
    return _singleton


def reset(path: str | None = None, **kw) -> Autotuner:
    """Swap the process-wide autotuner (tests, benchmarks)."""
    global _singleton
    _singleton = Autotuner(path, **kw) if (path or kw) else None
    return get()
