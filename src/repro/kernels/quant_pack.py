"""Pallas TPU kernel: fused per-row minmax + SR-quantize + bit-pack.

One VMEM pass over the activation block:
    HBM read  : x fp32                      (R*d*4 bytes)
    HBM write : packed uint8 + scale + zero (R*d*b/8 + 8R bytes)

vs the unfused jnp path which materializes codes (R*d) before packing.
SR noise comes from an in-kernel counter hash (see hashrng.py) so no noise
tensor is ever read from HBM — this is the TPU adaptation of the paper's
cuRAND-in-CUDA-kernel design.

Block shape: (block_r, d) — a row's minmax needs the full feature dim, which
for KGNN/recsys/LM activations (d = 16 … 12288) fits VMEM comfortably at
block_r = 256 (256×12288×4B ≈ 12.6 MB is the worst case; callers shrink
block_r for very wide rows). Lane dim d should be a multiple of 128 for
peak VPU efficiency; any d works correctly.

The packed layout matches ``repro.core.quant.pack_bits`` (chunk-interleaved)
so either backend can dequantize the other's QTensor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import autotune
from .hashrng import hash_uniform

__all__ = ["quant_pack_kernel", "quant_pack", "dequant_unpack"]

_EPS = 1e-12


def _quant_kernel(seed_ref, x_ref, packed_ref, scale_ref, zero_ref, *,
                  bits: int, stochastic: bool, block_r: int, d: int,
                  d_pad: int, dp: int, cpb: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (block_r, d_pad)
    bins = jnp.float32(2**bits - 1)
    if d_pad == d:
        valid = None
        lo = jnp.min(x, axis=-1, keepdims=True)
        hi = jnp.max(x, axis=-1, keepdims=True)
    else:
        # pad+mask path for d % cpb != 0: pad columns must not perturb the
        # per-row minmax, and their codes pack as 0 (matching
        # core.quant.pack_bits' zero-padded layout exactly)
        col = jax.lax.broadcasted_iota(jnp.int32, (block_r, d_pad), 1)
        valid = col < d
        lo = jnp.min(jnp.where(valid, x, float("inf")), axis=-1,
                     keepdims=True)
        hi = jnp.max(jnp.where(valid, x, float("-inf")), axis=-1,
                     keepdims=True)
    rng = hi - lo
    inv = bins / jnp.maximum(rng, _EPS)
    normed = (x - lo) * inv  # in [0, bins] on valid columns
    if stochastic:
        # global element index -> counter hash, indexed over the TRUE
        # width d so the stream matches ref_quant_pack bit-for-bit even
        # when d needed padding (pad columns draw out-of-range hashes
        # but their codes are masked to 0 below)
        row = jax.lax.broadcasted_iota(jnp.uint32, (block_r, d_pad), 0)
        col = jax.lax.broadcasted_iota(jnp.uint32, (block_r, d_pad), 1)
        gidx = (row + jnp.uint32(i * block_r)) * jnp.uint32(d) + col
        u = hash_uniform(gidx, seed_ref[0])
        floor = jnp.floor(normed)
        codes_f = floor + (u < (normed - floor)).astype(jnp.float32)
    else:
        codes_f = jnp.round(normed)
    codes = jnp.clip(codes_f, 0.0, bins).astype(jnp.uint8)
    if valid is not None:
        codes = jnp.where(valid, codes, jnp.uint8(0))
    # chunk-interleaved pack: byte j holds codes [k*dp + j], k = 0..cpb-1
    if cpb == 1:
        packed = codes
    else:
        packed = codes[:, 0:dp]
        for k in range(1, cpb):
            packed = packed | (codes[:, k * dp:(k + 1) * dp]
                               << jnp.uint8(k * bits))
    packed_ref[...] = packed
    scale_ref[...] = rng / bins
    zero_ref[...] = lo


@functools.partial(jax.jit,
                   static_argnames=("bits", "stochastic", "block_r",
                                    "interpret"))
def _quant_pack_call(x: jax.Array, seed: jax.Array, *, bits: int,
                     stochastic: bool, block_r: int, interpret: bool):
    rows, d = x.shape
    cpb = 8 // bits
    dp = -(-d // cpb)
    d_pad = dp * cpb
    if d_pad != d:
        # odd feature dim (d % cpb != 0): pad columns, mask them out of
        # the in-kernel minmax, and pack their codes as 0 — the layout
        # matches core.quant.pack_bits' zero-padded chunks, so every
        # downstream consumer (dequant, fused dqmm/SDDMM with tail
        # masking) reads it unchanged. No more silent jnp fallback.
        x = jnp.pad(x, ((0, 0), (0, d_pad - d)))
    block_r = min(block_r, rows)
    grid_r = -(-rows // block_r)
    pad_r = grid_r * block_r - rows
    if pad_r:
        x = jnp.pad(x, ((0, pad_r), (0, 0)))
    kernel = functools.partial(
        _quant_kernel, bits=bits, stochastic=stochastic, block_r=block_r,
        d=d, d_pad=d_pad, dp=dp, cpb=cpb)
    # seed rides in SMEM via scalar prefetch (TPU-idiomatic for scalars)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(grid_r,),
        in_specs=[pl.BlockSpec((block_r, d_pad), lambda i, s: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_r, dp), lambda i, s: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i, s: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i, s: (i, 0)),
        ],
    )
    packed, scale, zero = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((grid_r * block_r, dp), jnp.uint8),
            jax.ShapeDtypeStruct((grid_r * block_r, 1), jnp.float32),
            jax.ShapeDtypeStruct((grid_r * block_r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.uint32), x)
    if pad_r:
        packed, scale, zero = (packed[:rows], scale[:rows], zero[:rows])
    return packed, scale, zero


def quant_pack(x: jax.Array, seed: jax.Array, *, bits: int = 2,
               stochastic: bool = True, block_r: int | None = None,
               interpret: bool = True):
    """Fused quantize+pack. Returns (packed, scale, zero).

    x    : (rows, d) fp32/bf16 — callers flatten leading dims. Any d
           works: ``d % (8/bits) != 0`` pads one partial chunk in-kernel
           (masked minmax, zero pad codes) instead of falling back.
    seed : uint32 scalar (see hashrng.key_to_seed).

    ``block_r=None`` consults the autotune cache (measured winners per
    shape-bucket/bits/backend), defaulting to the old fixed 256.
    """
    rows, d = x.shape
    if block_r is None:
        tuner = autotune.get()
        measure = None
        if tuner.sweep and not isinstance(x, jax.core.Tracer):
            def measure(params):
                jax.block_until_ready(_quant_pack_call(
                    x, seed, bits=bits, stochastic=stochastic,
                    interpret=interpret, **params))
        block_r = tuner.pick(
            "quant_pack", shapes=(rows, d), bits=bits,
            candidates=[{"block_r": c} for c in (64, 128, 256, 512)],
            measure=measure, default={"block_r": 256})["block_r"]
    return _quant_pack_call(x, seed, bits=bits, stochastic=stochastic,
                            block_r=block_r, interpret=interpret)


def _dequant_kernel(packed_ref, scale_ref, zero_ref, out_ref, *,
                    bits: int, d: int, dp: int, cpb: int, out_dtype):
    packed = packed_ref[...]
    if cpb == 1:
        codes = packed[:, :d].astype(jnp.float32)
    else:
        mask = jnp.uint8(2**bits - 1)
        chunks = [(packed >> jnp.uint8(k * bits)) & mask for k in range(cpb)]
        codes = jnp.concatenate(chunks, axis=-1)[:, :d].astype(jnp.float32)
    out_ref[...] = (codes * scale_ref[...] + zero_ref[...]).astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("bits", "dim", "block_r", "interpret",
                                    "out_dtype"))
def _dequant_unpack_call(packed: jax.Array, scale: jax.Array,
                         zero: jax.Array, *, bits: int, dim: int,
                         block_r: int, out_dtype, interpret: bool):
    rows, dp = packed.shape
    cpb = 8 // bits
    block_r = min(block_r, rows)
    grid_r = -(-rows // block_r)
    pad_r = grid_r * block_r - rows
    if pad_r:
        packed = jnp.pad(packed, ((0, pad_r), (0, 0)))
        scale = jnp.pad(scale, ((0, pad_r), (0, 0)))
        zero = jnp.pad(zero, ((0, pad_r), (0, 0)))
    kernel = functools.partial(_dequant_kernel, bits=bits, d=dim, dp=dp,
                               cpb=cpb, out_dtype=out_dtype)
    out = pl.pallas_call(
        kernel,
        grid=(grid_r,),
        in_specs=[
            pl.BlockSpec((block_r, dp), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid_r * block_r, dim), out_dtype),
        interpret=interpret,
    )(packed, scale, zero)
    return out[:rows] if pad_r else out


def dequant_unpack(packed: jax.Array, scale: jax.Array, zero: jax.Array, *,
                   bits: int, dim: int, block_r: int | None = None,
                   out_dtype=jnp.float32, interpret: bool = True):
    """Fused unpack+dequantize: (rows, dp) uint8 -> (rows, dim) float.

    Handles padded packs (dp·(8/bits) > dim) by slicing the tail.
    ``block_r=None`` consults the autotune cache.
    """
    rows, dp = packed.shape
    if block_r is None:
        tuner = autotune.get()
        measure = None
        if tuner.sweep and not isinstance(packed, jax.core.Tracer):
            def measure(params):
                jax.block_until_ready(_dequant_unpack_call(
                    packed, scale, zero, bits=bits, dim=dim,
                    out_dtype=out_dtype, interpret=interpret, **params))
        block_r = tuner.pick(
            "dequant_unpack", shapes=(rows, dim), bits=bits,
            candidates=[{"block_r": c} for c in (64, 128, 256, 512)],
            measure=measure, default={"block_r": 256})["block_r"]
    return _dequant_unpack_call(packed, scale, zero, bits=bits, dim=dim,
                                block_r=block_r, out_dtype=out_dtype,
                                interpret=interpret)


quant_pack_kernel = _quant_kernel  # exported for tests/inspection
