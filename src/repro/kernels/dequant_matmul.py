"""Pallas TPU kernel: fused dequantize + GEMM for the ACT backward pass.

Computes  dW = x̂ᵀ @ g  where x̂ = dequant(packed, scale, zero) — the weight
gradient ∇Θ = Ĥᵀ∇J of paper Eq. (2) — WITHOUT materializing x̂ in HBM:

    HBM read : packed uint8 (R·d·b/8) + scale/zero (8R) + g (R·N·4)
    HBM write: dW (d·N·4)

The unfused path reads/writes an extra R·d·4 bytes for x̂. Since the
backward of every compressed matmul runs this op, fusing it removes the
dominant extra memory traffic of ACT training (beyond-paper optimization —
the CUDA original dequantizes to a full-precision buffer first).

Tiling: grid (d_tiles, n_tiles, r_tiles), r innermost, fp32 accumulation
into the output tile (standard revisiting pattern). A d-tile must lie
inside a single pack-chunk (block_d divides dp), so its codes live in one
contiguous byte range under one shift — the chunk-interleaved layout from
``quant_pack.py`` makes the unpack a single shift+mask per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune
from .backend import pick_block

__all__ = ["dequant_matmul"]


def _dqmm_kernel(packed_ref, scale_ref, zero_ref, g_ref, out_ref, *,
                 bits: int, dim: int, dp: int, block_d: int):
    di = pl.program_id(0)
    r = pl.program_id(2)
    mask = jnp.uint8(2**bits - 1)
    # which bit-field this d-tile lives in (chunk-interleaved layout)
    chunk = (di * block_d) // dp
    shift = (chunk * bits).astype(jnp.uint8)
    codes = ((packed_ref[...] >> shift) & mask).astype(jnp.float32)
    xhat = codes * scale_ref[...] + zero_ref[...]  # (block_r, block_d)
    # pad features beyond the true dim (dp·cpb > dim packs) contribute 0
    feat = di * block_d + jax.lax.broadcasted_iota(jnp.int32, xhat.shape, 1)
    xhat = jnp.where(feat < dim, xhat, 0.0)
    acc = jax.lax.dot_general(
        xhat, g_ref[...].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (block_d, block_n)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(r > 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit,
                   static_argnames=("bits", "dim", "block_r", "block_n",
                                    "block_d", "interpret"))
def _dqmm_call(packed: jax.Array, scale: jax.Array, zero: jax.Array,
               g: jax.Array, *, bits: int, dim: int,
               block_r: int, block_n: int, block_d: int, interpret: bool):
    rows, dp = packed.shape
    _, n = g.shape
    cpb = 8 // bits
    d_pad = dp * cpb                   # >= dim when the pack was padded

    assert dp % block_d == 0, (dp, block_d)
    block_r = min(block_r, rows)
    block_n = min(block_n, n)

    grid_r = -(-rows // block_r)
    grid_n = -(-n // block_n)
    grid_d = d_pad // block_d
    pad_r = grid_r * block_r - rows
    pad_n = grid_n * block_n - n
    if pad_r:
        packed = jnp.pad(packed, ((0, pad_r), (0, 0)))
        scale = jnp.pad(scale, ((0, pad_r), (0, 0)))  # pad rows dequant to 0
        zero = jnp.pad(zero, ((0, pad_r), (0, 0)))
        g = jnp.pad(g, ((0, pad_r), (0, 0)))
    if pad_n:
        g = jnp.pad(g, ((0, 0), (0, pad_n)))

    kernel = functools.partial(_dqmm_kernel, bits=bits, dim=dim, dp=dp,
                               block_d=block_d)
    out = pl.pallas_call(
        kernel,
        grid=(grid_d, grid_n, grid_r),
        in_specs=[
            pl.BlockSpec((block_r, block_d),
                         lambda di, ni, ri: (ri, di % (dp // block_d))),
            pl.BlockSpec((block_r, 1), lambda di, ni, ri: (ri, 0)),
            pl.BlockSpec((block_r, 1), lambda di, ni, ri: (ri, 0)),
            pl.BlockSpec((block_r, block_n), lambda di, ni, ri: (ri, ni)),
        ],
        out_specs=pl.BlockSpec((block_d, block_n),
                               lambda di, ni, ri: (di, ni)),
        out_shape=jax.ShapeDtypeStruct((d_pad, grid_n * block_n),
                                       jnp.float32),
        interpret=interpret,
    )(packed, scale, zero, g)
    return out[:dim, :n]


def dequant_matmul(packed: jax.Array, scale: jax.Array, zero: jax.Array,
                   g: jax.Array, *, bits: int, dim: int,
                   block_r: int | None = None, block_n: int | None = None,
                   block_d: int | None = None, interpret: bool = True):
    """``dequant(packed, scale, zero)ᵀ @ g``.

    packed : (R, dp) uint8 chunk-interleaved codes, dp·(8/bits) >= dim
             (pad features beyond ``dim`` are masked to zero in-kernel)
    scale  : (R, 1) fp32, zero: (R, 1) fp32
    g      : (R, N) float
    returns: (dim, N) fp32

    Tile sizes not passed explicitly come from the autotune cache
    (measured winners per shape-bucket/bits/backend), defaulting to the
    old ``_pick_block(dp, 512)`` / 256 heuristics on a miss.
    """
    rows, dp = packed.shape
    _, n = g.shape
    cpb = 8 // bits
    assert dp * cpb >= dim, f"packed dim mismatch: {dp}*{cpb} < {dim}"

    if block_r is None or block_n is None or block_d is None:
        divisors = sorted({pick_block(dp, c) for c in (128, 256, 512)})
        default = {"block_r": 256, "block_n": 256,
                   "block_d": pick_block(dp, 512)}
        tuner = autotune.get()
        concrete = not any(isinstance(a, jax.core.Tracer)
                           for a in (packed, g))
        measure = None
        if concrete and tuner.sweep:
            def measure(params):
                jax.block_until_ready(_dqmm_call(
                    packed, scale, zero, g, bits=bits, dim=dim,
                    interpret=interpret, **params))
        picked = tuner.pick(
            "dequant_matmul", shapes=(rows, dim, n), bits=bits,
            candidates=[{"block_r": br, "block_n": bn, "block_d": bd}
                        for br in (128, 256, 512)
                        for bn in (128, 256)
                        for bd in divisors],
            measure=measure, default=default)
        block_r = block_r if block_r is not None else picked["block_r"]
        block_n = block_n if block_n is not None else picked["block_n"]
        block_d = block_d if block_d is not None else picked["block_d"]
    return _dqmm_call(packed, scale, zero, g, bits=bits, dim=dim,
                      block_r=block_r, block_n=block_n, block_d=block_d,
                      interpret=interpret)
