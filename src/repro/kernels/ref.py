"""Pure-jnp oracles for the Pallas kernels (bit-exact references).

``ref_quant_pack`` mirrors the kernel's counter-hash SR draws element-for-
element, so kernel-vs-ref comparisons are exact equality on the packed
codes, not just statistical agreement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import pack_bits, unpack_bits

from .hashrng import hash_uniform

__all__ = ["ref_quant_pack", "ref_dequant_unpack", "ref_dequant_matmul"]

_EPS = 1e-12


def ref_quant_pack(x: jax.Array, seed: jax.Array, *, bits: int,
                   stochastic: bool = True):
    """Oracle for quant_pack: returns (packed, scale, zero)."""
    rows, d = x.shape
    xf = x.astype(jnp.float32)
    bins = float(2**bits - 1)
    lo = jnp.min(xf, axis=-1, keepdims=True)
    hi = jnp.max(xf, axis=-1, keepdims=True)
    rng = hi - lo
    normed = (xf - lo) * (bins / jnp.maximum(rng, _EPS))
    if stochastic:
        gidx = (jnp.arange(rows, dtype=jnp.uint32)[:, None] * jnp.uint32(d)
                + jnp.arange(d, dtype=jnp.uint32)[None, :])
        u = hash_uniform(gidx, jnp.asarray(seed, jnp.uint32))
        floor = jnp.floor(normed)
        codes_f = floor + (u < (normed - floor)).astype(jnp.float32)
    else:
        codes_f = jnp.round(normed)
    codes = jnp.clip(codes_f, 0.0, bins).astype(jnp.uint8)
    return pack_bits(codes, bits), rng / bins, lo


def ref_dequant_unpack(packed, scale, zero, *, bits: int, dim: int,
                       out_dtype=jnp.float32):
    codes = unpack_bits(packed, bits, dim).astype(jnp.float32)
    return (codes * scale + zero).astype(out_dtype)


def ref_dequant_matmul(packed, scale, zero, g, *, bits: int, dim: int):
    """Oracle for dequant_matmul: dequantize then plain fp32 GEMM."""
    xhat = ref_dequant_unpack(packed, scale, zero, bits=bits, dim=dim)
    return xhat.T.astype(jnp.float32) @ g.astype(jnp.float32)
