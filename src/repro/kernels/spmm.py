"""Pallas TPU kernels: fused SPMM for KG message passing (paper Eq. 2).

The op is ``out[v] = Σ_{e=(u→v)} ew[e] · x[u]``. The unfused jnp path
(``x[src] * ew → segment_sum``) materializes the full ``(E, d)`` message
tensor in HBM on the forward pass and again (``g[dst] * ew``) on the
backward — at 3 layers × industry-scale E this dwarfs what the quantizer
saves. The kernels here never form it:

  ``spmm``             forward / transpose aggregation. Per edge block:
                       gather source rows into VMEM, scale by edge
                       weights, accumulate into the destination tile via
                       a one-hot MXU matmul (the TPU-idiomatic
                       scatter-add). HBM traffic: gather reads + one
                       ``(N, d)`` output write — no ``(E, d)`` tensor.
  ``sddmm_ew``         backward ∇ew = ⟨x̂[src], g[dst]⟩ per edge, fp32
                       residuals.
  ``dequant_sddmm_ew`` same, reading the *packed* QTensor residual
                       directly — shift+mask in-kernel per feature tile,
                       mirroring ``dequant_matmul`` — so the b-bit
                       residual never dequantizes to a full fp32 buffer.

Edges arrive pre-blocked by ``repro.data.csr.build_spmm_layout``: each
``(1, block_e)`` slot block belongs to exactly one destination tile, and
a tile's blocks are consecutive in the grid, so the output tile is
accumulated across a contiguous run of grid steps (init on the first
block of each tile — the standard revisiting pattern, steered by the
scalar-prefetched ``tile_of_blk`` array in SMEM).

Two residency strategies for the gathered-from tables, dispatched by
``repro.kernels.ops`` against ``backend.vmem_budget_bytes()``:

  * **VMEM-resident** (``dma=False``): the node table rides in VMEM
    blocked over the feature dim only (``(N, block_d)``); in-kernel
    gathers are ``jnp.take`` over the sublane dim. Fastest while the
    table fits.
  * **HBM + double-buffered DMA** (``dma=True``): the table stays in HBM
    (``memory_space=ANY``); each grid step's ``block_e`` source rows are
    gathered by per-row async copies into a two-slot VMEM scratch, with
    block ``e+1``'s gather issued before block ``e`` is consumed — DMA
    overlaps the one-hot matmul. The per-block source-id vector is
    itself DMA'd into SMEM scratch first (DMA descriptors need scalar
    addresses). This removes the whole-table-in-VMEM assumption
    (DESIGN.md §4's upgrade path, now §10); grid, layout, and numerics
    are identical to the VMEM path — the parity suite runs both.

Tile sizes (``block_d``) come from ``repro.kernels.autotune`` when not
passed explicitly — measured winners per (op, shape-bucket, backend),
falling back to the old ``min(d, 512)`` heuristic on a cache miss.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import autotune as _autotune
from .backend import pick_block as _pick_block

__all__ = ["spmm", "sddmm_ew", "dequant_sddmm_ew"]

_BLOCK_D_CANDIDATES = (128, 256, 512)


def _is_concrete(*arrays) -> bool:
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def _tuned_block_d(op: str, *, shapes, bits=None, default: int,
                   candidates, measure_factory=None) -> int:
    """Consult the autotune cache (and sweep when enabled + concrete)."""
    tuner = _autotune.get()
    measure = None
    if measure_factory is not None and tuner.sweep:
        def measure(params):
            jax.block_until_ready(measure_factory(params["block_d"]))
    return tuner.pick(
        op, shapes=shapes, bits=bits,
        candidates=[{"block_d": c} for c in candidates],
        measure=measure, default={"block_d": default})["block_d"]


# ---------------------------------------------------------------------------
# forward / transpose aggregation — VMEM-resident node table
# ---------------------------------------------------------------------------


def _spmm_kernel(tile_ref, src_ref, ldst_ref, ew_ref, x_ref, out_ref, *,
                 block_rows: int, block_e: int):
    e = pl.program_id(1)
    tile = tile_ref[e]
    prev = tile_ref[jnp.maximum(e, 1) - 1]
    first = jnp.logical_or(e == 0, tile != prev)

    src = src_ref[0, :]                                   # (block_e,)
    msgs = jnp.take(x_ref[...], src, axis=0).astype(jnp.float32)
    msgs = msgs * ew_ref[0, :][:, None]                   # pads carry ew=0
    # one-hot scatter-add on the MXU: (rows, E_b) @ (E_b, d)
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_e), 0)
    onehot = (rows == ldst_ref[0, :][None, :]).astype(jnp.float32)
    acc = jax.lax.dot_general(
        onehot, msgs,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(first)
    def _init():
        out_ref[...] = acc

    @pl.when(jnp.logical_not(first))
    def _accum():
        out_ref[...] += acc


def _direction(layout, transpose: bool):
    m = layout.meta
    if transpose:
        return (layout.t_src_blk, layout.t_ldst_blk, layout.t_perm_blk,
                layout.t_tile_of_blk, m.t_n_blocks, m.t_n_tiles, m.n_src)
    return (layout.src_blk, layout.ldst_blk, layout.perm_blk,
            layout.tile_of_blk, m.n_blocks, m.n_tiles, m.n_dst)


def _ew_slots(ew, perm_blk, n_edges: int):
    # one gather permutes ew into slot order AND zeroes pad lanes
    # (pad slots carry perm == n_edges, pointing at the appended zero)
    w = jnp.ones((n_edges,), jnp.float32) if ew is None \
        else ew.astype(jnp.float32)
    return jnp.concatenate([w, jnp.zeros((1,), jnp.float32)])[perm_blk]


@functools.partial(jax.jit, static_argnames=("transpose", "block_d",
                                             "interpret"))
def _spmm_vmem(x, ew, layout, *, transpose: bool, block_d: int,
               interpret: bool):
    m = layout.meta
    src_blk, ldst_blk, perm_blk, tile_of, nb, n_tiles, n_out = \
        _direction(layout, transpose)
    rows, d = x.shape
    ew_slots = _ew_slots(ew, perm_blk, m.n_edges)

    grid_d = -(-d // block_d)
    pad_d = grid_d * block_d - d
    xf = x.astype(jnp.float32)
    if pad_d:
        xf = jnp.pad(xf, ((0, 0), (0, pad_d)))

    kernel = functools.partial(_spmm_kernel, block_rows=m.block_rows,
                               block_e=m.block_e)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(grid_d, nb),             # edge blocks innermost: a tile's
        in_specs=[                     # output accumulates consecutively
            pl.BlockSpec((1, m.block_e), lambda di, e, s: (e, 0)),
            pl.BlockSpec((1, m.block_e), lambda di, e, s: (e, 0)),
            pl.BlockSpec((1, m.block_e), lambda di, e, s: (e, 0)),
            pl.BlockSpec((rows, block_d), lambda di, e, s: (0, di)),
        ],
        out_specs=pl.BlockSpec((m.block_rows, block_d),
                               lambda di, e, s: (s[e], di)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_tiles * m.block_rows, grid_d * block_d), jnp.float32),
        interpret=interpret,
    )(tile_of, src_blk, ldst_blk, ew_slots, xf)
    return out[:n_out, :d].astype(x.dtype)


# ---------------------------------------------------------------------------
# forward / transpose aggregation — HBM table, double-buffered DMA gather
# ---------------------------------------------------------------------------


def _spmm_dma_kernel(tile_ref, ldst_ref, ew_ref, src_hbm, x_hbm, out_ref,
                     idx_smem, buf, idx_sem, dat_sem, *,
                     block_rows: int, block_e: int, block_d: int, nb: int):
    di = pl.program_id(0)
    e = pl.program_id(1)
    tile = tile_ref[e]
    prev = tile_ref[jnp.maximum(e, 1) - 1]
    first = jnp.logical_or(e == 0, tile != prev)

    def idx_fetch(slot, blk):
        # the per-block source-id vector, synchronously into SMEM: DMA
        # descriptors below need scalar addresses. block_e·4 bytes — its
        # latency hides behind the previous block's row gathers.
        cp = pltpu.make_async_copy(src_hbm.at[pl.ds(blk, 1), :],
                                   idx_smem.at[slot], idx_sem.at[slot])
        cp.start()
        cp.wait()

    def row_copy(slot, i, di_):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(idx_smem[slot, 0, i], 1),
                     pl.ds(di_ * block_d, block_d)],
            buf.at[slot, pl.ds(i, 1), :],
            dat_sem.at[slot])

    def rows_start(slot, di_):
        def body(i, _):
            row_copy(slot, i, di_).start()
            return 0
        jax.lax.fori_loop(0, block_e, body, 0)

    def rows_wait(slot, di_):
        def body(i, _):
            row_copy(slot, i, di_).wait()
            return 0
        jax.lax.fori_loop(0, block_e, body, 0)

    @pl.when(e == 0)
    def _warmup():
        idx_fetch(0, 0)
        rows_start(0, di)

    @pl.when(e + 1 < nb)
    def _prefetch():                      # overlap next gather w/ compute
        idx_fetch((e + 1) % 2, e + 1)
        rows_start((e + 1) % 2, di)

    slot = jax.lax.rem(e, 2)
    rows_wait(slot, di)
    msgs = buf[slot] * ew_ref[0, :][:, None]          # pads carry ew=0
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_e), 0)
    onehot = (rows == ldst_ref[0, :][None, :]).astype(jnp.float32)
    acc = jax.lax.dot_general(
        onehot, msgs,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(first)
    def _init():
        out_ref[...] = acc

    @pl.when(jnp.logical_not(first))
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("transpose", "block_d",
                                             "interpret"))
def _spmm_dma(x, ew, layout, *, transpose: bool, block_d: int,
              interpret: bool):
    m = layout.meta
    src_blk, ldst_blk, perm_blk, tile_of, nb, n_tiles, n_out = \
        _direction(layout, transpose)
    rows, d = x.shape
    ew_slots = _ew_slots(ew, perm_blk, m.n_edges)

    grid_d = -(-d // block_d)
    pad_d = grid_d * block_d - d
    xf = x.astype(jnp.float32)
    if pad_d:
        xf = jnp.pad(xf, ((0, 0), (0, pad_d)))

    kernel = functools.partial(_spmm_dma_kernel, block_rows=m.block_rows,
                               block_e=m.block_e, block_d=block_d, nb=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(grid_d, nb),
        in_specs=[
            pl.BlockSpec((1, m.block_e), lambda di, e, s: (e, 0)),
            pl.BlockSpec((1, m.block_e), lambda di, e, s: (e, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # src ids stay in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # node table stays in HBM
        ],
        out_specs=pl.BlockSpec((m.block_rows, block_d),
                               lambda di, e, s: (s[e], di)),
        scratch_shapes=[
            pltpu.SMEM((2, 1, m.block_e), jnp.int32),
            pltpu.VMEM((2, m.block_e, block_d), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_tiles * m.block_rows, grid_d * block_d), jnp.float32),
        interpret=interpret,
    )(tile_of, ldst_blk, ew_slots, src_blk, xf)
    return out[:n_out, :d].astype(x.dtype)


def spmm(x: jax.Array, ew: jax.Array | None, layout, *,
         transpose: bool = False, block_d: int | None = None,
         interpret: bool = True, dma: bool = False) -> jax.Array:
    """Fused gather + scale + segment-accumulate over a blocked-CSR layout.

    x   : (n_src, d) float — the gathered-from table (activations
          forward; output gradient for the transpose/∇x direction).
    ew  : (E,) float edge weights in ORIGINAL edge order, or None for
          unweighted aggregation (plain adjacency).
    dma : gather from an HBM-resident table via double-buffered async
          copies instead of assuming the table fits in VMEM (callers
          dispatch on ``backend.vmem_budget_bytes()``; see ``ops.spmm``).
    returns (n_out, d) in x.dtype, n_out = n_dst (fwd) / n_src (transpose).
    """
    rows, d = x.shape
    if block_d is None:
        impl = _spmm_dma if dma else _spmm_vmem
        block_d = _tuned_block_d(
            "spmm_dma" if dma else "spmm",
            shapes=(rows, d, layout.meta.n_edges), default=min(d, 512),
            candidates=[c for c in _BLOCK_D_CANDIDATES if c <= max(d, 128)],
            measure_factory=(
                (lambda bd: impl(x, ew, layout, transpose=transpose,
                                 block_d=bd, interpret=interpret))
                if _is_concrete(x, ew) else None))
    impl = _spmm_dma if dma else _spmm_vmem
    return impl(x, ew, layout, transpose=transpose, block_d=block_d,
                interpret=interpret)


# ---------------------------------------------------------------------------
# backward ∇ew: SDDMM (sampled dense-dense matmul over the edge pattern)
# ---------------------------------------------------------------------------


def _scatter_dew(dew_slots: jax.Array, perm_blk: jax.Array,
                 n_edges: int) -> jax.Array:
    """Per-slot partials -> (E,) in original edge order; pads dropped."""
    return jnp.zeros((n_edges,), jnp.float32).at[perm_blk.reshape(-1)].add(
        dew_slots.reshape(-1), mode="drop")


def _sddmm_kernel(src_ref, dst_ref, x_ref, g_ref, out_ref):
    di = pl.program_id(1)
    xr = jnp.take(x_ref[...], src_ref[0, :], axis=0).astype(jnp.float32)
    gr = jnp.take(g_ref[...], dst_ref[0, :], axis=0).astype(jnp.float32)
    part = jnp.sum(xr * gr, axis=-1)                      # (block_e,)

    @pl.when(di == 0)
    def _init():
        out_ref[0, :] = part

    @pl.when(di > 0)
    def _accum():
        out_ref[0, :] += part


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def _sddmm_call(x, g, layout, *, block_d: int, interpret: bool):
    m = layout.meta
    n_src, d = x.shape
    grid_d = -(-d // block_d)
    pad_d = grid_d * block_d - d
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    if pad_d:
        xf = jnp.pad(xf, ((0, 0), (0, pad_d)))
        gf = jnp.pad(gf, ((0, 0), (0, pad_d)))

    out = pl.pallas_call(
        _sddmm_kernel,
        grid=(m.n_blocks, grid_d),     # feature tiles innermost: the
        in_specs=[                     # (1, block_e) out row accumulates
            pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
            pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
            pl.BlockSpec((n_src, block_d), lambda e, di: (0, di)),
            pl.BlockSpec((gf.shape[0], block_d), lambda e, di: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
        out_shape=jax.ShapeDtypeStruct((m.n_blocks, m.block_e), jnp.float32),
        interpret=interpret,
    )(layout.src_blk, layout.dstg_blk, xf, gf)
    return _scatter_dew(out, layout.perm_blk, m.n_edges)


def sddmm_ew(x: jax.Array, g: jax.Array, layout, *,
             block_d: int | None = None,
             interpret: bool = True) -> jax.Array:
    """∇ew[e] = ⟨x[src_e], g[dst_e]⟩ — fp32 residual path.

    x : (n_src, d) saved activation, g : (n_dst, d) output gradient.
    returns (E,) fp32 in original edge order.
    """
    n_src, d = x.shape
    if block_d is None:
        block_d = _tuned_block_d(
            "sddmm", shapes=(n_src, d, layout.meta.n_edges),
            default=min(d, 512),
            candidates=[c for c in _BLOCK_D_CANDIDATES if c <= max(d, 128)],
            measure_factory=(
                (lambda bd: _sddmm_call(x, g, layout, block_d=bd,
                                        interpret=interpret))
                if _is_concrete(x, g) else None))
    return _sddmm_call(x, g, layout, block_d=block_d, interpret=interpret)


def _dq_sddmm_kernel(src_ref, dst_ref, packed_ref, scale_ref, zero_ref,
                     g_ref, out_ref, *, bits: int, dim: int, dp: int,
                     block_d: int):
    di = pl.program_id(1)
    src = src_ref[0, :]
    # which bit-field this feature tile lives in (chunk-interleaved pack)
    chunk = (di * block_d) // dp
    shift = (chunk * bits).astype(jnp.uint8)
    mask = jnp.uint8(2**bits - 1)
    prows = jnp.take(packed_ref[...], src, axis=0)        # (block_e, block_d)
    codes = ((prows >> shift) & mask).astype(jnp.float32)
    xhat = codes * jnp.take(scale_ref[...], src, axis=0) \
        + jnp.take(zero_ref[...], src, axis=0)
    # pad features beyond the true dim (dp·cpb > dim packs) contribute 0
    feat = di * block_d + jax.lax.broadcasted_iota(
        jnp.int32, xhat.shape, 1)
    xhat = jnp.where(feat < dim, xhat, 0.0)
    gr = jnp.take(g_ref[...], dst_ref[0, :], axis=0).astype(jnp.float32)
    part = jnp.sum(xhat * gr, axis=-1)

    @pl.when(di == 0)
    def _init():
        out_ref[0, :] = part

    @pl.when(di > 0)
    def _accum():
        out_ref[0, :] += part


@functools.partial(jax.jit, static_argnames=("bits", "dim", "block_d",
                                             "interpret"))
def _dq_sddmm_call(packed, scale, zero, g, layout, *, bits: int, dim: int,
                   block_d: int, interpret: bool):
    m = layout.meta
    n_src, dp = packed.shape
    cpb = 8 // bits
    d_pad = dp * cpb                   # >= dim when the pack was padded
    assert dp % block_d == 0, (dp, block_d)
    grid_d = d_pad // block_d
    nbt = dp // block_d                # distinct byte tiles (reused cpb×)
    gf = g.astype(jnp.float32)
    pad_g = d_pad - g.shape[1]
    if pad_g:
        gf = jnp.pad(gf, ((0, 0), (0, pad_g)))

    kernel = functools.partial(_dq_sddmm_kernel, bits=bits, dim=dim, dp=dp,
                               block_d=block_d)
    out = pl.pallas_call(
        kernel,
        grid=(m.n_blocks, grid_d),
        in_specs=[
            pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
            pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
            pl.BlockSpec((n_src, block_d), lambda e, di: (0, di % nbt)),
            pl.BlockSpec((n_src, 1), lambda e, di: (0, 0)),
            pl.BlockSpec((n_src, 1), lambda e, di: (0, 0)),
            pl.BlockSpec((gf.shape[0], block_d), lambda e, di: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
        out_shape=jax.ShapeDtypeStruct((m.n_blocks, m.block_e), jnp.float32),
        interpret=interpret,
    )(layout.src_blk, layout.dstg_blk, packed, scale, zero, gf)
    return _scatter_dew(out, layout.perm_blk, m.n_edges)


# -- HBM tables + double-buffered DMA (packed codes and g both streamed) ----


def _dq_sddmm_dma_kernel(src_ref, dst_ref, scale_ref, zero_ref,
                         src_hbm, dst_hbm, packed_hbm, g_hbm, out_ref,
                         idx_smem, pbuf, gbuf, idx_sem, p_sem, g_sem, *,
                         bits: int, dim: int, dp: int, block_e: int,
                         d_pad: int, nb: int):
    e = pl.program_id(0)

    def idx_fetch(slot, blk):
        # src ids then dst ids into the two SMEM rows of this slot
        for hbm, row in ((src_hbm, 0), (dst_hbm, 1)):
            cp = pltpu.make_async_copy(hbm.at[pl.ds(blk, 1), :],
                                       idx_smem.at[slot, pl.ds(row, 1), :],
                                       idx_sem.at[slot])
            cp.start()
            cp.wait()

    def row_copies(slot, i):
        return (
            pltpu.make_async_copy(
                packed_hbm.at[pl.ds(idx_smem[slot, 0, i], 1), :],
                pbuf.at[slot, pl.ds(i, 1), :], p_sem.at[slot]),
            pltpu.make_async_copy(
                g_hbm.at[pl.ds(idx_smem[slot, 1, i], 1), :],
                gbuf.at[slot, pl.ds(i, 1), :], g_sem.at[slot]),
        )

    def rows_start(slot):
        def body(i, _):
            for cp in row_copies(slot, i):
                cp.start()
            return 0
        jax.lax.fori_loop(0, block_e, body, 0)

    def rows_wait(slot):
        def body(i, _):
            for cp in row_copies(slot, i):
                cp.wait()
            return 0
        jax.lax.fori_loop(0, block_e, body, 0)

    @pl.when(e == 0)
    def _warmup():
        idx_fetch(0, 0)
        rows_start(0)

    @pl.when(e + 1 < nb)
    def _prefetch():
        idx_fetch((e + 1) % 2, e + 1)
        rows_start((e + 1) % 2)

    slot = jax.lax.rem(e, 2)
    rows_wait(slot)

    cpb = 8 // bits
    packed = pbuf[slot]                                   # (block_e, dp)
    mask = jnp.uint8(2**bits - 1)
    if cpb == 1:
        codes = packed.astype(jnp.float32)
    else:
        chunks = [(packed >> jnp.uint8(k * bits)) & mask
                  for k in range(cpb)]
        codes = jnp.concatenate(chunks, axis=-1).astype(jnp.float32)
    src = src_ref[0, :]
    xhat = codes * jnp.take(scale_ref[...], src, axis=0) \
        + jnp.take(zero_ref[...], src, axis=0)            # (block_e, d_pad)
    feat = jax.lax.broadcasted_iota(jnp.int32, xhat.shape, 1)
    xhat = jnp.where(feat < dim, xhat, 0.0)
    gr = gbuf[slot][:, :d_pad]
    out_ref[0, :] = jnp.sum(xhat * gr, axis=-1)


@functools.partial(jax.jit, static_argnames=("bits", "dim", "interpret"))
def _dq_sddmm_dma(packed, scale, zero, g, layout, *, bits: int, dim: int,
                  interpret: bool):
    m = layout.meta
    n_src, dp = packed.shape
    cpb = 8 // bits
    d_pad = dp * cpb
    gf = g.astype(jnp.float32)
    pad_g = d_pad - g.shape[1]
    if pad_g > 0:
        gf = jnp.pad(gf, ((0, 0), (0, pad_g)))

    kernel = functools.partial(
        _dq_sddmm_dma_kernel, bits=bits, dim=dim, dp=dp,
        block_e=m.block_e, d_pad=d_pad, nb=m.n_blocks)
    out = pl.pallas_call(
        kernel,
        grid=(m.n_blocks,),
        in_specs=[
            pl.BlockSpec((1, m.block_e), lambda e: (e, 0)),
            pl.BlockSpec((1, m.block_e), lambda e: (e, 0)),
            # per-row scale/zero stay VMEM-resident: 8 bytes/row, 64×
            # smaller than the d=128 fp32 table the DMA path sheds
            pl.BlockSpec((n_src, 1), lambda e: (0, 0)),
            pl.BlockSpec((n_src, 1), lambda e: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # src ids
            pl.BlockSpec(memory_space=pltpu.ANY),   # dst ids
            pl.BlockSpec(memory_space=pltpu.ANY),   # packed codes (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),   # g (HBM)
        ],
        out_specs=pl.BlockSpec((1, m.block_e), lambda e: (e, 0)),
        out_shape=jax.ShapeDtypeStruct((m.n_blocks, m.block_e), jnp.float32),
        scratch_shapes=[
            pltpu.SMEM((2, 2, m.block_e), jnp.int32),
            pltpu.VMEM((2, m.block_e, dp), jnp.uint8),
            pltpu.VMEM((2, m.block_e, d_pad), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(layout.src_blk, layout.dstg_blk, scale, zero,
      layout.src_blk, layout.dstg_blk, packed, gf)
    return _scatter_dew(out, layout.perm_blk, m.n_edges)


def dequant_sddmm_ew(packed: jax.Array, scale: jax.Array, zero: jax.Array,
                     g: jax.Array, layout, *, bits: int, dim: int,
                     block_d: int | None = None,
                     interpret: bool = True, dma: bool = False) -> jax.Array:
    """∇ew from the *packed* b-bit residual — shift+mask in-kernel.

    packed : (n_src, dp) uint8 chunk-interleaved codes, dp·(8/bits) >= dim
             (pad features beyond ``dim`` are masked to zero in-kernel)
    scale/zero : (n_src, 1) fp32, g : (n_dst, dim) float.
    dma    : stream packed rows and g rows from HBM with double-buffered
             async copies instead of holding both tables in VMEM.
    returns (E,) fp32 in original edge order.
    """
    n_src, dp = packed.shape
    cpb = 8 // bits
    assert dp * cpb >= dim, f"packed dim mismatch: {dp}*{cpb} < {dim}"
    if dma:
        return _dq_sddmm_dma(packed, scale, zero, g, layout, bits=bits,
                             dim=dim, interpret=interpret)
    if block_d is None:
        default = _pick_block(dp, 512)
        divisors = sorted({_pick_block(dp, c) for c in _BLOCK_D_CANDIDATES})
        block_d = _tuned_block_d(
            "dequant_sddmm", shapes=(n_src, dim, layout.meta.n_edges),
            bits=bits, default=default, candidates=divisors,
            measure_factory=(
                (lambda bd: _dq_sddmm_call(packed, scale, zero, g, layout,
                                           bits=bits, dim=dim, block_d=bd,
                                           interpret=interpret))
                if _is_concrete(packed, g) else None))
    return _dq_sddmm_call(packed, scale, zero, g, layout, bits=bits,
                          dim=dim, block_d=block_d, interpret=interpret)
