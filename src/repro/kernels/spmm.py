"""Pallas TPU kernels: fused SPMM for KG message passing (paper Eq. 2).

The op is ``out[v] = Σ_{e=(u→v)} ew[e] · x[u]``. The unfused jnp path
(``x[src] * ew → segment_sum``) materializes the full ``(E, d)`` message
tensor in HBM on the forward pass and again (``g[dst] * ew``) on the
backward — at 3 layers × industry-scale E this dwarfs what the quantizer
saves. The kernels here never form it:

  ``spmm``             forward / transpose aggregation. Per edge block:
                       gather source rows into VMEM, scale by edge
                       weights, accumulate into the destination tile via
                       a one-hot MXU matmul (the TPU-idiomatic
                       scatter-add). HBM traffic: gather reads + one
                       ``(N, d)`` output write — no ``(E, d)`` tensor.
  ``sddmm_ew``         backward ∇ew = ⟨x̂[src], g[dst]⟩ per edge, fp32
                       residuals.
  ``dequant_sddmm_ew`` same, reading the *packed* QTensor residual
                       directly — shift+mask in-kernel per feature tile,
                       mirroring ``dequant_matmul`` — so the b-bit
                       residual never dequantizes to a full fp32 buffer.

Edges arrive pre-blocked by ``repro.data.csr.build_spmm_layout``: each
``(1, block_e)`` slot block belongs to exactly one destination tile, and
a tile's blocks are consecutive in the grid, so the output tile is
accumulated across a contiguous run of grid steps (init on the first
block of each tile — the standard revisiting pattern, steered by the
scalar-prefetched ``tile_of_blk`` array in SMEM).

The node table rides in VMEM blocked over the feature dim only
(``(N, block_d)``); in-kernel gathers are ``jnp.take`` over the sublane
dim. For CKGs whose node table outgrows VMEM, the upgrade path is
per-tile DMA gathers from HBM (see DESIGN.md §4) — the layout already
carries everything that needs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["spmm", "sddmm_ew", "dequant_sddmm_ew"]


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target."""
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# forward / transpose aggregation
# ---------------------------------------------------------------------------


def _spmm_kernel(tile_ref, src_ref, ldst_ref, ew_ref, x_ref, out_ref, *,
                 block_rows: int, block_e: int):
    e = pl.program_id(1)
    tile = tile_ref[e]
    prev = tile_ref[jnp.maximum(e, 1) - 1]
    first = jnp.logical_or(e == 0, tile != prev)

    src = src_ref[0, :]                                   # (block_e,)
    msgs = jnp.take(x_ref[...], src, axis=0).astype(jnp.float32)
    msgs = msgs * ew_ref[0, :][:, None]                   # pads carry ew=0
    # one-hot scatter-add on the MXU: (rows, E_b) @ (E_b, d)
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_e), 0)
    onehot = (rows == ldst_ref[0, :][None, :]).astype(jnp.float32)
    acc = jax.lax.dot_general(
        onehot, msgs,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(first)
    def _init():
        out_ref[...] = acc

    @pl.when(jnp.logical_not(first))
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("transpose", "block_d",
                                             "interpret"))
def spmm(x: jax.Array, ew: jax.Array | None, layout, *,
         transpose: bool = False, block_d: int | None = None,
         interpret: bool = True) -> jax.Array:
    """Fused gather + scale + segment-accumulate over a blocked-CSR layout.

    x   : (n_src, d) float — the gathered-from table (activations
          forward; output gradient for the transpose/∇x direction).
    ew  : (E,) float edge weights in ORIGINAL edge order, or None for
          unweighted aggregation (plain adjacency).
    returns (n_out, d) in x.dtype, n_out = n_dst (fwd) / n_src (transpose).
    """
    m = layout.meta
    if transpose:
        src_blk, ldst_blk = layout.t_src_blk, layout.t_ldst_blk
        perm_blk, tile_of = layout.t_perm_blk, layout.t_tile_of_blk
        nb, n_tiles, n_out = m.t_n_blocks, m.t_n_tiles, m.n_src
    else:
        src_blk, ldst_blk = layout.src_blk, layout.ldst_blk
        perm_blk, tile_of = layout.perm_blk, layout.tile_of_blk
        nb, n_tiles, n_out = m.n_blocks, m.n_tiles, m.n_dst
    rows, d = x.shape

    # one gather permutes ew into slot order AND zeroes pad lanes
    # (pad slots carry perm == n_edges, pointing at the appended zero)
    w = jnp.ones((m.n_edges,), jnp.float32) if ew is None \
        else ew.astype(jnp.float32)
    ew_slots = jnp.concatenate([w, jnp.zeros((1,), jnp.float32)])[perm_blk]

    if block_d is None:
        block_d = min(d, 512)
    grid_d = -(-d // block_d)
    pad_d = grid_d * block_d - d
    xf = x.astype(jnp.float32)
    if pad_d:
        xf = jnp.pad(xf, ((0, 0), (0, pad_d)))

    kernel = functools.partial(_spmm_kernel, block_rows=m.block_rows,
                               block_e=m.block_e)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(grid_d, nb),             # edge blocks innermost: a tile's
        in_specs=[                     # output accumulates consecutively
            pl.BlockSpec((1, m.block_e), lambda di, e, s: (e, 0)),
            pl.BlockSpec((1, m.block_e), lambda di, e, s: (e, 0)),
            pl.BlockSpec((1, m.block_e), lambda di, e, s: (e, 0)),
            pl.BlockSpec((rows, block_d), lambda di, e, s: (0, di)),
        ],
        out_specs=pl.BlockSpec((m.block_rows, block_d),
                               lambda di, e, s: (s[e], di)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_tiles * m.block_rows, grid_d * block_d), jnp.float32),
        interpret=interpret,
    )(tile_of, src_blk, ldst_blk, ew_slots, xf)
    return out[:n_out, :d].astype(x.dtype)


# ---------------------------------------------------------------------------
# backward ∇ew: SDDMM (sampled dense-dense matmul over the edge pattern)
# ---------------------------------------------------------------------------


def _scatter_dew(dew_slots: jax.Array, perm_blk: jax.Array,
                 n_edges: int) -> jax.Array:
    """Per-slot partials -> (E,) in original edge order; pads dropped."""
    return jnp.zeros((n_edges,), jnp.float32).at[perm_blk.reshape(-1)].add(
        dew_slots.reshape(-1), mode="drop")


def _sddmm_kernel(src_ref, dst_ref, x_ref, g_ref, out_ref):
    di = pl.program_id(1)
    xr = jnp.take(x_ref[...], src_ref[0, :], axis=0).astype(jnp.float32)
    gr = jnp.take(g_ref[...], dst_ref[0, :], axis=0).astype(jnp.float32)
    part = jnp.sum(xr * gr, axis=-1)                      # (block_e,)

    @pl.when(di == 0)
    def _init():
        out_ref[0, :] = part

    @pl.when(di > 0)
    def _accum():
        out_ref[0, :] += part


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def sddmm_ew(x: jax.Array, g: jax.Array, layout, *,
             block_d: int | None = None,
             interpret: bool = True) -> jax.Array:
    """∇ew[e] = ⟨x[src_e], g[dst_e]⟩ — fp32 residual path.

    x : (n_src, d) saved activation, g : (n_dst, d) output gradient.
    returns (E,) fp32 in original edge order.
    """
    m = layout.meta
    n_src, d = x.shape
    if block_d is None:
        block_d = min(d, 512)
    grid_d = -(-d // block_d)
    pad_d = grid_d * block_d - d
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    if pad_d:
        xf = jnp.pad(xf, ((0, 0), (0, pad_d)))
        gf = jnp.pad(gf, ((0, 0), (0, pad_d)))

    out = pl.pallas_call(
        _sddmm_kernel,
        grid=(m.n_blocks, grid_d),     # feature tiles innermost: the
        in_specs=[                     # (1, block_e) out row accumulates
            pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
            pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
            pl.BlockSpec((n_src, block_d), lambda e, di: (0, di)),
            pl.BlockSpec((gf.shape[0], block_d), lambda e, di: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
        out_shape=jax.ShapeDtypeStruct((m.n_blocks, m.block_e), jnp.float32),
        interpret=interpret,
    )(layout.src_blk, layout.dstg_blk, xf, gf)
    return _scatter_dew(out, layout.perm_blk, m.n_edges)


def _dq_sddmm_kernel(src_ref, dst_ref, packed_ref, scale_ref, zero_ref,
                     g_ref, out_ref, *, bits: int, dp: int, block_d: int):
    di = pl.program_id(1)
    src = src_ref[0, :]
    # which bit-field this feature tile lives in (chunk-interleaved pack)
    chunk = (di * block_d) // dp
    shift = (chunk * bits).astype(jnp.uint8)
    mask = jnp.uint8(2**bits - 1)
    prows = jnp.take(packed_ref[...], src, axis=0)        # (block_e, block_d)
    codes = ((prows >> shift) & mask).astype(jnp.float32)
    xhat = codes * jnp.take(scale_ref[...], src, axis=0) \
        + jnp.take(zero_ref[...], src, axis=0)
    gr = jnp.take(g_ref[...], dst_ref[0, :], axis=0).astype(jnp.float32)
    part = jnp.sum(xhat * gr, axis=-1)

    @pl.when(di == 0)
    def _init():
        out_ref[0, :] = part

    @pl.when(di > 0)
    def _accum():
        out_ref[0, :] += part


@functools.partial(jax.jit, static_argnames=("bits", "dim", "block_d",
                                             "interpret"))
def dequant_sddmm_ew(packed: jax.Array, scale: jax.Array, zero: jax.Array,
                     g: jax.Array, layout, *, bits: int, dim: int,
                     block_d: int | None = None,
                     interpret: bool = True) -> jax.Array:
    """∇ew from the *packed* b-bit residual — shift+mask in-kernel.

    packed : (n_src, dp) uint8 chunk-interleaved codes (dp = dim·bits/8)
    scale/zero : (n_src, 1) fp32, g : (n_dst, dim) float.
    returns (E,) fp32 in original edge order.
    """
    m = layout.meta
    n_src, dp = packed.shape
    cpb = 8 // bits
    assert dp * cpb == dim, f"packed dim mismatch: {dp}*{cpb} != {dim}"
    if block_d is None:
        block_d = _pick_block(dp, 512)
    assert dp % block_d == 0, (dp, block_d)
    grid_d = dim // block_d
    nbt = dp // block_d                # distinct byte tiles (reused cpb×)

    kernel = functools.partial(_dq_sddmm_kernel, bits=bits, dp=dp,
                               block_d=block_d)
    out = pl.pallas_call(
        kernel,
        grid=(m.n_blocks, grid_d),
        in_specs=[
            pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
            pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
            pl.BlockSpec((n_src, block_d), lambda e, di: (0, di % nbt)),
            pl.BlockSpec((n_src, 1), lambda e, di: (0, 0)),
            pl.BlockSpec((n_src, 1), lambda e, di: (0, 0)),
            pl.BlockSpec((g.shape[0], block_d), lambda e, di: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, m.block_e), lambda e, di: (e, 0)),
        out_shape=jax.ShapeDtypeStruct((m.n_blocks, m.block_e), jnp.float32),
        interpret=interpret,
    )(layout.src_blk, layout.dstg_blk, packed, scale,
      zero, g.astype(jnp.float32))
    return _scatter_dew(out, layout.perm_blk, m.n_edges)
