"""Pallas TPU kernels for the ACT hot paths.

  quant_pack.py     fused per-row minmax + SR-quantize + bit-pack
  dequant_matmul.py fused dequantize + H^T.grad GEMM (ACT backward)
  spmm.py           fused KG message passing: forward/transpose SPMM +
                    dequant-SDDMM for ∇ew — no (E, d) message tensor
  topk_score.py     fused dequant·score·running-top-K retrieval over a
                    packed embedding store — no (B, I) score matrix
  ops.py            jit'd wrappers (QTensor I/O, backend switch)
  ref.py            pure-jnp oracles (bit-exact vs the kernels)
  hashrng.py        counter-hash SR noise (TPU analogue of cuRAND-in-kernel)
"""
