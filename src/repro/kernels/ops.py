"""Jit'd public wrappers around the Pallas kernels.

These adapt kernel I/O to the core ``QTensor`` container so the ACT ops in
``repro.core.act`` can switch backends with ``ACTPolicy(kernel="pallas")``.

On this CPU container the kernels run in ``interpret=True`` mode (Pallas
executes the kernel body in Python); on a real TPU set
``repro.kernels.ops.INTERPRET = False`` (the launcher does this when
``jax.default_backend() == "tpu"``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor

from . import dequant_matmul as _dqmm
from . import quant_pack as _qp
from .hashrng import key_to_seed

__all__ = ["quantize", "dequantize", "dequant_matmul", "INTERPRET"]

INTERPRET = jax.default_backend() != "tpu"


def quantize(x: jax.Array, key: jax.Array, *, bits: int = 2,
             stochastic: bool = True) -> QTensor:
    """Fused Pallas quantize+pack -> QTensor (same container as core)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    flat = x.reshape(-1, d)
    packed, scale, zero = _qp.quant_pack(
        flat, key_to_seed(key), bits=bits, stochastic=stochastic,
        interpret=INTERPRET)
    lead = orig_shape[:-1]
    return QTensor(
        packed=packed.reshape(*lead, packed.shape[-1]),
        scale=scale.reshape(*lead, 1),
        zero=zero.reshape(*lead, 1),
        bits=bits,
        dim=d,
        dtype=x.dtype,
    )


def dequantize(q: QTensor) -> jax.Array:
    lead = q.packed.shape[:-1]
    out = _qp.dequant_unpack(
        q.packed.reshape(-1, q.packed.shape[-1]),
        q.scale.reshape(-1, 1), q.zero.reshape(-1, 1),
        bits=q.bits, dim=q.dim, out_dtype=q.dtype, interpret=INTERPRET)
    return out.reshape(*lead, q.dim)


def dequant_matmul(q: QTensor, g: jax.Array) -> jax.Array:
    """Fused ``dequant(q)ᵀ @ g`` — the ACT weight-gradient hot path."""
    n = g.shape[-1]
    return _dqmm.dequant_matmul(
        q.packed.reshape(-1, q.packed.shape[-1]),
        q.scale.reshape(-1, 1), q.zero.reshape(-1, 1),
        g.reshape(-1, n),
        bits=q.bits, dim=q.dim, interpret=INTERPRET)
