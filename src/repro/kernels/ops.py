"""Jit'd public wrappers around the Pallas kernels.

These adapt kernel I/O to the core ``QTensor`` container so the ACT ops in
``repro.core.act`` can switch backends with ``ACTPolicy(kernel="pallas")``.

On this CPU container the kernels run in ``interpret=True`` mode (Pallas
executes the kernel body in Python); on a real TPU set
``repro.kernels.ops.INTERPRET = False`` (the launcher does this when
``jax.default_backend() == "tpu"``).
"""

from __future__ import annotations

import collections

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor
from repro.core.quant import dequantize as core_dequantize
from repro.core.quant import quantize as core_quantize

from . import dequant_matmul as _dqmm
from . import quant_pack as _qp
from . import spmm as _spmm
from .hashrng import key_to_seed

__all__ = ["quantize", "dequantize", "dequant_matmul", "spmm",
           "spmm_grad_ew", "INTERPRET", "TRACE_COUNTS"]

INTERPRET = jax.default_backend() != "tpu"

# trace-time call counters per fused op — lets tests assert that a jitted
# train step actually routed through the Pallas path (each counter bumps
# once per trace, not per execution)
TRACE_COUNTS: collections.Counter = collections.Counter()


def quantize(x: jax.Array, key: jax.Array, *, bits: int = 2,
             stochastic: bool = True) -> QTensor:
    """Fused Pallas quantize+pack -> QTensor (same container as core)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    if d % (8 // bits):
        # the fused kernel needs whole pack-chunks (d % (8/bits) == 0);
        # odd feature dims take the jnp quantizer — same QTensor layout,
        # different (jax.random) SR draws
        return core_quantize(x, key, bits=bits, stochastic=stochastic)
    flat = x.reshape(-1, d)
    packed, scale, zero = _qp.quant_pack(
        flat, key_to_seed(key), bits=bits, stochastic=stochastic,
        interpret=INTERPRET)
    lead = orig_shape[:-1]
    return QTensor(
        packed=packed.reshape(*lead, packed.shape[-1]),
        scale=scale.reshape(*lead, 1),
        zero=zero.reshape(*lead, 1),
        bits=bits,
        dim=d,
        dtype=x.dtype,
    )


def dequantize(q: QTensor) -> jax.Array:
    lead = q.packed.shape[:-1]
    out = _qp.dequant_unpack(
        q.packed.reshape(-1, q.packed.shape[-1]),
        q.scale.reshape(-1, 1), q.zero.reshape(-1, 1),
        bits=q.bits, dim=q.dim, out_dtype=q.dtype, interpret=INTERPRET)
    return out.reshape(*lead, q.dim)


def dequant_matmul(q: QTensor, g: jax.Array) -> jax.Array:
    """Fused ``dequant(q)ᵀ @ g`` — the ACT weight-gradient hot path."""
    n = g.shape[-1]
    dp = q.packed.shape[-1]
    if dp * (8 // q.bits) != q.dim:
        # padded pack from the odd-feature-dim quantizer fallback: the
        # fused kernel's tile indexing assumes whole chunks — dequantize
        # rows and take the plain fp32 GEMM instead of crashing
        xhat = core_dequantize(q).reshape(-1, q.dim)
        return xhat.astype(jnp.float32).T @ g.reshape(-1, n).astype(
            jnp.float32)
    return _dqmm.dequant_matmul(
        q.packed.reshape(-1, dp),
        q.scale.reshape(-1, 1), q.zero.reshape(-1, 1),
        g.reshape(-1, n),
        bits=q.bits, dim=q.dim, interpret=INTERPRET)


def spmm(x: jax.Array, ew: jax.Array | None, layout, *,
         transpose: bool = False) -> jax.Array:
    """Fused gather+scale+segment-accumulate over a blocked-CSR layout.

    Forward aggregation, or with ``transpose=True`` the ∇x scatter
    (``dx = Aᵀ(g · ew)``) — no ``(E, d)`` message tensor in HBM either way.
    """
    TRACE_COUNTS["spmm_t" if transpose else "spmm"] += 1
    return _spmm.spmm(x, ew, layout, transpose=transpose,
                      interpret=INTERPRET)


def spmm_grad_ew(res, g: jax.Array, layout) -> jax.Array:
    """∇ew for the SPMM backward — the fused dequant-SDDMM hot path.

    ``res`` is the saved forward residual: a packed QTensor under an
    active policy (read directly, shift+mask in-kernel) or the raw fp32
    activation otherwise. Returns (E,) fp32 in original edge order.
    """
    if isinstance(res, QTensor):
        dp = res.packed.shape[-1]
        if res.packed.ndim == 2 and dp * (8 // res.bits) == res.dim:
            TRACE_COUNTS["dequant_sddmm"] += 1
            return _spmm.dequant_sddmm_ew(
                res.packed, res.scale, res.zero, g, layout,
                bits=res.bits, dim=res.dim, interpret=INTERPRET)
        # odd feature dim (padded pack): dequantize rows, fp32 SDDMM —
        # still no (E, d) intermediate
        res = core_dequantize(res)
    TRACE_COUNTS["sddmm"] += 1
    return _spmm.sddmm_ew(res, g, layout, interpret=INTERPRET)
