"""Jit'd public wrappers around the Pallas kernels.

These adapt kernel I/O to the core ``QTensor`` container so the ACT ops in
``repro.core.act`` can switch backends with ``ACTPolicy(kernel="pallas")``.

Execution mode comes from ``repro.kernels.backend``: compiled (Mosaic /
Triton) where the runtime supports it, the Pallas interpreter elsewhere
(CPU CI). ``INTERPRET`` remains the module-level knob the launcher and
tests flip; it is initialized from the backend probe instead of a bare
``default_backend() != "tpu"`` guess.

Residency dispatch: the SPMM wrappers compare the gathered-from tables
against ``backend.vmem_budget_bytes()`` and route to the double-buffered
HBM-DMA kernels when a table can no longer be assumed VMEM-resident —
same numerics, same layout, different data movement (DESIGN.md §10).
"""

from __future__ import annotations

import collections

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor

from . import backend as _backend
from . import dequant_matmul as _dqmm
from . import quant_pack as _qp
from . import spmm as _spmm
from .hashrng import key_to_seed

__all__ = ["quantize", "dequantize", "dequant_matmul", "spmm",
           "spmm_grad_ew", "INTERPRET", "TRACE_COUNTS"]

INTERPRET = _backend.interpret_flag(_backend.probe_backend().default_mode)

# trace-time call counters per fused op — lets tests assert that a jitted
# train step actually routed through the Pallas path (each counter bumps
# once per trace, not per execution)
TRACE_COUNTS: collections.Counter = collections.Counter()

# fraction of the VMEM budget one resident table may claim (output tile,
# slot blocks, and double-buffer scratch share the rest)
_VMEM_TABLE_FRACTION = 0.5


def _table_fits_vmem(nbytes: int) -> bool:
    return nbytes <= _VMEM_TABLE_FRACTION * _backend.vmem_budget_bytes()


def quantize(x: jax.Array, key: jax.Array, *, bits: int = 2,
             stochastic: bool = True) -> QTensor:
    """Fused Pallas quantize+pack -> QTensor (same container as core).

    Any feature dim works: ``d % (8/bits) != 0`` pads the last pack chunk
    in-kernel (masked minmax, zero pad codes — layout-identical to
    ``core.quant.pack_bits``) instead of silently falling back to jnp.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    flat = x.reshape(-1, d)
    packed, scale, zero = _qp.quant_pack(
        flat, key_to_seed(key), bits=bits, stochastic=stochastic,
        interpret=INTERPRET)
    lead = orig_shape[:-1]
    return QTensor(
        packed=packed.reshape(*lead, packed.shape[-1]),
        scale=scale.reshape(*lead, 1),
        zero=zero.reshape(*lead, 1),
        bits=bits,
        dim=d,
        dtype=x.dtype,
    )


def dequantize(q: QTensor) -> jax.Array:
    lead = q.packed.shape[:-1]
    out = _qp.dequant_unpack(
        q.packed.reshape(-1, q.packed.shape[-1]),
        q.scale.reshape(-1, 1), q.zero.reshape(-1, 1),
        bits=q.bits, dim=q.dim, out_dtype=q.dtype, interpret=INTERPRET)
    return out.reshape(*lead, q.dim)


def dequant_matmul(q: QTensor, g: jax.Array) -> jax.Array:
    """Fused ``dequant(q)ᵀ @ g`` — the ACT weight-gradient hot path.

    Padded packs (odd feature dims) stay on the fused path: the kernel
    masks the tail features to zero instead of dequantizing rows first.
    """
    n = g.shape[-1]
    dp = q.packed.shape[-1]
    return _dqmm.dequant_matmul(
        q.packed.reshape(-1, dp),
        q.scale.reshape(-1, 1), q.zero.reshape(-1, 1),
        g.reshape(-1, n),
        bits=q.bits, dim=q.dim, interpret=INTERPRET)


def spmm(x: jax.Array, ew: jax.Array | None, layout, *,
         transpose: bool = False) -> jax.Array:
    """Fused gather+scale+segment-accumulate over a blocked-CSR layout.

    Forward aggregation, or with ``transpose=True`` the ∇x scatter
    (``dx = Aᵀ(g · ew)``) — no ``(E, d)`` message tensor in HBM either way.
    Node tables past the VMEM budget route to the double-buffered
    HBM-DMA gather automatically.
    """
    rows, d = x.shape
    dma = not _table_fits_vmem(rows * min(d, 512) * 4)
    key = "spmm_t" if transpose else "spmm"
    TRACE_COUNTS[key + "_dma" if dma else key] += 1
    return _spmm.spmm(x, ew, layout, transpose=transpose, dma=dma,
                      interpret=INTERPRET)


def spmm_grad_ew(res, g: jax.Array, layout) -> jax.Array:
    """∇ew for the SPMM backward — the fused dequant-SDDMM hot path.

    ``res`` is the saved forward residual: a packed QTensor under an
    active policy (read directly, shift+mask in-kernel) or the raw fp32
    activation otherwise. Returns (E,) fp32 in original edge order.
    Resident bytes (packed codes + scale/zero + the g table) past the
    VMEM budget route to the double-buffered HBM-DMA variant.
    """
    if isinstance(res, QTensor) and res.packed.ndim == 2:
        dp = res.packed.shape[-1]
        resident = (res.packed.shape[0] * (dp + 8)
                    + g.shape[0] * g.shape[-1] * 4)
        dma = not _table_fits_vmem(resident)
        TRACE_COUNTS["dequant_sddmm_dma" if dma else "dequant_sddmm"] += 1
        return _spmm.dequant_sddmm_ew(
            res.packed, res.scale, res.zero, g, layout,
            bits=res.bits, dim=res.dim, dma=dma, interpret=INTERPRET)
    if isinstance(res, QTensor):
        # leading-dim-structured residual: dequantize rows, fp32 SDDMM —
        # still no (E, d) intermediate
        from repro.core.quant import dequantize as core_dequantize
        res = core_dequantize(res)
    TRACE_COUNTS["sddmm"] += 1
    return _spmm.sddmm_ew(res, g, layout, interpret=INTERPRET)
