"""Backend probe + per-op execution-mode dispatch for the Pallas kernels.

Before this module, every kernel took a bare ``interpret=`` flag and the
module-level ``ops.INTERPRET`` guessed it from ``jax.default_backend()``.
That conflated three different execution modes that the benchmarks (and
the nightly regression gate) must keep apart:

  ``compiled``   the Pallas kernel lowered to native code — Mosaic on
                 TPU, Triton on GPU. The only mode whose wall-clock is a
                 performance claim.
  ``interpret``  the Pallas interpreter (kernel body emulated op-by-op
                 inside XLA). Parity evidence only; timings are
                 meaningless as perf numbers and must never gate.
  ``jnp``        the unfused XLA reference path (no Pallas at all).

``probe_backend()`` inspects the runtime once; ``resolve_mode()`` maps a
requested mode onto what the runtime can actually deliver, warning ONCE
per op when a compiled request degrades to interpret (CPU has no Pallas
lowering: "Only interpret mode is supported on CPU backend").

The probe's ``fingerprint`` keys the autotune cache (``autotune.py``) so
tile sizes tuned on one backend are never replayed on another.

``vmem_budget_bytes()`` is the single source of truth for "does this
table fit in VMEM" decisions (the DMA-vs-VMEM SPMM dispatch in
``ops.py``); override with ``REPRO_VMEM_BUDGET`` for tests.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import logging
import os

import jax

__all__ = ["BackendInfo", "probe_backend", "resolve_mode", "interpret_flag",
           "vmem_budget_bytes", "pick_block", "MODES", "reset_warnings"]

logger = logging.getLogger("repro.kernels.backend")

MODES = ("compiled", "interpret", "jnp")

# platform -> (pallas compiled lowering available, lowering name)
_LOWERINGS = {
    "tpu": (True, "mosaic"),
    "gpu": (True, "triton"),
    "cuda": (True, "triton"),
    "rocm": (True, "triton"),
}

# default VMEM budget: ~16 MB/core on TPU (see /opt guides); we apply the
# same figure everywhere so interpret-mode CI exercises the same
# DMA-vs-VMEM dispatch decisions a real TPU would take.
_DEFAULT_VMEM_BYTES = 16 * 2**20


@dataclasses.dataclass(frozen=True)
class BackendInfo:
    """What the runtime can execute, probed once per process."""

    platform: str             # cpu | gpu | tpu
    device_kind: str          # e.g. "TPU v5e", "NVIDIA A100", "cpu"
    compiled_available: bool  # Pallas native lowering exists here
    lowering: str             # mosaic | triton | interpret
    n_devices: int
    fingerprint: str          # stable key for the autotune cache

    @property
    def default_mode(self) -> str:
        return "compiled" if self.compiled_available else "interpret"


@functools.lru_cache(maxsize=None)
def probe_backend() -> BackendInfo:
    platform = jax.default_backend()
    compiled, lowering = _LOWERINGS.get(platform, (False, "interpret"))
    devs = jax.devices()
    kind = devs[0].device_kind if devs else platform
    raw = f"{platform}|{kind}|jax{jax.__version__}|{lowering}"
    fp = hashlib.sha1(raw.encode()).hexdigest()[:12]
    return BackendInfo(platform=platform, device_kind=kind,
                       compiled_available=compiled, lowering=lowering,
                       n_devices=len(devs), fingerprint=f"{platform}-{fp}")


_warned_ops: set[str] = set()


def reset_warnings() -> None:
    """Test hook: forget which ops already warned about degraded modes."""
    _warned_ops.clear()


def resolve_mode(requested: str = "auto", *, op: str = "kernel") -> str:
    """Map a requested execution mode onto what this runtime delivers.

    ``auto``      -> compiled where available, else interpret.
    ``compiled``  -> compiled where available; else interpret, with a
                     warning logged ONCE per op (benchmarks stay honest:
                     the caller records the *resolved* mode).
    ``interpret`` / ``jnp`` -> themselves (always available).
    """
    if requested not in ("auto",) + MODES:
        raise ValueError(f"unknown mode {requested!r}; "
                         f"expected one of {('auto',) + MODES}")
    b = probe_backend()
    if requested == "auto":
        return b.default_mode
    if requested == "compiled" and not b.compiled_available:
        if op not in _warned_ops:
            _warned_ops.add(op)
            logger.warning(
                "compiled Pallas requested for %s but backend=%s has no "
                "native lowering (%s); delivering interpret mode — "
                "timings from this path are parity evidence, not "
                "performance", op, b.platform, b.device_kind)
        return "interpret"
    return requested


def interpret_flag(mode: str) -> bool:
    """The ``interpret=`` argument for ``pl.pallas_call`` under ``mode``."""
    return mode != "compiled"


def vmem_budget_bytes() -> int:
    """Bytes of VMEM a single kernel may assume resident for its tables."""
    env = os.environ.get("REPRO_VMEM_BUDGET")
    if env:
        return int(env)
    return _DEFAULT_VMEM_BYTES


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (legacy heuristic).

    Shared by the kernels as the autotune-miss default; previously
    duplicated in ``spmm.py`` and ``dequant_matmul.py``.
    """
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b
