"""Pallas TPU kernels: fused dequantize·score·running-top-K retrieval,
plus the two-stage COARSE candidate scan over the packed code domain.

Serving-side generalization of ``dequant_matmul.py``'s in-kernel
shift+mask unpack: score a block of query vectors against a PACKED
item-embedding store and keep only the running top-K — the dense
``(B, I)`` score matrix never exists, in VMEM beyond one item chunk or
in HBM at all:

    HBM read : packed uint8 (I·d·b/8) + scale/zero (8I) + q (B·d·4)
               + exclusion lists (B·P·4)
    HBM write: top-K values + indices (B·K·8)

vs the unfused serving path which dequantizes the store (I·d·4) AND
materializes all scores (B·I·4). Grid is 1-D over item chunks; the two
output blocks (values, indices) map every grid step to block (0, 0) —
the standard revisiting pattern (cf. ``dequant_matmul``'s r-innermost
accumulator), here carrying a running top-K instead of a partial GEMM.

Exactness contract (tested, incl. ties): the merge is LOSSLESS — the
result is bit-identical to ``jax.lax.top_k`` over the full score row as
computed chunk-wise (an independently-computed dense matmul can differ
in value ulps from reduction reassociation, never in tie order or in
which items win by more than fp32 matmul tolerance). lax.top_k breaks
ties by
lowest index; chunk ``c``'s candidate indices are all larger than every
index already in the running top-K, and within the running top-K ties
are (inductively) in ascending-index order — so concatenating
``[running, candidates]`` and re-taking top-K preserves the global
tie order at every merge, including ties that straddle chunk
boundaries. This requires ``block_i >= k`` (enforced by the wrapper) so
the first chunk can seed the running state without -inf sentinels.

Per-user exclusion (train positives at eval, already-seen items in
production) rides in as padded index lists — (B, P) int32, pad = -1 —
and is applied to candidate scores IN-KERNEL before the merge, which is
exactly equivalent to the dense reference's ``where(train_mask, -inf)``
without ever building a (B, I) mask.

Coarse candidate scan (``fused_coarse_topm``): the two-stage retrieval
path (serving/scorer.py:two_stage_topk) scans ALL items while staying in
the packed integer-code domain — the per-item dequantize multiply-add is
hoisted OUT of the (B × I) score computation into a per-row affine
correction applied to the integer dot product:

    true score  t_i = q · (c_i·s_i + z_i·1) = s_i (q·c_i) + z_i Σ_j q_j
    coarse        ≈ qs·s_i (q8·c_i) + z_i Σ_j q_j

with ``q8 = clip(round(q/qs), ±127)`` a symmetric INT8 query (``qs =
max|q|/127`` per row) — the ONLY approximation is the query rounding,
bounded by |coarse - true| ≤ (qs/2)·‖x̂_i‖₁ (DESIGN.md §14). Both
``q8`` and the codes ride as integer-VALUED fp32, so every product and
the d-length dot are exactly representable (|q8·c| ≤ 127·255·d < 2²⁴
for d ≤ 512): the kernel and the jnp mirror agree to ZERO ulps, and the
scan's HBM traffic is the packed bytes — no fp32 item row ever
materializes. The merge machinery (running top-m, lossless tie order,
exclusion before merge) is shared with the exact kernel above.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune

__all__ = ["fused_topk_scores", "fused_coarse_topm"]

_NEG_INF = float("-inf")  # plain float: a jnp scalar would be captured
#                           as a kernel constant, which pallas_call rejects


def _unpack_codes(packed, *, bits: int, dim: int, cpb: int):
    """Chunk-interleaved unpack (same layout as quant_pack.py): byte j of
    a row holds codes [j, dp + j, 2*dp + j, ...] in bits-wide fields."""
    if cpb == 1:
        return packed[:, :dim].astype(jnp.float32)
    mask = jnp.uint8(2**bits - 1)
    chunks = [(packed >> jnp.uint8(kk * bits)) & mask
              for kk in range(cpb)]
    return jnp.concatenate(chunks, axis=-1)[:, :dim].astype(jnp.float32)


def _mask_merge(c, scores, excl, vals_ref, idx_ref, *, k: int,
                block_i: int, n_items: int):
    """Shared tail of both kernels: mask ghosts + exclusions, then the
    lossless running top-``k`` merge (tie-order argument above)."""
    b = scores.shape[0]
    ids = c * block_i + jax.lax.broadcasted_iota(jnp.int32, (1, block_i), 1)
    ids = jnp.broadcast_to(ids, (b, block_i))       # (B, block_i) global ids
    # tail-chunk padding rows score as garbage — mask them out
    scores = jnp.where(ids < n_items, scores, _NEG_INF)
    # per-user exclusion lists: (B, P) global item ids, -1 = pad (never hits)
    hit = jnp.any(excl[:, :, None] == ids[:, None, :], axis=1)
    scores = jnp.where(hit, _NEG_INF, scores)

    @pl.when(c == 0)
    def _seed():
        v, p = jax.lax.top_k(scores, k)
        vals_ref[...] = v
        idx_ref[...] = jnp.take_along_axis(ids, p, axis=1)

    @pl.when(c > 0)
    def _merge():
        all_v = jnp.concatenate([vals_ref[...], scores], axis=1)
        all_i = jnp.concatenate([idx_ref[...], ids], axis=1)
        v, p = jax.lax.top_k(all_v, k)
        vals_ref[...] = v
        idx_ref[...] = jnp.take_along_axis(all_i, p, axis=1)


def _topk_kernel(q_ref, packed_ref, scale_ref, zero_ref, excl_ref,
                 vals_ref, idx_ref, *, bits: int, dim: int, dp: int,
                 cpb: int, k: int, block_i: int, n_items: int):
    c = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)          # (B, dim)
    codes = _unpack_codes(packed_ref[...], bits=bits, dim=dim, cpb=cpb)
    xhat = codes * scale_ref[...] + zero_ref[...]   # (block_i, dim)
    scores = jax.lax.dot_general(
        q, xhat, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (B, block_i)
    _mask_merge(c, scores, excl_ref[...], vals_ref, idx_ref, k=k,
                block_i=block_i, n_items=n_items)


def _coarse_kernel(q8_ref, qmeta_ref, packed_ref, scale_ref, zero_ref,
                   excl_ref, vals_ref, idx_ref, *, bits: int, dim: int,
                   dp: int, cpb: int, m: int, block_i: int, n_items: int):
    """Coarse scan: integer dot + per-row affine correction — the item
    rows are NEVER dequantized (module docstring has the math)."""
    c = pl.program_id(0)
    q8 = q8_ref[...]                            # (B, dim) int-valued fp32
    codes = _unpack_codes(packed_ref[...], bits=bits, dim=dim, cpb=cpb)
    dot = jax.lax.dot_general(
        q8, codes, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (B, block_i), exact
    qmeta = qmeta_ref[...]                          # (B, 2): [qs, Σq]
    scale_t = jnp.transpose(scale_ref[...])         # (1, block_i)
    zero_t = jnp.transpose(zero_ref[...])
    scores = dot * (qmeta[:, 0:1] * scale_t) + qmeta[:, 1:2] * zero_t
    _mask_merge(c, scores, excl_ref[...], vals_ref, idx_ref, k=m,
                block_i=block_i, n_items=n_items)


@functools.partial(jax.jit,
                   static_argnames=("bits", "dim", "k", "n_items",
                                    "block_i", "interpret"))
def _topk_call(q: jax.Array, packed: jax.Array, scale: jax.Array,
               zero: jax.Array, excl: jax.Array, *, bits: int,
               dim: int, k: int, n_items: int, block_i: int,
               interpret: bool):
    rows, dp = packed.shape
    assert rows == n_items, (rows, n_items)
    cpb = 8 // bits
    # dp*cpb > dim for padded packs: the in-kernel unpack slices [:dim],
    # dropping the zero pad codes, so padded stores score identically
    assert dp * cpb >= dim, f"packed dim mismatch: {dp}*{cpb} < {dim}"
    block_i = max(min(block_i, rows), k)   # first chunk must seed k entries
    grid_i = -(-rows // block_i)
    pad_i = grid_i * block_i - rows
    if pad_i:
        packed = jnp.pad(packed, ((0, pad_i), (0, 0)))
        scale = jnp.pad(scale, ((0, pad_i), (0, 0)))
        zero = jnp.pad(zero, ((0, pad_i), (0, 0)))
    b, _ = q.shape
    p = excl.shape[1]
    kernel = functools.partial(
        _topk_kernel, bits=bits, dim=dim, dp=dp, cpb=cpb, k=k,
        block_i=block_i, n_items=n_items)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(grid_i,),
        in_specs=[
            pl.BlockSpec((b, dim), lambda c: (0, 0)),
            pl.BlockSpec((block_i, dp), lambda c: (c, 0)),
            pl.BlockSpec((block_i, 1), lambda c: (c, 0)),
            pl.BlockSpec((block_i, 1), lambda c: (c, 0)),
            pl.BlockSpec((b, p), lambda c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda c: (0, 0)),
            pl.BlockSpec((b, k), lambda c: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), packed, scale, zero, excl.astype(jnp.int32))
    return vals, idx


def fused_topk_scores(q: jax.Array, packed: jax.Array, scale: jax.Array,
                      zero: jax.Array, excl: jax.Array, *, bits: int,
                      dim: int, k: int, n_items: int,
                      block_i: int | None = None,
                      interpret: bool = True):
    """Top-K of ``q @ dequant(packed, scale, zero)ᵀ`` with exclusions.

    q      : (B, dim) fp32 query vectors (dequantized user rows)
    packed : (I, dp) uint8 chunk-interleaved codes, dp·(8/bits) >= dim
    scale  : (I, 1) fp32, zero: (I, 1) fp32
    excl   : (B, P) int32 item ids to force to -inf per row; -1 pads
    returns (values (B, k) fp32, indices (B, k) int32) — bit-identical to
    ``jax.lax.top_k`` over the dense masked score row.

    ``block_i=None`` consults the autotune cache for the item chunk size
    (measured winners per shape-bucket/bits/backend; old fixed 1024 on a
    miss). The merge is lossless at ANY block_i >= k, so tuning it is
    perf-only — the exactness contract above is block-size independent.
    """
    rows, _ = packed.shape
    if block_i is None:
        tuner = autotune.get()
        measure = None
        if tuner.sweep and not isinstance(q, jax.core.Tracer):
            def measure(params):
                jax.block_until_ready(_topk_call(
                    q, packed, scale, zero, excl, bits=bits, dim=dim,
                    k=k, n_items=n_items, interpret=interpret, **params))
        block_i = tuner.pick(
            "topk_score", shapes=(rows, dim, q.shape[0]), bits=bits,
            extra=f"k{k}",
            candidates=[{"block_i": c} for c in (256, 512, 1024, 2048)],
            measure=measure, default={"block_i": 1024})["block_i"]
    return _topk_call(q, packed, scale, zero, excl, bits=bits, dim=dim,
                      k=k, n_items=n_items, block_i=block_i,
                      interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("bits", "dim", "m", "n_items",
                                    "block_i", "interpret"))
def _coarse_call(q8: jax.Array, qmeta: jax.Array, packed: jax.Array,
                 scale: jax.Array, zero: jax.Array, excl: jax.Array, *,
                 bits: int, dim: int, m: int, n_items: int, block_i: int,
                 interpret: bool):
    rows, dp = packed.shape
    assert rows == n_items, (rows, n_items)
    cpb = 8 // bits
    assert dp * cpb >= dim, f"packed dim mismatch: {dp}*{cpb} < {dim}"
    block_i = max(min(block_i, rows), m)   # first chunk must seed m entries
    grid_i = -(-rows // block_i)
    pad_i = grid_i * block_i - rows
    if pad_i:
        packed = jnp.pad(packed, ((0, pad_i), (0, 0)))
        scale = jnp.pad(scale, ((0, pad_i), (0, 0)))
        zero = jnp.pad(zero, ((0, pad_i), (0, 0)))
    b, _ = q8.shape
    p = excl.shape[1]
    kernel = functools.partial(
        _coarse_kernel, bits=bits, dim=dim, dp=dp, cpb=cpb, m=m,
        block_i=block_i, n_items=n_items)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(grid_i,),
        in_specs=[
            pl.BlockSpec((b, dim), lambda c: (0, 0)),
            pl.BlockSpec((b, 2), lambda c: (0, 0)),
            pl.BlockSpec((block_i, dp), lambda c: (c, 0)),
            pl.BlockSpec((block_i, 1), lambda c: (c, 0)),
            pl.BlockSpec((block_i, 1), lambda c: (c, 0)),
            pl.BlockSpec((b, p), lambda c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, m), lambda c: (0, 0)),
            pl.BlockSpec((b, m), lambda c: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), jnp.float32),
            jax.ShapeDtypeStruct((b, m), jnp.int32),
        ],
        interpret=interpret,
    )(q8.astype(jnp.float32), qmeta.astype(jnp.float32), packed, scale,
      zero, excl.astype(jnp.int32))
    return vals, idx


def fused_coarse_topm(q8: jax.Array, qmeta: jax.Array, packed: jax.Array,
                      scale: jax.Array, zero: jax.Array, excl: jax.Array, *,
                      bits: int, dim: int, m: int, n_items: int,
                      block_i: int | None = None, interpret: bool = True):
    """Top-``m`` CANDIDATES by coarse packed-domain score, with exclusions.

    q8     : (B, dim) symmetric-INT8 query codes as integer-valued fp32
             (``serving/scorer.py:quantize_query``)
    qmeta  : (B, 2) fp32 — column 0 the query scale ``qs``, column 1 the
             fp32 query row-sum ``Σ_j q_j``
    packed/scale/zero/excl: as :func:`fused_topk_scores`
    returns (coarse values (B, m) fp32, indices (B, m) int32); the merge
    is lossless over the COARSE scores (same tie contract), and the jnp
    mirror in serving/scorer.py agrees to zero ulps — every arithmetic
    value is integer-valued fp32 until the final affine correction,
    which both paths apply with the identical op sequence.
    """
    rows, _ = packed.shape
    if block_i is None:
        tuner = autotune.get()
        measure = None
        if tuner.sweep and not isinstance(q8, jax.core.Tracer):
            def measure(params):
                jax.block_until_ready(_coarse_call(
                    q8, qmeta, packed, scale, zero, excl, bits=bits,
                    dim=dim, m=m, n_items=n_items, interpret=interpret,
                    **params))
        block_i = tuner.pick(
            "topk_coarse", shapes=(rows, dim, q8.shape[0]), bits=bits,
            extra=f"m{m}",
            candidates=[{"block_i": c} for c in (256, 512, 1024, 2048)],
            measure=measure, default={"block_i": 1024})["block_i"]
    return _coarse_call(q8, qmeta, packed, scale, zero, excl, bits=bits,
                        dim=dim, m=m, n_items=n_items, block_i=block_i,
                        interpret=interpret)
