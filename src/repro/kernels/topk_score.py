"""Pallas TPU kernel: fused dequantize + score + running top-K retrieval.

Serving-side generalization of ``dequant_matmul.py``'s in-kernel
shift+mask unpack: score a block of query vectors against a PACKED
item-embedding store and keep only the running top-K — the dense
``(B, I)`` score matrix never exists, in VMEM beyond one item chunk or
in HBM at all:

    HBM read : packed uint8 (I·d·b/8) + scale/zero (8I) + q (B·d·4)
               + exclusion lists (B·P·4)
    HBM write: top-K values + indices (B·K·8)

vs the unfused serving path which dequantizes the store (I·d·4) AND
materializes all scores (B·I·4). Grid is 1-D over item chunks; the two
output blocks (values, indices) map every grid step to block (0, 0) —
the standard revisiting pattern (cf. ``dequant_matmul``'s r-innermost
accumulator), here carrying a running top-K instead of a partial GEMM.

Exactness contract (tested, incl. ties): the merge is LOSSLESS — the
result is bit-identical to ``jax.lax.top_k`` over the full score row as
computed chunk-wise (an independently-computed dense matmul can differ
in value ulps from reduction reassociation, never in tie order or in
which items win by more than fp32 matmul tolerance). lax.top_k breaks
ties by
lowest index; chunk ``c``'s candidate indices are all larger than every
index already in the running top-K, and within the running top-K ties
are (inductively) in ascending-index order — so concatenating
``[running, candidates]`` and re-taking top-K preserves the global
tie order at every merge, including ties that straddle chunk
boundaries. This requires ``block_i >= k`` (enforced by the wrapper) so
the first chunk can seed the running state without -inf sentinels.

Per-user exclusion (train positives at eval, already-seen items in
production) rides in as padded index lists — (B, P) int32, pad = -1 —
and is applied to candidate scores IN-KERNEL before the merge, which is
exactly equivalent to the dense reference's ``where(train_mask, -inf)``
without ever building a (B, I) mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune

__all__ = ["fused_topk_scores"]

_NEG_INF = float("-inf")  # plain float: a jnp scalar would be captured
#                           as a kernel constant, which pallas_call rejects


def _topk_kernel(q_ref, packed_ref, scale_ref, zero_ref, excl_ref,
                 vals_ref, idx_ref, *, bits: int, dim: int, dp: int,
                 cpb: int, k: int, block_i: int, n_items: int):
    c = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)          # (B, dim)
    packed = packed_ref[...]                    # (block_i, dp)
    # chunk-interleaved unpack (same layout as quant_pack.py): byte j of a
    # row holds codes [j, dp + j, 2*dp + j, ...] in bits-wide fields
    if cpb == 1:
        codes = packed[:, :dim].astype(jnp.float32)
    else:
        mask = jnp.uint8(2**bits - 1)
        chunks = [(packed >> jnp.uint8(kk * bits)) & mask
                  for kk in range(cpb)]
        codes = jnp.concatenate(chunks, axis=-1)[:, :dim].astype(jnp.float32)
    xhat = codes * scale_ref[...] + zero_ref[...]   # (block_i, dim)
    scores = jax.lax.dot_general(
        q, xhat, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (B, block_i)

    b = q.shape[0]
    ids = c * block_i + jax.lax.broadcasted_iota(jnp.int32, (1, block_i), 1)
    ids = jnp.broadcast_to(ids, (b, block_i))       # (B, block_i) global ids
    # tail-chunk padding rows score as garbage — mask them out
    scores = jnp.where(ids < n_items, scores, _NEG_INF)
    # per-user exclusion lists: (B, P) global item ids, -1 = pad (never hits)
    excl = excl_ref[...]
    hit = jnp.any(excl[:, :, None] == ids[:, None, :], axis=1)
    scores = jnp.where(hit, _NEG_INF, scores)

    @pl.when(c == 0)
    def _seed():
        v, p = jax.lax.top_k(scores, k)
        vals_ref[...] = v
        idx_ref[...] = jnp.take_along_axis(ids, p, axis=1)

    @pl.when(c > 0)
    def _merge():
        all_v = jnp.concatenate([vals_ref[...], scores], axis=1)
        all_i = jnp.concatenate([idx_ref[...], ids], axis=1)
        v, p = jax.lax.top_k(all_v, k)
        vals_ref[...] = v
        idx_ref[...] = jnp.take_along_axis(all_i, p, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("bits", "dim", "k", "n_items",
                                    "block_i", "interpret"))
def _topk_call(q: jax.Array, packed: jax.Array, scale: jax.Array,
               zero: jax.Array, excl: jax.Array, *, bits: int,
               dim: int, k: int, n_items: int, block_i: int,
               interpret: bool):
    rows, dp = packed.shape
    assert rows == n_items, (rows, n_items)
    cpb = 8 // bits
    # dp*cpb > dim for padded packs: the in-kernel unpack slices [:dim],
    # dropping the zero pad codes, so padded stores score identically
    assert dp * cpb >= dim, f"packed dim mismatch: {dp}*{cpb} < {dim}"
    block_i = max(min(block_i, rows), k)   # first chunk must seed k entries
    grid_i = -(-rows // block_i)
    pad_i = grid_i * block_i - rows
    if pad_i:
        packed = jnp.pad(packed, ((0, pad_i), (0, 0)))
        scale = jnp.pad(scale, ((0, pad_i), (0, 0)))
        zero = jnp.pad(zero, ((0, pad_i), (0, 0)))
    b, _ = q.shape
    p = excl.shape[1]
    kernel = functools.partial(
        _topk_kernel, bits=bits, dim=dim, dp=dp, cpb=cpb, k=k,
        block_i=block_i, n_items=n_items)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(grid_i,),
        in_specs=[
            pl.BlockSpec((b, dim), lambda c: (0, 0)),
            pl.BlockSpec((block_i, dp), lambda c: (c, 0)),
            pl.BlockSpec((block_i, 1), lambda c: (c, 0)),
            pl.BlockSpec((block_i, 1), lambda c: (c, 0)),
            pl.BlockSpec((b, p), lambda c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda c: (0, 0)),
            pl.BlockSpec((b, k), lambda c: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), packed, scale, zero, excl.astype(jnp.int32))
    return vals, idx


def fused_topk_scores(q: jax.Array, packed: jax.Array, scale: jax.Array,
                      zero: jax.Array, excl: jax.Array, *, bits: int,
                      dim: int, k: int, n_items: int,
                      block_i: int | None = None,
                      interpret: bool = True):
    """Top-K of ``q @ dequant(packed, scale, zero)ᵀ`` with exclusions.

    q      : (B, dim) fp32 query vectors (dequantized user rows)
    packed : (I, dp) uint8 chunk-interleaved codes, dp·(8/bits) >= dim
    scale  : (I, 1) fp32, zero: (I, 1) fp32
    excl   : (B, P) int32 item ids to force to -inf per row; -1 pads
    returns (values (B, k) fp32, indices (B, k) int32) — bit-identical to
    ``jax.lax.top_k`` over the dense masked score row.

    ``block_i=None`` consults the autotune cache for the item chunk size
    (measured winners per shape-bucket/bits/backend; old fixed 1024 on a
    miss). The merge is lossless at ANY block_i >= k, so tuning it is
    perf-only — the exactness contract above is block-size independent.
    """
    rows, _ = packed.shape
    if block_i is None:
        tuner = autotune.get()
        measure = None
        if tuner.sweep and not isinstance(q, jax.core.Tracer):
            def measure(params):
                jax.block_until_ready(_topk_call(
                    q, packed, scale, zero, excl, bits=bits, dim=dim,
                    k=k, n_items=n_items, interpret=interpret, **params))
        block_i = tuner.pick(
            "topk_score", shapes=(rows, dim, q.shape[0]), bits=bits,
            extra=f"k{k}",
            candidates=[{"block_i": c} for c in (256, 512, 1024, 2048)],
            measure=measure, default={"block_i": 1024})["block_i"]
    return _topk_call(q, packed, scale, zero, excl, bits=bits, dim=dim,
                      k=k, n_items=n_items, block_i=block_i,
                      interpret=interpret)
