"""Counter-based uniform hash used for in-kernel stochastic rounding.

The GPU paper draws SR noise from cuRAND global state inside the CUDA
kernel. TPU Pallas has ``pltpu.prng_random_bits``, but a stateless
counter hash (murmur3 finalizer over element index ⊕ seed) is:
  * identical in interpret mode (CPU) and on real TPU,
  * reproducible across restarts (fault-tolerant replay),
  * free of HBM traffic (no pre-generated noise tensor),
  * expressible in plain jnp — so the ref.py oracle matches bit-exactly.

Statistical quality is far beyond what SR needs (murmur3 passes avalanche;
SR only needs E[u]=1/2 uniformity per element).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["hash_uniform", "key_to_seed"]


def _murmur3_fmix(x: jax.Array) -> jax.Array:
    """murmur3 32-bit finalizer; input/output uint32."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def hash_uniform(idx: jax.Array, seed: jax.Array) -> jax.Array:
    """U[0,1) floats from uint32 element indices + uint32 scalar seed."""
    h = _murmur3_fmix(idx.astype(jnp.uint32) ^ seed.astype(jnp.uint32))
    # 24 mantissa bits -> exact float32 in [0, 1)
    return (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def key_to_seed(key: jax.Array) -> jax.Array:
    """Fold a jax PRNG key down to a uint32 scalar seed."""
    data = jax.random.key_data(key).astype(jnp.uint32)
    return _murmur3_fmix(data[..., 0] ^ _murmur3_fmix(data[..., -1]))
